"""Per-kernel validation (deliverable c): shape/dtype sweeps in
interpret=True mode against the pure-jnp oracles in each ref.py."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.packing import pack_tokens
from repro.kernels.flash_attention import attention_ref, flash_attention
from repro.kernels.histogram import histogram_ref, token_histogram
from repro.kernels.token_pack import (delta_zigzag_device, delta_zigzag_ref,
                                      pack_fixed_batch_device, pack_ref,
                                      pack_tokens_device)

RNG = np.random.default_rng(0)


# -- flash attention ---------------------------------------------------------

SWEEP = [
    # B, Sq, Skv, Hq, Hkv, hd, causal, window, cap
    (2, 128, 128, 4, 2, 64, True, 0, 0.0),
    (1, 256, 256, 4, 4, 32, True, 64, 0.0),
    (2, 128, 128, 8, 1, 64, True, 0, 50.0),     # MQA + gemma2 softcap
    (1, 96, 96, 2, 2, 64, True, 0, 0.0),        # pad path
    (2, 1, 384, 4, 2, 64, True, 0, 0.0),        # decode with offset
    (1, 64, 64, 2, 2, 128, True, 0, 0.0),       # hw-aligned head dim
]


@pytest.mark.parametrize("case", SWEEP, ids=[str(i) for i in range(len(SWEEP))])
@pytest.mark.parametrize("dtype", [jnp.float32, jnp.bfloat16])
def test_flash_attention_vs_ref(case, dtype):
    B, Sq, Skv, Hq, Hkv, hd, causal, window, cap = case
    off = Skv - Sq if Sq < Skv else 0
    q = jnp.asarray(RNG.normal(size=(B, Sq, Hq, hd)), dtype)
    k = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    v = jnp.asarray(RNG.normal(size=(B, Skv, Hkv, hd)), dtype)
    out = flash_attention(q, k, v, causal=causal, window=window, softcap=cap,
                          block_q=64, block_kv=64, q_offset=off, interpret=True)
    ref = attention_ref(q.transpose(0, 2, 1, 3), k.transpose(0, 2, 1, 3),
                        v.transpose(0, 2, 1, 3), causal=causal, window=window,
                        softcap=cap, q_offset=off).transpose(0, 2, 1, 3)
    tol = 2e-5 if dtype == jnp.float32 else 2e-2
    np.testing.assert_allclose(np.asarray(out, np.float32),
                               np.asarray(ref, np.float32), rtol=tol, atol=tol)


def test_flash_attention_matches_model_engine():
    """Kernel == the model's blockwise/flash jnp engines (one oracle)."""
    from repro.models.attention import blockwise_attention, flash_self_attention

    q = jnp.asarray(RNG.normal(size=(2, 128, 4, 32)), jnp.float32)
    k = jnp.asarray(RNG.normal(size=(2, 128, 2, 32)), jnp.float32)
    v = jnp.asarray(RNG.normal(size=(2, 128, 2, 32)), jnp.float32)
    pos = jnp.arange(128, dtype=jnp.int32)
    a = flash_attention(q, k, v, block_q=64, block_kv=64, interpret=True)
    b = blockwise_attention(q, k, v, pos, pos, block_q=64, block_kv=64)
    c = flash_self_attention(q, k, v, True, 0, 0.0, None, (64, 64), 0)
    np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-5)
    np.testing.assert_allclose(np.asarray(a), np.asarray(c), rtol=1e-5, atol=1e-5)


# -- token pack --------------------------------------------------------------

@pytest.mark.parametrize("n,hi", [(1, 60000), (777, 60000), (2048, 60000),
                                  (4096, 100000), (3000, 2**31 - 1)])
def test_pack_kernel_bit_identical(n, hi):
    ids = RNG.integers(0, hi, n)
    fb, data = pack_tokens_device(ids)
    assert bytes([fb]) + data == pack_tokens(ids, "fixed")


@settings(max_examples=30, deadline=None)
@given(st.lists(st.integers(0, 2**31 - 1), min_size=1, max_size=200))
def test_pack_kernel_property(ids):
    arr = np.asarray(ids, np.uint32)
    fb, data = pack_tokens_device(arr)
    assert bytes([fb]) + data == pack_tokens(arr, "fixed")


def test_pack_batch_kernel_matches_numpy():
    """Pallas batch path (one launch per width group, interpret mode) is
    bit-identical to per-stream pack_fixed — mixed widths, empty streams,
    and non-block-multiple lengths in one batch."""
    streams = [RNG.integers(0, 60000, 37),          # u16
               RNG.integers(0, 2**31 - 1, 2048),    # u32, block-aligned
               np.zeros(0, np.uint32),              # empty
               RNG.integers(0, 100, 1),             # u16 single
               RNG.integers(0, 100352, 555),        # u32 (special-token range)
               RNG.integers(0, 65536, 4097)]        # u16, crosses a block boundary
    got = pack_fixed_batch_device(streams, interpret=True)
    want = [pack_tokens(ids, "fixed") for ids in streams]
    assert got == want


@settings(max_examples=15, deadline=None)
@given(st.lists(st.lists(st.integers(0, 2**31 - 1), max_size=100), max_size=8))
def test_pack_batch_kernel_property(streams):
    arrs = [np.asarray(s, np.uint32) for s in streams]
    got = pack_fixed_batch_device(arrs, interpret=True)
    assert got == [pack_tokens(a, "fixed") for a in arrs]


def test_pack_ref_widths():
    ids = jnp.asarray([0, 1, 255, 256, 65535], jnp.int32)
    b2 = pack_ref(ids, 2)
    assert b2.shape == (5, 2)
    assert bytes(np.asarray(b2[4])) == b"\xff\xff"


def test_delta_zigzag_kernel():
    ids = jnp.asarray(RNG.integers(0, 2**30, 3000), jnp.int32)
    prev = jnp.concatenate([jnp.zeros(1, ids.dtype), ids[:-1]])
    np.testing.assert_array_equal(np.asarray(delta_zigzag_device(ids)),
                                  np.asarray(delta_zigzag_ref(ids, prev)))


# -- histogram ---------------------------------------------------------------

@pytest.mark.parametrize("n,v", [(100, 512), (5000, 8192), (4096, 100352),
                                 (1, 8), (1024, 2048)])
def test_histogram_vs_ref(n, v):
    ids = jnp.asarray(RNG.integers(0, v, n), jnp.int32)
    h = token_histogram(ids, v)
    np.testing.assert_array_equal(np.asarray(h), np.asarray(histogram_ref(ids, v)))
    assert int(np.asarray(h).sum()) == n


@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 511), min_size=1, max_size=300))
def test_histogram_property(ids):
    arr = jnp.asarray(ids, jnp.int32)
    h = np.asarray(token_histogram(arr, 512))
    assert h.sum() == len(ids)
    ref = np.bincount(np.asarray(ids), minlength=512)
    np.testing.assert_array_equal(h, ref)


def test_histogram_ignores_padding_ids():
    ids = jnp.asarray([-1, 3, 3, -1, 7], jnp.int32)
    h = np.asarray(token_histogram(ids, 8))
    assert h[3] == 2 and h[7] == 1 and h.sum() == 3
