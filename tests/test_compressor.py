"""LoPace engine tests: the paper's lossless guarantee (§3.5), method
ordering (§5.1), backends, frames, adaptive selection, entropy accounting."""

import hashlib

import pytest
from _hypothesis_compat import given, settings, st

from repro.core import (AdaptiveCompressor, PromptCompressor, compress_hybrid,
                        compress_token, compress_zstd, decompress_hybrid,
                        decompress_token, decompress_zstd, hybrid_tokens)
from repro.core.entropy import bits_per_char, efficiency, shannon_entropy, theoretical_cr
from repro.core.zstd_backend import (BACKENDS, HAVE_ZSTD, compress_bytes,
                                     decompress_bytes)
from repro.data.corpus import generate_corpus
from repro.tokenizer.vocab import default_tokenizer


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


@pytest.fixture(scope="module")
def prompts():
    return generate_corpus(8, seed=11)


METHODS = ["zstd", "token", "hybrid"]


@pytest.mark.parametrize("method", METHODS)
def test_lossless_on_corpus(tok, prompts, method):
    """Paper §5.10: zero reconstruction error, SHA-256 verified."""
    pc = PromptCompressor(tok, method=method)
    for p in prompts[:5]:
        v = pc.verify(p.text)
        assert v["exact_match"] and v["sha256_match"]
        assert v["reconstruction_errors"] == 0


@settings(max_examples=40, deadline=None)
@given(text=st.text(min_size=0, max_size=400),
       method=st.sampled_from(METHODS))
def test_lossless_property(text, method):
    tok = default_tokenizer()
    pc = PromptCompressor(tok, method=method)
    assert pc.decompress(pc.compress(text)) == text


@settings(max_examples=25, deadline=None)
@given(text=st.text(alphabet=st.characters(codec="utf-8"), max_size=300))
def test_lossless_arbitrary_unicode(text):
    tok = default_tokenizer()
    pc = PromptCompressor(tok, method="hybrid")
    assert pc.decompress(pc.compress(text)) == text


def test_method_ordering(tok, prompts):
    """Hybrid >= zstd >> token on redundant prompts (paper §5.1)."""
    big = max(prompts, key=lambda p: p.n_chars)
    raw = len(big.text.encode())
    sizes = {m: len(PromptCompressor(tok, method=m).compress_raw(big.text))
             for m in METHODS}
    assert raw / sizes["hybrid"] > 2.0
    assert sizes["hybrid"] <= sizes["zstd"] * 1.05
    assert sizes["token"] > sizes["hybrid"]


def test_token_method_uint32_expansion(tok):
    """§3.3.4: specials push ids > 65535 -> 4B/token; short ASCII text can
    then expand (negative space savings), which hybrid repairs."""
    text = "<|system|>ab<|user|>cd<|assistant|>" * 3
    token_payload = compress_token(text, tok)
    assert token_payload[0] == 0x01  # uint32
    hybrid_payload = compress_hybrid(text, tok, level=15)
    assert len(hybrid_payload) < len(token_payload)


def test_paper_exact_functions(tok):
    text = "compress me " * 50
    assert decompress_zstd(compress_zstd(text)) == text
    assert decompress_token(compress_token(text, tok), tok) == text
    assert decompress_hybrid(compress_hybrid(text, tok), tok) == text


def test_token_stream_mode(tok):
    """§8.4.2 #10: hybrid payload -> token ids without detokenization."""
    text = "def main():\n    return 42\n" * 20
    payload = compress_hybrid(text, tok)
    ids = hybrid_tokens(payload)
    assert list(ids) == tok.encode(text)


def test_cross_instance_compatibility(tok):
    """§6.2.2: C1.compress -> C2.decompress with same tokenizer."""
    text = "shared vocabulary " * 30
    c1 = PromptCompressor(tok, method="hybrid")
    c2 = PromptCompressor(tok, method="hybrid")
    assert c2.decompress(c1.compress(text)) == text


def test_tokenizer_mismatch_refused(tok):
    from repro.tokenizer.bpe import train_bpe

    other = train_bpe(["completely different corpus contents"], vocab_size=260)
    blob = PromptCompressor(tok, method="hybrid").compress("hello world")
    with pytest.raises(ValueError, match="fingerprint"):
        PromptCompressor(other, method="hybrid").decompress(blob)


@pytest.mark.parametrize("backend", sorted(BACKENDS))
def test_backends_roundtrip(backend):
    data = ("backend test data " * 100).encode()
    assert decompress_bytes(compress_bytes(data, level=5, backend=backend),
                            backend=backend) == data


def test_zstd_levels_tradeoff():
    data = open(__file__, "rb").read() * 4
    s1 = len(compress_bytes(data, level=1))
    s19 = len(compress_bytes(data, level=19))
    assert s19 <= s1


@pytest.mark.skipif(not HAVE_ZSTD, reason="dictionary training needs the zstandard C library")
def test_zstd_dict_backend(prompts):
    from repro.core.zstd_backend import ZstdDictBackend

    samples = [p.text for p in prompts]
    be = ZstdDictBackend(samples, dict_size=8192)
    data = prompts[0].text.encode()
    assert be.decompress(be.compress(data)) == data


def test_adaptive_choices(tok, prompts):
    import os

    ac = AdaptiveCompressor(tok)
    # in-domain redundant text tokenizes well -> hybrid
    in_domain = prompts[0].text
    assert ac.choose(in_domain).method == "hybrid"
    # OUT-of-domain text tokenizes at <2 chars/token -> packing would expand
    # (the §3.3.4 pathology) -> adaptive correctly falls back to zstd
    out_domain = "the same line again\n" * 200
    choice = ac.choose(out_domain)
    assert choice.method == "zstd"
    assert "expand" in choice.reason
    # near-incompressible content routes away from hybrid too
    incompressible = os.urandom(8192).decode("latin-1", "replace")
    assert ac.choose(incompressible).method in ("zstd", "hybrid")
    for text in (in_domain, out_domain, incompressible):
        assert ac.decompress(ac.compress(text)) == text


def test_entropy_accounting():
    text = "abababababab" * 50
    h = shannon_entropy(text)
    assert abs(h - 1.0) < 1e-9                       # two equiprobable symbols
    assert abs(theoretical_cr(text) - 8.0) < 1e-9    # Eq. 25
    blob = compress_zstd(text)
    assert bits_per_char(text, len(blob)) < 8.0      # Eq. 33
    assert efficiency(text, len(blob)) > 1.0         # LZ beats order-0 bound


def test_frame_header_parse(tok):
    from repro.core.api import parse_frame

    pc = PromptCompressor(tok, method="hybrid", level=7, scheme="varint")
    info = parse_frame(pc.compress("xyz"))
    assert info.method == "hybrid"
    assert info.level == 7
    assert info.scheme == "varint"
    with pytest.raises(ValueError):
        parse_frame(b"NOPE")
