"""CI anchor for the chaos harness: ``scripts/chaos.py --smoke`` at a
fixed seed must exit 0.  The harness itself does the asserting (zero
acked-write loss across a SIGKILL takeover, quarantine + degraded reads
after an injected corruption, fault/retry counters visible in the obs
snapshots); this test pins it into the tier-1 suite under the ``chaos``
marker so a regression in the fault-tolerance stack fails `make test`,
not just `make quick`.  Deselect with ``-m "not chaos"``; the full
multi-seed sweep is ``make chaos``."""

import os
import subprocess
import sys

import pytest

pytestmark = [pytest.mark.chaos, pytest.mark.slow]

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def test_chaos_smoke_fixed_seed():
    env = dict(os.environ)
    env["PYTHONPATH"] = os.path.join(REPO, "src") + (
        os.pathsep + env["PYTHONPATH"] if env.get("PYTHONPATH") else "")
    proc = subprocess.run(
        [sys.executable, os.path.join(REPO, "scripts", "chaos.py"),
         "--smoke", "--seed", "0"],
        env=env, capture_output=True, text=True, timeout=240)
    assert proc.returncode == 0, (
        f"chaos smoke failed (exit {proc.returncode}):\n"
        f"{proc.stdout}\n{proc.stderr}")
    assert "OK" in proc.stdout and "lossless" in proc.stdout
