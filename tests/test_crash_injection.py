"""Crash injection for the generation swap and the online rebalance.

Driven by the shared failpoint harness (`repro.core.failpoints`): one
alternation rule over the durability sites and the `store.replace`
commit points enumerates every durability step of `swap_shard` (dict
sidecar write included) and `rebalance` with a `count` action, then a
`nth:N,crash` rule simulates the process dying at each step — the store
root is reopened cold and must present either the OLD or the NEW
generation byte-identically (never a torn mix), with every orphaned
`.bin` / `.idx.jsonl` / `.dict` file garbage-collected.

Both operations are deterministic for a quiescent store, so the clean-run
"after" snapshot is computed once per operation on a copy of the seeded
root and reused as the NEW-side reference for every fault point.
"""

import json
import shutil
from pathlib import Path

import pytest

from repro.core import failpoints
from repro.core.api import PromptCompressor
from repro.core.store import ShardedPromptStore
from repro.service.compaction import compact_store
from repro.tokenizer.vocab import default_tokenizer

pytestmark = pytest.mark.crash

#: one shared hit counter across every durability step: file/dir fsyncs,
#: temp-file writes, and the os.replace commit points of the store
_PATTERN = "durability.*|store.replace"


class InjectedCrash(BaseException):
    """BaseException so no production except-Exception path can swallow
    the simulated death (for the one non-failpoint injection below)."""


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


TEXTS = [f"crash {i}: restart the ingest pod, verify quorum, page the "
         f"oncall for cell #{i % 7}." for i in range(24)]


def _open(root, tok):
    return ShardedPromptStore(root, PromptCompressor(tok, method="zstd"))


def _seed(root: Path, tok) -> None:
    store = ShardedPromptStore(root, PromptCompressor(tok, method="zstd"),
                               n_shards=2)
    store.put_many(TEXTS)


def _snapshot(root: Path) -> dict:
    return {p.name: p.read_bytes() for p in root.iterdir() if p.is_file()}


def _live_files(store) -> set:
    lay = store._layout
    # store.lease is permanent once a writer has opened the root: the
    # flock (not the file) is ownership, so it is never GC'd
    names = {"store.json", "store.lease"}
    for i in range(lay.n_shards):
        data, idx = store._shard_paths(i, lay.gens[i], lay.n_shards)
        if data.exists():
            names.add(data.name)
        if idx.exists():
            names.add(idx.name)
        if lay.dict_shas[i]:
            names.add(store._dict_path(i, lay.gens[i], lay.n_shards).name)
    return names


def _assert_meta_old_or_new(data: bytes, before: dict, after: dict,
                            crash_at: int) -> None:
    """store.json must describe, per shard, either the old or the new
    (gen, dict) pair — a mid-pass compaction legitimately leaves shard 0
    committed at the new generation while shard 1 is still old, but a
    single shard's entry may never be torn."""
    doc = json.loads(data)
    n = doc["n_shards"]
    sides = []
    for ref in (before, after):
        if "store.json" in ref:
            d = json.loads(ref["store.json"])
            if d["n_shards"] == n:
                sides.append(d)
    assert sides, f"meta shard count at fault {crash_at} matches neither side"
    gens = doc["gens"]
    dicts = doc.get("dicts", [None] * n)
    for i in range(n):
        ok = any(gens[i] == d["gens"][i]
                 and dicts[i] == d.get("dicts", [None] * n)[i]
                 for d in sides)
        assert ok, (f"shard {i} meta entry at fault {crash_at} is neither "
                    "the old nor the new generation")


OPS = {
    # dict-training compaction: data + index + .dict sidecar per shard,
    # then the atomic meta replace
    "compact_dict": lambda store: compact_store(store, reselect=True,
                                                train_dict=True),
    # second-generation swap on an ALREADY dict-bearing store (old sidecar
    # must survive a crash, new one must not leak)
    "recompact": lambda store: compact_store(store, reselect=True,
                                             train_dict=True),
    "rebalance_grow": lambda store: store.rebalance(5),
    "rebalance_shrink": lambda store: store.rebalance(1),
}
# ops whose seed root is first dict-compacted cleanly
PRE_COMPACTED = {"recompact", "rebalance_grow", "rebalance_shrink"}


@pytest.fixture(scope="module")
def seeded(tok, tmp_path_factory):
    """One seeded root per op + its clean-run 'after' snapshot."""
    base = tmp_path_factory.mktemp("crash-seeds")
    out = {}
    for name, op in OPS.items():
        seed = base / f"{name}-seed"
        _seed(seed, tok)
        if name in PRE_COMPACTED:
            pre = _open(seed, tok)
            compact_store(pre, reselect=True, train_dict=True)
            assert pre.stats()["dicts"] > 0  # sidecar faults are exercised
        before = _snapshot(seed)
        clean = base / f"{name}-clean"
        shutil.copytree(seed, clean)
        op(_open(clean, tok))
        after = _snapshot(clean)
        out[name] = (seed, before, after)
    return out


def _fault_count(seeded_root, op, tok, tmp_path):
    """Enumerate the operation's durability steps with a count rule."""
    work = tmp_path / "count"
    shutil.copytree(seeded_root, work)
    with failpoints.injected(f"{_PATTERN}=always,count") as rules:
        op(_open(work, tok))
        hits = rules[0].hits
    return hits


@pytest.mark.parametrize("opname", sorted(OPS))
def test_crash_at_every_fault_point(opname, seeded, tok, tmp_path):
    op = OPS[opname]
    seed_root, before, after = seeded[opname]
    n_faults = _fault_count(seed_root, op, tok, tmp_path)
    assert n_faults >= 3, "operation must have durability steps to test"
    keys = _open(seed_root, tok).keys()

    for nth in range(1, n_faults + 1):
        work = tmp_path / f"crash-{nth}"
        shutil.copytree(seed_root, work)
        with failpoints.injected(f"{_PATTERN}=nth:{nth},crash"):
            store = _open(work, tok)
            with pytest.raises(failpoints.FailpointCrash):
                op(store)
            del store  # the process is dead; only the disk survives

        # cold reopen: every record present and byte-lossless
        reopened = _open(work, tok)
        assert reopened.keys() == keys, f"keys lost at fault {nth}"
        assert reopened.get_many(keys) == TEXTS
        assert reopened.verify_all()["failure"] == 0

        # old-or-new, never a torn mix: every surviving shard file equals
        # its pre-op or clean-run-after bytes.  The atomic unit is the
        # SHARD generation (compact_store commits one meta replace per
        # shard), so store.json is checked per shard entry instead.
        files = _snapshot(work)
        for name, data in files.items():
            if name == "store.json":
                _assert_meta_old_or_new(data, before, after, nth)
                continue
            assert (before.get(name) == data or after.get(name) == data), (
                f"{name} at fault {nth} is neither the old nor the "
                "new generation")

        # orphan GC: nothing outside the committed layout remains
        assert set(files) == _live_files(reopened), (
            f"orphans after fault {nth}: "
            f"{set(files) ^ _live_files(reopened)}")
        shutil.rmtree(work)


def test_torn_creation_meta_never_publishes(tok, tmp_path):
    """A power cut mid-write of the creation meta's TEMP file (torn
    action at the cooperating write_durable site) leaves a truncated
    temp — which must never reach the commit name: store.json is the
    os.replace target, so it either doesn't exist or is whole.  Retrying
    after the 'power cut' completes creation and the store is fully
    functional."""
    with failpoints.injected("durability.write_durable=nth:1,torn"):
        with pytest.raises(failpoints.TornWrite):
            _open(tmp_path, tok)
    assert not (tmp_path / "store.json").exists()
    torn_tmp = tmp_path / ".store.json.tmp"
    if torn_tmp.exists():  # the partial is a strict prefix, never whole
        assert not torn_tmp.read_bytes().endswith(b"\n")
    store = _open(tmp_path, tok)
    keys = store.put_many(TEXTS)
    assert store.get_many(keys) == TEXTS
    assert store.verify_all()["failure"] == 0


def test_crash_after_rebalance_commit_sweeps_gen0_leftovers(tok, monkeypatch,
                                                           tmp_path):
    """A shrink committed from a NEVER-compacted store leaves gen-0 files
    of the dropped shards if the process dies before cleanup.  Those
    names are ambiguous with foreign backups, so GC must not guess —
    the committed meta's explicit `sweep` list declares them ours and a
    reopen finishes the unlink.  (Path.unlink is not an I/O commit
    point, so this one stays a monkeypatch rather than a failpoint.)"""
    _seed(tmp_path, tok)  # 2 shards, all gen 0
    store = _open(tmp_path, tok)

    def dying_unlink(self, *a, **kw):
        raise InjectedCrash(f"unlink {self.name}")

    with monkeypatch.context() as m:
        m.setattr(Path, "unlink", dying_unlink)
        with pytest.raises(InjectedCrash):
            store.rebalance(1)
    # meta committed (n_shards=1) but every old gen-0 file survived
    assert json.loads((tmp_path / "store.json").read_bytes())["n_shards"] == 1
    assert (tmp_path / "shard-000.bin").exists()
    assert (tmp_path / "shard-001.bin").exists()
    reopened = _open(tmp_path, tok)
    assert not (tmp_path / "shard-000.bin").exists()
    assert not (tmp_path / "shard-001.bin").exists()
    assert "sweep" not in json.loads((tmp_path / "store.json").read_bytes())
    assert reopened.keys() and reopened.verify_all()["failure"] == 0
    assert reopened.get_many(reopened.keys()) == TEXTS


def test_rebalance_preserves_seq_order_across_crashes(seeded, tok, tmp_path):
    """Acceptance: rebalance(n_shards) preserves every key AND the global
    seq iteration order at every fault point (spot-checked above per key
    set; this pins the order against the seed)."""
    seed_root, _, _ = seeded["rebalance_grow"]
    expected = _open(seed_root, tok).keys()
    n_faults = _fault_count(seed_root, OPS["rebalance_grow"], tok,
                            tmp_path / "c")
    for nth in (1, n_faults // 2 + 1, n_faults):
        work = tmp_path / f"seq-{nth}"
        shutil.copytree(seed_root, work)
        with failpoints.injected(f"{_PATTERN}=nth:{nth},crash"):
            with pytest.raises(failpoints.FailpointCrash):
                _open(work, tok).rebalance(5)
        assert _open(work, tok).keys() == expected
        shutil.rmtree(work)
