"""PromptStore durability/integrity + the deterministic LoPace-backed
training data pipeline."""

import json

import numpy as np
import pytest

from repro.core.api import PromptCompressor, parse_frame
from repro.core.store import PromptStore, ShardedPromptStore
from repro.data.corpus import corpus_stats, generate_corpus
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_store_from_corpus
from repro.tokenizer.vocab import default_tokenizer


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


def test_corpus_matches_paper_stats():
    ps = generate_corpus(96, seed=0)
    st = corpus_stats(ps)
    assert st["min"] == 129                      # paper §4.1
    assert st["max"] >= 200_000
    assert 0.75 < st["content_mix"]["code"] < 0.9
    assert 10_000 < st["median"] < 40_000


def test_store_roundtrip_and_tokens(tmp_path, tok):
    store = PromptStore(tmp_path, PromptCompressor(tok, method="hybrid"))
    texts = [p.text for p in generate_corpus(5, seed=3)]
    keys = store.put_many(texts)
    assert len(store) == 5
    assert store.get(keys[2]) == texts[2]
    assert tok.decode(store.get_tokens(keys[1])) == texts[1]
    assert store.put(texts[0]) == keys[0]        # idempotent
    assert len(store) == 5
    st = store.stats()
    assert st["space_savings_pct"] > 50          # paper §5.2 territory
    assert store.verify_all() == {"success": 5, "failure": 0, "total": 5}


def test_store_survives_torn_index(tmp_path, tok):
    store = PromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    keys = store.put_many(["alpha " * 50, "beta " * 50])
    # simulate a crash mid-append: truncated json line at the tail
    with open(tmp_path / "index.jsonl", "a") as f:
        f.write('{"key": "deadbeef", "offset": 999999')
    store2 = PromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    assert set(store2.keys()) == set(keys)
    assert store2.get(keys[0]).startswith("alpha")


def test_corrupt_frame_headers_raise_valueerror(tok):
    """parse_frame must fail loudly (ValueError, not bare KeyError/IndexError)
    on unknown method/backend/scheme ids from corrupt or future frames."""
    blob = bytearray(PromptCompressor(tok, method="hybrid").compress("x" * 64))
    for offset, what in ((3, "method"), (4, "backend"), (6, "scheme")):
        bad = bytearray(blob)
        bad[offset] = 0xEE
        with pytest.raises(ValueError, match=f"unknown {what} id"):
            parse_frame(bytes(bad))
    with pytest.raises(ValueError, match="not a LoPace frame"):
        parse_frame(b"XX" + bytes(blob[2:]))
    with pytest.raises(ValueError, match="not a LoPace frame"):
        parse_frame(blob[:4])  # shorter than the header


def test_store_rejects_corrupt_blob(tmp_path, tok):
    """A record whose frame header got scribbled on fails get() cleanly and
    is counted by verify_all, without touching other records."""
    store = PromptStore(tmp_path, PromptCompressor(tok, method="hybrid"))
    keys = store.put_many(["intact " * 40, "corrupted " * 40])
    rec = store._index[keys[1]]
    with open(tmp_path / "data.bin", "r+b") as f:
        f.seek(rec["offset"] + 3)  # method id byte
        f.write(b"\xee")
    store2 = PromptStore(tmp_path, PromptCompressor(tok, method="hybrid"))
    assert store2.get(keys[0]).startswith("intact")
    with pytest.raises(ValueError, match="unknown method id"):
        store2.get(keys[1])
    assert store2.verify_all() == {"success": 1, "failure": 1, "total": 2}


# -- sharded store -----------------------------------------------------------


def test_sharded_group_commit_matches_per_put(tmp_path, tok):
    """put_many's group commit lays out every shard byte-identically to a
    sequence of per-record puts — only the fsync count differs."""
    texts = [f"shard me {i} " * 30 for i in range(12)]
    a = ShardedPromptStore(tmp_path / "a", PromptCompressor(tok, method="token"),
                           n_shards=4)
    b = ShardedPromptStore(tmp_path / "b", PromptCompressor(tok, method="token"),
                           n_shards=4)
    keys = a.put_many(texts)
    assert [b.put(t) for t in texts] == keys
    for i in range(4):
        name = f"shard-{i:03d}.bin"
        assert (tmp_path / "a" / name).read_bytes() == \
            (tmp_path / "b" / name).read_bytes()
    assert a.put_many(texts) == keys  # idempotent re-ingest
    assert sum(a.stats()["prompts_per_shard"]) == len(set(keys))


def test_sharded_torn_tail_isolated_to_one_shard(tmp_path, tok):
    """Crash recovery per segment: a torn index tail in one shard drops only
    that shard's unpublished record; every other shard stays readable."""
    store = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"),
                               n_shards=4)
    texts = [f"durable record {i} " * 20 for i in range(16)]
    keys = store.put_many(texts)
    victim = store._shard_of(keys[0])
    # simulate a crash mid-publish in the victim shard: a fully published
    # record whose data never hit disk (index ahead of data), then a
    # truncated json line
    with open(tmp_path / f"shard-{victim:03d}.idx.jsonl", "a") as f:
        f.write(json.dumps({"key": "deadbeef", "seq": 999, "offset": 10 ** 9,
                            "length": 64, "method": "zstd", "n_chars": 1}) + "\n")
        f.write('{"key": "feedface", "offset": 999')
    store2 = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    assert store2.n_shards == 4
    assert set(store2.keys()) == set(keys)
    for k, t in zip(keys, texts):
        assert store2.get(k) == t


def test_sharded_data_truncation_drops_only_tail_record(tmp_path, tok):
    """Index published but data truncated (torn data tail): the affected
    shard drops records past the truncation point on open."""
    store = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"),
                               n_shards=2)
    texts = [f"payload {i} " * 25 for i in range(8)]
    keys = store.put_many(texts)
    victim = 0
    data_path = tmp_path / f"shard-{victim:03d}.bin"
    in_victim = [k for k in keys if store._shard_of(k) == victim]
    assert len(in_victim) >= 2
    last = max(in_victim, key=lambda k: store._index[k]["offset"])
    with open(data_path, "r+b") as f:
        f.truncate(store._index[last]["offset"] + 1)
    store2 = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    assert set(store2.keys()) == set(keys) - {last}
    survivors = [k for k in keys if k != last]
    assert store2.verify_all() == {"success": len(survivors), "failure": 0,
                                   "total": len(survivors)}


def test_legacy_single_file_layout_reopens(tmp_path, tok):
    """A 1-shard store keeps the flat data.bin/index.jsonl layout, and a
    ShardedPromptStore handed that root respects the existing layout."""
    store = PromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    key = store.put("legacy layout " * 10)
    assert (tmp_path / "data.bin").exists()
    assert (tmp_path / "index.jsonl").exists()
    reopened = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"),
                                  n_shards=8)  # request ignored: layout wins
    assert reopened.n_shards == 1
    assert reopened.get(key) == "legacy layout " * 10


def test_sharded_reopen_preserves_put_order(tmp_path, tok):
    """Iteration order is put order, stable across reopen — TokenPipeline's
    restart-safe resume concatenates streams in this order."""
    texts = [f"ordering matters {i} " * 10 for i in range(20)]
    store = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="token"),
                               n_shards=4)
    keys = store.put_many(texts)
    assert store.keys() == keys
    reopened = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="token"))
    assert reopened.keys() == keys
    # appends after reopen continue the sequence
    more = reopened.put_many(["appended later " * 10])
    assert reopened.keys() == keys + more


def test_get_many_and_tokens_many(tmp_path, tok):
    store = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="hybrid"),
                               n_shards=4)
    texts = [p.text[:1500] for p in generate_corpus(6, seed=7)]
    keys = store.put_many(texts)
    assert store.get_many(keys) == texts
    for t, ids in zip(texts, store.get_tokens_many(keys)):
        assert tok.decode(ids) == t


def test_pipeline_determinism_and_resume(tmp_path):
    store = build_store_from_corpus(tmp_path / "s", n_prompts=6, seed=5)
    cfg = PipelineConfig(seq_len=128, global_batch=4, seed=9)
    p1 = TokenPipeline(store, cfg)
    p2 = TokenPipeline(store, cfg)
    b1 = [next(p1) for _ in range(3)]
    b2 = [next(p2) for _ in range(3)]
    for a, b in zip(b1, b2):
        assert np.array_equal(a["tokens"], b["tokens"])
    # resume from checkpointed state
    state = p1.state()
    p3 = TokenPipeline(store, cfg)
    p3.restore(state)
    assert np.array_equal(next(p3)["tokens"], next(p1)["tokens"])
    # next-token labels are shifted inputs
    b = p1.batch_at(0)
    assert np.array_equal(b["tokens"][0][1:], b["labels"][0][:-1])


def test_pipeline_host_sharding_disjoint(tmp_path):
    store = build_store_from_corpus(tmp_path / "s", n_prompts=6, seed=5)
    shard0 = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=4,
                                                 shard_id=0, num_shards=2))
    shard1 = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=4,
                                                 shard_id=1, num_shards=2))
    a, b = shard0.batch_at(0), shard1.batch_at(0)
    assert a["tokens"].shape[0] == 2 and b["tokens"].shape[0] == 2
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_with_accum_reshape(tmp_path):
    store = build_store_from_corpus(tmp_path / "s", n_prompts=6, seed=5)
    pipe = TokenPipeline(store, PipelineConfig(seq_len=64, global_batch=8))
    batch = pipe.batch_at(0)
    acc = pipe.with_accum(batch, 4)
    assert acc["tokens"].shape == (4, 2, 64)
