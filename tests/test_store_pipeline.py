"""PromptStore durability/integrity + the deterministic LoPace-backed
training data pipeline."""

import json

import numpy as np
import pytest

from repro.core.api import PromptCompressor
from repro.core.store import PromptStore
from repro.data.corpus import corpus_stats, generate_corpus
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_store_from_corpus
from repro.tokenizer.vocab import default_tokenizer


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


def test_corpus_matches_paper_stats():
    ps = generate_corpus(96, seed=0)
    st = corpus_stats(ps)
    assert st["min"] == 129                      # paper §4.1
    assert st["max"] >= 200_000
    assert 0.75 < st["content_mix"]["code"] < 0.9
    assert 10_000 < st["median"] < 40_000


def test_store_roundtrip_and_tokens(tmp_path, tok):
    store = PromptStore(tmp_path, PromptCompressor(tok, method="hybrid"))
    texts = [p.text for p in generate_corpus(5, seed=3)]
    keys = store.put_many(texts)
    assert len(store) == 5
    assert store.get(keys[2]) == texts[2]
    assert tok.decode(store.get_tokens(keys[1])) == texts[1]
    assert store.put(texts[0]) == keys[0]        # idempotent
    assert len(store) == 5
    st = store.stats()
    assert st["space_savings_pct"] > 50          # paper §5.2 territory
    assert store.verify_all() == {"success": 5, "failure": 0, "total": 5}


def test_store_survives_torn_index(tmp_path, tok):
    store = PromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    keys = store.put_many(["alpha " * 50, "beta " * 50])
    # simulate a crash mid-append: truncated json line at the tail
    with open(tmp_path / "index.jsonl", "a") as f:
        f.write('{"key": "deadbeef", "offset": 999999')
    store2 = PromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    assert set(store2.keys()) == set(keys)
    assert store2.get(keys[0]).startswith("alpha")


def test_pipeline_determinism_and_resume(tmp_path):
    store = build_store_from_corpus(tmp_path / "s", n_prompts=6, seed=5)
    cfg = PipelineConfig(seq_len=128, global_batch=4, seed=9)
    p1 = TokenPipeline(store, cfg)
    p2 = TokenPipeline(store, cfg)
    b1 = [next(p1) for _ in range(3)]
    b2 = [next(p2) for _ in range(3)]
    for a, b in zip(b1, b2):
        assert np.array_equal(a["tokens"], b["tokens"])
    # resume from checkpointed state
    state = p1.state()
    p3 = TokenPipeline(store, cfg)
    p3.restore(state)
    assert np.array_equal(next(p3)["tokens"], next(p1)["tokens"])
    # next-token labels are shifted inputs
    b = p1.batch_at(0)
    assert np.array_equal(b["tokens"][0][1:], b["labels"][0][:-1])


def test_pipeline_host_sharding_disjoint(tmp_path):
    store = build_store_from_corpus(tmp_path / "s", n_prompts=6, seed=5)
    shard0 = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=4,
                                                 shard_id=0, num_shards=2))
    shard1 = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=4,
                                                 shard_id=1, num_shards=2))
    a, b = shard0.batch_at(0), shard1.batch_at(0)
    assert a["tokens"].shape[0] == 2 and b["tokens"].shape[0] == 2
    assert not np.array_equal(a["tokens"], b["tokens"])


def test_with_accum_reshape(tmp_path):
    store = build_store_from_corpus(tmp_path / "s", n_prompts=6, seed=5)
    pipe = TokenPipeline(store, PipelineConfig(seq_len=64, global_batch=8))
    batch = pipe.batch_at(0)
    acc = pipe.with_accum(batch, 4)
    assert acc["tokens"].shape == (4, 2, 64)
