"""Vectorized codec hot path: parity with the scalar oracles.

Covers the three tentpole pieces of the vectorized rewrite:

* LZ77 — the NumPy parse must produce *valid streams of the identical
  wire format* (round-trip-identical; byte identity is promised only for
  the scalar path, which small payloads and `REPRO_LZ_MODE=scalar` pin),
  and either decoder must decode either compressor's output;
* rANS — the interleaved N-lane coder must round-trip for every lane
  count, reproduce the scalar oracle's word stream bit-for-bit at one
  lane, and keep the single-lane blob layout byte-identical to the
  historical format;
* batch plumbing — the pooled byte-stage fan-out must be byte-identical
  to sequential encoding.
"""

import os

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.entropy import byte_histogram
from repro.core.lz77 import (_lz_compress_np, _lz_compress_scalar,
                             _lz_decompress_np, _lz_decompress_scalar,
                             lz_compress, lz_decompress)
from repro.core.rans_np import (normalize_freqs, rans_compress_bytes,
                                rans_decode_interleaved, rans_decompress_bytes,
                                rans_encode, rans_encode_interleaved)
from repro.core.zstd_backend import compress_bytes, decompress_bytes

LANES = (1, 2, 4, 8)

EDGE_PAYLOADS = [
    b"",
    b"a",
    b"ab",
    b"abc",
    b"abcd" * 400,                     # period-4 run
    b"\x00" * 5000,                    # zero page
    b"x" * 3,
    bytes(range(256)) * 24,            # incompressible-ish cycle
    b"the quick brown fox " * 300,     # natural-ish text
]
EDGE_IDS = ["empty", "1B", "2B", "3B", "period4", "zeros", "tiny-run",
            "cycle", "text"]


@pytest.fixture(scope="module")
def incompressible():
    rng = np.random.default_rng(7)
    return rng.integers(0, 256, 20000, dtype=np.uint8).tobytes()


@pytest.fixture(scope="module")
def corpus_blob():
    from repro.data.corpus import generate_corpus

    return "\n".join(p.text for p in generate_corpus(12, seed=3)).encode()


# ---------------------------------------------------------------------------
# LZ77 scalar <-> vectorized
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("payload", EDGE_PAYLOADS, ids=EDGE_IDS)
def test_lz_cross_path_roundtrip_edges(payload):
    """Either decoder decodes either compressor's output — the wire
    format carries no producer mark."""
    for comp_fn in (_lz_compress_scalar, _lz_compress_np):
        blob = comp_fn(payload)
        assert _lz_decompress_scalar(blob) == payload
        assert _lz_decompress_np(blob) == payload


def test_lz_cross_path_roundtrip_bulk(corpus_blob, incompressible):
    for payload in (corpus_blob, incompressible):
        for comp_fn in (_lz_compress_scalar, _lz_compress_np):
            blob = comp_fn(payload)
            assert _lz_decompress_scalar(blob) == payload
            assert _lz_decompress_np(blob) == payload


@settings(max_examples=40, deadline=None)
@given(data=st.binary(min_size=0, max_size=600),
       prefix=st.binary(min_size=0, max_size=800))
def test_lz_vectorized_prefix_property(data, prefix):
    """Dictionary mode: vectorized compress/decompress against arbitrary
    prefixes, cross-decoded by the scalar oracle."""
    blob = _lz_compress_np(data, prefix=prefix)
    assert _lz_decompress_scalar(blob, prefix=prefix) == data
    assert _lz_decompress_np(blob, prefix=prefix) == data
    # and the oracle's stream through the vectorized decoder
    assert _lz_decompress_np(_lz_compress_scalar(data, prefix=prefix),
                             prefix=prefix) == data


def test_lz_prefix_dictionary_bulk(corpus_blob):
    prefix = corpus_blob[:8192]
    data = corpus_blob[8192:40000]
    for comp_fn in (_lz_compress_scalar, _lz_compress_np):
        blob = comp_fn(data, prefix=prefix)
        assert _lz_decompress_np(blob, prefix=prefix) == data
        assert _lz_decompress_scalar(blob, prefix=prefix) == data
    # a dictionary should actually help on shared-structure payloads
    assert len(_lz_compress_np(data, prefix=prefix)) <= len(_lz_compress_np(data))


def test_lz_mode_env_forces_path(corpus_blob, monkeypatch):
    data = corpus_blob[:30000]
    monkeypatch.setenv("REPRO_LZ_MODE", "scalar")
    assert lz_compress(data) == _lz_compress_scalar(data)
    monkeypatch.setenv("REPRO_LZ_MODE", "vector")
    assert lz_compress(data) == _lz_compress_np(data)
    assert lz_decompress(lz_compress(data)) == data
    monkeypatch.delenv("REPRO_LZ_MODE")
    assert lz_decompress(lz_compress(data)) == data


def test_lz_small_payloads_stay_scalar_byte_identical():
    """Below the crossover the public entry point IS the scalar oracle —
    every historical golden blob and dict-sidecar stream is unchanged."""
    data = b"short payload " * 10  # < _NP_MIN_COMPRESS
    assert lz_compress(data) == _lz_compress_scalar(data)


def test_lz_run_probe_routes_zero_pages_scalar(monkeypatch):
    monkeypatch.delenv("REPRO_LZ_MODE", raising=False)
    z = b"\x00" * 100_000
    assert lz_compress(z) == _lz_compress_scalar(z)


# -- truncation / corruption -------------------------------------------------


GOLDEN_BLOCK_DATA = b"hello hello hello world world banana " * 4


@pytest.mark.parametrize("dec_fn", [_lz_decompress_scalar, _lz_decompress_np],
                         ids=["scalar", "vector"])
def test_lz_truncation_at_every_byte(dec_fn):
    """Truncating a golden block at every byte position either raises the
    pointed ValueError or decodes a clean prefix (cuts that land exactly
    after a literal run are indistinguishable from a valid final
    sequence) — never an IndexError, never garbage."""
    golden = _lz_compress_scalar(GOLDEN_BLOCK_DATA)
    for cut in range(len(golden)):
        t = golden[:cut]
        if cut == 0:
            assert dec_fn(t) == b""
            continue
        try:
            out = dec_fn(t)
        except ValueError as e:
            assert "corrupt LZ stream" in str(e)
        else:
            assert GOLDEN_BLOCK_DATA.startswith(out)


def test_lz_truncation_paths_agree():
    golden = _lz_compress_np(GOLDEN_BLOCK_DATA)
    for cut in range(len(golden)):
        outs = []
        for dec_fn in (_lz_decompress_scalar, _lz_decompress_np):
            try:
                outs.append(dec_fn(golden[:cut]))
            except ValueError:
                outs.append(ValueError)
        assert outs[0] == outs[1], f"paths disagree at cut {cut}"


@pytest.mark.parametrize("dec_fn", [_lz_decompress_scalar, _lz_decompress_np],
                         ids=["scalar", "vector"])
def test_lz_corrupt_offsets_raise(dec_fn):
    # zero offset: token with match, offset bytes 00 00
    with pytest.raises(ValueError, match="zero offset"):
        dec_fn(bytes([0x10]) + b"A" + b"\x00\x00" + b"\x00")
    # offset before start of output
    with pytest.raises(ValueError, match="offset before start"):
        dec_fn(bytes([0x10]) + b"A" + b"\xff\xff" + b"\x00")


# ---------------------------------------------------------------------------
# rANS interleaved lanes
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lanes", LANES)
@pytest.mark.parametrize("payload", EDGE_PAYLOADS, ids=EDGE_IDS)
def test_rans_lane_roundtrip_edges(lanes, payload):
    blob = rans_compress_bytes(payload, lanes=lanes)
    assert rans_decompress_bytes(blob) == payload


@pytest.mark.parametrize("lanes", LANES)
def test_rans_lane_roundtrip_bulk(lanes, corpus_blob, incompressible):
    for payload in (corpus_blob[:50000], incompressible):
        assert rans_decompress_bytes(
            rans_compress_bytes(payload, lanes=lanes)) == payload


@settings(max_examples=30, deadline=None)
@given(data=st.binary(min_size=0, max_size=2000),
       lanes=st.sampled_from(LANES))
def test_rans_lane_property(data, lanes):
    assert rans_decompress_bytes(rans_compress_bytes(data, lanes=lanes)) == data


def test_rans_single_lane_blob_byte_identical(corpus_blob):
    """lanes=1 (and the auto route below the size threshold) must keep the
    historical blob layout byte-for-byte — old readers parse it."""
    data = corpus_blob[:3000]
    symbols = np.frombuffer(data, np.uint8)
    freqs = normalize_freqs(np.bincount(symbols, minlength=256))
    words, state = rans_encode(symbols, freqs)
    import struct

    nonzero = np.flatnonzero(freqs)
    assert nonzero.size < 171  # text: sparse table
    expected = (struct.pack("<IBH", symbols.size, 12, nonzero.size)
                + nonzero.astype("<u1").tobytes()
                + freqs[nonzero].astype("<u2").tobytes()
                + struct.pack("<II", state, words.size)
                + words[::-1].astype("<u2").tobytes())
    assert rans_compress_bytes(data, lanes=1) == expected
    assert rans_compress_bytes(data) == expected  # auto -> single lane


def test_rans_interleaved_lane1_matches_scalar_words(corpus_blob):
    """One lane of the interleaved engine IS the scalar coder: identical
    final state and word stream (only the serialization container differs)."""
    symbols = np.frombuffer(corpus_blob[:9973], np.uint8)
    freqs = normalize_freqs(np.bincount(symbols, minlength=256))
    w_ref, st_ref = rans_encode(symbols, freqs)
    w_vec, states = rans_encode_interleaved(symbols, freqs, 1)
    assert int(states[0]) == st_ref
    assert np.array_equal(w_vec[::-1], w_ref)  # vec stores forward order
    out = rans_decode_interleaved(w_vec, states, symbols.size, freqs, 1)
    assert np.array_equal(out, symbols)


def test_rans_multilane_header_flag(corpus_blob):
    blob1 = rans_compress_bytes(corpus_blob[:3000], lanes=1)
    blob8 = rans_compress_bytes(corpus_blob[:3000], lanes=8)
    assert blob1[4] == 12          # plain prob_bits byte
    assert blob8[4] == (12 | 0x80)  # interleaved flag
    assert blob8[5] == 3           # log2(8)
    assert rans_decompress_bytes(blob8) == rans_decompress_bytes(blob1)


def test_rans_lanes_validation():
    with pytest.raises(ValueError, match="power of two"):
        rans_compress_bytes(b"xy", lanes=3)
    with pytest.raises(ValueError, match="power of two"):
        rans_compress_bytes(b"xy", lanes=2048)


def test_rans_auto_lane_env_override(corpus_blob, monkeypatch):
    monkeypatch.setenv("REPRO_RANS_LANES", "4")
    blob = rans_compress_bytes(corpus_blob[:3000])
    assert blob[4] & 0x80 and blob[5] == 2
    assert rans_decompress_bytes(blob) == corpus_blob[:3000]


def test_rans_single_symbol_full_table():
    """A one-symbol alphabet puts freq == 2**prob_bits in the table
    (x_max == 2**32) — the uint64 lanes must carry it."""
    data = b"\x07" * 9000
    for lanes in LANES:
        assert rans_decompress_bytes(rans_compress_bytes(data, lanes=lanes)) == data


# ---------------------------------------------------------------------------
# repro-lz / repro-lzr end to end + batch pool
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("backend", ["repro-lz", "repro-lzr"])
def test_backend_roundtrip_both_modes(backend, corpus_blob, monkeypatch):
    data = corpus_blob[:40000]
    blobs = {}
    for mode in ("scalar", "vector"):
        monkeypatch.setenv("REPRO_LZ_MODE", mode)
        blobs[mode] = compress_bytes(data, backend=backend)
        assert decompress_bytes(blobs[mode], backend=backend) == data
    # cross-mode: scalar-written stores decode under vector mode & back
    monkeypatch.setenv("REPRO_LZ_MODE", "vector")
    assert decompress_bytes(blobs["scalar"], backend=backend) == data
    monkeypatch.setenv("REPRO_LZ_MODE", "scalar")
    assert decompress_bytes(blobs["vector"], backend=backend) == data


def test_batch_pool_byte_identical(corpus_blob, monkeypatch):
    """The pooled byte-stage fan-out must not change a single output byte
    vs sequential encoding (order-preserving pool.map)."""
    from repro.core.codec import ByteCompressorCodec

    payloads = [corpus_blob[i * 4096 : (i + 1) * 4096] for i in range(24)]
    codec = ByteCompressorCodec(backend="repro-lzr")
    monkeypatch.setenv("REPRO_CODEC_THREADS", "3")
    pooled = codec.encode_batch(payloads)
    monkeypatch.setenv("REPRO_CODEC_THREADS", "0")
    sequential = codec.encode_batch(payloads)
    assert pooled == sequential
    monkeypatch.setenv("REPRO_CODEC_THREADS", "3")
    assert codec.decode_batch(pooled) == payloads


def test_compressor_batch_identical_with_pool(monkeypatch):
    from repro.core.api import PromptCompressor
    from repro.tokenizer.vocab import default_tokenizer

    texts = [f"prompt number {i}: the quick brown fox " * 40 for i in range(8)]
    pc = PromptCompressor(default_tokenizer(), method="hybrid")
    monkeypatch.setenv("REPRO_CODEC_THREADS", "2")
    batch = pc.compress_batch(texts)
    singles = [pc.compress(t) for t in texts]
    assert batch == singles
    assert pc.decompress_batch(batch) == texts


# ---------------------------------------------------------------------------
# histogram primitive
# ---------------------------------------------------------------------------


def test_byte_histogram_matches_bincount(incompressible):
    counts = byte_histogram(incompressible)
    ref = np.bincount(np.frombuffer(incompressible, np.uint8), minlength=256)
    assert np.array_equal(counts, ref)
    assert byte_histogram(b"").sum() == 0


def test_byte_histogram_device_parity(incompressible):
    """Pallas (interpret-mode on CPU) histogram == bincount — the table
    the device rANS coder builds is exact."""
    from repro.kernels.histogram import byte_histogram_device

    counts = byte_histogram_device(incompressible[:4096], interpret=True)
    ref = np.bincount(np.frombuffer(incompressible[:4096], np.uint8),
                      minlength=256)
    assert np.array_equal(counts, ref)
