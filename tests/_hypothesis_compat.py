"""Hypothesis shim: real hypothesis when installed, otherwise a seeded
fallback property runner so the suite still exercises every property test.

Install the real thing with ``pip install -r requirements-dev.txt``.  The
fallback implements just the strategy surface this repo's tests use
(integers / lists / text / characters / sampled_from / binary) and runs
each ``@given`` test over ``max_examples`` deterministic samples, so a
missing dev dependency degrades shrinking quality, not coverage.
"""

from __future__ import annotations

try:
    from hypothesis import given, settings
    from hypothesis import strategies as st

    HAVE_HYPOTHESIS = True
except ImportError:
    import inspect
    import random
    import string
    from functools import wraps

    HAVE_HYPOTHESIS = False
    _DEFAULT_EXAMPLES = 25

    class _Strategy:
        def __init__(self, sample):
            self._sample = sample

        def example(self, rng: "random.Random"):
            return self._sample(rng)

    class st:  # noqa: N801 - mimics `hypothesis.strategies as st`
        @staticmethod
        def integers(min_value=0, max_value=2**63 - 1):
            return _Strategy(lambda rng: rng.randint(min_value, max_value))

        @staticmethod
        def sampled_from(elements):
            seq = list(elements)
            return _Strategy(lambda rng: rng.choice(seq))

        @staticmethod
        def lists(elements, min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 20

            def sample(rng):
                n = rng.randint(min_size, hi)
                return [elements.example(rng) for _ in range(n)]

            return _Strategy(sample)

        @staticmethod
        def characters(codec=None, **_kw):
            def sample(rng):
                # mostly printable ASCII, occasionally the full BMP+ range
                if rng.random() < 0.7:
                    return rng.choice(string.printable)
                cp = rng.randint(0, 0x10FFFF)
                while 0xD800 <= cp <= 0xDFFF:  # surrogates not encodable
                    cp = rng.randint(0, 0x10FFFF)
                return chr(cp)

            return _Strategy(sample)

        @staticmethod
        def text(alphabet=None, min_size=0, max_size=None):
            alphabet = alphabet or st.characters()
            hi = max_size if max_size is not None else min_size + 50

            def sample(rng):
                n = rng.randint(min_size, hi)
                return "".join(alphabet.example(rng) for _ in range(n))

            return _Strategy(sample)

        @staticmethod
        def binary(min_size=0, max_size=None):
            hi = max_size if max_size is not None else min_size + 100

            def sample(rng):
                return bytes(rng.randrange(256)
                             for _ in range(rng.randint(min_size, hi)))

            return _Strategy(sample)

    def given(*arg_strategies, **kw_strategies):
        def decorate(fn):
            # like hypothesis, strip strategy-bound parameters from the
            # signature pytest sees, so the rest resolve as fixtures;
            # positional strategies bind the rightmost unbound parameters
            sig = inspect.signature(fn)
            unbound = [p for p in sig.parameters if p not in kw_strategies]
            n_pos = len(arg_strategies)
            pos_names = unbound[len(unbound) - n_pos:] if n_pos else []
            fixture_names = [p for p in unbound if p not in pos_names]

            @wraps(fn)
            def wrapper(**fixture_kwargs):
                rng = random.Random(fn.__name__)  # deterministic per test
                n = getattr(wrapper, "_max_examples", _DEFAULT_EXAMPLES)
                for _ in range(n):
                    call = dict(fixture_kwargs)
                    for name, s in zip(pos_names, arg_strategies):
                        call[name] = s.example(rng)
                    for name, s in kw_strategies.items():
                        call[name] = s.example(rng)
                    fn(**call)

            wrapper.__signature__ = sig.replace(
                parameters=[sig.parameters[p] for p in fixture_names])
            return wrapper

        return decorate

    def settings(max_examples=_DEFAULT_EXAMPLES, **_kw):
        def decorate(fn):
            fn._max_examples = max_examples
            return fn

        return decorate

__all__ = ["given", "settings", "st", "HAVE_HYPOTHESIS"]
