"""rANS entropy coder: python oracle, JAX interleaved lanes, and the
self-contained token-stream blob format."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.rans import (rans_decode_lanes, rans_encode_lanes,
                             tokens_compress_device, tokens_decompress_device,
                             _lane_split)
from repro.core.rans_np import (normalize_freqs, rans_compress_bytes,
                                rans_decode, rans_decompress_bytes, rans_encode)


def test_normalize_freqs_sums_to_table():
    counts = np.array([100, 5, 0, 1, 3000])
    f = normalize_freqs(counts, 12)
    assert f.sum() == 4096
    assert f[2] == 0 and all(f[i] > 0 for i in (0, 1, 3, 4))


def test_np_oracle_roundtrip():
    rng = np.random.default_rng(0)
    syms = rng.integers(0, 17, 5000)
    freqs = normalize_freqs(np.bincount(syms, minlength=17), 12)
    words, state = rans_encode(syms, freqs, 12)
    out = rans_decode(words, state, syms.size, freqs, 12)
    assert np.array_equal(out, syms)


def test_np_bytes_roundtrip():
    data = open(__file__, "rb").read()
    blob = rans_compress_bytes(data)
    assert rans_decompress_bytes(blob) == data
    assert len(blob) < len(data)  # source text is compressible


@settings(max_examples=30, deadline=None)
@given(st.binary(max_size=2000))
def test_np_bytes_property(data):
    assert rans_decompress_bytes(rans_compress_bytes(data)) == data


def test_jax_matches_oracle_per_lane():
    """Lane 0 of the JAX coder must reproduce the python oracle stream."""
    import jax.numpy as jnp

    rng = np.random.default_rng(1)
    syms = rng.integers(0, 11, 257).astype(np.int32)
    freqs = normalize_freqs(np.bincount(syms, minlength=11), 12)
    words_ref, state_ref = rans_encode(syms, freqs, 12)

    sym2, val2, _ = _lane_split(syms, 1)
    words, flags, states = rans_encode_lanes(
        jnp.asarray(sym2), jnp.asarray(val2), jnp.asarray(freqs.astype(np.uint32)),
        prob_bits=12)
    lane_words = np.asarray(words)[0][np.asarray(flags)[0]]
    assert int(states[0]) == state_ref
    assert np.array_equal(lane_words.astype(np.uint16), words_ref)


@pytest.mark.parametrize("lanes", [1, 3, 8])
def test_device_blob_roundtrip(lanes):
    rng = np.random.default_rng(2)
    for ids in (np.array([], np.int64), np.array([5]), np.array([7] * 100),
                rng.integers(0, 100_000, 2048), rng.zipf(1.5, 3000) % 50_000):
        blob = tokens_compress_device(ids, lanes=lanes)
        out = tokens_decompress_device(blob)
        assert np.array_equal(out.astype(np.int64), np.asarray(ids, np.int64))


@pytest.mark.slow
@settings(max_examples=25, deadline=None)
@given(st.lists(st.integers(0, 100_256), max_size=400))
def test_device_blob_property(ids):
    arr = np.array(ids, dtype=np.int64)
    assert np.array_equal(
        tokens_decompress_device(tokens_compress_device(arr)).astype(np.int64), arr)


def test_device_coder_compresses_skewed_streams():
    rng = np.random.default_rng(3)
    ids = (rng.zipf(1.3, 20_000) % 8192).astype(np.int64)
    blob = tokens_compress_device(ids)
    fixed = 1 + 2 * ids.size
    assert len(blob) < fixed  # beats uint16 packing on skewed data
