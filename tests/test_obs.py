"""repro.obs: log2-bucket histogram vs a NumPy oracle, multi-thread
hammering under the lock sanitizer, disabled-mode no-op identity,
snapshot/diff round-trips, span journaling, owned-counter stats()
compatibility, and an end-to-end BatchServer run that must land real
ms/token samples in the serve histograms."""

import dataclasses
import json
import threading

import numpy as np
import pytest

from repro import obs
from repro.obs import export
from repro.obs.metrics import (EXP_MAX, EXP_MIN, N_BUCKETS, Counter,
                               Histogram, bucket_index, bucket_mid,
                               canonical_name)
from repro.obs.trace import Journal


@pytest.fixture(autouse=True)
def _fresh_obs(monkeypatch):
    """Enabled obs against a private registry/journal per test."""
    monkeypatch.delenv("REPRO_OBS", raising=False)
    obs.reset()
    yield
    obs.reset()


# ---------------------------------------------------------------------------
# histogram vs NumPy oracle
# ---------------------------------------------------------------------------

def _oracle_bucket(v: float) -> int:
    if v <= 0.0:
        return 0
    _, e = np.frexp(np.float64(v))
    return int(np.clip(e, EXP_MIN, EXP_MAX)) - EXP_MIN + 1


def test_bucket_index_matches_numpy_frexp():
    rng = np.random.default_rng(0)
    vals = np.concatenate([
        rng.lognormal(0.0, 6.0, 500),          # ~e^-20 .. e^20
        [0.0, -1.0, 1e-300, 1e300, 0.5, 1.0, 2.0, 4.0 - 1e-12],
    ])
    for v in vals:
        assert bucket_index(float(v)) == _oracle_bucket(float(v))
    assert bucket_index(0.0) == 0
    assert 0 <= bucket_index(1e300) < N_BUCKETS


def test_histogram_counts_match_numpy_bincount():
    rng = np.random.default_rng(1)
    vals = rng.lognormal(-2.0, 3.0, 2000)
    h = Histogram("t")
    for v in vals:
        h.observe(float(v))
    oracle = np.bincount([_oracle_bucket(float(v)) for v in vals],
                         minlength=N_BUCKETS)
    assert h.snapshot()["count"] == 2000
    snap = h.snapshot()["buckets"]
    dense = np.zeros(N_BUCKETS, dtype=np.int64)
    for key, n in snap.items():
        idx = 0 if key == "zero" else int(key) - EXP_MIN + 1
        dense[idx] = n
    assert np.array_equal(dense, oracle)


def test_histogram_stats_vs_numpy():
    rng = np.random.default_rng(2)
    vals = rng.lognormal(0.0, 2.0, 5000)
    h = Histogram("t")
    for v in vals:
        h.observe(float(v))
    s = h.snapshot()
    assert s["mean"] == pytest.approx(float(vals.mean()), rel=1e-9)
    assert s["min"] == pytest.approx(float(vals.min()))
    assert s["max"] == pytest.approx(float(vals.max()))
    # log2 buckets bound any percentile to a factor of 2 of the truth
    for q in (50, 90, 99):
        truth = float(np.percentile(vals, q))
        got = h.percentile(q)
        assert truth / 2 <= got <= truth * 2, (q, got, truth)


def test_histogram_zero_and_negative_land_in_zero_bucket():
    h = Histogram("t")
    h.observe(0.0)
    h.observe(-3.0)
    s = h.snapshot()
    assert s["buckets"] == {"zero": 2}
    assert h.percentile(50) == 0.0


def test_bucket_mid_is_inside_its_bucket():
    for v in (1e-9, 0.37, 1.0, 17.3, 4096.0):
        i = bucket_index(v)
        mid = bucket_mid(i)
        assert bucket_index(mid) == i


# ---------------------------------------------------------------------------
# thread safety (sanitizer enabled via the concurrency marker)
# ---------------------------------------------------------------------------

@pytest.mark.concurrency
def test_threaded_hammer_exact_totals():
    c = obs.counter("hammer.count")
    h = obs.histogram("hammer.lat")
    g = obs.gauge("hammer.level")
    n_threads, per = 8, 10_000

    def work(seed):
        for i in range(per):
            c.inc()
            h.observe(float((seed * per + i) % 97) + 0.5)
            g.set(float(i))

    ts = [threading.Thread(target=work, args=(s,)) for s in range(n_threads)]
    for t in ts:
        t.start()
    for t in ts:
        t.join()
    assert c.value == n_threads * per
    assert h.count == n_threads * per
    assert h.sum == pytest.approx(sum(
        float((s * per + i) % 97) + 0.5
        for s in range(n_threads) for i in range(per)))


@pytest.mark.concurrency
def test_threaded_snapshot_while_writing():
    h = obs.histogram("race.lat")
    stop = threading.Event()

    def writer():
        i = 0
        while not stop.is_set():
            h.observe(float(i % 13) + 1.0)
            i += 1

    t = threading.Thread(target=writer)
    t.start()
    try:
        for _ in range(50):
            snap = obs.snapshot()
            hs = snap["histograms"].get("race.lat")
            if hs:
                assert hs["count"] == sum(hs["buckets"].values())
    finally:
        stop.set()
        t.join()


# ---------------------------------------------------------------------------
# disabled mode
# ---------------------------------------------------------------------------

def test_disabled_factories_return_shared_noops(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "0")
    assert not obs.enabled()
    assert obs.counter("a") is obs.counter("b") is obs.NULL_COUNTER
    assert obs.histogram("a") is obs.NULL_HISTOGRAM
    assert obs.gauge("a") is obs.derived_gauge("b", lambda: 1.0) \
        is obs.NULL_GAUGE
    obs.counter("a").inc(5)
    obs.histogram("a").observe(1.0)
    obs.gauge("a").set(3.0)
    assert obs.default_registry().names() == []
    snap = obs.snapshot()
    assert snap["counters"] == {} and snap["histograms"] == {}


def test_disabled_span_still_times(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "0")
    with obs.span("x.y") as sp:
        assert sp.elapsed_s >= 0.0
    assert sp.duration_s >= 0.0
    assert obs.default_registry().names() == []


def test_disabled_owned_counter_still_counts(monkeypatch):
    monkeypatch.setenv("REPRO_OBS", "0")
    c = obs.owned_counter("cache.hits")
    c.inc(3)
    assert c.value == 3                       # stats() stays accurate
    assert obs.default_registry().names() == []  # but nothing exported


# ---------------------------------------------------------------------------
# registry semantics
# ---------------------------------------------------------------------------

def test_get_or_create_shares_by_name_and_labels():
    a = obs.counter("x", method="m")
    b = obs.counter("x", method="m")
    assert a is b
    assert obs.counter("x", method="other") is not a
    assert canonical_name("x", {"b": 1, "a": 2}) == "x{a=2,b=1}"


def test_kind_mismatch_raises():
    obs.counter("x")
    with pytest.raises(ValueError):
        obs.histogram("x")


def test_owned_counter_replace_follows_newest_instance():
    first = obs.owned_counter("cache.hits")
    first.inc(7)
    second = obs.owned_counter("cache.hits")  # new component instance
    second.inc(2)
    assert obs.snapshot()["counters"]["cache.hits"] == 2
    assert first.value == 7                   # old instance keeps working


def test_owned_gauge_replace_follows_newest_instance():
    obs.owned_gauge("cache.hit_rate", lambda: 0.25)
    obs.owned_gauge("cache.hit_rate", lambda: 0.75)
    assert obs.snapshot()["gauges"]["cache.hit_rate"] == 0.75


# ---------------------------------------------------------------------------
# spans + journal
# ---------------------------------------------------------------------------

def test_span_records_histogram_and_journal():
    with obs.span("unit.op", method="m") as sp:
        pass
    assert sp.duration_s >= 0.0
    snap = obs.snapshot()
    hs = snap["histograms"]["unit.op.s{method=m}"]
    assert hs["count"] == 1
    events = obs.default_journal().events()
    assert len(events) == 1
    ev = events[0]
    assert ev["name"] == "unit.op" and ev["labels"] == {"method": "m"}
    assert ev["dur_s"] >= 0.0 and "thread" in ev


def test_span_records_error_type():
    with pytest.raises(RuntimeError):
        with obs.span("unit.boom"):
            raise RuntimeError("nope")
    ev = obs.default_journal().events()[-1]
    assert ev["error"] == "RuntimeError"


def test_journal_ring_buffer_drops_oldest(tmp_path):
    j = Journal(4)
    for i in range(10):
        j.append({"name": f"e{i}"})
    assert len(j) == 4 and j.dropped == 6
    assert [e["name"] for e in j.events()] == ["e6", "e7", "e8", "e9"]
    out = tmp_path / "j.jsonl"
    assert j.dump_jsonl(str(out)) == 4
    lines = out.read_text().splitlines()
    assert json.loads(lines[0])["name"] == "e6"


# ---------------------------------------------------------------------------
# snapshot / diff round-trip
# ---------------------------------------------------------------------------

def test_snapshot_diff_roundtrip_through_json():
    c = obs.counter("req.count")
    h = obs.histogram("req.lat")
    obs.derived_gauge("req.ratio", lambda: 2.5)
    c.inc(3)
    h.observe(0.5)
    before = json.loads(json.dumps(obs.snapshot()))
    c.inc(7)
    h.observe(1.5)
    h.observe(2.5)
    after = json.loads(json.dumps(obs.snapshot()))

    d = obs.diff(before, after)
    assert d["counters"]["req.count"]["delta"] == 7
    assert d["counters"]["req.count"]["rate_per_s"] >= 0.0
    assert d["histograms"]["req.lat"]["count_delta"] == 2
    assert after["gauges"]["req.ratio"] == 2.5

    text = obs.render(after) + obs.render_diff(d)
    for needle in ("req.count", "req.lat", "req.ratio"):
        assert needle in text


def test_derived_gauge_error_reads_zero():
    obs.derived_gauge("bad.ratio", lambda: 1 / 0)
    assert obs.snapshot()["gauges"]["bad.ratio"] == 0.0


def test_snapshot_version_and_shape():
    snap = obs.snapshot()
    assert snap["version"] == export.SNAPSHOT_VERSION
    assert set(snap) >= {"version", "ts", "counters", "gauges", "histograms"}
    assert "journal" not in snap       # journal is created lazily
    with obs.span("shape.probe"):
        pass
    snap = obs.snapshot()
    assert snap["journal"]["len"] == 1
    assert snap["journal"]["capacity"] >= 1


# ---------------------------------------------------------------------------
# component integration
# ---------------------------------------------------------------------------

def test_token_cache_stats_keys_on_registry():
    from repro.service.cache import TokenCache

    cache = TokenCache(1 << 20)
    cache.put("k", np.arange(8, dtype=np.int64))
    cache.get("k")
    cache.get("absent")
    cache.invalidate("k")
    cache.clear()
    st = cache.stats()
    # pre-obs keys, byte-compatible + the two new lifecycle counters
    assert set(st) == {"capacity_bytes", "bytes", "entries", "hits",
                       "misses", "evictions", "oversize_rejects",
                       "invalidations", "clears", "hit_rate"}
    assert st["hits"] == 1 and st["misses"] == 1
    assert st["invalidations"] == 1 and st["clears"] == 1
    snap = obs.snapshot()
    assert snap["counters"]["cache.hits"] == 1
    assert snap["gauges"]["cache.hit_rate"] == pytest.approx(0.5)


def test_codec_pipeline_gauges_track_traffic():
    from repro.core.codec import method_pipeline
    from repro.tokenizer.vocab import default_tokenizer

    codec = method_pipeline("hybrid", default_tokenizer())
    payloads = [("sample text for the obs layer %d " % i * 40).encode()
                for i in range(4)]
    enc = codec.encode_batch(payloads)
    assert codec.decode_batch(enc) == payloads
    snap = obs.snapshot()
    assert snap["counters"]["codec.encode.bytes_in{method=hybrid}"] \
        == sum(len(p) for p in payloads)
    assert snap["gauges"]["codec.compression_ratio{method=hybrid}"] > 1.0
    assert snap["gauges"]["codec.encode_mb_s{method=hybrid}"] > 0.0
    assert snap["gauges"]["codec.decode_mb_s{method=hybrid}"] > 0.0


def test_serve_loop_ms_per_token_histograms():
    """BatchServer fills serve.prefill/decode ms_per_token with real,
    nonzero samples end-to-end (paper serving-latency accounting)."""
    import jax

    from repro.configs.lopace import CONFIG as LOPACE_CONFIG
    from repro.train.serve_loop import BatchServer
    from repro.train.train_loop import init_train_state

    cfg = dataclasses.replace(LOPACE_CONFIG.smoke(), vocab_size=512,
                              name="obs-serve")
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    server = BatchServer(params, cfg, batch_slots=2, max_len=64)
    rng = np.random.default_rng(3)
    reqs = [server.submit_tokens(
        rng.integers(0, cfg.vocab_size, size=12).astype(np.int64),
        max_new_tokens=4) for _ in range(3)]
    server.run(max_steps=200)
    assert all(r.done for r in reqs)

    snap = obs.snapshot()
    prefill = snap["histograms"]["serve.prefill.ms_per_token"]
    decode = snap["histograms"]["serve.decode.ms_per_token"]
    assert prefill["count"] == 3            # one sample per filled slot
    assert decode["count"] >= 4             # one per wave step
    for hs in (prefill, decode):
        assert hs["p50"] > 0.0 and hs["p99"] >= hs["p50"] > 0.0
        assert hs["mean"] > 0.0
    assert snap["counters"]["serve.decode.tokens"] \
        == sum(len(r.out_tokens) for r in reqs)
