"""Self-healing store: scrub -> quarantine -> degraded reads -> repair.

Corrupts real on-disk records (targeted byte flips inside a record's
[offset, offset+length) window), then asserts the fault-tolerance
contract end to end: the scrubber quarantines exactly the failing
shard, every healthy key keeps serving (degraded reads, never a
store-wide failure), repair re-commits survivors / resyncs casualties
from a replica root / drops only what no copy of survives, and the
gateway surfaces the whole state machine ("shard_quarantined" error
code, scrub stats, store_generation)."""

import numpy as np
import pytest

from repro.core.api import PromptCompressor
from repro.core.store import ShardedPromptStore, ShardQuarantined
from repro.service import PromptService
from repro.service.compaction import compact_shard, compact_store
from repro.service.scrub import (BackgroundScrubber, repair_shard,
                                 repair_store, scrub_shard, scrub_store)
from repro.service.gateway import GatewayClient, GatewayError, start_in_thread
from repro.tokenizer.vocab import default_tokenizer


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


TEXTS = [f"scrub {i}: rotate the audit log, re-sign the manifest, "
         f"then verify checksum chain segment #{i % 5}. " * 3
         for i in range(18)]


def _store(root, tok, **kw):
    kw.setdefault("n_shards", 3)
    return ShardedPromptStore(root, PromptCompressor(tok, method="zstd"),
                              **kw)


def _corrupt(store, key) -> int:
    """Flip bytes in the middle of `key`'s on-disk record; returns its
    shard id."""
    lay = store._layout
    sid = store._shard_of(key, lay.n_shards)
    rec = store._index[key]
    data, _ = store._shard_paths(sid, lay.gens[sid], lay.n_shards)
    with open(data, "r+b") as f:
        f.seek(rec["offset"] + rec["length"] // 2)
        chunk = max(4, rec["length"] // 4)
        f.write(bytes(b ^ 0xFF for b in f.read(chunk)) or b"\xff")
    return sid


def _seeded(root, tok):
    store = _store(root, tok)
    keys = store.put_many(TEXTS)
    return store, keys


# ---------------------------------------------------------------------------
# scrub + quarantine
# ---------------------------------------------------------------------------


def test_clean_scrub_quarantines_nothing(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    results = scrub_store(store)
    assert len(results) == store.n_shards
    assert all(r.clean and not r.quarantined for r in results)
    assert sum(r.n_records for r in results) == len(keys)
    assert store.quarantined() == {}
    store.close()


def test_scrub_detects_corruption_and_reads_degrade(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    bad_key = keys[4]
    sid = _corrupt(store, bad_key)
    res = scrub_shard(store, sid)
    assert res.quarantined and bad_key in res.bad_keys

    # the corrupt key refuses with the full casualty list...
    with pytest.raises(ShardQuarantined) as ei:
        store.get(bad_key)
    assert ei.value.shard_id == sid
    assert bad_key in ei.value.bad_keys
    # ...while every healthy key keeps serving byte-identically —
    # including healthy keys in the QUARANTINED shard
    healthy = [k for k in keys if k not in res.bad_keys]
    assert any(store._shard_of(k, store.n_shards) == sid for k in healthy)
    assert store.get_many(healthy) == [TEXTS[keys.index(k)] for k in healthy]

    st = store.stats()
    assert st["quarantined_shards"] == [sid]
    assert st["quarantined_keys"] == len(res.bad_keys)
    store.close()


def test_quarantine_blocks_tokens_and_merges(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    sid = store._shard_of(keys[0], store.n_shards)
    store.quarantine_shard(sid, [keys[0]], "test")
    with pytest.raises(ShardQuarantined):
        store.get_tokens(keys[0])
    # idempotent merge: a second declaration extends the casualty list
    more = [k for k in keys[1:]
            if store._shard_of(k, store.n_shards) == sid][:1]
    store.quarantine_shard(sid, more)
    assert store.quarantined()[sid]["bad_keys"] == sorted([keys[0]] + more)
    held = store.clear_quarantine(sid)
    assert sorted(held) == sorted([keys[0]] + more)
    assert store.get(keys[0]) == TEXTS[0]     # (bytes were never touched)
    store.close()


def test_compactor_skips_quarantined_shard(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    sid = _corrupt(store, keys[0])
    scrub_shard(store, sid)
    assert compact_shard(store, sid, reselect=False) is None  # forensics
    other = (sid + 1) % store.n_shards
    # healthy shards still compact
    assert compact_shard(store, other, reselect=False) is not None \
        or store.shard_records(other) == []
    store.close()


# ---------------------------------------------------------------------------
# repair
# ---------------------------------------------------------------------------


def test_repair_without_source_drops_casualties(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    bad_key = keys[7]
    sid = _corrupt(store, bad_key)
    casualties = scrub_shard(store, sid).bad_keys
    res = repair_shard(store, sid)
    assert res.repaired and res.n_dropped == len(casualties)
    assert res.n_resynced == 0
    assert not store.is_quarantined(sid)
    # honest loss: KeyError, not wrong bytes and not a held quarantine
    with pytest.raises(KeyError):
        store.get(bad_key)
    survivors = [k for k in keys if k not in casualties]
    assert store.get_many(survivors) == [TEXTS[keys.index(k)]
                                         for k in survivors]
    store.close()
    # a cold reopen scrubs clean
    reopened = _store(tmp_path, tok)
    assert all(r.clean for r in scrub_store(reopened))
    assert reopened.get_many(survivors) == [TEXTS[keys.index(k)]
                                            for k in survivors]
    reopened.close()


def test_repair_resyncs_from_source(tmp_path, tok):
    backup, bkeys = _seeded(tmp_path / "backup", tok)
    store, keys = _seeded(tmp_path / "live", tok)
    assert bkeys == keys                      # content-addressed: same keys
    sid = _corrupt(store, keys[2])
    casualties = scrub_shard(store, sid).bad_keys
    res = repair_shard(store, sid, source=backup)
    assert res.repaired and res.n_resynced == len(casualties)
    assert res.n_dropped == 0
    # full recovery, byte-identical, including the ex-casualty
    assert store.get_many(keys) == TEXTS
    assert all(r.clean for r in scrub_store(store))
    store.close()
    backup.close()


def test_repair_carries_dictionary_sidecar(tmp_path, tok):
    """Survivors in a dict-compacted shard reference the .dict sidecar;
    the repaired generation must re-persist it or they rot on reopen."""
    store, keys = _seeded(tmp_path, tok)
    compact_store(store, reselect=True, train_dict=True)
    assert store.stats()["dicts"] > 0
    bad_key = keys[0]
    sid = _corrupt(store, bad_key)
    casualties = scrub_shard(store, sid).bad_keys
    assert repair_shard(store, sid).repaired
    store.close()
    reopened = _store(tmp_path, tok)
    survivors = [k for k in keys if k not in casualties]
    assert reopened.get_many(survivors) == [TEXTS[keys.index(k)]
                                            for k in survivors]
    assert reopened.verify_all()["failure"] == 0
    reopened.close()


def test_repair_store_heals_every_quarantined_shard(tmp_path, tok):
    backup, _ = _seeded(tmp_path / "backup", tok)
    store, keys = _seeded(tmp_path / "live", tok)
    sids = {_corrupt(store, keys[1]), _corrupt(store, keys[9])}
    scrub_store(store)
    assert set(store.quarantined()) == sids
    results = repair_store(store, source=backup)
    assert len(results) == len(sids) and all(r.repaired for r in results)
    assert store.quarantined() == {}
    assert store.get_many(keys) == TEXTS
    store.close()
    backup.close()


# ---------------------------------------------------------------------------
# background scrubber + service wiring
# ---------------------------------------------------------------------------


def test_background_scrubber_pass_counts_new_quarantines(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    scrubber = BackgroundScrubber(store, interval_s=3600.0)
    assert all(r.clean for r in scrubber.run_pass())
    _corrupt(store, keys[3])
    scrubber.run_pass()
    scrubber.run_pass()                       # still quarantined: no recount
    st = scrubber.stats()
    assert st["passes"] == 3 and st["quarantines"] == 1
    store.close()


def test_service_scrub_and_repair_methods(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    svc = PromptService(store, ingest_async=False,
                        scrub_interval_s=3600.0).start()
    try:
        assert svc.scrubber is not None
        assert all(r.clean for r in svc.scrub())
        sid = _corrupt(store, keys[5])
        assert svc.scrub(sid)[0].quarantined
        assert svc.stats()["scrub"]["passes"] == 0  # synchronous path
        assert svc.repair(sid)[0].repaired
        assert not store.is_quarantined(sid)
    finally:
        svc.stop()
        store.close()


def test_gateway_surfaces_quarantine(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    svc = PromptService(store, ingest_async=False,
                        scrub_interval_s=3600.0).start()
    with start_in_thread(svc) as h:
        with GatewayClient("127.0.0.1", h.port) as c:
            bad_key = keys[6]
            sid = _corrupt(store, bad_key)
            svc.scrub(sid)
            with pytest.raises(GatewayError) as ei:
                c.get(bad_key)
            assert ei.value.code == "shard_quarantined"
            assert ei.value.retryable is False   # terminal: don't hammer
            # healthy keys keep serving through the same gateway
            casualties = store.quarantined()[sid]["bad_keys"]
            healthy = [k for k in keys if k not in casualties]
            assert c.get_many(healthy) == [TEXTS[keys.index(k)]
                                           for k in healthy]
            st = c.stats()
            assert st["service"]["store"]["quarantined_shards"] == [sid]
            assert st["service"]["scrub"]["interval_s"] == 3600.0
            assert st["gateway"]["store_generation"] >= 1
    svc.stop()
    store.close()


def test_meta_generation_tracks_commits_and_replica_staleness(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    g0 = store.meta_generation
    assert g0 >= 1
    replica = _store(tmp_path, tok, readonly=True)
    assert replica.meta_generation == g0
    compact_store(store, reselect=False, train_dict=False)
    assert store.meta_generation > g0         # every publish bumps
    assert replica.meta_generation <= store.meta_generation
    replica.refresh()
    assert replica.meta_generation == store.meta_generation
    assert replica.get_many(keys) == TEXTS
    assert store.stats()["meta_gen"] == store.meta_generation
    replica.close()
    store.close()


def test_tokens_stay_lossless_after_repair(tmp_path, tok):
    store, keys = _seeded(tmp_path, tok)
    before = [np.asarray(a) for a in store.get_tokens_many(keys)]
    sid = _corrupt(store, keys[8])
    casualties = scrub_shard(store, sid).bad_keys
    repair_shard(store, sid)
    for k, ref in zip(keys, before):
        if k in casualties:
            continue
        assert np.array_equal(np.asarray(store.get_tokens(k)), ref)
    store.close()
