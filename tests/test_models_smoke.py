"""Per-architecture smoke tests (assignment deliverable f): every assigned
arch instantiates a REDUCED same-family config and runs one forward/train
step on CPU asserting shapes + no NaNs, plus a decode step through its
cache/recurrent-state path."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import ARCH_IDS, all_configs, count_params
from repro.models import decode_step, forward, init_params, loss_fn, prefill

CONFIGS = all_configs()


def make_batch(cfg, B=2, S=32, seed=0):
    rng = np.random.default_rng(seed)
    batch = {}
    if cfg.frontend == "audio_stub":
        batch["embeds"] = jnp.asarray(rng.normal(size=(B, S, cfg.d_model)),
                                      jnp.bfloat16)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    elif cfg.frontend == "vision_stub":
        S_txt = S - cfg.n_patches
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_txt)))
        batch["patch_embeds"] = jnp.asarray(
            rng.normal(size=(B, cfg.n_patches, cfg.d_model)), jnp.bfloat16)
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S_txt)))
    else:
        batch["tokens"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
        batch["labels"] = jnp.asarray(rng.integers(0, cfg.vocab_size, (B, S)))
    return batch


def seq_for(cfg):
    return 256 if "mlstm" in cfg.block_pattern else 32


# heaviest smoke params (sequential scans / MoE dispatch): 10-60 s each
_SLOW_ARCHS = {"xlstm_1_3b", "deepseek_moe_16b", "minicpm3_4b"}


def _arch_params(archs):
    return [pytest.param(a, marks=pytest.mark.slow) if a in _SLOW_ARCHS else a
            for a in archs]


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_smoke_train_step(arch):
    cfg = CONFIGS[arch].smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg, S=seq_for(cfg))
    (loss, parts), grads = jax.value_and_grad(
        lambda p: loss_fn(p, cfg, batch), has_aux=True)(params)
    gnorm = jnp.sqrt(sum(jnp.sum(g.astype(jnp.float32) ** 2)
                         for g in jax.tree_util.tree_leaves(grads)))
    assert jnp.isfinite(loss), arch
    assert jnp.isfinite(gnorm) and float(gnorm) > 0, arch
    # loss ~ ln(V) at init
    assert 0.5 * np.log(cfg.vocab_size) < float(parts["ce"]) < 2.5 * np.log(cfg.vocab_size)


@pytest.mark.parametrize("arch", _arch_params(ARCH_IDS))
def test_smoke_decode_step(arch):
    cfg = CONFIGS[arch].smoke()
    params = init_params(jax.random.PRNGKey(1), cfg)
    S = seq_for(cfg)
    batch = make_batch(cfg, S=S)
    logits, cache = prefill(params, cfg, batch, max_len=S + 8)
    assert logits.shape[-1] == cfg.vocab_size
    dec_in = ({"embeds": batch["embeds"][:, :1]} if cfg.frontend == "audio_stub"
              else {"tokens": batch["tokens"][:, :1]})
    lg, cache = decode_step(params, cfg, cache, dec_in, logits.shape[1])
    assert lg.shape == (2, 1, cfg.vocab_size)
    assert np.isfinite(np.asarray(lg, np.float32)).all(), arch


@pytest.mark.parametrize("arch", _arch_params(
    ["internlm2_20b", "recurrentgemma_2b", "xlstm_1_3b", "minicpm3_4b"]))
def test_decode_consistent_with_forward(arch):
    """Prefill+decode must reproduce the teacher-forced forward logits —
    validates every cache layout (KV, ring, latent, recurrent state)."""
    import dataclasses

    # f32 activations: this test checks cache-layout MATH, so bf16 drift
    # across 16 stacked layers must not mask it
    cfg = dataclasses.replace(CONFIGS[arch].smoke(),
                              activation_dtype="float32")
    params = init_params(jax.random.PRNGKey(2), cfg)
    S = 256 if "mlstm" in cfg.block_pattern else 24
    batch = make_batch(cfg, B=1, S=S)
    full_logits, _, _ = forward(params, cfg, batch)

    logits, cache = prefill(params, cfg, {"tokens": batch["tokens"][:, :S - 2]}
                            if cfg.frontend == "token" else batch, max_len=S + 4)
    if cfg.frontend != "token":
        pytest.skip("teacher-forcing check on token frontends only")
    lg, cache = decode_step(params, cfg, cache,
                            {"tokens": batch["tokens"][:, S - 2:S - 1]}, S - 2)
    a = np.asarray(lg[0, -1], np.float32)
    b = np.asarray(full_logits[0, S - 2], np.float32)
    np.testing.assert_allclose(a, b, rtol=2e-2, atol=2e-2)


def test_param_counts_in_band():
    """Full configs land near their nameplate sizes."""
    expect = {"deepseek_moe_16b": (15e9, 18e9), "dbrx_132b": (125e9, 137e9),
              "xlstm_1_3b": (1.0e9, 2.5e9), "recurrentgemma_2b": (2.3e9, 3.3e9),
              "minicpm3_4b": (3.4e9, 5.0e9), "gemma_7b": (7.5e9, 9.5e9),
              "gemma2_27b": (24e9, 30e9), "internlm2_20b": (17e9, 22e9),
              "musicgen_medium": (1.0e9, 2.0e9), "llava_next_34b": (30e9, 38e9)}
    for arch, (lo, hi) in expect.items():
        n = count_params(CONFIGS[arch])
        assert lo <= n <= hi, f"{arch}: {n/1e9:.2f}B outside [{lo/1e9},{hi/1e9}]"


def test_moe_capacity_drops_are_bounded():
    from repro.models.ffn import _gshard_dispatch

    cfg = CONFIGS["deepseek_moe_16b"].smoke()
    rng = np.random.default_rng(0)
    G, Sg, k, E, C = 2, 64, cfg.moe.top_k, cfg.moe.n_experts, 32
    top_e = jnp.asarray(rng.integers(0, E, (G, Sg, k)))
    top_p = jnp.asarray(np.full((G, Sg, k), 1.0 / k), jnp.float32)
    dispatch, combine = _gshard_dispatch(cfg, top_e, top_p, C)
    # each (expert, slot) holds at most one token
    assert float(dispatch.sum(axis=1).max()) <= 1.0
    # routed fraction is high at uniform load
    assert float(dispatch.sum()) / (G * Sg * k) > 0.8


def test_remat_modes_agree():
    cfg = CONFIGS["internlm2_20b"].smoke()
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    l0, _ = loss_fn(params, cfg, batch, remat="none")
    l1, _ = loss_fn(params, cfg, batch, remat="full")
    l2, _ = loss_fn(params, cfg, batch, remat="dots")
    assert abs(float(l0) - float(l1)) < 1e-5
    assert abs(float(l0) - float(l2)) < 1e-5


def test_unroll_matches_scan():
    cfg = CONFIGS["recurrentgemma_2b"].smoke()  # has remainder layers
    params = init_params(jax.random.PRNGKey(0), cfg)
    batch = make_batch(cfg)
    a, _, _ = forward(params, cfg, batch, unroll=False)
    b, _, _ = forward(params, cfg, batch, unroll=True)
    np.testing.assert_allclose(np.asarray(a, np.float32),
                               np.asarray(b, np.float32), rtol=1e-4, atol=1e-4)
