"""Launcher regression tests: CLI parsing (the --smoke flag bug), the
kill -> relaunch -> resume cycle through repro.dist.checkpoint, and
compressed-gradient trajectory closeness."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lopace import CONFIG as LOPACE_CONFIG
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_store_from_corpus
from repro.dist.checkpoint import checkpoint_extra, checkpoint_step, latest_checkpoint
from repro.launch import train as launch_train
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step


# ---------------------------------------------------------------------------
# CLI parsing
# ---------------------------------------------------------------------------


def test_smoke_flag_defaults_on_and_can_be_disabled():
    """Regression: --smoke was `store_true, default=True`, so smoke mode
    could never be turned off."""
    assert launch_train.parse_args([]).smoke is True
    assert launch_train.parse_args(["--smoke"]).smoke is True
    assert launch_train.parse_args(["--no-smoke"]).smoke is False
    assert launch_train.parse_args(["--full"]).smoke is False
    assert launch_train.parse_args(["--full", "--smoke"]).smoke is False


def test_parse_args_roundtrip():
    args = launch_train.parse_args(
        ["--arch", "gemma-7b", "--steps", "7", "--ckpt-every", "3",
         "--ckpt-dir", "/tmp/x", "--grad-accum", "2", "--compress-grads"])
    assert args.arch == "gemma-7b"
    assert args.steps == 7 and args.ckpt_every == 3
    assert args.ckpt_dir == "/tmp/x"
    assert args.grad_accum == 2 and args.compress_grads


# ---------------------------------------------------------------------------
# End-to-end: train -> kill -> relaunch -> resume
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_launcher_kill_relaunch_resumes(tmp_path, capsys):
    store = str(tmp_path / "store")
    common = ["--seq-len", "128", "--batch", "4", "--n-prompts", "4",
              "--ckpt-every", "3", "--store-dir", store]

    # uninterrupted reference run
    ck_a = str(tmp_path / "ckpt_a")
    launch_train.main(common + ["--steps", "6", "--ckpt-dir", ck_a])

    # interrupted run: die after the step-3 checkpoint, then relaunch
    ck_b = str(tmp_path / "ckpt_b")
    launch_train.main(common + ["--steps", "3", "--ckpt-dir", ck_b])
    capsys.readouterr()
    launch_train.main(common + ["--steps", "6", "--ckpt-dir", ck_b])
    assert "resumed from step 3" in capsys.readouterr().out

    ck = latest_checkpoint(ck_b)
    assert checkpoint_step(ck) == 6
    # TokenPipeline position resumed exactly: both runs consumed 6 batches
    assert checkpoint_extra(ck)["data"]["step"] == 6
    assert checkpoint_extra(latest_checkpoint(ck_a))["data"]["step"] == 6

    # resumed trajectory lands on the same state as the uninterrupted one
    cfg = dataclasses.replace(LOPACE_CONFIG.smoke(), name="parity")
    params, opt = init_train_state(jax.random.PRNGKey(0), cfg)
    from repro.dist.checkpoint import restore_checkpoint

    a = restore_checkpoint(latest_checkpoint(ck_a), {"params": params, "opt": opt})
    b = restore_checkpoint(ck, {"params": params, "opt": opt})
    for x, y in zip(jax.tree_util.tree_leaves(a), jax.tree_util.tree_leaves(b)):
        np.testing.assert_allclose(np.asarray(x, np.float32),
                                   np.asarray(y, np.float32), rtol=1e-6)


# ---------------------------------------------------------------------------
# Gradient compression: trajectory stays close to the uncompressed run
# ---------------------------------------------------------------------------


@pytest.mark.slow
def test_compress_grads_trajectory_close(tmp_path):
    cfg = dataclasses.replace(LOPACE_CONFIG.smoke(), vocab_size=8192,
                              name="lopace-efcmp")
    store = build_store_from_corpus(tmp_path / "store", n_prompts=4, seed=5)
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=20,
                          weight_decay=0.0)

    def trajectory(compress):
        pipe = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=4,
                                                   seed=7))
        step = jax.jit(make_train_step(cfg, opt_cfg, remat="none",
                                       compress_grads=compress))
        params, opt = init_train_state(jax.random.PRNGKey(11), cfg,
                                       compress_grads=compress)
        losses = []
        for _ in range(12):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            params, opt, m = step(params, opt, batch)
            losses.append(float(m["loss"]))
        return np.asarray(losses)

    base = trajectory(False)
    comp = trajectory(True)
    assert np.all(np.isfinite(comp))
    # int8 EF perturbs steps (per-tensor scales are coarse early on) but
    # must track the same descent: bounded gap, comparable total progress
    descent_base = base[0] - base[-1]
    descent_comp = comp[0] - comp[-1]
    assert descent_comp > 0.6 * descent_base, (base, comp)
    assert np.abs(base - comp).max() < 0.5 * descent_base, (base, comp)
