"""repro.service tier: async ingest queue, background compaction with
codec stage reselection, the serve-path token cache, and PromptService
lifecycle — including the concurrency contracts (threaded store access,
reader/compactor coordination, crash-safe generation swap)."""

import threading
import time

import numpy as np
import pytest

from repro.core.api import PromptCompressor
from repro.core.store import ShardedPromptStore, content_key
from repro.service import (BackgroundCompactor, IngestError, IngestQueue,
                           PromptService, TokenCache, compact_shard,
                           compact_store)
from repro.tokenizer.vocab import default_tokenizer


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


def _texts(n, tag="svc", rep=20):
    return [f"{tag} prompt {i}: deploy the canary and watch the dashboards. "
            * rep for i in range(n)]


def _store(root, tok, method="hybrid", n_shards=4):
    return ShardedPromptStore(root, PromptCompressor(tok, method=method),
                              n_shards=n_shards)


# -- token cache --------------------------------------------------------------


def test_token_cache_hit_miss_eviction_budget():
    cache = TokenCache(capacity_bytes=4 * 100)  # room for 4 100-byte arrays
    arrs = {f"k{i}": np.arange(25, dtype=np.uint32) for i in range(6)}  # 100 B
    assert cache.get("k0") is None                        # miss
    for k, a in arrs.items():
        cache.put(k, a)
    st = cache.stats()
    assert st["entries"] == 4 and st["bytes"] == 400      # budget enforced
    assert st["evictions"] == 2                           # k0, k1 evicted (LRU)
    assert cache.get("k0") is None and cache.get("k5") is not None
    # touching k2 makes k3 the LRU victim
    assert cache.get("k2") is not None
    cache.put("k9", np.arange(25, dtype=np.uint32))
    assert cache.get("k3") is None and cache.get("k2") is not None
    # an array bigger than the whole budget is rejected, not thrashed
    cache.put("huge", np.arange(1000, dtype=np.uint32))
    assert cache.get("huge") is None
    assert cache.stats()["oversize_rejects"] == 1
    assert 0.0 < cache.stats()["hit_rate"] < 1.0


def test_token_cache_get_or_load_many_batches_misses():
    cache = TokenCache(capacity_bytes=1 << 20)
    calls = []

    def loader_many(keys):
        calls.append(list(keys))
        return [np.full(3, int(k[1:]), dtype=np.uint32) for k in keys]

    out = cache.get_or_load_many(["k1", "k2", "k1"], loader_many)
    assert calls == [["k1", "k2"]]        # one batched load, dup deduped
    assert np.array_equal(out[0], out[2])
    out2 = cache.get_or_load_many(["k2", "k3"], loader_many)
    assert calls[1] == ["k3"]             # only the miss is loaded
    assert np.array_equal(out2[0], np.full(3, 2, np.uint32))


# -- ingest queue -------------------------------------------------------------


def test_ingest_queue_roundtrip_lossless(tmp_path, tok):
    store = _store(tmp_path, tok)
    texts = _texts(20)
    with IngestQueue(store, flush_batch=8, flush_interval_s=0.02) as q:
        tickets = [q.submit(texts[i:i + 5]) for i in range(0, 20, 5)]
        keys = [k for t in tickets for k in t.wait(20)]
    assert keys == [content_key(t) for t in texts]  # keys known at submit
    assert store.get_many(keys) == texts
    assert store.verify_all()["failure"] == 0
    st = q.stats()
    assert st["submitted"] == st["committed"] == 20 and st["pending"] == 0


def test_ingest_queue_matches_sync_store_bytes(tmp_path, tok):
    """Async group commits lay out every shard byte-identically to the
    same batches through synchronous put_many (same frames, same seq
    order per shard)."""
    texts = _texts(24, tag="bytes")
    a = _store(tmp_path / "a", tok, method="token")
    b = _store(tmp_path / "b", tok, method="token")
    with IngestQueue(a, flush_batch=8) as q:
        for i in range(0, 24, 8):
            q.submit(texts[i:i + 8]).wait(20)  # one flush per submission
    for i in range(0, 24, 8):
        b.put_many(texts[i:i + 8])
    assert a.keys() == b.keys()
    for i in range(4):
        name = f"shard-{i:03d}.bin"
        assert (tmp_path / "a" / name).read_bytes() == \
            (tmp_path / "b" / name).read_bytes()


def test_ingest_interval_flush_without_explicit_flush(tmp_path, tok):
    store = _store(tmp_path, tok)
    with IngestQueue(store, flush_batch=1000, flush_interval_s=0.02) as q:
        ticket = q.submit(["interval flush " * 10])
        ticket.wait(20)                       # group-commit timer fired
        assert ticket.keys[0] in store


def test_ingest_prefix_ordered_durability(tmp_path, tok):
    """On an error-free run, ticket N waiting implies every earlier
    submission is durable too (WAL-style group-commit ordering; errors
    are isolated per flush — see test_ingest_error_propagates...)."""
    store = _store(tmp_path, tok)
    texts = _texts(30, tag="prefix")
    with IngestQueue(store, flush_batch=4, flush_interval_s=0.01) as q:
        tickets = [q.submit([t]) for t in texts]
        tickets[-1].wait(20)
        for t, text in zip(tickets, texts):   # all earlier ones done
            assert t.done()
            assert t.keys[0] in store


def test_ingest_backpressure_bounds_queue(tmp_path, tok):
    store = _store(tmp_path, tok)
    texts = _texts(40, tag="bp", rep=4)
    with IngestQueue(store, flush_batch=4, max_pending=8) as q:
        for t in texts:
            q.submit([t])
        q.drain()
    st = q.stats()
    assert st["committed"] == 40
    assert st["max_queue_depth"] <= 8 + 1     # one submission of overshoot
    assert len(store) == 40


def test_ingest_error_propagates_and_queue_survives(tmp_path, tok):
    store = _store(tmp_path, tok)
    with IngestQueue(store, flush_batch=4) as q:
        bad = q.submit(["doomed " * 5], method="no-such-method")
        with pytest.raises(IngestError, match="method") as ei:
            bad.wait(20)
        assert isinstance(ei.value.__cause__, ValueError)
        ok = q.submit(["fine " * 5])          # queue still alive after error
        ok.wait(20)
        assert ok.keys[0] in store
    with pytest.raises(RuntimeError, match="not running"):
        q.submit(["too late"])


def test_ingest_error_distinct_instances_per_ticket(tmp_path, tok):
    """Every ticket of a failed flush (and every wait() on one ticket)
    raises a FRESH IngestError — concurrent waiters must never share one
    exception object whose traceback they'd race to mutate.  The shared
    part is the cause: one underlying flush error."""
    store = _store(tmp_path, tok)
    with IngestQueue(store, flush_batch=64,
                     flush_interval_s=10.0) as q:
        t1 = q.submit(["doomed a " * 5], method="no-such-method")
        t2 = q.submit(["doomed b " * 5], method="no-such-method")
        q.flush()                             # both land in ONE flush
        errs = []
        for t in (t1, t2, t1):                # third: re-wait same ticket
            with pytest.raises(IngestError) as ei:
                t.wait(20)
            errs.append(ei.value)
    assert errs[0] is not errs[1]
    assert errs[0] is not errs[2]
    assert errs[0].__cause__ is errs[1].__cause__  # one flush, one cause


# -- compaction ---------------------------------------------------------------


def test_compaction_preserves_bytes_golden(tmp_path, tok):
    """Compaction is content-lossless: every text and token stream is
    byte/id-identical before and after, sha sweep stays clean, and the
    rebuilt shard carries exactly the records it had."""
    store = _store(tmp_path, tok, method="hybrid")
    texts = _texts(16, tag="golden")
    keys = store.put_many(texts)
    before_texts = store.get_many(keys)
    before_tokens = store.get_tokens_many(keys)
    results = compact_store(store, reselect=True)
    assert [r.shard_id for r in results] == list(range(store.n_shards))
    assert store.keys() == keys               # order preserved
    assert store.get_many(keys) == before_texts
    for a, b in zip(before_tokens, store.get_tokens_many(keys)):
        assert np.array_equal(a, b)
    assert store.verify_all() == {"success": 16, "failure": 0, "total": 16}
    for r in results:
        assert r.bytes_after <= r.bytes_before
    # the swap is a generation bump: old filenames gone, meta committed
    st = store.stats()
    assert st["gens"] == [1] * store.n_shards and st["dead_bytes"] == 0
    assert not (tmp_path / "shard-000.bin").exists()
    # reopen resolves the new generation and preserves order + content
    reopened = _store(tmp_path, tok)
    assert reopened.keys() == keys
    assert reopened.get_many(keys) == before_texts


def test_compaction_reencodes_when_another_pipeline_wins(tmp_path, tok):
    """Stage reselection: a shard stored with a deliberately poor method
    for its mix gets re-encoded with the winning pipeline, and shrinks."""
    store = _store(tmp_path, tok, method="token", n_shards=1)
    # highly repetitive text: byte-compression beats raw token packing
    keys = store.put_many([("the same sentence again and again. " * 120)
                           + str(i) for i in range(6)])
    before = store.shard_stats(0)["file_bytes"]
    res = compact_shard(store, 0, reselect=True)
    assert res.reencoded and res.method in ("zstd", "hybrid")
    assert res.bytes_after < before
    assert store.get_many(keys) and store.verify_all()["failure"] == 0
    # frames are self-describing, so a reopen decodes the new method
    reopened = _store(tmp_path, tok, n_shards=1)
    assert reopened.verify_all()["failure"] == 0


def test_compaction_reclaims_duplicate_dead_bytes(tmp_path, tok):
    """The async-ingest dup race (two planners, same text) leaves a dead
    copy on disk; compaction reclaims it."""
    store = _store(tmp_path, tok, method="zstd", n_shards=1)
    text = "raced duplicate " * 30
    _, plan1 = store.plan_batch([text])
    _, plan2 = store.plan_batch([text])       # planned before plan1 commits
    for plan in (plan1, plan2):
        for sid, entries in plan.items():
            store.commit_batch(sid, entries)
    assert len(store) == 1
    assert store.shard_stats(0)["dead_bytes"] > 0
    res = compact_shard(store, 0, reselect=False)
    assert res.bytes_reclaimed > 0
    assert store.shard_stats(0)["dead_bytes"] == 0
    assert store.get(content_key(text)) == text


def test_crashed_compaction_generations_are_garbage_collected(tmp_path, tok):
    store = _store(tmp_path, tok, n_shards=2)
    keys = store.put_many(_texts(8, tag="gc"))
    # crash BEFORE the meta commit: orphaned next-generation files
    (tmp_path / "shard-000.g0001.bin").write_bytes(b"orphan")
    (tmp_path / "shard-000.g0001.idx.jsonl").write_text("{broken")
    reopened = _store(tmp_path, tok)
    assert not (tmp_path / "shard-000.g0001.bin").exists()
    assert reopened.keys() == keys and reopened.verify_all()["failure"] == 0
    # crash AFTER the meta commit: stale old-generation files linger
    compact_store(reopened, reselect=False)
    (tmp_path / "shard-001.bin").write_bytes(b"stale old gen")
    again = _store(tmp_path, tok)
    assert not (tmp_path / "shard-001.bin").exists()
    assert again.keys() == keys and again.verify_all()["failure"] == 0


def test_gc_globs_do_not_swallow_wider_shard_names(tmp_path, tok):
    """GC patterns must match shard i exactly: 'shard-100*' would also
    match shard-1000+ once n_shards needs 4 digits."""
    store = _store(tmp_path, tok, n_shards=4)
    keys = store.put_many(_texts(8, tag="wide"))
    # a (hypothetical) wider-named shard file must survive shard-000's GC
    wide = tmp_path / "shard-0001.bin"
    wide.write_bytes(b"not shard 000's to collect")
    reopened = _store(tmp_path, tok)
    assert wide.exists()
    wide.unlink()
    assert reopened.keys() == keys


def test_gc_leaves_foreign_family_gen0_files(tmp_path, tok):
    """A legacy data.bin/index.jsonl sitting in a multi-shard root (e.g. a
    restored backup awaiting migration) is not ours to collect — only
    generation-suffixed names are unambiguously store-written, so gen-0
    files of a different naming family survive every GC sweep."""
    store = _store(tmp_path, tok, n_shards=4)
    keys = store.put_many(_texts(8, tag="foreign"))
    (tmp_path / "data.bin").write_bytes(b"someone's backup")
    (tmp_path / "index.jsonl").write_text("not ours either\n")
    compact_store(store, reselect=False)      # in-process GC path
    reopened = _store(tmp_path, tok)          # open-time GC path
    assert (tmp_path / "data.bin").read_bytes() == b"someone's backup"
    assert (tmp_path / "index.jsonl").exists()
    assert reopened.keys() == keys
    (tmp_path / "data.bin").unlink()
    (tmp_path / "index.jsonl").unlink()


def test_all_shard_stats_matches_per_shard(tmp_path, tok):
    store = _store(tmp_path, tok, n_shards=4)
    store.put_many(_texts(12, tag="stats"))
    assert store.all_shard_stats() == [store.shard_stats(i) for i in range(4)]


def test_compaction_catches_up_concurrent_commits(tmp_path, tok):
    """Records committed between the compactor's snapshot and its swap are
    carried into the new generation (reader/compactor coordination)."""
    store = _store(tmp_path, tok, n_shards=1)
    keys = store.put_many(_texts(6, tag="snap"))
    recs = store.shard_records(0)
    blobs = store.read_records(0, recs)
    entries = [{"key": r["key"], "seq": r["seq"], "method": r["method"],
                "n_chars": r["n_chars"], "blob": b}
               for r, b in zip(recs, blobs)]
    late = store.put_many(["committed mid-compaction " * 10])  # after snapshot
    swap = store.swap_shard(0, entries)
    assert swap["n_caught_up"] == 1
    assert store.keys() == keys + late
    assert store.verify_all()["failure"] == 0


# -- rebalance ----------------------------------------------------------------


def test_rebalance_preserves_keys_seq_and_content(tmp_path, tok):
    store = _store(tmp_path, tok, n_shards=4)
    texts = _texts(24, tag="reb")
    keys = store.put_many(texts)
    for target in (8, 3, 1):
        res = store.rebalance(target)
        assert res["n_shards_after"] == target == store.n_shards
        assert store.keys() == keys          # seq order preserved
        assert store.get_many(keys) == texts
        reopened = _store(tmp_path, tok)
        assert reopened.n_shards == target and reopened.keys() == keys
    # writes keep working on the final layout
    extra = store.put_many(_texts(4, tag="after-reb"))
    assert store.keys() == keys + extra
    assert store.rebalance(1)["n_caught_up"] == 0  # no-op path


def test_rebalance_while_writers_commit_reroutes(tmp_path, tok):
    """A plan made under the old layout commits correctly after a
    rebalance: commit_batch re-routes by the new shard count."""
    store = _store(tmp_path, tok, n_shards=2)
    texts = _texts(8, tag="stale-plan")
    _, plan = store.plan_batch(texts)
    store.rebalance(5)                        # invalidates the plan routing
    for sid, entries in plan.items():
        store.commit_batch(sid, entries)
    assert len(store) == 8
    assert store.verify_all()["failure"] == 0
    reopened = _store(tmp_path, tok)
    assert reopened.keys() == store.keys()


# -- PromptService ------------------------------------------------------------


def test_service_cached_admission_decodes_once(tmp_path, tok):
    store = _store(tmp_path, tok)
    keys = store.put_many(_texts(6, tag="adm"))
    with PromptService(store, cache_bytes=1 << 20, ingest_async=False) as svc:
        first = svc.get_tokens_many(keys)
        second = svc.get_tokens_many(keys)
        for a, b in zip(first, second):
            assert np.array_equal(a, b)
        st = svc.cache.stats()
        assert st["misses"] == 6 and st["hits"] == 6
        assert np.array_equal(svc.get_tokens(keys[0]), first[0])
        assert svc.cache.stats()["hits"] == 7


def test_service_sync_degrade_and_stats(tmp_path, tok):
    store = _store(tmp_path, tok)
    with PromptService(store, cache_bytes=0, ingest_async=False) as svc:
        ticket = svc.put_async(["sync degrade " * 8])
        assert ticket.done()                  # already durable
        assert ticket.wait(0) == ticket.keys
        st = svc.stats()
        assert st["cache"] is None and st["ingest"] is None
        assert st["store"]["n_prompts"] == 1


def test_service_lifecycle_stop_idempotent(tmp_path, tok):
    store = _store(tmp_path, tok)
    svc = PromptService(store, compact_interval_s=60.0).start()
    t = svc.put_async(_texts(3, tag="stop"))
    svc.stop()
    assert t.done() and t.wait(0)             # stop() drained first
    svc.stop()                                # idempotent
    with pytest.raises(RuntimeError):
        svc.start()


def test_service_no_zombie_restart_after_stop(tmp_path, tok):
    """start()/__enter__/put_async after stop() must raise, not hand back
    a service whose dispatcher and compactor threads are dead (work
    submitted to that zombie would queue forever, undrained)."""
    store = _store(tmp_path, tok)
    svc = PromptService(store)
    svc.start()
    svc.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        svc.start()
    with pytest.raises(RuntimeError, match="stopped"):
        with svc:
            pass                              # pragma: no cover
    with pytest.raises(RuntimeError, match="stopped"):
        svc.put_async(["too late " * 4])
    # the sync-degrade path must refuse too: no queue, but the contract
    # (stopped service accepts no writes) is the same
    sync_svc = PromptService(store, ingest_async=False)
    sync_svc.start()
    sync_svc.stop()
    with pytest.raises(RuntimeError, match="stopped"):
        sync_svc.put_async(["too late " * 4])


def test_cache_serves_read_only_arrays(tmp_path, tok):
    """Cached token arrays are shared across hits; a caller mutating one
    must get a ValueError, and the cached entry must stay intact."""
    store = _store(tmp_path, tok)
    with PromptService(store, ingest_async=False) as svc:
        key = svc.put("mutation probe " * 8)
        arr = svc.get_tokens(key)             # miss: loads + caches
        with pytest.raises(ValueError):
            arr[0] = 999999
        again = svc.get_tokens(key)           # hit: same shared array
        assert again is arr
        assert np.array_equal(np.asarray(store.get_tokens(key)), arr)
    # direct TokenCache.put enforces the same freeze
    cache = TokenCache(1 << 20)
    src = np.arange(8, dtype=np.int64)
    cache.put("k", src)
    with pytest.raises(ValueError):
        cache.get("k")[0] = 7


# -- concurrency (slow tier) --------------------------------------------------


@pytest.mark.slow
@pytest.mark.concurrency
def test_threaded_put_many_and_get_tokens_many(tmp_path, tok):
    """Writers and readers hammer one ShardedPromptStore; every read is
    lossless and the final store passes the sha sweep."""
    store = _store(tmp_path, tok, method="token", n_shards=4)
    texts = _texts(96, tag="thr", rep=6)
    committed: list = []
    commit_lock = threading.Lock()
    errors: list = []

    def writer(lo, hi):
        try:
            for i in range(lo, hi, 4):
                batch = texts[i:i + 4]
                keys = store.put_many(batch)
                with commit_lock:
                    committed.extend(zip(keys, batch))
        except Exception as e:  # pragma: no cover
            errors.append(e)

    def reader():
        try:
            deadline = time.monotonic() + 15
            while time.monotonic() < deadline:
                with commit_lock:
                    snap = list(committed)
                if len(snap) >= len(texts):
                    break
                if not snap:
                    continue
                keys = [k for k, _ in snap[-8:]]
                toks = store.get_tokens_many(keys)
                for (k, text), ids in zip(snap[-8:], toks):
                    assert tok.decode(ids) == text
        except Exception as e:  # pragma: no cover
            errors.append(e)

    threads = ([threading.Thread(target=writer, args=(lo, lo + 24))
                for lo in range(0, 96, 24)]
               + [threading.Thread(target=reader) for _ in range(3)])
    for t in threads:
        t.start()
    for t in threads:
        t.join()
    assert not errors
    assert len(store) == len(texts)
    assert store.verify_all()["failure"] == 0
    # reopen-stable iteration order survives the concurrent commits
    reopened = _store(tmp_path, tok)
    assert reopened.keys() == store.keys()


@pytest.mark.slow
@pytest.mark.concurrency
def test_service_concurrent_ingest_compaction_serve(tmp_path, tok):
    """Acceptance: with the ingest queue AND background compaction
    running, the service stays byte-lossless — verify_all passes and
    every get/get_tokens matches a synchronous reference store."""
    store = _store(tmp_path, tok, method="token", n_shards=4)
    texts = _texts(80, tag="e2e", rep=8)
    svc = PromptService(store, cache_bytes=1 << 20, flush_batch=8,
                        flush_interval_s=0.005, compact_interval_s=0.02,
                        compact_trigger_dead_ratio=0.0, compact_min_dead_bytes=0)
    errors: list = []
    with svc:
        tickets = []

        def producer(lo, hi):
            try:
                for i in range(lo, hi, 5):
                    tickets.append(svc.put_async(texts[i:i + 5]))
            except Exception as e:  # pragma: no cover
                errors.append(e)

        def server_reader():
            try:
                deadline = time.monotonic() + 15
                while time.monotonic() < deadline and len(svc) < len(texts):
                    keys = svc.keys()[-6:]
                    if keys:
                        for ids, key in zip(svc.get_tokens_many(keys), keys):
                            assert content_key(tok.decode(ids)) == key
            except Exception as e:  # pragma: no cover
                errors.append(e)

        producers = [threading.Thread(target=producer, args=(lo, lo + 40))
                     for lo in (0, 40)]
        readers = [threading.Thread(target=server_reader) for _ in range(2)]
        for t in producers + readers:
            t.start()
        for t in producers:
            t.join()
        svc.drain()
        for t in readers:
            t.join()
        for t in tickets:
            t.wait(20)
        assert not errors
        # with warm caches the whole load can finish inside the compactor's
        # first 0.02s tick — give the background thread a bounded window to
        # take its first pass rather than racing it
        deadline = time.monotonic() + 5
        while (time.monotonic() < deadline
               and svc.stats()["compaction"]["compactions"] == 0):
            time.sleep(0.01)
        assert svc.stats()["compaction"]["compactions"] > 0
    assert store.verify_all()["failure"] == 0
    # byte-lossless vs the synchronous reference
    ref = _store(tmp_path / "ref", tok, method="token")
    ref_keys = ref.put_many(texts)
    assert set(store.keys()) == set(ref_keys)
    for key, text in zip(ref_keys, texts):
        assert store.get(key) == ref.get(key) == text
        assert np.array_equal(store.get_tokens(key), ref.get_tokens(key))
    # and the store reopens cleanly after all the generation churn
    reopened = _store(tmp_path, tok)
    assert reopened.verify_all()["failure"] == 0


@pytest.mark.slow
@pytest.mark.concurrency
def test_rebalance_races_ingest_compaction_and_cached_serve(tmp_path, tok):
    """Online rebalances race the async ingest queue, the background
    (dict-training) compactor, and cached `get_tokens` readers on one
    store: no key may be lost, the seq order must be reopen-stable, and
    the TokenCache must never serve an array that does not decode to its
    own content key (content addressing makes staleness structurally
    impossible — this asserts it under the worst interleaving)."""
    store = _store(tmp_path, tok, method="zstd", n_shards=4)
    texts = _texts(120, tag="rebrace", rep=3)
    svc = PromptService(store, cache_bytes=1 << 20, flush_batch=8,
                        flush_interval_s=0.005, compact_interval_s=0.02,
                        compact_trigger_dead_ratio=0.0,
                        compact_min_dead_bytes=0)
    errors: list = []
    tickets: list = []
    with svc:
        def producer(lo, hi):
            try:
                for i in range(lo, hi, 5):
                    tickets.append(svc.put_async(texts[i:i + 5]))
            except Exception as e:  # pragma: no cover
                errors.append(e)

        def cached_reader():
            try:
                deadline = time.monotonic() + 20
                while time.monotonic() < deadline and len(svc) < len(texts):
                    keys = svc.keys()[-6:]
                    if keys:
                        for ids, key in zip(svc.get_tokens_many(keys), keys):
                            assert content_key(tok.decode(ids)) == key
            except Exception as e:  # pragma: no cover
                errors.append(e)

        def rebalancer():
            try:
                for target in (8, 2, 6, 3):
                    time.sleep(0.03)
                    res = svc.rebalance(target)
                    assert res["n_shards_after"] == target
            except Exception as e:  # pragma: no cover
                errors.append(e)

        threads = ([threading.Thread(target=producer, args=(lo, lo + 40))
                    for lo in (0, 40, 80)]
                   + [threading.Thread(target=cached_reader) for _ in range(2)]
                   + [threading.Thread(target=rebalancer)])
        for t in threads:
            t.start()
        for t in threads:
            t.join()
        svc.drain()
        for t in tickets:
            t.wait(20)
        assert not errors
    assert store.n_shards == 3
    assert len(store) == len(texts)                  # no lost keys
    assert store.verify_all()["failure"] == 0
    reopened = _store(tmp_path, tok)
    assert reopened.keys() == store.keys()           # seq order stable
    assert reopened.n_shards == 3
    by_key = {content_key(t): t for t in texts}
    for key in reopened.keys():
        assert reopened.get(key) == by_key[key]
    assert reopened.verify_all()["failure"] == 0


# -- serve-loop / launcher satellites -----------------------------------------


def test_batch_server_rids_monotonic_across_queue_drain():
    """rid must not recycle after the queue drains (len(queue) did)."""
    from repro.configs.lopace import CONFIG
    from repro.train.serve_loop import BatchServer

    server = BatchServer(None, CONFIG.smoke(), batch_slots=2, max_len=32)
    r0 = server.submit_tokens(np.array([1, 2, 3]))
    r1 = server.submit_tokens(np.array([4, 5]))
    server.queue.clear()                      # simulate a drained queue
    r2 = server.submit_tokens(np.array([6]))
    assert [r0.rid, r1.rid, r2.rid] == [0, 1, 2]


def test_serve_parse_args_rejects_oversized_max_new(capsys):
    from repro.launch import serve

    args = serve.parse_args(["--max-new", "16", "--max-len", "128"])
    assert args.max_new == 16 and args.cache_mb == 0.0
    args = serve.parse_args(["--cache-mb", "32", "--ingest-async", "--compact"])
    assert args.cache_mb == 32.0 and args.ingest_async and args.compact
    serve.parse_args(["--max-new", "126", "--max-len", "128"])  # largest ok
    for max_new in ("127", "128", "500"):  # 127 leaves zero prompt tokens
        with pytest.raises(SystemExit):
            serve.parse_args(["--max-new", max_new, "--max-len", "128"])
    assert "--max-new" in capsys.readouterr().err


def test_build_store_from_corpus_async_matches_sync(tmp_path):
    from repro.data.pipeline import build_store_from_corpus

    sync = build_store_from_corpus(tmp_path / "sync", n_prompts=6, seed=5)
    asyn = build_store_from_corpus(tmp_path / "async", n_prompts=6, seed=5,
                                   async_ingest=True)
    assert asyn.keys() == sync.keys()
    assert asyn.verify_all()["failure"] == 0
