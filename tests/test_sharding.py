"""Sharding-rule unit tests: divisibility guards across every arch on both
production mesh shapes (no devices needed — rules only read mesh.shape)."""

import jax
import pytest
from jax.sharding import PartitionSpec as P

from repro.configs import ARCH_IDS, all_configs
from repro.dist.sharding import batch_pspecs, cache_pspecs, param_pspecs, zero1_pspecs
from repro.models.transformer import init_cache, init_params
from repro.train.optimizer import init_opt_state

CONFIGS = all_configs()


class FakeMesh:
    """Duck-typed stand-in: the rules only use .shape and .axis_names."""

    def __init__(self, shape_dict):
        self.shape = shape_dict
        self.axis_names = tuple(shape_dict)


SINGLE = FakeMesh({"data": 16, "model": 16})
MULTI = FakeMesh({"pod": 2, "data": 16, "model": 16})


def _check_divisible(tree, specs, mesh):
    flat, treedef = jax.tree_util.tree_flatten_with_path(tree)
    flat_specs = treedef.flatten_up_to(specs)
    for (path, leaf), spec in zip(flat, flat_specs):
        parts = list(spec) + [None] * (len(leaf.shape) - len(spec))
        for dim, axis in enumerate(parts):
            if axis is None:
                continue
            axes = axis if isinstance(axis, tuple) else (axis,)
            k = 1
            for a in axes:
                k *= mesh.shape[a]
            assert leaf.shape[dim] % k == 0, (
                f"{'/'.join(str(p) for p in path)} dim {dim} "
                f"({leaf.shape[dim]}) not divisible by {axes}={k}")


@pytest.mark.parametrize("arch", ARCH_IDS)
@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_param_specs_divisible(arch, mesh):
    cfg = CONFIGS[arch]
    sds = jax.eval_shape(lambda k: init_params(k, cfg),
                         jax.ShapeDtypeStruct((2,), "uint32"))
    _check_divisible(sds, param_pspecs(sds, cfg, mesh), mesh)


@pytest.mark.parametrize("arch", ["internlm2_20b", "deepseek_moe_16b", "xlstm_1_3b"])
def test_zero1_specs_divisible_and_data_sharded(arch):
    cfg = CONFIGS[arch]
    p_sds = jax.eval_shape(lambda k: init_params(k, cfg),
                           jax.ShapeDtypeStruct((2,), "uint32"))
    o_sds = jax.eval_shape(init_opt_state, p_sds)
    specs = zero1_pspecs(o_sds, cfg, SINGLE)
    _check_divisible(o_sds, specs, SINGLE)
    # at least 80% of moment bytes are data-sharded (ZeRO-1 effective)
    flat, treedef = jax.tree_util.tree_flatten_with_path(o_sds)
    flat_specs = treedef.flatten_up_to(specs)
    sharded = total = 0
    for (path, leaf), spec in zip(flat, flat_specs):
        top = str(getattr(path[0], "key", ""))
        if top not in ("m", "v"):
            continue
        n = 1
        for s in leaf.shape:
            n *= s
        total += n
        if any(a == "data" or (isinstance(a, tuple) and "data" in a)
               for a in spec if a is not None):
            sharded += n
    assert sharded / total > 0.8


def test_expert_weights_expert_parallel():
    cfg = CONFIGS["deepseek_moe_16b"]
    sds = jax.eval_shape(lambda k: init_params(k, cfg),
                         jax.ShapeDtypeStruct((2,), "uint32"))
    specs = param_pspecs(sds, cfg, SINGLE)
    moe_spec = specs["blocks"][0]["ffn"]["w_gate"]
    assert "model" in tuple(moe_spec)  # E dim sharded


def test_vocab_parallel_embeddings():
    cfg = CONFIGS["internlm2_20b"]
    sds = jax.eval_shape(lambda k: init_params(k, cfg),
                         jax.ShapeDtypeStruct((2,), "uint32"))
    specs = param_pspecs(sds, cfg, SINGLE)
    assert tuple(specs["embed"]["table"]) == ("model", None)
    assert tuple(specs["head"]["w"]) == (None, "model")


def test_kv_heads_replicated_when_not_divisible():
    cfg = CONFIGS["dbrx_132b"]  # kv=8 on model=16
    sds = jax.eval_shape(lambda k: init_params(k, cfg),
                         jax.ShapeDtypeStruct((2,), "uint32"))
    specs = param_pspecs(sds, cfg, SINGLE)
    wk = specs["blocks"][0]["mixer"]["wk"]
    assert all(a is None for a in tuple(wk))
    wq = specs["blocks"][0]["mixer"]["wq"]
    assert "model" in tuple(wq)  # 48 q heads DO shard


@pytest.mark.parametrize("mesh", [SINGLE, MULTI], ids=["single", "multi"])
def test_batch_specs(mesh):
    import jax.numpy as jnp

    batch = {"tokens": jax.ShapeDtypeStruct((256, 4096), jnp.int32),
             "accum": jax.ShapeDtypeStruct((8, 32, 4096), jnp.int32),
             "tiny": jax.ShapeDtypeStruct((1, 128), jnp.int32)}
    specs = batch_pspecs(batch, mesh)
    _check_divisible(batch, specs, mesh)
    assert specs["tokens"][0] is not None
    assert specs["accum"][1] is not None and specs["accum"][0] is None
    assert all(a is None for a in tuple(specs["tiny"]))
    # a B=1 probe must replicate, never shard its sequence dim over data
    probe = {"embeds": jax.ShapeDtypeStruct((1, 4096, 64), jnp.float32)}
    assert all(a is None for a in tuple(batch_pspecs(probe, mesh)["embeds"]))
    # explicit accum: microbatch dim shards even when accum count divides dp
    acc = {"tokens": jax.ShapeDtypeStruct((32, 32, 128), jnp.int32)}
    spec = batch_pspecs(acc, mesh, accum=True)["tokens"]
    assert spec[0] is None and spec[1] is not None


@pytest.mark.parametrize("arch", ["internlm2_20b", "minicpm3_4b",
                                  "recurrentgemma_2b", "xlstm_1_3b"])
def test_cache_specs_divisible(arch):
    cfg = CONFIGS[arch]
    sds = jax.eval_shape(lambda: init_cache(cfg, 128, 4096))
    specs = cache_pspecs(sds, cfg, SINGLE)
    _check_divisible(sds, specs, SINGLE)
