"""Optimizer, gradient compression, bucketed collectives, checkpointing,
fault-tolerance scaffolding."""

import json

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.dist.checkpoint import (latest_checkpoint, restore_checkpoint,
                                   save_checkpoint)
from repro.dist.collectives import (dequantize_int8, ef_compress_tree,
                                    flatten_buckets, psum_bucketed,
                                    quantize_int8, unflatten_buckets)
from repro.dist.fault import FleetMonitor, Heartbeat, RestartPolicy
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule


def test_adamw_descends_quadratic():
    cfg = AdamWConfig(lr=0.1, warmup_steps=0, total_steps=100, weight_decay=0.0)
    params = {"w": jnp.array([5.0, -3.0])}
    opt = init_opt_state(params)
    for _ in range(60):
        grads = {"w": 2 * params["w"]}
        params, opt, m = adamw_update(cfg, grads, opt, params)
    assert float(jnp.abs(params["w"]).max()) < 0.5
    assert int(opt["step"]) == 60


def test_lr_schedule_shape():
    cfg = AdamWConfig(lr=1e-3, warmup_steps=10, total_steps=100, min_lr_ratio=0.1)
    assert float(lr_schedule(cfg, jnp.array(0))) == 0.0
    assert abs(float(lr_schedule(cfg, jnp.array(10))) - 1e-3) < 1e-9
    assert float(lr_schedule(cfg, jnp.array(100))) < 2e-4


def test_grad_clipping():
    cfg = AdamWConfig(lr=0.0, clip_norm=1.0, weight_decay=0.0)
    params = {"w": jnp.zeros(3)}
    opt = init_opt_state(params)
    _, _, m = adamw_update(cfg, {"w": jnp.full(3, 100.0)}, opt, params)
    assert float(m["grad_norm"]) > 100


def test_int8_quantize_roundtrip_error_bound():
    x = jnp.array(np.random.default_rng(0).normal(size=1000), jnp.float32)
    q, s = quantize_int8(x)
    err = jnp.abs(dequantize_int8(q, s) - x).max()
    assert float(err) <= float(s) / 2 + 1e-6


def test_error_feedback_conservation():
    """EF property: decompressed + residual == grad + old residual."""
    rng = np.random.default_rng(1)
    grads = {"a": jnp.array(rng.normal(size=50), jnp.float32),
             "b": (jnp.array(rng.normal(size=(4, 5)), jnp.float32),)}
    ef0 = jax.tree_util.tree_map(lambda g: jnp.ones_like(g) * 0.01, grads)
    deq, ef1 = ef_compress_tree(grads, ef0)
    lhs = jax.tree_util.tree_map(lambda d, e: d + e, deq, ef1)
    rhs = jax.tree_util.tree_map(lambda g, e: g + e, grads, ef0)
    for a, b in zip(jax.tree_util.tree_leaves(lhs), jax.tree_util.tree_leaves(rhs)):
        np.testing.assert_allclose(np.asarray(a), np.asarray(b), rtol=1e-5, atol=1e-6)


def test_bucketed_flatten_roundtrip():
    rng = np.random.default_rng(2)
    tree = {"x": jnp.array(rng.normal(size=(7, 3)), jnp.float32),
            "y": [jnp.array(rng.normal(size=100), jnp.bfloat16),
                  jnp.array([1, 2], jnp.float32)]}
    buckets, spec = flatten_buckets(tree, bucket_bytes=256)
    assert len(buckets) >= 2
    out = unflatten_buckets(buckets, spec)
    for a, b in zip(jax.tree_util.tree_leaves(out), jax.tree_util.tree_leaves(tree)):
        assert a.dtype == b.dtype
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=1e-2)


def test_psum_bucketed_under_shard_map():
    from jax.experimental.shard_map import shard_map
    from jax.sharding import Mesh, PartitionSpec as P

    mesh = Mesh(np.array(jax.devices()[:1]), ("data",))
    tree = {"w": jnp.arange(8, dtype=jnp.float32)}

    def f(t):
        return psum_bucketed(t, "data")

    out = shard_map(f, mesh=mesh, in_specs=({"w": P()},), out_specs={"w": P()})(tree)
    np.testing.assert_allclose(np.asarray(out["w"]), np.arange(8))


def test_checkpoint_roundtrip_and_pruning(tmp_path):
    tree = {"params": {"w": jnp.arange(6, dtype=jnp.float32).reshape(2, 3)},
            "step": jnp.array(7, jnp.int32)}
    for step in (1, 2, 3, 4):
        save_checkpoint(tmp_path, step, tree, extra={"data_state": {"step": step}},
                        keep_last=2)
    assert latest_checkpoint(tmp_path).name == "step_00000004"
    # keep_last pruned old steps
    names = sorted(p.name for p in tmp_path.iterdir())
    assert "step_00000001" not in names
    restored = restore_checkpoint(latest_checkpoint(tmp_path), tree)
    np.testing.assert_array_equal(np.asarray(restored["params"]["w"]),
                                  np.asarray(tree["params"]["w"]))


def test_checkpoint_detects_corruption(tmp_path):
    tree = {"w": jnp.ones(10)}
    path = save_checkpoint(tmp_path, 1, tree)
    shard = next(path.glob("shard_*.npz"))
    data = bytearray(shard.read_bytes())
    data[-1] ^= 0xFF
    shard.write_bytes(bytes(data))
    with pytest.raises(ValueError, match="corrupt"):
        restore_checkpoint(path, tree)


def test_checkpoint_shape_mismatch_refused(tmp_path):
    path = save_checkpoint(tmp_path, 1, {"w": jnp.ones(10)})
    with pytest.raises(ValueError, match="shape"):
        restore_checkpoint(path, {"w": jnp.ones(11)})


def test_checkpoint_dtype_mismatch_refused(tmp_path):
    path = save_checkpoint(tmp_path, 1, {"w": jnp.ones(10, jnp.float32)})
    with pytest.raises(ValueError, match="dtype"):
        restore_checkpoint(path, {"w": jnp.ones(10, jnp.bfloat16)})


def test_restart_policy_recovered_host_counts_fresh():
    """A host that recovers and later dies again is a new failure, not an
    already-accounted one."""
    from repro.dist.fault import FleetStatus, RestartPolicy

    pol = RestartPolicy(max_failures=3)
    dead_b = FleetStatus(alive=["a"], dead=["b"], stragglers=[],
                         median_step_time=1.0)
    healthy = FleetStatus(alive=["a", "b"], dead=[], stragglers=[],
                          median_step_time=1.0)
    assert pol.decide(dead_b) == "restart_elastic"
    assert pol.decide(healthy) == "continue"
    assert pol.decide(dead_b) == "restart_elastic"


def test_checkpoint_sweeps_orphaned_tmp_dirs(tmp_path):
    orphan = tmp_path / ".tmp_step_00000001_99999"
    orphan.mkdir(parents=True)
    (orphan / "shard_00000.npz").write_bytes(b"junk from a killed writer")
    save_checkpoint(tmp_path, 2, {"w": jnp.ones(4)})
    assert not list(tmp_path.glob(".tmp_step_*"))
    assert latest_checkpoint(tmp_path).name == "step_00000002"


def test_restart_policy_does_not_recount_stale_dead(tmp_path):
    """A stale heartbeat (dead on every scan) must not drain the failure
    budget and abort a healthy run."""
    from repro.dist.fault import FleetStatus, RestartPolicy

    pol = RestartPolicy(max_failures=2)
    degraded = FleetStatus(alive=["a", "b"], dead=["stale"], stragglers=[],
                           median_step_time=1.0)
    assert pol.decide(degraded) == "restart_elastic"
    for _ in range(20):  # same stale host on every subsequent scan
        assert pol.decide(degraded) == "continue"
    # a SECOND distinct dead host still trips max_failures
    worse = FleetStatus(alive=["a"], dead=["stale", "b"], stragglers=[],
                        median_step_time=1.0)
    assert pol.decide(worse) == "abort"


def test_fleet_monitor_and_straggler(tmp_path):
    hb1 = Heartbeat(tmp_path, "host0")
    hb2 = Heartbeat(tmp_path, "host1")
    hb3 = Heartbeat(tmp_path, "host2")
    for step in range(3):
        hb1.beat(step, step_time_s=1.0)
        hb2.beat(step, step_time_s=1.1)
        hb3.beat(step, step_time_s=9.0)  # straggler
    mon = FleetMonitor(tmp_path, dead_after=60, straggler_factor=2.0)
    st = mon.scan()
    assert set(st.alive) == {"host0", "host1", "host2"}
    assert st.stragglers == ["host2"]
    # host death
    st2 = mon.scan(now=__import__("time").time() + 120)
    assert set(st2.dead) == {"host0", "host1", "host2"}
    pol = RestartPolicy(max_failures=2)
    assert pol.decide(st) == "continue"
    assert pol.decide(st2) == "abort" or pol.decide(st2) == "restart_elastic"


def test_restart_policy_elastic_then_abort(tmp_path):
    from repro.dist.fault import FleetStatus

    pol = RestartPolicy(max_failures=3)
    dead1 = FleetStatus(alive=["a"], dead=["b"], stragglers=[], median_step_time=1.0)
    assert pol.decide(dead1) == "restart_elastic"
    dead3 = FleetStatus(alive=[], dead=["a", "b", "c"], stragglers=[], median_step_time=None)
    assert pol.decide(dead3) == "abort"
