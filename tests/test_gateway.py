"""Gateway + multi-process store ownership: the socket frame protocol,
admission control and per-connection backpressure, client resilience
(reconnect, retry taxonomy, seeded backoff, ticket re-attach), the
fcntl store lease (writer / standby / replica roles), read-replica
generation follow, and the writer-kill -> standby-takeover crash
path."""

import json
import os
import signal
import socket
import struct
import subprocess
import sys
import threading
import time
from pathlib import Path

import numpy as np
import pytest

from repro.core import failpoints
from repro.core.api import PromptCompressor
from repro.core.lease import (StoreLeaseHeld, acquire_store_lease,
                              lease_path)
from repro.core.store import ShardedPromptStore
from repro.service import PromptService
from repro.service.gateway import (GatewayClient, GatewayConnectionLost,
                                   GatewayError, RetryPolicy,
                                   start_in_thread)
from repro.tokenizer.vocab import default_tokenizer

_SRC = str(Path(__file__).resolve().parent.parent / "src")


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


def _texts(n, tag="gw", rep=8):
    return [f"{tag} prompt {i}: page the oncall, roll the deploy back, "
            f"then file the postmortem. " * rep for i in range(n)]


def _store(root, tok, method="hybrid", n_shards=2, **kw):
    return ShardedPromptStore(root, PromptCompressor(tok, method=method),
                              n_shards=n_shards, **kw)


def _service(store, **kw):
    kw.setdefault("flush_batch", 4)
    kw.setdefault("flush_interval_s", 0.05)
    return PromptService(store, **kw).start()


# -- frame protocol + core ops (in-process server) ----------------------------


def test_gateway_ops_roundtrip(tmp_path, tok):
    store = _store(tmp_path, tok)
    svc = _service(store)
    texts = _texts(6)
    with start_in_thread(svc) as h:
        with GatewayClient("127.0.0.1", h.port) as c:
            assert c.ping()["pong"] is True
            keys = c.put(texts[:3])
            assert c.get_many(keys) == texts[:3]          # lossless
            r = c.put_async([texts[3]], wait=True)
            assert r["durable"] and c.get(r["keys"][0]) == texts[3]
            r = c.put_async(texts[4:6])                   # ticketed
            assert not r["durable"]
            assert c.wait(r["ticket"]) == r["keys"]
            assert c.get_many(r["keys"]) == texts[4:6]
            toks = c.get_tokens(keys[0])
            assert np.array_equal(toks,
                                  np.asarray(store.get_tokens(keys[0])))
            st = c.stats(snapshot=True)
            assert st["service"]["store"]["n_prompts"] == 6
            assert st["gateway"]["requests"] >= 8
            lat = {k: v for k, v in st["obs"]["histograms"].items()
                   if k.startswith("gateway.request.s")}
            assert any(v["count"] > 0 and v["p50"] > 0
                       for v in lat.values())
            with pytest.raises(GatewayError) as ei:
                c.get("0" * 64)
            assert ei.value.code == "not_found"
            with pytest.raises(GatewayError) as ei:
                c.wait("no-such-ticket")
            assert ei.value.code == "unknown_ticket"
            with pytest.raises(GatewayError) as ei:
                c.call("frobnicate")
            assert ei.value.code == "unknown_op"
    svc.stop()
    store.close()


def test_gateway_frame_limits_and_bad_frames(tmp_path, tok):
    store = _store(tmp_path, tok)
    svc = _service(store, ingest_async=False)
    with start_in_thread(svc, frame_max=1024) as h:
        # oversized frame: error response, then the connection closes
        with GatewayClient("127.0.0.1", h.port) as c:
            resp = c.request("ping", junk="x" * 4096)
            assert resp["error"] == "frame_too_large"
            with pytest.raises(ConnectionError):
                c.request("ping")
        # non-JSON payload: bad_frame, then close
        sock = socket.create_connection(("127.0.0.1", h.port), timeout=10)
        try:
            sock.sendall(struct.pack(">I", 4) + b"}{!x")
            rf = sock.makefile("rb")
            (length,) = struct.unpack(">I", rf.read(4))
            assert json.loads(rf.read(length))["error"] == "bad_frame"
            assert rf.read(4) == b""                      # closed
        finally:
            sock.close()
    svc.stop()
    store.close()


def test_gateway_admission_reject(tmp_path, tok):
    """With max_inflight=1, a request arriving while one executes is
    rejected immediately — never queued behind it.  retries=0 observes
    the raw protocol verdict (the default retrying client would mask
    the reject by backing off until the slot frees — that's its job)."""
    store = _store(tmp_path, tok)
    svc = _service(store, flush_interval_s=0.4, flush_batch=1024)
    with start_in_thread(svc, max_inflight=1, conn_window=4) as h:
        occupied = threading.Event()
        done: list = []

        def slow_put():
            with GatewayClient("127.0.0.1", h.port) as c1:
                occupied.set()
                # blocks in ticket.wait until the 0.4s flush interval
                done.append(c1.put_async(["slow " * 20], wait=True))

        t = threading.Thread(target=slow_put)
        t.start()
        occupied.wait(5)
        time.sleep(0.1)                       # let the put reach _execute
        with GatewayClient("127.0.0.1", h.port, retries=0) as c2:
            with pytest.raises(GatewayError) as ei:
                c2.ping()
            assert ei.value.code == "admission_reject"
            assert ei.value.retryable is True # server taxonomy verdict
            t.join(10)
            assert done and done[0]["durable"]
            assert c2.ping()["pong"] is True  # slot free again
            st = c2.stats()
            assert st["gateway"]["admission_rejects"] >= 1
    svc.stop()
    store.close()


# -- client resilience: retry taxonomy, reconnect, backoff --------------------


def test_client_retries_admission_reject_to_success(tmp_path, tok):
    """The flip side of the reject test: a DEFAULT client treats
    admission_reject as the transient the server declares it to be and
    backs off until the slot frees — no caller-visible error."""
    store = _store(tmp_path, tok)
    svc = _service(store, flush_interval_s=0.3, flush_batch=1024)
    with start_in_thread(svc, max_inflight=1, conn_window=4) as h:
        occupied = threading.Event()
        done: list = []

        def slow_put():
            with GatewayClient("127.0.0.1", h.port) as c1:
                occupied.set()
                done.append(c1.put_async(["slow " * 20], wait=True))

        t = threading.Thread(target=slow_put)
        t.start()
        occupied.wait(5)
        time.sleep(0.1)
        with GatewayClient("127.0.0.1", h.port, retries=8,
                           retry_base_s=0.05) as c2:
            assert c2.ping()["pong"] is True   # retried through the reject
            t.join(10)
            assert done and done[0]["durable"]
            assert c2.stats()["gateway"]["admission_rejects"] >= 1
    svc.stop()
    store.close()


def test_client_reconnects_after_server_closed_conn(tmp_path, tok):
    """frame_too_large kills the connection server-side; the terminal
    error surfaces (never retried), then the next call transparently
    reconnects instead of failing forever on a dead socket."""
    store = _store(tmp_path, tok)
    svc = _service(store, ingest_async=False)
    with start_in_thread(svc, frame_max=1024) as h:
        with GatewayClient("127.0.0.1", h.port, retries=4,
                           retry_base_s=0.01) as c:
            with pytest.raises(GatewayError) as ei:
                c.call("ping", junk="x" * 4096)
            assert ei.value.code == "frame_too_large"
            assert ei.value.retryable is False
            assert c.ping()["pong"] is True    # lazy reconnect healed it
    svc.stop()
    store.close()


def test_client_survives_injected_socket_faults(tmp_path, tok):
    """Deterministic chaos at the client socket sites: every injected
    send/recv failure is absorbed by reconnect+retry and the acked data
    reads back byte-identical (puts are content-addressed, so the
    ambiguous 'did the torn request execute?' retry is safe)."""
    store = _store(tmp_path, tok)
    svc = _service(store)
    texts = _texts(6, tag="fault")
    with start_in_thread(svc) as h:
        with GatewayClient("127.0.0.1", h.port, retries=6,
                           retry_base_s=0.01) as c:
            with failpoints.injected("gateway.recv=nth:2,error"):
                keys = c.put(texts[:3])
                assert c.get_many(keys) == texts[:3]
            with failpoints.injected("gateway.send=nth:1,error"):
                keys2 = c.put(texts[3:])
            assert c.get_many(keys2) == texts[3:]
    svc.stop()
    store.close()


def test_connection_lost_carries_request_context(tmp_path, tok):
    store = _store(tmp_path, tok)
    svc = _service(store, ingest_async=False)
    with start_in_thread(svc) as h:
        c = GatewayClient("127.0.0.1", h.port, retries=0)
        try:
            with failpoints.injected("gateway.recv=nth:1,error"):
                with pytest.raises(GatewayConnectionLost) as ei:
                    c.get("0" * 64)
            assert ei.value.op == "get"
            assert ei.value.request_id == 1
            assert ei.value.bytes_read == 0
            assert isinstance(ei.value, ConnectionError)  # old contract
        finally:
            c.close()
    svc.stop()
    store.close()


def test_wait_reattaches_to_ticket_across_connections(tmp_path, tok):
    """Tickets are server-side state keyed by server id: a ticket issued
    on one connection is redeemable on ANOTHER (the reconnect-retry of
    `wait` is therefore idempotent, never a lost write)."""
    store = _store(tmp_path, tok)
    svc = _service(store, flush_interval_s=0.2, flush_batch=1024)
    texts = _texts(3, tag="ticket")
    with start_in_thread(svc) as h:
        with GatewayClient("127.0.0.1", h.port) as c1:
            r = c1.put_async(texts)
            assert not r["durable"]
        # c1 is gone; a fresh connection redeems the same ticket
        with GatewayClient("127.0.0.1", h.port) as c2:
            assert c2.wait(r["ticket"], timeout=30) == r["keys"]
            assert c2.get_many(r["keys"]) == texts
    svc.stop()
    store.close()


def test_retry_policy_backoff_is_seeded_and_bounded():
    a = RetryPolicy(retries=4, base_s=0.05, seed=11)
    b = RetryPolicy(retries=4, base_s=0.05, seed=11)
    seq_a = [a.backoff_s(i) for i in range(8)]
    assert seq_a == [b.backoff_s(i) for i in range(8)]      # replayable
    assert all(0 < s <= a.max_s for s in seq_a)
    # exponential envelope: attempt i is bounded by base * 2^i
    for i, s in enumerate(seq_a):
        assert s <= min(a.max_s, 0.05 * 2 ** i)


# -- store lease --------------------------------------------------------------


def _flock_free(root) -> bool:
    """True iff the lease flock is currently acquirable.  A fresh fd in
    the SAME process conflicts with a held flock (locks attach to open
    file descriptions), so this probes real kernel state."""
    fcntl = pytest.importorskip("fcntl")
    fd = os.open(str(lease_path(root)), os.O_RDWR)
    try:
        try:
            fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
        except OSError:
            return False
        fcntl.flock(fd, fcntl.LOCK_UN)
        return True
    finally:
        os.close(fd)


def test_lease_refcounted_within_process(tmp_path, tok):
    root = tmp_path / "store"
    s1 = _store(root, tok)
    s1.put("lease probe " * 8)
    # historical same-process reopen pattern still works: the second
    # writable open shares the held lease instead of self-deadlocking
    # on a second flock fd
    s2 = _store(root, tok)
    assert len(s2) == 1
    s2.close()
    # s1 still owns the root after s2's release (refcount, not drop)
    assert lease_path(root).exists()
    assert not _flock_free(root)
    s1.close()
    assert _flock_free(root)                  # last holder released


def test_lease_cross_process_conflict(tmp_path, tok):
    root = tmp_path / "store"
    store = _store(root, tok)
    probe = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "from repro.core.lease import acquire_store_lease, StoreLeaseHeld\n"
        "try:\n"
        "    acquire_store_lease({root!r}, mode='try')\n"
        "    print('ACQUIRED')\n"
        "except StoreLeaseHeld:\n"
        "    print('HELD')\n"
    ).format(src=_SRC, root=str(root))
    out = subprocess.run([sys.executable, "-c", probe],
                         capture_output=True, text=True, timeout=60)
    assert out.stdout.strip() == "HELD", out.stderr
    store.close()
    out = subprocess.run([sys.executable, "-c", probe],
                         capture_output=True, text=True, timeout=60)
    assert out.stdout.strip() == "ACQUIRED", out.stderr


def test_lease_wait_timeout_releases_cleanly(tmp_path, tok):
    """A standby whose mode='wait' acquire times out must leave no
    residue: no fd holding the flock, a clean TimeoutError, and the
    ability to immediately re-wait — and then actually win once the
    holder exits."""
    root = tmp_path / "store"
    _store(root, tok).close()                 # create the root + lease file
    hold = (
        "import sys; sys.path.insert(0, {src!r})\n"
        "from repro.core.lease import acquire_store_lease\n"
        "lease = acquire_store_lease({root!r}, mode='try')\n"
        "print('HELD', flush=True)\n"
        "sys.stdin.readline()\n"              # parent says when to let go
        "lease.release()\n"
    ).format(src=_SRC, root=str(root))
    holder = subprocess.Popen([sys.executable, "-c", hold],
                              stdin=subprocess.PIPE, stdout=subprocess.PIPE,
                              text=True)
    try:
        assert holder.stdout.readline().strip() == "HELD"
        t0 = time.monotonic()
        with pytest.raises(TimeoutError, match="store lease"):
            acquire_store_lease(root, mode="wait", timeout_s=0.3)
        assert time.monotonic() - t0 < 5.0
        # clean state after the timeout: an immediate re-wait behaves
        # identically instead of deadlocking on a leaked fd
        with pytest.raises(TimeoutError):
            acquire_store_lease(root, mode="wait", timeout_s=0.3)
        holder.stdin.write("go\n")
        holder.stdin.flush()
        assert holder.wait(timeout=30) == 0
        lease = acquire_store_lease(root, mode="wait", timeout_s=10)
        lease.release()
        assert _flock_free(root)              # nothing leaked across all that
    finally:
        if holder.poll() is None:
            holder.kill()


def test_lease_acquire_failpoint_site(tmp_path, tok):
    """The lease.acquire failpoint injects before the flock: chaos can
    simulate a flaky takeover without touching kernel state."""
    root = tmp_path / "store"
    _store(root, tok).close()
    with failpoints.injected("lease.acquire=nth:1,error"):
        with pytest.raises(ConnectionError):
            acquire_store_lease(root, mode="try")
    lease = acquire_store_lease(root, mode="try")   # healthy afterwards
    lease.release()


def test_lease_none_skips_ownership(tmp_path, tok):
    store = _store(tmp_path / "s", tok, lease=None)
    store.put("no lease " * 8)
    assert not lease_path(tmp_path / "s").exists()
    store.close()


# -- read replicas ------------------------------------------------------------


def test_replica_follows_writer(tmp_path, tok):
    root = tmp_path / "store"
    writer = _store(root, tok, n_shards=2)
    texts = _texts(10, tag="rep")
    keys = writer.put_many(texts[:6])
    replica = _store(root, tok, readonly=True)
    assert replica.readonly and not writer.readonly
    assert replica.get_many(keys) == texts[:6]            # byte-identical
    # mutators refuse
    for call in (lambda: replica.put("nope"),
                 lambda: replica.put_many(["nope"]),
                 lambda: replica.rebalance(4),
                 lambda: replica.swap_shard(0, [])):
        with pytest.raises(RuntimeError, match="read-only replica"):
            call()
    with pytest.raises(RuntimeError, match="replicas"):
        writer.refresh()
    # new ingest becomes visible on refresh (no meta change needed)
    keys += writer.put_many(texts[6:])
    assert replica.refresh() is True
    assert replica.get_many(keys) == texts
    assert replica.refresh() is False                     # nothing new
    # compaction generation swap (with dict sidecar training)
    from repro.service.compaction import compact_store
    compact_store(writer, reselect=True, train_dict=True)
    assert replica.refresh() is True
    assert replica._layout.gens == writer._layout.gens
    assert replica.get_many(keys) == texts
    # online rebalance: replica follows the layout change too
    writer.rebalance(3)
    assert replica.refresh() is True
    assert replica.n_shards == 3
    assert replica.get_many(keys) == texts
    for a, b in zip(replica.get_tokens_many(keys),
                    writer.get_tokens_many(keys)):
        assert np.array_equal(np.asarray(a), np.asarray(b))
    replica.close()
    writer.close()


def test_replica_requires_existing_store(tmp_path, tok):
    with pytest.raises(ValueError, match="replica"):
        _store(tmp_path / "nope", tok, readonly=True)


def test_replica_gateway_rejects_writes(tmp_path, tok):
    root = tmp_path / "store"
    writer = _store(root, tok)
    texts = _texts(4, tag="rgw")
    keys = writer.put_many(texts)
    replica = _store(root, tok, readonly=True)
    rsvc = PromptService(replica, ingest_async=False).start()
    with start_in_thread(rsvc, readonly=True) as h:
        with GatewayClient("127.0.0.1", h.port) as c:
            assert c.ping()["readonly"] is True
            assert c.get_many(keys) == texts
            for op, kw in (("put", {"texts": ["x"]}),
                           ("put_async", {"texts": ["x"]}),
                           ("wait", {"ticket": "1"})):
                with pytest.raises(GatewayError) as ei:
                    c.call(op, **kw)
                assert ei.value.code == "read_only"
            # refresh is the replica op; writer gateways refuse it
            writer.put_many(_texts(2, tag="rgw2"))
            assert c.refresh() is True
            assert len(c.stats()["service"]["store"]) > 0
    rsvc.stop()
    replica.close()
    writer.close()


# -- crash: writer SIGKILL -> standby takeover --------------------------------


def _spawn_gateway(root: Path, port_file: Path, *extra: str):
    env = dict(os.environ)
    env["PYTHONPATH"] = _SRC + (os.pathsep + env["PYTHONPATH"]
                                if env.get("PYTHONPATH") else "")
    return subprocess.Popen(
        [sys.executable, "-m", "repro.launch.gateway",
         "--store-dir", str(root), "--port", "0",
         "--port-file", str(port_file), *extra],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT,
        text=True)


def _wait_port(proc, port_file: Path, timeout=30.0) -> dict:
    t0 = time.monotonic()
    while not port_file.exists():
        if proc.poll() is not None:
            raise AssertionError(
                f"gateway died at startup:\n{proc.stdout.read()}")
        if time.monotonic() - t0 > timeout:
            proc.kill()
            raise AssertionError("gateway did not become ready")
        time.sleep(0.05)
    return json.loads(port_file.read_text())


@pytest.mark.slow
@pytest.mark.crash
def test_writer_kill_standby_takeover(tmp_path, tok):
    """SIGKILL the writer gateway mid-ingest: the kernel releases the
    flock, the blocked standby acquires it and serves the store — with
    every durably acknowledged text byte-identical."""
    root = tmp_path / "store"
    writer = _spawn_gateway(root, tmp_path / "w.json", "--shards", "2",
                            "--flush-batch", "4")
    try:
        winfo = _wait_port(writer, tmp_path / "w.json")
        texts = _texts(8, tag="kill")
        with GatewayClient(winfo["host"], winfo["port"]) as c:
            keys = c.put(texts)                   # synchronous: durable
            # standby blocks on the lease while the writer is alive
            standby = _spawn_gateway(root, tmp_path / "s.json",
                                     "--role", "standby")
            try:
                time.sleep(1.0)
                assert not (tmp_path / "s.json").exists(), \
                    "standby must not serve while the writer holds the lease"
                # mid-ingest kill: async tickets in flight, never waited
                c.put_async(_texts(6, tag="doomed"))
                os.kill(writer.pid, signal.SIGKILL)
                writer.wait(timeout=10)
                # the kernel released the flock with the process
                sinfo = _wait_port(standby, tmp_path / "s.json")
                assert sinfo["role"] == "standby"
                with GatewayClient(sinfo["host"], sinfo["port"]) as c2:
                    assert c2.ping()["readonly"] is False
                    # every durably acknowledged text reopens byte-identical
                    assert c2.get_many(keys) == texts
                    # the takeover writer owns ingest now
                    r = c2.put_async(["takeover " * 10], wait=True)
                    assert c2.get(r["keys"][0]) == "takeover " * 10
                standby.send_signal(signal.SIGTERM)
                assert standby.wait(timeout=20) == 0
            finally:
                if standby.poll() is None:
                    standby.kill()
    finally:
        if writer.poll() is None:
            writer.kill()


@pytest.mark.crash
def test_lease_released_on_process_death(tmp_path, tok):
    """The flock dies with the process: after SIGKILL, a fresh writable
    open succeeds immediately and the store is intact."""
    root = tmp_path / "store"
    writer = _spawn_gateway(root, tmp_path / "w.json", "--build-corpus", "6")
    winfo = _wait_port(writer, tmp_path / "w.json")
    with GatewayClient(winfo["host"], winfo["port"]) as c:
        keys = c.put(_texts(3, tag="lease"))
        texts = c.get_many(keys)
    os.kill(writer.pid, signal.SIGKILL)
    writer.wait(timeout=10)
    lease = acquire_store_lease(root, mode="wait", timeout_s=10)
    lease.release()
    reopened = _store(root, tok)
    assert reopened.get_many(keys) == texts
    assert reopened.verify_all()["failure"] == 0
    reopened.close()


# -- concurrency: backpressure under many clients -----------------------------


@pytest.mark.slow
@pytest.mark.concurrency
def test_gateway_concurrent_clients_backpressure(tmp_path, tok):
    """Many client threads push through a small conn_window / max_pending
    configuration (lock sanitizer on via the marker): every acknowledged
    batch is durable and byte-identical, nothing is lost or doubled."""
    store = _store(tmp_path, tok, n_shards=2)
    svc = _service(store, flush_batch=8, max_pending=16)
    n_clients, n_batches = 4, 6
    errors: list = []
    acked: dict = {}
    lock = threading.Lock()
    with start_in_thread(svc, max_inflight=8, conn_window=2) as h:

        def client(ci: int) -> None:
            try:
                with GatewayClient("127.0.0.1", h.port) as c:
                    for bi in range(n_batches):
                        batch = _texts(4, tag=f"c{ci}b{bi}", rep=4)
                        r = c.put_async(batch, wait=True, timeout=60)
                        with lock:
                            acked.update(zip(r["keys"], batch))
            except Exception as e:  # noqa: BLE001 - collected for assert
                errors.append(e)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(n_clients)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(120)
        assert not errors, errors
        with GatewayClient("127.0.0.1", h.port) as c:
            assert len(acked) == n_clients * n_batches * 4
            keys = list(acked)
            for i in range(0, len(keys), 16):
                chunk = keys[i:i + 16]
                assert c.get_many(chunk) == [acked[k] for k in chunk]
            st = c.stats()
            assert st["gateway"]["requests"] >= n_clients * n_batches
    svc.stop()
    store.close()
    assert len(store) == len(acked)
