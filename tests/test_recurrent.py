"""Recurrent-mixer math: linear-scan custom VJP, mLSTM chunkwise ==
recurrent decode, RG-LRU decode == parallel scan."""

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs import all_configs
from repro.models.recurrent import (apply_mlstm, apply_rglru, init_mlstm,
                                    init_mlstm_cache, init_rglru,
                                    init_rglru_cache, linear_scan)

CONFIGS = all_configs()


def test_linear_scan_matches_sequential():
    rng = np.random.default_rng(0)
    a = jnp.asarray(rng.uniform(0.5, 0.99, (2, 64, 8)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(2, 64, 8)), jnp.float32)
    h = np.zeros((2, 8))
    seq = []
    for t in range(64):
        h = np.asarray(a[:, t]) * h + np.asarray(b[:, t])
        seq.append(h.copy())
    ref = np.stack(seq, axis=1)
    # associative (tree) reduction reassociates f32 products: tolerance
    # reflects reassociation error, not a logic difference
    np.testing.assert_allclose(np.asarray(linear_scan(a, b)), ref,
                               rtol=2e-4, atol=1e-5)


@pytest.mark.slow
def test_linear_scan_vjp_matches_autodiff():
    rng = np.random.default_rng(1)
    a = jnp.asarray(rng.uniform(0.3, 0.95, (1, 32, 4)), jnp.float32)
    b = jnp.asarray(rng.normal(size=(1, 32, 4)), jnp.float32)

    def naive(a, b):
        def combine(e1, e2):
            a1, b1 = e1
            a2, b2 = e2
            return a1 * a2, a2 * b1 + b2
        _, h = jax.lax.associative_scan(combine, (a, b), axis=1)
        return (h ** 3).sum()

    def ours(a, b):
        return (linear_scan(a, b) ** 3).sum()

    g1 = jax.grad(naive, argnums=(0, 1))(a, b)
    g2 = jax.grad(ours, argnums=(0, 1))(a, b)
    for x, y in zip(g1, g2):
        np.testing.assert_allclose(np.asarray(x), np.asarray(y), rtol=1e-4, atol=1e-5)


def test_rglru_decode_matches_parallel():
    cfg = CONFIGS["recurrentgemma_2b"].smoke()
    params = init_rglru(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(2)
    x = jnp.asarray(rng.normal(size=(2, 16, cfg.d_model)) * 0.1, jnp.float32)
    y_par, _ = apply_rglru(params, cfg, x, cache=None)
    cache = init_rglru_cache(cfg, 2)
    outs = []
    for t in range(16):
        y_t, cache = apply_rglru(params, cfg, x[:, t:t + 1], cache=cache)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32), rtol=2e-3, atol=2e-3)


@pytest.mark.slow
def test_mlstm_decode_matches_chunkwise():
    cfg = CONFIGS["xlstm_1_3b"].smoke()
    params = init_mlstm(jax.random.PRNGKey(0), cfg)
    rng = np.random.default_rng(3)
    S = 256  # one chunk
    x = jnp.asarray(rng.normal(size=(1, S, cfg.d_model)) * 0.1, jnp.float32)
    y_par, _ = apply_mlstm(params, cfg, x, cache=None)
    cache = init_mlstm_cache(cfg, 1)
    outs = []
    for t in range(S):
        y_t, cache = apply_mlstm(params, cfg, x[:, t:t + 1], cache=cache)
        outs.append(y_t)
    y_seq = jnp.concatenate(outs, axis=1)
    np.testing.assert_allclose(np.asarray(y_par, np.float32),
                               np.asarray(y_seq, np.float32), rtol=5e-3, atol=5e-3)


def test_mlstm_multi_chunk_consistency():
    """Chunk boundaries are invisible: S=512 (2 chunks) == decode replay."""
    cfg = CONFIGS["xlstm_1_3b"].smoke()
    params = init_mlstm(jax.random.PRNGKey(4), cfg)
    rng = np.random.default_rng(5)
    x = jnp.asarray(rng.normal(size=(1, 512, cfg.d_model)) * 0.1, jnp.float32)
    y2, _ = apply_mlstm(params, cfg, x, cache=None)          # 2 chunks of 256
    from repro.models import recurrent as rec
    old = rec._MLSTM_CHUNK
    rec._MLSTM_CHUNK = 512
    try:
        y1, _ = apply_mlstm(params, cfg, x, cache=None)      # single chunk
    finally:
        rec._MLSTM_CHUNK = old
    np.testing.assert_allclose(np.asarray(y1, np.float32),
                               np.asarray(y2, np.float32), rtol=3e-3, atol=3e-3)
