"""Unit + property tests for LoPace binary packing (paper §3.3.3)."""

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core import packing


token_streams = st.lists(st.integers(0, 2**31 - 1), max_size=300)
small_streams = st.lists(st.integers(0, 65535), max_size=300)


@pytest.mark.parametrize("scheme", ["fixed", "varint", "delta-varint"])
def test_roundtrip_basic(scheme):
    for ids in ([], [0], [65535], [65536], [1, 2, 3, 70000, 5],
                list(range(1000))):
        out = packing.unpack_tokens(packing.pack_tokens(ids, scheme))
        assert list(out) == ids


@settings(max_examples=60, deadline=None)
@given(ids=token_streams, scheme=st.sampled_from(["fixed", "varint", "delta-varint"]))
def test_roundtrip_property(ids, scheme):
    out = packing.unpack_tokens(packing.pack_tokens(ids, scheme))
    assert list(out) == ids


@settings(max_examples=40, deadline=None)
@given(ids=small_streams)
def test_uint16_decision(ids):
    """Eq. 7: uint16 iff max <= 65535; total size 1 + 2n (paper §3.3.3)."""
    payload = packing.pack_tokens(ids, "fixed")
    assert payload[0] == packing.FMT_U16
    assert len(payload) == 1 + 2 * len(ids)


def test_uint32_escalation():
    ids = [1, 2, 65536]
    payload = packing.pack_tokens(ids, "fixed")
    assert payload[0] == packing.FMT_U32
    assert len(payload) == 1 + 4 * len(ids)


def test_packed_nbytes_fixed_matches():
    for ids in ([], [5], [70000], list(range(100))):
        assert packing.packed_nbytes_fixed(ids) == len(packing.pack_tokens(ids, "fixed"))


def test_self_describing_format_byte():
    """The format byte alone selects the decoder (paper §3.1)."""
    p16 = packing.pack_tokens([1, 2], "fixed")
    p32 = packing.pack_tokens([1, 2, 99999], "fixed")
    pv = packing.pack_tokens([1, 2], "varint")
    pd = packing.pack_tokens([1, 2], "delta-varint")
    assert {p16[0], p32[0], pv[0], pd[0]} == {0x00, 0x01, 0x02, 0x03}


def test_unknown_format_rejected():
    with pytest.raises(ValueError):
        packing.unpack_tokens(bytes([0x7F, 1, 2]))
    with pytest.raises(ValueError):
        packing.unpack_tokens(b"")


def test_delta_varint_compact_for_sorted():
    ids = list(range(10_000, 12_000))
    assert len(packing.pack_tokens(ids, "delta-varint")) < len(
        packing.pack_tokens(ids, "fixed"))


def test_out_of_range_rejected():
    with pytest.raises(ValueError):
        packing.pack_tokens([-1])
    with pytest.raises(ValueError):
        packing.pack_tokens([2**32])
