"""repro.analysis: per-rule trigger/non-trigger fixtures, waiver and
baseline round-trips, the frozen-format repin gate, the env registry,
and the runtime lock-order sanitizer (including a provoked reversed
shard/index acquisition on a real store)."""

import json
import os
import subprocess
import sys
import tempfile
import threading

import pytest

from repro.analysis import baseline as baseline_mod
from repro.analysis import rules_frozen
from repro.analysis.cli import main as cli_main
from repro.analysis.core import parse_source, run_rules
from repro.core import env
from repro.core.locks import (RANKS, LockOrderViolation, make_lock,
                              make_rlock)
from repro.core.store import ShardedPromptStore

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


def _findings(sources, rule, waive=True):
    """Run one rule over {path: source}; returns findings."""
    files = [parse_source(p, s) for p, s in sorted(sources.items())]
    return [f for f in run_rules(files, [rule]) if f.rule == rule]


# ---------------------------------------------------------------------------
# REPRO001 lock order
# ---------------------------------------------------------------------------

_LOCKED_STORE = '''
import threading
from repro.core.locks import make_lock, make_rlock

class Store:
    def __init__(self):
        self._index_lock = make_rlock("index")
        self.shard_locks = [make_rlock("shard") for _ in range(4)]
        self._meta_lock = make_lock("meta")
'''


def test_lock_order_flags_reversed_nesting():
    src = _LOCKED_STORE + '''
    def read(self, sid):
        with self._index_lock:
            with self.shard_locks[sid]:
                return 1
'''
    found = _findings({"store.py": src}, "REPRO001")
    assert any("rank 20" in f.message and "rank 30" in f.message
               for f in found)


def test_lock_order_accepts_documented_nesting():
    src = _LOCKED_STORE + '''
    def commit(self, sid):
        with self.shard_locks[sid]:
            with self._index_lock:
                return 1
'''
    assert _findings({"store.py": src}, "REPRO001") == []


def test_lock_order_sees_through_one_call_level():
    src = _LOCKED_STORE + '''
    def publish_index(self):
        with self._index_lock:
            pass

    def hold_meta_and_publish(self):
        with self._meta_lock:
            self.publish_index()
'''
    found = _findings({"store.py": src}, "REPRO001")
    assert any("'index'" in f.message and "'meta'" in f.message
               for f in found)


def test_lock_order_flags_cycles_between_unranked_locks():
    src = '''
import threading
A_LOCK = threading.Lock()
B_LOCK = threading.Lock()

def ab():
    with A_LOCK:
        with B_LOCK:
            pass

def ba():
    with B_LOCK:
        with A_LOCK:
            pass
'''
    found = _findings({"mod.py": src}, "REPRO001")
    assert any("cycle" in f.message for f in found)


def test_lock_order_flags_fsync_under_index_lock():
    src = _LOCKED_STORE + '''
    def bad_publish(self, shard):
        with self._index_lock:
            shard.publish([])
'''
    found = _findings({"store.py": src}, "REPRO001")
    assert any("blocking work" in f.message for f in found)


def test_lock_order_resolves_bare_acquire_and_getters():
    src = _LOCKED_STORE + '''
    def compaction_lock(self, sid):
        return self.shard_locks[sid]

def worker(store):
    lock = store.compaction_lock(0)
    lock.acquire()
    try:
        with store._meta_lock:
            pass
    finally:
        lock.release()
'''
    # shard(20) -> meta(40) is legal; reversed getter use must flag
    assert _findings({"store.py": src}, "REPRO001") == []
    src_bad = _LOCKED_STORE + '''
    def compaction_lock(self, sid):
        return self.shard_locks[sid]

def worker(store):
    with store._meta_lock:
        lock = store.compaction_lock(0)
        lock.acquire()
        lock.release()
'''
    found = _findings({"store.py": src_bad}, "REPRO001")
    assert any("'shard'" in f.message and "'meta'" in f.message
               for f in found)


# ---------------------------------------------------------------------------
# REPRO002 durability
# ---------------------------------------------------------------------------

def test_durability_flags_replace_without_fsyncs():
    src = '''
import os

def publish(tmp, final):
    with open(tmp, "w") as f:
        f.write("x")
    os.replace(tmp, final)
'''
    found = _findings({"mod.py": src}, "REPRO002")
    msgs = " | ".join(f.message for f in found)
    assert "preceding file fsync" in msgs
    assert "fsync_dir" in msgs


def test_durability_accepts_full_sequence():
    src = '''
import os
from repro.core.durability import fsync_dir, write_durable

def publish(tmp, final, parent):
    write_durable(tmp, b"x")
    os.replace(tmp, final)
    fsync_dir(parent)
'''
    assert _findings({"mod.py": src}, "REPRO002") == []


def test_durability_waiver_suppresses_with_reason():
    src = '''
import os

def beat(tmp, final):
    # repro-analysis: disable=REPRO002 ephemeral liveness signal
    os.replace(tmp, final)
'''
    assert _findings({"mod.py": src}, "REPRO002") == []


def test_waiver_without_reason_is_itself_a_finding():
    src = '''
import os

def beat(tmp, final):
    # repro-analysis: disable=REPRO002
    os.replace(tmp, final)
'''
    files = [parse_source("mod.py", src)]
    found = run_rules(files, ["REPRO002"])
    assert any(f.rule == "REPRO000" and "without a reason" in f.message
               for f in found)


# ---------------------------------------------------------------------------
# REPRO003 frozen formats
# ---------------------------------------------------------------------------

def _fixture_manifest(tmp_path, fn_src, golden_text="golden v1"):
    mod = tmp_path / "wire.py"
    mod.write_text(fn_src)
    golden = tmp_path / "test_golden.py"
    golden.write_text(golden_text)
    pf = parse_source("wire.py", fn_src)
    node = rules_frozen.find_function(pf.tree, "emit")
    manifest = {
        "version": 1,
        "functions": {"wire.py::emit": rules_frozen.normalized_hash(node)},
        "golden_tests": {"test_golden.py":
                         rules_frozen.file_sha256(str(golden))},
    }
    mpath = tmp_path / "frozen.json"
    mpath.write_text(json.dumps(manifest))
    return mod, golden, mpath


def test_frozen_comment_and_docstring_churn_is_invisible(tmp_path, monkeypatch):
    mod, _, mpath = _fixture_manifest(
        tmp_path, 'def emit(x):\n    """doc."""\n    return x + 1\n')
    monkeypatch.setenv("REPRO_ANALYSIS_FROZEN_MANIFEST", str(mpath))
    churned = ('def emit(x):\n    """rewritten docs!"""\n'
               '    # a new comment\n    return x + 1\n')
    assert _findings({"wire.py": churned}, "REPRO003") == []


def test_frozen_semantic_change_is_flagged(tmp_path, monkeypatch):
    mod, _, mpath = _fixture_manifest(
        tmp_path, "def emit(x):\n    return x + 1\n")
    monkeypatch.setenv("REPRO_ANALYSIS_FROZEN_MANIFEST", str(mpath))
    found = _findings({"wire.py": "def emit(x):\n    return x + 2\n"},
                      "REPRO003")
    assert found and "changed" in found[0].message
    found = _findings({"wire.py": "def other(x):\n    return x\n"},
                      "REPRO003")
    assert found and "no longer exists" in found[0].message


def test_frozen_repin_requires_changed_goldens(tmp_path, monkeypatch):
    mod, golden, mpath = _fixture_manifest(
        tmp_path, "def emit(x):\n    return x + 1\n")
    monkeypatch.setenv("REPRO_ANALYSIS_FROZEN_MANIFEST", str(mpath))
    changed = "def emit(x):\n    return x + 2\n"
    mod.write_text(changed)
    files = [parse_source("wire.py", changed)]
    with pytest.raises(RuntimeError, match="golden"):
        rules_frozen.repin(files, str(tmp_path))
    golden.write_text("golden v2: pins the new stream bytes")
    rules_frozen.repin(files, str(tmp_path))
    assert _findings({"wire.py": changed}, "REPRO003") == []


def test_frozen_src_pins_match_current_tree():
    """The committed manifest matches the committed frozen functions."""
    manifest = rules_frozen.load_manifest(rules_frozen.DEFAULT_MANIFEST)
    files = []
    for spec in manifest["functions"]:
        rel = spec.split("::", 1)[0]
        full = os.path.join(REPO, rel)
        with open(full) as fh:
            files.append(parse_source(rel, fh.read()))
    pins = rules_frozen.compute_pins(files, manifest)
    assert pins == manifest["functions"]


# ---------------------------------------------------------------------------
# REPRO004 kernel hygiene
# ---------------------------------------------------------------------------

_KERNEL_WRAP = '''
import functools
import jax.numpy as jnp
from jax.experimental import pallas as pl

_SCALE = jnp.float32(2.0)
_state = {{}}

def _kernel(x_ref, o_ref):
{body}

def launch(x):
    return pl.pallas_call(_kernel, out_shape=x)(x)
'''


def test_kernel_hygiene_flags_host_state():
    bad_bodies = {
        "    print('tracing')": "print",
        "    o_ref[...] = x_ref[...] * _state['k']": "mutable state",
        "    import numpy as np\n    o_ref[...] = np.random.rand()":
            "host module",
    }
    for body, why in bad_bodies.items():
        src = _KERNEL_WRAP.format(body=body)
        found = _findings({"kernel.py": src}, "REPRO004")
        assert found, f"expected a finding for: {why}"


def test_kernel_hygiene_accepts_clean_kernel():
    src = _KERNEL_WRAP.format(
        body="    o_ref[...] = x_ref[...] * _SCALE")
    assert _findings({"kernel.py": src}, "REPRO004") == []


def test_kernel_hygiene_flags_captured_shape():
    src = '''
import jax.numpy as jnp
from jax.experimental import pallas as pl

table = jnp.zeros((8,))

def _kernel(x_ref, o_ref):
    o_ref[...] = x_ref[...] + table.shape[0]

def launch(x):
    return pl.pallas_call(_kernel, out_shape=x)(x)
'''
    found = _findings({"kernel.py": src}, "REPRO004")
    assert any("shape" in f.message for f in found)


def test_kernel_hygiene_real_kernels_are_clean():
    files = []
    kern_root = os.path.join(REPO, "src", "repro", "kernels")
    for dirpath, _, names in os.walk(kern_root):
        for name in names:
            if name.endswith(".py"):
                full = os.path.join(dirpath, name)
                rel = os.path.relpath(full, REPO)
                with open(full) as fh:
                    files.append(parse_source(rel, fh.read()))
    assert [f for f in run_rules(files, ["REPRO004"])
            if f.rule == "REPRO004"] == []


# ---------------------------------------------------------------------------
# REPRO005 env registry
# ---------------------------------------------------------------------------

def test_env_rule_flags_raw_and_dynamic_reads():
    src = '''
import os

def knob():
    return os.environ.get("REPRO_SOME_KNOB", "1")

def dynamic(name):
    return os.getenv(name)
'''
    found = _findings({"mod.py": src}, "REPRO005")
    assert len(found) == 2
    assert any("REPRO_SOME_KNOB" in f.message for f in found)
    assert any("dynamic key" in f.message for f in found)


def test_env_rule_ignores_writes_and_foreign_vars():
    src = '''
import os

def setup():
    os.environ["XLA_FLAGS"] = "--xla_force_host_platform_device_count=8"
    return os.environ.get("XLA_FLAGS", "")
'''
    assert _findings({"mod.py": src}, "REPRO005") == []
    # env.py itself is the sanctioned reader
    raw = 'import os\n\ndef read(n):\n    return os.environ.get(n, "")\n'
    assert _findings({"repro/core/env.py": raw}, "REPRO005") == []


# ---------------------------------------------------------------------------
# REPRO006 pool re-entrancy
# ---------------------------------------------------------------------------

_POOL_SRC = '''
def _codec_pool():
    return None

def _parallel_map(fn, payloads):
    pool = _codec_pool()
    return list(pool.map(fn, payloads))

def compress_one(p):
    return p

def nested_batch(payloads):
    return _parallel_map(lambda p: compress_one(p), payloads)

def reentrant_task(p):
    return _parallel_map(lambda q: q, [p])

def deadlock_batch(payloads):
    return _parallel_map(reentrant_task, payloads)

def indirect(p):
    return reentrant_task(p)

def indirect_batch(payloads):
    return _parallel_map(lambda p: indirect(p), payloads)
'''


def test_pool_rule_flags_reentrant_tasks_only():
    found = _findings({"codec.py": _POOL_SRC}, "REPRO006")
    lines = {f.line for f in found}
    src_lines = _POOL_SRC.splitlines()
    flagged = {src_lines[l - 1].strip() for l in lines}
    assert any("reentrant_task" in s for s in flagged)
    assert any("indirect" in s for s in flagged)
    assert not any("compress_one" in s for s in flagged)


def test_pool_rule_follows_registry_dict_dispatch():
    src = '''
def _codec_pool():
    return None

def _parallel_map(fn, payloads):
    pool = _codec_pool()
    return list(pool.map(fn, payloads))

def _bad_backend(p):
    return _parallel_map(lambda q: q, [p])

BACKENDS = {"bad": (_bad_backend, None)}

def compress_bytes(p, backend="bad"):
    fn = BACKENDS[backend][0]
    return fn(p)

def batch(payloads):
    return _parallel_map(lambda p: compress_bytes(p), payloads)
'''
    found = _findings({"codec.py": src}, "REPRO006")
    assert any("compress_bytes" in f.message or "lambda" in f.message
               for f in found)


# ---------------------------------------------------------------------------
# REPRO007 obs metric hygiene
# ---------------------------------------------------------------------------

def test_obs_rule_flags_direct_construction_outside_obs():
    src = '''
from repro.obs.metrics import Counter, Histogram
c = Counter("svc.requests")
h = Histogram("svc.latency")
'''
    found = _findings({"src/repro/service/thing.py": src}, "REPRO007")
    assert len(found) == 2
    assert all("direct" in f.message and "helpers" in f.message
               for f in found)
    # the same constructions inside the obs package are the implementation
    assert _findings({"src/repro/obs/metrics2.py": src}, "REPRO007") == []


def test_obs_rule_ignores_unrelated_counter_and_histogram_names():
    src = '''
from collections import Counter
import numpy as np
c = Counter("abc")
h = np.histogram([1, 2, 3])
'''
    assert _findings({"src/repro/core/thing.py": src}, "REPRO007") == []


def test_obs_rule_flags_kind_conflicts_across_files():
    a = 'from repro import obs\nobs.counter("svc.lat")\n'
    b = 'from repro import obs\nobs.histogram("svc.lat")\n'
    found = _findings({"src/repro/a.py": a, "src/repro/b.py": b}, "REPRO007")
    assert len(found) == 1
    assert "one name, one kind" in found[0].message
    # a span owns <name>.s, so a histogram of that name elsewhere conflicts
    a = 'from repro import obs\nwith obs.span("op"): pass\n'
    b = 'from repro import obs\nobs.counter("op.s")\n'
    found = _findings({"src/repro/a.py": a, "src/repro/b.py": b}, "REPRO007")
    assert len(found) == 1 and "'op.s'" in found[0].message


def test_obs_rule_same_kind_reuse_is_fine():
    a = 'from repro import obs\nobs.counter("svc.hits")\n'
    b = 'from repro import obs\nobs.owned_counter("svc.hits")\n'
    assert _findings({"src/repro/a.py": a, "src/repro/b.py": b},
                     "REPRO007") == []


def test_obs_rule_flags_perf_counter_in_service_paths_only():
    src = 'import time\nt0 = time.perf_counter()\n'
    found = _findings({"src/repro/service/pool.py": src}, "REPRO007")
    assert len(found) == 1 and "obs.span" in found[0].message
    assert _findings({"src/repro/core/codec2.py": src}, "REPRO007") == []


def test_obs_rule_waiver():
    src = ('import time\n'
           't0 = time.perf_counter()'
           '  # repro-analysis: disable=REPRO007 scheduler clock, not a metric\n')
    assert _findings({"src/repro/service/pool.py": src}, "REPRO007") == []


# ---------------------------------------------------------------------------
# CLI, baseline round-trip, and the committed tree
# ---------------------------------------------------------------------------

def test_cli_src_is_clean_with_empty_baseline(capsys):
    rc = cli_main([os.path.join(REPO, "src"),
                   "--baseline", os.path.join(REPO,
                                              "analysis-baseline.json")])
    out = capsys.readouterr().out
    assert rc == 0, out
    baseline = json.load(open(os.path.join(REPO, "analysis-baseline.json")))
    assert baseline["findings"] == []


def test_cli_json_format_and_exit_code(tmp_path, capsys):
    bad = tmp_path / "mod.py"
    bad.write_text('import os\n\ndef f():\n'
                   '    return os.environ.get("REPRO_X")\n')
    rc = cli_main([str(bad), "--format", "json"])
    report = json.loads(capsys.readouterr().out)
    assert rc == 1
    assert report["findings"][0]["rule"] == "REPRO005"


def test_cli_baseline_roundtrip(tmp_path, capsys):
    bad = tmp_path / "mod.py"
    bad.write_text('import os\n\ndef f():\n'
                   '    return os.environ.get("REPRO_X")\n')
    base = tmp_path / "base.json"
    assert cli_main([str(bad), "--write-baseline", str(base)]) == 0
    capsys.readouterr()
    assert cli_main([str(bad), "--baseline", str(base)]) == 0
    out = capsys.readouterr().out
    assert "baselined" in out
    # a second, new finding still fails
    bad.write_text(bad.read_text()
                   + '\ndef g():\n    return os.environ.get("REPRO_Y")\n')
    assert cli_main([str(bad), "--baseline", str(base)]) == 1


def test_cli_rejects_unknown_rule(tmp_path, capsys):
    mod = tmp_path / "m.py"
    mod.write_text("x = 1\n")
    assert cli_main([str(mod), "--rules", "REPRO999"]) == 2


# ---------------------------------------------------------------------------
# env registry runtime behavior
# ---------------------------------------------------------------------------

def test_env_registry_rejects_undeclared_names():
    with pytest.raises(RuntimeError, match="undeclared"):
        env.read("REPRO_NOT_A_KNOB")


def test_env_registry_parser_contracts(monkeypatch):
    monkeypatch.setenv("REPRO_CODEC_THREADS", "garbage")
    assert env.read("REPRO_CODEC_THREADS") == 0      # historical: disable
    monkeypatch.setenv("REPRO_LZ_MODE", "bogus")
    assert env.read("REPRO_LZ_MODE") == "auto"
    monkeypatch.setenv("REPRO_LZ_DEVICE_MIN", "nah")
    assert env.read("REPRO_LZ_DEVICE_MIN", 77) == 77  # raise -> default
    monkeypatch.setenv("REPRO_RANS_LANES", "48")
    with pytest.warns(RuntimeWarning):
        assert env.read("REPRO_RANS_LANES") == 32     # clamp to pow2
    monkeypatch.delenv("REPRO_RANS_LANES")
    assert env.read("REPRO_RANS_LANES") is None


# ---------------------------------------------------------------------------
# runtime lock-order sanitizer
# ---------------------------------------------------------------------------

@pytest.fixture
def sanitized(monkeypatch):
    monkeypatch.setenv("REPRO_LOCK_SANITIZER", "1")


def test_sanitizer_disabled_returns_plain_primitives(monkeypatch):
    monkeypatch.delenv("REPRO_LOCK_SANITIZER", raising=False)
    assert type(make_lock("shard")) is type(threading.Lock())


def test_sanitizer_allows_documented_order(sanitized):
    shard, index = make_rlock("shard"), make_rlock("index")
    with shard:
        with index:
            pass
    with index:  # and re-entry of an rlock is fine
        with index:
            pass


def test_sanitizer_raises_on_reversal_with_sites(sanitized):
    shard, index = make_rlock("shard"), make_rlock("index")
    with index:
        with pytest.raises(LockOrderViolation) as exc:
            shard.acquire()
    msg = str(exc.value)
    assert "rank 20" in msg and "rank 30" in msg
    assert "test_analysis.py" in msg  # acquisition sites are reported


def test_sanitizer_equal_ranks_allowed(sanitized):
    locks = [make_rlock("shard") for _ in range(3)]
    for lock in locks:
        lock.acquire()
    for lock in reversed(locks):
        lock.release()


def test_sanitizer_self_deadlock_on_plain_lock(sanitized):
    lock = make_lock("meta")
    lock.acquire()
    with pytest.raises(LockOrderViolation, match="self-deadlock"):
        lock.acquire()
    lock.release()


def test_sanitizer_is_per_thread(sanitized):
    shard, index = make_rlock("shard"), make_rlock("index")
    errors = []

    def other():
        try:
            with shard:   # this thread holds nothing else: fine
                pass
        except LockOrderViolation as exc:  # pragma: no cover
            errors.append(exc)

    with index:
        t = threading.Thread(target=other)
        t.start()
        t.join()
    assert errors == []


def test_sanitizer_catches_reversed_store_acquisition(sanitized, tmp_path):
    """The acceptance scenario: holding the index lock, a reader path
    that takes a shard lock must raise under the sanitizer."""
    store = ShardedPromptStore(tmp_path, n_shards=2)
    key = store.put("the quick brown fox")
    assert store.get(key) == "the quick brown fox"
    with store._index_lock:
        with pytest.raises(LockOrderViolation):
            store.get(key)
    # ...and the store still works once the bad hold is released
    assert store.get(key) == "the quick brown fox"


@pytest.mark.concurrency
def test_concurrency_marker_turns_sanitizer_on(tmp_path):
    """conftest wires REPRO_LOCK_SANITIZER=1 for this marker; a store
    built here must carry sanitized locks."""
    assert os.environ.get("REPRO_LOCK_SANITIZER") == "1"
    store = ShardedPromptStore(tmp_path, n_shards=2)
    key = store.put("marker-enabled store")
    with store._index_lock:
        with pytest.raises(LockOrderViolation):
            store.get(key)


def test_sanitized_store_full_pipeline(sanitized, tmp_path):
    """put/get/batch/rebalance all stay violation-free under the
    sanitizer (the documented order is actually followed)."""
    store = ShardedPromptStore(tmp_path, n_shards=2)
    keys = store.put_many([f"prompt {i} body text" for i in range(24)])
    assert store.get(keys[7]) == "prompt 7 body text"
    store.rebalance(4)
    assert store.get(keys[3]) == "prompt 3 body text"
    assert len(store) == 24
