"""Trained-dictionary codec stage: property round-trips over arbitrary
corpora (train -> compress -> decompress byte-identical), the dict-absent
fallbacks (empty corpus, tiny shards, backends without a dictionary
mode), the golden v2 frame-header layout, and the store-level sidecar
contract (compaction adoption, reopen validation, rebalance stripping).
"""

import hashlib
import struct

import numpy as np
import pytest
from _hypothesis_compat import given, settings, st

from repro.core.api import (DICT_VERSION, VERSION, PromptCompressor,
                            dict_fingerprint, parse_frame)
from repro.core.codec import DictCodec, get_codec, method_pipeline
from repro.core.lz77 import lz_compress, lz_decompress
from repro.core.store import ShardedPromptStore
from repro.core.zstd_backend import (DICT_BACKENDS, compress_bytes_dict,
                                     decompress_bytes_dict,
                                     train_dictionary_bytes)
from repro.service.compaction import compact_store
from repro.tokenizer.vocab import default_tokenizer


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


def _short_corpus(n, tag="dict"):
    return [f"{tag} {i}: fetch the weather for city #{i % 13} and reply "
            "tersely with units." for i in range(n)]


# -- lz77 prefix (dictionary) mode --------------------------------------------


@settings(max_examples=40)
@given(data=st.binary(min_size=0, max_size=400),
       prefix=st.binary(min_size=0, max_size=600))
def test_lz77_prefix_roundtrip(data, prefix):
    comp = lz_compress(data, prefix=prefix)
    assert lz_decompress(comp, prefix=prefix) == data


@settings(max_examples=25)
@given(data=st.binary(min_size=0, max_size=300))
def test_lz77_empty_prefix_is_byte_identical_to_plain(data):
    """prefix=b'' must not change a single output byte — every existing
    repro-lz / repro-lzr blob stays decodable and golden tests hold."""
    assert lz_compress(data, prefix=b"") == lz_compress(data)
    assert lz_decompress(lz_compress(data), prefix=b"") == data


# -- dictionary training -------------------------------------------------------


def test_train_dictionary_edge_cases():
    assert train_dictionary_bytes([], 4096) == b""          # empty corpus
    assert train_dictionary_bytes([b""], 4096) == b""       # empty samples
    assert train_dictionary_bytes([b"abc"], 0) == b""       # zero budget
    # a single unique record has no cross-record redundancy to learn
    one = train_dictionary_bytes([b"solitary record"], 4096)
    assert isinstance(one, bytes)


def test_trained_dictionary_shrinks_templated_corpus():
    samples = [t.encode() for t in _short_corpus(64)]
    d = train_dictionary_bytes(samples, 4096)
    assert d and len(d) <= 4096
    for backend in sorted(DICT_BACKENDS):
        plain = sum(len(compress_bytes_dict(s, b"\x00", backend=backend))
                    for s in samples[:8])
        primed = sum(len(compress_bytes_dict(s, d, backend=backend))
                     for s in samples[:8])
        assert primed < plain, backend


@settings(max_examples=20)
@given(texts=st.lists(st.text(min_size=0, max_size=120), min_size=0,
                      max_size=12))
def test_dict_backend_roundtrip_arbitrary_corpora(texts):
    """Arbitrary corpus -> train -> compress/decompress byte-identical,
    including the dict-absent (empty-training-result) fallback."""
    samples = [t.encode("utf-8") for t in texts]
    d = train_dictionary_bytes(samples, 2048)
    for backend in sorted(DICT_BACKENDS):
        for s in samples:
            if d:
                blob = compress_bytes_dict(s, d, backend=backend)
                assert decompress_bytes_dict(blob, d, backend=backend) == s
            else:  # no dictionary learnable: callers compress plain
                from repro.core.zstd_backend import (compress_bytes,
                                                     decompress_bytes)
                assert decompress_bytes(compress_bytes(s, backend=backend),
                                        backend=backend) == s


# -- DictCodec stage -----------------------------------------------------------


def test_dict_codec_stage_and_registry():
    d = train_dictionary_bytes([t.encode() for t in _short_corpus(32)], 2048)
    codec = get_codec("dict-compressor", dictionary=d)
    assert isinstance(codec, DictCodec)
    payloads = [t.encode() for t in _short_corpus(8, tag="stage")]
    assert codec.decode_batch(codec.encode_batch(payloads)) == payloads
    with pytest.raises(ValueError, match="non-empty"):
        DictCodec(b"")
    with pytest.raises(ValueError, match="dictionary mode"):
        DictCodec(d, backend="lzma")
    with pytest.raises(ValueError, match="byte-compressor stage"):
        method_pipeline("token", tokenizer=default_tokenizer(), dictionary=d)


# -- frame layer ---------------------------------------------------------------


GOLDEN_DICT = b"golden dictionary bytes for the v2 frame header test"


def test_golden_dict_frame_header_layout(tok):
    """Pin the v2 frame header byte layout: the v1 header (15 bytes:
    magic 'LP', version, method, backend, signed level, scheme, 8-byte
    tokenizer fp) followed by the 8-byte dictionary fingerprint
    (sha256(dict)[:8]).  A layout drift would silently orphan every
    dict-compressed store."""
    pc = PromptCompressor(tok, method="zstd", level=15, backend="zstd",
                          scheme="fixed")
    blob = pc.compress_batch(["golden text"], "zstd",
                             dictionary=GOLDEN_DICT)[0]
    expected = (
        b"LP"                                       # magic
        + bytes([DICT_VERSION])                     # version 2
        + bytes([0])                                # method id: zstd
        + bytes([5])                                # backend id: zstd (sorted)
        + struct.pack("<b", 15)                     # signed level byte
        + bytes([0])                                # scheme id: fixed
        + b"\x00" * 8                               # no tokenizer for zstd
        + hashlib.sha256(GOLDEN_DICT).digest()[:8]  # dict fingerprint
    )
    assert blob[:23] == expected
    info = parse_frame(blob)
    assert info.dict_fp == dict_fingerprint(GOLDEN_DICT)
    assert DICT_VERSION == 2 and VERSION == 1
    # and a dictionary-less frame still writes the unchanged v1 header
    plain = pc.compress("golden text", "zstd")
    assert plain[2] == VERSION and parse_frame(plain).dict_fp is None


@settings(max_examples=15)
@given(texts=st.lists(st.text(min_size=1, max_size=150), min_size=1,
                      max_size=8))
def test_compressor_dict_frames_roundtrip_property(texts, tok):
    pc = PromptCompressor(tok)
    for method in ("zstd", "hybrid"):
        d = train_dictionary_bytes(
            pc.byte_stage_payloads(texts, method), 2048)
        if not d:
            continue
        blobs = pc.compress_batch(texts, method, dictionary=d)
        assert pc.decompress_batch(blobs) == texts
        plain = pc.tokens_batch(pc.compress_batch(texts, method))
        primed = pc.tokens_batch(blobs)
        for a, b in zip(plain, primed):
            assert np.array_equal(a, b)


def test_unregistered_dictionary_fails_pointedly(tok):
    pc = PromptCompressor(tok, method="zstd")
    d = train_dictionary_bytes([t.encode() for t in _short_corpus(32)], 2048)
    blob = pc.compress_batch(["needs the dict"], dictionary=d)[0]
    fresh = PromptCompressor(tok, method="zstd")
    with pytest.raises(ValueError, match="sidecar"):
        fresh.decompress(blob)
    fresh.register_dictionary(d)
    assert fresh.decompress(blob) == "needs the dict"


# -- store sidecar contract ----------------------------------------------------


def _dict_store(root, tok, n_texts=48, n_shards=2):
    store = ShardedPromptStore(root, PromptCompressor(tok, method="zstd"),
                               n_shards=n_shards)
    texts = _short_corpus(n_texts, tag="store")
    keys = store.put_many(texts)
    return store, keys, texts


def test_compaction_adopts_dictionary_and_reopens(tmp_path, tok):
    """Acceptance: dictionary-trained compaction strictly reduces total
    store bytes (sidecars charged) on a short-prompt corpus, and the
    store reopens through the sidecar validation path."""
    store, keys, texts = _dict_store(tmp_path, tok)
    st0 = store.stats()
    results = compact_store(store, reselect=True, train_dict=True)
    st1 = store.stats()
    assert any(r.used_dict for r in results)
    assert st1["file_bytes"] + st1["dict_bytes"] < st0["file_bytes"] + st0["dict_bytes"]
    assert store.get_many(keys) == texts
    sidecars = sorted(p.name for p in tmp_path.glob("*.dict"))
    assert sidecars and all(".g0001." in s for s in sidecars)
    reopened = ShardedPromptStore(tmp_path,
                                  PromptCompressor(tok, method="zstd"))
    assert reopened.keys() == keys
    assert reopened.get_many(keys) == texts
    assert reopened.verify_all()["failure"] == 0


def test_second_compaction_keeps_frames_decodable(tmp_path, tok):
    """A rebuild of a dict-bearing shard must never drop the sidecar out
    from under frames that still reference it (carry-through), and a
    re-encode to a new dictionary must swap sidecars atomically."""
    store, keys, texts = _dict_store(tmp_path, tok)
    compact_store(store, train_dict=True)
    # no-reselect pass: blobs are kept verbatim, so the dict must carry
    compact_store(store, reselect=False)
    assert store.get_many(keys) == texts
    reopened = ShardedPromptStore(tmp_path,
                                  PromptCompressor(tok, method="zstd"))
    assert reopened.get_many(keys) == texts
    assert reopened.stats()["dicts"] > 0


def test_corrupt_or_missing_sidecar_refused_on_open(tmp_path, tok):
    store, keys, _ = _dict_store(tmp_path, tok)
    compact_store(store, train_dict=True)
    sidecar = next(tmp_path.glob("*.dict"))
    original = sidecar.read_bytes()
    sidecar.write_bytes(original[:-1] + bytes([original[-1] ^ 1]))
    with pytest.raises(ValueError, match="sha256 mismatch"):
        ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    sidecar.unlink()
    with pytest.raises(ValueError, match="missing"):
        ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"))
    sidecar.write_bytes(original)  # restored: opens again
    assert ShardedPromptStore(
        tmp_path, PromptCompressor(tok, method="zstd")).keys() == keys


def test_one_record_shard_compacts_without_dictionary(tmp_path, tok):
    """1-record shards (below MIN_DICT_RECORDS) never pay for a sidecar."""
    store = ShardedPromptStore(tmp_path, PromptCompressor(tok, method="zstd"),
                               n_shards=1)
    key = store.put("a single lonely record " * 3)
    results = compact_store(store, train_dict=True)
    assert all(not r.used_dict for r in results)
    assert not list(tmp_path.glob("*.dict"))
    assert store.get(key)


def test_rebalance_strips_dict_frames(tmp_path, tok):
    """Rebalancing mixes records from many source shards, so it re-encodes
    dict frames plain: the new layout must carry no sidecar dependencies
    and still be byte-lossless."""
    store, keys, texts = _dict_store(tmp_path, tok, n_shards=4)
    compact_store(store, train_dict=True)
    assert list(tmp_path.glob("*.dict"))
    res = store.rebalance(2)
    assert res["n_reencoded"] > 0
    assert not list(tmp_path.glob("*.dict"))
    assert store.keys() == keys and store.get_many(keys) == texts
    reopened = ShardedPromptStore(tmp_path,
                                  PromptCompressor(tok, method="zstd"))
    assert reopened.n_shards == 2
    assert reopened.keys() == keys and reopened.get_many(keys) == texts
    assert reopened.verify_all()["failure"] == 0
