"""Device codec path: Pallas LZ77 match finder + lane-parallel rANS.

Everything runs in interpret mode on CPU (the kernels compile unchanged
on real accelerators), and every assertion is **byte identity** against
the NumPy fast path — whose own wire format is held to the scalar
oracles by tests/test_codec_vectorized.py — so the oracle chain bottoms
out at the pure-Python coders.
"""

from __future__ import annotations

import contextlib
import os
import random
import struct

import numpy as np
import pytest

os.environ.setdefault("JAX_PLATFORMS", "cpu")

import jax.numpy as jnp

from repro.core.entropy import byte_histogram
from repro.core.lz77 import (_candidates_np, _lz_compress_device,
                             _lz_compress_np, _lz_decompress_scalar,
                             lz_compress, lz_decompress)
from repro.core.rans_np import (_env_lanes, rans_compress_bytes,
                                rans_decode_interleaved,
                                rans_decompress_bytes,
                                rans_decompress_to_device,
                                rans_encode_interleaved)
from repro.kernels.lz_match import lz_candidates_device, lz_candidates_ref
from repro.kernels.rans_lanes import (decode_lanes_ref, encode_lanes_ref,
                                      rans_decode_interleaved_device,
                                      rans_encode_interleaved_device)
from repro.kernels.token_pack import unpack_fixed_device

_PB = 12
DEVICE_LANES = (16, 64, 256, 1024)


def _freqs_for(payload: bytes) -> np.ndarray:
    from repro.core.rans_np import normalize_freqs

    return normalize_freqs(np.bincount(
        np.frombuffer(payload, np.uint8), minlength=256), _PB)


@pytest.fixture(scope="module")
def corpus():
    rng = random.Random(1234)
    words = [bytes(rng.choices(b"etaoin shrdlu\n", k=rng.randint(2, 9)))
             for _ in range(64)]
    text = b"".join(rng.choice(words) for _ in range(12000))
    return {
        "text": text,
        "random": rng.randbytes(50000),
        "runs": b"\x00" * 30000 + rng.randbytes(500) + b"Z" * 5000,
        "period3": b"abc" * 15000,
        "skewed": bytes(rng.choices(b"ab", weights=[200, 1], k=40000)),
        "tiny": b"abcabcabcXYZ",
        "lane-edge": rng.randbytes(4101),   # n % lanes != 0 for every count
    }


# ---------------------------------------------------------------------------
# Lane-parallel rANS kernels (satellite 3: lanes 16..1024, golden headers,
# cross-decode)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("lanes", DEVICE_LANES)
def test_rans_lanes_kernel_bit_identical(corpus, lanes):
    for name, payload in corpus.items():
        sym = np.frombuffer(payload, np.uint8)
        freqs = _freqs_for(payload)
        w_ref, x_ref = encode_lanes_ref(sym, freqs, lanes, _PB)
        w_dev, x_dev = rans_encode_interleaved_device(
            sym, freqs, lanes, _PB, interpret=True)
        assert np.array_equal(w_ref, w_dev), (name, lanes)
        assert np.array_equal(x_ref, x_dev), (name, lanes)
        out = rans_decode_interleaved_device(
            w_dev, x_dev, sym.size, freqs, lanes, _PB, interpret=True)
        assert bytes(out) == payload, (name, lanes)


@pytest.mark.parametrize("lanes", DEVICE_LANES)
def test_rans_lanes_cross_decode(corpus, lanes):
    """NumPy-encoded streams decode on device and vice versa — the blob
    carries no producer mark."""
    payload = corpus["text"]
    sym = np.frombuffer(payload, np.uint8)
    freqs = _freqs_for(payload)
    w_np, x_np = rans_encode_interleaved(sym, freqs, lanes, _PB)
    out_dev = rans_decode_interleaved_device(
        w_np, x_np, sym.size, freqs, lanes, _PB, interpret=True)
    assert bytes(out_dev) == payload
    w_dev, x_dev = rans_encode_interleaved_device(
        sym, freqs, lanes, _PB, interpret=True)
    out_np = rans_decode_interleaved(w_dev, x_dev, sym.size, freqs, lanes, _PB)
    assert out_np.tobytes() == payload


@pytest.mark.parametrize("lanes", DEVICE_LANES)
def test_multilane_golden_header(corpus, lanes, monkeypatch):
    """Frozen multi-lane layout: bit 7 of the prob_bits byte flags the
    interleaved format, the next byte is log2(lanes), and the device
    coder produces the identical blob."""
    payload = corpus["text"]
    blob = rans_compress_bytes(payload, lanes=lanes)
    n, pbb, lane_exp, asize = struct.unpack_from("<IBBH", blob, 0)
    assert n == len(payload)
    assert pbb == _PB | 0x80
    assert lane_exp == lanes.bit_length() - 1
    monkeypatch.setenv("REPRO_RANS_MODE", "device")
    assert rans_compress_bytes(payload, lanes=lanes) == blob
    assert rans_decompress_bytes(blob) == payload
    monkeypatch.setenv("REPRO_RANS_MODE", "numpy")
    assert rans_decompress_bytes(blob) == payload


def test_rans_device_mode_blob_identical(corpus, monkeypatch):
    """REPRO_RANS_MODE=device reproduces the auto-lane NumPy blob
    byte-for-byte on every corpus payload."""
    for name, payload in corpus.items():
        monkeypatch.setenv("REPRO_RANS_MODE", "numpy")
        ref = rans_compress_bytes(payload)
        monkeypatch.setenv("REPRO_RANS_MODE", "device")
        assert rans_compress_bytes(payload) == ref, name
        assert rans_decompress_bytes(ref) == payload, name


def test_rans_device_single_symbol_alphabet(monkeypatch):
    """f == 2**prob_bits overflows the u32 kernel state; dispatch must
    keep that alphabet on the NumPy uint64 lanes even in device mode."""
    payload = b"\x07" * 20000
    monkeypatch.setenv("REPRO_RANS_MODE", "device")
    blob = rans_compress_bytes(payload)
    assert rans_decompress_bytes(blob) == payload
    monkeypatch.setenv("REPRO_RANS_MODE", "numpy")
    assert rans_compress_bytes(payload) == blob


def test_rans_device_underflow_detected(corpus):
    payload = corpus["text"]
    sym = np.frombuffer(payload, np.uint8)
    freqs = _freqs_for(payload)
    words, states = rans_encode_interleaved(sym, freqs, 64, _PB)
    with pytest.raises(ValueError, match="underflow"):
        rans_decode_interleaved_device(
            words[: words.size // 2], states, sym.size, freqs, 64, _PB,
            interpret=True)


def test_rans_decompress_to_device(corpus):
    for payload in (corpus["text"], corpus["tiny"], b"", b"\x42" * 9000):
        blob = rans_compress_bytes(payload)
        out = rans_decompress_to_device(blob)
        assert isinstance(out, jnp.ndarray)
        assert bytes(np.asarray(out)) == payload


def test_decode_lanes_ref_roundtrip(corpus):
    sym = np.frombuffer(corpus["lane-edge"], np.uint8)
    freqs = _freqs_for(corpus["lane-edge"])
    words, states = encode_lanes_ref(sym, freqs, 16, _PB)
    assert decode_lanes_ref(
        words, states, sym.size, freqs, 16, _PB).tobytes() == corpus["lane-edge"]


# ---------------------------------------------------------------------------
# REPRO_RANS_LANES env hardening (satellite 2)
# ---------------------------------------------------------------------------


@pytest.mark.parametrize("raw,expect,warns", [
    ("-1", None, True),        # negative -> auto, warned
    ("0", None, False),        # documented auto spelling, silent
    ("2048", 1024, True),      # above max -> clamp, warned
    ("banana", None, True),    # garbage -> auto, warned
    ("48", 32, True),          # non-power-of-two -> clamp down, warned
    ("64", 64, False),
    ("", None, False),
])
def test_env_lanes_sanitized(monkeypatch, raw, expect, warns):
    monkeypatch.setenv("REPRO_RANS_LANES", raw)
    if warns:
        with pytest.warns(RuntimeWarning):
            assert _env_lanes() == expect
    else:
        assert _env_lanes() == expect


@pytest.mark.parametrize("raw", ["-1", "0", "2048", "banana"])
def test_env_lanes_never_break_compression(monkeypatch, corpus, raw):
    """Bad env values degrade to auto/clamped lanes with a warning —
    compression itself must keep working and round-trip."""
    payload = corpus["text"]
    monkeypatch.delenv("REPRO_RANS_LANES", raising=False)
    monkeypatch.setenv("REPRO_RANS_LANES", raw)
    ctx = (pytest.warns(RuntimeWarning) if raw != "0"
           else contextlib.nullcontext())
    with ctx:
        blob = rans_compress_bytes(payload)
    assert rans_decompress_bytes(blob) == payload
    if raw == "2048":
        assert blob[5] == 10  # clamped to 1024 lanes


def test_explicit_lanes_argument_still_strict():
    """Only env input is sanitized; the programmatic API keeps raising."""
    for bad in (-1, 3, 2048):
        with pytest.raises(ValueError, match="power of two"):
            rans_compress_bytes(b"xy" * 100, lanes=bad)


# ---------------------------------------------------------------------------
# Device LZ77 match finder
# ---------------------------------------------------------------------------


def test_lz_candidates_device_matches_ref(corpus):
    for name, payload in corpus.items():
        ok_r, cand_r, mlen_r = lz_candidates_ref(payload, 0)
        ok_d, cand_d, mlen_d = lz_candidates_device(
            payload, 0, interpret=True)
        assert np.array_equal(ok_r, ok_d), name
        assert np.array_equal(cand_r[ok_r], cand_d[ok_d]), name
        # exact lengths agree; the dense device extension may resolve
        # positions the NumPy run-dominance break left lazy (negative) —
        # never the reverse, and both resolve identically at selection
        both = (mlen_r > 0) & (mlen_d > 0)
        assert np.array_equal(mlen_r[both], mlen_d[both]), name
        assert not np.any((mlen_d < 0) & (mlen_r > 0)), name


def test_lz_device_compress_byte_identical(corpus):
    for name, payload in corpus.items():
        ref = _lz_compress_np(payload)
        dev = _lz_compress_device(payload)
        assert dev == ref, name
        assert _lz_decompress_scalar(dev) == payload, name


def test_lz_device_dictionary_prefix(corpus):
    prefix = corpus["text"][:4096]
    for payload in (corpus["text"][4096:20000], corpus["tiny"],
                    corpus["random"][:8000]):
        ref = _lz_compress_np(payload, prefix=prefix)
        dev = _lz_compress_device(payload, prefix=prefix)
        assert dev == ref
        assert _lz_decompress_scalar(dev, prefix=prefix) == payload


@pytest.mark.parametrize("n", [0, 1, 3, 4, 5, 7, 8, 9, 16, 17])
def test_lz_device_truncation_edges(corpus, n):
    """Every byte length around the 4-gram/8-gram bounds."""
    payload = corpus["text"][:n]
    assert _lz_compress_device(payload) == _lz_compress_np(payload)
    assert lz_decompress(_lz_compress_device(payload)) == payload


def test_lz_mode_device_env(corpus, monkeypatch):
    monkeypatch.setenv("REPRO_LZ_MODE", "device")
    dev = lz_compress(corpus["text"])
    monkeypatch.setenv("REPRO_LZ_MODE", "vector")
    assert dev == lz_compress(corpus["text"])
    assert lz_decompress(dev) == corpus["text"]


def test_lz_candidates_np_shared_contract(corpus):
    """The refactored NumPy candidate stage feeds the same selection/emit
    the device path uses; its output must equal the ref wrapper."""
    payload = corpus["text"][:30000]
    ok, cand, mlen = _candidates_np(payload, 0, len(payload))
    ok2, cand2, mlen2 = lz_candidates_ref(payload, 0)
    assert np.array_equal(ok, ok2)
    assert np.array_equal(cand, cand2)
    assert np.array_equal(mlen, mlen2)


# ---------------------------------------------------------------------------
# Histogram crossover (satellite 1) + device token landing
# ---------------------------------------------------------------------------


def test_histogram_small_payload_stays_host(corpus, monkeypatch):
    """Below the crossover the device path is never taken implicitly —
    byte_histogram must not import/launch the kernel for tiny payloads
    even when a backend claims to be attached."""
    from repro.core import device as _device

    monkeypatch.setattr(_device, "backend_available", lambda: True)
    calls = []
    import repro.kernels.histogram as hk

    real = hk.byte_histogram_device
    monkeypatch.setattr(hk, "byte_histogram_device",
                        lambda *a, **k: calls.append(1) or real(*a, **k))
    small = byte_histogram(corpus["tiny"])
    assert not calls, "device histogram launched below the crossover"
    assert int(small.sum()) == len(corpus["tiny"])
    monkeypatch.setenv("REPRO_HIST_DEVICE_MIN", "4")
    big = byte_histogram(corpus["tiny"])
    assert calls, "crossover override ignored"
    assert np.array_equal(big, small)


def test_histogram_forced_device_parity(corpus):
    for payload in (corpus["text"], corpus["skewed"]):
        assert np.array_equal(
            np.asarray(byte_histogram(payload, use_device=True)),
            byte_histogram(payload, use_device=False))


def test_unpack_fixed_device_parity():
    from repro.core.packing import pack_fixed, unpack_tokens

    for ids in ([], [0], [1, 65535, 2], list(range(70000, 70100)),
                list(np.random.default_rng(5).integers(0, 2**20, 513))):
        payload = pack_fixed(np.asarray(ids, np.uint32))
        dev = unpack_fixed_device(payload)
        assert isinstance(dev, jnp.ndarray)
        assert np.array_equal(np.asarray(dev), unpack_tokens(payload))


def test_tokens_batch_to_device(corpus, monkeypatch):
    from repro.core.api import PromptCompressor
    from repro.tokenizer.bpe import train_bpe

    tok = train_bpe(["the quick brown fox jumps over the lazy dog"],
                    vocab_size=300)
    pc = PromptCompressor(tokenizer=tok, method="hybrid",
                          backend="repro-lzr")
    text = "the quick brown fox " * 300
    for method in ("hybrid", "token", "zstd"):
        blob = pc.compress(text, method=method)
        host = pc.tokens(blob)
        dev = pc.tokens(blob, to_device=True)
        assert isinstance(dev, jnp.ndarray), method
        assert np.array_equal(np.asarray(dev), host), method
