"""BPE tokenizer substrate: lossless roundtrip (the τ⁻¹(τ(T)) = T half of
the paper's §3.5 proof), specials, serialization."""

import pytest
from _hypothesis_compat import given, settings, st

from repro.tokenizer.bpe import SPECIAL_ID_BASE, BPETokenizer, train_bpe
from repro.tokenizer.vocab import default_tokenizer, load_tokenizer, save_tokenizer

TEXTS = [
    "",
    "hello world",
    "def f(x: int) -> int:\n    return x * 2\n",
    "UPPER lower 12345 !@#$%",
    "tabs\tand\nnewlines\r\n",
    "unicode: čišćenje 北京 🎉 ñandú",
    "  leading and trailing  ",
    "a" * 500,
]


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


@pytest.mark.parametrize("text", TEXTS)
def test_roundtrip_fixed(tok, text):
    assert tok.decode(tok.encode(text)) == text


@settings(max_examples=80, deadline=None)
@given(st.text(max_size=300))
def test_roundtrip_property(text):
    tok = default_tokenizer()
    assert tok.decode(tok.encode(text)) == text


def test_special_tokens_above_uint16(tok):
    ids = tok.encode("<|system|>\nhi\n<|endofprompt|>")
    specials = [i for i in ids if i >= SPECIAL_ID_BASE]
    assert len(specials) == 2
    assert all(i > 65535 for i in specials)  # forces the uint32 path (§3.3.4)
    assert tok.decode(ids) == "<|system|>\nhi\n<|endofprompt|>"


def test_train_determinism():
    docs = ["the cat sat on the mat " * 20, "def f(): return 1\n" * 30]
    t1 = train_bpe(docs, vocab_size=300)
    t2 = train_bpe(docs, vocab_size=300)
    assert t1.merges == t2.merges
    assert t1.fingerprint() == t2.fingerprint()


def test_save_load_roundtrip(tmp_path, tok):
    path = tmp_path / "tok.json"
    save_tokenizer(tok, path)
    tok2 = load_tokenizer(path)
    assert tok2.fingerprint() == tok.fingerprint()
    s = "some text with <|user|> special"
    assert tok2.encode(s) == tok.encode(s)


def test_fingerprint_detects_tampering(tmp_path, tok):
    path = tmp_path / "tok.json"
    save_tokenizer(tok, path)
    doc = path.read_text().replace('"merges": [[', '"merges": [[9, 9], [', 1)
    path.write_text(doc)
    with pytest.raises(ValueError):
        load_tokenizer(path)


def test_compression_prior(tok):
    """Tokenization maps ~3-5 chars to one id on in-domain text (§4.2.1)."""
    from repro.data.corpus import generate_corpus

    p = generate_corpus(3, seed=7)[1]
    ids = tok.encode(p.text)
    ratio = len(p.text) / len(ids)
    assert 2.0 < ratio < 8.0
