"""End-to-end system tests: LoPace-compressed corpus -> token pipeline ->
training loop -> checkpoint/restart (the paper's storage layer feeding a
real training run, deliverable b/c)."""

import dataclasses

import jax
import jax.numpy as jnp
import numpy as np
import pytest

from repro.configs.lopace import CONFIG as LOPACE_CONFIG
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_store_from_corpus
from repro.dist.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step


@pytest.fixture(scope="module")
def tiny_cfg():
    return dataclasses.replace(
        LOPACE_CONFIG.smoke(), vocab_size=8192, name="lopace-e2e")


@pytest.fixture(scope="module")
def store(tmp_path_factory):
    return build_store_from_corpus(tmp_path_factory.mktemp("store"),
                                   n_prompts=8, seed=1)


@pytest.mark.slow
def test_train_from_compressed_store(tiny_cfg, store):
    """Loss decreases training on LoPace token-stream data (no re-tokenize)."""
    pipe = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=8, seed=0))
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=40,
                          weight_decay=0.01)
    step_fn = jax.jit(make_train_step(tiny_cfg, opt_cfg, remat="none"))
    params, opt_state = init_train_state(jax.random.PRNGKey(0), tiny_cfg)
    losses = []
    for _ in range(30):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert all(np.isfinite(losses))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.5, losses


def test_grad_accum_equivalence(tiny_cfg, store):
    """accum=4 over microbatches == one full-batch step (same update).
    f32 activations: bf16 summation noise flips near-zero gradient signs,
    which AdamW amplifies to ~2*lr — this test checks accumulation MATH."""
    cfg = dataclasses.replace(tiny_cfg, activation_dtype="float32")
    pipe = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=8, seed=0))
    batch = {k: jnp.asarray(v) for k, v in pipe.batch_at(0).items()}
    opt_cfg = AdamWConfig(lr=1e-3, warmup_steps=0, weight_decay=0.0)
    params, opt_state = init_train_state(jax.random.PRNGKey(1), cfg)
    f1 = jax.jit(make_train_step(cfg, opt_cfg, remat="none", grad_accum=1))
    f4 = jax.jit(make_train_step(cfg, opt_cfg, remat="none", grad_accum=4))
    acc_batch = {k: v.reshape(4, 2, *v.shape[1:]) for k, v in batch.items()}
    p1, _, m1 = f1(params, opt_state, batch)
    p4, _, m4 = f4(params, opt_state, acc_batch)
    for a, b in zip(jax.tree_util.tree_leaves(p1), jax.tree_util.tree_leaves(p4)):
        np.testing.assert_allclose(np.asarray(a, np.float32),
                                   np.asarray(b, np.float32), rtol=2e-3, atol=2e-4)
    # metrics parity: the accum path reports the same aux-loss breakdown
    # (averaged over microbatches) as the full-batch path
    for key in ("loss", "ce", "aux", "z_loss"):
        assert key in m1 and key in m4, (key, sorted(m1), sorted(m4))
        np.testing.assert_allclose(float(m1[key]), float(m4[key]),
                                   rtol=2e-2, atol=1e-3)


@pytest.mark.slow
def test_compressed_grad_training_converges(tiny_cfg, store):
    """int8 error-feedback gradient compression still trains."""
    pipe = TokenPipeline(store, PipelineConfig(seq_len=128, global_batch=8, seed=2))
    opt_cfg = AdamWConfig(lr=1e-2, warmup_steps=5, total_steps=40, weight_decay=0.0)
    step_fn = jax.jit(make_train_step(tiny_cfg, opt_cfg, remat="none",
                                      compress_grads=True))
    params, opt_state = init_train_state(jax.random.PRNGKey(2), tiny_cfg,
                                         compress_grads=True)
    losses = []
    for _ in range(25):
        batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
        params, opt_state, metrics = step_fn(params, opt_state, batch)
        losses.append(float(metrics["loss"]))
    assert np.mean(losses[-5:]) < np.mean(losses[:5]) - 0.3, losses


@pytest.mark.slow
def test_checkpoint_restart_bitwise(tiny_cfg, store, tmp_path):
    """Fault-tolerance: kill after step k, restore, and reproduce the same
    trajectory (deterministic data order + exact state round-trip)."""
    pipe_cfg = PipelineConfig(seq_len=128, global_batch=8, seed=3)
    opt_cfg = AdamWConfig(lr=5e-3, warmup_steps=2, total_steps=20)
    step_fn = jax.jit(make_train_step(tiny_cfg, opt_cfg, remat="none"))

    def run(n_steps, params, opt_state, pipe):
        traj = []
        for _ in range(n_steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            traj.append(float(m["loss"]))
        return params, opt_state, traj

    pipe = TokenPipeline(store, pipe_cfg)
    params, opt_state = init_train_state(jax.random.PRNGKey(3), tiny_cfg)
    _, _, full_traj = run(8, params, opt_state, pipe)

    pipe = TokenPipeline(store, pipe_cfg)
    params, opt_state = init_train_state(jax.random.PRNGKey(3), tiny_cfg)
    params, opt_state, traj_a = run(4, params, opt_state, pipe)
    save_checkpoint(tmp_path, 4, {"params": params, "opt": opt_state},
                    extra={"data": pipe.state()})
    del params, opt_state, pipe

    ck = latest_checkpoint(tmp_path)
    params2, opt2 = init_train_state(jax.random.PRNGKey(99), tiny_cfg)  # junk init
    restored = restore_checkpoint(ck, {"params": params2, "opt": opt2})
    pipe2 = TokenPipeline(store, pipe_cfg)
    from repro.dist.checkpoint import checkpoint_extra

    pipe2.restore(checkpoint_extra(ck)["data"])
    _, _, traj_b = run(4, restored["params"], restored["opt"], pipe2)

    np.testing.assert_allclose(traj_a + traj_b, full_traj, rtol=1e-5)


def test_serve_from_store(tiny_cfg, store):
    """BatchServer admits stored prompts via token-stream mode and decodes."""
    from repro.train.serve_loop import BatchServer

    params, _ = init_train_state(jax.random.PRNGKey(0), tiny_cfg)
    server = BatchServer(params, tiny_cfg, batch_slots=2, max_len=96)
    keys = store.keys()[:3]
    reqs = [server.submit_text(store, k, max_new_tokens=4) for k in keys]
    server.run(max_steps=400)
    for r in reqs:
        assert r.done and len(r.out_tokens) == 4
        assert all(0 <= t < tiny_cfg.vocab_size for t in r.out_tokens)
