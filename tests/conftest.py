"""Shared pytest wiring: the runtime lock-order sanitizer is on for
every test carrying the ``concurrency``, ``crash``, or ``chaos`` marker
(the tests that actually interleave store lock paths), via
``REPRO_LOCK_SANITIZER`` — see ``repro.core.locks``.  Stores built
inside those tests get sanitized locks (chaos gateways inherit the flag
through the subprocess env); the flag is restored afterwards so
unmarked tests measure the production (unwrapped) primitives."""

import os

_SANITIZED_MARKERS = ("concurrency", "crash", "chaos")
_SAVED = object()


def pytest_runtest_setup(item):
    if any(item.get_closest_marker(m) for m in _SANITIZED_MARKERS):
        item._repro_saved_sanitizer = os.environ.get("REPRO_LOCK_SANITIZER")
        os.environ["REPRO_LOCK_SANITIZER"] = "1"


def pytest_runtest_teardown(item):
    saved = getattr(item, "_repro_saved_sanitizer", _SAVED)
    if saved is _SAVED:
        return
    if saved is None:
        os.environ.pop("REPRO_LOCK_SANITIZER", None)
    else:
        os.environ["REPRO_LOCK_SANITIZER"] = saved
