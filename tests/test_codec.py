"""Codec-pipeline layer: stage round-trips, registry, batch==sequential
byte identity, and the paper-exact golden-byte guarantees the layering
must not disturb."""

import hashlib

import numpy as np
import pytest

from repro.core import (ByteCompressorCodec, PipelineCodec, PromptCompressor,
                        TokenPackCodec, compress_hybrid, compress_token,
                        compress_zstd, get_codec, method_pipeline,
                        register_codec)
from repro.core import packing
from repro.core.zstd_backend import compress_bytes
from repro.data.corpus import generate_corpus
from repro.tokenizer.vocab import default_tokenizer

METHODS = ["zstd", "token", "hybrid"]


@pytest.fixture(scope="module")
def tok():
    return default_tokenizer()


@pytest.fixture(scope="module")
def texts():
    corpus = [p.text[:2000] for p in generate_corpus(4, seed=21)]
    return corpus + ["", "short", "<|system|>hi<|user|>there" * 2]


# -- stage round-trips -------------------------------------------------------


def test_token_pack_stage_roundtrip(tok, texts):
    stage = TokenPackCodec(tok, scheme="fixed")
    payloads = [t.encode("utf-8") for t in texts]
    assert stage.decode_batch(stage.encode_batch(payloads)) == payloads


@pytest.mark.parametrize("scheme", ["fixed", "varint", "delta-varint"])
def test_token_pack_stage_schemes(tok, scheme):
    stage = TokenPackCodec(tok, scheme=scheme)
    payload = ("scheme sweep " * 40).encode("utf-8")
    assert stage.decode_batch(stage.encode_batch([payload])) == [payload]


def test_byte_compressor_stage_roundtrip(texts):
    stage = ByteCompressorCodec(level=5, backend="zstd")
    payloads = [t.encode("utf-8") for t in texts]
    assert stage.decode_batch(stage.encode_batch(payloads)) == payloads


def test_pipeline_composition_roundtrip(tok, texts):
    pipe = PipelineCodec([TokenPackCodec(tok), ByteCompressorCodec(level=3)],
                         name="hybrid")
    payloads = [t.encode("utf-8") for t in texts]
    assert pipe.decode_batch(pipe.encode_batch(payloads)) == payloads


# -- registry ----------------------------------------------------------------


def test_registry_lookup_and_roundtrip(tok):
    stage = get_codec("token-pack", tokenizer=tok)
    payload = "registry round trip".encode("utf-8")
    assert stage.decode_batch(stage.encode_batch([payload])) == [payload]
    stage = get_codec("byte-compressor", level=1)
    assert stage.decode_batch(stage.encode_batch([payload])) == [payload]


def test_registry_rejects_unknown_and_duplicate():
    with pytest.raises(ValueError, match="unknown codec"):
        get_codec("no-such-codec")
    with pytest.raises(ValueError, match="already registered"):
        register_codec("token-pack", TokenPackCodec)


def test_method_pipeline_shapes(tok):
    assert [s.name for s in method_pipeline("zstd").stages] == ["byte-compressor"]
    assert [s.name for s in method_pipeline("token", tokenizer=tok).stages] == \
        ["token-pack"]
    assert [s.name for s in method_pipeline("hybrid", tokenizer=tok).stages] == \
        ["token-pack", "byte-compressor"]
    with pytest.raises(ValueError, match="unknown method"):
        method_pipeline("lz4")


# -- paper-exact golden bytes ------------------------------------------------


GOLDEN_TEXT = "def quantize(x, scale):\n    return round(x / scale) * scale\n" * 7
# sha256 of compress_token(GOLDEN_TEXT, default_tokenizer()) — fixed-width
# u16 packing of a deterministic vocabulary, so this digest is stable
# across environments and pins the paper-exact payload bytes.
GOLDEN_TOKEN_SHA = "8a3fa039f71e88477ec48defcdc21dec08e05e71074ee62fedebcacd9b5218bc"


def test_golden_token_payload(tok):
    payload = compress_token(GOLDEN_TEXT, tok)
    assert hashlib.sha256(payload).hexdigest() == GOLDEN_TOKEN_SHA


def test_paper_exact_functions_equal_primitive_composition(tok, texts):
    """compress_{zstd,token,hybrid} == the primitive compositions of
    Algorithms 1-2 — the codec layering must not change a byte."""
    for t in texts:
        utf8 = t.encode("utf-8")
        ids = tok.encode(t)
        assert compress_zstd(t) == compress_bytes(utf8, level=15, backend="zstd")
        assert compress_token(t, tok) == packing.pack_tokens(ids, "fixed")
        assert compress_hybrid(t, tok) == compress_bytes(
            packing.pack_tokens(ids, "fixed"), level=15, backend="zstd")


@pytest.mark.parametrize("method", METHODS)
def test_pipeline_matches_paper_exact(tok, texts, method):
    """Single-element pipeline encode == the paper-exact function."""
    pc = PromptCompressor(tok, method=method)
    fn = {"zstd": lambda t: compress_zstd(t),
          "token": lambda t: compress_token(t, tok),
          "hybrid": lambda t: compress_hybrid(t, tok)}[method]
    for t in texts:
        assert pc.compress_raw(t) == fn(t)


# -- batch == sequential -----------------------------------------------------


@pytest.mark.parametrize("method", METHODS)
def test_batch_byte_identical_to_sequential(tok, texts, method):
    pc = PromptCompressor(tok, method=method)
    batch = pc.compress_batch(texts)
    assert batch == [pc.compress(t) for t in texts]
    assert pc.decompress_batch(batch) == list(texts)


def test_tokens_batch_matches_sequential(tok, texts):
    pc = PromptCompressor(tok, method="hybrid")
    blobs = pc.compress_batch(texts)
    for seq, batched in zip([pc.tokens(b) for b in blobs],
                            pc.tokens_batch(blobs)):
        np.testing.assert_array_equal(seq, batched)


def test_tokens_batch_mixed_methods(tok):
    """A mixed-method blob batch groups by (method, backend) internally."""
    pc = PromptCompressor(tok)
    texts = ["zstd framed " * 10, "token framed " * 10, "hybrid framed " * 10]
    blobs = [pc.compress(t, m) for t, m in zip(texts, METHODS)]
    for t, ids in zip(texts, pc.tokens_batch(blobs)):
        assert list(ids) == tok.encode(t)
    assert pc.decompress_batch(blobs) == texts


# -- frame-level fixes -------------------------------------------------------


def test_negative_level_roundtrip(tok):
    from repro.core.api import parse_frame

    pc = PromptCompressor(tok, method="hybrid", level=-5)
    blob = pc.compress("negative zstd levels are valid " * 8)
    assert parse_frame(blob).level == -5
    assert pc.decompress(blob) == "negative zstd levels are valid " * 8


def test_level_out_of_signed_byte_rejected(tok):
    with pytest.raises(ValueError, match="signed level byte"):
        PromptCompressor(tok, level=128)
    with pytest.raises(ValueError, match="signed level byte"):
        PromptCompressor(tok, level=-129)


def test_tokens_requires_tokenizer_for_zstd_frames():
    pc = PromptCompressor(None, method="zstd")
    blob = pc.compress("plain text frame")
    with pytest.raises(ValueError, match="needs a tokenizer"):
        pc.tokens(blob)
