"""repro.core.failpoints: spec grammar, seeded schedules, action
semantics (crash/torn/error/latency/count), the shared alternation hit
counter, live REPRO_FAULTS env re-sync, and the REPRO008 static rule
that keeps fire() call sites honest against the SITES catalog."""

import os

import pytest

from repro.analysis.core import parse_source, run_rules
from repro.core import failpoints
from repro.core.durability import write_durable
from repro.core.failpoints import (FailpointCrash, FailpointError, FaultRule,
                                   TornWrite, parse_spec)

REPO = os.path.dirname(os.path.dirname(os.path.abspath(__file__)))


@pytest.fixture(autouse=True)
def _clean_registry():
    failpoints.disarm_all()
    yield
    failpoints.disarm_all()


# ---------------------------------------------------------------------------
# grammar
# ---------------------------------------------------------------------------


def test_parse_spec_clauses():
    rules = parse_spec(
        "durability.fsync_file=nth:3,crash; gateway.send=p:0.05,error;"
        "codec.*=always,latency:0.01;;")
    assert [r.pattern for r in rules] == [
        "durability.fsync_file", "gateway.send", "codec.*"]
    assert rules[0].schedule == ("nth", 3)
    assert rules[1].schedule == ("p", 0.05)
    assert rules[2].action == ("latency", 0.01)


@pytest.mark.parametrize("bad", [
    "durability.fsync_file",                 # no schedule/action
    "durability.fsync_file=nth:3",           # no action
    "durability.fsync_file=nth:0,crash",     # nth is 1-based
    "durability.fsync_file=p:1.5,crash",     # p out of range
    "durability.fsync_file=every:2,crash",   # unknown schedule
    "durability.fsync_file=nth:1,explode",   # unknown action
    "durability.fsync_file=nth:1,crash:9",   # crash takes no arg
    "durability.fsync_file=nth:1,torn:1.0",  # torn frac must be < 1
    "no.such.site=nth:1,crash",              # unregistered literal
    "nosuch.*=nth:1,crash",                  # glob matching no site
    "durability.fsync_file|=nth:1,crash",    # empty alternation part
])
def test_parse_spec_rejects(bad):
    with pytest.raises(ValueError):
        parse_spec(bad)


def test_fire_rejects_unregistered_site_even_unarmed():
    with pytest.raises(RuntimeError, match="unregistered failpoint site"):
        failpoints.fire("no.such.site")


# ---------------------------------------------------------------------------
# schedules + actions
# ---------------------------------------------------------------------------


def test_nth_fires_exactly_once():
    with failpoints.injected("codec.decompress=nth:3,error") as rules:
        for i in range(1, 6):
            if i == 3:
                with pytest.raises(FailpointError):
                    failpoints.fire("codec.decompress")
            else:
                failpoints.fire("codec.decompress")
        assert rules[0].hits == 5
        assert rules[0].fired == 1


def test_probability_is_seed_deterministic():
    def pattern(seed):
        fired = []
        rule = FaultRule("codec.tokens", "p:0.5", "count", seed=seed)
        for _ in range(64):
            rule.hits += 1
            fired.append(rule._should_fire())
        return fired

    assert pattern(7) == pattern(7)            # replayable
    assert pattern(7) != pattern(8)            # seed actually matters
    # distinct rule indices from one seed get distinct streams
    a = FaultRule("codec.tokens", "p:0.5", "count", seed=7, index=0)
    b = FaultRule("codec.tokens", "p:0.5", "count", seed=7, index=1)
    draws = [(a._should_fire(), b._should_fire()) for _ in range(64)]
    assert any(x != y for x, y in draws)


def test_alternation_shares_one_hit_counter():
    # 4 hits interleaved across two sites; nth:3 lands on the second
    # decompress hit because the counter is shared — the property the
    # crash suite's combined fsync+replace enumeration depends on
    with failpoints.injected(
            "codec.decompress|codec.tokens=nth:3,error") as rules:
        failpoints.fire("codec.decompress")   # hit 1
        failpoints.fire("codec.tokens")       # hit 2
        with pytest.raises(FailpointError):
            failpoints.fire("codec.decompress")  # hit 3 -> fires
        failpoints.fire("codec.tokens")       # hit 4
        assert rules[0].hits == 4 and rules[0].fired == 1


def test_error_action_is_oserror():
    with failpoints.injected("gateway.send=always,error"):
        with pytest.raises(OSError):
            failpoints.fire("gateway.send")
        with pytest.raises(ConnectionError):
            failpoints.fire("gateway.send")


def test_crash_action_is_baseexception_not_exception():
    with failpoints.injected("store.replace=always,crash"):
        try:
            failpoints.fire("store.replace")
        except Exception:  # noqa: BLE001 - asserting it is NOT caught here
            pytest.fail("FailpointCrash must not be catchable as Exception")
        except BaseException as e:
            assert isinstance(e, FailpointCrash)


def test_torn_write_persists_prefix(tmp_path):
    """The cooperating write_durable site leaves keep(n) bytes of the
    payload on disk before re-raising — a real torn file."""
    payload = bytes(range(10)) * 10          # 100 bytes
    target = tmp_path / "artifact.bin"
    with failpoints.injected("durability.write_durable=nth:1,torn:0.3"):
        with pytest.raises(TornWrite) as ei:
            write_durable(target, payload)
    keep = ei.value.keep(len(payload))
    assert keep == 30
    assert target.read_bytes() == payload[:keep]
    # exhausted nth rule: the retry goes through whole
    with failpoints.injected("durability.write_durable=nth:1,torn:0.3"):
        pass
    write_durable(target, payload)
    assert target.read_bytes() == payload


def test_torn_keep_never_whole():
    t = TornWrite("durability.write_durable", 1, frac=0.99)
    assert t.keep(1) == 0
    assert t.keep(100) == 99                 # capped at n-1
    assert TornWrite("durability.write_durable", 1, frac=0.0).keep(100) == 0


def test_count_action_never_faults():
    with failpoints.injected("codec.*=always,count") as rules:
        for _ in range(5):
            failpoints.fire("codec.decompress")
        failpoints.fire("codec.tokens")
        assert rules[0].hits == 6 and rules[0].fired == 6


def test_injected_disarms_on_exception_and_stats_report():
    with pytest.raises(FailpointCrash):
        with failpoints.injected("lease.acquire=always,crash"):
            assert failpoints.stats()["n_rules"] == 1
            failpoints.fire("lease.acquire")
    assert failpoints.stats()["n_rules"] == 0
    failpoints.fire("lease.acquire")         # disarmed: clean


# ---------------------------------------------------------------------------
# env-driven arming (REPRO_FAULTS)
# ---------------------------------------------------------------------------


def test_env_spec_arms_and_resyncs(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "codec.decompress=nth:1,error")
    with pytest.raises(FailpointError):
        failpoints.fire("codec.decompress")
    # changed spec re-arms (fresh counters), removal disarms — no restart
    monkeypatch.setenv("REPRO_FAULTS", "codec.tokens=nth:1,error")
    failpoints.fire("codec.decompress")
    with pytest.raises(FailpointError):
        failpoints.fire("codec.tokens")
    monkeypatch.delenv("REPRO_FAULTS")
    failpoints.fire("codec.tokens")
    assert failpoints.active() == []


def test_env_seed_feeds_probability_rules(monkeypatch):
    def fired_hits(seed):
        monkeypatch.setenv("REPRO_FAULTS", "codec.tokens=p:0.5,count")
        monkeypatch.setenv("REPRO_FAULTS_SEED", str(seed))
        # force a re-parse: the raw spec string is the change detector
        failpoints._sync_env()
        failpoints._env_raw = None
        failpoints._sync_env()
        for _ in range(32):
            failpoints.fire("codec.tokens")
        rule = failpoints.active()[0]
        return rule.fired

    a, b = fired_hits(3), fired_hits(3)
    assert a == b


def test_env_malformed_spec_is_loud(monkeypatch):
    monkeypatch.setenv("REPRO_FAULTS", "not-a-clause")
    with pytest.raises(ValueError, match="bad failpoint clause"):
        failpoints.fire("codec.decompress")
    monkeypatch.delenv("REPRO_FAULTS")
    failpoints.fire("codec.decompress")


# ---------------------------------------------------------------------------
# REPRO008: static fire()-site hygiene
# ---------------------------------------------------------------------------


def _real_failpoints_file():
    path = os.path.join(REPO, "src", "repro", "core", "failpoints.py")
    with open(path, encoding="utf-8") as fh:
        return parse_source("src/repro/core/failpoints.py", fh.read())


def _rule8(extra_sources):
    files = [_real_failpoints_file()]
    files += [parse_source(p, s) for p, s in sorted(extra_sources.items())]
    return [f for f in run_rules(files, ["REPRO008"])
            if f.rule == "REPRO008"]


def _fires_all_sites():
    """Source that fires every declared site (keeps never-fired quiet)."""
    lines = ["from repro.core import failpoints"]
    lines += [f"failpoints.fire({s!r})" for s in failpoints.SITES]
    return "\n".join(lines) + "\n"


def test_repro008_clean_on_real_tree():
    assert _rule8({"src/ok.py": _fires_all_sites()}) == []


def test_repro008_flags_unknown_site():
    src = _fires_all_sites() + "failpoints.fire('no.such.site')\n"
    found = _rule8({"src/bad.py": src})
    assert len(found) == 1
    assert "unknown failpoint site" in found[0].message


def test_repro008_flags_non_literal_site():
    src = _fires_all_sites() + "name = 'x'\nfailpoints.fire(name)\n"
    found = _rule8({"src/bad.py": src})
    assert len(found) == 1 and "non-literal" in found[0].message


def test_repro008_flags_never_fired_sites():
    src = ("from repro.core.failpoints import fire\n"
           "fire('durability.publish')\n")
    found = _rule8({"src/partial.py": src})
    missing = {f.message.split("'")[1] for f in found}
    assert missing == set(failpoints.SITES) - {"durability.publish"}
    assert all("never" in f.message for f in found)
    assert all(f.path == "src/repro/core/failpoints.py" for f in found)
