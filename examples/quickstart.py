#!/usr/bin/env python
"""Quickstart: LoPace's three compression methods on a real prompt.

    PYTHONPATH=src python examples/quickstart.py
"""

from repro.core import AdaptiveCompressor, PromptCompressor
from repro.core.entropy import bits_per_char, shannon_entropy, theoretical_cr
from repro.data.corpus import generate_corpus
from repro.tokenizer.vocab import default_tokenizer


def main() -> None:
    tok = default_tokenizer()
    prompt = generate_corpus(8, seed=42)[3].text
    raw = len(prompt.encode("utf-8"))
    print(f"prompt: {len(prompt)} chars / {raw} bytes "
          f"(H={shannon_entropy(prompt):.2f} bits/char, "
          f"order-0 bound {theoretical_cr(prompt):.2f}x)\n")

    print(f"{'method':8s} {'bytes':>9s} {'CR':>7s} {'savings':>8s} {'BPC':>6s} lossless")
    for method in ("zstd", "token", "hybrid"):
        pc = PromptCompressor(tok, method=method, level=15)
        blob = pc.compress(prompt)
        ok = pc.decompress(blob) == prompt
        print(f"{method:8s} {len(blob):9d} {raw/len(blob):6.2f}x "
              f"{100*(1-len(blob)/raw):7.1f}% {bits_per_char(prompt, len(blob)):6.2f} {ok}")

    ac = AdaptiveCompressor(tok)
    choice = ac.choose(prompt)
    print(f"\nadaptive selection -> {choice.method} ({choice.reason})")


if __name__ == "__main__":
    main()
