#!/usr/bin/env python
"""Serving scenario: batched greedy decoding where request prompts are
admitted straight from the LoPace PromptStore in token-stream mode
(paper §6.2.3 + §8.4.2 #10).

    PYTHONPATH=src python examples/serve_prompts.py
"""

import tempfile
import time

import jax

from repro.configs.lopace import CONFIG
from repro.data.pipeline import build_store_from_corpus
from repro.train.serve_loop import BatchServer
from repro.train.train_loop import init_train_state


def main() -> None:
    cfg = CONFIG.smoke()
    params, _ = init_train_state(jax.random.PRNGKey(0), cfg)
    with tempfile.TemporaryDirectory() as tmp:
        store = build_store_from_corpus(tmp, n_prompts=8, seed=4)
        server = BatchServer(params, cfg, batch_slots=4, max_len=128)
        keys = store.keys()[:6]
        t0 = time.perf_counter()
        reqs = [server.submit_text(store, k, max_new_tokens=16) for k in keys]
        server.run()
        dt = time.perf_counter() - t0
        done = sum(r.done for r in reqs)
        toks = sum(len(r.out_tokens) for r in reqs)
        print(f"served {done}/{len(reqs)} requests, {toks} tokens "
              f"in {dt:.1f}s ({toks/dt:.1f} tok/s, greedy, CPU)")
        for r in reqs[:3]:
            print(f"  req {r.rid}: prompt[{r.prompt_tokens.size} toks] -> "
                  f"{r.out_tokens}")


if __name__ == "__main__":
    main()
