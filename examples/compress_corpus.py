#!/usr/bin/env python
"""End-to-end storage scenario (paper §6.2.3): compress a corpus into a
PromptStore, verify integrity, report the §5 metrics, and read prompts
back in token-stream mode.

    PYTHONPATH=src python examples/compress_corpus.py [n_prompts]
"""

import sys
import tempfile
import time

from repro.core import PromptCompressor, PromptStore
from repro.data.corpus import corpus_stats, generate_corpus
from repro.tokenizer.vocab import default_tokenizer


def main() -> None:
    n = int(sys.argv[1]) if len(sys.argv) > 1 else 64
    prompts = generate_corpus(n, seed=0)
    print("corpus:", corpus_stats(prompts))

    with tempfile.TemporaryDirectory() as root:
        store = PromptStore(root, PromptCompressor(default_tokenizer(),
                                                   method="hybrid", level=15))
        t0 = time.perf_counter()
        keys = store.put_many([p.text for p in prompts])
        dt = time.perf_counter() - t0
        st = store.stats()
        mb = st["original_chars"] / 1e6
        print(f"stored {st['n_prompts']} prompts: {mb:.1f}MB -> "
              f"{st['stored_bytes']/1e6:.1f}MB "
              f"({st['space_savings_pct']:.1f}% savings) at {mb/dt:.1f}MB/s")
        print("integrity sweep:", store.verify_all())
        toks = store.get_tokens(keys[0])
        print(f"token-stream mode: prompt 0 -> {toks.size} token ids "
              f"(no detokenization round-trip)")


if __name__ == "__main__":
    main()
