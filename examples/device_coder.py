#!/usr/bin/env python
"""The TPU-native stage: batch-compress token streams with the JAX
interleaved rANS coder and validate losslessness (DESIGN.md §4).

    PYTHONPATH=src python examples/device_coder.py
"""

import time

import numpy as np

from repro.core.rans import tokens_compress_device, tokens_decompress_device
from repro.data.corpus import generate_corpus
from repro.tokenizer.vocab import default_tokenizer


def main() -> None:
    tok = default_tokenizer()
    prompts = generate_corpus(8, seed=2)
    streams = [np.asarray(tok.encode(p.text)) for p in prompts]
    raw = sum(len(p.text.encode()) for p in prompts)
    t0 = time.perf_counter()
    blobs = [tokens_compress_device(s) for s in streams]
    dt = time.perf_counter() - t0
    for s, b in zip(streams, blobs):
        assert np.array_equal(tokens_decompress_device(b).astype(np.int64), s)
    comp = sum(len(b) for b in blobs)
    print(f"device rANS coder: {raw/1e6:.2f}MB text -> {comp/1e6:.2f}MB "
          f"(CR {raw/comp:.2f}x) in {dt:.1f}s [CPU-backend proxy; "
          f"lanes vectorize on the TPU VPU]")
    print("losslessness: verified on all streams")


if __name__ == "__main__":
    main()
