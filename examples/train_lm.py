#!/usr/bin/env python
"""End-to-end driver (deliverable b): train the ~100M-param LoPace LM on a
LoPace-compressed corpus for a few hundred steps with checkpointing.

    PYTHONPATH=src python examples/train_lm.py --steps 300 [--smoke]
"""

import argparse
import tempfile
import time

import jax
import jax.numpy as jnp

from repro.configs.lopace import CONFIG
from repro.data.pipeline import PipelineConfig, TokenPipeline, build_store_from_corpus
from repro.dist.checkpoint import latest_checkpoint, restore_checkpoint, save_checkpoint
from repro.train.optimizer import AdamWConfig
from repro.train.train_loop import init_train_state, make_train_step


def main() -> None:
    ap = argparse.ArgumentParser()
    ap.add_argument("--steps", type=int, default=300)
    ap.add_argument("--smoke", action="store_true",
                    help="reduced model (CI-speed)")
    ap.add_argument("--seq-len", type=int, default=512)
    ap.add_argument("--batch", type=int, default=8)
    ap.add_argument("--ckpt-every", type=int, default=100)
    ap.add_argument("--ckpt-dir", default=None)
    args = ap.parse_args()

    cfg = CONFIG.smoke() if args.smoke else CONFIG
    print(f"model: {cfg.name} ({cfg.n_layers}L d={cfg.d_model})")

    with tempfile.TemporaryDirectory() as tmp:
        ckpt_dir = args.ckpt_dir or tmp + "/ckpt"
        store = build_store_from_corpus(tmp + "/store", n_prompts=96, seed=0)
        print("corpus store:", store.stats())
        pipe = TokenPipeline(store, PipelineConfig(
            seq_len=args.seq_len, global_batch=args.batch, seed=0))

        opt_cfg = AdamWConfig(lr=3e-4, warmup_steps=50, total_steps=args.steps)
        step_fn = jax.jit(make_train_step(cfg, opt_cfg, remat="none"),
                          donate_argnums=(0, 1))
        params, opt_state = init_train_state(jax.random.PRNGKey(0), cfg)

        start = 0
        ck = latest_checkpoint(ckpt_dir)
        if ck is not None:
            state = restore_checkpoint(ck, {"params": params, "opt": opt_state})
            params, opt_state = state["params"], state["opt"]
            from repro.dist.checkpoint import checkpoint_extra, checkpoint_step
            pipe.restore(checkpoint_extra(ck)["data"])
            start = checkpoint_step(ck)
            print(f"resumed from step {start}")

        t0 = time.perf_counter()
        for step in range(start, args.steps):
            batch = {k: jnp.asarray(v) for k, v in next(pipe).items()}
            params, opt_state, m = step_fn(params, opt_state, batch)
            if (step + 1) % 20 == 0:
                dt = time.perf_counter() - t0
                tok_s = 20 * args.batch * args.seq_len / dt
                print(f"step {step+1:4d} loss={float(m['loss']):.3f} "
                      f"lr={float(m['lr']):.2e} gnorm={float(m['grad_norm']):.2f} "
                      f"({tok_s/1e3:.1f}k tok/s)")
                t0 = time.perf_counter()
            if (step + 1) % args.ckpt_every == 0:
                save_checkpoint(ckpt_dir, step + 1,
                                {"params": params, "opt": opt_state},
                                extra={"data": pipe.state()})
                print(f"checkpointed @ {step+1}")


if __name__ == "__main__":
    main()
