#!/usr/bin/env python
"""Chaos harness: a seeded fault schedule against a live gateway fleet.

Spawns a writer + a lease-waiting standby + a read replica (real
subprocesses via ``repro.launch.gateway``), drives them with concurrent
retrying clients while faults are armed at every layer, and asserts the
fault-tolerance contract end to end:

* **zero acked-write loss** — every put acknowledged ``durable: true``
  survives an injected fsync error, injected socket faults, and a
  SIGKILL of the writer mid-workload, and reads back byte-identical
  from the standby that takes over the lease — and from the replica
  after a refresh;
* **degraded reads** — an injected on-disk corruption is quarantined by
  the standby's background scrubber; the corrupt key refuses with
  ``shard_quarantined`` (terminal, non-retryable) while every healthy
  key — including healthy keys in the quarantined shard — keeps
  serving.  Corruption never escalates into a store-wide failure;
* **observability** — the fault/retry/quarantine counters
  (``faults.fired``, ``gateway.client.retries``, ``scrub.quarantines``)
  are visible in the obs snapshots on both sides of the wire.

Fault placement per process (all four site families are exercised):

    writer   REPRO_FAULTS  fsync error (nth) + fsync latency (p) +
                           store.replace latency — any in-memory
                           weirdness dies with the SIGKILL; durability
                           is what the standby verifies
    standby  REPRO_FAULTS  fsync latency only (it must survive to
                           verify), deterministic nth + seeded p
    replica  REPRO_FAULTS  codec decompress/tokens errors (nth) —
                           absorbed by app-level retry
    clients  arm_spec      gateway.send/recv errors (nth + seeded p) —
                           absorbed by GatewayClient's retry loop

Every random choice — nth schedules, probabilities, which record gets
corrupted — derives from ``--seed``, and the same seed flows into
``REPRO_FAULTS_SEED`` (server ``p:`` schedules, client retry jitter),
so a failing run replays exactly.

    PYTHONPATH=src python scripts/chaos.py --seed 3          # full run
    PYTHONPATH=src python scripts/chaos.py --smoke --seed 0  # ~30s gate
    make chaos                                               # seeds 0-4

Needs only the stdlib + the repo (jax-free, like the gateway launcher).
"""

from __future__ import annotations

import argparse
import json
import os
import random
import signal
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path
from typing import Dict, List, Optional, Tuple

SRC = str(Path(__file__).resolve().parent.parent / "src")
sys.path.insert(0, SRC)

from repro import obs  # noqa: E402
from repro.core import failpoints  # noqa: E402
from repro.core.api import PromptCompressor  # noqa: E402
from repro.core.store import ShardedPromptStore  # noqa: E402
from repro.service.gateway import (GatewayClient, GatewayError)  # noqa: E402
from repro.tokenizer.vocab import default_tokenizer  # noqa: E402

#: protocol verdicts the harness treats as bugs, not injected noise
_TERMINAL_CODES = frozenset({
    "shard_quarantined", "not_found", "bad_request", "unknown_op",
    "read_only", "frame_too_large", "bad_frame", "unknown_ticket",
    "not_a_replica"})


class Config:
    def __init__(self, seed: int, smoke: bool, clients: int) -> None:
        self.seed = seed
        self.smoke = smoke
        self.clients = clients or (2 if smoke else 4)
        self.batches_a = 3 if smoke else 5
        self.batches_b = 2 if smoke else 4
        self.texts = 3 if smoke else 4
        self.op_deadline_s = 60.0


def _text(seed: int, phase: str, ci: int, bi: int, r: int) -> str:
    return (f"chaos s{seed} {phase} c{ci} b{bi} r{r}: flush the journal, "
            f"fence the epoch, re-elect the shard leader. " * 3)


def _fault_specs(seed: int) -> Dict[str, str]:
    rng = random.Random(0xC4A05 ^ seed)
    return {
        # one deterministic fsync error (past startup's ~4 fsyncs, well
        # inside phase A's >= 12) + seeded latency jitter everywhere
        "writer": (
            f"durability.fsync_file=nth:{rng.randint(6, 10)},error;"
            f"durability.fsync_file|durability.fsync_dir=p:0.03,"
            f"latency:0.002;"
            f"store.replace=nth:{rng.randint(1, 3)},latency:0.02"),
        # the standby must survive to verify: latency only
        "standby": (
            f"durability.fsync_file=nth:2,latency:0.005;"
            f"durability.fsync_file|durability.fsync_dir=p:0.03,"
            f"latency:0.002"),
        "replica": (
            f"codec.decompress=nth:{rng.randint(2, 6)},error;"
            f"codec.tokens=nth:1,error"),
        "clients": (
            f"gateway.recv=nth:{rng.randint(2, 5)},error;"
            f"gateway.send|gateway.recv=p:0.04,error"),
    }


# ---------------------------------------------------------------------------
# fleet processes
# ---------------------------------------------------------------------------


class Proc:
    def __init__(self, name: str, cmd: List[str], env: dict,
                 log: Path) -> None:
        self.name = name
        self.log = log
        self._logf = open(log, "w")
        self.popen = subprocess.Popen(cmd, env=env, stdout=self._logf,
                                      stderr=subprocess.STDOUT, text=True)

    def tail(self, n: int = 25) -> str:
        self._logf.flush()
        lines = self.log.read_text(errors="replace").splitlines()
        return "\n".join(f"  [{self.name}] {ln}" for ln in lines[-n:])

    def close(self) -> None:
        if self.popen.poll() is None:
            self.popen.kill()
            self.popen.wait(10)
        self._logf.close()


def _spawn(name: str, role: str, store: Path, port_file: Path, spec: str,
           seed: int, tmp: Path, *, scrub_s: float = 0.0,
           stats_json: Optional[Path] = None) -> Proc:
    cmd = [sys.executable, "-m", "repro.launch.gateway",
           "--store-dir", str(store), "--role", role,
           "--port", "0", "--port-file", str(port_file),
           "--shards", "3", "--flush-batch", "8"]
    if scrub_s:
        cmd += ["--scrub-interval", str(scrub_s)]
    if stats_json is not None:
        cmd += ["--stats-json", str(stats_json)]
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    env["REPRO_FAULTS"] = spec
    env["REPRO_FAULTS_SEED"] = str(seed)
    return Proc(name, cmd, env, tmp / f"{name}.log")


def _wait_port(port_file: Path, proc: Proc, timeout_s: float = 30.0) -> dict:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        if port_file.exists():
            try:
                return json.loads(port_file.read_text())
            except ValueError:  # mid-publish
                pass
        if proc.popen.poll() is not None:
            raise RuntimeError(
                f"{proc.name} died at startup "
                f"(exit {proc.popen.returncode})\n{proc.tail()}")
        time.sleep(0.05)
    raise RuntimeError(f"{proc.name} not serving within {timeout_s}s")


# ---------------------------------------------------------------------------
# failover client
# ---------------------------------------------------------------------------


class FleetClient:
    """A GatewayClient that fails over across an ordered list of port
    files: when the dialed gateway dies (connection loss the client's
    own retry budget cannot heal) it re-dials whichever endpoint serves
    first — the SIGKILL takeover path.  Injected server-side faults
    (``FailpointError`` responses) get a bounded application-level
    retry; genuine protocol verdicts propagate."""

    def __init__(self, port_files: List[Path], seed: int,
                 deadline_s: float = 60.0) -> None:
        self._port_files = list(port_files)
        self._seed = seed
        self._deadline_s = deadline_s
        self._client: Optional[GatewayClient] = None
        self.injected_errors = 0
        self.redials = 0

    def _dial(self) -> GatewayClient:
        t0 = time.monotonic()
        while time.monotonic() - t0 < self._deadline_s:
            for pf in self._port_files:
                try:
                    info = json.loads(pf.read_text())
                except (OSError, ValueError):
                    continue  # not published yet (standby pre-takeover)
                try:
                    client = GatewayClient(info["host"], info["port"],
                                           timeout=10.0,
                                           retry_seed=self._seed)
                except OSError:
                    continue  # that gateway is dead; try the next
                self.redials += 1
                return client
            time.sleep(0.1)
        raise TimeoutError(
            f"no gateway endpoint dialable within {self._deadline_s}s "
            f"(tried {[str(p) for p in self._port_files]})")

    def op(self, name: str, *args, **kw):
        last: Optional[BaseException] = None
        t0 = time.monotonic()
        attempt = 0
        while time.monotonic() - t0 < self._deadline_s:
            if self._client is None:
                self._client = self._dial()
            try:
                return getattr(self._client, name)(*args, **kw)
            except GatewayError as e:
                if e.code in _TERMINAL_CODES:
                    raise
                # an injected server-side fault surfaced as an error
                # response (e.g. FailpointError at a writer fsync): the
                # op was not acked, so a re-issue is safe and idempotent
                self.injected_errors += 1
                last = e
            except (ConnectionError, OSError) as e:
                last = e
                self.close()
            attempt += 1
            time.sleep(min(0.5, 0.05 * attempt))
        raise TimeoutError(f"op {name!r} did not succeed within "
                           f"{self._deadline_s}s") from last

    def close(self) -> None:
        if self._client is not None:
            try:
                self._client.close()
            except OSError:
                pass
            self._client = None


# ---------------------------------------------------------------------------
# workload
# ---------------------------------------------------------------------------


def _worker(cfg: Config, phase: str, ci: int, n_batches: int,
            port_files: List[Path], acked: Dict[str, str],
            lock: threading.Lock, first_ack: threading.Event,
            errors: List[BaseException], injected: List[int]) -> None:
    fleet = FleetClient(port_files, cfg.seed + ci,
                        deadline_s=cfg.op_deadline_s)
    try:
        for bi in range(n_batches):
            texts = [_text(cfg.seed, phase, ci, bi, r)
                     for r in range(cfg.texts)]
            keys = fleet.op("put", texts)
            with lock:
                acked.update(zip(keys, texts))
            first_ack.set()
            got = fleet.op("get_many", keys)
            if got != texts:
                raise AssertionError(
                    f"lossless violation: {phase} c{ci} b{bi} read back "
                    f"different bytes than it acked")
    except BaseException as e:  # noqa: BLE001 - reported by the driver
        errors.append(e)
    finally:
        with lock:
            injected[0] += fleet.injected_errors
        fleet.close()


def _run_phase(cfg: Config, phase: str, n_batches: int,
               port_files: List[Path], acked: Dict[str, str],
               injected: List[int]) -> threading.Event:
    lock = threading.Lock()
    first_ack = threading.Event()
    errors: List[BaseException] = []
    threads = [threading.Thread(
        target=_worker, name=f"{phase}-c{ci}",
        args=(cfg, phase, ci, n_batches, port_files, acked, lock,
              first_ack, errors, injected))
        for ci in range(cfg.clients)]
    for t in threads:
        t.start()
    if phase == "pB":
        return first_ack, threads, errors  # caller kills the writer
    for t in threads:
        t.join(120)
    if errors:
        raise RuntimeError(f"phase {phase} worker errors: {errors!r}")
    return first_ack, [], errors


def _verify_acked(fleet: FleetClient, acked: Dict[str, str],
                  chunk: int = 64) -> None:
    keys = sorted(acked)
    for i in range(0, len(keys), chunk):
        ks = keys[i:i + chunk]
        texts = fleet.op("get_many", ks)
        for k, t in zip(ks, texts):
            if t != acked[k]:
                raise AssertionError(
                    f"acked-write loss: key {k[:12]}... read back "
                    f"{len(t)} chars != the {len(acked[k])} acked")


# ---------------------------------------------------------------------------
# corruption
# ---------------------------------------------------------------------------


def _corrupt_record(store_dir: Path, key: str) -> Tuple[int, List[str]]:
    """Flip bytes mid-record in `key`'s on-disk frame (readonly open: the
    standby holds the lease).  Returns (shard id, every key routed to
    that shard) so degraded-read assertions can target shard-mates."""
    store = ShardedPromptStore(
        store_dir, PromptCompressor(default_tokenizer(), method="zstd"),
        readonly=True)
    try:
        lay = store._layout
        sid = store._shard_of(key, lay.n_shards)
        rec = store._index[key]
        data, _ = store._shard_paths(sid, lay.gens[sid], lay.n_shards)
        with open(data, "r+b") as f:
            f.seek(rec["offset"] + rec["length"] // 2)
            n = max(4, rec["length"] // 4)
            f.write(bytes(b ^ 0xFF for b in f.read(n)) or b"\xff")
        mates = [k for k in store._index
                 if store._shard_of(k, lay.n_shards) == sid]
        return sid, mates
    finally:
        store.close()


def _wait_quarantine(fleet: FleetClient, timeout_s: float = 45.0) -> dict:
    t0 = time.monotonic()
    while time.monotonic() - t0 < timeout_s:
        st = fleet.op("stats")
        if st["service"]["store"]["quarantined_shards"]:
            return st
        time.sleep(0.3)
    raise TimeoutError(
        f"scrubber never quarantined the corrupted shard in {timeout_s}s")


def _counter_sum(snap: dict, name: str, contains: str = "") -> float:
    return sum(v for k, v in snap.get("counters", {}).items()
               if (k == name or k.startswith(name + "{"))
               and contains in k)


# ---------------------------------------------------------------------------
# driver
# ---------------------------------------------------------------------------


def run(cfg: Config) -> int:
    rng = random.Random(cfg.seed)
    specs = _fault_specs(cfg.seed)
    tmp = Path(tempfile.mkdtemp(prefix=f"chaos-s{cfg.seed}-"))
    store_dir = tmp / "store"
    pf = {r: tmp / f"{r}.port.json" for r in ("writer", "standby",
                                              "replica")}
    stats_json = tmp / "standby-stats.json"
    procs: List[Proc] = []
    try:
        writer = _spawn("writer", "writer", store_dir, pf["writer"],
                        specs["writer"], cfg.seed, tmp)
        procs.append(writer)
        _wait_port(pf["writer"], writer)
        # only after the writer owns the lease: a standby racing an
        # un-created store would win the flock and become the writer
        standby = _spawn("standby", "standby", store_dir, pf["standby"],
                         specs["standby"], cfg.seed, tmp, scrub_s=0.5,
                         stats_json=stats_json)
        procs.append(standby)
        replica = _spawn("replica", "replica", store_dir, pf["replica"],
                         specs["replica"], cfg.seed, tmp)
        procs.append(replica)
        _wait_port(pf["replica"], replica)

        failpoints.arm_spec(specs["clients"], seed=cfg.seed)
        acked: Dict[str, str] = {}
        injected = [0]
        endpoints = [pf["writer"], pf["standby"]]

        # phase A: workload against the (fault-armed) writer
        _run_phase(cfg, "pA", cfg.batches_a, endpoints, acked, injected)

        # the writer's nth fsync error is guaranteed to have fired inside
        # phase A's puts; its own snapshot proves it (a client may or may
        # not see the error response — its read can be severed by a
        # client-side injected socket fault, and the retried put succeeds)
        fleet = FleetClient([pf["writer"], pf["standby"]], cfg.seed,
                            deadline_s=cfg.op_deadline_s)
        wsnap = fleet.op("stats", snapshot=True)["obs"]
        if _counter_sum(wsnap, "faults.fired", contains="action=error") < 1:
            raise AssertionError(
                "the writer's injected fsync error never fired during "
                "phase A — the fault schedule did not run")

        # phase B: SIGKILL the writer mid-workload; the standby's lease
        # wait breaks the instant the flock drops and clients fail over
        first_ack, threads, errors = _run_phase(
            cfg, "pB", cfg.batches_b, endpoints, acked, injected)
        if not first_ack.wait(60):
            raise TimeoutError("phase B never acked a first write")
        time.sleep(0.1)
        writer.popen.send_signal(signal.SIGKILL)
        for t in threads:
            t.join(120)
        if errors:
            raise RuntimeError(f"phase B worker errors: {errors!r}")
        writer.popen.wait(10)

        # phase C: the fleet must keep ACCEPTING writes after the
        # takeover, not just serving old ones — and it guarantees the
        # standby's own deterministic fsync faults fire (phase B can
        # complete against the writer if the SIGKILL lands late)
        _run_phase(cfg, "pC", 1, endpoints, acked, injected)

        # zero acked-write loss through the takeover (the fleet client
        # redials: the writer endpoint refuses, the standby serves)
        _verify_acked(fleet, acked)

        # the replica converges after a refresh — byte-identical too,
        # through its injected codec faults
        rfleet = FleetClient([pf["replica"]], cfg.seed,
                             deadline_s=cfg.op_deadline_s)
        rfleet.op("refresh")
        _verify_acked(rfleet, acked)
        sample = rng.choice(sorted(acked))
        if len(rfleet.op("get_tokens", sample)) == 0:
            raise AssertionError("replica served an empty token array")
        wgen = fleet.op("stats")["gateway"]["store_generation"]
        rgen = rfleet.op("stats")["gateway"]["store_generation"]
        if not (wgen >= 1 and rgen == wgen):
            raise AssertionError(
                f"replica staleness after refresh: gen {rgen} != {wgen}")
        rfleet.close()

        # corruption -> scrub -> quarantine -> degraded reads
        bad_key = rng.choice(sorted(acked))
        sid, mates = _corrupt_record(store_dir, bad_key)
        st = _wait_quarantine(fleet)
        if st["service"]["store"]["quarantined_shards"] != [sid]:
            raise AssertionError(
                f"expected exactly shard {sid} quarantined, got "
                f"{st['service']['store']['quarantined_shards']}")
        try:
            fleet.op("get", bad_key)
            raise AssertionError(
                "corrupt key served instead of refusing with "
                "shard_quarantined")
        except GatewayError as e:
            if e.code != "shard_quarantined" or e.retryable:
                raise AssertionError(
                    f"corrupt key refused with {e.code!r} "
                    f"retryable={e.retryable}; wanted terminal "
                    f"shard_quarantined") from e
        healthy = {k: v for k, v in acked.items() if k != bad_key}
        healthy_mates = [k for k in healthy if k in mates]
        if not healthy_mates:
            raise AssertionError(
                f"no healthy shard-mates for {bad_key[:12]}... — cannot "
                f"prove per-key (not per-shard) degradation")
        _verify_acked(fleet, healthy)  # shard-mates included

        # counters on both sides of the wire
        snap = fleet.op("stats", snapshot=True)["obs"]
        local = obs.snapshot()
        checks = {
            "standby scrub.quarantines": _counter_sum(
                snap, "scrub.quarantines"),
            "standby scrub.corrupt_records": _counter_sum(
                snap, "scrub.corrupt_records"),
            "standby faults.fired": _counter_sum(snap, "faults.fired"),
            "client gateway.client.retries": _counter_sum(
                local, "gateway.client.retries"),
            "client faults.fired": _counter_sum(local, "faults.fired"),
            "client reconnects": _counter_sum(
                local, "gateway.client.reconnects"),
        }
        missing = {k: v for k, v in checks.items() if v < 1}
        if missing:
            raise AssertionError(
                f"fault/retry/quarantine counters not visible: {missing}")

        # graceful drain of the survivors; SIGKILL is the writer's only
        # legitimate exit
        standby.popen.send_signal(signal.SIGTERM)
        replica.popen.send_signal(signal.SIGTERM)
        if standby.popen.wait(30) != 0:
            raise RuntimeError(
                f"standby drain exit {standby.popen.returncode}\n"
                f"{standby.tail()}")
        if replica.popen.wait(30) != 0:
            raise RuntimeError(
                f"replica drain exit {replica.popen.returncode}\n"
                f"{replica.tail()}")
        if writer.popen.returncode != -signal.SIGKILL:
            raise RuntimeError(
                f"writer exit {writer.popen.returncode}, expected "
                f"-SIGKILL")
        json.loads(stats_json.read_text())  # atomic publish parses
        fleet.close()

        print(f"chaos seed {cfg.seed}: OK — {len(acked)} acked writes "
              f"lossless across a SIGKILL takeover; shard {sid} "
              f"quarantined ({len(mates) - len(healthy_mates)} casualty, "
              f"{len(healthy_mates)} shard-mates kept serving); "
              f"server errors absorbed={injected[0]}, client retries="
              f"{int(checks['client gateway.client.retries'])}, "
              f"reconnects={int(checks['client reconnects'])}")
        return 0
    except (AssertionError, RuntimeError, TimeoutError, OSError) as e:
        print(f"chaos seed {cfg.seed}: FAIL — {e}", file=sys.stderr)
        for p in procs:
            print(p.tail(), file=sys.stderr)
        return 1
    finally:
        failpoints.disarm_all()
        for p in procs:
            p.close()


def main(argv=None) -> int:
    ap = argparse.ArgumentParser(description=__doc__.splitlines()[0])
    ap.add_argument("--seed", type=int, default=0,
                    help="drives every schedule and random choice")
    ap.add_argument("--smoke", action="store_true",
                    help="bounded ~30s run (CI gate): one SIGKILL "
                         "takeover + one injected fsync fault + one "
                         "injected shard corruption")
    ap.add_argument("--clients", type=int, default=0,
                    help="concurrent workload clients (default 2 smoke, "
                         "4 full)")
    args = ap.parse_args(argv)
    return run(Config(args.seed, args.smoke, args.clients))


if __name__ == "__main__":
    sys.exit(main())
