"""Disabled-mode obs overhead smoke (`make obs-smoke`, scripts/check.sh).

With ``REPRO_OBS=0`` the instrumented codec hot path must run within a
few percent of the uninstrumented PR-5 baseline: the only residue the
obs layer is allowed to leave on a disabled process is two
``perf_counter`` reads plus one no-op method call per *batch* (byte
sums are computed only by the enabled twin).  On a ~1 MB repro-lzr
compress (~hundreds of ms) that residue is nanoseconds; a failure here
means per-call work leaked outside the ``obs.enabled()`` gate.

The baseline is ``compress_bytes`` called directly — the exact path
``ByteCompressorCodec.encode_batch`` wrapped before instrumentation —
so the measured delta is framing + disabled-obs residue and nothing
else.  Best-of-N with a warmup pass keeps allocator/JIT noise out; the
3% ceiling is ~30x the residue, so only a real regression trips it.
"""

import os
import sys
import time

os.environ["REPRO_OBS"] = "0"  # before any repro import: codecs built
                               # below must resolve to the no-op stubs

sys.path.insert(0, os.path.join(os.path.dirname(__file__), "..", "src"))

from repro.core.codec import ByteCompressorCodec          # noqa: E402
from repro.core.zstd_backend import compress_bytes        # noqa: E402
from repro.data.corpus import generate_corpus             # noqa: E402

CEILING = 0.03  # fractional overhead allowed with REPRO_OBS=0
REPS = 5


def best(fn, reps=REPS):
    fn()  # warmup
    b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        fn()
        b = min(b, time.perf_counter() - t0)
    return b


def main() -> int:
    blob = "\n".join(
        p.text for p in generate_corpus(32, seed=0)).encode()[:1 << 20]
    codec = ByteCompressorCodec(backend="repro-lzr")

    t_raw = best(lambda: compress_bytes(blob, backend="repro-lzr"))
    t_obs = best(lambda: codec.encode_batch([blob]))
    overhead = t_obs / t_raw - 1.0

    print(f"obs smoke: repro-lzr 1MiB compress raw {t_raw * 1e3:.0f}ms "
          f"instrumented(REPRO_OBS=0) {t_obs * 1e3:.0f}ms "
          f"overhead {overhead * 100:+.1f}% (ceiling {CEILING * 100:.0f}%)")
    if overhead > CEILING:
        print("obs smoke: FAIL — disabled-mode instrumentation is doing "
              "per-call work; check that all metric math sits behind the "
              "enabled twin in repro.core.codec", file=sys.stderr)
        return 1
    return 0


if __name__ == "__main__":
    sys.exit(main())
