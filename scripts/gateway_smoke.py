#!/usr/bin/env python
"""Gateway smoke: spawn a real gateway subprocess, drive it with
concurrent clients, and assert the service tier actually measured
itself — nonzero request-latency percentiles in the obs snapshot, a
graceful SIGTERM drain (exit 0), and an atomically published
``--stats-json`` that parses.

Run by scripts/check.sh (and ``make gateway-smoke``); needs only the
stdlib + the repo (the gateway launcher is deliberately jax-free, so
this costs store-open time, not accelerator-import time).
"""

from __future__ import annotations

import json
import os
import signal
import subprocess
import sys
import tempfile
import threading
import time
from pathlib import Path

SRC = str(Path(__file__).resolve().parent.parent / "src")
sys.path.insert(0, SRC)

from repro.service.gateway import GatewayClient  # noqa: E402

N_CLIENTS = 3
N_BATCHES = 4


def main() -> int:
    tmp = Path(tempfile.mkdtemp(prefix="gateway-smoke-"))
    port_file = tmp / "port.json"
    stats_json = tmp / "stats.json"
    env = dict(os.environ)
    env["PYTHONPATH"] = SRC + (os.pathsep + env["PYTHONPATH"]
                               if env.get("PYTHONPATH") else "")
    proc = subprocess.Popen(
        [sys.executable, "-m", "repro.launch.gateway",
         "--store-dir", str(tmp / "store"), "--build-corpus", "12",
         "--port", "0", "--port-file", str(port_file),
         "--stats-json", str(stats_json), "--flush-batch", "8"],
        env=env, stdout=subprocess.PIPE, stderr=subprocess.STDOUT, text=True)
    try:
        t0 = time.monotonic()
        while not port_file.exists():
            if proc.poll() is not None:
                print(proc.stdout.read())
                print("gateway smoke: FAIL (gateway died at startup)")
                return 1
            if time.monotonic() - t0 > 30:
                print("gateway smoke: FAIL (gateway not ready in 30s)")
                return 1
            time.sleep(0.05)
        info = json.loads(port_file.read_text())
        errors: list = []

        def client(ci: int) -> None:
            try:
                with GatewayClient(info["host"], info["port"]) as c:
                    for bi in range(N_BATCHES):
                        texts = [f"smoke c{ci} b{bi} r{r}: drain the "
                                 "queue, verify the quorum. " * 6
                                 for r in range(3)]
                        keys = c.put_async(texts, wait=True)["keys"]
                        got = c.get_many(keys)
                        if got != texts:
                            raise AssertionError(
                                f"lossless violation on client {ci}")
                        c.get_tokens(keys[0])
            except Exception as e:  # noqa: BLE001 - reported below
                errors.append(e)

        threads = [threading.Thread(target=client, args=(i,))
                   for i in range(N_CLIENTS)]
        for t in threads:
            t.start()
        for t in threads:
            t.join(60)
        if errors:
            print(f"gateway smoke: FAIL (client errors: {errors})")
            return 1

        with GatewayClient(info["host"], info["port"]) as c:
            snap = c.stats(snapshot=True)["obs"]
        lat = {k: v for k, v in snap["histograms"].items()
               if k.startswith("gateway.request.s")}
        live = {k: v for k, v in lat.items() if v["count"] > 0}
        if not live or not all(v["p50"] > 0 and v["p99"] > 0
                               for v in live.values()):
            print(f"gateway smoke: FAIL (no nonzero request-latency "
                  f"percentiles: {lat})")
            return 1

        proc.send_signal(signal.SIGTERM)
        code = proc.wait(timeout=30)
        if code != 0:
            print(proc.stdout.read())
            print(f"gateway smoke: FAIL (drain exit code {code})")
            return 1
        final = json.loads(stats_json.read_text())  # atomic publish parses
        ops = ", ".join(
            f"{k.split('op=')[1].rstrip('}')} p50 {v['p50']*1e3:.2f}ms "
            f"p99 {v['p99']*1e3:.2f}ms" for k, v in sorted(live.items()))
        print(f"gateway smoke: {N_CLIENTS} clients x {N_BATCHES} batches, "
              f"{ops}; drain exit 0, stats-json "
              f"({len(final['histograms'])} histograms) parses")
        return 0
    finally:
        if proc.poll() is None:
            proc.kill()


if __name__ == "__main__":
    sys.exit(main())
