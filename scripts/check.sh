#!/usr/bin/env bash
# Quick verification loop: the not-slow test tier plus an explicit run of
# the golden-frame tests that pin on-disk byte layouts (v1 token payload,
# v2 dict header).  Full tier-1 remains `PYTHONPATH=src python -m pytest
# -x -q` (see ROADMAP.md); `pytest -m crash` selects the crash-injection
# suite alone.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"
python -m pytest -q -m "not slow"
python -m pytest -q tests/test_codec.py tests/test_dict_codec.py -k golden
