#!/usr/bin/env bash
# Quick verification loop: the not-slow test tier plus an explicit run of
# the golden-frame tests that pin on-disk byte layouts (v1 token payload,
# v2 dict header).  Full tier-1 remains `PYTHONPATH=src python -m pytest
# -x -q` (see ROADMAP.md); `pytest -m crash` selects the crash-injection
# suite alone.
set -euo pipefail
cd "$(dirname "$0")/.."
export PYTHONPATH="src${PYTHONPATH:+:$PYTHONPATH}"

# Invariant gate first: the static analyzer (lock order, durability,
# frozen wire formats, kernel hygiene, env registry, pool re-entrancy)
# fails in seconds, before any test tier spends minutes.
python -m repro.analysis src --baseline analysis-baseline.json

python -m pytest -q -m "not slow"
python -m pytest -q tests/test_codec.py tests/test_dict_codec.py -k golden

# Perf smoke: the vectorized repro-lzr compress path must beat the scalar
# baseline by a conservative floor on a ~1 MB sample — this is the guard
# against silently falling back to the scalar path (e.g. a routing or
# env-knob regression).  The floor (1.8x) sits far below the measured
# speedup (~4-6x on this corpus) so machine-load noise cannot trip it.
python - <<'PYEOF'
import os, time
from repro.data.corpus import generate_corpus
from repro.core.zstd_backend import compress_bytes

blob = "\n".join(p.text for p in generate_corpus(32, seed=0)).encode()[:1 << 20]

def best(reps=3):
    b = float("inf")
    for _ in range(reps):
        t0 = time.perf_counter()
        compress_bytes(blob, backend="repro-lzr")
        b = min(b, time.perf_counter() - t0)
    return b

os.environ.update(REPRO_LZ_MODE="scalar", REPRO_RANS_LANES="1")
t_scalar = best()
os.environ.pop("REPRO_LZ_MODE"); os.environ.pop("REPRO_RANS_LANES")
t_vec = best()
speedup = t_scalar / t_vec
print(f"perf smoke: repro-lzr compress scalar {t_scalar*1e3:.0f}ms "
      f"vec {t_vec*1e3:.0f}ms speedup {speedup:.1f}x (floor 1.8x)")
assert speedup >= 1.8, (
    f"vectorized repro-lzr compress only {speedup:.2f}x over scalar — "
    "did the hot path silently fall back to the scalar loop?")
PYEOF

# Obs smoke: with REPRO_OBS=0 the instrumented codec hot path must sit
# within 3% of the raw compress baseline — the guard against metric
# bookkeeping leaking outside the enabled() gate (see scripts/obs_smoke.py).
python scripts/obs_smoke.py

# Device-kernel smoke: both codec kernels (LZ77 match finder, lane-parallel
# rANS) run in interpret mode and must be byte-identical to the scalar-
# rooted oracles — the guard against a kernel or dispatch change silently
# breaking wire-format parity on hosts with no accelerator attached.
python - <<'PYEOF'
import numpy as np
from repro.core.lz77 import _lz_compress_device, _lz_compress_np
from repro.core.rans_np import normalize_freqs, rans_encode_interleaved
from repro.kernels.rans_lanes import (rans_decode_interleaved_device,
                                      rans_encode_interleaved_device)
from repro.data.corpus import generate_corpus

blob = "\n".join(p.text for p in generate_corpus(8, seed=1)).encode()[:1 << 16]
assert _lz_compress_device(blob) == _lz_compress_np(blob), \
    "device LZ77 match finder diverged from the NumPy parse"
sym = np.frombuffer(blob, np.uint8)
freqs = normalize_freqs(np.bincount(sym, minlength=256))
w_r, x_r = rans_encode_interleaved(sym, freqs, 256)
w_d, x_d = rans_encode_interleaved_device(sym, freqs, 256, 12, interpret=True)
assert np.array_equal(w_r, w_d) and np.array_equal(x_r, x_d), \
    "device rANS encoder diverged from the NumPy interleaved coder"
assert bytes(rans_decode_interleaved_device(
    w_d, x_d, sym.size, freqs, 256, 12, interpret=True)) == blob, \
    "device rANS decoder failed to round-trip"
print("kernel smoke: LZ77 + rANS device paths byte-identical (interpret mode)")
PYEOF

# Gateway smoke: spawn a real gateway subprocess (jax-free launcher),
# drive it with concurrent socket clients, and require nonzero request-
# latency percentiles in the obs snapshot, a graceful SIGTERM drain
# (exit 0), and an atomically published --stats-json that parses.
python scripts/gateway_smoke.py

# Chaos smoke (~30s, fixed seed): writer + standby + replica fleet under
# a seeded fault schedule — one SIGKILL takeover, one injected fsync
# fault, one injected shard corruption.  Asserts zero acked-write loss,
# quarantine + degraded reads (never store-wide failure), and the
# fault/retry/quarantine counters in the obs snapshots.  `make chaos`
# runs the full harness across seeds 0-4.
python scripts/chaos.py --smoke --seed 0
