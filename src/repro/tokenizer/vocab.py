"""Vocabulary (de)serialization and the cached default tokenizer.

The serialized form is a small JSON document: merge list, special tokens,
name, and the content fingerprint.  LoPace payload metadata references the
fingerprint so that decompression with a mismatched vocabulary is refused
(paper §8.4.1 limitation #1: tokenizer versioning).
"""

from __future__ import annotations

import json
import os
from pathlib import Path
from typing import Optional

from repro.core import env
from repro.core.durability import fsync_dir, write_durable
from repro.tokenizer.bpe import BPETokenizer, train_bpe

_DEFAULT_VOCAB_SIZE = 8192
_DEFAULT_SPECIALS = [
    "<|system|>",
    "<|user|>",
    "<|assistant|>",
    "<|endofprompt|>",
    "<|fim_prefix|>",
    "<|fim_middle|>",
    "<|fim_suffix|>",
]

_CACHE: Optional[BPETokenizer] = None


def save_tokenizer(tok: BPETokenizer, path: str | Path) -> None:
    path = Path(path)
    path.parent.mkdir(parents=True, exist_ok=True)
    doc = {
        "format": "repro-bpe-v1",
        "name": tok.name,
        "merges": [[int(a), int(b)] for a, b in tok.merges],
        "special_tokens": list(tok.special_tokens),
        "fingerprint": tok.fingerprint(),
    }
    tmp = path.with_suffix(path.suffix + ".tmp")
    write_durable(tmp, json.dumps(doc).encode())
    os.replace(tmp, path)  # atomic publish
    fsync_dir(path.parent)


def load_tokenizer(path: str | Path) -> BPETokenizer:
    doc = json.loads(Path(path).read_text())
    if doc.get("format") != "repro-bpe-v1":
        raise ValueError(f"unknown tokenizer format in {path}")
    tok = BPETokenizer(
        merges=[(int(a), int(b)) for a, b in doc["merges"]],
        special_tokens=list(doc["special_tokens"]),
        name=doc.get("name", "repro_bpe"),
    )
    if doc.get("fingerprint") and doc["fingerprint"] != tok.fingerprint():
        raise ValueError(f"tokenizer fingerprint mismatch loading {path}")
    return tok


def default_tokenizer_path() -> Path:
    root = env.read("REPRO_ASSET_DIR",
                    os.path.join(os.path.dirname(__file__), "assets"))
    return Path(root) / f"repro_bpe_{_DEFAULT_VOCAB_SIZE}.json"


def default_tokenizer(vocab_size: int = _DEFAULT_VOCAB_SIZE) -> BPETokenizer:
    """The framework's standard tokenizer; trained once on the synthetic
    corpus and cached on disk (and in-process)."""
    global _CACHE
    if _CACHE is not None and vocab_size == _DEFAULT_VOCAB_SIZE:
        return _CACHE
    path = default_tokenizer_path()
    if vocab_size == _DEFAULT_VOCAB_SIZE and path.exists():
        tok = load_tokenizer(path)
        _CACHE = tok
        return tok
    from repro.data.corpus import generate_corpus

    docs = [p.text for p in generate_corpus(n_prompts=160, seed=0)]
    tok = train_bpe(docs, vocab_size=vocab_size, special_tokens=_DEFAULT_SPECIALS)
    if vocab_size == _DEFAULT_VOCAB_SIZE:
        save_tokenizer(tok, path)
        _CACHE = tok
    return tok
