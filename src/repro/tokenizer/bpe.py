"""Byte-level Byte-Pair Encoding: trainer and runtime codec.

Design follows the GPT-2/tiktoken lineage the paper builds on:

* base alphabet = the 256 byte values (so *any* UTF-8 string round-trips,
  including invalid-unicode edge cases fed in as bytes),
* a pre-tokenization regex splits text into "words" (contractions, letter
  runs, digit runs, punctuation runs, whitespace runs) and merges never
  cross word boundaries — this is what makes training tractable and
  encoding cacheable,
* merges are learned greedily by pair frequency over the *unique-word*
  multiset with incremental pair-count maintenance (only words containing
  the merged pair are touched per iteration),
* special tokens live above ``SPECIAL_ID_BASE`` (100_000) so realistic
  prompts exercise LoPace's uint32 packing path exactly as cl100k_base
  special tokens do in the paper (§3.3.4).

Everything is deterministic: ties in pair frequency break on the pair's
token ids, so the same corpus always yields the same vocabulary.
"""

from __future__ import annotations

import re
from collections import Counter
from dataclasses import dataclass, field
from functools import lru_cache
from typing import Dict, Iterable, List, Optional, Sequence, Tuple

# GPT-2 style pre-tokenizer, expressed with `re` (no `regex` module offline):
# contractions | letter runs (w/ leading space) | digit runs | punct runs | whitespace.
_PRETOKEN_RE = re.compile(
    r"'s|'t|'re|'ve|'m|'ll|'d"
    r"| ?[A-Za-z_]+"
    r"| ?[0-9]+"
    r"| ?[^\sA-Za-z_0-9]+"
    r"|\s+(?!\S)|\s+"
)

SPECIAL_ID_BASE = 100_000

Pair = Tuple[int, int]


def pretokenize(text: str) -> List[bytes]:
    """Split text into byte-level words; concatenation of words == text."""
    return [w.encode("utf-8") for w in _PRETOKEN_RE.findall(text)]


# ---------------------------------------------------------------------------
# Trainer
# ---------------------------------------------------------------------------


def _count_pairs(
    word_syms: List[List[int]], word_freqs: List[int]
) -> Tuple[Counter, Dict[Pair, set]]:
    """Initial pair frequency count + inverted index pair -> word indices."""
    pair_counts: Counter = Counter()
    pair_words: Dict[Pair, set] = {}
    for wi, (syms, freq) in enumerate(zip(word_syms, word_freqs)):
        for a, b in zip(syms, syms[1:]):
            pair_counts[(a, b)] += freq
            pair_words.setdefault((a, b), set()).add(wi)
    return pair_counts, pair_words


def _merge_word(syms: List[int], pair: Pair, new_id: int) -> List[int]:
    """Replace every non-overlapping occurrence of `pair` in `syms`."""
    out: List[int] = []
    i, n = 0, len(syms)
    a, b = pair
    while i < n:
        if i + 1 < n and syms[i] == a and syms[i + 1] == b:
            out.append(new_id)
            i += 2
        else:
            out.append(syms[i])
            i += 1
    return out


def train_bpe(
    corpus: Iterable[str],
    vocab_size: int = 8192,
    special_tokens: Sequence[str] = (),
    verbose: bool = False,
) -> "BPETokenizer":
    """Learn a byte-level BPE vocabulary of `vocab_size` tokens.

    `vocab_size` counts the 256 byte tokens plus learned merges (special
    tokens live in their own id space above SPECIAL_ID_BASE and do not
    consume merge budget).
    """
    if vocab_size < 256:
        raise ValueError("vocab_size must be >= 256 (byte alphabet)")

    # Unique-word frequency table.
    word_counter: Counter = Counter()
    for doc in corpus:
        word_counter.update(pretokenize(doc))
    words = list(word_counter.keys())
    word_freqs = [word_counter[w] for w in words]
    word_syms: List[List[int]] = [list(w) for w in words]

    pair_counts, pair_words = _count_pairs(word_syms, word_freqs)

    merges: List[Pair] = []
    n_merges = vocab_size - 256
    for step in range(n_merges):
        if not pair_counts:
            break
        # Deterministic argmax: highest count, then lowest pair ids.
        best_pair, best_count = None, -1
        for p, c in pair_counts.items():
            if c > best_count or (c == best_count and (best_pair is None or p < best_pair)):
                best_pair, best_count = p, c
        if best_count < 2:  # nothing left worth merging
            break
        new_id = 256 + len(merges)
        merges.append(best_pair)

        # Incremental update: only words containing best_pair change.
        touched = pair_words.pop(best_pair, set())
        pair_counts.pop(best_pair, None)
        for wi in touched:
            syms, freq = word_syms[wi], word_freqs[wi]
            # retract old pair counts for this word
            for a, b in zip(syms, syms[1:]):
                pc = pair_counts.get((a, b))
                if pc is not None:
                    if pc <= freq:
                        pair_counts.pop((a, b), None)
                        pair_words.get((a, b), set()).discard(wi)
                    else:
                        pair_counts[(a, b)] = pc - freq
            new_syms = _merge_word(syms, best_pair, new_id)
            word_syms[wi] = new_syms
            # add new pair counts
            for a, b in zip(new_syms, new_syms[1:]):
                pair_counts[(a, b)] = pair_counts.get((a, b), 0) + freq
                pair_words.setdefault((a, b), set()).add(wi)
        if verbose and (step + 1) % 512 == 0:
            print(f"  bpe-train: {step + 1}/{n_merges} merges")

    return BPETokenizer(merges=merges, special_tokens=list(special_tokens))


# ---------------------------------------------------------------------------
# Runtime codec
# ---------------------------------------------------------------------------


@dataclass
class BPETokenizer:
    """Byte-level BPE encoder/decoder.

    ids 0..255        : raw bytes
    ids 256..256+M-1  : learned merges (rank order)
    ids >= 100_000    : special tokens (uint32-path by construction)
    """

    merges: List[Pair]
    special_tokens: List[str] = field(default_factory=list)
    name: str = "repro_bpe"

    def __post_init__(self) -> None:
        self._ranks: Dict[Pair, int] = {p: i for i, p in enumerate(self.merges)}
        # id -> bytes table
        self._id_to_bytes: List[bytes] = [bytes([i]) for i in range(256)]
        for a, b in self.merges:
            self._id_to_bytes.append(self._id_to_bytes[a] + self._id_to_bytes[b])
        self._special_to_id = {
            s: SPECIAL_ID_BASE + i for i, s in enumerate(self.special_tokens)
        }
        self._id_to_special = {v: k for k, v in self._special_to_id.items()}
        if self.special_tokens:
            self._special_re = re.compile(
                "(" + "|".join(re.escape(s) for s in self.special_tokens) + ")"
            )
        else:
            self._special_re = None
        # Per-instance LRU memo over the merge loop: pre-tokenization makes
        # words the unit of encoding (merges never cross word boundaries),
        # and realistic text reuses a small working set of words, so the
        # merge loop — the encode path's hot loop — runs only on cache
        # misses.  lru_cache (vs the old never-evicting dict) keeps the
        # memo bounded under adversarial/streaming vocabularies while C
        # hashing keeps hits ~100ns.
        self._encode_word = lru_cache(maxsize=1 << 18)(self._encode_word_miss)

    # -- properties ---------------------------------------------------------

    @property
    def vocab_size(self) -> int:
        return 256 + len(self.merges)

    @property
    def max_id(self) -> int:
        if self.special_tokens:
            return SPECIAL_ID_BASE + len(self.special_tokens) - 1
        return self.vocab_size - 1

    def fingerprint(self) -> str:
        """Content hash of the vocabulary (stored in LoPace payload metadata)."""
        import hashlib

        h = hashlib.sha256()
        for a, b in self.merges:
            h.update(a.to_bytes(4, "little") + b.to_bytes(4, "little"))
        for s in self.special_tokens:
            h.update(s.encode("utf-8"))
        return h.hexdigest()[:16]

    # -- encode -------------------------------------------------------------

    def _encode_word_miss(self, word: bytes) -> Tuple[int, ...]:
        """Apply merges to one word; reached only on `_encode_word` cache
        misses (the lru_cache wrapper is built in __post_init__)."""
        syms: List[int] = list(word)
        ranks = self._ranks
        while len(syms) > 1:
            # find the lowest-rank pair present
            best_rank, best_idx = None, -1
            for i in range(len(syms) - 1):
                r = ranks.get((syms[i], syms[i + 1]))
                if r is not None and (best_rank is None or r < best_rank):
                    best_rank, best_idx = r, i
            if best_rank is None:
                break
            a, b = syms[best_idx], syms[best_idx + 1]
            syms = _merge_word(syms, (a, b), 256 + best_rank)
        return tuple(syms)

    def encode(self, text: str) -> List[int]:
        """Text -> token ids. Special tokens are recognized and mapped."""
        ids: List[int] = []
        if self._special_re is not None:
            chunks = self._special_re.split(text)
        else:
            chunks = [text]
        for chunk in chunks:
            if not chunk:
                continue
            sid = self._special_to_id.get(chunk)
            if sid is not None:
                ids.append(sid)
                continue
            for word in pretokenize(chunk):
                ids.extend(self._encode_word(word))
        return ids

    def encode_batch(self, texts: Sequence[str]) -> List[List[int]]:
        return [self.encode(t) for t in texts]

    # -- decode -------------------------------------------------------------

    def decode_bytes(self, ids: Sequence[int]) -> bytes:
        table = self._id_to_bytes
        parts: List[bytes] = []
        for t in ids:
            t = int(t)
            if t >= SPECIAL_ID_BASE:
                sp = self._id_to_special.get(t)
                if sp is None:
                    raise ValueError(f"unknown special token id {t}")
                parts.append(sp.encode("utf-8"))
            else:
                parts.append(table[t])
        return b"".join(parts)

    def decode(self, ids: Sequence[int]) -> str:
        return self.decode_bytes(ids).decode("utf-8", errors="strict")

    # lossless identity: decode(encode(t)) == t for all valid unicode text.
