"""Byte-level BPE tokenizer substrate.

tiktoken is not available in this environment, so the tokenizer layer the
paper depends on is built from scratch: a trainer (`train_bpe`), a runtime
codec (`BPETokenizer.encode` / `.decode`), vocab (de)serialization, and
special-token handling.  Special tokens are deliberately assigned IDs
>= 100_000 (mirroring cl100k_base) so that prompts containing them exercise
the uint32 packing path of the LoPace format.
"""

from repro.tokenizer.bpe import BPETokenizer, train_bpe
from repro.tokenizer.vocab import load_tokenizer, save_tokenizer, default_tokenizer

__all__ = [
    "BPETokenizer",
    "train_bpe",
    "load_tokenizer",
    "save_tokenizer",
    "default_tokenizer",
]
