"""PartitionSpec rules for the production meshes.

Single pod: (data=16, model=16); multi-pod: (pod=2, data=16, model=16).
Every rule is duck-typed over `mesh.shape` / `mesh.axis_names` only (the
unit tests drive them with a FakeMesh; the dry-run with a real 256/512-way
host mesh) and divisibility-guarded: an axis is only ever sharded when the
dimension divides the mesh-axis product, otherwise that dimension is
replicated.  This is what lets one rule set cover every architecture in
the pool — dbrx's 8 KV heads replicate on a 16-way model axis while its
48 query heads shard; minicpm3's 73448-entry vocabulary falls back from
vocab-parallel to hidden-parallel embeddings; and so on.

Layout conventions (matching repro.models):

* params under ``blocks`` are stacked over the scan-of-layers axis
  (leading ``n_per`` dim, never sharded); ``rem_blocks`` / ``embed`` /
  ``head`` are unstacked.
* attention projections shard the *head* axis (tensor parallelism) and
  replicate when the head count does not divide the model axis — those
  archs run sequence-parallel attention instead (launch.dryrun
  `_seq_shard_specs`).
* MoE expert tensors shard the expert axis (expert parallelism).
* embeddings are vocab-parallel (``table``: vocab dim, ``head.w``: output
  dim) with a hidden-dim fallback.
* ZeRO-1: optimizer moments additionally shard their first replicated,
  divisible dimension over ``data``.
"""

from __future__ import annotations

from typing import Any, Sequence, Tuple

import jax
from jax.sharding import PartitionSpec as P

from repro.dist._util import path_names as _path_names

Mesh = Any  # duck-typed: needs .shape (dict-like) and .axis_names


# ---------------------------------------------------------------------------
# Mesh helpers
# ---------------------------------------------------------------------------


def _axis_size(mesh: Mesh, name: str) -> int:
    return int(dict(mesh.shape).get(name, 1))


def _dp_axes(mesh: Mesh) -> Tuple[str, ...]:
    """Data-parallel axes; the pod axis composes with data when present."""
    return ("pod", "data") if "pod" in tuple(mesh.axis_names) else ("data",)


def _dp_size(mesh: Mesh, axes: Sequence[str]) -> int:
    n = 1
    for a in axes:
        n *= _axis_size(mesh, a)
    return n


# ---------------------------------------------------------------------------
# Parameter rules
# ---------------------------------------------------------------------------

_HEAD_PROJ = {"wq", "wk", "wv"}           # [d, n_heads, head_dim]
_LATENT_PROJ = {"wuq", "wuk", "wuv"}      # [rank, n_heads, head_dim]
_BLOCKDIAG = {"w_q", "w_k", "w_v",        # [n_heads, hd, hd] per-head mats
              "r_z", "r_i", "r_f", "r_o"}


def _param_axes(names: Tuple[str, ...], shape: Tuple[int, ...],
                model: int) -> Tuple[Any, ...]:
    """Model-parallel spec for one UNSTACKED param leaf, full rank."""
    spec = [None] * len(shape)
    if len(shape) < 2:
        return tuple(spec)  # norms, biases, gates: replicate

    def ok(dim: int) -> bool:
        return shape[dim] % model == 0

    name = names[-1]
    if name in _HEAD_PROJ and len(shape) == 3:
        # head-parallel or fully replicated (KV heads of GQA archs whose
        # head count does not divide the model axis stay replicated; the
        # launcher shards the sequence instead)
        if ok(1):
            spec[1] = "model"
    elif name == "wo" and len(shape) == 3:      # [n_heads, hd, d]
        if ok(0):
            spec[0] = "model"
        elif ok(2):
            spec[2] = "model"
    elif name in _LATENT_PROJ and len(shape) == 3:   # [rank, n, hd]
        if ok(1):
            spec[1] = "model"
        elif ok(0):
            spec[0] = "model"
    elif name in _BLOCKDIAG and len(shape) == 3:     # [n, hd, hd]
        if ok(0):
            spec[0] = "model"
        elif ok(2):
            spec[2] = "model"
    elif len(shape) == 3:                       # MoE expert mats [E, ., .]
        if ok(0):
            spec[0] = "model"
    elif name == "table" and "embed" in names:  # [vocab, d]: vocab-parallel
        if ok(0):
            spec[0] = "model"
        elif ok(1):
            spec[1] = "model"
    elif len(shape) == 2:
        # generic matmul weight [in, out]: column-parallel, row fallback
        if ok(1):
            spec[1] = "model"
        elif ok(0):
            spec[0] = "model"
    return tuple(spec)


def _param_spec(path, leaf, model: int) -> Tuple[Any, ...]:
    names = _path_names(path)
    shape = tuple(leaf.shape)
    if names and names[0] == "blocks":
        # stacked over the layer-scan axis: rule applies to shape[1:]
        return (None,) + _param_axes(names, shape[1:], model)
    return _param_axes(names, shape, model)


def param_pspecs(params: Any, cfg: Any, mesh: Mesh) -> Any:
    """Tensor-parallel PartitionSpecs for a param tree (replicated over
    data; see `fsdp_pspecs` / `zero1_pspecs` for data-sharded variants)."""
    model = _axis_size(mesh, "model")
    return jax.tree_util.tree_map_with_path(
        lambda path, leaf: P(*_param_spec(path, leaf, model)), params)


def _with_data_axis(spec: Tuple[Any, ...], shape: Tuple[int, ...],
                    mesh: Mesh) -> P:
    """Add a data-parallel axis on the first replicated, divisible dim."""
    dp = _dp_axes(mesh)
    out = list(spec)
    for axes in (dp, ("data",)) if len(dp) > 1 else (dp,):
        k = _dp_size(mesh, axes)
        for d in range(len(shape)):
            if out[d] is None and shape[d] % k == 0 and shape[d] >= k:
                out[d] = axes if len(axes) > 1 else axes[0]
                return P(*out)
    return P(*out)


def fsdp_pspecs(params: Any, cfg: Any, mesh: Mesh) -> Any:
    """param_pspecs + shard each leaf's first free dim over data (FSDP-
    style weight sharding for archs whose TP-only footprint blows HBM)."""
    model = _axis_size(mesh, "model")

    def rule(path, leaf):
        spec = _param_spec(path, leaf, model)
        return _with_data_axis(spec, tuple(leaf.shape), mesh)

    return jax.tree_util.tree_map_with_path(rule, params)


# ---------------------------------------------------------------------------
# Optimizer (ZeRO-1)
# ---------------------------------------------------------------------------


def zero1_pspecs(opt_state: Any, cfg: Any, mesh: Mesh) -> Any:
    """Optimizer-state specs: moments (and the error-feedback carry)
    mirror the param specs plus a data shard on the first free divisible
    dim (ZeRO-1: each DP rank owns a slice of m/v); scalars replicate."""
    model = _axis_size(mesh, "model")

    def moment_rule(path, leaf):
        spec = _param_spec(path, leaf, model)
        return _with_data_axis(spec, tuple(leaf.shape), mesh)

    out = {}
    for key, sub in opt_state.items():
        if key in ("m", "v", "ef"):
            out[key] = jax.tree_util.tree_map_with_path(moment_rule, sub)
        else:
            out[key] = jax.tree_util.tree_map(lambda _: P(), sub)
    return out


# ---------------------------------------------------------------------------
# Batch / cache rules
# ---------------------------------------------------------------------------


def batch_pspecs(batch: Any, mesh: Mesh, *, accum: bool = False) -> Any:
    """Shard the (micro)batch dim over all data-parallel axes.

    Leading dims [B, ...] or [accum, micro_B, ...]: pass ``accum=True``
    when the leaves carry a leading grad-accumulation dim — dim 1 (the
    microbatch) is then the batch dim and the scanned accum dim always
    stays on-host.  Without it, dim 0 is the batch dim, with a dim-1
    fallback only when dim 0 is a plausible accum count (> 1) — so a
    B=1 probe replicates instead of sharding its sequence dim.
    Non-divisible leaves replicate."""
    dp = _dp_axes(mesh)
    dp_size = _dp_size(mesh, dp)
    axis = dp if len(dp) > 1 else dp[0]

    def rule(_, leaf):
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if accum and len(shape) >= 3:
            candidates = (1,)
        elif len(shape) >= 3 and shape[0] > 1:
            candidates = (0, 1)
        else:
            candidates = (0,)
        for d in candidates:
            if shape[d] % dp_size == 0 and shape[d] >= dp_size:
                spec[d] = axis
                break
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, batch)


def cache_pspecs(cache: Any, cfg: Any, mesh: Mesh) -> Any:
    """Decode/prefill cache specs: batch dim over data, KV-head dim over
    model where divisible.  Cache trees are {"scanned": ..., "rem": ...}
    (repro.models.transformer.init_cache); scanned leaves carry a leading
    layer-stack dim.  `key_pos` index vectors replicate."""
    dp = _dp_axes(mesh)
    dp_size = _dp_size(mesh, dp)
    model = _axis_size(mesh, "model")
    dp_axis = dp if len(dp) > 1 else dp[0]

    def rule(path, leaf):
        names = _path_names(path)
        shape = tuple(leaf.shape)
        spec = [None] * len(shape)
        if names[-1] == "key_pos":
            return P(*spec)
        b = 1 if names[0] == "scanned" else 0  # skip the layer-stack dim
        if b < len(shape) and shape[b] % dp_size == 0 and shape[b] >= dp_size:
            spec[b] = dp_axis
        if (names[-1] in ("k", "v", "k_scale", "v_scale")
                and len(shape) - b == 4 and shape[b + 2] % model == 0):
            spec[b + 2] = "model"  # KV heads (GQA caches) over model
        return P(*spec)

    return jax.tree_util.tree_map_with_path(rule, cache)


# ---------------------------------------------------------------------------
# NamedSharding adapter
# ---------------------------------------------------------------------------


def named(specs: Any, mesh) -> Any:
    """PartitionSpec tree -> NamedSharding tree (requires a real Mesh)."""
    from jax.sharding import NamedSharding

    return jax.tree_util.tree_map(
        lambda s: NamedSharding(mesh, s), specs,
        is_leaf=lambda x: isinstance(x, P))
