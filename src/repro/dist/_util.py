"""Shared internals for the dist modules."""

from __future__ import annotations

from typing import Tuple


def path_names(path) -> Tuple[str, ...]:
    """jax tree path -> tuple of key strings (DictKey / SequenceKey /
    GetAttrKey all normalize to their name or index)."""
    out = []
    for k in path:
        if hasattr(k, "key"):
            out.append(str(k.key))
        elif hasattr(k, "idx"):
            out.append(str(k.idx))
        else:
            out.append(str(k))
    return tuple(out)
