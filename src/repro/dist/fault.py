"""Fleet fault tolerance: heartbeat files, dead/straggler detection, and
the restart state machine.

Hosts publish heartbeats as atomically-renamed JSON files in a shared
directory (works on any POSIX filesystem — no coordinator service).  A
monitor (any host, or an external supervisor) scans the directory and
classifies the fleet; `RestartPolicy` turns a `FleetStatus` into one of
three decisions:

    continue         — everyone alive (stragglers are reported, not fatal)
    restart_elastic  — some hosts dead but quorum remains: reload the
                       latest checkpoint on the surviving hosts with a
                       re-carved data-parallel sharding
    abort            — too many failures (or no survivors): stop and page
"""

from __future__ import annotations

import json
import os
import statistics
import time
from dataclasses import dataclass, field
from pathlib import Path
from typing import Dict, List, Optional


class Heartbeat:
    """One host's heartbeat publisher: `beat(step, step_time_s=...)` after
    every training step."""

    def __init__(self, root, host_id: str):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.host_id = str(host_id)
        self._path = self.root / f"{self.host_id}.json"

    def beat(self, step: int, *, step_time_s: Optional[float] = None,
             now: Optional[float] = None) -> None:
        doc = {"host": self.host_id, "step": int(step),
               "step_time_s": step_time_s,
               "time": time.time() if now is None else now}
        tmp = self._path.with_suffix(f".tmp{os.getpid()}")
        tmp.write_text(json.dumps(doc))
        # repro-analysis: disable=REPRO002 heartbeats are per-step ephemeral liveness signals; fsyncing one per training step would serialize the hot loop on the platter, and a beat lost to power-loss is indistinguishable from the host being dead (which is what the monitor concludes anyway)
        os.replace(tmp, self._path)  # readers never see a torn beat


@dataclass(frozen=True)
class FleetStatus:
    alive: List[str]
    dead: List[str]
    stragglers: List[str]
    median_step_time: Optional[float]


class FleetMonitor:
    """Scans a heartbeat directory and classifies hosts.

    dead: no beat within `dead_after` seconds of `now`.
    straggler: alive but step_time > straggler_factor * fleet median."""

    def __init__(self, root, *, dead_after: float = 60.0,
                 straggler_factor: float = 2.0):
        self.root = Path(root)
        self.dead_after = float(dead_after)
        self.straggler_factor = float(straggler_factor)

    def _read_beats(self) -> Dict[str, dict]:
        beats = {}
        if not self.root.is_dir():
            return beats
        for p in sorted(self.root.glob("*.json")):
            try:
                doc = json.loads(p.read_text())
            except (OSError, json.JSONDecodeError):
                continue  # torn/just-replaced file: treat as missing beat
            beats[doc.get("host", p.stem)] = doc
        return beats

    def scan(self, now: Optional[float] = None) -> FleetStatus:
        now = time.time() if now is None else float(now)
        beats = self._read_beats()
        alive, dead = [], []
        for host, doc in sorted(beats.items()):
            age = now - float(doc.get("time", 0.0))
            (alive if age <= self.dead_after else dead).append(host)

        times = {h: beats[h].get("step_time_s") for h in alive
                 if beats[h].get("step_time_s") is not None}
        median = statistics.median(times.values()) if times else None
        stragglers = []
        if median is not None and median > 0:
            stragglers = sorted(
                h for h, t in times.items()
                if t > self.straggler_factor * median)
        return FleetStatus(alive=alive, dead=dead, stragglers=stragglers,
                           median_step_time=median)


@dataclass
class RestartPolicy:
    """continue / restart_elastic / abort from a FleetStatus.

    `max_failures` is the abort threshold on *distinct* dead hosts over
    the run — a host already accounted for (e.g. a stale heartbeat file
    from a previous launch) is not re-counted on every scan, so one stale
    file can never drain the budget and abort a healthy run.
    `total_restarts` bounds elastic restarts across the run (a fleet that
    keeps losing hosts should page a human, not thrash)."""

    max_failures: int = 2
    total_restarts: int = 8
    restarts_taken: int = field(default=0)
    _seen_dead: set = field(default_factory=set)

    def decide(self, status: FleetStatus) -> str:
        # a host that came back is no longer "accounted for": if it dies
        # again it must trigger a fresh elastic restart
        self._seen_dead -= set(status.alive)
        if not status.dead:
            return "continue"
        newly_dead = set(status.dead) - self._seen_dead
        self._seen_dead |= newly_dead
        if not status.alive or len(self._seen_dead) >= self.max_failures:
            return "abort"
        if not newly_dead:
            return "continue"  # degraded but already accounted for
        if self.restarts_taken >= self.total_restarts:
            return "abort"
        self.restarts_taken += 1
        return "restart_elastic"
