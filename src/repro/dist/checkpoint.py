"""Sharded, checksummed, atomically-committed `.npz` checkpoints.

Layout (one directory per step):

    <root>/step_00000042/
        shard_00000.npz   # uint8 blobs, one entry per leaf key
        shard_00001.npz   # ...leaves greedily packed up to shard_bytes
        meta.json         # step, extra, per-leaf {shape,dtype,shard},
                          # per-shard sha256 over the file bytes

Design points:

* leaves are serialized as raw uint8 blobs with shape/dtype recorded in
  meta.json — this round-trips dtypes numpy's npz container can't
  (bfloat16 moments, int8 EF carries) and makes the checksum exact;
* the step directory is written under a dot-prefixed temp name and
  `os.replace`d into place, so a killed writer never leaves a directory
  that `latest_checkpoint` would pick up;
* restore verifies every shard's sha256 BEFORE parsing (a flipped bit
  raises ``ValueError("corrupt ...")``, never a deserializer crash) and
  refuses shape mismatches against the restore template;
* `extra` carries JSON state (e.g. `TokenPipeline.state()`) so a resumed
  run replays the exact data order.
"""

from __future__ import annotations

import hashlib
import io
import json
import os
import shutil
from pathlib import Path
from typing import Any, Dict, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import failpoints
from repro.core.durability import fsync_dir, write_durable
from repro.dist._util import path_names

_STEP_FMT = "step_{:08d}"
_DEFAULT_SHARD_BYTES = 1 << 28  # 256 MB per shard


def _leaf_key(path) -> str:
    return "/".join(path_names(path)) or "."


def _np_dtype(name: str) -> np.dtype:
    try:
        return np.dtype(name)
    except TypeError:
        return np.dtype(getattr(jnp, name))  # bfloat16 et al. via ml_dtypes


def _sha256(path: Path) -> str:
    h = hashlib.sha256()
    with open(path, "rb") as f:
        for chunk in iter(lambda: f.read(1 << 20), b""):
            h.update(chunk)
    return h.hexdigest()


def save_checkpoint(
    root,
    step: int,
    tree: Any,
    *,
    extra: Optional[Dict[str, Any]] = None,
    keep_last: Optional[int] = None,
    shard_bytes: int = _DEFAULT_SHARD_BYTES,
) -> Path:
    """Write `tree` as a sharded checkpoint under `root`; returns the
    committed step directory.  `keep_last=N` prunes older steps."""
    root = Path(root)
    root.mkdir(parents=True, exist_ok=True)
    final = root / _STEP_FMT.format(step)
    # sweep temp dirs orphaned by killed writers (single writer per root:
    # the launcher checkpoints from one host), then claim our own
    for orphan in root.glob(".tmp_step_*"):
        shutil.rmtree(orphan, ignore_errors=True)
    tmp = root / f".tmp_{final.name}_{os.getpid()}"
    tmp.mkdir(parents=True)

    flat = jax.tree_util.tree_flatten_with_path(tree)[0]
    leaves_meta: Dict[str, Dict[str, Any]] = {}
    shards: Dict[str, Dict[str, np.ndarray]] = {}
    cur: Dict[str, np.ndarray] = {}
    cur_bytes = 0

    def flush():
        nonlocal cur, cur_bytes
        if cur:
            shards[f"shard_{len(shards):05d}.npz"] = cur
            cur, cur_bytes = {}, 0

    for path, leaf in flat:
        key = _leaf_key(path)
        arr = np.asarray(leaf)
        blob = np.frombuffer(arr.tobytes(), dtype=np.uint8)
        if cur_bytes and cur_bytes + blob.nbytes > shard_bytes:
            flush()
        shard_name = f"shard_{len(shards):05d}.npz"
        leaves_meta[key] = {"shape": list(arr.shape),
                            "dtype": str(arr.dtype),
                            "shard": shard_name}
        cur[key] = blob
        cur_bytes += blob.nbytes
    flush()
    if not shards:  # empty tree still commits a (checksummable) shard
        shards["shard_00000.npz"] = {}

    checksums = {}
    for name, entries in shards.items():
        buf = io.BytesIO()
        np.savez(buf, **entries)
        data = buf.getvalue()
        write_durable(tmp / name, data)
        # hash the in-memory bytes — re-reading the file would double the
        # checkpoint I/O for the identical digest
        checksums[name] = hashlib.sha256(data).hexdigest()

    meta = {"step": int(step), "extra": extra or {},
            "leaves": leaves_meta, "shard_sha256": checksums}
    write_durable(tmp / "meta.json", json.dumps(meta, indent=1).encode())
    # the directory entries for the shard files must be durable before
    # the rename publishes them under the final name
    fsync_dir(tmp)

    if final.exists():
        shutil.rmtree(final)
    failpoints.fire("checkpoint.replace")
    os.replace(tmp, final)
    fsync_dir(root)

    if keep_last is not None:
        steps = sorted(p for p in root.iterdir()
                       if p.is_dir() and p.name.startswith("step_"))
        for old in steps[:-keep_last]:
            shutil.rmtree(old)
    return final


def latest_checkpoint(root) -> Optional[Path]:
    """Newest committed step directory under `root`, or None."""
    root = Path(root)
    if not root.is_dir():
        return None
    steps = sorted(p for p in root.iterdir()
                   if p.is_dir() and p.name.startswith("step_")
                   and (p / "meta.json").exists())
    return steps[-1] if steps else None


def _load_meta(path: Path) -> Dict[str, Any]:
    meta_path = Path(path) / "meta.json"
    if not meta_path.exists():
        raise ValueError(f"not a checkpoint directory: {path}")
    return json.loads(meta_path.read_text())


def checkpoint_step(path) -> int:
    return int(_load_meta(Path(path))["step"])


def checkpoint_extra(path) -> Dict[str, Any]:
    return _load_meta(Path(path))["extra"]


def restore_checkpoint(path, template: Any) -> Any:
    """Restore a tree with `template`'s structure from a step directory.

    Raises ValueError on checksum mismatch ("corrupt ..."), on leaves
    missing from the checkpoint, and on shape or dtype mismatches against
    the template (a resumed run must never silently reshape or re-cast
    state)."""
    path = Path(path)
    meta = _load_meta(path)

    flat, treedef = jax.tree_util.tree_flatten_with_path(template)
    needed = {}
    for p, leaf in flat:
        needed[_leaf_key(p)] = leaf
    extra = sorted(set(meta["leaves"]) - set(needed))
    if extra:
        # e.g. a --compress-grads checkpoint restored without the flag:
        # dropping the EF residual silently would change the math
        raise ValueError(
            f"checkpoint {path.name} has leaves absent from the restore "
            f"template (would be silently dropped): {extra[:5]}"
            f"{'...' if len(extra) > 5 else ''}")
    shard_names = {meta["leaves"][k]["shard"] for k in needed
                   if k in meta["leaves"]}

    blobs: Dict[str, np.ndarray] = {}
    for name in sorted(shard_names):
        shard_path = path / name
        if not shard_path.exists():
            raise ValueError(f"corrupt checkpoint: missing shard {name}")
        digest = _sha256(shard_path)
        if digest != meta["shard_sha256"].get(name):
            raise ValueError(
                f"corrupt checkpoint shard {name}: sha256 {digest[:12]}... "
                f"does not match manifest")
        with np.load(shard_path) as z:
            for k in z.files:
                blobs[k] = z[k]

    out = []
    for p, leaf in flat:
        key = _leaf_key(p)
        info = meta["leaves"].get(key)
        if info is None:
            raise ValueError(f"checkpoint {path.name} has no leaf {key!r}")
        want = tuple(leaf.shape)
        got = tuple(info["shape"])
        if want != got:
            raise ValueError(
                f"shape mismatch for {key!r}: checkpoint has {got}, "
                f"restore template expects {want}")
        if str(jnp.dtype(leaf.dtype)) != info["dtype"]:
            raise ValueError(
                f"dtype mismatch for {key!r}: checkpoint has "
                f"{info['dtype']}, restore template expects "
                f"{jnp.dtype(leaf.dtype)}")
        arr = np.frombuffer(blobs[key].tobytes(),
                            dtype=_np_dtype(info["dtype"])).reshape(got)
        out.append(jnp.asarray(arr))
    return jax.tree_util.tree_unflatten(treedef, out)
