"""Distributed layer: sharding rules, sharded checkpoints, compressed
collectives, and fleet fault tolerance.

The four modules are deliberately independent of each other so the
launchers can compose them:

* `sharding`    — PartitionSpec rules for params / optimizer (ZeRO-1) /
                  batches / decode caches on the production meshes
                  (16x16 single pod, 2x16x16 multi-pod).
* `checkpoint`  — sharded `.npz` save/restore with per-shard checksums,
                  atomic directory commit, `keep_last` pruning, and an
                  `extra` dict for data-pipeline resume state.
* `collectives` — bucketed psum + int8 error-feedback gradient
                  compression (the paper's lossless-first philosophy on
                  the DP axis: compress on the wire, reconstruct exactly
                  via the carried residual).
* `fault`       — heartbeat files, fleet scan (dead / straggler
                  detection), restart policy (continue / restart_elastic
                  / abort).
"""

from repro.dist import checkpoint, collectives, fault, sharding

__all__ = ["sharding", "checkpoint", "collectives", "fault"]
