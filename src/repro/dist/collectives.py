"""Bucketed collectives and int8 error-feedback gradient compression.

The DP-axis analogue of the paper's lossless-first storage philosophy:
gradients cross the wire int8-quantized (4x fewer bytes than f32), and
the quantization residual is carried in the optimizer state and re-added
to the next step's gradient — so nothing is ever lost, only deferred
(EF-SGD / 1-bit-Adam style error feedback).  The invariant the tests pin:

    dequantized + new_residual == gradient + old_residual   (exactly)

Bucketing: psum'ing thousands of small leaves issues thousands of
collectives; `flatten_buckets` packs same-dtype leaves into ~bucket_bytes
flat buffers so `psum_bucketed` launches O(total_bytes / bucket_bytes)
all-reduces instead of O(n_leaves).
"""

from __future__ import annotations

from typing import Any, List, NamedTuple, Optional, Sequence, Tuple

import jax
import jax.numpy as jnp

DEFAULT_BUCKET_BYTES = 4 << 20  # 4 MB per all-reduce launch


# ---------------------------------------------------------------------------
# int8 quantization + error feedback
# ---------------------------------------------------------------------------


def quantize_int8(x: jnp.ndarray) -> Tuple[jnp.ndarray, jnp.ndarray]:
    """Per-tensor symmetric int8: returns (q int8, scale f32 scalar) with
    x ~= q * scale and |x - q*scale| <= scale/2."""
    xf = x.astype(jnp.float32)
    scale = jnp.maximum(jnp.max(jnp.abs(xf)) / 127.0, 1e-12)
    q = jnp.clip(jnp.round(xf / scale), -127, 127).astype(jnp.int8)
    return q, scale


def dequantize_int8(q: jnp.ndarray, scale: jnp.ndarray,
                    dtype=jnp.float32) -> jnp.ndarray:
    return (q.astype(jnp.float32) * scale).astype(dtype)


def ef_compress_tree(grads: Any, ef: Optional[Any]
                     ) -> Tuple[Any, Any]:
    """Error-feedback int8 round-trip over a gradient tree.

    Each leaf g is compensated (t = g + residual), quantized to int8 —
    the form that would cross the DP axis — dequantized, and the new
    residual t - deq is returned for the caller to carry into the next
    step.  Returns (dequantized_tree, new_residual_tree)."""
    if ef is None:
        ef = jax.tree_util.tree_map(
            lambda g: jnp.zeros(g.shape, jnp.float32), grads)

    def one(g, e):
        t = g.astype(jnp.float32) + e.astype(jnp.float32)
        q, s = quantize_int8(t)
        d = dequantize_int8(q, s)
        return d.astype(g.dtype), (t - d)

    flat_g, treedef = jax.tree_util.tree_flatten(grads)
    flat_e = treedef.flatten_up_to(ef)
    pairs = [one(g, e) for g, e in zip(flat_g, flat_e)]
    deq = jax.tree_util.tree_unflatten(treedef, [p[0] for p in pairs])
    new_ef = jax.tree_util.tree_unflatten(treedef, [p[1] for p in pairs])
    return deq, new_ef


# ---------------------------------------------------------------------------
# Bucketed flatten / psum
# ---------------------------------------------------------------------------


class _BucketEntry(NamedTuple):
    leaf_index: int
    shape: Tuple[int, ...]
    size: int


class BucketSpec(NamedTuple):
    treedef: Any
    n_leaves: int
    entries: Tuple[Tuple[_BucketEntry, ...], ...]  # per bucket


def flatten_buckets(tree: Any, bucket_bytes: int = DEFAULT_BUCKET_BYTES
                    ) -> Tuple[List[jnp.ndarray], BucketSpec]:
    """Pack the tree's leaves into flat same-dtype buffers of at most
    `bucket_bytes` each (a leaf bigger than the budget gets its own
    bucket; leaves are never split).  Returns (buckets, spec)."""
    leaves, treedef = jax.tree_util.tree_flatten(tree)
    by_dtype: dict = {}
    for i, leaf in enumerate(leaves):
        by_dtype.setdefault(jnp.dtype(leaf.dtype), []).append(i)

    buckets: List[jnp.ndarray] = []
    entries: List[Tuple[_BucketEntry, ...]] = []
    for dt in sorted(by_dtype, key=str):
        group: List[_BucketEntry] = []
        group_bytes = 0

        def flush():
            nonlocal group, group_bytes
            if group:
                buckets.append(jnp.concatenate(
                    [leaves[e.leaf_index].reshape(-1) for e in group]))
                entries.append(tuple(group))
                group, group_bytes = [], 0

        for i in by_dtype[dt]:
            leaf = leaves[i]
            nbytes = leaf.size * jnp.dtype(leaf.dtype).itemsize
            if group_bytes and group_bytes + nbytes > bucket_bytes:
                flush()
            group.append(_BucketEntry(i, tuple(leaf.shape), int(leaf.size)))
            group_bytes += nbytes
        flush()
    return buckets, BucketSpec(treedef, len(leaves), tuple(entries))


def unflatten_buckets(buckets: Sequence[jnp.ndarray], spec: BucketSpec) -> Any:
    """Inverse of flatten_buckets (dtype- and shape-exact)."""
    leaves: List[Optional[jnp.ndarray]] = [None] * spec.n_leaves
    for buf, group in zip(buckets, spec.entries):
        off = 0
        for e in group:
            leaves[e.leaf_index] = buf[off:off + e.size].reshape(e.shape)
            off += e.size
    return jax.tree_util.tree_unflatten(spec.treedef, leaves)


def psum_bucketed(tree: Any, axis_name: str,
                  bucket_bytes: int = DEFAULT_BUCKET_BYTES) -> Any:
    """`lax.psum` over the tree via flat buckets — one collective per
    bucket instead of one per leaf.  Use inside shard_map/pmap."""
    buckets, spec = flatten_buckets(tree, bucket_bytes)
    summed = [jax.lax.psum(b, axis_name) for b in buckets]
    return unflatten_buckets(summed, spec)
