"""Findings baseline: grandfathered hits that don't fail the gate.

The baseline exists so the analyzer can be adopted mid-stream on a tree
with known findings and ratchet them down — new findings always fail,
baselined ones report as suppressed.  This repo's committed baseline is
**empty** (every true finding was fixed, every false positive carries
an inline waiver with a reason); the mechanism stays because the next
rule added will likely land with grandfathered hits.

Matching is by (rule, path, message) — line numbers shift under
unrelated edits and would make the baseline churn-prone.
"""

from __future__ import annotations

import json
from typing import List, Sequence, Tuple

from repro.analysis.core import Finding

VERSION = 1


def save(path: str, findings: Sequence[Finding]) -> None:
    doc = {
        "version": VERSION,
        "findings": [
            {"rule": f.rule, "path": f.path, "message": f.message}
            for f in sorted(findings, key=Finding.identity)
        ],
    }
    with open(path, "w") as fh:
        json.dump(doc, fh, indent=1, sort_keys=True)
        fh.write("\n")


def load(path: str) -> List[Tuple[str, str, str]]:
    with open(path) as fh:
        doc = json.load(fh)
    if doc.get("version") != VERSION:
        raise ValueError(
            f"unsupported baseline version {doc.get('version')!r} in {path}")
    return [(e["rule"], e["path"], e["message"])
            for e in doc.get("findings", [])]


def split(findings: Sequence[Finding],
          baseline: Sequence[Tuple[str, str, str]]
          ) -> Tuple[List[Finding], List[Finding]]:
    """(new, suppressed): a baseline entry absorbs at most one finding
    per occurrence count — a *second* identical hit is new."""
    budget = {}
    for ident in baseline:
        budget[ident] = budget.get(ident, 0) + 1
    new: List[Finding] = []
    suppressed: List[Finding] = []
    for f in findings:
        ident = f.identity()
        if budget.get(ident, 0) > 0:
            budget[ident] -= 1
            suppressed.append(f)
        else:
            new.append(f)
    return new, suppressed
