"""repro.analysis — AST-based invariant checker for this repo.

Eight rules, each enforcing an invariant the code's correctness
argument already depends on (see ARCHITECTURE.md "Static analysis &
invariants"):

| id       | invariant                                                |
|----------|----------------------------------------------------------|
| REPRO001 | lock acquisition follows the documented rank order       |
| REPRO002 | os.replace publishes fsync the file before, the dir after|
| REPRO003 | frozen wire-format functions match pinned AST hashes     |
| REPRO004 | Pallas kernel fns stay pure (no host state / shapes)     |
| REPRO005 | REPRO_* env reads go through repro.core.env              |
| REPRO006 | codec-pool tasks never submit back into the pool         |
| REPRO007 | obs metrics go through repro.obs helpers, names coherent |
| REPRO008 | failpoints.fire() uses literal names declared in SITES   |

Run as ``python -m repro.analysis src/`` (or ``make analyze``).  Waive
a single false positive inline with ``# repro-analysis:
disable=REPRO00N <reason>`` on or above the flagged line.
"""

from repro.analysis.core import (Finding, ParsedFile, Rule, all_rules,
                                 parse_source, register, run_rules)

__all__ = ["Finding", "ParsedFile", "Rule", "all_rules", "parse_source",
           "register", "run_rules"]
