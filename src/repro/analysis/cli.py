"""Command line front end: ``python -m repro.analysis [paths...]``.

Exit codes: 0 clean (or all findings baselined/waived), 1 findings,
2 usage/internal error.  ``--format json`` emits a machine-readable
report for CI; ``--write-baseline`` snapshots current findings to adopt
the analyzer on a dirty tree; ``--repin-frozen`` updates the
frozen-format manifest (refusing unless golden tests changed too).
"""

from __future__ import annotations

import argparse
import json
import os
import sys
from typing import List, Optional

from repro.analysis import baseline as baseline_mod
from repro.analysis import rules_frozen
from repro.analysis.core import (META_RULE, Finding, ParsedFile, all_rules,
                                 parse_source, run_rules)


def _find_repo_root(start: str) -> str:
    cur = os.path.abspath(start)
    while True:
        if os.path.isdir(os.path.join(cur, ".git")) \
                or os.path.isfile(os.path.join(cur, "ROADMAP.md")):
            return cur
        parent = os.path.dirname(cur)
        if parent == cur:
            return os.path.abspath(start)
        cur = parent


def collect_files(paths: List[str], root: str) -> List[ParsedFile]:
    """Parse every .py under `paths`; syntax errors become REPRO000
    findings carried on a pseudo-file (path, no tree) — surfaced by
    run()."""
    files: List[ParsedFile] = []
    errors: List[Finding] = []
    seen = set()
    for path in paths:
        if os.path.isfile(path):
            candidates = [path]
        else:
            candidates = []
            for dirpath, dirnames, filenames in os.walk(path):
                dirnames[:] = [d for d in dirnames
                               if d not in ("__pycache__", ".git")]
                candidates += [os.path.join(dirpath, n)
                               for n in sorted(filenames)
                               if n.endswith(".py")]
        for cand in candidates:
            rel = os.path.relpath(os.path.abspath(cand), root)
            rel = rel.replace(os.sep, "/")
            if rel in seen:
                continue
            seen.add(rel)
            try:
                with open(cand, encoding="utf-8") as fh:
                    source = fh.read()
                files.append(parse_source(rel, source))
            except SyntaxError as exc:
                errors.append(Finding(
                    META_RULE, rel, exc.lineno or 0,
                    f"does not parse: {exc.msg}"))
            except OSError as exc:
                errors.append(Finding(
                    META_RULE, rel, 0, f"unreadable: {exc}"))
    files.sort(key=lambda f: f.path)
    collect_files.errors = errors  # type: ignore[attr-defined]
    return files


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.analysis",
        description="repro invariant checker (lock order, durability, "
                    "frozen formats, kernel hygiene, env registry, "
                    "pool re-entrancy)")
    parser.add_argument("paths", nargs="*", default=None,
                        help="files/directories to scan (default: src)")
    parser.add_argument("--baseline", metavar="FILE",
                        help="findings baseline; baselined hits report "
                             "as suppressed and do not fail")
    parser.add_argument("--write-baseline", metavar="FILE",
                        help="write current findings as the new baseline "
                             "and exit 0")
    parser.add_argument("--format", choices=("text", "json"),
                        default="text")
    parser.add_argument("--rules", metavar="IDS",
                        help="comma-separated rule ids to run "
                             "(default: all)")
    parser.add_argument("--list-rules", action="store_true",
                        help="print the rule catalog and exit")
    parser.add_argument("--repin-frozen", action="store_true",
                        help="update frozen-format AST-hash pins "
                             "(requires changed golden tests)")
    args = parser.parse_args(argv)

    if args.list_rules:
        for rid, cls in sorted(all_rules().items()):
            print(f"{rid}  {cls.title}")
        return 0

    paths = args.paths or ["src"]
    for p in paths:
        if not os.path.exists(p):
            print(f"error: no such path: {p}", file=sys.stderr)
            return 2
    root = _find_repo_root(paths[0])
    files = collect_files(paths, root)
    parse_errors = collect_files.errors  # type: ignore[attr-defined]

    if args.repin_frozen:
        try:
            print(rules_frozen.repin(files, root))
            return 0
        except RuntimeError as exc:
            print(f"error: {exc}", file=sys.stderr)
            return 2

    only = [r.strip() for r in args.rules.split(",")] if args.rules else None
    try:
        findings = parse_errors + run_rules(files, only)
    except KeyError as exc:
        print(f"error: {exc.args[0]}", file=sys.stderr)
        return 2

    if args.write_baseline:
        baseline_mod.save(args.write_baseline, findings)
        print(f"wrote {len(findings)} finding(s) to {args.write_baseline}")
        return 0

    suppressed: List[Finding] = []
    if args.baseline:
        try:
            known = baseline_mod.load(args.baseline)
        except (OSError, ValueError) as exc:
            print(f"error: cannot load baseline: {exc}", file=sys.stderr)
            return 2
        findings, suppressed = baseline_mod.split(findings, known)

    if args.format == "json":
        print(json.dumps({
            "findings": [
                {"rule": f.rule, "path": f.path, "line": f.line,
                 "message": f.message} for f in findings],
            "suppressed": len(suppressed),
        }, indent=1, sort_keys=True))
    else:
        for f in findings:
            print(f.format())
        tail = f"{len(findings)} finding(s)"
        if suppressed:
            tail += f", {len(suppressed)} baselined"
        print(tail if findings or suppressed else "clean")
    return 1 if findings else 0
