"""REPRO006 — no re-entrant submission into the shared codec pool.

The codec thread pool (repro.core.codec) is bounded and shared; its
deadlock-freedom argument is one sentence: *leaf tasks never submit
back into the pool*.  If a function that runs AS a pool task (directly
or transitively) calls back into the pool's submission gateway, all
workers can end up blocked waiting for tasks that can only run on those
same workers.

Statically: a **sink** is a function that both obtains the shared pool
(calls ``_codec_pool``) and dispatches work into an executor
(``.submit``/``.map`` attribute call) — in this tree that is
``_parallel_map``.  A **root** is any callable passed as a task to a
sink's call site (lambda or function name in the first argument).  The
rule builds a name-based call graph — augmented with module-level
registry dicts whose values reference functions, so dispatch like
``BACKENDS[backend][0](...)`` keeps edges — and flags any root from
which a sink is reachable, reporting the call chain.
"""

from __future__ import annotations

import ast
from collections import deque
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding, ParsedFile, Rule, register

RULE_ID = "REPRO006"


def _call_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _function_calls(fn) -> Set[str]:
    """Simple names of everything `fn` calls (or whose value it takes —
    a function passed onward may be called by the receiver)."""
    out: Set[str] = set()
    for node in ast.walk(fn):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name:
                out.add(name)
            for arg in list(node.args) + [kw.value for kw in node.keywords]:
                if isinstance(arg, ast.Name):
                    out.add(arg.id)
    return out


def _lambda_calls(lam: ast.Lambda) -> Set[str]:
    out: Set[str] = set()
    for node in ast.walk(lam):
        if isinstance(node, ast.Call):
            name = _call_name(node)
            if name:
                out.add(name)
    return out


@register
class PoolReentrancyRule(Rule):
    id = RULE_ID
    title = "codec-pool tasks never submit back into the pool"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        # pass 1: function defs, their call sets, and registry-dict edges
        defs: Dict[str, List[Tuple[str, ast.AST]]] = {}
        calls_of: Dict[str, Set[str]] = {}
        registry_members: Dict[str, Set[str]] = {}  # dict name -> fn names
        for f in files:
            for stmt in f.tree.body:
                if isinstance(stmt, ast.Assign) \
                        and isinstance(stmt.value, ast.Dict):
                    names = {n.id for v in stmt.value.values
                             for n in ast.walk(v)
                             if isinstance(n, ast.Name)}
                    for t in stmt.targets:
                        if isinstance(t, ast.Name) and names:
                            registry_members.setdefault(
                                t.id, set()).update(names)
            for node in ast.walk(f.tree):
                if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                    defs.setdefault(node.name, []).append((f.path, node))
                    merged = calls_of.setdefault(node.name, set())
                    merged.update(_function_calls(node))
                    # dispatch through a registry dict reaches all members
                    for sub in ast.walk(node):
                        if isinstance(sub, ast.Subscript) \
                                and isinstance(sub.value, ast.Name) \
                                and sub.value.id in registry_members:
                            merged.update(registry_members[sub.value.id])

        # pass 2: sinks — functions that hold the shared pool AND dispatch
        sinks: Set[str] = set()
        for name, sites in defs.items():
            for _, fn in sites:
                gets_pool = False
                dispatches = False
                for node in ast.walk(fn):
                    if isinstance(node, ast.Call):
                        cname = _call_name(node)
                        if cname == "_codec_pool":
                            gets_pool = True
                        elif cname in ("submit", "map") \
                                and isinstance(node.func, ast.Attribute):
                            dispatches = True
                if gets_pool and dispatches:
                    sinks.add(name)
        if not sinks:
            return []

        # reachability: can `name` reach a sink through the call graph?
        reach_cache: Dict[str, Optional[List[str]]] = {}

        def chain_to_sink(start_calls: Set[str]) -> Optional[List[str]]:
            seen: Set[str] = set()
            queue = deque([(c, [c]) for c in sorted(start_calls)])
            while queue:
                name, chain = queue.popleft()
                if name in sinks:
                    return chain
                if name in seen or name not in calls_of:
                    continue
                seen.add(name)
                for nxt in sorted(calls_of[name]):
                    queue.append((nxt, chain + [nxt]))
            return None

        # pass 3: roots — callables handed to sink call sites as tasks
        findings: List[Finding] = []
        for f in files:
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call) \
                        or _call_name(node) not in sinks:
                    continue
                if not node.args:
                    continue
                task = node.args[0]
                if isinstance(task, ast.Lambda):
                    start = _lambda_calls(task)
                    label = "lambda"
                elif isinstance(task, ast.Name):
                    start = {task.id}
                    label = task.id
                else:
                    continue
                chain = chain_to_sink(start)
                if chain is not None:
                    findings.append(Finding(
                        RULE_ID, f.path, task.lineno,
                        f"task '{label}' submitted to the shared codec "
                        f"pool can re-enter it via "
                        f"{' -> '.join(chain)}; pool tasks must stay "
                        f"leaves (bounded-worker deadlock)"))
        return findings
