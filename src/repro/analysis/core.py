"""Engine of the invariant checker: parsing, waivers, rule registry.

The analyzer is deliberately stdlib-only (``ast`` + ``json``): it runs
in every environment the tests run in, including the CI container,
with zero install steps.  Design points:

* **Rules see the whole tree.**  A rule's ``run`` receives the full
  list of parsed files, not one file at a time — the lock-order graph
  (REPRO001) and the pool re-entrancy call graph (REPRO006) are
  cross-module properties and can't be checked file-locally.
* **Waivers are lexical and carry a reason.**  ``# repro-analysis:
  disable=REPRO001 <why>`` on the finding's line (or the line above)
  suppresses that rule there; a waiver without a reason is itself a
  finding (REPRO000) so suppressions stay auditable.
* **Findings are stable identities.**  A finding is (rule, path,
  message); the baseline matcher ignores line numbers so unrelated
  edits above a grandfathered hit don't resurrect it.
"""

from __future__ import annotations

import ast
import re
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional, Sequence

#: waiver comment shape: ``repro-analysis: disable=<RULE>[,<RULE>] <reason>``
_WAIVER_RE = re.compile(
    r"#\s*repro-analysis:\s*disable=([A-Z0-9,]+)(?:\s+(\S.*))?")

META_RULE = "REPRO000"  # analyzer self-diagnostics (parse errors, bad waivers)


@dataclass(frozen=True)
class Finding:
    rule: str
    path: str        # repo-relative, forward slashes
    line: int        # 1-based; 0 = whole-file
    message: str

    def format(self) -> str:
        return f"{self.path}:{self.line}: {self.rule}: {self.message}"

    def identity(self) -> tuple:
        """Line-insensitive identity used for baseline matching."""
        return (self.rule, self.path, self.message)


@dataclass
class Waiver:
    line: int
    rules: List[str]
    reason: Optional[str]


@dataclass
class ParsedFile:
    path: str                      # repo-relative, forward slashes
    source: str
    tree: ast.Module
    waivers: List[Waiver] = field(default_factory=list)

    def waived(self, rule: str, line: int) -> bool:
        """True if `rule` is waived at `line` (same line or line above)."""
        for w in self.waivers:
            if rule in w.rules and w.line in (line, line - 1):
                return True
        return False


def parse_source(path: str, source: str) -> ParsedFile:
    """Parse one file; raises SyntaxError (callers convert to REPRO000)."""
    tree = ast.parse(source, filename=path)
    waivers = []
    for lineno, line in enumerate(source.splitlines(), start=1):
        m = _WAIVER_RE.search(line)
        if m:
            rules = [r for r in m.group(1).split(",") if r]
            waivers.append(Waiver(lineno, rules, m.group(2)))
    return ParsedFile(path=path, source=source, tree=tree, waivers=waivers)


class Rule:
    """Base class; subclasses set ``id``/``title`` and override ``run``."""

    id: str = ""
    title: str = ""

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        raise NotImplementedError


_RULES: Dict[str, Callable[[], Rule]] = {}


def register(cls):
    """Class decorator adding a rule to the registry (import-time)."""
    if not cls.id:
        raise ValueError(f"rule {cls.__name__} has no id")
    if cls.id in _RULES:
        raise ValueError(f"duplicate rule id {cls.id}")
    _RULES[cls.id] = cls
    return cls


def all_rules() -> Dict[str, Callable[[], Rule]]:
    # rule modules register on import; pull them in here so the registry
    # is complete no matter which entry point asked
    from repro.analysis import (rules_durability, rules_env,  # noqa: F401
                                rules_faults, rules_frozen, rules_kernels,
                                rules_locks, rules_obs, rules_pool)
    return dict(_RULES)


def run_rules(files: Sequence[ParsedFile],
              only: Optional[Sequence[str]] = None) -> List[Finding]:
    """Run (a subset of) the registry; returns non-waived findings plus
    REPRO000 diagnostics for malformed waivers."""
    findings: List[Finding] = []
    for f in files:
        for w in f.waivers:
            if w.reason is None:
                findings.append(Finding(
                    META_RULE, f.path, w.line,
                    "waiver without a reason; write '# repro-analysis: "
                    "disable=REPROxxx <one-line justification>'"))
    rules = all_rules()
    wanted = list(only) if only else sorted(rules)
    for rid in wanted:
        if rid not in rules:
            raise KeyError(f"unknown rule {rid!r}; known: {sorted(rules)}")
        rule = rules[rid]()
        by_path = {f.path: f for f in files}
        for finding in rule.run(files):
            pf = by_path.get(finding.path)
            if pf is not None and pf.waived(finding.rule, finding.line):
                continue
            findings.append(finding)
    findings.sort(key=lambda x: (x.path, x.line, x.rule, x.message))
    return findings
