"""REPRO002 — tmp-then-rename publishes must fsync file and directory.

For every function containing an ``os.replace(...)`` (the atomic-publish
commit point), two things must be lexically present in the same
function:

* a *file* fsync **before** the replace — ``os.fsync(...)`` or one of
  the ``repro.core.durability`` helpers (``fsync_file`` /
  ``write_durable``), so the payload bytes are on the platter before
  the name points at them;
* a *directory* fsync **after** it — ``fsync_dir(...)``, so the rename
  itself survives power loss (an unsynced directory can forget the
  rename and resurrect the old bytes).

Both findings anchor at the ``os.replace`` line, so one waiver line
covers a deliberately non-durable publisher (heartbeats).  The check is
function-local by design: the durability helpers exist precisely so the
whole write→fsync→replace→fsync-dir sequence is visible at the publish
site (see repro.core.durability), and a publish whose fsync lives in a
different function defeats that reviewability even when correct.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from repro.analysis.core import Finding, ParsedFile, Rule, register

RULE_ID = "REPRO002"

_FILE_FSYNC = frozenset({"fsync_file", "write_durable"})
_DIR_FSYNC = frozenset({"fsync_dir"})


def _call_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _is_os_call(call: ast.Call, attr: str) -> bool:
    fn = call.func
    return (isinstance(fn, ast.Attribute) and fn.attr == attr
            and isinstance(fn.value, ast.Name) and fn.value.id == "os")


@register
class DurabilityRule(Rule):
    id = RULE_ID
    title = "os.replace publishes fsync the file before and the dir after"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        findings: List[Finding] = []
        for f in files:
            for fn in (n for n in ast.walk(f.tree)
                       if isinstance(n, (ast.FunctionDef,
                                         ast.AsyncFunctionDef))):
                findings.extend(self._check_function(f, fn))
        return findings

    def _check_function(self, f: ParsedFile, fn) -> List[Finding]:
        replaces: List[ast.Call] = []
        file_syncs: List[int] = []
        dir_syncs: List[int] = []
        for node in ast.walk(fn):
            if not isinstance(node, ast.Call):
                continue
            name = _call_name(node)
            if _is_os_call(node, "replace"):
                replaces.append(node)
            elif _is_os_call(node, "fsync") or name in _FILE_FSYNC:
                file_syncs.append(node.lineno)
            elif name in _DIR_FSYNC:
                dir_syncs.append(node.lineno)
        findings: List[Finding] = []
        for rep in replaces:
            if not any(line <= rep.lineno for line in file_syncs):
                findings.append(Finding(
                    RULE_ID, f.path, rep.lineno,
                    f"os.replace in '{fn.name}' without a preceding file "
                    f"fsync (os.fsync / fsync_file / write_durable): the "
                    f"rename can land before the data"))
            if not any(line >= rep.lineno for line in dir_syncs):
                findings.append(Finding(
                    RULE_ID, f.path, rep.lineno,
                    f"os.replace in '{fn.name}' without a following "
                    f"fsync_dir: the rename itself is not durable"))
        return findings
