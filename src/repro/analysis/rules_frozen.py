"""REPRO003 — frozen wire-format functions must not drift silently.

Three codec backends (scalar, NumPy, Pallas) must emit byte-identical
streams; what freezes the format is a small set of host functions (the
shared LZ77 emit, the rANS stream layout, the frame header, the packing
formats).  This rule pins a *normalized AST hash* of each one in
``frozen_format.json``: docstrings stripped, positions dropped, so
comment/formatting churn never trips it, while any semantic edit does.

A hash mismatch is a finding.  The sanctioned way to change a frozen
function is ``python -m repro.analysis --repin-frozen``, which refuses
to update the pins unless at least one of the manifest's *golden test
files* changed too — byte-format changes must land with the golden
tests that prove old blobs still decode (or a deliberate format bump).

``REPRO_ANALYSIS_FROZEN_MANIFEST`` overrides the manifest path so tests
can exercise the rule against fixtures.
"""

from __future__ import annotations

import ast
import hashlib
import json
import os
from typing import Dict, List, Optional, Sequence

from repro.analysis.core import Finding, ParsedFile, Rule, register
from repro.core import env

RULE_ID = "REPRO003"

DEFAULT_MANIFEST = os.path.join(os.path.dirname(__file__),
                                "frozen_format.json")


def manifest_path() -> str:
    return env.read("REPRO_ANALYSIS_FROZEN_MANIFEST") or DEFAULT_MANIFEST


def load_manifest(path: Optional[str] = None) -> dict:
    with open(path or manifest_path()) as fh:
        return json.load(fh)


def normalized_hash(fn_node) -> str:
    """sha256 of the def's AST with positions and the docstring removed."""
    node = ast.parse(ast.unparse(fn_node)).body[0]  # re-parse: fresh copy
    body = node.body
    if body and isinstance(body[0], ast.Expr) \
            and isinstance(body[0].value, ast.Constant) \
            and isinstance(body[0].value.value, str):
        node.body = body[1:] or [ast.Pass()]
    return hashlib.sha256(
        ast.dump(node, include_attributes=False).encode()).hexdigest()


def find_function(tree: ast.Module, qualname: str):
    """Locate ``fn`` or ``Class.method`` at module top level."""
    parts = qualname.split(".")
    scope = tree.body
    node = None
    for i, part in enumerate(parts):
        node = next(
            (n for n in scope
             if isinstance(n, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)) and n.name == part), None)
        if node is None:
            return None
        if i < len(parts) - 1:
            if not isinstance(node, ast.ClassDef):
                return None
            scope = node.body
    if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
        return node
    return None


def file_sha256(path: str) -> str:
    with open(path, "rb") as fh:
        return hashlib.sha256(fh.read()).hexdigest()


def compute_pins(files: Sequence[ParsedFile],
                 manifest: dict) -> Dict[str, Optional[str]]:
    """{spec: current hash or None if the function is missing} for every
    manifest entry whose file is in the scanned set."""
    by_suffix = {f.path: f for f in files}
    out: Dict[str, Optional[str]] = {}
    for spec in manifest.get("functions", {}):
        rel, qualname = spec.split("::", 1)
        pf = None
        for path, cand in by_suffix.items():
            if path == rel or path.endswith("/" + rel):
                pf = cand
                break
        if pf is None:
            continue  # file not in this scan's scope
        fn = find_function(pf.tree, qualname)
        out[spec] = None if fn is None else normalized_hash(fn)
    return out


@register
class FrozenFormatRule(Rule):
    id = RULE_ID
    title = "frozen wire-format functions match their pinned AST hashes"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        path = manifest_path()
        try:
            manifest = load_manifest(path)
        except FileNotFoundError:
            return [Finding(RULE_ID, path, 0,
                            "frozen-format manifest missing")]
        findings: List[Finding] = []
        pinned = manifest.get("functions", {})
        for spec, current in sorted(compute_pins(files, manifest).items()):
            rel, qualname = spec.split("::", 1)
            pf = next(f for f in files
                      if f.path == rel or f.path.endswith("/" + rel))
            if current is None:
                findings.append(Finding(
                    RULE_ID, pf.path, 0,
                    f"frozen function '{qualname}' is pinned in the "
                    f"manifest but no longer exists"))
                continue
            if current != pinned[spec]:
                fn = find_function(pf.tree, qualname)
                findings.append(Finding(
                    RULE_ID, pf.path, fn.lineno,
                    f"frozen wire-format function '{qualname}' changed "
                    f"(AST hash {current[:12]} != pinned "
                    f"{pinned[spec][:12]}); re-pin with --repin-frozen "
                    f"alongside updated golden tests"))
        return findings


def repin(files: Sequence[ParsedFile], repo_root: str,
          path: Optional[str] = None) -> str:
    """Rewrite the manifest pins; refuses when function hashes changed
    but every golden test file is byte-identical to its recorded hash.
    Returns a human-readable summary."""
    path = path or manifest_path()
    manifest = load_manifest(path)
    pins = compute_pins(files, manifest)
    changed = [s for s, h in pins.items()
               if h is not None and h != manifest["functions"].get(s)]
    missing = [s for s, h in pins.items() if h is None]
    if missing:
        raise RuntimeError(
            f"cannot re-pin: frozen functions missing: {missing}")
    goldens = manifest.get("golden_tests", {})
    if changed and goldens:
        stale = []
        for rel, sha in goldens.items():
            full = os.path.join(repo_root, rel)
            if not os.path.exists(full) or file_sha256(full) == sha:
                stale.append(rel)
        if len(stale) == len(goldens):
            raise RuntimeError(
                "refusing to re-pin: frozen wire-format functions changed "
                f"({changed}) but none of the golden test files "
                f"({sorted(goldens)}) changed; update the golden tests in "
                "the same diff to prove old blobs still decode")
    for spec, h in pins.items():
        manifest["functions"][spec] = h
    for rel in goldens:
        full = os.path.join(repo_root, rel)
        if os.path.exists(full):
            goldens[rel] = file_sha256(full)
    with open(path, "w") as fh:
        json.dump(manifest, fh, indent=1, sort_keys=True)
        fh.write("\n")
    return (f"re-pinned {len(changed)} changed of {len(pins)} frozen "
            f"functions in {path}")
