"""REPRO007 — metric-name hygiene for the repro.obs layer.

Three checks keep the metric inventory coherent:

* **No direct instrument construction outside ``repro.obs``.**  Call
  sites must go through the ``obs.counter``/``obs.histogram``/
  ``obs.owned_counter``/``obs.span`` helpers (which resolve the
  REPRO_OBS gate and register into the default registry); constructing
  ``Counter``/``Gauge``/``Histogram``/``Journal``/``Registry``/``Span``
  imported from ``repro.obs.metrics``/``repro.obs.trace`` elsewhere
  creates unregistered instruments that never reach a snapshot.
* **One name, one kind.**  The same literal metric name used with
  conflicting instrument kinds (``obs.counter("x")`` in one module,
  ``obs.histogram("x")`` in another) would raise at runtime only when
  both sites happen to run in one process; statically it is always a
  bug.  A span ``obs.span("x")`` owns the histogram name ``x.s``.
* **No raw ``time.perf_counter`` timing in ``service/`` paths.**  The
  service tier reports latency through ``obs.span`` (journal + duration
  histogram in one call); a bare perf_counter pair is dark telemetry.
  Waiverable as usual for timing that is genuinely not a metric.
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding, ParsedFile, Rule, register

RULE_ID = "REPRO007"

#: classes whose construction belongs inside repro/obs/
_INSTRUMENT_CLASSES = frozenset(
    {"Counter", "Gauge", "Histogram", "Registry", "Journal", "Span"})

#: obs helper -> the instrument kind its literal name argument claims
_HELPER_KINDS = {
    "counter": "counter",
    "owned_counter": "counter",
    "gauge": "gauge",
    "derived_gauge": "gauge",
    "owned_gauge": "gauge",
    "histogram": "histogram",
    "span": "span",
}


def _is_obs_file(path: str) -> bool:
    return "repro/obs/" in path or path.endswith("repro/obs")


def _obs_imports(tree: ast.Module) -> Set[str]:
    """Names bound by ``from repro.obs[.metrics|.trace] import ...``."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) and node.module \
                and node.module.startswith("repro.obs"):
            for alias in node.names:
                names.add(alias.asname or alias.name)
    return names


def _helper_call(call: ast.Call) -> Optional[str]:
    """The obs helper name if `call` is ``obs.<helper>(...)`` or a
    bare ``<helper>(...)`` (from-import style), else None."""
    fn = call.func
    if isinstance(fn, ast.Attribute) and fn.attr in _HELPER_KINDS \
            and isinstance(fn.value, ast.Name) and fn.value.id == "obs":
        return fn.attr
    if isinstance(fn, ast.Name) and fn.id in _HELPER_KINDS \
            and fn.id in ("owned_counter", "owned_gauge", "derived_gauge"):
        # bare short names (counter/span/...) are too collision-prone to
        # claim without the obs. prefix; the owned_*/derived_* spellings
        # are unambiguous
        return fn.id
    return None


@register
class MetricHygieneRule(Rule):
    id = RULE_ID
    title = "obs metrics go through repro.obs helpers with consistent names"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        findings: List[Finding] = []
        # metric name -> (kind, first path, first line)
        seen: Dict[str, Tuple[str, str, int]] = {}

        for f in files:
            obs_names = _obs_imports(f.tree) if not _is_obs_file(f.path) \
                else set()
            in_service = "/service/" in f.path or f.path.startswith("service/")
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call):
                    continue
                self._check_direct_construction(
                    f, node, obs_names, findings)
                self._check_name_kinds(f, node, seen, findings)
                if in_service:
                    self._check_perf_counter(f, node, findings)
        return findings

    def _check_direct_construction(self, f: ParsedFile, call: ast.Call,
                                   obs_names: Set[str],
                                   findings: List[Finding]) -> None:
        if _is_obs_file(f.path):
            return
        fn = call.func
        cls: Optional[str] = None
        if isinstance(fn, ast.Name) and fn.id in _INSTRUMENT_CLASSES \
                and fn.id in obs_names:
            cls = fn.id
        elif isinstance(fn, ast.Attribute) and fn.attr in _INSTRUMENT_CLASSES \
                and isinstance(fn.value, ast.Name) \
                and fn.value.id in ("metrics", "trace") \
                and fn.value.id in obs_names:
            cls = fn.attr
        if cls is not None:
            findings.append(Finding(
                RULE_ID, f.path, call.lineno,
                f"direct {cls} construction outside repro.obs; use the "
                f"obs.counter/gauge/histogram/span/owned_* helpers so the "
                f"instrument is registered and REPRO_OBS-gated"))

    def _check_name_kinds(self, f: ParsedFile, call: ast.Call,
                          seen: Dict[str, Tuple[str, str, int]],
                          findings: List[Finding]) -> None:
        helper = _helper_call(call)
        if helper is None or not call.args:
            return
        arg = call.args[0]
        if not (isinstance(arg, ast.Constant) and isinstance(arg.value, str)):
            return
        kind = _HELPER_KINDS[helper]
        # a span owns its duration histogram's name
        name = arg.value + ".s" if kind == "span" else arg.value
        kind = "histogram" if kind == "span" else kind
        prior = seen.get(name)
        if prior is None:
            seen[name] = (kind, f.path, call.lineno)
        elif prior[0] != kind:
            findings.append(Finding(
                RULE_ID, f.path, call.lineno,
                f"metric name {name!r} used as {kind} here but as "
                f"{prior[0]} at {prior[1]}:{prior[2]}; one name, one kind"))

    def _check_perf_counter(self, f: ParsedFile, call: ast.Call,
                            findings: List[Finding]) -> None:
        fn = call.func
        raw = (isinstance(fn, ast.Attribute) and fn.attr == "perf_counter"
               and isinstance(fn.value, ast.Name) and fn.value.id == "time") \
            or (isinstance(fn, ast.Name) and fn.id == "perf_counter")
        if raw:
            findings.append(Finding(
                RULE_ID, f.path, call.lineno,
                "raw time.perf_counter timing in a service/ path bypasses "
                "obs.span (no histogram, no journal event); wrap the block "
                "in obs.span or waive with a reason"))
