"""REPRO005 — every ``REPRO_*`` env read goes through the registry.

``repro.core.env`` declares each knob once with a typed parser and a
default; an ad-hoc ``os.environ.get("REPRO_...")`` elsewhere silently
forks the parsing/fallback contract (exactly how the pre-registry tree
ended up with three different garbage-handling behaviors).  Flagged
outside ``core/env.py``:

* ``os.environ.get`` / ``os.getenv`` / ``os.environ[...]`` reads whose
  key is a literal starting with ``REPRO_``;
* the same reads with a *non-literal* key — dynamic keys are how
  generic helpers smuggle untracked knobs in (the registry's ``read``
  is the sanctioned dynamic accessor).

Writes (``os.environ[...] = ...``, used by launch scripts for XLA
flags) and non-REPRO literals are out of scope.
"""

from __future__ import annotations

import ast
from typing import List, Optional, Sequence

from repro.analysis.core import Finding, ParsedFile, Rule, register

RULE_ID = "REPRO005"


def _env_read_key(node: ast.AST) -> Optional[object]:
    """Returns the key expression of an environ read, else None.

    Recognizes ``os.environ.get(k, ...)``, ``os.getenv(k, ...)`` and
    ``os.environ[k]`` in Load context.
    """
    if isinstance(node, ast.Call):
        fn = node.func
        if isinstance(fn, ast.Attribute) and fn.attr == "get" \
                and _is_os_environ(fn.value) and node.args:
            return node.args[0]
        if isinstance(fn, ast.Attribute) and fn.attr == "getenv" \
                and isinstance(fn.value, ast.Name) \
                and fn.value.id == "os" and node.args:
            return node.args[0]
    if isinstance(node, ast.Subscript) and _is_os_environ(node.value) \
            and isinstance(node.ctx, ast.Load):
        return node.slice
    return None


def _is_os_environ(node: ast.AST) -> bool:
    return (isinstance(node, ast.Attribute) and node.attr == "environ"
            and isinstance(node.value, ast.Name) and node.value.id == "os")


@register
class EnvRegistryRule(Rule):
    id = RULE_ID
    title = "REPRO_* env vars are read only via repro.core.env"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        findings: List[Finding] = []
        for f in files:
            if f.path.endswith("core/env.py"):
                continue
            for node in ast.walk(f.tree):
                key = _env_read_key(node)
                if key is None:
                    continue
                if isinstance(key, ast.Constant) \
                        and isinstance(key.value, str):
                    if key.value.startswith("REPRO_"):
                        findings.append(Finding(
                            RULE_ID, f.path, node.lineno,
                            f"raw environ read of {key.value!r}; use "
                            f"repro.core.env.read (declared parser + "
                            f"default)"))
                else:
                    findings.append(Finding(
                        RULE_ID, f.path, node.lineno,
                        "environ read with a dynamic key; route it "
                        "through repro.core.env.read so the knob is "
                        "declared"))
        return findings
