"""REPRO008 — failpoint-site hygiene for repro.core.failpoints.

The fault-injection layer is only deterministic if the site catalog and
the ``fire()`` call sites agree: a spec like
``REPRO_FAULTS="store.replace=nth:2,crash"`` silently injects nothing
if the name drifted from the code.  ``fire()`` validates at runtime,
but only on paths that actually execute — this rule closes the gap
statically:

* **``fire()`` takes a string literal.**  A computed site name can't be
  checked against the catalog here and can't be grepped by someone
  writing a fault spec; the whole point of the registry is that
  ``SITES`` in ``repro/core/failpoints.py`` is the complete, searchable
  truth.
* **The literal is a declared site.**  Unknown names would raise
  ``RuntimeError`` at runtime — on the injection path, which by
  definition only runs under fault testing; catch the typo before that.
* **Every declared site is fired somewhere.**  A catalog entry with no
  call site is dead: specs targeting it match-and-arm but never inject,
  which reads as "the code survived the fault" when the fault never
  happened.  (Cross-module, like the REPRO001 lock graph: assumes the
  usual full-``src`` scan.)
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.core import Finding, ParsedFile, Rule, register

RULE_ID = "REPRO008"

_FAILPOINTS_PATH = "src/repro/core/failpoints.py"


def _sites_catalog(files: Sequence[ParsedFile]) -> Optional[Dict[str, int]]:
    """Statically parse ``SITES`` (name -> declaration line) out of the
    failpoints module; None when it is not in the scanned set."""
    for f in files:
        if f.path != _FAILPOINTS_PATH:
            continue
        for node in ast.walk(f.tree):
            if isinstance(node, ast.Assign):
                targets = [t.id for t in node.targets
                           if isinstance(t, ast.Name)]
            elif isinstance(node, ast.AnnAssign) \
                    and isinstance(node.target, ast.Name):
                targets = [node.target.id]
            else:
                continue
            if "SITES" not in targets or not isinstance(node.value, ast.Dict):
                continue
            sites: Dict[str, int] = {}
            for key in node.value.keys:
                if isinstance(key, ast.Constant) \
                        and isinstance(key.value, str):
                    sites[key.value] = key.lineno
            return sites
    return None


def _fire_call(call: ast.Call, fire_names: Set[str]) -> bool:
    """True if `call` is ``failpoints.fire(...)`` or a bare ``fire(...)``
    bound by ``from repro.core.failpoints import fire``."""
    fn = call.func
    if isinstance(fn, ast.Attribute) and fn.attr == "fire" \
            and isinstance(fn.value, ast.Name) \
            and fn.value.id == "failpoints":
        return True
    return isinstance(fn, ast.Name) and fn.id in fire_names


def _fire_imports(tree: ast.Module) -> Set[str]:
    """Local names ``fire`` is bound to by from-imports of the module."""
    names: Set[str] = set()
    for node in ast.walk(tree):
        if isinstance(node, ast.ImportFrom) \
                and node.module == "repro.core.failpoints":
            for alias in node.names:
                if alias.name == "fire":
                    names.add(alias.asname or alias.name)
    return names


@register
class FailpointSiteRule(Rule):
    id = RULE_ID
    title = "failpoints.fire() uses literal names declared in SITES"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        findings: List[Finding] = []
        sites = _sites_catalog(files)
        fired: Set[str] = set()
        for f in files:
            if f.path == _FAILPOINTS_PATH:
                continue  # fire() internals reference sites dynamically
            fire_names = _fire_imports(f.tree)
            for node in ast.walk(f.tree):
                if not isinstance(node, ast.Call) \
                        or not _fire_call(node, fire_names):
                    continue
                arg = node.args[0] if node.args else None
                if not (isinstance(arg, ast.Constant)
                        and isinstance(arg.value, str)):
                    findings.append(Finding(
                        RULE_ID, f.path, node.lineno,
                        "failpoints.fire() with a non-literal site name; "
                        "use a string literal from SITES so fault specs "
                        "stay greppable and statically checkable"))
                    continue
                fired.add(arg.value)
                if sites is not None and arg.value not in sites:
                    findings.append(Finding(
                        RULE_ID, f.path, node.lineno,
                        f"unknown failpoint site {arg.value!r}; declare "
                        f"it in repro.core.failpoints.SITES"))
        if sites is not None and fired:
            for name in sorted(set(sites) - fired):
                findings.append(Finding(
                    RULE_ID, _FAILPOINTS_PATH, sites[name],
                    f"failpoint site {name!r} is declared but never "
                    f"fired; a spec targeting it arms but injects "
                    f"nothing — remove the entry or add the fire() call"))
        return findings
