"""REPRO004 — Pallas kernel hygiene.

Kernel functions (the first argument of a ``pl.pallas_call``, possibly
wrapped in ``functools.partial``) execute as traced device code: they
run once at trace time, so anything that looks like Python-side effectful
or stateful code is a latent correctness bug, not just style.  Flagged
inside kernel bodies:

* ``print`` calls and ``global``/``nonlocal`` statements;
* any use of host-state modules: ``os``, ``random``, ``time``,
  ``np``/``numpy`` (device code uses ``jnp``), in particular
  ``np.random`` — trace-time randomness bakes one sample into the
  compiled kernel;
* reads of module-level mutable state: a Name that resolves to a
  module-level binding which is neither an import, a function/class,
  nor an ALL-CAPS constant — mutable captures are frozen at trace time
  and silently go stale;
* ``.shape`` on anything that is not a kernel parameter (a ref) or a
  kernel-local value — shapes must come from refs/BlockSpec, never from
  captured host arrays.
"""

from __future__ import annotations

import ast
import re
from typing import Dict, List, Optional, Sequence, Set

from repro.analysis.core import Finding, ParsedFile, Rule, register

RULE_ID = "REPRO004"

_CONST_RE = re.compile(r"^_?[A-Z][A-Z0-9_]*$")
_HOST_MODULES = frozenset({"os", "np", "numpy", "random", "time"})


def _kernel_names(tree: ast.Module) -> Dict[str, int]:
    """{function name: pallas_call line} for kernel fns in this module."""
    out: Dict[str, int] = {}
    for node in ast.walk(tree):
        if not isinstance(node, ast.Call):
            continue
        fn = node.func
        is_pallas = (isinstance(fn, ast.Attribute) and fn.attr == "pallas_call"
                     and isinstance(fn.value, ast.Name)
                     and fn.value.id == "pl")
        if not is_pallas or not node.args:
            continue
        kernel = node.args[0]
        if isinstance(kernel, ast.Call):  # functools.partial(kernel, ...)
            callee = kernel.func
            is_partial = (isinstance(callee, ast.Attribute)
                          and callee.attr == "partial") or \
                         (isinstance(callee, ast.Name)
                          and callee.id == "partial")
            if is_partial and kernel.args:
                kernel = kernel.args[0]
        if isinstance(kernel, ast.Name):
            out[kernel.id] = node.lineno
    return out


def _module_bindings(tree: ast.Module) -> Dict[str, str]:
    """Top-level name -> kind ('import' | 'def' | 'const' | 'mutable')."""
    out: Dict[str, str] = {}
    for stmt in tree.body:
        if isinstance(stmt, ast.Import):
            for alias in stmt.names:
                out[alias.asname or alias.name.split(".")[0]] = "import"
        elif isinstance(stmt, ast.ImportFrom):
            for alias in stmt.names:
                out[alias.asname or alias.name] = "import"
        elif isinstance(stmt, (ast.FunctionDef, ast.AsyncFunctionDef,
                               ast.ClassDef)):
            out[stmt.name] = "def"
        elif isinstance(stmt, (ast.Assign, ast.AnnAssign)):
            targets = stmt.targets if isinstance(stmt, ast.Assign) \
                else [stmt.target]
            for t in targets:
                if isinstance(t, ast.Name):
                    out[t.id] = ("const" if _CONST_RE.match(t.id)
                                 else "mutable")
    return out


def _local_names(fn) -> Set[str]:
    """Parameters plus every name assigned/bound inside the function."""
    names: Set[str] = set()
    args = fn.args
    for a in (list(args.posonlyargs) + list(args.args)
              + list(args.kwonlyargs)):
        names.add(a.arg)
    if args.vararg:
        names.add(args.vararg.arg)
    if args.kwarg:
        names.add(args.kwarg.arg)
    for node in ast.walk(fn):
        if isinstance(node, ast.Name) and isinstance(node.ctx, ast.Store):
            names.add(node.id)
        elif isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                and node is not fn:
            names.add(node.name)
        elif isinstance(node, ast.comprehension):
            for sub in ast.walk(node.target):
                if isinstance(sub, ast.Name):
                    names.add(sub.id)
    return names


@register
class KernelHygieneRule(Rule):
    id = RULE_ID
    title = "Pallas kernel fns stay pure: no host state, shapes from refs"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        findings: List[Finding] = []
        for f in files:
            kernels = _kernel_names(f.tree)
            if not kernels:
                continue
            bindings = _module_bindings(f.tree)
            for fn in ast.walk(f.tree):
                if isinstance(fn, (ast.FunctionDef, ast.AsyncFunctionDef)) \
                        and fn.name in kernels:
                    findings.extend(self._check_kernel(f, fn, bindings))
        return findings

    def _check_kernel(self, f: ParsedFile, fn,
                      bindings: Dict[str, str]) -> List[Finding]:
        findings: List[Finding] = []
        local = _local_names(fn)

        def flag(node, msg: str) -> None:
            findings.append(Finding(
                RULE_ID, f.path, node.lineno,
                f"kernel '{fn.name}': {msg}"))

        for node in ast.walk(fn):
            if isinstance(node, (ast.Global, ast.Nonlocal)):
                flag(node, f"{type(node).__name__.lower()} statement; "
                     f"kernels must not mutate enclosing scopes")
            elif isinstance(node, ast.Call) \
                    and isinstance(node.func, ast.Name) \
                    and node.func.id == "print":
                flag(node, "print() runs at trace time only; use "
                     "pl.debug_print or drop it")
            elif isinstance(node, ast.Attribute) \
                    and isinstance(node.value, ast.Name):
                base = node.value.id
                if base in _HOST_MODULES and base not in local:
                    flag(node, f"uses host module '{base}.{node.attr}'; "
                         f"device code must use jnp/pl/jax.lax only")
                elif node.attr == "shape" and base not in local \
                        and bindings.get(base) not in ("import",):
                    flag(node, f"reads '{base}.shape' from a captured "
                         f"host value; shapes must come from refs or "
                         f"BlockSpec parameters")
            elif isinstance(node, ast.Name) \
                    and isinstance(node.ctx, ast.Load):
                if node.id in local or node.id in ("True", "False", "None"):
                    continue
                kind = bindings.get(node.id)
                if kind == "mutable":
                    flag(node, f"captures module-level mutable state "
                         f"'{node.id}'; trace-time capture freezes one "
                         f"value forever (make it an ALL_CAPS constant "
                         f"or pass it as a parameter)")
        return findings
