"""REPRO001 — static lock-order checking over the store's rank table.

Approximates each function's lock behavior from the AST:

* **Nodes** are lock *classes*, keyed by normalized attribute name
  (``_meta_lock`` → ``meta``, ``shard_locks`` → ``shard``): a store has
  N shard locks, but ordering is a property of the class, not the
  instance (equal-rank acquisitions are legal — the rebalancer sweeps
  whole classes in index order under the rebalance lock).
* **Ranks** come from the creation site: a lock built through
  ``make_lock("shard")`` / ``make_rlock(...)`` (repro.core.locks)
  carries its documented rank; raw ``threading.Lock()`` nodes are
  unranked and participate only in cycle detection.
* **Edges** a→b mean "b acquired while a held": nested ``with`` blocks,
  bare ``.acquire()`` calls (held for the rest of the function — the
  try/finally sweep idiom), and one level of call propagation (holding
  a, call ``f()``; f directly acquires b).  Propagation is name-based
  and skips ubiquitous method names (``get``, ``append``, ...) that
  would drown the graph in dict/list noise.

Findings: (1) an edge from a higher rank to a lower rank — the direct
witness of a reversed acquisition; (2) any cycle among lock nodes;
(3) non-reentrant locks ``with``-nested inside themselves; (4) *hot
sections*: fsync / publish / batch-compression work under an ``index``
or ``meta``-class lock, which serializes every reader behind disk or
CPU time (one aggregated finding per ``with`` block, so one waiver
line covers a justified case).
"""

from __future__ import annotations

import ast
from typing import Dict, List, Optional, Sequence, Set, Tuple

from repro.analysis.core import Finding, ParsedFile, Rule, register
from repro.core.locks import RANKS

RULE_ID = "REPRO001"

#: method names excluded from call propagation: dict/list/ndarray noise
_PROPAGATE_SKIP = frozenset({
    "get", "put", "pop", "popitem", "append", "extend", "add", "read",
    "write", "update", "copy", "items", "values", "keys", "sort",
    "sorted", "close", "join", "clear", "move_to_end", "setdefault",
    "len", "range", "dict", "list", "sum", "max", "min", "zip", "exists",
    "unlink", "stat",
})

#: calls that must not run under an index/meta-class lock
_HOT_PLAIN = frozenset({
    "publish", "fsync_file", "fsync_dir", "compress_bytes",
    "compress_bytes_dict", "compress_batch", "decompress_batch",
    "encode_batch", "decode_batch", "plan_batch", "put_many",
})
_HOT_NODES = ("index", "meta")


def _normalize(raw: str) -> str:
    s = raw.lstrip("_").lower()
    for suffix in ("_locks", "_lock"):
        if s.endswith(suffix):
            s = s[: -len(suffix)]
            break
    return s if s else "lock"


def _lock_ctor(call: ast.Call) -> Optional[Tuple[Optional[str], bool]]:
    """(order, reentrant) if `call` constructs a lock, else None."""
    fn = call.func
    name = fn.attr if isinstance(fn, ast.Attribute) else (
        fn.id if isinstance(fn, ast.Name) else None)
    if name in ("Lock", "RLock"):
        base = fn.value if isinstance(fn, ast.Attribute) else None
        if base is None or (isinstance(base, ast.Name)
                            and base.id == "threading"):
            return (None, name == "RLock")
        return None
    if name in ("make_lock", "make_rlock"):
        order = None
        if call.args and isinstance(call.args[0], ast.Constant) \
                and isinstance(call.args[0].value, str):
            order = call.args[0].value
        return (order, name == "make_rlock")
    return None


class _LockNode:
    __slots__ = ("name", "orders", "reentrant", "sites")

    def __init__(self, name: str):
        self.name = name
        self.orders: Set[str] = set()
        self.reentrant = False
        self.sites: List[Tuple[str, int]] = []

    @property
    def rank(self) -> Optional[int]:
        ranks = {RANKS[o] for o in self.orders if o in RANKS}
        return min(ranks) if ranks else None


class _FunctionFacts:
    """Per-function lock behavior extracted in one ordered AST walk."""

    def __init__(self, name: str, path: str):
        self.name = name
        self.path = path
        self.direct: Set[str] = set()                 # nodes acquired
        self.edges: List[Tuple[str, str, int]] = []   # (held, acquired, line)
        self.calls: List[Tuple[str, Tuple[str, ...], int]] = []
        self.self_nest: List[Tuple[str, int]] = []    # non-reentrant re-with
        self.hot: Dict[Tuple[str, int], List[Tuple[str, int]]] = {}


@register
class LockOrderRule(Rule):
    id = RULE_ID
    title = "lock acquisition order matches the documented rank table"

    def run(self, files: Sequence[ParsedFile]) -> List[Finding]:
        nodes: Dict[str, _LockNode] = {}
        getters: Dict[str, str] = {}   # function name -> node it returns
        funcs: List[_FunctionFacts] = []

        for f in files:
            self._collect_nodes(f, nodes)
        for f in files:
            self._collect_getters(f, nodes, getters)
        for f in files:
            for fn in self._iter_functions(f.tree):
                funcs.append(self._analyze_function(
                    fn, f.path, nodes, getters))

        findings: List[Finding] = []
        for node in nodes.values():
            if len({RANKS[o] for o in node.orders if o in RANKS}) > 1:
                path, line = node.sites[0]
                findings.append(Finding(
                    RULE_ID, path, line,
                    f"lock class '{node.name}' created with conflicting "
                    f"orders {sorted(node.orders)}"))

        # merge edges; first witness wins for reporting
        edges: Dict[Tuple[str, str], Tuple[str, int]] = {}
        direct_by_name: Dict[str, Set[str]] = {}
        for fn in funcs:
            direct_by_name.setdefault(fn.name, set()).update(fn.direct)
        for fn in funcs:
            for held, acq, line in fn.edges:
                edges.setdefault((held, acq), (fn.path, line))
            for callee, held, line in fn.calls:
                if callee in _PROPAGATE_SKIP:
                    continue
                for acq in direct_by_name.get(callee, ()):
                    for h in held:
                        if h != acq:  # propagated self-edges are noise
                            edges.setdefault((h, acq), (fn.path, line))

        order_str = " < ".join(sorted(RANKS, key=RANKS.get))
        for (a, b), (path, line) in sorted(edges.items()):
            ra = nodes[a].rank if a in nodes else None
            rb = nodes[b].rank if b in nodes else None
            if ra is not None and rb is not None and ra > rb:
                findings.append(Finding(
                    RULE_ID, path, line,
                    f"acquires '{b}' (rank {rb}) while holding '{a}' "
                    f"(rank {ra}); documented order is {order_str}"))

        for scc in _sccs({a for e in edges for a in e},
                         {e for e in edges}):
            if len(scc) < 2:
                continue
            for (a, b), (path, line) in sorted(edges.items()):
                if a in scc and b in scc:
                    findings.append(Finding(
                        RULE_ID, path, line,
                        f"lock cycle among {sorted(scc)}: edge "
                        f"'{a}' -> '{b}' closes a deadlock-capable loop"))

        for fn in funcs:
            for name, line in fn.self_nest:
                findings.append(Finding(
                    RULE_ID, fn.path, line,
                    f"non-reentrant lock '{name}' acquired inside a block "
                    f"already holding it (self-deadlock)"))
            for (name, wline), hits in sorted(fn.hot.items()):
                what = ", ".join(sorted({h for h, _ in hits}))
                findings.append(Finding(
                    RULE_ID, fn.path, wline,
                    f"holds '{name}' lock across blocking work ({what}); "
                    f"fsync/compression under an index/meta lock "
                    f"serializes all readers"))
        return findings

    # -- collection passes ---------------------------------------------------

    def _collect_nodes(self, f: ParsedFile,
                       nodes: Dict[str, _LockNode]) -> None:
        for stmt in ast.walk(f.tree):
            if not isinstance(stmt, ast.Assign):
                continue
            value = stmt.value
            calls = []
            if isinstance(value, ast.Call):
                calls.append(value)
            elif isinstance(value, ast.ListComp) \
                    and isinstance(value.elt, ast.Call):
                calls.append(value.elt)
            for call in calls:
                ctor = _lock_ctor(call)
                if ctor is None:
                    continue
                order, reentrant = ctor
                for target in stmt.targets:
                    raw = None
                    if isinstance(target, ast.Attribute):
                        raw = target.attr
                    elif isinstance(target, ast.Name):
                        raw = target.id
                    if raw is None:
                        continue
                    name = _normalize(raw)
                    node = nodes.setdefault(name, _LockNode(name))
                    if order:
                        node.orders.add(order)
                    node.reentrant = node.reentrant or reentrant
                    node.sites.append((f.path, stmt.lineno))

    def _collect_getters(self, f: ParsedFile, nodes: Dict[str, _LockNode],
                         getters: Dict[str, str]) -> None:
        """Functions whose return expression IS a lock node ('lock
        getters', e.g. store.compaction_lock) propagate the node to
        variables bound from their call."""
        for fn in self._iter_functions(f.tree):
            for stmt in ast.walk(fn):
                if isinstance(stmt, ast.Return) and stmt.value is not None:
                    name = _resolve_lock_expr(stmt.value, nodes, {}, {})
                    if name is not None:
                        getters[fn.name] = name

    def _iter_functions(self, tree: ast.Module):
        for node in ast.walk(tree):
            if isinstance(node, (ast.FunctionDef, ast.AsyncFunctionDef)):
                yield node

    # -- the ordered walk ----------------------------------------------------

    def _analyze_function(self, fn, path: str, nodes: Dict[str, _LockNode],
                          getters: Dict[str, str]) -> _FunctionFacts:
        facts = _FunctionFacts(fn.name, path)
        bindings: Dict[str, str] = {}   # local var -> lock node
        bare_held: List[str] = []       # .acquire()d, held to function end

        def held_now(with_stack: Tuple[str, ...]) -> Tuple[str, ...]:
            return tuple(bare_held) + with_stack

        def scan_expr(expr: ast.AST, with_stack: Tuple[str, ...],
                      hot_key: Optional[Tuple[str, int]]) -> None:
            for node in ast.walk(expr):
                if not isinstance(node, ast.Call):
                    continue
                callee = _call_name(node)
                if callee == "acquire":
                    target = _resolve_lock_expr(
                        node.func.value, nodes, bindings, getters) \
                        if isinstance(node.func, ast.Attribute) else None
                    if target is not None:
                        facts.direct.add(target)
                        for h in held_now(with_stack):
                            if h != target:
                                facts.edges.append((h, target, node.lineno))
                        bare_held.append(target)
                    continue
                if callee == "release":
                    target = _resolve_lock_expr(
                        node.func.value, nodes, bindings, getters) \
                        if isinstance(node.func, ast.Attribute) else None
                    if target is not None and target in bare_held:
                        bare_held.remove(target)
                    continue
                if callee is not None:
                    held = held_now(with_stack)
                    if held:
                        facts.calls.append((callee, held, node.lineno))
                    if hot_key is not None and _is_hot_call(node, callee):
                        facts.hot.setdefault(hot_key, []).append(
                            (callee, node.lineno))

        def walk(stmts, with_stack: Tuple[str, ...],
                 hot_key: Optional[Tuple[str, int]]) -> None:
            for stmt in stmts:
                if isinstance(stmt, ast.With):
                    acquired: List[str] = []
                    for item in stmt.items:
                        scan_expr(item.context_expr, with_stack, hot_key)
                        name = _resolve_lock_expr(
                            item.context_expr, nodes, bindings, getters)
                        if name is None:
                            continue
                        facts.direct.add(name)
                        if name in with_stack:
                            node = nodes.get(name)
                            if node is not None and not node.reentrant:
                                facts.self_nest.append((name, stmt.lineno))
                        for h in held_now(with_stack):
                            if h != name:
                                facts.edges.append((h, name, stmt.lineno))
                        acquired.append(name)
                    inner = with_stack + tuple(acquired)
                    key = hot_key
                    for name in acquired:
                        if any(tag in name for tag in _HOT_NODES):
                            key = (name, stmt.lineno)
                    walk(stmt.body, inner, key)
                elif isinstance(stmt, ast.For):
                    scan_expr(stmt.iter, with_stack, hot_key)
                    src = _resolve_lock_expr(stmt.iter, nodes, bindings,
                                             getters)
                    if src is not None and isinstance(stmt.target, ast.Name):
                        bindings[stmt.target.id] = src
                    walk(stmt.body, with_stack, hot_key)
                    walk(stmt.orelse, with_stack, hot_key)
                elif isinstance(stmt, ast.Assign):
                    scan_expr(stmt.value, with_stack, hot_key)
                    src = _resolve_lock_expr(stmt.value, nodes, bindings,
                                             getters)
                    if src is not None:
                        for target in stmt.targets:
                            if isinstance(target, ast.Name):
                                bindings[target.id] = src
                elif isinstance(stmt, (ast.If, ast.While)):
                    scan_expr(stmt.test, with_stack, hot_key)
                    walk(stmt.body, with_stack, hot_key)
                    walk(stmt.orelse, with_stack, hot_key)
                elif isinstance(stmt, ast.Try):
                    walk(stmt.body, with_stack, hot_key)
                    for handler in stmt.handlers:
                        walk(handler.body, with_stack, hot_key)
                    walk(stmt.orelse, with_stack, hot_key)
                    walk(stmt.finalbody, with_stack, hot_key)
                elif isinstance(stmt, (ast.FunctionDef,
                                       ast.AsyncFunctionDef, ast.ClassDef)):
                    continue  # nested defs analyzed as their own functions
                else:
                    scan_expr(stmt, with_stack, hot_key)

        walk(fn.body, (), None)
        return facts


def _call_name(call: ast.Call) -> Optional[str]:
    fn = call.func
    if isinstance(fn, ast.Attribute):
        return fn.attr
    if isinstance(fn, ast.Name):
        return fn.id
    return None


def _is_hot_call(call: ast.Call, callee: str) -> bool:
    if callee in _HOT_PLAIN:
        return True
    fn = call.func
    if isinstance(fn, ast.Attribute) and fn.attr in ("fsync", "replace") \
            and isinstance(fn.value, ast.Name) and fn.value.id == "os":
        return True
    return False


def _resolve_lock_expr(expr: ast.AST, nodes: Dict[str, "_LockNode"],
                       bindings: Dict[str, str],
                       getters: Dict[str, str]) -> Optional[str]:
    """Lock node a runtime expression denotes, if recognizable."""
    if isinstance(expr, ast.Subscript):
        return _resolve_lock_expr(expr.value, nodes, bindings, getters)
    if isinstance(expr, ast.Attribute):
        name = _normalize(expr.attr)
        return name if name in nodes else None
    if isinstance(expr, ast.Name):
        if expr.id in bindings:
            return bindings[expr.id]
        name = _normalize(expr.id)
        return name if name in nodes else None
    if isinstance(expr, ast.Call):
        callee = _call_name(expr)
        if callee in getters:
            return getters[callee]
    return None


def _sccs(vertices: Set[str],
          edges: Set[Tuple[str, str]]) -> List[Set[str]]:
    """Tarjan strongly-connected components (iterative)."""
    adj: Dict[str, List[str]] = {v: [] for v in vertices}
    for a, b in edges:
        adj.setdefault(a, []).append(b)
        adj.setdefault(b, [])
    index: Dict[str, int] = {}
    low: Dict[str, int] = {}
    on_stack: Set[str] = set()
    stack: List[str] = []
    out: List[Set[str]] = []
    counter = [0]

    for root in sorted(adj):
        if root in index:
            continue
        work = [(root, iter(adj[root]))]
        index[root] = low[root] = counter[0]
        counter[0] += 1
        stack.append(root)
        on_stack.add(root)
        while work:
            v, it = work[-1]
            advanced = False
            for w in it:
                if w not in index:
                    index[w] = low[w] = counter[0]
                    counter[0] += 1
                    stack.append(w)
                    on_stack.add(w)
                    work.append((w, iter(adj[w])))
                    advanced = True
                    break
                if w in on_stack:
                    low[v] = min(low[v], index[w])
            if advanced:
                continue
            work.pop()
            if work:
                parent = work[-1][0]
                low[parent] = min(low[parent], low[v])
            if low[v] == index[v]:
                comp: Set[str] = set()
                while True:
                    w = stack.pop()
                    on_stack.discard(w)
                    comp.add(w)
                    if w == v:
                        break
                out.append(comp)
    return out
