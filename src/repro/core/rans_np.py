"""Reference rANS entropy coder (numpy/python) + the vectorized
interleaved N-lane coder that serves the codec hot path.

This is the entropy-coding stage of the paper's model of Zstd
(``FSE(LZ77(...))`` — FSE is the table-driven cousin of rANS) implemented
from scratch.  It serves three roles:

1. oracle for the JAX/TPU interleaved coder in ``repro.core.rans``,
2. entropy stage of the from-scratch ``repro-lzr`` backend
   (LZ77 -> rANS ~= the paper's LZ77 -> FSE description of Zstd),
3. order-0 coder over *token ids* for the token-stream storage mode.

Classic 32-bit-state rANS with 16-bit renormalization; python ints make
the scalar arithmetic exact, numpy handles tables.  Streaming convention:
encoder walks the symbols in reverse and appends 16-bit words; the
serialized stream stores those words reversed so the decoder reads
forward.

The interleaved coder runs N independent rANS states in lockstep over a
round-robin symbol split (symbol ``i`` belongs to lane ``i % N``): every
step is a handful of vectorized uint64 ops over the N states, and because
a 32-bit state with 16-bit renorm emits **at most one** word per symbol
(``x_max = f << (32-pb) >= 2^16`` for ``pb <= 16``), renormalization is a
single mask.  All lanes share one word stream: the encoder emits each
step's words in descending-lane order so the (forward-reading) decoder
can consume them in ascending-lane order.  Lane 1 of the interleaved
coder reproduces the scalar stream bit-for-bit (asserted in tests).

Blob format: the header's `asize` field distinguishes a dense 256-entry
frequency table (asize == 256, the original layout) from the sparse
(symbol, freq)-pair table for small/low-alphabet inputs (asize 1..255).
Single-lane blobs keep the original layout byte-for-byte.  Multi-lane
blobs set bit 7 of the ``prob_bits`` header byte (legacy writers only
ever produced 1..16 there) and insert one lane-count byte —
``log2(lanes)`` — after it; the tail then carries ``lanes`` u32 states
followed by one shared word stream.  Readers predating the flag cannot
parse multi-lane blobs; this reader accepts every layout.
"""

from __future__ import annotations

import struct
from typing import Optional, Tuple

import numpy as np

from repro.core import env

PROB_BITS_DEFAULT = 12
_STATE_LOW = 1 << 16  # renormalization lower bound

# interleaved-coder defaults: payloads below _LANES_MIN_BYTES stay on the
# single-lane scalar path (fixed numpy overhead + 4 header bytes per lane
# dominate tiny blobs); above it the lane count scales with payload size
# so per-step vector width amortizes numpy dispatch
_LANES_MIN_BYTES = 4096
_LANES_MAX = 1024

# auto-mode crossover for the device lane-parallel kernels: below this the
# upload + per-call dispatch beats the lockstep win; override with
# REPRO_RANS_DEVICE_MIN after re-measuring (benchmarks/kernel_throughput.py)
_DEVICE_MIN_BYTES = 1 << 16


def _use_device_rans(n: int) -> bool:
    """REPRO_RANS_MODE routing: ``numpy`` forces the host coder,
    ``device`` forces the Pallas lane kernels (interpret mode on CPU —
    tests/parity smokes), ``auto`` (default) takes the device only when a
    non-CPU backend is attached and the payload clears the crossover."""
    mode = env.read("REPRO_RANS_MODE")
    if mode == "device":
        return True
    if mode != "auto":
        return False
    from repro.core import device as _device

    return _device.use_device(n, "REPRO_RANS_DEVICE_MIN", _DEVICE_MIN_BYTES)


def _env_lanes() -> Optional[int]:
    """``REPRO_RANS_LANES``, sanitized by the env registry's parser (the
    explicit ``lanes=`` argument keeps strict validation; the env knob
    warns and clamps — see repro.core.env)."""
    return env.read("REPRO_RANS_LANES")


def normalize_freqs(counts: np.ndarray, prob_bits: int = PROB_BITS_DEFAULT) -> np.ndarray:
    """Scale a histogram to sum to 2**prob_bits with every observed symbol
    keeping frequency >= 1 (largest-remainder apportionment)."""
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    target = 1 << prob_bits
    if total <= 0:
        raise ValueError("empty histogram")
    present = counts > 0
    n_present = int(present.sum())
    if n_present > target:
        raise ValueError(f"alphabet has {n_present} symbols > table size {target}")
    raw = counts * (target / total)
    freqs = np.floor(raw).astype(np.int64)
    freqs[present & (freqs == 0)] = 1
    diff = target - int(freqs.sum())
    if diff > 0:  # hand out leftovers by largest remainder
        rema = raw - np.floor(raw)
        rema[~present] = -1.0
        order = np.argsort(-rema, kind="stable")
        freqs[order[:diff]] += 1
    elif diff < 0:  # take back from the largest entries (keep >= 1)
        order = np.argsort(-freqs, kind="stable")
        k = 0
        while diff < 0:
            idx = order[k % len(order)]
            if freqs[idx] > 1:
                freqs[idx] -= 1
                diff += 1
            k += 1
    assert freqs.sum() == target
    return freqs.astype(np.uint32)


def rans_encode(
    symbols: np.ndarray, freqs: np.ndarray, prob_bits: int = PROB_BITS_DEFAULT
) -> Tuple[np.ndarray, int]:
    """Encode `symbols` under `freqs`; returns (emitted u16 words, state)."""
    cum = np.concatenate(([0], np.cumsum(freqs.astype(np.int64))))
    x = _STATE_LOW
    words = []
    shift = 16 + 16 - prob_bits  # x_max = freq << shift keeps x < 2**32
    for s in symbols[::-1]:
        s = int(s)
        f = int(freqs[s])
        if f == 0:
            raise ValueError(f"symbol {s} has zero frequency")
        x_max = f << shift
        while x >= x_max:
            words.append(x & 0xFFFF)
            x >>= 16
        x = ((x // f) << prob_bits) + (x % f) + int(cum[s])
    return np.array(words, dtype=np.uint16), x


def rans_decode(
    words: np.ndarray, state: int, n: int, freqs: np.ndarray,
    prob_bits: int = PROB_BITS_DEFAULT,
) -> np.ndarray:
    """Inverse of `rans_encode`. `words` in emission order."""
    cum = np.concatenate(([0], np.cumsum(freqs.astype(np.int64))))
    # slot -> symbol lookup
    slot2sym = np.repeat(
        np.arange(len(freqs), dtype=np.int64), freqs.astype(np.int64)
    )
    mask = (1 << prob_bits) - 1
    x = int(state)
    pos = len(words) - 1  # consume in reverse emission order
    out = np.empty(n, dtype=np.int64)
    for i in range(n):
        slot = x & mask
        s = int(slot2sym[slot])
        out[i] = s
        x = int(freqs[s]) * (x >> prob_bits) + slot - int(cum[s])
        while x < _STATE_LOW:
            if pos < 0:
                raise ValueError("rANS stream underflow")
            x = (x << 16) | int(words[pos])
            pos -= 1
    return out


# ---------------------------------------------------------------------------
# Vectorized interleaved N-lane coder
# ---------------------------------------------------------------------------


def rans_encode_interleaved(
    symbols: np.ndarray, freqs: np.ndarray, lanes: int,
    prob_bits: int = PROB_BITS_DEFAULT,
) -> Tuple[np.ndarray, np.ndarray]:
    """Encode `symbols` over N interleaved lanes (lane = index % lanes).

    Returns (words u16 in forward/decode order, final states [lanes] u32).
    All arithmetic is uint64 so the single-symbol-alphabet edge
    (f == 2**prob_bits, x_max == 2**32) needs no special case.
    """
    n = symbols.size
    cum = np.concatenate(
        (np.zeros(1, np.uint64), np.cumsum(freqs, dtype=np.uint64)))
    fs = freqs.astype(np.uint64)[symbols]
    cs = cum[symbols]
    xm = fs << np.uint64(32 - prob_bits)
    T = n // lanes          # full steps
    rem = n - T * lanes     # partial tail step (lanes 0..rem-1)
    x = np.full(lanes, _STATE_LOW, np.uint64)
    pb = np.uint64(prob_bits)
    u16 = np.uint64(0xFFFF)
    sixteen = np.uint64(16)
    chunks = []
    if rem:  # encoder runs back-to-front: tail step first
        xa = x[:rem]
        emit = xa >= xm[T * lanes :]
        w = (xa[emit] & u16).astype(np.uint16)
        if w.size:
            chunks.append(w[::-1])
        xa = xa >> (emit.astype(np.uint64) * sixteen)
        q, r = np.divmod(xa, fs[T * lanes :])
        x[:rem] = (q << pb) + r + cs[T * lanes :]
    fg = fs[: T * lanes].reshape(T, lanes)
    cg = cs[: T * lanes].reshape(T, lanes)
    xg = xm[: T * lanes].reshape(T, lanes)
    for t in range(T - 1, -1, -1):
        emit = x >= xg[t]
        w = (x[emit] & u16).astype(np.uint16)
        if w.size:
            chunks.append(w[::-1])
        x = x >> (emit.astype(np.uint64) * sixteen)
        q, r = np.divmod(x, fg[t])
        x = (q << pb) + r + cg[t]
    if chunks:
        words = np.concatenate(chunks)[::-1]
    else:
        words = np.zeros(0, np.uint16)
    return words, x.astype(np.uint32)


def rans_decode_interleaved(
    words: np.ndarray, states: np.ndarray, n: int, freqs: np.ndarray,
    lanes: int, prob_bits: int = PROB_BITS_DEFAULT,
) -> np.ndarray:
    """Inverse of `rans_encode_interleaved`; returns uint8 symbols [n]."""
    cum = np.concatenate(
        (np.zeros(1, np.uint64), np.cumsum(freqs, dtype=np.uint64)))
    freqs64 = freqs.astype(np.uint64)
    slot2sym = np.repeat(np.arange(freqs.size, dtype=np.uint8),
                         freqs.astype(np.int64))
    mask = np.uint64((1 << prob_bits) - 1)
    pb = np.uint64(prob_bits)
    low = np.uint64(_STATE_LOW)
    sixteen = np.uint64(16)
    T = n // lanes
    rem = n - T * lanes
    x = states.astype(np.uint64)
    out = np.empty(T * lanes + (lanes if rem else 0), np.uint8)
    wl = words.astype(np.uint64)
    wpos = 0
    for t in range(T):
        slot = x & mask
        s = slot2sym[slot.astype(np.int64)]
        out[t * lanes : (t + 1) * lanes] = s
        x = freqs64[s] * (x >> pb) + (slot - cum[s])
        need = x < low
        k = int(np.count_nonzero(need))
        if k:
            if wpos + k > wl.size:
                raise ValueError("rANS stream underflow")
            x[need] = (x[need] << sixteen) | wl[wpos : wpos + k]
            wpos += k
    if rem:
        xa = x[:rem]
        slot = xa & mask
        out[T * lanes : T * lanes + rem] = slot2sym[slot.astype(np.int64)]
    return out[:n]


def _auto_lanes(n: int) -> int:
    """Power-of-two lane count targeting ~512 lockstep steps: the
    per-step cost is numpy dispatch (width-independent), so wider is
    faster until the 4-byte-per-lane state header matters — at n/512
    lanes the header stays ~2% of a typically-compressed payload.
    Auto range is 16..1024 (n >= 4096 implies (n>>9).bit_length() >= 4);
    smaller explicit lane counts remain valid via the `lanes` argument."""
    if n < _LANES_MIN_BYTES:
        return 1
    return min(1 << (n >> 9).bit_length(), _LANES_MAX)


# ---------------------------------------------------------------------------
# Self-contained byte-stream format
# ---------------------------------------------------------------------------
#
# single-lane (original layout, unchanged byte-for-byte):
#   u32le n_symbols | u8 prob_bits | u16le alphabet_size
#   freqs: alphabet_size x u16le   | u32le state | u32le n_words | words u16le
#   (words stored reversed so decode reads forward)
# interleaved (bit 7 of the prob_bits byte set; legacy writers never set it):
#   u32le n_symbols | u8 prob_bits|0x80 | u8 log2(lanes) | u16le alphabet_size
#   freqs table (same sparse/dense convention) | lanes x u32le states
#   u32le n_words | words u16le (forward order)


def _freq_table(symbols: np.ndarray, prob_bits: int) -> Tuple[np.ndarray, bytes, int]:
    from repro.core.entropy import byte_histogram

    counts = byte_histogram(symbols)  # np.bincount on CPU, Pallas on device
    freqs = normalize_freqs(counts, prob_bits)
    # `asize` field: 256 = dense 256-entry table; 1..255 = sparse table of
    # (symbol u8, freq u2) pairs.  Sparse wins on small or low-alphabet
    # inputs, where a 512-byte dense table would dominate the blob
    # (3 bytes/symbol vs 2 bytes/slot -> sparse iff k < 171).
    nonzero = np.flatnonzero(freqs)
    if nonzero.size < 171:
        table = (nonzero.astype("<u1").tobytes()
                 + freqs[nonzero].astype("<u2").tobytes())
        return freqs, table, nonzero.size
    return freqs, freqs.astype("<u2").tobytes(), 256


def rans_compress_bytes(data: bytes, prob_bits: int = PROB_BITS_DEFAULT,
                        lanes: Optional[int] = None) -> bytes:
    """Entropy-code `data`.  ``lanes=None`` auto-routes: the scalar
    single-lane path (original blob layout) for small payloads, the
    vectorized interleaved coder above ``_LANES_MIN_BYTES``.  Forcing
    ``lanes=1`` always yields the original layout byte-for-byte."""
    symbols = np.frombuffer(data, dtype=np.uint8)
    if symbols.size == 0:
        return struct.pack("<IBH", 0, prob_bits, 0)
    if lanes is None:
        lanes = _env_lanes()
        if lanes is None:
            lanes = _auto_lanes(symbols.size)
    if lanes & (lanes - 1) or not 1 <= lanes <= _LANES_MAX:
        raise ValueError(f"lanes must be a power of two in 1..{_LANES_MAX}")
    freqs, table, asize = _freq_table(symbols, prob_bits)
    if lanes == 1:
        words, state = rans_encode(symbols, freqs, prob_bits)
        header = struct.pack("<IBH", symbols.size, prob_bits, asize)
        tail = (struct.pack("<II", state, words.size)
                + words[::-1].astype("<u2").tobytes())
        return header + table + tail
    # the single-symbol alphabet (f == 2**prob_bits) overflows the device
    # kernel's uint32 x_max; only the NumPy uint64 lanes handle it
    if asize > 1 and _use_device_rans(symbols.size):
        from repro.kernels.rans_lanes import rans_encode_interleaved_device

        words, states = rans_encode_interleaved_device(
            symbols, freqs, lanes, prob_bits)
    else:
        words, states = rans_encode_interleaved(
            symbols, freqs, lanes, prob_bits)
    header = struct.pack("<IBBH", symbols.size, prob_bits | 0x80,
                         lanes.bit_length() - 1, asize)
    return (header + table + states.astype("<u4").tobytes()
            + struct.pack("<I", words.size) + words.astype("<u2").tobytes())


def _read_freq_table(blob: bytes, asize: int, off: int) -> Tuple[np.ndarray, int]:
    if asize < 256:  # sparse (symbol, freq) pairs
        syms = np.frombuffer(blob, dtype="<u1", count=asize, offset=off)
        off += asize
        vals = np.frombuffer(blob, dtype="<u2", count=asize, offset=off)
        off += 2 * asize
        freqs = np.zeros(256, dtype=np.uint32)
        freqs[syms] = vals
        return freqs, off
    freqs = np.frombuffer(blob, dtype="<u2", count=asize, offset=off).astype(np.uint32)
    return freqs, off + 2 * asize


def _parse_interleaved(blob: bytes):
    """Header/table/state/word fields of a multi-lane blob."""
    n, pbb, lane_exp, asize = struct.unpack_from("<IBBH", blob, 0)
    lanes = 1 << lane_exp
    freqs, off = _read_freq_table(blob, asize, 8)
    states = np.frombuffer(blob, dtype="<u4", count=lanes, offset=off)
    off += 4 * lanes
    (n_words,) = struct.unpack_from("<I", blob, off)
    off += 4
    words = np.frombuffer(blob, dtype="<u2", count=n_words, offset=off)
    return n, pbb & 0x7F, lanes, asize, freqs, states, words


def rans_decompress_bytes(blob: bytes) -> bytes:
    n, prob_bits, = struct.unpack_from("<IB", blob, 0)
    if n == 0:
        return b""
    if prob_bits & 0x80:  # interleaved layout
        n, pb, lanes, asize, freqs, states, words = _parse_interleaved(blob)
        if asize > 1 and _use_device_rans(n):
            from repro.kernels.rans_lanes import \
                rans_decode_interleaved_device

            out = rans_decode_interleaved_device(
                words, states, n, freqs, lanes, pb)
        else:
            out = rans_decode_interleaved(words, states, n, freqs, lanes, pb)
        return out.tobytes()
    n, prob_bits, asize = struct.unpack_from("<IBH", blob, 0)
    freqs, off = _read_freq_table(blob, asize, 7)
    state, n_words = struct.unpack_from("<II", blob, off)
    off += 8
    words = np.frombuffer(blob, dtype="<u2", count=n_words, offset=off)[::-1]
    out = rans_decode(words, state, n, freqs, prob_bits)
    return out.astype(np.uint8).tobytes()


def rans_decompress_to_device(blob: bytes):
    """Decode a blob into a **device-resident** uint8 array (a jnp array)
    — the serve path's decompress-to-tokens hands this straight to the
    token-unpack stage without a host byte round trip.  Layouts the lane
    kernel doesn't cover (single-lane, empty, single-symbol alphabet)
    decode on the host and upload."""
    import jax.numpy as jnp

    n, prob_bits, = struct.unpack_from("<IB", blob, 0)
    if n and prob_bits & 0x80:
        n, pb, lanes, asize, freqs, states, words = _parse_interleaved(blob)
        if asize > 1:
            from repro.kernels.rans_lanes import \
                rans_decode_interleaved_device

            return rans_decode_interleaved_device(
                words, states, n, freqs, lanes, pb, to_host=False)
    return jnp.asarray(
        np.frombuffer(rans_decompress_bytes(blob), np.uint8))
