"""Reference rANS entropy coder (numpy/python, exact-arithmetic oracle).

This is the entropy-coding stage of the paper's model of Zstd
(``FSE(LZ77(...))`` — FSE is the table-driven cousin of rANS) implemented
from scratch.  It serves three roles:

1. oracle for the JAX/TPU interleaved coder in ``repro.core.rans``,
2. entropy stage of the from-scratch ``repro-lzr`` backend
   (LZ77 -> rANS ~= the paper's LZ77 -> FSE description of Zstd),
3. order-0 coder over *token ids* for the token-stream storage mode.

Classic 32-bit-state rANS with 16-bit renormalization; python ints make
the arithmetic exact, numpy handles tables.  Streaming convention: encoder
walks the symbols in reverse and appends 16-bit words; the serialized
stream stores those words reversed so the decoder reads forward.

Blob format note: the header's `asize` field distinguishes a dense
256-entry frequency table (asize == 256, the original layout) from the
sparse (symbol, freq)-pair table added for small/low-alphabet inputs
(asize in 1..255).  This reader accepts both; readers predating the
sparse layout cannot parse sparse blobs.
"""

from __future__ import annotations

import struct
from typing import Tuple

import numpy as np

PROB_BITS_DEFAULT = 12
_STATE_LOW = 1 << 16  # renormalization lower bound


def normalize_freqs(counts: np.ndarray, prob_bits: int = PROB_BITS_DEFAULT) -> np.ndarray:
    """Scale a histogram to sum to 2**prob_bits with every observed symbol
    keeping frequency >= 1 (largest-remainder apportionment)."""
    counts = np.asarray(counts, dtype=np.float64)
    total = counts.sum()
    target = 1 << prob_bits
    if total <= 0:
        raise ValueError("empty histogram")
    present = counts > 0
    n_present = int(present.sum())
    if n_present > target:
        raise ValueError(f"alphabet has {n_present} symbols > table size {target}")
    raw = counts * (target / total)
    freqs = np.floor(raw).astype(np.int64)
    freqs[present & (freqs == 0)] = 1
    diff = target - int(freqs.sum())
    if diff > 0:  # hand out leftovers by largest remainder
        rema = raw - np.floor(raw)
        rema[~present] = -1.0
        order = np.argsort(-rema, kind="stable")
        freqs[order[:diff]] += 1
    elif diff < 0:  # take back from the largest entries (keep >= 1)
        order = np.argsort(-freqs, kind="stable")
        k = 0
        while diff < 0:
            idx = order[k % len(order)]
            if freqs[idx] > 1:
                freqs[idx] -= 1
                diff += 1
            k += 1
    assert freqs.sum() == target
    return freqs.astype(np.uint32)


def rans_encode(
    symbols: np.ndarray, freqs: np.ndarray, prob_bits: int = PROB_BITS_DEFAULT
) -> Tuple[np.ndarray, int]:
    """Encode `symbols` under `freqs`; returns (emitted u16 words, state)."""
    cum = np.concatenate(([0], np.cumsum(freqs.astype(np.int64))))
    x = _STATE_LOW
    words = []
    shift = 16 + 16 - prob_bits  # x_max = freq << shift keeps x < 2**32
    for s in symbols[::-1]:
        s = int(s)
        f = int(freqs[s])
        if f == 0:
            raise ValueError(f"symbol {s} has zero frequency")
        x_max = f << shift
        while x >= x_max:
            words.append(x & 0xFFFF)
            x >>= 16
        x = ((x // f) << prob_bits) + (x % f) + int(cum[s])
    return np.array(words, dtype=np.uint16), x


def rans_decode(
    words: np.ndarray, state: int, n: int, freqs: np.ndarray,
    prob_bits: int = PROB_BITS_DEFAULT,
) -> np.ndarray:
    """Inverse of `rans_encode`. `words` in emission order."""
    cum = np.concatenate(([0], np.cumsum(freqs.astype(np.int64))))
    # slot -> symbol lookup
    slot2sym = np.repeat(
        np.arange(len(freqs), dtype=np.int64), freqs.astype(np.int64)
    )
    mask = (1 << prob_bits) - 1
    x = int(state)
    pos = len(words) - 1  # consume in reverse emission order
    out = np.empty(n, dtype=np.int64)
    for i in range(n):
        slot = x & mask
        s = int(slot2sym[slot])
        out[i] = s
        x = int(freqs[s]) * (x >> prob_bits) + slot - int(cum[s])
        while x < _STATE_LOW:
            if pos < 0:
                raise ValueError("rANS stream underflow")
            x = (x << 16) | int(words[pos])
            pos -= 1
    return out


# ---------------------------------------------------------------------------
# Self-contained byte-stream format
# ---------------------------------------------------------------------------
#
#   u32le n_symbols | u8 prob_bits | u16le alphabet_size
#   freqs: alphabet_size x u16le   | u32le state | u32le n_words | words u16le
# (words stored reversed so decode reads forward)


def rans_compress_bytes(data: bytes, prob_bits: int = PROB_BITS_DEFAULT) -> bytes:
    symbols = np.frombuffer(data, dtype=np.uint8)
    if symbols.size == 0:
        return struct.pack("<IBH", 0, prob_bits, 0)
    counts = np.bincount(symbols, minlength=256)
    freqs = normalize_freqs(counts, prob_bits)
    words, state = rans_encode(symbols, freqs, prob_bits)
    # Header `asize` field: 256 = dense 256-entry table; 1..255 = sparse
    # table of (symbol u8, freq u2) pairs.  Sparse wins on small or
    # low-alphabet inputs, where a 512-byte dense table would dominate
    # the blob (3 bytes/symbol vs 2 bytes/slot -> sparse iff k < 171).
    nonzero = np.flatnonzero(freqs)
    if nonzero.size < 171:
        header = struct.pack("<IBH", symbols.size, prob_bits, nonzero.size)
        table = (nonzero.astype("<u1").tobytes()
                 + freqs[nonzero].astype("<u2").tobytes())
    else:
        header = struct.pack("<IBH", symbols.size, prob_bits, 256)
        table = freqs.astype("<u2").tobytes()
    tail = struct.pack("<II", state, words.size) + words[::-1].astype("<u2").tobytes()
    return header + table + tail


def rans_decompress_bytes(blob: bytes) -> bytes:
    n, prob_bits, asize = struct.unpack_from("<IBH", blob, 0)
    off = 7
    if n == 0:
        return b""
    if asize < 256:  # sparse (symbol, freq) pairs
        syms = np.frombuffer(blob, dtype="<u1", count=asize, offset=off)
        off += asize
        vals = np.frombuffer(blob, dtype="<u2", count=asize, offset=off)
        off += 2 * asize
        freqs = np.zeros(256, dtype=np.uint32)
        freqs[syms] = vals
    else:
        freqs = np.frombuffer(blob, dtype="<u2", count=asize, offset=off).astype(np.uint32)
        off += 2 * asize
    state, n_words = struct.unpack_from("<II", blob, off)
    off += 8
    words = np.frombuffer(blob, dtype="<u2", count=n_words, offset=off)[::-1]
    out = rans_decode(words, state, n, freqs, prob_bits)
    return out.astype(np.uint8).tobytes()
