"""Shannon-entropy accounting (paper §3.6): theoretical limits and the
compression-efficiency metric η = CR_actual / CR_theoretical — plus the
byte-histogram primitive the rANS frequency tables are built from.

``byte_histogram`` is the one entry point: vectorized ``np.bincount`` on
CPU hosts, the Pallas one-hot-matmul histogram kernel
(``repro.kernels.histogram``) when a non-CPU backend is attached — the
same auto-routing convention the token-pack stage uses.  The rANS coders
(``repro.core.rans_np`` / ``repro.core.rans``) and the bytes fast path of
``shannon_entropy`` all feed from it, so frequency counting is vectorized
everywhere on the codec hot path.
"""

from __future__ import annotations

import math
from collections import Counter
from typing import Optional, Union

import numpy as np

Data = Union[str, bytes]

# device-histogram crossover (bytes): even with an accelerator attached,
# small payloads pay more in upload + dispatch than the one-hot matmul
# saves — measured on the kernel_throughput sweep; override with
# REPRO_HIST_DEVICE_MIN when re-tuning on new hardware
_DEVICE_MIN_BYTES = 1 << 15


def byte_histogram(data, use_device: Optional[bool] = None) -> np.ndarray:
    """256-bucket histogram of a byte payload (bytes or uint8 ndarray).

    ``use_device=None`` auto-routes through the shared policy in
    ``repro.core.device``: the Pallas histogram kernel only when a
    non-CPU backend is attached *and* the payload clears the
    ``REPRO_HIST_DEVICE_MIN`` crossover; ``np.bincount`` otherwise.
    Both paths are exact (kernel parity is asserted in
    tests/test_kernels.py)."""
    arr = (np.frombuffer(data, np.uint8)
           if isinstance(data, (bytes, bytearray, memoryview))
           else np.asarray(data, np.uint8))
    from repro.core import device as _device

    if _device.use_device(arr.size, "REPRO_HIST_DEVICE_MIN",
                          _DEVICE_MIN_BYTES, force=use_device) and arr.size:
        import jax

        from repro.kernels.histogram import byte_histogram_device

        # compiled kernel on real accelerators; interpret mode only when
        # the device path is forced on a CPU host (tests, parity smokes)
        return byte_histogram_device(
            arr, interpret=jax.default_backend() == "cpu")
    return np.bincount(arr, minlength=256).astype(np.int64)


def shannon_entropy(data: Data) -> float:
    """H(X) in bits/symbol over character (str) or byte (bytes) frequencies
    (Eq. 23).  Bytes take the vectorized histogram path."""
    if len(data) == 0:
        return 0.0
    if isinstance(data, (bytes, bytearray, memoryview)):
        counts = byte_histogram(data)
        p = counts[counts > 0] / float(len(data))
        return float(-(p * np.log2(p)).sum())
    counts = Counter(data)
    n = len(data)
    return -sum((c / n) * math.log2(c / n) for c in counts.values())


def theoretical_min_bytes(data: Data) -> float:
    """S_min = H(X) * |T| / 8 (Eq. 24)."""
    return shannon_entropy(data) * len(data) / 8.0


def theoretical_cr(data: Data) -> float:
    """CR_theoretical = 8 / H(X) (Eq. 25). Infinite for constant input."""
    h = shannon_entropy(data)
    return math.inf if h == 0.0 else 8.0 / h


def efficiency(data: Data, compressed_size: int) -> float:
    """η (Eq. 26). NOTE: an LZ coder exploits *sequence* structure that an
    order-0 character model cannot see, so η > 1 is possible and expected
    for repetitive text; the paper's 60–80 % band refers to low-redundancy
    content."""
    if compressed_size <= 0:
        raise ValueError("compressed_size must be positive")
    cr_actual = len(data) if isinstance(data, bytes) else len(data.encode("utf-8"))
    cr_actual = cr_actual / compressed_size
    cr_theory = theoretical_cr(data)
    return 0.0 if math.isinf(cr_theory) else cr_actual / cr_theory


def bits_per_char(text: str, compressed_size: int) -> float:
    """BPC (Eq. 33)."""
    if len(text) == 0:
        return 0.0
    return compressed_size * 8.0 / len(text)
