"""Shannon-entropy accounting (paper §3.6): theoretical limits and the
compression-efficiency metric η = CR_actual / CR_theoretical."""

from __future__ import annotations

import math
from collections import Counter
from typing import Union

Data = Union[str, bytes]


def shannon_entropy(data: Data) -> float:
    """H(X) in bits/symbol over character (str) or byte (bytes) frequencies
    (Eq. 23)."""
    if len(data) == 0:
        return 0.0
    counts = Counter(data)
    n = len(data)
    return -sum((c / n) * math.log2(c / n) for c in counts.values())


def theoretical_min_bytes(data: Data) -> float:
    """S_min = H(X) * |T| / 8 (Eq. 24)."""
    return shannon_entropy(data) * len(data) / 8.0


def theoretical_cr(data: Data) -> float:
    """CR_theoretical = 8 / H(X) (Eq. 25). Infinite for constant input."""
    h = shannon_entropy(data)
    return math.inf if h == 0.0 else 8.0 / h


def efficiency(data: Data, compressed_size: int) -> float:
    """η (Eq. 26). NOTE: an LZ coder exploits *sequence* structure that an
    order-0 character model cannot see, so η > 1 is possible and expected
    for repetitive text; the paper's 60–80 % band refers to low-redundancy
    content."""
    if compressed_size <= 0:
        raise ValueError("compressed_size must be positive")
    cr_actual = len(data) if isinstance(data, bytes) else len(data.encode("utf-8"))
    cr_actual = cr_actual / compressed_size
    cr_theory = theoretical_cr(data)
    return 0.0 if math.isinf(cr_theory) else cr_actual / cr_theory


def bits_per_char(text: str, compressed_size: int) -> float:
    """BPC (Eq. 33)."""
    if len(text) == 0:
        return 0.0
    return compressed_size * 8.0 / len(text)
