"""Our own LZ77 codec ("repro-lz") — the dictionary-coding substrate the
paper's Zstd stage is built from (§3.2.2: ``C_zstd = FSE(LZ77(T, W, L))``).

The wire format is LZ4-block-style: greedy hash-table match finding,
min-match 4, 64 KiB window, sequences of

    [token: litlen<<4 | (matchlen-4)] [litlen ext*] [literals]
    [offset u16le] [matchlen ext*]

with a final literals-only sequence.  Pure Python + slice tricks; it exists
so the framework owns a complete compression stack end-to-end (the
``zstandard`` C library remains the paper-faithful default backend, this is
the from-scratch baseline and the feeder for the rANS entropy stage).

Dictionary (prefix) mode: ``lz_compress(data, prefix=d)`` seeds the match
window with ``d`` — matches may reach back into the dictionary, which is
exactly how zstd's trained-dictionary mode recovers cross-record
redundancy for payloads too short to build their own window.  The output
covers only ``data``; ``lz_decompress(comp, prefix=d)`` must be handed the
identical dictionary (the codec layer threads a fingerprint through frame
headers to guarantee that).
"""

from __future__ import annotations

import threading

_MIN_MATCH = 4
_WINDOW = 0xFFFF  # 64 KiB - 1, max encodable offset
_HASH_MASK = (1 << 20) - 1

# Seeded match tables per dictionary: a dict-primed compress call would
# otherwise re-hash every prefix position per record — per-record O(dict)
# setup across a whole shard.  Small bounded memo; entries are copied per
# call because compression mutates the table.  The lock matters: parallel
# compactions (per-shard locks allow them) score dict candidates
# concurrently, and unsynchronized eviction could double-pop.
_PREFIX_TABLES: dict = {}
_PREFIX_TABLES_MAX = 8
_PREFIX_TABLES_LOCK = threading.Lock()


def _seeded_table(prefix: bytes) -> dict:
    """Match-table entries fully inside the prefix (data-independent, so
    cacheable); the caller adds the few positions whose keys straddle the
    prefix/payload boundary."""
    with _PREFIX_TABLES_LOCK:
        cached = _PREFIX_TABLES.get(prefix)
        if cached is None:
            cached = {}
            for j in range(0, max(len(prefix) - _MIN_MATCH + 1, 0)):
                cached[prefix[j : j + _MIN_MATCH]] = j
            while len(_PREFIX_TABLES) >= _PREFIX_TABLES_MAX:
                _PREFIX_TABLES.pop(next(iter(_PREFIX_TABLES)))
            _PREFIX_TABLES[prefix] = cached
        return dict(cached)


def _ext_len(value: int) -> bytes:
    """LZ4-style length extension: 255-run + remainder."""
    out = bytearray()
    while value >= 255:
        out.append(255)
        value -= 255
    out.append(value)
    return bytes(out)


def _match_len(data: bytes, a: int, b: int, n: int) -> int:
    """Length of the common run data[a:] == data[b:] (a < b), capped at n-b."""
    l = 0
    step = 64
    while b + l + step <= n and data[a + l : a + l + step] == data[b + l : b + l + step]:
        l += step
    while b + l < n and data[a + l] == data[b + l]:
        l += 1
    return l


def lz_compress(data: bytes, prefix: bytes = b"") -> bytes:
    """Greedy single-pass LZ77; returns self-contained block.

    ``prefix`` seeds the window without being emitted: matches may start
    inside it (offsets reach at most ``_WINDOW`` back), so short payloads
    that share structure with the dictionary compress to a few
    dict-offset matches.  ``prefix=b""`` is byte-identical to the
    historical no-dictionary behavior.
    """
    plen = len(prefix)
    buf = prefix + data if plen else data
    n = len(buf)
    out = bytearray()
    if n == plen:
        return bytes(out)
    limit = n - _MIN_MATCH
    # seed the table with every dictionary position (last occurrence wins:
    # closest candidate, shortest offsets); the fully-in-prefix entries
    # come from a per-dictionary memo, only the boundary-straddling keys
    # depend on the payload
    if plen:
        table = _seeded_table(prefix)
        for j in range(max(plen - _MIN_MATCH + 1, 0), min(plen, limit + 1)):
            table[buf[j : j + _MIN_MATCH]] = j
    else:
        table = {}
    i = plen
    lit_start = plen
    # leave the last MIN_MATCH bytes as literals (simplifies the tail)
    while i <= limit:
        key = buf[i : i + _MIN_MATCH]
        cand = table.get(key)
        table[key] = i
        if cand is not None and i - cand <= _WINDOW:
            mlen = _match_len(buf, cand, i, n)
            if mlen >= _MIN_MATCH:
                lit_len = i - lit_start
                offset = i - cand
                tok_lit = min(lit_len, 15)
                tok_match = min(mlen - _MIN_MATCH, 15)
                out.append((tok_lit << 4) | tok_match)
                if tok_lit == 15:
                    out += _ext_len(lit_len - 15)
                out += buf[lit_start:i]
                out.append(offset & 0xFF)
                out.append(offset >> 8)
                if tok_match == 15:
                    out += _ext_len(mlen - _MIN_MATCH - 15)
                # seed the table sparsely inside the match (speed/ratio balance)
                end = i + mlen
                for j in range(i + 1, min(end, limit), 7):
                    table[buf[j : j + _MIN_MATCH]] = j
                i = end
                lit_start = i
                continue
        i += 1
    # final literals-only sequence
    lit_len = n - lit_start
    tok_lit = min(lit_len, 15)
    out.append(tok_lit << 4)
    if tok_lit == 15:
        out += _ext_len(lit_len - 15)
    out += buf[lit_start:n]
    return bytes(out)


def lz_decompress(comp: bytes, prefix: bytes = b"") -> bytes:
    out = bytearray(prefix)
    plen = len(prefix)
    i, n = 0, len(comp)
    if n == 0:
        return b""
    while i < n:
        token = comp[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                b = comp[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if lit_len:
            out += comp[i : i + lit_len]
            i += lit_len
        if i >= n:  # final sequence: literals only
            break
        offset = comp[i] | (comp[i + 1] << 8)
        i += 2
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                b = comp[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        start = len(out) - offset
        if start < 0:
            raise ValueError("corrupt LZ stream: offset before start")
        if offset >= mlen:
            out += out[start : start + mlen]
        else:
            # overlapping copy: the pattern repeats with period `offset`
            seg = bytes(out[start:])
            reps = mlen // offset + 1
            out += (seg * reps)[:mlen]
    return bytes(out[plen:])
