"""Our own LZ77 codec ("repro-lz") — the dictionary-coding substrate the
paper's Zstd stage is built from (§3.2.2: ``C_zstd = FSE(LZ77(T, W, L))``).

The wire format is LZ4-block-style: greedy hash-table match finding,
min-match 4, 64 KiB window, sequences of

    [token: litlen<<4 | (matchlen-4)] [litlen ext*] [literals]
    [offset u16le] [matchlen ext*]

with a final literals-only sequence.  Pure Python + slice tricks; it exists
so the framework owns a complete compression stack end-to-end (the
``zstandard`` C library remains the paper-faithful default backend, this is
the from-scratch baseline and the feeder for the rANS entropy stage).
"""

from __future__ import annotations

_MIN_MATCH = 4
_WINDOW = 0xFFFF  # 64 KiB - 1, max encodable offset
_HASH_MASK = (1 << 20) - 1


def _ext_len(value: int) -> bytes:
    """LZ4-style length extension: 255-run + remainder."""
    out = bytearray()
    while value >= 255:
        out.append(255)
        value -= 255
    out.append(value)
    return bytes(out)


def _match_len(data: bytes, a: int, b: int, n: int) -> int:
    """Length of the common run data[a:] == data[b:] (a < b), capped at n-b."""
    l = 0
    step = 64
    while b + l + step <= n and data[a + l : a + l + step] == data[b + l : b + l + step]:
        l += step
    while b + l < n and data[a + l] == data[b + l]:
        l += 1
    return l


def lz_compress(data: bytes) -> bytes:
    """Greedy single-pass LZ77; returns self-contained block."""
    n = len(data)
    out = bytearray()
    if n == 0:
        return bytes(out)
    table: dict = {}
    i = 0
    lit_start = 0
    # leave the last MIN_MATCH bytes as literals (simplifies the tail)
    limit = n - _MIN_MATCH
    while i <= limit:
        key = data[i : i + _MIN_MATCH]
        cand = table.get(key)
        table[key] = i
        if cand is not None and i - cand <= _WINDOW:
            mlen = _match_len(data, cand, i, n)
            if mlen >= _MIN_MATCH:
                lit_len = i - lit_start
                offset = i - cand
                tok_lit = min(lit_len, 15)
                tok_match = min(mlen - _MIN_MATCH, 15)
                out.append((tok_lit << 4) | tok_match)
                if tok_lit == 15:
                    out += _ext_len(lit_len - 15)
                out += data[lit_start:i]
                out.append(offset & 0xFF)
                out.append(offset >> 8)
                if tok_match == 15:
                    out += _ext_len(mlen - _MIN_MATCH - 15)
                # seed the table sparsely inside the match (speed/ratio balance)
                end = i + mlen
                for j in range(i + 1, min(end, limit), 7):
                    table[data[j : j + _MIN_MATCH]] = j
                i = end
                lit_start = i
                continue
        i += 1
    # final literals-only sequence
    lit_len = n - lit_start
    tok_lit = min(lit_len, 15)
    out.append(tok_lit << 4)
    if tok_lit == 15:
        out += _ext_len(lit_len - 15)
    out += data[lit_start:n]
    return bytes(out)


def lz_decompress(comp: bytes) -> bytes:
    out = bytearray()
    i, n = 0, len(comp)
    if n == 0:
        return b""
    while i < n:
        token = comp[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                b = comp[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if lit_len:
            out += comp[i : i + lit_len]
            i += lit_len
        if i >= n:  # final sequence: literals only
            break
        offset = comp[i] | (comp[i + 1] << 8)
        i += 2
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                b = comp[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        start = len(out) - offset
        if start < 0:
            raise ValueError("corrupt LZ stream: offset before start")
        if offset >= mlen:
            out += out[start : start + mlen]
        else:
            # overlapping copy: the pattern repeats with period `offset`
            seg = bytes(out[start:])
            reps = mlen // offset + 1
            out += (seg * reps)[:mlen]
    return bytes(out)
