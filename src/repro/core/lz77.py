"""Our own LZ77 codec ("repro-lz") — the dictionary-coding substrate the
paper's Zstd stage is built from (§3.2.2: ``C_zstd = FSE(LZ77(T, W, L))``).

The wire format is LZ4-block-style: greedy hash-table match finding,
min-match 4, 64 KiB window, sequences of

    [token: litlen<<4 | (matchlen-4)] [litlen ext*] [literals]
    [offset u16le] [matchlen ext*]

with a final literals-only sequence.

Two implementations share that wire format:

* the **scalar** path — the original pure-Python greedy loop, kept
  byte-for-byte as the reference oracle and used for small payloads
  (below ``_NP_MIN_COMPRESS``/``_NP_MIN_DECOMPRESS``) where NumPy's
  fixed per-call overhead loses to the tight loop;
* the **vectorized** path — match candidates from a hashed head-table
  filled block-by-block with NumPy scatter/gather (plus short-period
  run detection), match lengths from batched 8-byte-gram XOR rounds,
  greedy selection as a tiny Python jump loop over precomputed arrays,
  and the sequence stream emitted with fused cumsum/scatter passes.
  Output is a valid stream of the same format (round-trip-identical);
  the exact byte stream may differ from the scalar parse because the
  vectorized candidate table sees *every* position while the scalar
  loop seeds sparsely inside matches.

A third, **device** variant reuses the vectorized path's candidate
contract but runs the match-finding stage (gram/hash build, head-table
scatter, batched extension) on the accelerator via
``repro.kernels.lz_match``; greedy selection and sequence emit are the
*same host code* as the vectorized path, so its output is byte-identical
to the vectorized parse.

Every path decodes the others' output — the format carries no
producer mark.  ``REPRO_LZ_MODE=scalar|vector|device|auto`` (env)
forces a path; ``auto`` (default) routes on payload size and a cheap
byte-run probe (run-dominated inputs like zero pages stay scalar, whose
skip-ahead loop beats any per-position vectorization), and takes the
device match finder only when a non-CPU backend is attached and the
payload clears ``REPRO_LZ_DEVICE_MIN`` (see ``repro.core.device``).

Dictionary (prefix) mode: ``lz_compress(data, prefix=d)`` seeds the
match window with ``d`` — matches may reach back into the dictionary,
which is exactly how zstd's trained-dictionary mode recovers
cross-record redundancy for payloads too short to build their own
window.  The output covers only ``data``; ``lz_decompress(comp,
prefix=d)`` must be handed the identical dictionary (the codec layer
threads a fingerprint through frame headers to guarantee that).
"""

from __future__ import annotations

import threading
from array import array

import numpy as np

from repro.core import env

_MIN_MATCH = 4
_WINDOW = 0xFFFF  # 64 KiB - 1, max encodable offset
_HASH_MASK = (1 << 20) - 1

# -- vectorized-path tuning ------------------------------------------------
_NP_MIN_COMPRESS = 2048     # payload bytes below which scalar compress wins
_NP_MIN_DECOMPRESS = 4096   # compressed bytes below which scalar decode wins
_HASH_BITS = 20             # head-table size (2^bits int32 entries)
_HASH_MUL = np.uint32(2654435761)
_SCAN_BLOCK = 1024          # head-table scatter granularity: candidates are
                            # invisible within the same block (run detection
                            # catches the short-period ones); smaller blocks
                            # buy ~1% ratio for measurably slower scans
_EXT_ROUNDS = 3             # eager extension: 8-byte grams, cap 4+8*rounds
_RUN_PROBE = 8192           # bytes sampled by the run-dominance probe
_DEVICE_MIN_COMPRESS = 1 << 20   # auto-mode device crossover (bytes): the
                            # candidate stage must amortize the byte
                            # upload + ok/cand/mlen download; override
                            # with REPRO_LZ_DEVICE_MIN after re-measuring
_DECODE_MAX_ROUNDS = 64     # frontier-batch rounds before python fallback

# Seeded match tables per dictionary (scalar path): a dict-primed compress
# call would otherwise re-hash every prefix position per record — per-record
# O(dict) setup across a whole shard.  Small bounded memo; entries are
# copied per call because compression mutates the table.  The lock matters:
# parallel compactions (per-shard locks allow them) score dict candidates
# concurrently, and unsynchronized eviction could double-pop.
_PREFIX_TABLES: dict = {}
_PREFIX_TABLES_MAX = 8
_PREFIX_TABLES_LOCK = threading.Lock()


def _lz_mode() -> str:
    return env.read("REPRO_LZ_MODE")


def _seeded_table(prefix: bytes) -> dict:
    """Match-table entries fully inside the prefix (data-independent, so
    cacheable); the caller adds the few positions whose keys straddle the
    prefix/payload boundary."""
    with _PREFIX_TABLES_LOCK:
        cached = _PREFIX_TABLES.get(prefix)
        if cached is None:
            cached = {}
            for j in range(0, max(len(prefix) - _MIN_MATCH + 1, 0)):
                cached[prefix[j : j + _MIN_MATCH]] = j
            while len(_PREFIX_TABLES) >= _PREFIX_TABLES_MAX:
                _PREFIX_TABLES.pop(next(iter(_PREFIX_TABLES)))
            _PREFIX_TABLES[prefix] = cached
        return dict(cached)


def _ext_len(value: int) -> bytes:
    """LZ4-style length extension: 255-run + remainder."""
    out = bytearray()
    while value >= 255:
        out.append(255)
        value -= 255
    out.append(value)
    return bytes(out)


def _match_len(data: bytes, a: int, b: int, n: int) -> int:
    """Length of the common run data[a:] == data[b:] (a < b), capped at n-b."""
    l = 0
    step = 64
    while b + l + step <= n and data[a + l : a + l + step] == data[b + l : b + l + step]:
        l += step
    while b + l < n and data[a + l] == data[b + l]:
        l += 1
    return l


def _match_len_fast(buf: bytes, a: int, b: int, n: int) -> int:
    """`_match_len` via doubling + bisection on C-level slice compares —
    used by the vectorized path's lazy tail extension, where matches are
    long and the per-byte loop would dominate."""
    cap = n - b
    lo, step = 0, 64
    while lo + step <= cap and buf[a + lo : a + lo + step] == buf[b + lo : b + lo + step]:
        lo += step
        step <<= 1
    hi = min(lo + step, cap)
    while lo < hi:
        mid = (lo + hi + 1) >> 1
        if buf[a + lo : a + mid] == buf[b + lo : b + mid]:
            lo = mid
        else:
            hi = mid - 1
    return lo


def _only_literals(buf: bytes, plen: int, n: int) -> bytes:
    out = bytearray()
    lit_len = n - plen
    tok_lit = min(lit_len, 15)
    out.append(tok_lit << 4)
    if tok_lit == 15:
        out += _ext_len(lit_len - 15)
    out += buf[plen:n]
    return bytes(out)


# ---------------------------------------------------------------------------
# Scalar path (reference oracle)
# ---------------------------------------------------------------------------


def _lz_compress_scalar(data: bytes, prefix: bytes = b"") -> bytes:
    """Greedy single-pass LZ77; returns self-contained block.

    ``prefix`` seeds the window without being emitted: matches may start
    inside it (offsets reach at most ``_WINDOW`` back), so short payloads
    that share structure with the dictionary compress to a few
    dict-offset matches.  ``prefix=b""`` is byte-identical to the
    historical no-dictionary behavior.
    """
    plen = len(prefix)
    buf = prefix + data if plen else data
    n = len(buf)
    out = bytearray()
    if n == plen:
        return bytes(out)
    limit = n - _MIN_MATCH
    # seed the table with every dictionary position (last occurrence wins:
    # closest candidate, shortest offsets); the fully-in-prefix entries
    # come from a per-dictionary memo, only the boundary-straddling keys
    # depend on the payload
    if plen:
        table = _seeded_table(prefix)
        for j in range(max(plen - _MIN_MATCH + 1, 0), min(plen, limit + 1)):
            table[buf[j : j + _MIN_MATCH]] = j
    else:
        table = {}
    i = plen
    lit_start = plen
    # leave the last MIN_MATCH bytes as literals (simplifies the tail)
    while i <= limit:
        key = buf[i : i + _MIN_MATCH]
        cand = table.get(key)
        table[key] = i
        if cand is not None and i - cand <= _WINDOW:
            mlen = _match_len(buf, cand, i, n)
            if mlen >= _MIN_MATCH:
                lit_len = i - lit_start
                offset = i - cand
                tok_lit = min(lit_len, 15)
                tok_match = min(mlen - _MIN_MATCH, 15)
                out.append((tok_lit << 4) | tok_match)
                if tok_lit == 15:
                    out += _ext_len(lit_len - 15)
                out += buf[lit_start:i]
                out.append(offset & 0xFF)
                out.append(offset >> 8)
                if tok_match == 15:
                    out += _ext_len(mlen - _MIN_MATCH - 15)
                # seed the table sparsely inside the match (speed/ratio balance)
                end = i + mlen
                for j in range(i + 1, min(end, limit), 7):
                    table[buf[j : j + _MIN_MATCH]] = j
                i = end
                lit_start = i
                continue
        i += 1
    # final literals-only sequence
    lit_len = n - lit_start
    tok_lit = min(lit_len, 15)
    out.append(tok_lit << 4)
    if tok_lit == 15:
        out += _ext_len(lit_len - 15)
    out += buf[lit_start:n]
    return bytes(out)


def _lz_decompress_scalar(comp: bytes, prefix: bytes = b"") -> bytes:
    out = bytearray(prefix)
    plen = len(prefix)
    i, n = 0, len(comp)
    if n == 0:
        return b""
    ended = False
    while i < n:
        token = comp[i]
        i += 1
        lit_len = token >> 4
        if lit_len == 15:
            while True:
                if i >= n:
                    raise ValueError("corrupt LZ stream: truncated")
                b = comp[i]
                i += 1
                lit_len += b
                if b != 255:
                    break
        if i + lit_len > n:
            raise ValueError("corrupt LZ stream: truncated")
        if lit_len:
            out += comp[i : i + lit_len]
            i += lit_len
        if i >= n:  # final sequence: literals only
            ended = True
            break
        if i + 2 > n:
            raise ValueError("corrupt LZ stream: truncated")
        offset = comp[i] | (comp[i + 1] << 8)
        i += 2
        mlen = (token & 0xF) + _MIN_MATCH
        if (token & 0xF) == 15:
            while True:
                if i >= n:
                    raise ValueError("corrupt LZ stream: truncated")
                b = comp[i]
                i += 1
                mlen += b
                if b != 255:
                    break
        if offset == 0:
            raise ValueError("corrupt LZ stream: zero offset")
        start = len(out) - offset
        if start < 0:
            raise ValueError("corrupt LZ stream: offset before start")
        if offset >= mlen:
            out += out[start : start + mlen]
        else:
            # overlapping copy: the pattern repeats with period `offset`
            seg = bytes(out[start:])
            reps = mlen // offset + 1
            out += (seg * reps)[:mlen]
    if not ended:
        # a valid block always ends with a literals-only sequence (the
        # encoder emits one even when empty); stopping right after a match
        # means the tail was cut off
        raise ValueError("corrupt LZ stream: truncated")
    return bytes(out[plen:])


# ---------------------------------------------------------------------------
# Vectorized path
# ---------------------------------------------------------------------------


def _candidates_np(buf: bytes, plen: int, n: int):
    """Candidate stage of the vectorized parse: hashed head-table
    candidates + batched 8-byte-gram extension.

    Returns ``(ok, cand, mlen)`` over the ``n - 3`` positions holding a
    full 4-gram: ``ok`` marks positions with a verified in-window
    candidate, ``cand`` its source position, ``mlen`` the match length —
    exact when positive, a *lazy* marker when negative (cap survivors and
    out-of-room tails; ``_select_emit`` resolves those by memcmp).  The
    device match finder (``repro.kernels.lz_match``) produces the same
    contract, so both feed one shared selection/emit."""
    nv = n - 3   # positions holding a full 4-gram (valid match starts)
    n8 = n - 7   # positions holding a full 8-gram (extension bound)
    # every 4-gram as a little-endian uint32, via a 1-byte-strided view
    # (x86/ARM handle the unaligned loads; the copy aligns for gathers)
    v = np.ascontiguousarray(
        np.ndarray(shape=(nv,), dtype="<u4", buffer=buf, strides=(1,)))
    h = ((v * _HASH_MUL) >> np.uint32(32 - _HASH_BITS)).astype(np.intp)

    # head-table scatter, one block at a time: candidates always come from
    # an earlier block (`cand` read before `head` update), so a position
    # never proposes itself; duplicate hashes within a block resolve
    # last-wins, matching the "closest candidate" policy
    cand = np.empty(nv, np.intp)
    head = np.full(1 << _HASH_BITS, -1, np.intp)
    idx = np.arange(nv, dtype=np.intp)
    for a in range(0, nv, _SCAN_BLOCK):
        b = a + _SCAN_BLOCK
        hb = h[a:b]
        cand[a:b] = head[hb]
        head[hb] = idx[a:b]

    # short-period runs are invisible to the block scatter (same block) —
    # catch them directly: d=4 covers periods 1/2/4, d=3 period 3; nearer
    # candidates overwrite the cross-block ones (shorter offsets)
    eq = v[4:] == v[:-4]
    cand[4:][eq] = idx[:-4][eq]
    eq = v[3:] == v[:-3]
    cand[3:][eq] = idx[:-3][eq]

    # verify: exact 4-gram equality kills hash collisions; window-check
    ok = (cand >= 0) & (idx - cand <= _WINDOW) & (v[np.maximum(cand, 0)] == v)
    if plen:
        ok[:plen] = False  # matches may start only in the payload

    # eager extension: compare 8-byte grams at l, l+8, ...; a mismatching
    # gram contributes its common low-end bytes exactly (XOR trailing
    # zero-byte count), so mlen below the cap is exact.  Positions that hit
    # the cap, ran past the gram bound, or belong to a run-dominated input
    # (survivor set not shrinking) fall back to lazy memcmp extension at
    # selection time — long matches amortize it.
    v8 = np.ascontiguousarray(
        np.ndarray(shape=(n8,), dtype="<u8", buffer=buf, strides=(1,))) \
        if n8 > 0 else np.zeros(0, np.uint64)
    i_act = np.flatnonzero(ok)
    mlen = np.zeros(nv, np.int64)
    mlen[i_act] = _MIN_MATCH
    c_act = cand[i_act]
    l = _MIN_MATCH
    lazy_tails = []
    for _ in range(_EXT_ROUNDS):
        if not i_act.size or n8 <= 0:
            break
        # i_act is ascending, so positions whose next gram would run off
        # the buffer form a suffix — they go straight to the lazy path
        k = int(np.searchsorted(i_act, n8 - l))
        if k < i_act.size:
            lazy_tails.append(i_act[k:])
            i_act = i_act[:k]
            c_act = c_act[:k]
            if not i_act.size:
                break
        d8 = v8[i_act + l] ^ v8[c_act + l]
        full = d8 == 0
        part = ~full
        dp = d8[part]
        # exact extra bytes from the mismatching gram: exponent of its
        # lowest set bit in bytes (float64-mantissa trick, branch-free)
        lsb = (dp & (np.uint64(0) - dp)).astype(np.float64)
        mlen[i_act[part]] += ((lsb.view(np.int64) >> 52) - 1023) >> 3
        i_act = i_act[full]
        c_act = c_act[full]
        mlen[i_act] += 8
        l += 8
        if i_act.size * 2 > ok.size:  # run-dominated: stop burning rounds
            break
    # lazy marker (negative mlen): cap survivors + extensions that ran out
    # of gram room before finding a mismatch
    if i_act.size:
        mlen[i_act] *= -1
    for lt in lazy_tails:
        mlen[lt] *= -1
    return ok, cand, mlen


def _select_emit(buf: bytes, plen: int, n: int, ok: np.ndarray,
                 cand: np.ndarray, mlen: np.ndarray) -> bytes:
    """Greedy selection + fused sequence emit over a candidate triple
    (shared by the NumPy and device match finders — this is the half that
    freezes the wire format)."""
    arr = np.frombuffer(buf, np.uint8)
    nv = n - 3
    # greedy selection: ok-byte probe + match-length jumps.  178K-sequence
    # streams spend ~60ms here; everything the loop touches is O(1) —
    # bytes for the candidate test, a C array for lengths.
    ok_b = ok.tobytes()  # bool -> \x00/\x01 bytes
    ml_a = array("q")
    ml_a.frombytes(mlen.tobytes())
    seq_pos: list = []
    seq_ml: list = []
    ap = seq_pos.append
    am = seq_ml.append
    i = plen
    while i < nv:
        if not ok_b[i]:
            i += 1
            continue
        m = ml_a[i]
        if m <= 0:
            m = _MIN_MATCH + _match_len_fast(
                buf, int(cand[i]) + _MIN_MATCH, i + _MIN_MATCH, n)
        ap(i)
        am(m)
        i += m
    S = len(seq_pos)
    if S == 0:
        return _only_literals(buf, plen, n)

    # fused emit: all sequence fields as arrays, one cumsum for the layout,
    # span-fills for ext runs, one gather/scatter for the literals
    mp = np.array(seq_pos, dtype=np.int64)
    ml = np.array(seq_ml, dtype=np.int64)
    ls = np.empty(S, np.int64)
    ls[0] = plen
    ls[1:] = mp[:-1] + ml[:-1]
    ll = mp - ls
    off = (mp - cand[mp]).astype(np.int64)
    tok_lit = np.minimum(ll, 15)
    tok_match = np.minimum(ml - _MIN_MATCH, 15)
    token = (tok_lit << 4) | tok_match
    vl = ll - 15
    el = np.where(ll >= 15, vl // 255 + 1, 0)          # lit ext byte counts
    vm = ml - _MIN_MATCH - 15
    em = np.where(ml - _MIN_MATCH >= 15, vm // 255 + 1, 0)
    starts = np.zeros(S + 1, np.int64)
    np.cumsum(1 + el + ll + 2 + em, out=starts[1:])
    out = np.zeros(int(starts[-1]), np.uint8)
    st = starts[:-1]
    out[st] = token
    he = np.flatnonzero(el)
    if he.size:
        e_st = st[he] + 1
        e_len = el[he]
        fill = (np.repeat(e_st - np.cumsum(e_len) + e_len, e_len)
                + np.arange(int(e_len.sum())))
        out[fill] = 255
        out[e_st + e_len - 1] = (vl[he] % 255).astype(np.uint8)
    lit_dst = st + 1 + el
    if int(ll.sum()):
        nz = np.flatnonzero(ll)
        lln = ll[nz]
        csum = np.cumsum(lln)
        ar = np.arange(int(csum[-1]))
        out[np.repeat(lit_dst[nz] - csum + lln, lln) + ar] = \
            arr[np.repeat(ls[nz] - csum + lln, lln) + ar]
    op = lit_dst + ll
    out[op] = off & 0xFF
    out[op + 1] = off >> 8
    hm = np.flatnonzero(em)
    if hm.size:
        e_st = op[hm] + 2
        e_len = em[hm]
        fill = (np.repeat(e_st - np.cumsum(e_len) + e_len, e_len)
                + np.arange(int(e_len.sum())))
        out[fill] = 255
        out[e_st + e_len - 1] = (vm[hm] % 255).astype(np.uint8)
    final = bytearray(out.tobytes())
    fin_ls = int(mp[-1] + ml[-1])
    fin_ll = n - fin_ls
    ftl = min(fin_ll, 15)
    final.append(ftl << 4)
    if ftl == 15:
        final += _ext_len(fin_ll - 15)
    final += buf[fin_ls:n]
    return bytes(final)


def _lz_compress_np(data: bytes, prefix: bytes = b"") -> bytes:
    """Vectorized greedy parse: hashed head-table candidates + batched
    8-byte-gram extension + jump-table selection + fused sequence emit."""
    plen = len(prefix)
    buf = prefix + data if plen else data
    n = len(buf)
    if n == plen:
        return b""
    if n - _MIN_MATCH < plen:
        return _only_literals(buf, plen, n)
    ok, cand, mlen = _candidates_np(buf, plen, n)
    return _select_emit(buf, plen, n, ok, cand, mlen)


def _lz_compress_device(data: bytes, prefix: bytes = b"") -> bytes:
    """Device greedy parse: the candidate stage (gram/hash build,
    head-table scatter, batched extension) runs as Pallas kernels + XLA
    scatter via ``repro.kernels.lz_match``; selection/emit is the same
    host code as the NumPy path, so the emitted stream is byte-identical
    to ``_lz_compress_np`` (asserted across the parity corpus in
    tests/test_kernel_codec.py)."""
    from repro.kernels.lz_match import lz_candidates_device

    plen = len(prefix)
    buf = prefix + data if plen else data
    n = len(buf)
    if n == plen:
        return b""
    if n - _MIN_MATCH < plen:
        return _only_literals(buf, plen, n)
    ok, cand, mlen = lz_candidates_device(buf, plen)
    return _select_emit(buf, plen, n, ok, cand, mlen)


def _lz_decompress_np(comp: bytes, prefix: bytes = b"") -> bytes:
    """Vectorized decode.

    Three passes: (1) a speculative parse computes, for *every* byte
    position, the sequence fields a sequence starting there would have
    (literal length incl. ext runs, match length, next-sequence offset) —
    all clamped gathers, no branches; (2) a tiny pointer-chase walks the
    real sequence chain through the precomputed next-array; (3) output is
    built with one bulk gather for all literals and frontier-batched match
    application: each round applies, in a single gather, every match whose
    source no longer intersects any unapplied destination (self-overlapping
    copies fold through ``% offset``).  Dependency chains deeper than
    ``_DECODE_MAX_ROUNDS`` finish on a sequential fallback."""
    n = len(comp)
    if n == 0:
        return b""
    plen = len(prefix)
    c = np.frombuffer(comp, np.uint8)
    pos = np.arange(n, dtype=np.int64)
    ll0 = (c >> 4).astype(np.int64)
    ml0 = (c & 15).astype(np.int64)
    cl = c.astype(np.int64)
    # nn[p]: first q >= p with comp[q] != 255 (n when none) — ext-run ends
    if bool((c == 255).any()):
        nz = np.where(c != 255, pos, np.int64(n))
        nn = np.minimum.accumulate(nz[::-1])[::-1]
    else:
        nn = pos
    npad = np.concatenate([nn, [np.int64(n)]])
    cpad = np.concatenate([cl, [np.int64(0)]])

    def ext_value(start):
        """255-run value beginning at comp[start] (start may be >= n: bad).
        Returns (value, n_ext_bytes, bad)."""
        e = npad[np.minimum(start, n)]
        bad = e >= n
        ec = np.minimum(e, n - 1)
        return 255 * (ec - start) + cpad[ec], ec - start + 1, bad

    has_lext = ll0 == 15
    lv, lc, lbad = ext_value(pos + 1)
    ll = ll0 + np.where(has_lext, lv, 0)
    extl = np.where(has_lext, lc, 0)
    bad = has_lext & lbad
    le = pos + 1 + extl          # literal run start
    q1 = le + ll                 # offset field position
    terminal = q1 == n
    bad |= q1 > n
    bad |= ~terminal & (q1 + 2 > n)
    if n >= 2:
        ov = np.ndarray(shape=(n - 1,), dtype="<u2", buffer=comp, strides=(1,))
        off = ov[np.minimum(q1, n - 2)].astype(np.int64)
    else:
        off = np.zeros(n, np.int64)  # single-byte stream: terminal only
    has_mext = ml0 == 15
    mv_, mc, mbad = ext_value(q1 + 2)
    ml = ml0 + _MIN_MATCH + np.where(has_mext, mv_, 0)
    bad |= has_mext & ~terminal & mbad
    nxt = q1 + 2 + np.where(has_mext, mc, 0)

    # chase the real sequence chain
    nxt_a = array("q")
    nxt_a.frombytes(nxt.tobytes())
    bad_b = bad.tobytes()
    term_b = terminal.tobytes()
    tpos: list = []
    ap = tpos.append
    p = 0
    fin = -1
    while p < n:
        if bad_b[p]:
            raise ValueError("corrupt LZ stream: truncated")
        if term_b[p]:
            fin = p
            break
        ap(p)
        p = nxt_a[p]
    if fin < 0:
        # a valid block always ends with a literals-only sequence (the
        # encoder emits one even when empty); stopping right after a match
        # means the tail was cut off
        raise ValueError("corrupt LZ stream: truncated")
    fin_ll = int(ll[fin])
    fin_ls = int(le[fin])

    S = len(tpos)
    if S == 0:
        out = bytearray(comp[fin_ls : fin_ls + fin_ll])
        return bytes(out)
    tp = np.array(tpos, np.int64)
    ll_v = ll[tp]
    ml_v = ml[tp]
    le_v = le[tp]
    off_v = off[tp]
    if (off_v == 0).any():
        raise ValueError("corrupt LZ stream: zero offset")
    lit_dst = np.empty(S, np.int64)
    lit_dst[0] = plen
    np.cumsum((ll_v + ml_v)[:-1], out=lit_dst[1:])
    lit_dst[1:] += plen
    m_dst = lit_dst + ll_v
    src = m_dst - off_v
    if (src < 0).any():
        raise ValueError("corrupt LZ stream: offset before start")
    total = int(m_dst[-1] + ml_v[-1]) + fin_ll
    out = np.empty(total, np.uint8)
    if plen:
        out[:plen] = np.frombuffer(prefix, np.uint8)
    # literals: one gather/scatter over every span
    if int(ll_v.sum()):
        nz2 = np.flatnonzero(ll_v)
        lln = ll_v[nz2]
        csum = np.cumsum(lln)
        ar = np.arange(int(csum[-1]))
        out[np.repeat(lit_dst[nz2] - csum + lln, lln) + ar] = \
            c[np.repeat(le_v[nz2] - csum + lln, lln) + ar]
    if fin_ll:
        out[total - fin_ll :] = c[fin_ls : fin_ls + fin_ll]
    # matches: sequential application over C arrays + memoryview slice
    # copies.  (A frontier-batched gather scheme was tried and loses: on
    # match-dense prompt corpora the output is one deep copy-chain, so
    # rounds never free more than a handful of matches.)
    d_a = array("q"); d_a.frombytes(m_dst.tobytes())
    s_a = array("q"); s_a.frombytes(src.tobytes())
    m_a = array("q"); m_a.frombytes(ml_v.tobytes())
    o_a = array("q"); o_a.frombytes(off_v.tobytes())
    mv2 = memoryview(out)
    for k in range(S):
        d = d_a[k]
        s = s_a[k]
        m = m_a[k]
        if d - s >= m:
            mv2[d : d + m] = mv2[s : s + m]
        else:
            o = o_a[k]
            seg = bytes(mv2[s : s + o])
            mv2[d : d + m] = (seg * (m // o + 1))[:m]
    return out[plen:].tobytes()


# ---------------------------------------------------------------------------
# Public entry points (size/mode routing)
# ---------------------------------------------------------------------------


def lz_compress(data: bytes, prefix: bytes = b"") -> bytes:
    """Compress ``data`` (optionally against a dictionary ``prefix``).

    Auto-routes scalar vs vectorized on payload size; run-dominated
    payloads (zero pages, padding) stay scalar, where the skip-ahead
    loop is faster than any per-position vectorized scan.
    """
    mode = _lz_mode()
    if mode == "device":
        return _lz_compress_device(data, prefix)
    if mode == "scalar" or (mode == "auto" and len(data) < _NP_MIN_COMPRESS):
        return _lz_compress_scalar(data, prefix)
    if mode == "auto":
        probe = np.frombuffer(data[:_RUN_PROBE], np.uint8)
        if probe.size > 16 and float((probe[1:] == probe[:-1]).mean()) > 0.5:
            return _lz_compress_scalar(data, prefix)
        from repro.core import device as _device

        if _device.use_device(len(data), "REPRO_LZ_DEVICE_MIN",
                              _DEVICE_MIN_COMPRESS):
            return _lz_compress_device(data, prefix)
    return _lz_compress_np(data, prefix)


def lz_decompress(comp: bytes, prefix: bytes = b"") -> bytes:
    """Decode a block.  ``auto`` stays on the scalar loop: its bulk slice
    copies already run at memcpy speed, and the vectorized
    parse+gather path (kept behind ``REPRO_LZ_MODE=vector``) measured at
    parity on match-dense streams and *slower* on literal-heavy ones —
    the decode-side throughput win comes from the rANS stage instead
    (see ARCHITECTURE.md "Vectorized codec path")."""
    if len(comp) == 0:
        return b""
    if _lz_mode() == "vector" and len(comp) >= _NP_MIN_DECOMPRESS:
        return _lz_decompress_np(comp, prefix)
    return _lz_decompress_scalar(comp, prefix)
