"""Binary packing of token-id streams (paper §3.3.3, Algorithms 1–2).

Paper-faithful formats
----------------------
``0x00``  uint16 little-endian fixed width (all ids <= 65535)
``0x01``  uint32 little-endian fixed width

Beyond-paper formats (paper §8.4.2 future work #1/#13, each selectable and
benchmarked separately; the format byte keeps every payload self-describing
exactly as the paper's scheme does)
----------------------------------------------------------------------
``0x02``  LEB128 varint
``0x03``  delta-zigzag LEB128 varint (exploits local id correlation)

All packers are bijective on sequences of non-negative ids < 2**32, which
is what the lossless proof of §3.5 requires of ``P``/``P^-1``.
"""

from __future__ import annotations

from typing import Sequence, Union

import numpy as np

FMT_U16 = 0x00
FMT_U32 = 0x01
FMT_VARINT = 0x02
FMT_DELTA_VARINT = 0x03

_FIXED = {FMT_U16: np.uint16, FMT_U32: np.uint32}

TokenSeq = Union[Sequence[int], np.ndarray]


def _as_u32(ids: TokenSeq) -> np.ndarray:
    arr = np.asarray(ids)
    if arr.size and (arr.min() < 0 or arr.max() > 0xFFFFFFFF):
        raise ValueError("token ids must be in [0, 2**32)")
    return arr.astype(np.uint32)


# ---------------------------------------------------------------------------
# Fixed-width (paper Algorithm 1 packing decision, Eq. 7)
# ---------------------------------------------------------------------------


def pack_fixed(ids: TokenSeq) -> bytes:
    arr = _as_u32(ids)
    if arr.size == 0 or int(arr.max()) <= 0xFFFF:
        return bytes([FMT_U16]) + arr.astype("<u2").tobytes()
    return bytes([FMT_U32]) + arr.astype("<u4").tobytes()


# ---------------------------------------------------------------------------
# LEB128 varint (+ delta-zigzag variant)
# ---------------------------------------------------------------------------


def _varint_encode(arr: np.ndarray) -> bytes:
    """Vectorized LEB128 over a uint64 array."""
    if arr.size == 0:
        return b""
    a = arr.astype(np.uint64)
    # number of 7-bit groups per value (at least 1)
    nbits = np.maximum(1, 64 - _clz64(a))
    ngroups = (nbits + 6) // 7
    total = int(ngroups.sum())
    out = np.empty(total, dtype=np.uint8)
    # offsets of each value's first byte
    ends = np.cumsum(ngroups)
    starts = ends - ngroups
    # scalar loop only over groups via numpy trick: max 5 groups for u32
    max_g = int(ngroups.max())
    for g in range(max_g):
        sel = ngroups > g
        vals = (a[sel] >> np.uint64(7 * g)) & np.uint64(0x7F)
        cont = (ngroups[sel] - 1 > g).astype(np.uint8) << 7
        out[starts[sel] + g] = vals.astype(np.uint8) | cont
    return out.tobytes()


def _clz64(a: np.ndarray) -> np.ndarray:
    """Count leading zeros of uint64 array (via float64 exponent trick is
    unsafe for >2**53; use bit-length by successive shifts)."""
    x = a.copy()
    n = np.full(a.shape, 64, dtype=np.int64)
    shift = 32
    while shift:
        y = x >> np.uint64(shift)
        has = y != 0
        n = np.where(has, n - shift, n)
        x = np.where(has, y, x)
        shift //= 2
    return (n - (x != 0).astype(np.int64)).astype(np.int64)


def _varint_decode(data: bytes) -> np.ndarray:
    buf = np.frombuffer(data, dtype=np.uint8)
    if buf.size == 0:
        return np.zeros(0, dtype=np.uint32)
    is_last = (buf & 0x80) == 0
    ends = np.flatnonzero(is_last)
    starts = np.concatenate(([0], ends[:-1] + 1))
    lengths = ends - starts + 1
    if lengths.max() > 5:
        raise ValueError("varint group longer than 5 bytes for u32 stream")
    vals = np.zeros(len(ends), dtype=np.uint64)
    max_g = int(lengths.max())
    for g in range(max_g):
        sel = lengths > g
        vals[sel] |= (buf[starts[sel] + g].astype(np.uint64) & np.uint64(0x7F)) << np.uint64(7 * g)
    return vals.astype(np.uint32)


def _zigzag(d: np.ndarray) -> np.ndarray:
    d64 = d.astype(np.int64)
    return ((d64 << 1) ^ (d64 >> 63)).astype(np.uint64)


def _unzigzag(z: np.ndarray) -> np.ndarray:
    z64 = z.astype(np.uint64)
    return ((z64 >> np.uint64(1)) ^ (np.uint64(0) - (z64 & np.uint64(1)))).astype(np.int64)


def pack_varint(ids: TokenSeq) -> bytes:
    return bytes([FMT_VARINT]) + _varint_encode(_as_u32(ids).astype(np.uint64))


def pack_delta_varint(ids: TokenSeq) -> bytes:
    arr = _as_u32(ids).astype(np.int64)
    deltas = np.diff(arr, prepend=np.int64(0))
    return bytes([FMT_DELTA_VARINT]) + _varint_encode(_zigzag(deltas))


# ---------------------------------------------------------------------------
# Dispatch
# ---------------------------------------------------------------------------

PACKERS = {
    "fixed": pack_fixed,
    "varint": pack_varint,
    "delta-varint": pack_delta_varint,
}


def pack_tokens(ids: TokenSeq, scheme: str = "fixed") -> bytes:
    """Pack a token-id stream; the leading format byte makes the payload
    self-describing (paper §3.1), so `unpack_tokens` needs no side channel."""
    try:
        return PACKERS[scheme](ids)
    except KeyError:
        raise ValueError(f"unknown packing scheme {scheme!r}") from None


def unpack_tokens(payload: bytes) -> np.ndarray:
    """Inverse of any packer. Returns uint32 ids."""
    if not payload:
        raise ValueError("empty token payload")
    fmt, body = payload[0], payload[1:]
    if fmt in _FIXED:
        width = "<u2" if fmt == FMT_U16 else "<u4"
        return np.frombuffer(body, dtype=width).astype(np.uint32)
    if fmt == FMT_VARINT:
        return _varint_decode(body)
    if fmt == FMT_DELTA_VARINT:
        deltas = _unzigzag(_varint_decode(body).astype(np.uint64))
        return np.cumsum(deltas, dtype=np.int64).astype(np.uint32)
    raise ValueError(f"unknown packing format byte 0x{fmt:02x}")


def packed_nbytes_fixed(ids: TokenSeq) -> int:
    """Paper Eq. 10 numerator: 1 + k*n without materializing the payload."""
    arr = _as_u32(ids)
    k = 2 if (arr.size == 0 or int(arr.max()) <= 0xFFFF) else 4
    return 1 + k * int(arr.size)
