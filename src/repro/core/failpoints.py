"""Deterministic fault injection: named failpoints at I/O boundaries.

Every place the system touches the outside world — fsync, the
``os.replace`` commit points, the gateway socket, lease acquisition,
codec decode — calls :func:`fire` with a **registered site name** from
:data:`SITES`.  With nothing armed, a fire is two dict lookups; with a
rule armed (programmatically via :func:`arm` / :func:`injected`, or from
the environment via ``REPRO_FAULTS``), the rule's seeded schedule
decides whether this hit faults, and its action decides how:

========== ==============================================================
action     effect at the site
========== ==============================================================
``crash``   raise :class:`FailpointCrash` (a ``BaseException``, so
            blanket ``except Exception`` recovery code cannot swallow a
            simulated process death — the crash-injection suites assert
            on-disk state afterwards)
``torn``    raise :class:`TornWrite`; the *cooperating* site
            (``durability.write_durable``) first writes a prefix of the
            payload, simulating a power cut mid-write
``error``   raise :class:`FailpointError` (a ``ConnectionError`` →
            ``OSError``), indistinguishable from a real I/O failure to
            retry/recovery paths — this is the one they must handle
``latency`` sleep for the configured seconds (slow-disk / slow-network)
``count``   never faults; just counts hits — how the crash suites
            enumerate fault points before crashing at each one
========== ==============================================================

Schedules are deterministic given a seed and the hit order: ``nth:N``
fires exactly once on the Nth matching hit (1-based), ``p:F`` draws from
a per-rule ``random.Random`` seeded from ``REPRO_FAULTS_SEED`` (or the
``seed=`` argument), ``always`` fires every hit.

The spec grammar (one rule per ``;``)::

    REPRO_FAULTS="durability.fsync_file=nth:3,crash;gateway.send=p:0.05,error"

A pattern is an ``fnmatch`` glob, optionally ``|``-alternated
(``durability.*|store.replace``); alternation shares ONE hit counter
across all matched sites, which is how a single rule reproduces the old
combined fsync+replace fault counter of ``tests/test_crash_injection``.

The site catalog is an *auditable registry*: :func:`fire` rejects names
not in :data:`SITES`, patterns that match no site are rejected at arm
time, and the static checker (``repro.analysis`` rule REPRO008) verifies
every ``fire()`` call site in the tree uses a literal, registered name —
so :data:`SITES` is always the complete inventory of injection points.
"""

from __future__ import annotations

import fnmatch
import random
import threading
import time
from contextlib import contextmanager
from typing import Any, Dict, Iterator, List, Optional, Tuple

from repro.core import env
from repro.core.locks import make_lock

# ---------------------------------------------------------------------------
# The site catalog.  Literal dict so repro.analysis REPRO008 can read it
# statically; fire() enforces membership at runtime.
# ---------------------------------------------------------------------------

SITES: Dict[str, str] = {
    "durability.fsync_file": "file fsync in durability.fsync_file",
    "durability.fsync_dir": "directory fsync in durability.fsync_dir",
    "durability.write_durable": "payload write in durability.write_durable "
                                "(the torn-write site)",
    "durability.publish": "os.replace commit in durability.publish_durable",
    "store.replace": "an os.replace commit point in core.store (meta, "
                     "index, generation and sidecar publishes)",
    "checkpoint.replace": "os.replace commit in dist.checkpoint save",
    "lease.acquire": "flock acquisition in core.lease.acquire_store_lease",
    "gateway.send": "GatewayClient frame send on the client socket",
    "gateway.recv": "GatewayClient response read on the client socket",
    "codec.decompress": "blob decode entry in core.api decompress_batch",
    "codec.tokens": "token decode entry in core.api tokens_batch",
}


class FailpointCrash(BaseException):
    """Simulated process death at a failpoint.

    Deliberately a ``BaseException`` (like ``KeyboardInterrupt``): crash
    tests assert that *on-disk* state recovers, so no library-level
    ``except Exception`` may swallow the simulated crash in flight.
    """


class TornWrite(FailpointCrash):
    """Crash mid-write: the cooperating site persists ``keep(len))``
    bytes of the payload before re-raising — a torn file, not a clean
    old-or-new one."""

    def __init__(self, site: str, hit: int, frac: float = 0.5):
        super().__init__(
            f"injected torn write at failpoint {site!r} "
            f"(hit #{hit}, keeping {frac:.0%} of the payload)")
        self.site = site
        self.frac = frac

    def keep(self, n_bytes: int) -> int:
        """How many payload bytes survive the simulated power cut."""
        return max(0, min(n_bytes - 1, int(n_bytes * self.frac)))


class FailpointError(ConnectionError):
    """Injected *recoverable* I/O failure.  A ``ConnectionError`` (and
    therefore an ``OSError``): retry and degradation paths must treat it
    exactly like the real thing."""


# ---------------------------------------------------------------------------
# Rules
# ---------------------------------------------------------------------------

_SCHEDULES = ("nth", "p", "always")
_ACTIONS = ("crash", "torn", "error", "latency", "count")


def _parse_schedule(raw: str) -> Tuple:
    if raw == "always":
        return ("always",)
    kind, sep, arg = raw.partition(":")
    if kind == "nth" and sep:
        n = int(arg)
        if n < 1:
            raise ValueError(f"nth schedule is 1-based, got nth:{n}")
        return ("nth", n)
    if kind == "p" and sep:
        p = float(arg)
        if not 0.0 <= p <= 1.0:
            raise ValueError(f"probability must be in [0, 1], got p:{p}")
        return ("p", p)
    raise ValueError(
        f"unknown failpoint schedule {raw!r} "
        f"(expected nth:N, p:F, or always)")


def _parse_action(raw: str) -> Tuple:
    kind, sep, arg = raw.partition(":")
    if kind in ("crash", "error", "count"):
        if sep:
            raise ValueError(f"action {kind!r} takes no argument, got {raw!r}")
        return (kind,)
    if kind == "torn":
        frac = float(arg) if sep else 0.5
        if not 0.0 <= frac < 1.0:
            raise ValueError(f"torn fraction must be in [0, 1), got {raw!r}")
        return ("torn", frac)
    if kind == "latency" and sep:
        s = float(arg)
        if s < 0:
            raise ValueError(f"latency seconds must be >= 0, got {raw!r}")
        return ("latency", s)
    raise ValueError(
        f"unknown failpoint action {raw!r} "
        f"(expected crash, torn[:frac], error, latency:S, or count)")


def _validate_pattern(pattern: str) -> None:
    for part in pattern.split("|"):
        if not part:
            raise ValueError(f"empty alternation in pattern {pattern!r}")
        if any(ch in part for ch in "*?["):
            if not any(fnmatch.fnmatchcase(s, part) for s in SITES):
                raise ValueError(
                    f"failpoint pattern {part!r} matches no registered "
                    f"site (known: {sorted(SITES)})")
        elif part not in SITES:
            raise ValueError(
                f"unregistered failpoint site {part!r} in pattern "
                f"(known: {sorted(SITES)})")


class FaultRule:
    """One armed rule: pattern + seeded schedule + action, with a hit
    counter shared across every site the pattern matches."""

    def __init__(self, pattern: str, schedule: str, action: str, *,
                 seed: int = 0, index: int = 0):
        _validate_pattern(pattern)
        self.pattern = pattern
        self.schedule = _parse_schedule(schedule)
        self.action = _parse_action(action)
        # Distinct deterministic stream per (seed, rule index): two p:
        # rules armed from one spec don't mirror each other's draws.
        self._rng = random.Random((seed * 1_000_003 + index) ^ 0x5EED)
        self._parts = pattern.split("|")
        self.hits = 0
        self.fired = 0

    def matches(self, site: str) -> bool:
        return any(fnmatch.fnmatchcase(site, p) for p in self._parts)

    def _should_fire(self) -> bool:
        """Called with the registry lock held, after ``hits`` was bumped
        for the current matching hit."""
        kind = self.schedule[0]
        if kind == "always":
            return True
        if kind == "nth":
            return self.hits == self.schedule[1]
        return self._rng.random() < self.schedule[1]

    def describe(self) -> Dict[str, Any]:
        return {"pattern": self.pattern,
                "schedule": ":".join(str(x) for x in self.schedule),
                "action": ":".join(str(x) for x in self.action),
                "hits": self.hits, "fired": self.fired}

    def __repr__(self) -> str:
        return f"<FaultRule {self.describe()!r}>"


def parse_spec(spec: str, *, seed: int = 0) -> List[FaultRule]:
    """Parse ``pattern=schedule,action[;...]`` into rules (unarmed)."""
    rules: List[FaultRule] = []
    for i, clause in enumerate(c.strip() for c in spec.split(";")):
        if not clause:
            continue
        pattern, sep, rest = clause.partition("=")
        schedule, sep2, action = rest.partition(",")
        if not sep or not sep2:
            raise ValueError(
                f"bad failpoint clause {clause!r} "
                f"(expected pattern=schedule,action)")
        rules.append(FaultRule(pattern.strip(), schedule.strip(),
                               action.strip(), seed=seed, index=i))
    return rules


# ---------------------------------------------------------------------------
# The armed-rule registry
# ---------------------------------------------------------------------------

_LOCK = make_lock("faults")
_manual: List[FaultRule] = []
_env_rules: List[FaultRule] = []
_env_raw: Optional[str] = None   # REPRO_FAULTS value the env rules came from
_active: List[FaultRule] = []    # _manual + _env_rules, rebuilt on change


def _rebuild() -> None:
    global _active
    _active = _manual + _env_rules


def _sync_env() -> None:
    """Re-arm the env-sourced rules whenever ``REPRO_FAULTS`` changes.
    A malformed spec raises on every fire — loud by design: silently
    running a chaos schedule with zero faults armed would pass every
    assertion for the wrong reason."""
    global _env_raw, _env_rules
    raw = env.read("REPRO_FAULTS")
    if raw == _env_raw:
        return
    with _LOCK:
        raw = env.read("REPRO_FAULTS")
        if raw == _env_raw:
            return
        seed = env.read("REPRO_FAULTS_SEED")
        _env_rules = parse_spec(raw, seed=seed) if raw else []
        _env_raw = raw
        _rebuild()


def fire(site: str) -> None:
    """Hit the named failpoint.  No-op unless an armed rule matches and
    its schedule elects this hit; then the rule's action applies (see
    module docstring).  Unregistered names raise ``RuntimeError`` even
    when nothing is armed — the catalog stays honest."""
    if site not in SITES:
        raise RuntimeError(
            f"unregistered failpoint site {site!r}; add it to "
            f"repro.core.failpoints.SITES (known: {sorted(SITES)})")
    _sync_env()
    if not _active:
        return
    to_apply: List[FaultRule] = []
    with _LOCK:
        for rule in _active:
            if rule.matches(site):
                rule.hits += 1
                if rule._should_fire():
                    rule.fired += 1
                    to_apply.append(rule)
    for rule in to_apply:
        _apply(site, rule)


def _apply(site: str, rule: FaultRule) -> None:
    kind = rule.action[0]
    if kind == "count":
        return
    from repro import obs

    obs.counter("faults.fired", site=site, action=kind).inc()
    if kind == "latency":
        time.sleep(rule.action[1])
        return
    if kind == "torn":
        raise TornWrite(site, rule.hits, frac=rule.action[1])
    if kind == "error":
        raise FailpointError(
            f"injected I/O error at failpoint {site!r} (hit #{rule.hits})")
    raise FailpointCrash(
        f"injected crash at failpoint {site!r} (hit #{rule.hits})")


def arm(pattern: str, schedule: str, action: str, *,
        seed: int = 0) -> FaultRule:
    """Arm one rule programmatically; returns it (see :func:`disarm`)."""
    with _LOCK:
        rule = FaultRule(pattern, schedule, action,
                         seed=seed, index=len(_manual))
        _manual.append(rule)
        _rebuild()
    return rule


def arm_spec(spec: str, *, seed: int = 0) -> List[FaultRule]:
    """Arm every rule in a ``REPRO_FAULTS``-grammar spec string."""
    rules = parse_spec(spec, seed=seed)
    with _LOCK:
        _manual.extend(rules)
        _rebuild()
    return rules


def disarm(rule: FaultRule) -> None:
    with _LOCK:
        if rule in _manual:
            _manual.remove(rule)
            _rebuild()


def disarm_all() -> None:
    """Drop every programmatically armed rule (env rules re-sync from
    ``REPRO_FAULTS`` on the next fire)."""
    with _LOCK:
        _manual.clear()
        _rebuild()


@contextmanager
def injected(spec: str, *, seed: int = 0) -> Iterator[List[FaultRule]]:
    """``with injected("site=nth:2,crash"): ...`` — armed for the body,
    disarmed on exit even when the injected fault propagates."""
    rules = arm_spec(spec, seed=seed)
    try:
        yield rules
    finally:
        with _LOCK:
            for rule in rules:
                if rule in _manual:
                    _manual.remove(rule)
            _rebuild()


def active() -> List[FaultRule]:
    """Snapshot of every armed rule (manual + env)."""
    _sync_env()
    with _LOCK:
        return list(_active)


def stats() -> Dict[str, Any]:
    """Hit/fire counters per armed rule, for stats endpoints and tests."""
    with _LOCK:
        return {"n_rules": len(_active),
                "rules": [r.describe() for r in _active]}
