"""Byte-compression backends.

``zstd``      the paper's backend (zstandard C library, level -131072..22,
              default 15 per §4.5) — paper-faithful path.  When the C
              library is not installed, this name transparently falls
              back to ``repro-lzr`` (see ZSTD_IS_FALLBACK).
``zstd-dict`` zstd with a trained dictionary (paper §8.4.2 #2 future work).
``repro-lz``  our own LZ77 (LZ4-style block) — from-scratch substrate.
``repro-lzr`` our LZ77 + our rANS entropy stage — the paper's own
              structural model of Zstd (FSE(LZ77(T))) built from scratch.
``zlib`` / ``bz2`` / ``lzma``  stdlib baselines (paper §8.4.2 #3).

Every backend exposes compress(data, level) / decompress(data) and is
registered in BACKENDS for the benchmark sweep.
"""

from __future__ import annotations

import bz2 as _bz2
import lzma as _lzma
import zlib as _zlib
from typing import Callable, Dict, Optional, Tuple

from repro.core.lz77 import lz_compress, lz_decompress
from repro.core.rans_np import rans_compress_bytes, rans_decompress_bytes

try:
    import zstandard as _zstd

    HAVE_ZSTD = True
except ImportError:
    _zstd = None
    HAVE_ZSTD = False

# When the zstandard C library is absent the "zstd" name transparently
# routes to the from-scratch repro-lzr stack (rANS(LZ77(T)) — the paper's
# own structural model of Zstd, §3.2.2).  Frames written under the
# fallback are only readable by the fallback; ZSTD_IS_FALLBACK lets
# callers and benchmarks report which implementation produced the bytes.
ZSTD_IS_FALLBACK = not HAVE_ZSTD

DEFAULT_LEVEL = 15  # paper §4.5


# -- zstd ---------------------------------------------------------------


_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"  # RFC 8878 frame magic, little-endian


def _zstd_compress(data: bytes, level: int = DEFAULT_LEVEL) -> bytes:
    if not HAVE_ZSTD:
        return _repro_lzr_compress(data, level)
    return _zstd.ZstdCompressor(level=level).compress(data)


def _zstd_decompress(data: bytes) -> bytes:
    # Sniff the zstd frame magic so stores stay portable across hosts:
    # fallback-written payloads decode even after zstandard gets installed,
    # and real-zstd payloads fail with a pointed error instead of garbage
    # when it is missing.
    if data[:4] == _ZSTD_MAGIC:
        if not HAVE_ZSTD:
            raise RuntimeError(
                "payload was written by the real zstd library; install "
                "zstandard (requirements-dev.txt) to read it")
        return _zstd.ZstdDecompressor().decompress(data)
    return _repro_lzr_decompress(data)


class ZstdDictBackend:
    """Zstd with a trained dictionary (future-work baseline §8.4.2 #2)."""

    def __init__(self, samples, dict_size: int = 16384, level: int = DEFAULT_LEVEL):
        if not HAVE_ZSTD:
            raise RuntimeError("zstandard not available")
        self._dict = _zstd.train_dictionary(dict_size, [s.encode() if isinstance(s, str) else s for s in samples])
        self._level = level

    def compress(self, data: bytes, level: Optional[int] = None) -> bytes:
        c = _zstd.ZstdCompressor(level=level or self._level, dict_data=self._dict)
        return c.compress(data)

    def decompress(self, data: bytes) -> bytes:
        return _zstd.ZstdDecompressor(dict_data=self._dict).decompress(data)


# -- from-scratch backends ----------------------------------------------


def _repro_lz_compress(data: bytes, level: int = 0) -> bytes:
    return lz_compress(data)


def _repro_lzr_compress(data: bytes, level: int = 0) -> bytes:
    return rans_compress_bytes(lz_compress(data))


def _repro_lzr_decompress(data: bytes) -> bytes:
    return lz_decompress(rans_decompress_bytes(data))


# -- stdlib baselines ----------------------------------------------------


def _zlib_compress(data: bytes, level: int = 9) -> bytes:
    return _zlib.compress(data, min(max(level, 0), 9))


def _bz2_compress(data: bytes, level: int = 9) -> bytes:
    return _bz2.compress(data, min(max(level, 1), 9))


def _lzma_compress(data: bytes, level: int = 6) -> bytes:
    return _lzma.compress(data, preset=min(max(level, 0), 9))


# -- registry ------------------------------------------------------------

BACKENDS: Dict[str, Tuple[Callable[..., bytes], Callable[[bytes], bytes]]] = {
    "zstd": (_zstd_compress, _zstd_decompress),
    "repro-lz": (_repro_lz_compress, lz_decompress),
    "repro-lzr": (_repro_lzr_compress, _repro_lzr_decompress),
    "zlib": (_zlib_compress, _zlib.decompress),
    "bz2": (_bz2_compress, _bz2.decompress),
    "lzma": (_lzma_compress, _lzma.decompress),
}


def compress_bytes(data: bytes, level: int = DEFAULT_LEVEL, backend: str = "zstd") -> bytes:
    try:
        fn = BACKENDS[backend][0]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}") from None
    return fn(data, level)


def decompress_bytes(data: bytes, backend: str = "zstd") -> bytes:
    try:
        fn = BACKENDS[backend][1]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}") from None
    return fn(data)
