"""Byte-compression backends.

``zstd``      the paper's backend (zstandard C library, level -131072..22,
              default 15 per §4.5) — paper-faithful path.  When the C
              library is not installed, this name transparently falls
              back to ``repro-lzr`` (see ZSTD_IS_FALLBACK).
``zstd-dict`` zstd with a trained dictionary (paper §8.4.2 #2 future work).
``repro-lz``  our own LZ77 (LZ4-style block) — from-scratch substrate.
``repro-lzr`` our LZ77 + our rANS entropy stage — the paper's own
              structural model of Zstd (FSE(LZ77(T))) built from scratch.
``zlib`` / ``bz2`` / ``lzma``  stdlib baselines (paper §8.4.2 #3).

Every backend exposes compress(data, level) / decompress(data) and is
registered in BACKENDS for the benchmark sweep.
"""

from __future__ import annotations

import bz2 as _bz2
import lzma as _lzma
import threading as _threading
import zlib as _zlib
from typing import Callable, Dict, Optional, Tuple

from repro.core.lz77 import lz_compress, lz_decompress
from repro.core.rans_np import rans_compress_bytes, rans_decompress_bytes

try:
    import zstandard as _zstd

    HAVE_ZSTD = True
except ImportError:
    _zstd = None
    HAVE_ZSTD = False

# When the zstandard C library is absent the "zstd" name transparently
# routes to the from-scratch repro-lzr stack (rANS(LZ77(T)) — the paper's
# own structural model of Zstd, §3.2.2).  Frames written under the
# fallback are only readable by the fallback; ZSTD_IS_FALLBACK lets
# callers and benchmarks report which implementation produced the bytes.
ZSTD_IS_FALLBACK = not HAVE_ZSTD

DEFAULT_LEVEL = 15  # paper §4.5


# -- zstd ---------------------------------------------------------------


_ZSTD_MAGIC = b"\x28\xb5\x2f\xfd"  # RFC 8878 frame magic, little-endian


def _zstd_compress(data: bytes, level: int = DEFAULT_LEVEL) -> bytes:
    if not HAVE_ZSTD:
        return _repro_lzr_compress(data, level)
    return _zstd.ZstdCompressor(level=level).compress(data)


def _zstd_decompress(data: bytes) -> bytes:
    # Sniff the zstd frame magic so stores stay portable across hosts:
    # fallback-written payloads decode even after zstandard gets installed,
    # and real-zstd payloads fail with a pointed error instead of garbage
    # when it is missing.
    if data[:4] == _ZSTD_MAGIC:
        if not HAVE_ZSTD:
            raise RuntimeError(
                "payload was written by the real zstd library; install "
                "zstandard (requirements-dev.txt) to read it")
        return _zstd.ZstdDecompressor().decompress(data)
    return _repro_lzr_decompress(data)


class ZstdDictBackend:
    """Zstd with a trained dictionary (future-work baseline §8.4.2 #2)."""

    def __init__(self, samples, dict_size: int = 16384, level: int = DEFAULT_LEVEL):
        if not HAVE_ZSTD:
            raise RuntimeError("zstandard not available")
        self._dict = _zstd.train_dictionary(dict_size, [s.encode() if isinstance(s, str) else s for s in samples])
        self._level = level

    def compress(self, data: bytes, level: Optional[int] = None) -> bytes:
        c = _zstd.ZstdCompressor(level=level or self._level, dict_data=self._dict)
        return c.compress(data)

    def decompress(self, data: bytes) -> bytes:
        return _zstd.ZstdDecompressor(dict_data=self._dict).decompress(data)


# -- from-scratch backends ----------------------------------------------


def _repro_lz_compress(data: bytes, level: int = 0) -> bytes:
    return lz_compress(data)


def _repro_lzr_compress(data: bytes, level: int = 0) -> bytes:
    return rans_compress_bytes(lz_compress(data))


def _repro_lzr_decompress(data: bytes) -> bytes:
    return lz_decompress(rans_decompress_bytes(data))


# -- stdlib baselines ----------------------------------------------------


def _zlib_compress(data: bytes, level: int = 9) -> bytes:
    return _zlib.compress(data, min(max(level, 0), 9))


def _bz2_compress(data: bytes, level: int = 9) -> bytes:
    return _bz2.compress(data, min(max(level, 1), 9))


def _lzma_compress(data: bytes, level: int = 6) -> bytes:
    return _lzma.compress(data, preset=min(max(level, 0), 9))


# -- registry ------------------------------------------------------------

BACKENDS: Dict[str, Tuple[Callable[..., bytes], Callable[[bytes], bytes]]] = {
    "zstd": (_zstd_compress, _zstd_decompress),
    "repro-lz": (_repro_lz_compress, lz_decompress),
    "repro-lzr": (_repro_lzr_compress, _repro_lzr_decompress),
    "zlib": (_zlib_compress, _zlib.decompress),
    "bz2": (_bz2_compress, _bz2.decompress),
    "lzma": (_lzma_compress, _lzma.decompress),
}


def compress_bytes(data: bytes, level: int = DEFAULT_LEVEL, backend: str = "zstd") -> bytes:
    try:
        fn = BACKENDS[backend][0]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}") from None
    return fn(data, level)


def decompress_bytes(data: bytes, backend: str = "zstd") -> bytes:
    try:
        fn = BACKENDS[backend][1]
    except KeyError:
        raise ValueError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}") from None
    return fn(data)


# -- trained dictionaries -------------------------------------------------
#
# A dictionary trained on a corpus sample recovers the cross-record
# redundancy that per-record compression cannot see (paper §8.4.2 #2) —
# exactly where short prompts lose the most.  ``train_dictionary_bytes``
# produces the dictionary blob; ``compress_bytes_dict`` /
# ``decompress_bytes_dict`` apply it.  Both sides must hold the identical
# blob — the codec layer threads a fingerprint through frame headers.

DEFAULT_DICT_SIZE = 16384
_TRAIN_WINDOW = 16   # fallback sampler: fragment length ...
_TRAIN_STRIDE = 4    # ... sampled at this stride


def _train_dict_fallback(samples, dict_size: int) -> bytes:
    """From-scratch frequent-substring sampler for the repro-lzr path:
    count fixed-width fragments across the samples, keep the repeated
    ones, and concatenate them most-frequent-LAST (closest to the
    payload, so LZ offsets into the dictionary stay short — the same
    convention zstd's trainer uses)."""
    from collections import Counter

    counts: Counter = Counter()
    for s in samples:
        for i in range(0, max(len(s) - _TRAIN_WINDOW + 1, 0), _TRAIN_STRIDE):
            counts[s[i : i + _TRAIN_WINDOW]] += 1
    frags = [f for f, c in counts.most_common() if c >= 2]
    picked = []
    seen = bytearray()
    size = 0
    for f in frags:
        if f in seen:  # already covered by an earlier fragment
            continue
        picked.append(f)
        seen += f
        size += len(f)
        if size >= dict_size:
            break
    picked.reverse()  # most frequent last
    return bytes(b"".join(picked)[-dict_size:])


def train_dictionary_bytes(samples, dict_size: int = DEFAULT_DICT_SIZE) -> bytes:
    """Train a dictionary over ``samples`` (sequence of bytes).  Returns
    ``b""`` when no useful dictionary exists (empty/tiny corpora) — the
    caller should then compress without one.  Uses zstd's trainer when
    the C library is installed, the from-scratch sampler otherwise."""
    samples = [bytes(s) for s in samples if s]
    if not samples or dict_size <= 0:
        return b""
    if HAVE_ZSTD:
        try:
            return _zstd.train_dictionary(dict_size, samples).as_bytes()
        except Exception:
            # corpora too small/uniform for the trainer: fall back to the
            # sampler as a raw-content dictionary (zstd accepts those)
            pass
    return _train_dict_fallback(samples, dict_size)


# ZstdCompressionDict digestion is the expensive step of dictionary
# (de)compression, and the per-record batch paths would otherwise pay it
# for every frame — memoize the digested object per dictionary bytes.
# (Compressor/decompressor objects are not shared: they are cheap given a
# digested dict and not safe for concurrent use.)
_ZSTD_CDICTS: Dict[bytes, object] = {}
_ZSTD_CDICTS_MAX = 8
_ZSTD_CDICTS_LOCK = _threading.Lock()


def _zstd_cdict(dictionary: bytes):
    with _ZSTD_CDICTS_LOCK:
        cdict = _ZSTD_CDICTS.get(dictionary)
        if cdict is None:
            cdict = _zstd.ZstdCompressionDict(dictionary)
            while len(_ZSTD_CDICTS) >= _ZSTD_CDICTS_MAX:
                _ZSTD_CDICTS.pop(next(iter(_ZSTD_CDICTS)))
            _ZSTD_CDICTS[dictionary] = cdict
        return cdict


def _zstd_compress_dict(data: bytes, dictionary: bytes,
                        level: int = DEFAULT_LEVEL) -> bytes:
    if not HAVE_ZSTD:
        return _repro_lzr_compress_dict(data, dictionary, level)
    return _zstd.ZstdCompressor(
        level=level, dict_data=_zstd_cdict(dictionary)).compress(data)


def _zstd_decompress_dict(data: bytes, dictionary: bytes) -> bytes:
    # same frame-magic sniffing as the plain path: fallback-written
    # payloads stay readable after zstandard gets installed, and
    # real-zstd payloads fail pointedly instead of decoding garbage
    if data[:4] == _ZSTD_MAGIC:
        if not HAVE_ZSTD:
            raise RuntimeError(
                "payload was written by the real zstd library; install "
                "zstandard (requirements-dev.txt) to read it")
        return _zstd.ZstdDecompressor(
            dict_data=_zstd_cdict(dictionary)).decompress(data)
    return _repro_lzr_decompress_dict(data, dictionary)


def _repro_lz_compress_dict(data: bytes, dictionary: bytes, level: int = 0) -> bytes:
    return lz_compress(data, prefix=dictionary)


def _repro_lz_decompress_dict(data: bytes, dictionary: bytes) -> bytes:
    return lz_decompress(data, prefix=dictionary)


def _repro_lzr_compress_dict(data: bytes, dictionary: bytes, level: int = 0) -> bytes:
    # Dictionary mode exists for payloads too short to build their own
    # window — exactly where the rANS stage's freq-table header can cost
    # more than it saves.  One flag byte picks per record: 0x01 = rANS
    # over the LZ stream, 0x00 = raw LZ stream.  (New wire format, so no
    # compatibility constraint; plain repro-lzr frames are unchanged.)
    lz = lz_compress(data, prefix=dictionary)
    r = rans_compress_bytes(lz)
    return b"\x01" + r if len(r) < len(lz) else b"\x00" + lz


def _repro_lzr_decompress_dict(data: bytes, dictionary: bytes) -> bytes:
    if not data:
        raise ValueError("truncated repro-lzr dict payload")
    body = data[1:]
    lz = rans_decompress_bytes(body) if data[0] == 1 else body
    return lz_decompress(lz, prefix=dictionary)


def _zlib_compress_dict(data: bytes, dictionary: bytes, level: int = 9) -> bytes:
    co = _zlib.compressobj(min(max(level, 0), 9), zdict=dictionary)
    return co.compress(data) + co.flush()


def _zlib_decompress_dict(data: bytes, dictionary: bytes) -> bytes:
    return _zlib.decompressobj(zdict=dictionary).decompress(data)


# backend -> (compress(data, dict, level), decompress(data, dict));
# lzma/bz2 have no dictionary mode, so they are simply absent here
DICT_BACKENDS: Dict[str, Tuple[Callable[..., bytes], Callable[[bytes, bytes], bytes]]] = {
    "zstd": (_zstd_compress_dict, _zstd_decompress_dict),
    "repro-lz": (_repro_lz_compress_dict, _repro_lz_decompress_dict),
    "repro-lzr": (_repro_lzr_compress_dict, _repro_lzr_decompress_dict),
    "zlib": (_zlib_compress_dict, _zlib_decompress_dict),
}


def compress_bytes_dict(data: bytes, dictionary: bytes,
                        level: int = DEFAULT_LEVEL, backend: str = "zstd") -> bytes:
    try:
        fn = DICT_BACKENDS[backend][0]
    except KeyError:
        raise ValueError(
            f"backend {backend!r} has no dictionary mode; "
            f"have {sorted(DICT_BACKENDS)}") from None
    return fn(data, dictionary, level)


def decompress_bytes_dict(data: bytes, dictionary: bytes,
                          backend: str = "zstd") -> bytes:
    try:
        fn = DICT_BACKENDS[backend][1]
    except KeyError:
        raise ValueError(
            f"backend {backend!r} has no dictionary mode; "
            f"have {sorted(DICT_BACKENDS)}") from None
    return fn(data, dictionary)
