"""LoPace core: the paper's contribution as a composable library.

Three lossless methods (zstd / token / hybrid), self-describing binary
packing, pluggable byte backends, adaptive selection, a content-addressed
PromptStore, and the JAX/TPU batch entropy coder (repro.core.rans).
"""

from repro.core.api import (
    PromptCompressor,
    compress_hybrid,
    compress_token,
    compress_zstd,
    decompress_hybrid,
    decompress_token,
    decompress_zstd,
    hybrid_tokens,
)
from repro.core.adaptive import AdaptiveCompressor
from repro.core.codec import (
    ByteCompressorCodec,
    Codec,
    PipelineCodec,
    TokenPackCodec,
    get_codec,
    method_pipeline,
    register_codec,
)
from repro.core.packing import pack_tokens, unpack_tokens
from repro.core.store import PromptStore, ShardedPromptStore

__all__ = [
    "PromptCompressor",
    "AdaptiveCompressor",
    "PromptStore",
    "ShardedPromptStore",
    "Codec",
    "PipelineCodec",
    "TokenPackCodec",
    "ByteCompressorCodec",
    "register_codec",
    "get_codec",
    "method_pipeline",
    "compress_zstd",
    "decompress_zstd",
    "compress_token",
    "decompress_token",
    "compress_hybrid",
    "decompress_hybrid",
    "hybrid_tokens",
    "pack_tokens",
    "unpack_tokens",
]
