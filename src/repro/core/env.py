"""The single registry for ``REPRO_*`` environment knobs.

Every environment variable the runtime reads is *declared* here with a
typed parser, a default, and a one-line description — and every read
goes through :func:`read`, which looks the variable up fresh on each
call (benchmarks and tests re-tune without reimporting).  The static
invariant checker (``repro.analysis`` rule REPRO005) enforces the other
half of the contract: no module outside this one may touch
``os.environ`` for a ``REPRO_*`` name, so the table below is always the
complete inventory of runtime knobs.

Parser semantics are part of each knob's contract (several predate this
registry and keep their historical fallback behavior exactly):

* a parser may *raise* ``ValueError`` — :func:`read` then falls back to
  the default silently (the device-crossover knobs work this way);
* a parser may *absorb* garbage itself when the historical behavior was
  not "fall back to default" — ``REPRO_CODEC_THREADS`` maps garbage to
  0 (pool disabled), ``REPRO_RANS_LANES`` warns and clamps.

Unset or empty values never reach a parser; they yield the default
(the per-call ``default=`` override wins over the declared one, which
is how call sites keep ownership of measured tuning constants).
"""

from __future__ import annotations

import os
import warnings
from dataclasses import dataclass
from typing import Any, Callable, Dict, Optional


@dataclass(frozen=True)
class EnvVar:
    """One declared knob: its parser, declared default, and doc line."""

    name: str
    parse: Callable[[str], Any]
    default: Any
    help: str


_REGISTRY: Dict[str, EnvVar] = {}


def declare(name: str, parse: Callable[[str], Any], default: Any,
            help: str) -> EnvVar:
    if not name.startswith("REPRO_"):
        raise ValueError(f"env registry only holds REPRO_* names, got {name!r}")
    if name in _REGISTRY:
        raise ValueError(f"env var {name!r} already declared")
    var = EnvVar(name, parse, default, help)
    _REGISTRY[name] = var
    return var


_UNSET = object()


def read(name: str, default: Any = _UNSET) -> Any:
    """Parsed value of `name` (declared names only; raises RuntimeError
    for undeclared ones — the point of the registry is that there is no
    ad-hoc read path).  ``default=`` overrides the declared default for
    knobs whose fallback is a call-site measurement."""
    spec = _REGISTRY.get(name)
    if spec is None:
        raise RuntimeError(
            f"undeclared environment variable {name!r}; declare it in "
            f"repro.core.env (known: {sorted(_REGISTRY)})")
    fallback = spec.default if default is _UNSET else default
    raw = os.environ.get(name, "")
    if raw == "":
        return fallback
    try:
        return spec.parse(raw)
    except ValueError:
        return fallback


def registry() -> Dict[str, EnvVar]:
    """Snapshot of every declared knob (docs, tests, ``--help`` dumps)."""
    return dict(_REGISTRY)


# ---------------------------------------------------------------------------
# Parsers
# ---------------------------------------------------------------------------


def _parse_str(raw: str) -> str:
    return raw


def _parse_int_min0(raw: str) -> int:
    """Non-negative int; garbage raises (read() falls back to default)."""
    return max(int(raw), 0)


def _parse_flag(raw: str) -> bool:
    return raw.strip().lower() in ("1", "true", "yes", "on")


def _parse_codec_threads(raw: str) -> int:
    """Historical contract: garbage disables the pool (0), it does not
    fall back to auto sizing — an operator who set the knob at all asked
    for explicit control."""
    try:
        return max(int(raw), 0)
    except ValueError:
        return 0


def _choice(options: tuple, fallback: str) -> Callable[[str], str]:
    def parse(raw: str) -> str:
        return raw if raw in options else fallback

    return parse


def _parse_lanes(raw: str) -> Optional[int]:
    """``REPRO_RANS_LANES``, sanitized.  Env input never raises — the
    explicit ``lanes=`` argument keeps strict validation: ``0`` means
    auto (mirrors ``REPRO_CODEC_THREADS=0``); garbage and negatives fall
    back to auto with a warning; values above the lane maximum or
    non-powers-of-two clamp down with a warning."""
    from repro.core.rans_np import _LANES_MAX

    try:
        val = int(raw)
    except ValueError:
        warnings.warn(
            f"REPRO_RANS_LANES={raw!r} is not an integer; using auto lanes",
            RuntimeWarning, stacklevel=4)
        return None
    if val == 0:
        return None
    if val < 0:
        warnings.warn(
            f"REPRO_RANS_LANES={val} is negative; using auto lanes",
            RuntimeWarning, stacklevel=4)
        return None
    if val > _LANES_MAX:
        warnings.warn(
            f"REPRO_RANS_LANES={val} exceeds the maximum; "
            f"clamping to {_LANES_MAX}", RuntimeWarning, stacklevel=4)
        return _LANES_MAX
    if val & (val - 1):
        p2 = 1 << (val.bit_length() - 1)
        warnings.warn(
            f"REPRO_RANS_LANES={val} is not a power of two; "
            f"clamping to {p2}", RuntimeWarning, stacklevel=4)
        return p2
    return val


# ---------------------------------------------------------------------------
# The knob inventory
# ---------------------------------------------------------------------------

declare("REPRO_ASSET_DIR", _parse_str, None,
        "directory holding trained tokenizer assets (default: the "
        "package's tokenizer/assets)")
declare("REPRO_CODEC_THREADS", _parse_codec_threads, None,
        "shared codec pool size; 0/1 disables, unset = auto "
        "(min(4, cpus) on >2-CPU hosts)")
declare("REPRO_LZ_MODE", _choice(("scalar", "vector", "device", "auto"),
                                 "auto"), "auto",
        "LZ77 path: scalar reference loop, NumPy vector parse, Pallas "
        "device match finder, or size-routed auto")
declare("REPRO_RANS_MODE", _choice(("auto", "device"), "numpy"), "auto",
        "rANS path: numpy forces the host coder, device forces the "
        "Pallas lane kernels, auto routes on backend + payload size")
declare("REPRO_RANS_LANES", _parse_lanes, None,
        "interleaved rANS lane count (power of two); 0/unset = auto")
declare("REPRO_LZ_DEVICE_MIN", _parse_int_min0, None,
        "payload bytes before the LZ77 device match finder pays off")
declare("REPRO_RANS_DEVICE_MIN", _parse_int_min0, None,
        "payload bytes before the device rANS lane kernels pay off")
declare("REPRO_PACK_DEVICE_MIN", _parse_int_min0, None,
        "batch token count before the device pack kernel pays off")
declare("REPRO_HIST_DEVICE_MIN", _parse_int_min0, None,
        "payload bytes before the device histogram kernel pays off")
declare("REPRO_LOCK_SANITIZER", _parse_flag, False,
        "1/true enables the runtime lock-order sanitizer "
        "(repro.core.locks); on for concurrency/crash test markers")
declare("REPRO_ANALYSIS_FROZEN_MANIFEST", _parse_str, None,
        "override path of the frozen wire-format hash manifest "
        "(repro.analysis rule REPRO003; tests point it at fixtures)")
declare("REPRO_OBS", _parse_flag, True,
        "0/false disables the repro.obs metrics/tracing layer; "
        "instrument sites resolve to shared no-op stubs at creation")
declare("REPRO_OBS_JOURNAL", _parse_int_min0, 4096,
        "capacity (events) of the repro.obs span journal ring buffer; "
        "oldest events are dropped first")


def _parse_int_min1(raw: str) -> int:
    """Positive int; garbage raises (read() falls back to default)."""
    val = int(raw)
    if val < 1:
        raise ValueError(f"expected >= 1, got {val}")
    return val


def _parse_float_min0(raw: str) -> float:
    """Non-negative float; garbage raises (read() falls back)."""
    val = float(raw)
    if val < 0:
        raise ValueError(f"expected >= 0, got {val}")
    return val


declare("REPRO_GATEWAY_MAX_INFLIGHT", _parse_int_min1, 64,
        "gateway admission control: max requests executing at once "
        "across all connections; excess requests are rejected with "
        "error=admission_reject, never buffered")
declare("REPRO_GATEWAY_CONN_WINDOW", _parse_int_min1, 8,
        "gateway per-connection in-flight window; a client pipelining "
        "past it is stalled by TCP backpressure (the reader loop stops "
        "consuming), propagating the ingest queue's max_pending")
declare("REPRO_GATEWAY_FRAME_MAX", _parse_int_min1, 16 << 20,
        "max accepted gateway frame payload (bytes); larger frames "
        "close the connection with error=frame_too_large")
declare("REPRO_GATEWAY_DRAIN_S", _parse_float_min0, 5.0,
        "graceful-drain budget on SIGTERM: seconds the gateway waits "
        "for in-flight requests before forcing shutdown")
declare("REPRO_GATEWAY_REFRESH_S", _parse_float_min0, 0.5,
        "read-replica poll interval: how often a replica gateway "
        "re-checks store.json / shard indexes for writer publishes")
declare("REPRO_FAULTS", _parse_str, "",
        "fault-injection spec 'pattern=schedule,action[;...]' armed at "
        "the named failpoint sites (repro.core.failpoints.SITES); "
        "empty = nothing injected")
declare("REPRO_FAULTS_SEED", _parse_int_min0, 0,
        "seed for the per-rule RNG behind probabilistic (p:) fault "
        "schedules; same seed + same hit order = same fault sequence")
declare("REPRO_GATEWAY_RETRIES", _parse_int_min0, 4,
        "GatewayClient retry budget per call(): total attempts for "
        "retryable failures (connection loss, admission_reject, "
        "timeout); 0 disables retries")
declare("REPRO_GATEWAY_RETRY_BASE_S", _parse_float_min0, 0.05,
        "GatewayClient backoff base: sleep base*2^attempt plus "
        "seeded jitter between retries, capped at 2s")
