"""Crash-durability helpers: the fsync half of tmp-then-rename publish.

The store's atomic-publish discipline (ARCHITECTURE.md) is: write to a
tmp file, fsync the *file* (data reaches the platter before the name
does), ``os.replace`` onto the final name, then fsync the *parent
directory* (the rename itself is metadata — on ext4/xfs an unsynced
directory can forget the rename after power loss, resurrecting the old
bytes under the new name).  These helpers are deliberately small and
call-site-visible: publishers keep their ``os.replace`` inline rather
than calling one opaque wrapper, so the static durability rule
(``repro.analysis`` REPRO002) can see the full
write → fsync → replace → fsync-dir sequence lexically and flag any
publisher that skips a step.

``fsync_dir`` is best-effort: directory fds are unsupported on some
platforms/filesystems (notably Windows), and a publish that lands but
may be forgotten on power-loss is strictly better than one that
crashes every save on such hosts.
"""

from __future__ import annotations

import os
import time
from pathlib import Path
from typing import Union

from repro import obs
from repro.core import failpoints


def fsync_file(f) -> None:
    """Flush a writable file object's buffers down to the platter."""
    failpoints.fire("durability.fsync_file")
    t0 = time.perf_counter()
    f.flush()
    os.fsync(f.fileno())
    obs.histogram("durability.fsync.s").observe(time.perf_counter() - t0)


def fsync_dir(path: Union[str, Path]) -> None:
    """Best-effort fsync of a directory (persists renames/creates in it).
    The failpoint fires *outside* the best-effort absorption below: real
    directory-fsync errors are survivable, injected crashes are not."""
    failpoints.fire("durability.fsync_dir")
    try:
        fd = os.open(str(path), os.O_RDONLY)
    except OSError:
        return
    try:
        os.fsync(fd)
    except OSError:
        pass
    finally:
        os.close(fd)


def write_durable(path: Union[str, Path], data: bytes) -> None:
    """Write ``data`` to ``path`` and fsync the file (not the parent —
    publishers fsync the parent after their ``os.replace``).  This is
    the cooperating torn-write site: a ``torn`` fault persists a prefix
    of ``data`` before the crash propagates, so recovery code sees a
    genuinely half-written file, not a clean absence."""
    try:
        failpoints.fire("durability.write_durable")
    except failpoints.TornWrite as torn:
        with open(path, "wb") as f:
            f.write(data[:torn.keep(len(data))])
        raise
    with open(path, "wb") as f:
        f.write(data)
        fsync_file(f)


def publish_durable(path: Union[str, Path], data: bytes) -> None:
    """Atomically publish ``data`` at ``path`` via tmp → fsync →
    ``os.replace`` → fsync-dir.  For standalone artifacts (stats-json
    dumps, port files) whose readers must never observe a torn
    document; store publishers keep the sequence inline instead so
    REPRO002 can check their interleaving with index/meta writes."""
    path = Path(path)
    tmp = path.with_name(path.name + ".tmp")
    write_durable(tmp, data)
    failpoints.fire("durability.publish")
    os.replace(tmp, path)
    fsync_dir(path.parent)
