"""Cross-process store ownership: ``fcntl.flock`` leases on a store root.

A store root is owned by at most ONE writer process at a time.  The
writer holds an exclusive ``flock`` on ``<root>/store.lease`` for the
lifetime of its :class:`~repro.core.store.ShardedPromptStore`; read-only
replicas (``ShardedPromptStore(readonly=True)``) never touch the lease
and follow the writer's generation swaps through ``store.json``.

Why ``flock`` and not a pid file: the kernel releases the lock the
instant the holder's last fd closes — including SIGKILL, OOM, or a
power-cycle of the container — so a standby that blocks on the lease
takes over the moment the writer dies, with no stale-pid heuristics and
no janitor.  The lease *file* is never deleted; its contents (holder
pid) are advisory debugging info only, the lock itself is the truth.

Within one process the lease is refcounted per root: a second writable
open of the same root shares the held lock instead of self-deadlocking
on a second fd (``flock`` locks conflict *between fds*, even in one
process).  This preserves the historical "one process owns a root"
contract for in-process reopen patterns while excluding other
processes.

On platforms without ``fcntl`` (Windows) the lease degrades to the
in-process registry: same-process exclusivity still holds, cross-process
exclusivity is advisory only.
"""

from __future__ import annotations

import os
import threading
import time
from pathlib import Path
from typing import Dict, Optional, Union

try:
    import fcntl
except ImportError:  # pragma: no cover - non-POSIX hosts
    fcntl = None  # type: ignore[assignment]

from repro.core import failpoints

LEASE_NAME = "store.lease"

#: how often a "wait"-mode acquire re-polls the lock (non-blocking
#: attempts rather than a blocking flock, so timeouts work and the
#: in-process registry stays consistent between attempts)
_POLL_S = 0.05


class StoreLeaseHeld(RuntimeError):
    """Another process holds the writer lease for this store root."""


def lease_path(root: Union[str, Path]) -> Path:
    return Path(root) / LEASE_NAME


class _Entry:
    __slots__ = ("fd", "count")

    def __init__(self, fd: int) -> None:
        self.fd = fd
        self.count = 1


_registry_lock = threading.Lock()
_leases: Dict[str, _Entry] = {}


class StoreLease:
    """Handle on one acquisition of a root's writer lease.  ``release()``
    decrements the per-process refcount; the flock drops when the last
    in-process holder releases (or the process dies)."""

    def __init__(self, key: str, path: Path) -> None:
        self._key = key
        self.path = path
        self._released = False

    def release(self) -> None:
        if self._released:
            return
        self._released = True
        with _registry_lock:
            entry = _leases.get(self._key)
            if entry is None:  # pragma: no cover - double-release safety
                return
            entry.count -= 1
            if entry.count == 0:
                del _leases[self._key]
                if fcntl is not None:
                    try:
                        fcntl.flock(entry.fd, fcntl.LOCK_UN)
                    except OSError:  # pragma: no cover - fd already dead
                        pass
                os.close(entry.fd)

    def __enter__(self) -> "StoreLease":
        return self

    def __exit__(self, *exc) -> None:
        self.release()

    def __repr__(self) -> str:
        return f"<StoreLease {self.path} released={self._released}>"


def holder_pid(root: Union[str, Path]) -> Optional[int]:
    """Advisory pid recorded by the current/most recent holder (the
    flock, not this value, decides ownership)."""
    try:
        raw = lease_path(root).read_text().strip()
        return int(raw.split()[0]) if raw else None
    except (OSError, ValueError):
        return None


def _try_flock(fd: int) -> bool:
    if fcntl is None:
        return True  # degraded mode: in-process exclusivity only
    try:
        fcntl.flock(fd, fcntl.LOCK_EX | fcntl.LOCK_NB)
        return True
    except OSError:
        return False


def acquire_store_lease(root: Union[str, Path], mode: str = "try",
                        timeout_s: Optional[float] = None) -> StoreLease:
    """Acquire the writer lease for ``root``.

    ``mode="try"`` raises :class:`StoreLeaseHeld` immediately when
    another process holds it; ``mode="wait"`` polls until the holder
    dies or releases (a standby's takeover path), raising
    ``TimeoutError`` if ``timeout_s`` elapses first.
    """
    if mode not in ("try", "wait"):
        raise ValueError(f"lease mode must be 'try' or 'wait', got {mode!r}")
    failpoints.fire("lease.acquire")
    path = lease_path(root)
    key = os.path.realpath(str(path))
    deadline = (time.monotonic() + timeout_s) if timeout_s is not None else None
    while True:
        with _registry_lock:
            entry = _leases.get(key)
            if entry is not None:  # this process already owns it: share
                entry.count += 1
                return StoreLease(key, path)
            fd = os.open(str(path), os.O_RDWR | os.O_CREAT, 0o644)
            if _try_flock(fd):
                # advisory holder info; the flock is the source of truth,
                # so this needs no durability discipline
                try:
                    os.ftruncate(fd, 0)
                    os.write(fd, f"{os.getpid()}\n".encode())
                except OSError:  # pragma: no cover - best effort
                    pass
                _leases[key] = _Entry(fd)
                return StoreLease(key, path)
            os.close(fd)
            pid = holder_pid(root)
        if mode == "try":
            raise StoreLeaseHeld(
                f"store root {root} is owned by another process"
                + (f" (pid {pid})" if pid else "")
                + "; open with readonly=True for a replica, or lease='wait' "
                "to stand by for takeover")
        if deadline is not None and time.monotonic() >= deadline:
            raise TimeoutError(
                f"timed out waiting {timeout_s}s for the store lease on "
                f"{root}" + (f" (held by pid {pid})" if pid else ""))
        time.sleep(_POLL_S)
