"""Runtime lock-order sanitizer: ranked lock constructors for the store.

The store's deadlock-freedom argument is a total order on its lock
classes (ARCHITECTURE.md "Static analysis & invariants"): a thread may
only acquire a lock whose rank is **>=** the highest rank it already
holds.  Equal ranks are allowed because the rebalancer legitimately
takes *all* compact locks, then *all* shard locks (each class in index
order, and only under the rebalance lock, so two such sweeps never
interleave).

    rebalance(0) < compact(10) < shard(20) < index(30) < meta(40)
                                                       < obs(100)

:func:`make_lock` / :func:`make_rlock` are drop-in constructor
replacements for ``threading.Lock()`` / ``threading.RLock()``.  With
``REPRO_LOCK_SANITIZER`` unset (production) they return the plain
threading primitive — zero overhead, nothing wrapped.  With the flag set
(the ``concurrency`` and ``crash`` pytest markers turn it on via
conftest) they return a :class:`_SanitizedLock` that keeps a per-thread
stack of held locks and raises :class:`LockOrderViolation` — with both
acquisition sites in the message — the moment any thread acquires
against the order, whether or not the opposing thread is running.  The
flag is read at *creation* time: a store built inside a sanitized test
stays sanitized for its lifetime.

The static half of this invariant is ``repro.analysis`` rule REPRO001,
which checks the acquisition *graph* over the same rank table without
running anything; this module catches what static analysis cannot see
(acquisitions through callbacks, test monkeypatching, future code the
graph walker under-approximates).
"""

from __future__ import annotations

import sys
import threading
from typing import Dict, List, Union

from repro.core import env

RANKS: Dict[str, int] = {
    "rebalance": 0,
    "compact": 10,
    "shard": 20,
    "index": 30,
    "meta": 40,
    # Near-leaf: the failpoint rule registry (repro.core.failpoints).
    # fire() runs inside arbitrary critical sections (an fsync under the
    # shard lock, a meta publish under the meta lock), so its lock must
    # out-rank every store lock; it stays below obs because _apply
    # records a metric, and obs never calls back into failpoints.
    "faults": 90,
    # Leaf rank: repro.obs instrument/registry/journal locks.  Metrics
    # are recorded from inside every other critical section (a shard
    # append observes its fsync latency while the shard lock is held),
    # so obs locks must be acquirable while holding anything — and obs
    # code never calls back out, so nothing is ever acquired under them.
    "obs": 100,
}


class LockOrderViolation(RuntimeError):
    """A thread acquired a lock ranked below one it already holds."""


def sanitizer_enabled() -> bool:
    return env.read("REPRO_LOCK_SANITIZER")


_HELD = threading.local()  # .stack: List[_Held] for the current thread


class _Held:
    __slots__ = ("lock", "site")

    def __init__(self, lock: "_SanitizedLock", site: str):
        self.lock = lock
        self.site = site


def _held_stack() -> List[_Held]:
    stack = getattr(_HELD, "stack", None)
    if stack is None:
        stack = _HELD.stack = []
    return stack


def _acquisition_site() -> str:
    """One-line description of the nearest caller frame outside this
    module; cheap enough for hot test paths."""
    frame = sys._getframe(1)
    while frame is not None and frame.f_code.co_filename == __file__:
        frame = frame.f_back
    if frame is None:
        return "<unknown>"
    return (f"{frame.f_code.co_filename}:{frame.f_lineno} "
            f"in {frame.f_code.co_name}")


class _SanitizedLock:
    """Ranked wrapper over a threading Lock/RLock with order checking."""

    def __init__(self, order: str, reentrant: bool):
        if order not in RANKS:
            raise ValueError(
                f"unknown lock order {order!r}; known: {sorted(RANKS)}")
        self.order = order
        self.rank = RANKS[order]
        self.reentrant = reentrant
        self._inner: Union[threading.Lock, threading.RLock] = (
            threading.RLock() if reentrant else threading.Lock())

    def _check(self) -> None:
        stack = _held_stack()
        if not stack:
            return
        if any(h.lock is self for h in stack):
            if self.reentrant:
                return  # RLock re-entry is legal and not an ordering event
            raise LockOrderViolation(
                f"self-deadlock: thread already holds non-reentrant "
                f"{self.order!r} lock (acquired at {next(h.site for h in stack if h.lock is self)})")
        top = max(stack, key=lambda h: h.lock.rank)
        if self.rank < top.lock.rank:
            held = ", ".join(
                f"{h.lock.order}(rank {h.lock.rank}) at {h.site}"
                for h in stack)
            raise LockOrderViolation(
                f"lock-order violation: acquiring {self.order!r} "
                f"(rank {self.rank}) at {_acquisition_site()} while "
                f"holding higher-ranked locks [{held}]; documented order "
                f"is {' < '.join(sorted(RANKS, key=RANKS.get))}")

    def acquire(self, blocking: bool = True, timeout: float = -1) -> bool:
        self._check()
        got = self._inner.acquire(blocking, timeout)
        if got:
            _held_stack().append(_Held(self, _acquisition_site()))
        return got

    def release(self) -> None:
        self._inner.release()
        stack = _held_stack()
        for i in range(len(stack) - 1, -1, -1):
            if stack[i].lock is self:
                del stack[i]
                break

    def __enter__(self) -> bool:
        return self.acquire()

    def __exit__(self, *exc) -> None:
        self.release()

    def locked(self) -> bool:
        inner = self._inner
        return inner.locked() if hasattr(inner, "locked") else False

    def __repr__(self) -> str:
        return f"<SanitizedLock order={self.order} rank={self.rank}>"


def make_lock(order: str):
    """A ``threading.Lock()`` tagged with its documented rank; sanitized
    wrapper only when ``REPRO_LOCK_SANITIZER`` is set at creation."""
    if sanitizer_enabled():
        return _SanitizedLock(order, reentrant=False)
    if order not in RANKS:
        raise ValueError(
            f"unknown lock order {order!r}; known: {sorted(RANKS)}")
    return threading.Lock()


def make_rlock(order: str):
    """``threading.RLock()`` counterpart of :func:`make_lock`."""
    if sanitizer_enabled():
        return _SanitizedLock(order, reentrant=True)
    if order not in RANKS:
        raise ValueError(
            f"unknown lock order {order!r}; known: {sorted(RANKS)}")
    return threading.RLock()
