"""Layered codec pipelines: the paper's three methods as composable stages.

The paper's hybrid method (§3.4, Algorithm 1) *is* a two-stage pipeline —
pack the token ids, then byte-compress the packed stream — and CompactPrompt
/ LLMLingua-style systems generalize exactly this shape: a chain of
bijective stages, each mapping a batch of byte payloads to a batch of byte
payloads.  This module makes that structure explicit:

    Codec              protocol: encode_batch / decode_batch over payloads
    TokenPackCodec     text bytes  <-> packed token ids (τ then P)
    ByteCompressorCodec payload    <-> C_backend(payload)  (any BACKENDS entry)
    PipelineCodec      ordered stage composition (decode runs in reverse)

and re-expresses the paper's methods as pipelines:

    zstd   = [ByteCompressorCodec]
    token  = [TokenPackCodec]
    hybrid = [TokenPackCodec, ByteCompressorCodec]

Byte-exactness contract: for every method, the pipeline's single-element
encode output is bit-identical to the paper-exact functions in
``repro.core.api`` (``compress_zstd`` / ``compress_token`` /
``compress_hybrid``), and batched encode is bit-identical to sequential
encode.  Both identities are asserted by tests/test_codec.py, so benchmark
byte sizes are unchanged by this layering.

Device routing: the fixed-width pack stage is pure byte movement, so on an
accelerator the batch path concatenates streams and runs the Pallas
byte-split kernel in one launch per width group
(``repro.kernels.token_pack.pack_fixed_batch_device``); on CPU hosts the
pure-NumPy ``packing.pack_fixed`` path is used per stream.
"""

from __future__ import annotations

import os
import threading
import time
from concurrent.futures import ThreadPoolExecutor
from typing import Callable, Dict, List, Optional, Protocol, Sequence, runtime_checkable

from repro.core import env
from repro import obs

import numpy as np

from repro.core import packing
from repro.core.zstd_backend import (BACKENDS, DEFAULT_LEVEL, DICT_BACKENDS,
                                     compress_bytes, compress_bytes_dict,
                                     decompress_bytes, decompress_bytes_dict)
from repro.tokenizer.bpe import BPETokenizer

# ---------------------------------------------------------------------------
# Shared codec thread pool
# ---------------------------------------------------------------------------
#
# One process-wide pool fans per-record byte compression out across cores:
# `PromptCompressor.compress_batch`, `ShardedPromptStore.plan_batch` and the
# ingest dispatcher all reach it through the byte-stage codecs below, so a
# group commit's latency is bounded by its slowest record, not the sum.
# The win is real where the leaf releases the GIL (zlib/bz2/lzma and the
# zstd C library do; the from-scratch backends only during their NumPy
# spans — see ARCHITECTURE.md "Vectorized codec path" for measurements).
# Sizing: REPRO_CODEC_THREADS always wins (0/1 disables); the default is
# min(4, cpu_count) on hosts with >2 CPUs and DISABLED on <=2-CPU boxes,
# where measurement shows even the GIL-releasing C codecs lose to the
# handoff+contention cost (2 vCPUs are typically hyperthread siblings).
# Leaf tasks never submit back into the pool, so a bounded worker count
# cannot deadlock.

_POOL: Optional[ThreadPoolExecutor] = None
_POOL_SIZE = 0
_POOL_LOCK = threading.Lock()
_PAR_MIN_BATCH = 4          # payloads per batch before the pool pays off
_PAR_MIN_BYTES = 1 << 16    # total bytes before the pool pays off


def codec_pool_size() -> int:
    size = env.read("REPRO_CODEC_THREADS")
    if size is not None:
        return size
    cpus = os.cpu_count() or 1
    return min(4, cpus) if cpus > 2 else 0


def _codec_pool() -> Optional[ThreadPoolExecutor]:
    global _POOL, _POOL_SIZE
    size = codec_pool_size()
    if size <= 1:
        return None
    with _POOL_LOCK:
        if _POOL is None or _POOL_SIZE != size:
            if _POOL is not None:
                _POOL.shutdown(wait=False)
            _POOL = ThreadPoolExecutor(max_workers=size,
                                       thread_name_prefix="codec")
            _POOL_SIZE = size
        return _POOL


def _parallel_map(fn: Callable[[bytes], bytes],
                  payloads: Sequence[bytes]) -> List[bytes]:
    """Order-preserving map over payloads, fanned across the shared pool
    when the batch is big enough to amortize the handoff."""
    if (len(payloads) >= _PAR_MIN_BATCH
            and sum(map(len, payloads)) >= _PAR_MIN_BYTES):
        pool = _codec_pool()
        if pool is not None:
            return list(pool.map(fn, payloads))
    return [fn(p) for p in payloads]


# ---------------------------------------------------------------------------
# Codec observability
# ---------------------------------------------------------------------------
#
# Every stage/pipeline owns a `_CodecObs` created once in __init__ —
# the REPRO_OBS gate is resolved there, so the per-batch cost with obs
# disabled is one perf_counter read and one no-op method call (byte
# totals are only summed by the enabled twin).  Pipelines additionally
# export the paper's Table metrics as derived gauges: live compression
# ratio and encode/decode MB/s per method, computed from the running
# byte/second totals at snapshot time.


class _CodecObs:
    __slots__ = ("enc_s", "dec_s", "enc_in", "enc_out", "dec_in", "dec_out")

    def __init__(self, **labels) -> None:
        self.enc_s = obs.histogram("codec.encode.s", **labels)
        self.dec_s = obs.histogram("codec.decode.s", **labels)
        self.enc_in = obs.counter("codec.encode.bytes_in", **labels)
        self.enc_out = obs.counter("codec.encode.bytes_out", **labels)
        self.dec_in = obs.counter("codec.decode.bytes_in", **labels)
        self.dec_out = obs.counter("codec.decode.bytes_out", **labels)

    def encode(self, dt: float, payloads: Sequence[bytes],
               out: Sequence[bytes]) -> None:
        self.enc_s.observe(dt)
        self.enc_in.inc(sum(map(len, payloads)))
        self.enc_out.inc(sum(map(len, out)))

    def decode(self, dt: float, payloads: Sequence[bytes],
               out: Sequence[bytes]) -> None:
        self.dec_s.observe(dt)
        self.dec_in.inc(sum(map(len, payloads)))
        self.dec_out.inc(sum(map(len, out)))


class _NullCodecObs:
    __slots__ = ()

    def encode(self, dt, payloads, out) -> None:
        pass

    def decode(self, dt, payloads, out) -> None:
        pass


_NULL_CODEC_OBS = _NullCodecObs()


def _codec_obs(**labels):
    return _CodecObs(**labels) if obs.enabled() else _NULL_CODEC_OBS


def _pipeline_obs(method: str):
    """Method-level obs plus the derived ratio/throughput gauges."""
    o = _codec_obs(method=method)
    if isinstance(o, _CodecObs):
        obs.derived_gauge(
            "codec.compression_ratio",
            lambda: o.enc_in.value / o.enc_out.value, method=method)
        obs.derived_gauge(
            "codec.encode_mb_s",
            lambda: (o.enc_in.value / 2**20) / o.enc_s.sum, method=method)
        obs.derived_gauge(
            "codec.decode_mb_s",
            lambda: (o.dec_out.value / 2**20) / o.dec_s.sum, method=method)
    return o


@runtime_checkable
class Codec(Protocol):
    """A bijective batch transform over byte payloads."""

    name: str

    def encode_batch(self, payloads: Sequence[bytes]) -> List[bytes]: ...

    def decode_batch(self, payloads: Sequence[bytes]) -> List[bytes]: ...


# ---------------------------------------------------------------------------
# Stage codecs
# ---------------------------------------------------------------------------


# device-packing crossover (total ids across the batch): one kernel
# launch per width group still has to beat per-stream NumPy casts;
# override with REPRO_PACK_DEVICE_MIN when re-tuning
_PACK_DEVICE_MIN_IDS = 1 << 14


class TokenPackCodec:
    """τ then P: UTF-8 text bytes <-> self-describing packed token stream.

    ``use_device=None`` auto-routes: Pallas kernel batch path on
    accelerators, per-stream NumPy on CPU.  Both paths are bit-identical
    (kernel parity tests in tests/test_kernels.py).
    """

    name = "token-pack"

    def __init__(self, tokenizer: BPETokenizer, scheme: str = "fixed",
                 use_device: Optional[bool] = None) -> None:
        if tokenizer is None:
            raise ValueError("TokenPackCodec requires a tokenizer")
        if scheme not in packing.PACKERS:
            raise ValueError(f"unknown packing scheme {scheme!r}")
        self.tokenizer = tokenizer
        self.scheme = scheme
        self.use_device = use_device
        self._obs = _codec_obs(stage=self.name, scheme=scheme)

    # -- token-level entry points (used by the token-stream storage mode) --

    def encode_ids_batch(self, ids_list: Sequence[np.ndarray]) -> List[bytes]:
        if self.scheme == "fixed":
            from repro.core import device as _device

            total = sum(np.asarray(ids).size for ids in ids_list)
            if _device.use_device(total, "REPRO_PACK_DEVICE_MIN",
                                  _PACK_DEVICE_MIN_IDS,
                                  force=self.use_device):
                import jax

                from repro.kernels.token_pack import pack_fixed_batch_device

                # compiled kernel on real accelerators; interpret mode only
                # when the device path is forced on a CPU host (tests)
                return pack_fixed_batch_device(
                    ids_list, interpret=jax.default_backend() == "cpu")
        return [packing.pack_tokens(ids, self.scheme) for ids in ids_list]

    def decode_ids_batch(self, payloads: Sequence[bytes],
                         to_device: bool = False) -> List[np.ndarray]:
        """Packed payloads -> token-id arrays.  ``to_device=True`` lands
        each array in device memory (jnp uint32) instead of host NumPy —
        the serve path's decompress-to-tokens feeds model input staging
        without a host round trip.  Fixed-width payloads byte-combine on
        device; varint formats decode on host and upload."""
        if to_device:
            import jax.numpy as jnp

            from repro.kernels.token_pack import unpack_fixed_device

            out = []
            for p in payloads:
                fmt = p[0] if len(p) else packing.FMT_U16
                if fmt in packing._FIXED:
                    out.append(unpack_fixed_device(p))
                else:
                    out.append(jnp.asarray(packing.unpack_tokens(p)))
            return out
        return [packing.unpack_tokens(p) for p in payloads]

    # -- Codec protocol ----------------------------------------------------

    def encode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        ids_list = self.tokenizer.encode_batch([p.decode("utf-8") for p in payloads])
        out = self.encode_ids_batch([np.asarray(ids, np.uint32) for ids in ids_list])
        self._obs.encode(time.perf_counter() - t0, payloads, out)
        return out

    def decode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        out = [self.tokenizer.decode_bytes(ids) for ids in self.decode_ids_batch(payloads)]
        self._obs.decode(time.perf_counter() - t0, payloads, out)
        return out


class ByteCompressorCodec:
    """C_backend stage over any registered byte backend (zstd by default)."""

    name = "byte-compressor"

    def __init__(self, level: int = DEFAULT_LEVEL, backend: str = "zstd") -> None:
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}; have {sorted(BACKENDS)}")
        self.level = level
        self.backend = backend
        self._obs = _codec_obs(stage=self.name, backend=backend)

    def encode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        out = _parallel_map(
            lambda p: compress_bytes(p, level=self.level, backend=self.backend),
            payloads)
        self._obs.encode(time.perf_counter() - t0, payloads, out)
        return out

    def decode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        out = _parallel_map(
            lambda p: decompress_bytes(p, backend=self.backend), payloads)
        self._obs.decode(time.perf_counter() - t0, payloads, out)
        return out


class DictCodec:
    """Dictionary-seeded byte-compressor stage (paper §8.4.2 #2).

    Same position in a pipeline as :class:`ByteCompressorCodec`, but the
    backend is primed with a trained dictionary, recovering cross-record
    redundancy that per-record compression cannot see.  Encode and decode
    must hold the identical dictionary bytes — the frame layer
    (``repro.core.api``) threads a fingerprint through v2 frame headers
    and the store persists the blob as a per-shard-generation sidecar.
    """

    name = "dict-compressor"

    def __init__(self, dictionary: bytes, level: int = DEFAULT_LEVEL,
                 backend: str = "zstd") -> None:
        if backend not in DICT_BACKENDS:
            raise ValueError(
                f"backend {backend!r} has no dictionary mode; "
                f"have {sorted(DICT_BACKENDS)}")
        if not dictionary:
            raise ValueError("DictCodec requires a non-empty dictionary")
        self.dictionary = bytes(dictionary)
        self.level = level
        self.backend = backend
        self._obs = _codec_obs(stage=self.name, backend=backend)

    def encode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        out = _parallel_map(
            lambda p: compress_bytes_dict(p, self.dictionary, level=self.level,
                                          backend=self.backend), payloads)
        self._obs.encode(time.perf_counter() - t0, payloads, out)
        return out

    def decode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        out = _parallel_map(
            lambda p: decompress_bytes_dict(p, self.dictionary,
                                            backend=self.backend), payloads)
        self._obs.decode(time.perf_counter() - t0, payloads, out)
        return out


class PipelineCodec:
    """Ordered composition of stages; decode applies the inverses in reverse."""

    def __init__(self, stages: Sequence[Codec], name: str = "pipeline") -> None:
        if not stages:
            raise ValueError("pipeline needs at least one stage")
        self.stages = list(stages)
        self.name = name
        self._obs = _pipeline_obs(name)

    def encode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        out = list(payloads)
        for stage in self.stages:
            out = stage.encode_batch(out)
        self._obs.encode(time.perf_counter() - t0, payloads, out)
        return out

    def decode_batch(self, payloads: Sequence[bytes]) -> List[bytes]:
        t0 = time.perf_counter()
        out = list(payloads)
        for stage in reversed(self.stages):
            out = stage.decode_batch(out)
        self._obs.decode(time.perf_counter() - t0, payloads, out)
        return out


# ---------------------------------------------------------------------------
# Registry
# ---------------------------------------------------------------------------

CODEC_REGISTRY: Dict[str, Callable[..., Codec]] = {}


def register_codec(name: str, factory: Callable[..., Codec]) -> None:
    if name in CODEC_REGISTRY:
        raise ValueError(f"codec {name!r} already registered")
    CODEC_REGISTRY[name] = factory


def get_codec(name: str, **kwargs) -> Codec:
    try:
        factory = CODEC_REGISTRY[name]
    except KeyError:
        raise ValueError(
            f"unknown codec {name!r}; have {sorted(CODEC_REGISTRY)}") from None
    return factory(**kwargs)


register_codec("token-pack", TokenPackCodec)
register_codec("byte-compressor", ByteCompressorCodec)
register_codec("dict-compressor", DictCodec)


def method_pipeline(
    method: str,
    tokenizer: Optional[BPETokenizer] = None,
    level: int = DEFAULT_LEVEL,
    backend: str = "zstd",
    scheme: str = "fixed",
    use_device: Optional[bool] = None,
    dictionary: Optional[bytes] = None,
) -> PipelineCodec:
    """The paper's three methods as stage pipelines (§3.2-§3.4).

    With ``dictionary``, the byte-compressor stage is swapped for a
    :class:`DictCodec` primed with it; ``token`` has no byte stage, so a
    dictionary there is an error."""
    if dictionary:
        byte_stage: Codec = DictCodec(dictionary, level, backend)
    else:
        byte_stage = ByteCompressorCodec(level, backend)
    if method == "zstd":
        stages: List[Codec] = [byte_stage]
    elif method == "token":
        if dictionary:
            raise ValueError(
                "method 'token' has no byte-compressor stage to apply a "
                "dictionary to")
        stages = [TokenPackCodec(tokenizer, scheme, use_device)]
    elif method == "hybrid":
        stages = [TokenPackCodec(tokenizer, scheme, use_device), byte_stage]
    else:
        raise ValueError(f"unknown method {method!r}")
    return PipelineCodec(stages, name=method)
