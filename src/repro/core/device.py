"""Central device-dispatch policy for the codec tier.

Every codec stage with a Pallas kernel behind it (histogram, token
packing, LZ77 match finding, lane-parallel rANS) asks the same two
questions before leaving the host:

1. is a non-CPU JAX backend actually attached?  On CPU hosts the
   interpret-mode kernels lose to vectorized NumPy by orders of
   magnitude, so the device path is never taken implicitly there;
2. is the payload big enough to amortize the host->device->host round
   trip?  Tiny payloads pay more in dispatch + transfer than the kernel
   saves — each call site carries a measured crossover, overridable by
   an env knob for re-tuning on new hardware.

Keeping the answers here (instead of one private helper per module, as
the histogram and token-pack stages originally grew) means the routing
policy is uniform and testable in one place.
"""

from __future__ import annotations

from typing import Optional

from repro import obs
from repro.core import env


def backend_available() -> bool:
    """True iff JAX has a non-CPU backend attached."""
    try:
        import jax

        return jax.default_backend() != "cpu"
    except Exception:  # pragma: no cover - jax is a hard dep of this repo
        return False


def crossover(env_var: str, default: int) -> int:
    """Payload-size floor (bytes/elements) for taking a device path.

    Reads ``env_var`` fresh on every call so benchmarks and tests can
    re-tune without reimporting; invalid values fall back to the
    measured default rather than raising (the registry's int parser
    raises and ``env.read`` absorbs it into the default).
    """
    return env.read(env_var, default)


def use_device(size: int, env_var: str, default_min: int,
               force: Optional[bool] = None) -> bool:
    """The standard routing decision: explicit ``force`` wins, otherwise
    a non-CPU backend must be attached and ``size`` must clear the
    crossover."""
    if force is not None:
        decision = force
    else:
        decision = (backend_available()
                    and size >= crossover(env_var, default_min))
    # routing census: how often each kernel family actually leaves the
    # host (obs.counter is a no-op stub when REPRO_OBS=0)
    obs.counter("device.dispatch", knob=env_var.lower(),
                path="device" if decision else "host").inc()
    return decision
