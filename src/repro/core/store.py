"""PromptStore: the database-integration layer of the paper (§6.2.3),
scaled out as a sharded, batch-first segment store.

Layout (``n_shards`` segment files, shard chosen by content-key prefix):

    <root>/store.json          {"version": 1, "n_shards": N, "gens": [...]}
    <root>/shard-000.bin       concatenated frames (segment 0, generation 0)
    <root>/shard-000.idx.jsonl one record per frame: key (sha256 of the
                               text), offset, length, method, n_chars
    ...

A 1-shard store uses the legacy flat names ``data.bin`` / ``index.jsonl``
so stores written by earlier versions open unchanged.  Compacted shards
live at a bumped *generation* (``shard-000.g0001.bin``); the meta file is
the atomic commit point, so a crash mid-compaction always reopens a fully
intact generation (see `swap_shard`).

Properties the paper calls for, preserved per shard:
* application-level compression before storage (§2.4),
* searchable token ids without full decompression (§6.2.3 — `get_tokens`),
* integrity: every get() verifies the content hash (§4.6 discipline),
* durability: a shard's data append is flushed+fsynced before its index
  lines are published; a torn final record (crash between data and index
  write, or mid index line) is detected and ignored on open, and a torn
  tail in one shard never affects the others.

Batch-first writes: ``put_many`` compresses the whole batch through the
codec pipeline (one batched BPE/pack pass), groups records by shard, and
group-commits — one data fsync and one index fsync per *shard touched per
batch* instead of two fsyncs per record, which is where the put_many
throughput win comes from (benchmarks/batch_throughput.py).

Concurrency (the contract the `repro.service` tier builds on):
* one lock per shard *slot* (stable across compaction generations)
  serializes appends, reads, and the compaction swap for that shard;
  different shards commit in parallel — the ingest queue's per-shard
  writer threads fsync concurrently;
* a store-wide index lock guards the in-memory key map and the `seq`
  counter; lock order is always shard lock -> index lock, never reversed;
* `put_many` splits into `plan_batch` (compress + reserve seqs; no I/O
  locks held during compression) and `commit_batch` (per-shard durable
  commit), so a dispatcher thread can plan while writer threads commit;
* racing planners may write the same content key twice (both blobs decode
  to the same text; the higher `seq` wins the index) — the duplicate's
  bytes become dead space that `repro.service.compaction` reclaims;
* `keys()` orders by `seq`, so iteration order is put order and
  reopen-stable even when shard commits complete out of order.

One process owns a store root at a time; cross-process coordination is
out of scope for this tier.
"""

from __future__ import annotations

import hashlib
import json
import os
import threading
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro.core.api import PromptCompressor

_META_NAME = "store.json"
_ITER_BATCH = 64


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


def content_key(text: str) -> str:
    """The store's content address for `text` (sha256 hex) — computable
    without compressing, which is how ingest tickets know their keys at
    submit time."""
    return _sha(text)


class _Shard:
    """One append-only segment file plus its jsonl index (a single
    generation; the store swaps in a fresh `_Shard` on compaction)."""

    def __init__(self, data_path: Path, index_path: Path) -> None:
        self.data_path = data_path
        self.index_path = index_path

    def load_index(self) -> List[dict]:
        """Read this shard's index, dropping a torn tail: a truncated json
        line, or records pointing past the end of the data file (crash
        between the data fsync and the index publish)."""
        if not self.index_path.exists():
            return []
        data_size = self.data_path.stat().st_size if self.data_path.exists() else 0
        records: List[dict] = []
        for line in self.index_path.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break
            if rec["offset"] + rec["length"] > data_size:
                break
            records.append(rec)
        return records

    def append(self, blobs: Sequence[bytes]) -> List[int]:
        """Group-commit data append: all blobs, one flush, one fsync.
        Returns the offset of each blob."""
        offsets: List[int] = []
        with open(self.data_path, "ab") as f:
            for blob in blobs:
                offsets.append(f.tell())
                f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        return offsets

    def publish(self, records: Sequence[dict]) -> None:
        """Group-commit index publish: all lines, one flush, one fsync.
        Must only run after `append`'s fsync so readers never index data
        that is not durable."""
        with open(self.index_path, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def read(self, offset: int, length: int) -> bytes:
        with open(self.data_path, "rb") as f:
            f.seek(offset)
            return f.read(length)

    def data_size(self) -> int:
        return self.data_path.stat().st_size if self.data_path.exists() else 0


class ShardedPromptStore:
    DEFAULT_SHARDS = 8

    def __init__(self, root: str | Path,
                 compressor: Optional[PromptCompressor] = None,
                 n_shards: Optional[int] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.compressor = compressor or PromptCompressor()
        self._meta_lock = threading.Lock()
        self.n_shards, self._gens = self._resolve_layout(n_shards)
        self._shard_locks = [threading.RLock() for _ in range(self.n_shards)]
        self._compact_locks = [threading.Lock() for _ in range(self.n_shards)]
        self._shards = [_Shard(*self._shard_paths(i, self._gens[i]))
                        for i in range(self.n_shards)]
        self._gc_stale_generations()
        self._index_lock = threading.RLock()
        self._index: Dict[str, dict] = {}
        self._next_seq = 0
        self._load_index()

    # -- layout ---------------------------------------------------------------

    def _resolve_layout(self, requested: Optional[int]) -> Tuple[int, List[int]]:
        """Existing layout always wins; `n_shards` only shapes new stores.
        Returns (n_shards, per-shard compaction generations)."""
        meta_path = self.root / _META_NAME
        if meta_path.exists():
            meta = json.loads(meta_path.read_text())
            n = int(meta["n_shards"])
            gens = [int(g) for g in meta.get("gens", [0] * n)]
            if len(gens) != n:
                raise ValueError(f"corrupt store meta: {len(gens)} gens for {n} shards")
            return n, gens
        if (self.root / "data.bin").exists():
            return 1, [0]  # legacy single-file store, predates store.json
        n = self.DEFAULT_SHARDS if requested is None else int(requested)
        if n < 1:
            raise ValueError("n_shards must be >= 1")
        meta_path.write_text(
            json.dumps({"version": 1, "n_shards": n, "gens": [0] * n}) + "\n")
        return n, [0] * n

    def _write_meta(self) -> None:
        """Atomic meta publish (temp file + os.replace): the commit point
        of a compaction swap.  Caller holds the shard lock of the swapped
        shard; `_meta_lock` serializes swaps of different shards."""
        with self._meta_lock:
            doc = {"version": 1, "n_shards": self.n_shards, "gens": list(self._gens)}
            tmp = self.root / (".{}.tmp".format(_META_NAME))
            with open(tmp, "w") as f:
                f.write(json.dumps(doc) + "\n")
                f.flush()
                os.fsync(f.fileno())
            os.replace(tmp, self.root / _META_NAME)

    def _shard_paths(self, i: int, gen: int) -> Tuple[Path, Path]:
        if self.n_shards == 1:
            if gen == 0:
                return self.root / "data.bin", self.root / "index.jsonl"
            return (self.root / f"data.g{gen:04d}.bin",
                    self.root / f"index.g{gen:04d}.jsonl")
        if gen == 0:
            return (self.root / f"shard-{i:03d}.bin",
                    self.root / f"shard-{i:03d}.idx.jsonl")
        return (self.root / f"shard-{i:03d}.g{gen:04d}.bin",
                self.root / f"shard-{i:03d}.g{gen:04d}.idx.jsonl")

    def _gc_stale_generations(self) -> None:
        """Drop shard files that are not the meta-committed generation:
        leftovers of a compaction that crashed either before its meta
        commit (orphaned higher gen) or after it (stale lower gen).
        Either way the committed generation is fully intact, so this is
        pure garbage collection."""
        for i in range(self.n_shards):
            current = set(self._shard_paths(i, self._gens[i]))
            if self.n_shards == 1:
                patterns = ("data.bin", "data.g*.bin",
                            "index.jsonl", "index.g*.jsonl")
            else:
                # exact stem + explicit ".g*" generation patterns: a bare
                # "shard-{i:03d}*" prefix would swallow 4-digit shard names
                # (shard-100* matches shard-1000.bin) once n_shards > 1000
                patterns = (f"shard-{i:03d}.bin", f"shard-{i:03d}.g*.bin",
                            f"shard-{i:03d}.idx.jsonl",
                            f"shard-{i:03d}.g*.idx.jsonl")
            for pat in patterns:
                for path in self.root.glob(pat):
                    if path not in current:
                        try:
                            path.unlink()
                        except OSError:  # pragma: no cover - best effort
                            pass
        tmp = self.root / (".{}.tmp".format(_META_NAME))
        if tmp.exists():
            try:
                tmp.unlink()
            except OSError:  # pragma: no cover
                pass

    def _shard_of(self, key: str) -> int:
        return int(key[:4], 16) % self.n_shards

    def _load_index(self) -> None:
        """Rebuild the in-memory index in global put order.

        Iteration order must be reopen-stable (TokenPipeline's resume
        guarantee concatenates streams in index order), so records carry a
        store-wide `seq` and the per-shard indexes are merged by it.
        Legacy single-file records predate `seq`; their file order *is*
        put order, so they sort by position."""
        records: List[dict] = []
        for shard in self._shards:
            for pos, rec in enumerate(shard.load_index()):
                rec.setdefault("seq", pos)
                records.append(rec)
        records.sort(key=lambda r: r["seq"])
        for rec in records:
            self._index[rec["key"]] = rec
        self._next_seq = records[-1]["seq"] + 1 if records else 0

    # -- bookkeeping ----------------------------------------------------------

    def __len__(self) -> int:
        with self._index_lock:
            return len(self._index)

    def __contains__(self, key: str) -> bool:
        with self._index_lock:
            return key in self._index

    def keys(self) -> List[str]:
        with self._index_lock:
            recs = sorted(self._index.values(), key=lambda r: r["seq"])
        return [r["key"] for r in recs]

    # -- writes ---------------------------------------------------------------

    def put(self, text: str, method: Optional[str] = None) -> str:
        """Compress and store; returns the content key. Idempotent."""
        return self.put_many([text], method)[0]

    def put_many(self, texts: Sequence[str], method: Optional[str] = None) -> List[str]:
        """Batch ingest with group commit.

        The whole batch is compressed in one codec-pipeline pass, then each
        shard touched by the batch commits once: data append + fsync, index
        publish + fsync.  Byte-identical to per-record `put` (same frames,
        same offsets within each shard) — only the fsync count changes.
        """
        keys, plan = self.plan_batch(texts, method)
        for shard_id in sorted(plan):
            self.commit_batch(shard_id, plan[shard_id])
        return keys

    def plan_batch(self, texts: Sequence[str], method: Optional[str] = None
                   ) -> Tuple[List[str], Dict[int, List[dict]]]:
        """Stage 1 of a group commit: dedupe against the index, compress
        the new texts in one batched pipeline pass, reserve their `seq`
        range, and group the planned entries by shard.  No file I/O — the
        heavy compression runs with no lock held, so an ingest dispatcher
        can plan the next flush while writer threads fsync the last one.

        Returns (keys for every input text, {shard_id: [entry...]}); each
        entry carries key/seq/method/n_chars/blob and commits via
        `commit_batch`.
        """
        keys = [_sha(t) for t in texts]
        # first occurrence of each not-yet-stored key, in batch order
        new_keys: List[str] = []
        new_texts: List[str] = []
        seen: set = set()
        with self._index_lock:
            for key, text in zip(keys, texts):
                if key in self._index or key in seen:
                    continue
                seen.add(key)
                new_keys.append(key)
                new_texts.append(text)
        if not new_texts:
            return keys, {}
        blobs = self.compressor.compress_batch(new_texts, method)
        with self._index_lock:
            base_seq = self._next_seq
            self._next_seq += len(new_keys)
        plan: Dict[int, List[dict]] = {}
        for i, key in enumerate(new_keys):
            plan.setdefault(self._shard_of(key), []).append({
                "key": key,
                "seq": base_seq + i,  # global put order, reopen-stable
                "method": method or self.compressor.method,
                "n_chars": len(new_texts[i]),
                "blob": blobs[i],
            })
        return keys, plan

    def commit_batch(self, shard_id: int, entries: Sequence[dict]) -> List[dict]:
        """Stage 2 of a group commit: durably append one shard's planned
        entries (data fsync, then index publish fsync) and publish them to
        the in-memory index.  Thread-safe; different shards commit in
        parallel under their own locks."""
        if not entries:
            return []
        with self._shard_locks[shard_id]:
            shard = self._shards[shard_id]
            offsets = shard.append([e["blob"] for e in entries])
            records = [
                {
                    "key": e["key"],
                    "seq": e["seq"],
                    "offset": off,
                    "length": len(e["blob"]),
                    "method": e["method"],
                    "n_chars": e["n_chars"],
                }
                for e, off in zip(entries, offsets)
            ]
            shard.publish(records)
            self._publish_index(records)
        return records

    def _publish_index(self, records: Sequence[dict]) -> None:
        """Install committed records in the in-memory index.  A racing
        duplicate keeps whichever record has the higher seq — the same
        winner `_load_index` picks on reopen."""
        with self._index_lock:
            for rec in records:
                prev = self._index.get(rec["key"])
                if prev is None or prev["seq"] <= rec["seq"]:
                    self._index[rec["key"]] = rec

    # -- reads ----------------------------------------------------------------

    def _read_blob(self, key: str) -> bytes:
        sid = self._shard_of(key)
        # record lookup and file read are atomic w.r.t. a compaction swap
        # (which retargets offsets and the backing file together)
        with self._shard_locks[sid]:
            with self._index_lock:
                rec = self._index[key]
            return self._shards[sid].read(rec["offset"], rec["length"])

    def get(self, key: str, verify: bool = True) -> str:
        text = self.compressor.decompress(self._read_blob(key))
        if verify and _sha(text) != key:
            raise ValueError(f"integrity failure for {key}: stored hash mismatch")
        return text

    def get_many(self, keys: Sequence[str], verify: bool = True) -> List[str]:
        texts = self.compressor.decompress_batch([self._read_blob(k) for k in keys])
        if verify:
            for key, text in zip(keys, texts):
                if _sha(text) != key:
                    raise ValueError(
                        f"integrity failure for {key}: stored hash mismatch")
        return texts

    def get_tokens(self, key: str) -> np.ndarray:
        """Token ids without detokenization (token-stream mode, §8.4.2 #10)."""
        return self.compressor.tokens(self._read_blob(key))

    def get_tokens_many(self, keys: Sequence[str]) -> List[np.ndarray]:
        return self.compressor.tokens_batch([self._read_blob(k) for k in keys])

    def iter_tokens(self) -> Iterator[np.ndarray]:
        keys = self.keys()
        for i in range(0, len(keys), _ITER_BATCH):
            yield from self.get_tokens_many(keys[i:i + _ITER_BATCH])

    # -- compaction hooks (used by repro.service.compaction) ------------------

    def compaction_lock(self, shard_id: int) -> threading.Lock:
        """Mutex a compactor must hold while rebuilding `shard_id` (only
        one rebuild per shard at a time; writers/readers are *not* blocked
        by it — they synchronize on the shard lock during the swap)."""
        return self._compact_locks[shard_id]

    def shard_records(self, shard_id: int) -> List[dict]:
        """Snapshot of the live records routed to `shard_id`, seq order."""
        with self._index_lock:
            recs = [dict(r) for r in self._index.values()
                    if self._shard_of(r["key"]) == shard_id]
        recs.sort(key=lambda r: r["seq"])
        return recs

    def read_records(self, shard_id: int, recs: Sequence[dict]) -> List[bytes]:
        """Read the blobs for a `shard_records` snapshot."""
        with self._shard_locks[shard_id]:
            shard = self._shards[shard_id]
            return [shard.read(r["offset"], r["length"]) for r in recs]

    def shard_stats(self, shard_id: int) -> dict:
        """Live/dead byte accounting for one shard (compaction trigger)."""
        with self._shard_locks[shard_id]:
            file_bytes = self._shards[shard_id].data_size()
            gen = self._gens[shard_id]
        with self._index_lock:
            live = [r["length"] for r in self._index.values()
                    if self._shard_of(r["key"]) == shard_id]
        live_bytes = sum(live)
        return {
            "shard_id": shard_id,
            "gen": gen,
            "n_records": len(live),
            "file_bytes": file_bytes,
            "live_bytes": live_bytes,
            "dead_bytes": max(file_bytes - live_bytes, 0),
        }

    def all_shard_stats(self) -> List[dict]:
        """`shard_stats` for every shard in ONE index pass — the
        background compactor's scan loop; per-shard calls would revisit
        the whole index n_shards times."""
        n_records = [0] * self.n_shards
        live_bytes = [0] * self.n_shards
        with self._index_lock:
            for r in self._index.values():
                sid = self._shard_of(r["key"])
                n_records[sid] += 1
                live_bytes[sid] += r["length"]
        out = []
        for i in range(self.n_shards):
            with self._shard_locks[i]:
                file_bytes = self._shards[i].data_size()
                gen = self._gens[i]
            out.append({
                "shard_id": i,
                "gen": gen,
                "n_records": n_records[i],
                "file_bytes": file_bytes,
                "live_bytes": live_bytes[i],
                "dead_bytes": max(file_bytes - live_bytes[i], 0),
            })
        return out

    def swap_shard(self, shard_id: int, entries: List[dict]) -> dict:
        """Atomically replace a shard's contents with `entries` (the
        compactor's rebuilt record set: key/seq/method/n_chars/blob).
        Caller holds `compaction_lock(shard_id)`, which is what makes the
        unlocked generation bump in phase 1 safe.

        Protocol (reuses the append-then-publish discipline):
        1. WITHOUT the shard lock — readers and writers keep going against
           the live generation — the new generation's data file is written
           + fsynced, then its index published + fsynced, at fresh
           filenames (`shard-XXX.gNNNN.*`);
        2. under the shard lock, catch up: any record committed after the
           compactor's snapshot is read from the live generation and
           appended to the rebuild (same append/publish discipline), so
           concurrent ingest is never lost;
        3. the meta file's `gens` entry is replaced atomically
           (`os.replace`) — THE commit point: a crash on either side of it
           reopens one fully intact generation, and `_gc_stale_generations`
           sweeps the loser's files on the next open;
        4. the in-memory shard object and record offsets swap in, and the
           old generation's files are unlinked.

        Returns {bytes_before, bytes_after, n_records, n_caught_up}.
        """
        def _records_for(new_entries: Sequence[dict],
                         offsets: Sequence[int]) -> List[dict]:
            return [
                {
                    "key": e["key"],
                    "seq": e["seq"],
                    "offset": off,
                    "length": len(e["blob"]),
                    "method": e["method"],
                    "n_chars": e["n_chars"],
                }
                for e, off in zip(new_entries, offsets)
            ]

        entries = sorted(entries, key=lambda e: e["seq"])
        planned_seqs = {e["seq"] for e in entries}
        # phase 1: bulk rewrite, shard stays fully live
        gen = self._gens[shard_id] + 1
        new_shard = _Shard(*self._shard_paths(shard_id, gen))
        for path in (new_shard.data_path, new_shard.index_path):
            if path.exists():  # leftover from a crashed compaction
                path.unlink()
        records = _records_for(
            entries, new_shard.append([e["blob"] for e in entries]))
        new_shard.publish(records)
        # phases 2-4: the only window readers/writers wait on
        with self._shard_locks[shard_id]:
            old_shard = self._shards[shard_id]
            bytes_before = old_shard.data_size()
            with self._index_lock:
                current = [dict(r) for r in self._index.values()
                           if self._shard_of(r["key"]) == shard_id]
            tail = sorted((r for r in current if r["seq"] not in planned_seqs),
                          key=lambda r: r["seq"])
            if tail:
                tail_entries = [
                    {
                        "key": r["key"],
                        "seq": r["seq"],
                        "method": r["method"],
                        "n_chars": r["n_chars"],
                        "blob": old_shard.read(r["offset"], r["length"]),
                    }
                    for r in tail
                ]
                records += _records_for(
                    tail_entries,
                    new_shard.append([e["blob"] for e in tail_entries]))
                new_shard.publish(records[-len(tail_entries):])
            self._gens[shard_id] = gen
            self._write_meta()  # atomic commit point
            self._shards[shard_id] = new_shard
            self._publish_index(records)
            bytes_after = new_shard.data_size()
            for path in (old_shard.data_path, old_shard.index_path):
                if path != new_shard.data_path and path != new_shard.index_path:
                    try:
                        path.unlink()
                    except OSError:  # pragma: no cover - best effort
                        pass
        return {"bytes_before": bytes_before, "bytes_after": bytes_after,
                "n_records": len(records), "n_caught_up": len(tail)}

    # -- ops ------------------------------------------------------------------

    def stats(self) -> dict:
        with self._index_lock:
            recs = list(self._index.values())
        stored = sum(r["length"] for r in recs)
        original = sum(r["n_chars"] for r in recs)
        per_shard = [0] * self.n_shards
        for r in recs:
            per_shard[self._shard_of(r["key"])] += 1
        file_bytes = 0
        for i in range(self.n_shards):
            with self._shard_locks[i]:
                file_bytes += self._shards[i].data_size()
        return {
            "n_prompts": len(recs),
            "n_shards": self.n_shards,
            "prompts_per_shard": per_shard,
            "stored_bytes": stored,
            "original_chars": original,
            "space_savings_pct": 100.0 * (1 - stored / original) if original else 0.0,
            "file_bytes": file_bytes,
            "dead_bytes": max(file_bytes - stored, 0),
            "gens": list(self._gens),
        }

    def verify_all(self) -> dict:
        """SHA-256 sweep over every record (paper §5.10 robustness check)."""
        ok = bad = 0
        for key in self.keys():
            try:
                self.get(key, verify=True)
                ok += 1
            except Exception:
                bad += 1
        return {"success": ok, "failure": bad, "total": ok + bad}


class PromptStore(ShardedPromptStore):
    """Single-shard store with the legacy flat ``data.bin``/``index.jsonl``
    layout — the paper-scale configuration, and the drop-in default.  Pass
    ``n_shards`` (or use ShardedPromptStore) for the scaled layout."""

    def __init__(self, root: str | Path,
                 compressor: Optional[PromptCompressor] = None,
                 n_shards: int = 1):
        super().__init__(root, compressor, n_shards=n_shards)
