"""PromptStore: the database-integration layer of the paper (§6.2.3),
scaled out as a sharded, batch-first segment store.

Layout (``n_shards`` segment files, shard chosen by content-key prefix):

    <root>/store.json          {"version": 1, "n_shards": N}
    <root>/shard-000.bin       concatenated frames (segment 0)
    <root>/shard-000.idx.jsonl one record per frame: key (sha256 of the
                               text), offset, length, method, n_chars
    ...

A 1-shard store uses the legacy flat names ``data.bin`` / ``index.jsonl``
so stores written by earlier versions open unchanged.

Properties the paper calls for, preserved per shard:
* application-level compression before storage (§2.4),
* searchable token ids without full decompression (§6.2.3 — `get_tokens`),
* integrity: every get() verifies the content hash (§4.6 discipline),
* durability: a shard's data append is flushed+fsynced before its index
  lines are published; a torn final record (crash between data and index
  write, or mid index line) is detected and ignored on open, and a torn
  tail in one shard never affects the others.

Batch-first writes: ``put_many`` compresses the whole batch through the
codec pipeline (one batched BPE/pack pass), groups records by shard, and
group-commits — one data fsync and one index fsync per *shard touched per
batch* instead of two fsyncs per record, which is where the put_many
throughput win comes from (benchmarks/batch_throughput.py).
"""

from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence

import numpy as np

from repro.core.api import PromptCompressor

_META_NAME = "store.json"
_ITER_BATCH = 64


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


class _Shard:
    """One append-only segment file plus its jsonl index."""

    def __init__(self, data_path: Path, index_path: Path) -> None:
        self.data_path = data_path
        self.index_path = index_path

    def load_index(self) -> List[dict]:
        """Read this shard's index, dropping a torn tail: a truncated json
        line, or records pointing past the end of the data file (crash
        between the data fsync and the index publish)."""
        if not self.index_path.exists():
            return []
        data_size = self.data_path.stat().st_size if self.data_path.exists() else 0
        records: List[dict] = []
        for line in self.index_path.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break
            if rec["offset"] + rec["length"] > data_size:
                break
            records.append(rec)
        return records

    def append(self, blobs: Sequence[bytes]) -> List[int]:
        """Group-commit data append: all blobs, one flush, one fsync.
        Returns the offset of each blob."""
        offsets: List[int] = []
        with open(self.data_path, "ab") as f:
            for blob in blobs:
                offsets.append(f.tell())
                f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        return offsets

    def publish(self, records: Sequence[dict]) -> None:
        """Group-commit index publish: all lines, one flush, one fsync.
        Must only run after `append`'s fsync so readers never index data
        that is not durable."""
        with open(self.index_path, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())

    def read(self, offset: int, length: int) -> bytes:
        with open(self.data_path, "rb") as f:
            f.seek(offset)
            return f.read(length)


class ShardedPromptStore:
    DEFAULT_SHARDS = 8

    def __init__(self, root: str | Path,
                 compressor: Optional[PromptCompressor] = None,
                 n_shards: Optional[int] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.compressor = compressor or PromptCompressor()
        self.n_shards = self._resolve_n_shards(n_shards)
        self._shards = [self._make_shard(i) for i in range(self.n_shards)]
        self._index: Dict[str, dict] = {}
        self._next_seq = 0
        self._load_index()

    # -- layout ---------------------------------------------------------------

    def _resolve_n_shards(self, requested: Optional[int]) -> int:
        """Existing layout always wins; `n_shards` only shapes new stores."""
        meta_path = self.root / _META_NAME
        if meta_path.exists():
            meta = json.loads(meta_path.read_text())
            return int(meta["n_shards"])
        if (self.root / "data.bin").exists():
            return 1  # legacy single-file store
        n = self.DEFAULT_SHARDS if requested is None else int(requested)
        if n < 1:
            raise ValueError("n_shards must be >= 1")
        meta_path.write_text(json.dumps({"version": 1, "n_shards": n}) + "\n")
        return n

    def _make_shard(self, i: int) -> _Shard:
        if self.n_shards == 1:
            return _Shard(self.root / "data.bin", self.root / "index.jsonl")
        return _Shard(self.root / f"shard-{i:03d}.bin",
                      self.root / f"shard-{i:03d}.idx.jsonl")

    def _shard_of(self, key: str) -> int:
        return int(key[:4], 16) % self.n_shards

    def _load_index(self) -> None:
        """Rebuild the in-memory index in global put order.

        Iteration order must be reopen-stable (TokenPipeline's resume
        guarantee concatenates streams in index order), so records carry a
        store-wide `seq` and the per-shard indexes are merged by it.
        Legacy single-file records predate `seq`; their file order *is*
        put order, so they sort by position."""
        records: List[dict] = []
        for shard in self._shards:
            for pos, rec in enumerate(shard.load_index()):
                rec.setdefault("seq", pos)
                records.append(rec)
        records.sort(key=lambda r: r["seq"])
        for rec in records:
            self._index[rec["key"]] = rec
        self._next_seq = records[-1]["seq"] + 1 if records else 0

    # -- bookkeeping ----------------------------------------------------------

    def __len__(self) -> int:
        return len(self._index)

    def __contains__(self, key: str) -> bool:
        return key in self._index

    def keys(self) -> List[str]:
        return list(self._index)

    # -- writes ---------------------------------------------------------------

    def put(self, text: str, method: Optional[str] = None) -> str:
        """Compress and store; returns the content key. Idempotent."""
        return self.put_many([text], method)[0]

    def put_many(self, texts: Sequence[str], method: Optional[str] = None) -> List[str]:
        """Batch ingest with group commit.

        The whole batch is compressed in one codec-pipeline pass, then each
        shard touched by the batch commits once: data append + fsync, index
        publish + fsync.  Byte-identical to per-record `put` (same frames,
        same offsets within each shard) — only the fsync count changes.
        """
        keys = [_sha(t) for t in texts]
        # first occurrence of each not-yet-stored key, in batch order
        new_keys: List[str] = []
        new_texts: List[str] = []
        seen: set = set()
        for key, text in zip(keys, texts):
            if key in self._index or key in seen:
                continue
            seen.add(key)
            new_keys.append(key)
            new_texts.append(text)
        if not new_texts:
            return keys
        blobs = self.compressor.compress_batch(new_texts, method)
        by_shard: Dict[int, List[int]] = {}
        for i, key in enumerate(new_keys):
            by_shard.setdefault(self._shard_of(key), []).append(i)
        committed: List[dict] = []
        for shard_id, members in by_shard.items():
            shard = self._shards[shard_id]
            offsets = shard.append([blobs[i] for i in members])
            records = [
                {
                    "key": new_keys[i],
                    "seq": self._next_seq + i,  # global put order, reopen-stable
                    "offset": off,
                    "length": len(blobs[i]),
                    "method": method or self.compressor.method,
                    "n_chars": len(new_texts[i]),
                }
                for i, off in zip(members, offsets)
            ]
            shard.publish(records)
            committed.extend(records)
        # publish to the in-memory index in put order, matching what a
        # reopen reconstructs from the seq field
        committed.sort(key=lambda r: r["seq"])
        for rec in committed:
            self._index[rec["key"]] = rec
        self._next_seq += len(new_keys)
        return keys

    # -- reads ----------------------------------------------------------------

    def _read_blob(self, key: str) -> bytes:
        rec = self._index[key]
        return self._shards[self._shard_of(key)].read(rec["offset"], rec["length"])

    def get(self, key: str, verify: bool = True) -> str:
        text = self.compressor.decompress(self._read_blob(key))
        if verify and _sha(text) != key:
            raise ValueError(f"integrity failure for {key}: stored hash mismatch")
        return text

    def get_many(self, keys: Sequence[str], verify: bool = True) -> List[str]:
        texts = self.compressor.decompress_batch([self._read_blob(k) for k in keys])
        if verify:
            for key, text in zip(keys, texts):
                if _sha(text) != key:
                    raise ValueError(
                        f"integrity failure for {key}: stored hash mismatch")
        return texts

    def get_tokens(self, key: str) -> np.ndarray:
        """Token ids without detokenization (token-stream mode, §8.4.2 #10)."""
        return self.compressor.tokens(self._read_blob(key))

    def get_tokens_many(self, keys: Sequence[str]) -> List[np.ndarray]:
        return self.compressor.tokens_batch([self._read_blob(k) for k in keys])

    def iter_tokens(self) -> Iterator[np.ndarray]:
        keys = self.keys()
        for i in range(0, len(keys), _ITER_BATCH):
            yield from self.get_tokens_many(keys[i:i + _ITER_BATCH])

    # -- ops ------------------------------------------------------------------

    def stats(self) -> dict:
        stored = sum(r["length"] for r in self._index.values())
        original = sum(r["n_chars"] for r in self._index.values())
        per_shard = [0] * self.n_shards
        for key in self._index:
            per_shard[self._shard_of(key)] += 1
        return {
            "n_prompts": len(self._index),
            "n_shards": self.n_shards,
            "prompts_per_shard": per_shard,
            "stored_bytes": stored,
            "original_chars": original,
            "space_savings_pct": 100.0 * (1 - stored / original) if original else 0.0,
        }

    def verify_all(self) -> dict:
        """SHA-256 sweep over every record (paper §5.10 robustness check)."""
        ok = bad = 0
        for key in self._index:
            try:
                self.get(key, verify=True)
                ok += 1
            except Exception:
                bad += 1
        return {"success": ok, "failure": bad, "total": ok + bad}


class PromptStore(ShardedPromptStore):
    """Single-shard store with the legacy flat ``data.bin``/``index.jsonl``
    layout — the paper-scale configuration, and the drop-in default.  Pass
    ``n_shards`` (or use ShardedPromptStore) for the scaled layout."""

    def __init__(self, root: str | Path,
                 compressor: Optional[PromptCompressor] = None,
                 n_shards: int = 1):
        super().__init__(root, compressor, n_shards=n_shards)
