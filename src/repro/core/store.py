"""PromptStore: the database-integration layer of the paper (§6.2.3).

An append-only, content-addressed store of LoPace frames:

    <root>/data.bin     concatenated frames
    <root>/index.jsonl  one record per frame: key (sha256 of the text),
                        offset, length, method, n_chars, tokenizer fp

Properties the paper calls for:
* application-level compression before storage (§2.4),
* searchable token ids without full decompression (§6.2.3 — `get_tokens`),
* integrity: every get() verifies the content hash (§4.6 discipline),
* durability: appends are flushed+fsynced before the index line is
  published; a torn final record is detected and ignored on open.

This is the storage substrate the training data pipeline and the serving
prompt cache are built on.
"""

from __future__ import annotations

import hashlib
import json
import os
from pathlib import Path
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.api import PromptCompressor


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


class PromptStore:
    def __init__(self, root: str | Path, compressor: Optional[PromptCompressor] = None):
        self.root = Path(root)
        self.root.mkdir(parents=True, exist_ok=True)
        self.compressor = compressor or PromptCompressor()
        self._data_path = self.root / "data.bin"
        self._index_path = self.root / "index.jsonl"
        self._index: Dict[str, dict] = {}
        self._load_index()

    # -- bookkeeping --------------------------------------------------------

    def _load_index(self) -> None:
        if not self._index_path.exists():
            return
        data_size = self._data_path.stat().st_size if self._data_path.exists() else 0
        for line in self._index_path.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break  # torn tail record from a crash; ignore the remainder
            if rec["offset"] + rec["length"] > data_size:
                break  # index ahead of data: crashed between data+index write
            self._index[rec["key"]] = rec

    def __len__(self) -> int:
        return len(self._index)

    def __contains__(self, key: str) -> bool:
        return key in self._index

    def keys(self) -> List[str]:
        return list(self._index)

    # -- writes --------------------------------------------------------------

    def put(self, text: str, method: Optional[str] = None) -> str:
        """Compress and store; returns the content key. Idempotent."""
        key = _sha(text)
        if key in self._index:
            return key
        blob = self.compressor.compress(text, method)
        with open(self._data_path, "ab") as f:
            offset = f.tell()
            f.write(blob)
            f.flush()
            os.fsync(f.fileno())
        rec = {
            "key": key,
            "offset": offset,
            "length": len(blob),
            "method": method or self.compressor.method,
            "n_chars": len(text),
        }
        with open(self._index_path, "a") as f:
            f.write(json.dumps(rec) + "\n")
            f.flush()
            os.fsync(f.fileno())
        self._index[key] = rec
        return key

    def put_many(self, texts: List[str], method: Optional[str] = None) -> List[str]:
        return [self.put(t, method) for t in texts]

    # -- reads ----------------------------------------------------------------

    def _read_blob(self, key: str) -> bytes:
        rec = self._index[key]
        with open(self._data_path, "rb") as f:
            f.seek(rec["offset"])
            return f.read(rec["length"])

    def get(self, key: str, verify: bool = True) -> str:
        text = self.compressor.decompress(self._read_blob(key))
        if verify and _sha(text) != key:
            raise ValueError(f"integrity failure for {key}: stored hash mismatch")
        return text

    def get_tokens(self, key: str) -> np.ndarray:
        """Token ids without detokenization (token-stream mode, §8.4.2 #10)."""
        return self.compressor.tokens(self._read_blob(key))

    def iter_tokens(self) -> Iterator[np.ndarray]:
        for key in self._index:
            yield self.get_tokens(key)

    # -- ops ------------------------------------------------------------------

    def stats(self) -> dict:
        stored = sum(r["length"] for r in self._index.values())
        original = sum(r["n_chars"] for r in self._index.values())
        return {
            "n_prompts": len(self._index),
            "stored_bytes": stored,
            "original_chars": original,
            "space_savings_pct": 100.0 * (1 - stored / original) if original else 0.0,
        }

    def verify_all(self) -> dict:
        """SHA-256 sweep over every record (paper §5.10 robustness check)."""
        ok = bad = 0
        for key in self._index:
            try:
                self.get(key, verify=True)
                ok += 1
            except Exception:
                bad += 1
        return {"success": ok, "failure": bad, "total": ok + bad}
