"""PromptStore: the database-integration layer of the paper (§6.2.3),
scaled out as a sharded, batch-first segment store.

Layout (``n_shards`` segment files, shard chosen by content-key prefix):

    <root>/store.json          {"version": 1, "n_shards": N, "gens": [...]}
    <root>/shard-000.bin       concatenated frames (segment 0, generation 0)
    <root>/shard-000.idx.jsonl one record per frame: key (sha256 of the
                               text), offset, length, method, n_chars
    ...

A 1-shard store uses the legacy flat names ``data.bin`` / ``index.jsonl``
so stores written by earlier versions open unchanged.  Compacted shards
live at a bumped *generation* (``shard-000.g0001.bin``); the meta file is
the atomic commit point, so a crash mid-compaction always reopens a fully
intact generation (see `swap_shard`).  A shard generation whose frames
were re-encoded with a trained dictionary carries the dictionary as a
sidecar (``shard-000.g0001.dict``) whose sha256 is recorded in
``store.json`` — the open path refuses a missing or corrupted sidecar,
and sidecars of losing generations are garbage-collected with their
``.bin``/``.idx.jsonl`` files.

The shard *count* itself can change online: ``rebalance(n_shards)``
re-partitions every key across a new layout through the same atomic
``store.json`` commit point.  Readers are served throughout; writers that
planned against the old layout re-route when they observe the swapped
``_Layout`` (see `commit_batch`).

Properties the paper calls for, preserved per shard:
* application-level compression before storage (§2.4),
* searchable token ids without full decompression (§6.2.3 — `get_tokens`),
* integrity: every get() verifies the content hash (§4.6 discipline),
* durability: a shard's data append is flushed+fsynced before its index
  lines are published; a torn final record (crash between data and index
  write, or mid index line) is detected and ignored on open, and a torn
  tail in one shard never affects the others.

Batch-first writes: ``put_many`` compresses the whole batch through the
codec pipeline (one batched BPE/pack pass), groups records by shard, and
group-commits — one data fsync and one index fsync per *shard touched per
batch* instead of two fsyncs per record, which is where the put_many
throughput win comes from (benchmarks/batch_throughput.py).

Concurrency (the contract the `repro.service` tier builds on):
* one lock per shard *slot* (stable across compaction generations)
  serializes appends, reads, and the compaction swap for that shard;
  different shards commit in parallel — the ingest queue's per-shard
  writer threads fsync concurrently;
* a store-wide index lock guards the in-memory key map and the `seq`
  counter; lock order is always shard lock -> index lock, never reversed;
* `put_many` splits into `plan_batch` (compress + reserve seqs; no I/O
  locks held during compression) and `commit_batch` (per-shard durable
  commit), so a dispatcher thread can plan while writer threads commit;
* racing planners may write the same content key twice (both blobs decode
  to the same text; the higher `seq` wins the index) — the duplicate's
  bytes become dead space that `repro.service.compaction` reclaims;
* `keys()` orders by `seq`, so iteration order is put order and
  reopen-stable even when shard commits complete out of order.

Cross-process ownership: exactly ONE process opens a root read-write at
a time, enforced by an ``fcntl.flock`` on ``<root>/store.lease``
(`repro.core.lease`) — the writer owns ingest, compaction, and
rebalancing, and its death (even SIGKILL) releases the lease so a
standby can take over.  Any number of *other* processes open the same
root with ``readonly=True``: a replica never takes the lease, never
mutates, and follows the writer through the atomic ``store.json``
commit point — ``refresh()`` re-reads the meta + shard indexes when
they change on disk, so a replica tracks compaction generation swaps,
rebalances, and new ingest without any writer↔replica channel beyond
the filesystem.  Within one process the lease is refcounted, so the
historical open-twice-in-one-process pattern still works.
"""

from __future__ import annotations

import hashlib
import json
import os
import re
import threading
import time
from pathlib import Path
from typing import Dict, Iterator, List, Optional, Sequence, Tuple

import numpy as np

from repro import obs
from repro.core import failpoints
from repro.core.api import PromptCompressor, parse_frame
from repro.core.durability import fsync_dir, fsync_file, write_durable
from repro.core.lease import acquire_store_lease
from repro.core.locks import make_lock, make_rlock

_META_NAME = "store.json"
_ITER_BATCH = 64

# Filenames this store has ever written, in their canonical spellings:
# shard ids are {i:03d} (3+ digits, no excess zero-padding), generations
# {g:04d}.  GC must recognize every one of these — including files of a
# *different* shard count left by a crashed rebalance — while never
# touching foreign files whose names merely look similar.
_OWNED_FILE_RE = re.compile(
    r"^(?:shard-(?P<sid>\d{3,})(?:\.g(?P<sgen>\d{4,}))?(?P<sext>\.bin|\.idx\.jsonl|\.dict)"
    r"|data(?:\.g(?P<dgen>\d{4,}))?(?P<dext>\.bin|\.dict)"
    r"|index(?:\.g(?P<igen>\d{4,}))?\.jsonl)$")


def _canonical_owned(name: str) -> bool:
    """True iff `name` is a file this store's naming scheme could have
    produced.  `shard-0001.bin` is NOT ours (we write shard 1 as `001`),
    so a GC sweep can never swallow a foreign file with a wider id."""
    m = _OWNED_FILE_RE.match(name)
    if not m:
        return False
    sid = m.group("sid")
    if sid is not None and f"{int(sid):03d}" != sid:
        return False
    for gen in (m.group("sgen"), m.group("dgen"), m.group("igen")):
        if gen is not None and f"{int(gen):04d}" != gen:
            return False
    return True


def _sha(text: str) -> str:
    return hashlib.sha256(text.encode("utf-8")).hexdigest()


def _index_records(entries: Sequence[dict], offsets: Sequence[int]) -> List[dict]:
    """Index records for planned entries landed at `offsets` — the single
    definition of the record schema every commit path publishes."""
    return [
        {
            "key": e["key"],
            "seq": e["seq"],
            "offset": off,
            "length": len(e["blob"]),
            "method": e["method"],
            "n_chars": e["n_chars"],
        }
        for e, off in zip(entries, offsets)
    ]


def content_key(text: str) -> str:
    """The store's content address for `text` (sha256 hex) — computable
    without compressing, which is how ingest tickets know their keys at
    submit time."""
    return _sha(text)


class ShardQuarantined(RuntimeError):
    """Degraded-read refusal: the requested key failed the scrubber's
    integrity sweep and its shard is quarantined.  Every *healthy* key —
    in this shard and every other — keeps serving; only the provably
    corrupt records refuse, each raise naming the full casualty list so
    operators can repair or resync (``repro.service.scrub``) instead of
    discovering losses one read at a time."""

    def __init__(self, shard_id: int, key: str, reason: str,
                 bad_keys: Sequence[str]):
        self.shard_id = shard_id
        self.key = key
        self.reason = reason
        self.bad_keys = tuple(sorted(bad_keys))
        super().__init__(
            f"key {key} is quarantined in shard {shard_id} "
            f"({reason or 'integrity failure'}); {len(self.bad_keys)} "
            f"key(s) affected — healthy shards still serve; run repair "
            f"or resync from a replica root")


class _Shard:
    """One append-only segment file plus its jsonl index (a single
    generation; the store swaps in a fresh `_Shard` on compaction)."""

    def __init__(self, data_path: Path, index_path: Path) -> None:
        self.data_path = data_path
        self.index_path = index_path

    def load_index(self) -> List[dict]:
        """Read this shard's index, dropping a torn tail: a truncated json
        line, or records pointing past the end of the data file (crash
        between the data fsync and the index publish)."""
        if not self.index_path.exists():
            return []
        data_size = self.data_path.stat().st_size if self.data_path.exists() else 0
        records: List[dict] = []
        for line in self.index_path.read_text().splitlines():
            if not line.strip():
                continue
            try:
                rec = json.loads(line)
            except json.JSONDecodeError:
                break
            if rec["offset"] + rec["length"] > data_size:
                break
            records.append(rec)
        return records

    def append(self, blobs: Sequence[bytes]) -> List[int]:
        """Group-commit data append: all blobs, one flush, one fsync.
        Returns the offset of each blob."""
        offsets: List[int] = []
        with open(self.data_path, "ab") as f:
            for blob in blobs:
                offsets.append(f.tell())
                f.write(blob)
            fsync_file(f)
        return offsets

    def publish(self, records: Sequence[dict]) -> None:
        """Group-commit index publish: all lines, one flush, one fsync.
        Must only run after `append`'s fsync so readers never index data
        that is not durable."""
        with open(self.index_path, "a") as f:
            for rec in records:
                f.write(json.dumps(rec) + "\n")
            fsync_file(f)

    def read(self, offset: int, length: int) -> bytes:
        with open(self.data_path, "rb") as f:
            f.seek(offset)
            return f.read(length)

    def data_size(self) -> int:
        # tolerant of the file vanishing between exists() and stat(): a
        # rebalance unlinks a superseded layout's files while stats
        # threads may still hold the old _Layout
        try:
            return self.data_path.stat().st_size
        except OSError:
            return 0


class _Layout:
    """One shard-count configuration of the store: the live `_Shard`
    objects, their locks, and per-shard compaction generations plus dict
    sidecar hashes.  `rebalance` builds a complete replacement and swaps
    it in with a single attribute assignment; readers/writers capture
    ``store._layout`` once, and revalidate identity after acquiring a
    shard lock (a mismatch means a rebalance won the race — re-route)."""

    __slots__ = ("n_shards", "shards", "shard_locks", "compact_locks",
                 "gens", "dict_shas")

    def __init__(self, n_shards: int, shards: List[_Shard],
                 gens: List[int], dict_shas: List[Optional[str]]) -> None:
        self.n_shards = n_shards
        self.shards = shards
        self.gens = gens
        self.dict_shas = dict_shas
        self.shard_locks = [make_rlock("shard") for _ in range(n_shards)]
        self.compact_locks = [make_lock("compact") for _ in range(n_shards)]


class ShardedPromptStore:
    DEFAULT_SHARDS = 8

    def __init__(self, root: str | Path,
                 compressor: Optional[PromptCompressor] = None,
                 n_shards: Optional[int] = None, *,
                 readonly: bool = False,
                 lease: Optional[str] = "try"):
        """Open (or create) the store at ``root``.

        ``readonly=True`` opens a read-replica: no lease, no mutation, no
        GC — the process follows the owning writer's ``store.json`` via
        `refresh`.  A writable open takes the cross-process writer lease:
        ``lease="try"`` (default) raises `StoreLeaseHeld` when another
        process owns the root, ``lease="wait"`` blocks until it is free
        (a standby's takeover path), ``lease=None`` skips the lease
        entirely (single-process embedders that manage their own
        exclusion)."""
        self.root = Path(root)
        self._readonly = bool(readonly)
        self._lease = None
        if self._readonly:
            if not ((self.root / _META_NAME).exists()
                    or (self.root / "data.bin").exists()):
                raise ValueError(
                    f"no store at {self.root}: a read-only replica cannot "
                    "create one — start the writer first")
        else:
            self.root.mkdir(parents=True, exist_ok=True)
            if lease is not None and lease != "none":
                self._lease = acquire_store_lease(self.root, mode=lease)
        try:
            self.compressor = compressor or PromptCompressor()
            self._meta_lock = make_lock("meta")
            self._rebalance_lock = make_lock("rebalance")
            # files a committed rebalance still owes an unlink for (crash
            # between its meta commit and its cleanup): carried in store.json
            # as "sweep" so a reopen can finish the job — by-name intent
            # beats guessing whether an old gen-0 file is ours or a backup
            self._pending_sweep: List[str] = []
            # scrubber-declared casualties (guarded by `_index_lock`):
            # key -> shard id it was quarantined in, and shard id ->
            # reason.  In-memory only: a reopen re-verifies from scratch
            # rather than trusting a stale casualty list.
            self._bad_keys: Dict[str, int] = {}
            self._quar_shards: Dict[int, str] = {}
            n, gens, dict_shas = self._resolve_layout(n_shards)
            shards = [_Shard(*self._shard_paths(i, gens[i], n))
                      for i in range(n)]
            self._layout = _Layout(n, shards, gens, dict_shas)
            self._load_dict_sidecars()
            if not self._readonly:
                self._gc_stale_files()
            self._index_lock = make_rlock("index")
            self._index: Dict[str, dict] = {}
            self._next_seq = 0
            self._load_index()
            self._disk_sig = self._read_disk_sig() if self._readonly else None
        except BaseException:
            self.close()
            raise

    @property
    def n_shards(self) -> int:
        return self._layout.n_shards

    @property
    def readonly(self) -> bool:
        return self._readonly

    @property
    def meta_generation(self) -> int:
        """Monotonic meta-commit counter (bumps on every ``store.json``
        publish).  Replica staleness = writer gen − replica gen."""
        return self._meta_gen

    def close(self) -> None:
        """Release the writer lease (if held).  Reads/writes through a
        closed store still work in-process; only the cross-process claim
        is dropped, so close exactly when another process may take over."""
        lease, self._lease = getattr(self, "_lease", None), None
        if lease is not None:
            lease.release()

    def __enter__(self) -> "ShardedPromptStore":
        return self

    def __exit__(self, *exc) -> None:
        self.close()

    def __del__(self):  # pragma: no cover - interpreter-shutdown timing
        try:
            self.close()
        except Exception:
            pass

    def _assert_writable(self, op: str) -> None:
        if self._readonly:
            raise RuntimeError(
                f"{op} on a read-only replica: this process follows the "
                "writer's store.json and must not mutate the root; open "
                "without readonly=True (winning the store.lease) to write")

    # -- layout ---------------------------------------------------------------

    def _resolve_layout(
            self, requested: Optional[int]
    ) -> Tuple[int, List[int], List[Optional[str]]]:
        """Existing layout always wins; `n_shards` only shapes new stores.
        Returns (n_shards, per-shard compaction generations, per-shard
        dict sidecar sha256s)."""
        meta_path = self.root / _META_NAME
        if meta_path.exists():
            meta = json.loads(meta_path.read_text())
            n = int(meta["n_shards"])
            gens = [int(g) for g in meta.get("gens", [0] * n)]
            if len(gens) != n:
                raise ValueError(f"corrupt store meta: {len(gens)} gens for {n} shards")
            dicts = list(meta.get("dicts", [None] * n))
            if len(dicts) != n:
                raise ValueError(f"corrupt store meta: {len(dicts)} dicts for {n} shards")
            self._pending_sweep = [str(s) for s in meta.get("sweep", [])]
            # pre-meta_gen stores read as generation 0; every commit bumps
            self._meta_gen = int(meta.get("meta_gen", 0))
            return n, gens, dicts
        if (self.root / "data.bin").exists():
            self._meta_gen = 0
            return 1, [0], [None]  # legacy single-file store, predates store.json
        if self._readonly:  # raced the writer's first meta publish
            raise ValueError(
                f"no store at {self.root}: a read-only replica cannot "
                "create one — start the writer first")
        n = self.DEFAULT_SHARDS if requested is None else int(requested)
        if n < 1:
            raise ValueError("n_shards must be >= 1")
        self._meta_gen = 1
        doc = {"version": 1, "n_shards": n, "gens": [0] * n,
               "meta_gen": self._meta_gen}
        tmp = self.root / (".{}.tmp".format(_META_NAME))
        write_durable(tmp, (json.dumps(doc) + "\n").encode())
        failpoints.fire("store.replace")
        os.replace(tmp, meta_path)
        fsync_dir(self.root)
        return n, [0] * n, [None] * n

    def _write_meta(self) -> None:
        """Atomic meta publish (temp file + os.replace): the commit point
        of a compaction swap or a rebalance.  Caller holds the shard
        lock(s) of the swapped shard(s); `_meta_lock` serializes swaps of
        different shards."""
        # repro-analysis: disable=REPRO001 the meta lock exists to serialize exactly this publish; only swap/rebalance commit points take it, readers never do
        with self._meta_lock:
            lay = self._layout
            # monotonic commit counter: bumped on every meta publish, so
            # replica staleness is measurable as writer_gen - replica_gen
            self._meta_gen += 1
            doc = {"version": 1, "n_shards": lay.n_shards,
                   "gens": list(lay.gens), "meta_gen": self._meta_gen}
            if any(lay.dict_shas):
                doc["dicts"] = list(lay.dict_shas)
            if self._pending_sweep:
                doc["sweep"] = list(self._pending_sweep)
            tmp = self.root / (".{}.tmp".format(_META_NAME))
            with open(tmp, "w") as f:
                f.write(json.dumps(doc) + "\n")
                fsync_file(f)
            failpoints.fire("store.replace")
            os.replace(tmp, self.root / _META_NAME)
            # directory fsync persists the rename AND the same-dir create
            # of any new-generation shard files this commit points at
            fsync_dir(self.root)
            obs.gauge("store.meta_gen").set(float(self._meta_gen))

    def _shard_paths(self, i: int, gen: int,
                     n_shards: Optional[int] = None) -> Tuple[Path, Path]:
        n = self._layout.n_shards if n_shards is None else n_shards
        if n == 1:
            if gen == 0:
                return self.root / "data.bin", self.root / "index.jsonl"
            return (self.root / f"data.g{gen:04d}.bin",
                    self.root / f"index.g{gen:04d}.jsonl")
        if gen == 0:
            return (self.root / f"shard-{i:03d}.bin",
                    self.root / f"shard-{i:03d}.idx.jsonl")
        return (self.root / f"shard-{i:03d}.g{gen:04d}.bin",
                self.root / f"shard-{i:03d}.g{gen:04d}.idx.jsonl")

    def _dict_path(self, i: int, gen: int,
                   n_shards: Optional[int] = None) -> Path:
        """The dictionary sidecar of shard `i` at generation `gen`."""
        n = self._layout.n_shards if n_shards is None else n_shards
        if n == 1:
            return self.root / ("data.dict" if gen == 0
                                else f"data.g{gen:04d}.dict")
        return self.root / (f"shard-{i:03d}.dict" if gen == 0
                            else f"shard-{i:03d}.g{gen:04d}.dict")

    def _load_dict_sidecars(self, lay: Optional[_Layout] = None) -> None:
        """Verify and register every meta-referenced dictionary sidecar.
        A missing or bit-flipped sidecar makes its shard's dict frames
        undecodable, so the open path fails loudly instead of deferring
        the error to some later get()."""
        lay = self._layout if lay is None else lay
        for i, sha in enumerate(lay.dict_shas):
            if not sha:
                continue
            path = self._dict_path(i, lay.gens[i], lay.n_shards)
            if not path.exists():
                raise ValueError(
                    f"corrupt store: dict sidecar {path.name} referenced by "
                    "store.json is missing")
            blob = path.read_bytes()
            if hashlib.sha256(blob).hexdigest() != sha:
                raise ValueError(
                    f"corrupt store: dict sidecar {path.name} sha256 mismatch")
            self.compressor.register_dictionary(blob)

    def _gc_stale_files(self) -> None:
        """Drop store-owned files that are not part of the meta-committed
        layout: leftovers of a compaction or rebalance that crashed either
        before its meta commit (orphaned higher generation / different
        shard count) or after it (stale lower generation).  Either way the
        committed layout is fully intact, so this is pure garbage
        collection.

        Scope is deliberately conservative: generation-suffixed names
        (``.gNNNN``) are only ever written by our swap/rebalance and are
        always collectible; bare gen-0 names are swept only when they
        belong to the CURRENT layout's naming family (a stale
        ``shard-001.bin`` under a compacted 2-shard store), because a
        gen-0 file of a *different* family — say a legacy ``data.bin``
        sitting in a multi-shard root — may be a foreign backup, not ours
        to delete.  Non-canonical spellings (``shard-0001.bin``) are
        never touched — see `_canonical_owned`.  The one case naming
        cannot decide — gen-0 files of shards a committed rebalance
        dropped — is covered by the meta's explicit ``sweep`` list, which
        names the old layout's files until the cleanup is finished."""
        lay = self._layout
        keep = set()
        for i in range(lay.n_shards):
            data, idx = self._shard_paths(i, lay.gens[i], lay.n_shards)
            keep.update((data.name, idx.name))
            if lay.dict_shas[i]:
                keep.add(self._dict_path(i, lay.gens[i], lay.n_shards).name)
        if self._pending_sweep:
            # finish a crashed rebalance's cleanup: these names are
            # declared ours by the committed meta, no guessing needed
            # (they can never name current-layout files — generations only
            # grow — but keep is honored as belt and braces)
            for name in self._pending_sweep:
                if name in keep:  # pragma: no cover - defensive only
                    continue
                try:
                    (self.root / name).unlink()
                except OSError:
                    pass
            self._pending_sweep = []
            self._write_meta()
        for path in self.root.iterdir():
            name = path.name
            if name in keep or not _canonical_owned(name):
                continue
            m = _OWNED_FILE_RE.match(name)
            has_gen = any(m.group(g) is not None
                          for g in ("sgen", "dgen", "igen"))
            if not has_gen:
                sid = m.group("sid")
                current_family = (sid is not None and lay.n_shards > 1
                                  and int(sid) < lay.n_shards) or (
                                      sid is None and lay.n_shards == 1)
                if not current_family:
                    continue  # gen-0 file of a foreign family: not ours
            try:
                path.unlink()
            except OSError:  # pragma: no cover - best effort
                pass
        tmp = self.root / (".{}.tmp".format(_META_NAME))
        if tmp.exists():
            try:
                tmp.unlink()
            except OSError:  # pragma: no cover
                pass

    def _shard_of(self, key: str, n_shards: Optional[int] = None) -> int:
        n = self._layout.n_shards if n_shards is None else n_shards
        return int(key[:4], 16) % n

    def _load_index(self) -> None:
        """Rebuild the in-memory index in global put order.

        Iteration order must be reopen-stable (TokenPipeline's resume
        guarantee concatenates streams in index order), so records carry a
        store-wide `seq` and the per-shard indexes are merged by it.
        Legacy single-file records predate `seq`; their file order *is*
        put order, so they sort by position."""
        records: List[dict] = []
        for shard in self._layout.shards:
            for pos, rec in enumerate(shard.load_index()):
                rec.setdefault("seq", pos)
                records.append(rec)
        records.sort(key=lambda r: r["seq"])
        for rec in records:
            self._index[rec["key"]] = rec
        self._next_seq = records[-1]["seq"] + 1 if records else 0

    # -- read-replica generation follow ---------------------------------------

    def _read_disk_sig(self) -> Optional[tuple]:
        """Cheap change fingerprint of the on-disk store: the meta file's
        identity (``os.replace`` gives every publish a fresh inode) plus
        each live shard index's size (plain ingest appends lines without
        touching the meta).  Compared, never parsed — any mismatch just
        triggers a full reload."""
        try:
            st = (self.root / _META_NAME).stat()
            sig = [(st.st_ino, st.st_mtime_ns, st.st_size)]
        except OSError:
            sig = [None]  # legacy single-file store has no meta
        lay = self._layout
        for i in range(lay.n_shards):
            try:
                sig.append(lay.shards[i].index_path.stat().st_size)
            except OSError:
                sig.append(None)
        return tuple(sig)

    def refresh(self, force: bool = False) -> bool:
        """Re-read ``store.json`` + shard indexes if they changed on disk
        (or unconditionally with ``force=True``), swapping in a fresh
        `_Layout` and index — how a read-only replica follows the
        writer's ingest, compaction generation swaps, and rebalances.
        Returns True when a reload happened.  Writer stores refuse: their
        in-memory state IS the authority the disk reflects."""
        if not self._readonly:
            raise RuntimeError(
                "refresh() is for read-only replicas; a writer's in-memory "
                "state is authoritative and never reloads from disk")
        with self._rebalance_lock:
            sig = self._read_disk_sig()
            if not force and sig == self._disk_sig:
                return False
            # a compaction/rebalance may swap files mid-reload; each retry
            # re-reads the meta so the last attempt sees a settled layout
            for attempt in range(3):
                try:
                    self._reload_locked()
                    break
                except (OSError, ValueError, KeyError, json.JSONDecodeError):
                    if attempt == 2:
                        raise
                    time.sleep(0.02)
            # the pre-reload signature: if the writer published again
            # mid-reload we re-detect the change next poll (conservative)
            self._disk_sig = sig
            obs.counter("store.replica.refresh").inc()
            obs.gauge("store.meta_gen").set(float(self._meta_gen))
            return True

    def _reload_locked(self) -> None:
        """One reload attempt (caller holds `_rebalance_lock`): read meta,
        build + verify the new layout fully off to the side, then install
        it under the index lock in one swap so readers never observe a
        half-loaded replica."""
        n, gens, dict_shas = self._resolve_layout(None)
        shards = [_Shard(*self._shard_paths(i, gens[i], n)) for i in range(n)]
        new_lay = _Layout(n, shards, gens, dict_shas)
        # dictionaries register before the swap: no reader may see a
        # dict-compressed frame whose dictionary is not yet resolvable
        self._load_dict_sidecars(new_lay)
        records: List[dict] = []
        for shard in shards:
            for pos, rec in enumerate(shard.load_index()):
                rec.setdefault("seq", pos)
                records.append(rec)
        records.sort(key=lambda r: r["seq"])
        index = {rec["key"]: rec for rec in records}
        with self._index_lock:
            self._layout = new_lay
            self._index = index
            self._next_seq = records[-1]["seq"] + 1 if records else 0

    # -- bookkeeping ----------------------------------------------------------

    def __len__(self) -> int:
        with self._index_lock:
            return len(self._index)

    def __contains__(self, key: str) -> bool:
        with self._index_lock:
            return key in self._index

    def keys(self) -> List[str]:
        with self._index_lock:
            recs = sorted(self._index.values(), key=lambda r: r["seq"])
        return [r["key"] for r in recs]

    # -- writes ---------------------------------------------------------------

    def put(self, text: str, method: Optional[str] = None) -> str:
        """Compress and store; returns the content key. Idempotent."""
        return self.put_many([text], method)[0]

    def put_many(self, texts: Sequence[str], method: Optional[str] = None) -> List[str]:
        """Batch ingest with group commit.

        The whole batch is compressed in one codec-pipeline pass, then each
        shard touched by the batch commits once: data append + fsync, index
        publish + fsync.  Byte-identical to per-record `put` (same frames,
        same offsets within each shard) — only the fsync count changes.
        """
        keys, plan = self.plan_batch(texts, method)
        for shard_id in sorted(plan):
            self.commit_batch(shard_id, plan[shard_id])
        return keys

    def plan_batch(self, texts: Sequence[str], method: Optional[str] = None
                   ) -> Tuple[List[str], Dict[int, List[dict]]]:
        """Stage 1 of a group commit: dedupe against the index, compress
        the new texts in one batched pipeline pass (the byte stage fans
        records out over the shared codec thread pool, so the plan takes
        the slowest record's time, not the sum), reserve their `seq`
        range, and group the planned entries by shard.  No file I/O — the
        heavy compression runs with no lock held, so an ingest dispatcher
        can plan the next flush while writer threads fsync the last one.

        Returns (keys for every input text, {shard_id: [entry...]}); each
        entry carries key/seq/method/n_chars/blob and commits via
        `commit_batch`.
        """
        self._assert_writable("plan_batch/put")
        with obs.span("store.plan"):
            keys = [_sha(t) for t in texts]
            # first occurrence of each not-yet-stored key, in batch order
            new_keys: List[str] = []
            new_texts: List[str] = []
            seen: set = set()
            with self._index_lock:
                for key, text in zip(keys, texts):
                    if key in self._index or key in seen:
                        continue
                    seen.add(key)
                    new_keys.append(key)
                    new_texts.append(text)
            obs.histogram("store.plan.records").observe(len(new_texts))
            if not new_texts:
                return keys, {}
            blobs = self.compressor.compress_batch(new_texts, method)
            with self._index_lock:
                base_seq = self._next_seq
                self._next_seq += len(new_keys)
            plan: Dict[int, List[dict]] = {}
            for i, key in enumerate(new_keys):
                plan.setdefault(self._shard_of(key), []).append({
                    "key": key,
                    "seq": base_seq + i,  # global put order, reopen-stable
                    "method": method or self.compressor.method,
                    "n_chars": len(new_texts[i]),
                    "blob": blobs[i],
                })
            return keys, plan

    def commit_batch(self, shard_id: int, entries: Sequence[dict]) -> List[dict]:
        """Stage 2 of a group commit: durably append one shard's planned
        entries (data fsync, then index publish fsync) and publish them to
        the in-memory index.  Thread-safe; different shards commit in
        parallel under their own locks.

        `shard_id` is the routing the *planner* computed; if a rebalance
        swapped the layout in between (or mid-wait on the old layout's
        lock), the entries are re-grouped under the current routing and
        committed there — a planned write is never lost and never lands
        in a shard its key no longer routes to."""
        self._assert_writable("commit_batch")
        out: List[dict] = []
        obs.histogram("store.commit.records").observe(len(entries))
        pending: List[Tuple[int, List[dict]]] = [(shard_id, list(entries))]
        with obs.span("store.commit"):
            while pending:
                sid, group = pending.pop()
                if not group:
                    continue
                lay = self._layout
                if sid >= lay.n_shards or any(
                        self._shard_of(e["key"], lay.n_shards) != sid
                        for e in group):
                    regroup: Dict[int, List[dict]] = {}
                    for e in group:
                        regroup.setdefault(
                            self._shard_of(e["key"], lay.n_shards), []).append(e)
                    pending.extend(regroup.items())
                    continue
                with lay.shard_locks[sid]:
                    if self._layout is not lay:
                        pending.append((sid, group))  # raced a rebalance: retry
                        continue
                    shard = lay.shards[sid]
                    records = _index_records(
                        group, shard.append([e["blob"] for e in group]))
                    shard.publish(records)
                    self._publish_index(records)
                    out.extend(records)
        return out

    def _publish_index(self, records: Sequence[dict]) -> None:
        """Install committed records in the in-memory index.  A racing
        duplicate keeps whichever record has the higher seq — the same
        winner `_load_index` picks on reopen."""
        with self._index_lock:
            for rec in records:
                prev = self._index.get(rec["key"])
                if prev is None or prev["seq"] <= rec["seq"]:
                    self._index[rec["key"]] = rec

    # -- reads ----------------------------------------------------------------

    def _read_blob(self, key: str) -> bytes:
        # record lookup and file read are atomic w.r.t. a compaction swap
        # (which retargets offsets and the backing file together) and a
        # rebalance (whose layout swap invalidates the captured _Layout —
        # retry re-routes against the new shard count).  On a read-only
        # replica a missing key or a vanished generation file may just
        # mean the writer moved on since the last poll: reload from disk
        # and retry (bounded), outside the shard lock — `refresh` takes
        # the rebalance-ranked lock, which must precede shard locks.
        self._check_quarantine(key)
        refreshes = 0
        while True:
            lay = self._layout
            sid = self._shard_of(key, lay.n_shards)
            with lay.shard_locks[sid]:
                if self._layout is not lay:
                    continue
                with self._index_lock:
                    rec = self._index.get(key)
                if rec is None:
                    if not self._readonly:
                        raise KeyError(key)
                else:
                    try:
                        return lay.shards[sid].read(
                            rec["offset"], rec["length"])
                    except OSError:
                        if not self._readonly:
                            raise
            if refreshes >= 3:
                raise KeyError(key)
            refreshes += 1
            self.refresh(force=True)

    def get(self, key: str, verify: bool = True) -> str:
        text = self.compressor.decompress(self._read_blob(key))
        if verify and _sha(text) != key:
            raise ValueError(f"integrity failure for {key}: stored hash mismatch")
        return text

    def get_many(self, keys: Sequence[str], verify: bool = True) -> List[str]:
        texts = self.compressor.decompress_batch([self._read_blob(k) for k in keys])
        if verify:
            for key, text in zip(keys, texts):
                if _sha(text) != key:
                    raise ValueError(
                        f"integrity failure for {key}: stored hash mismatch")
        return texts

    def get_tokens(self, key: str) -> np.ndarray:
        """Token ids without detokenization (token-stream mode, §8.4.2 #10)."""
        return self.compressor.tokens(self._read_blob(key))

    def get_tokens_many(self, keys: Sequence[str]) -> List[np.ndarray]:
        with obs.span("store.get_tokens"):
            return self.compressor.tokens_batch(
                [self._read_blob(k) for k in keys])

    def iter_tokens(self) -> Iterator[np.ndarray]:
        keys = self.keys()
        for i in range(0, len(keys), _ITER_BATCH):
            yield from self.get_tokens_many(keys[i:i + _ITER_BATCH])

    # -- quarantine (used by repro.service.scrub) ------------------------------

    def _check_quarantine(self, key: str) -> None:
        with self._index_lock:
            sid = self._bad_keys.get(key)
            if sid is None:
                return
            reason = self._quar_shards.get(sid, "integrity failure")
            casualties = [k for k, s in self._bad_keys.items() if s == sid]
        obs.counter("store.degraded_read").inc()
        raise ShardQuarantined(sid, key, reason, casualties)

    def quarantine_shard(self, shard_id: int, bad_keys: Sequence[str],
                         reason: str = "") -> None:
        """Declare `bad_keys` in `shard_id` corrupt: reads of those keys
        raise :class:`ShardQuarantined` (every other key keeps serving —
        the degraded-read contract) and the compactor skips the shard so
        the corrupt generation survives as forensics until repair.
        Idempotent; repeated calls merge casualty lists."""
        with self._index_lock:
            for key in bad_keys:
                self._bad_keys[key] = shard_id
            if reason or shard_id not in self._quar_shards:
                self._quar_shards[shard_id] = reason or "integrity failure"
            n = len(self._quar_shards)
        obs.counter("store.quarantine").inc()
        obs.gauge("store.quarantined_shards").set(float(n))

    def clear_quarantine(self, shard_id: int) -> List[str]:
        """Lift `shard_id`'s quarantine (repair committed a rebuilt
        generation).  Returns the keys that were held."""
        with self._index_lock:
            held = [k for k, s in self._bad_keys.items() if s == shard_id]
            for k in held:
                del self._bad_keys[k]
            self._quar_shards.pop(shard_id, None)
            n = len(self._quar_shards)
        obs.gauge("store.quarantined_shards").set(float(n))
        return held

    def is_quarantined(self, shard_id: int) -> bool:
        with self._index_lock:
            return shard_id in self._quar_shards

    def quarantined(self) -> Dict[int, dict]:
        """{shard_id: {"reason", "bad_keys"}} snapshot for stats/repair."""
        with self._index_lock:
            out: Dict[int, dict] = {
                sid: {"reason": reason, "bad_keys": []}
                for sid, reason in self._quar_shards.items()}
            for key, sid in self._bad_keys.items():
                out[sid]["bad_keys"].append(key)
        for doc in out.values():
            doc["bad_keys"].sort()
        return out

    def drop_keys(self, keys: Sequence[str]) -> int:
        """Remove `keys` from the in-memory index (repair's last resort
        for unrecoverable records: the loss becomes an honest KeyError
        instead of a quarantine held forever).  The on-disk index drops
        them at the repair's `swap_shard` commit."""
        self._assert_writable("drop_keys")
        dropped = 0
        with self._index_lock:
            for key in keys:
                if self._index.pop(key, None) is not None:
                    dropped += 1
        return dropped

    # -- compaction hooks (used by repro.service.compaction) ------------------

    def compaction_lock(self, shard_id: int) -> threading.Lock:
        """Mutex a compactor must hold while rebuilding `shard_id` (only
        one rebuild per shard at a time; writers/readers are *not* blocked
        by it — they synchronize on the shard lock during the swap).
        After acquiring, the caller must confirm the lock is still the
        current layout's (`store.compaction_lock(i) is lock`) — a
        rebalance replaces the lock table."""
        return self._layout.compact_locks[shard_id]

    def shard_records(self, shard_id: int) -> List[dict]:
        """Snapshot of the live records routed to `shard_id`, seq order."""
        lay = self._layout
        with self._index_lock:
            recs = [dict(r) for r in self._index.values()
                    if self._shard_of(r["key"], lay.n_shards) == shard_id]
        recs.sort(key=lambda r: r["seq"])
        return recs

    def read_records(self, shard_id: int, recs: Sequence[dict]) -> List[bytes]:
        """Read the blobs for a `shard_records` snapshot."""
        lay = self._layout
        with lay.shard_locks[shard_id]:
            shard = lay.shards[shard_id]
            return [shard.read(r["offset"], r["length"]) for r in recs]

    def shard_stats(self, shard_id: int) -> dict:
        """Live/dead byte accounting for one shard (compaction trigger)."""
        lay = self._layout
        with lay.shard_locks[shard_id]:
            file_bytes = lay.shards[shard_id].data_size()
            gen = lay.gens[shard_id]
        with self._index_lock:
            live = [r["length"] for r in self._index.values()
                    if self._shard_of(r["key"], lay.n_shards) == shard_id]
        live_bytes = sum(live)
        return {
            "shard_id": shard_id,
            "gen": gen,
            "n_records": len(live),
            "file_bytes": file_bytes,
            "live_bytes": live_bytes,
            "dead_bytes": max(file_bytes - live_bytes, 0),
        }

    def all_shard_stats(self) -> List[dict]:
        """`shard_stats` for every shard in ONE index pass — the
        background compactor's scan loop; per-shard calls would revisit
        the whole index n_shards times."""
        lay = self._layout
        n_records = [0] * lay.n_shards
        live_bytes = [0] * lay.n_shards
        with self._index_lock:
            for r in self._index.values():
                sid = self._shard_of(r["key"], lay.n_shards)
                n_records[sid] += 1
                live_bytes[sid] += r["length"]
        out = []
        for i in range(lay.n_shards):
            with lay.shard_locks[i]:
                file_bytes = lay.shards[i].data_size()
                gen = lay.gens[i]
            out.append({
                "shard_id": i,
                "gen": gen,
                "n_records": n_records[i],
                "file_bytes": file_bytes,
                "live_bytes": live_bytes[i],
                "dead_bytes": max(file_bytes - live_bytes[i], 0),
            })
        return out

    def swap_shard(self, shard_id: int, entries: List[dict],
                   dictionary: Optional[bytes] = None) -> dict:
        """Atomically replace a shard's contents with `entries` (the
        compactor's rebuilt record set: key/seq/method/n_chars/blob).
        Caller holds `compaction_lock(shard_id)`, which is what makes the
        unlocked generation bump in phase 1 safe (and excludes a
        concurrent rebalance, which takes every compaction lock).

        Protocol (reuses the append-then-publish discipline):
        1. WITHOUT the shard lock — readers and writers keep going against
           the live generation — the new generation's data file is written
           + fsynced, then its index published + fsynced, at fresh
           filenames (`shard-XXX.gNNNN.*`); if the rebuild was re-encoded
           against a trained `dictionary`, its sidecar
           (`shard-XXX.gNNNN.dict`) is written + fsynced alongside and
           registered with the compressor before any reader can see a
           frame that needs it;
        2. under the shard lock, catch up: any record committed after the
           compactor's snapshot is read from the live generation and
           appended to the rebuild (same append/publish discipline), so
           concurrent ingest is never lost;
        3. the meta file's `gens` (and `dicts`) entries are replaced
           atomically (`os.replace`) — THE commit point: a crash on either
           side of it reopens one fully intact generation, and
           `_gc_stale_files` sweeps the loser's files (sidecar included)
           on the next open;
        4. the in-memory shard object and record offsets swap in, and the
           old generation's files are unlinked.

        Returns {bytes_before, bytes_after, n_records, n_caught_up};
        bytes_after includes the new sidecar, so callers comparing totals
        charge the dictionary its own weight.
        """
        self._assert_writable("swap_shard")
        lay = self._layout
        entries = sorted(entries, key=lambda e: e["seq"])
        planned_seqs = {e["seq"] for e in entries}
        # phase 1: bulk rewrite, shard stays fully live
        gen = lay.gens[shard_id] + 1
        new_shard = _Shard(*self._shard_paths(shard_id, gen, lay.n_shards))
        new_dict_path = self._dict_path(shard_id, gen, lay.n_shards)
        for path in (new_shard.data_path, new_shard.index_path, new_dict_path):
            if path.exists():  # leftover from a crashed compaction
                path.unlink()
        dict_sha: Optional[str] = None
        if dictionary:
            with open(new_dict_path, "wb") as f:
                f.write(dictionary)
                fsync_file(f)
            dict_sha = hashlib.sha256(dictionary).hexdigest()
            self.compressor.register_dictionary(dictionary)
        records = _index_records(
            entries, new_shard.append([e["blob"] for e in entries]))
        new_shard.publish(records)
        # phases 2-4: the only window readers/writers wait on
        with lay.shard_locks[shard_id]:
            old_shard = lay.shards[shard_id]
            old_dict_path = (self._dict_path(shard_id, lay.gens[shard_id],
                                             lay.n_shards)
                             if lay.dict_shas[shard_id] else None)
            bytes_before = old_shard.data_size()
            if old_dict_path is not None and old_dict_path.exists():
                bytes_before += old_dict_path.stat().st_size
            with self._index_lock:
                current = [dict(r) for r in self._index.values()
                           if self._shard_of(r["key"], lay.n_shards) == shard_id]
            tail = sorted((r for r in current if r["seq"] not in planned_seqs),
                          key=lambda r: r["seq"])
            if tail:
                tail_entries = [
                    {
                        "key": r["key"],
                        "seq": r["seq"],
                        "method": r["method"],
                        "n_chars": r["n_chars"],
                        "blob": old_shard.read(r["offset"], r["length"]),
                    }
                    for r in tail
                ]
                records += _index_records(
                    tail_entries,
                    new_shard.append([e["blob"] for e in tail_entries]))
                new_shard.publish(records[-len(tail_entries):])
            lay.gens[shard_id] = gen
            lay.dict_shas[shard_id] = dict_sha
            self._write_meta()  # atomic commit point
            lay.shards[shard_id] = new_shard
            self._publish_index(records)
            bytes_after = new_shard.data_size()
            if dictionary:
                bytes_after += len(dictionary)
            stale = [old_shard.data_path, old_shard.index_path]
            if old_dict_path is not None:
                stale.append(old_dict_path)
            for path in stale:
                if path not in (new_shard.data_path, new_shard.index_path,
                                new_dict_path):
                    try:
                        path.unlink()
                    except OSError:  # pragma: no cover - best effort
                        pass
        return {"bytes_before": bytes_before, "bytes_after": bytes_after,
                "n_records": len(records), "n_caught_up": len(tail)}

    # -- rebalancing -----------------------------------------------------------

    def _strip_dict_frames(self, entries: List[dict]) -> int:
        """Re-encode any dictionary-compressed blobs in `entries` as plain
        (v1) frames, preserving each record's method.  Rebalancing mixes
        records from many source shards into each target shard, so the
        per-shard-generation sidecar model cannot follow them — the
        rebalanced layout carries no dictionary dependencies and the next
        compaction pass retrains per new shard.  Returns the re-encode
        count.  Unparseable blobs (preserved forensics from an
        integrity-failed shard) are moved bit-for-bit."""
        by_method: Dict[str, List[int]] = {}
        for i, e in enumerate(entries):
            try:
                if parse_frame(e["blob"]).dict_fp is not None:
                    by_method.setdefault(e["method"], []).append(i)
            except ValueError:
                continue
        n = 0
        for method, members in by_method.items():
            texts = self.compressor.decompress_batch(
                [entries[i]["blob"] for i in members])
            blobs = self.compressor.compress_batch(texts, method)
            for i, blob in zip(members, blobs):
                entries[i]["blob"] = blob
                n += 1
        return n

    def rebalance(self, n_shards: int) -> dict:
        """Re-partition every key across `n_shards` segments, online.

        The heavy rewrite (phase 1) runs with no shard lock held — reads
        and writes keep flowing against the old layout; the swap window
        (phase 2) takes every old shard lock, catches up records committed
        since the snapshot, publishes the new ``store.json`` atomically
        (THE commit point, same as a compaction swap), and installs the
        new `_Layout` in a single assignment.  Writers that planned under
        the old layout re-route in `commit_batch`; readers retry their
        layout capture in `_read_blob`.  All new shards start at
        ``max(old gens) + 1`` so filenames can never collide with any
        live generation, and a crash on either side of the meta replace
        reopens one fully intact layout (`_gc_stale_files` sweeps the
        loser, orphaned ``.dict`` sidecars included).

        Returns {n_shards_before, n_shards_after, n_records, n_caught_up,
        n_reencoded, bytes_before, bytes_after, wall_s}.
        """
        self._assert_writable("rebalance")
        n_new = int(n_shards)
        if n_new < 1:
            raise ValueError("n_shards must be >= 1")
        t0 = time.perf_counter()
        with self._rebalance_lock:
            old = self._layout
            if n_new == old.n_shards:
                size = sum(s.data_size() for s in old.shards)
                return {"n_shards_before": old.n_shards,
                        "n_shards_after": n_new, "n_records": len(self),
                        "n_caught_up": 0, "n_reencoded": 0,
                        "bytes_before": size, "bytes_after": size,
                        "wall_s": time.perf_counter() - t0}
            # serialize against every in-flight compaction: swap_shard's
            # phase-1 unlocked rewrite must never interleave a layout swap
            acquired: List[threading.Lock] = []
            try:
                for lock in old.compact_locks:
                    lock.acquire()
                    acquired.append(lock)
                result = self._rebalance_locked(old, n_new)
            finally:
                for lock in reversed(acquired):
                    lock.release()
        result["wall_s"] = time.perf_counter() - t0
        return result

    def _rebalance_locked(self, old: "_Layout", n_new: int) -> dict:
        gen = max(old.gens) + 1
        # phase 1: snapshot + bulk rewrite; the store stays fully live
        snap_entries: List[dict] = []
        for sid in range(old.n_shards):
            recs = self.shard_records(sid)
            blobs = self.read_records(sid, recs)
            snap_entries += [
                {"key": r["key"], "seq": r["seq"], "method": r["method"],
                 "n_chars": r["n_chars"], "blob": b}
                for r, b in zip(recs, blobs)
            ]
        planned_seqs = {e["seq"] for e in snap_entries}
        n_reencoded = self._strip_dict_frames(snap_entries)
        parts: Dict[int, List[dict]] = {}
        for e in snap_entries:
            parts.setdefault(self._shard_of(e["key"], n_new), []).append(e)
        new_shards = [_Shard(*self._shard_paths(i, gen, n_new))
                      for i in range(n_new)]
        new_records: Dict[int, List[dict]] = {}
        for i, shard in enumerate(new_shards):
            for path in (shard.data_path, shard.index_path,
                         self._dict_path(i, gen, n_new)):
                if path.exists():  # leftover from a crashed rebalance
                    path.unlink()
            entries = sorted(parts.get(i, []), key=lambda e: e["seq"])
            if entries:
                recs = _index_records(
                    entries, shard.append([e["blob"] for e in entries]))
                shard.publish(recs)
                new_records[i] = recs
        bytes_before = sum(s.data_size() for s in old.shards)
        # phase 2: the only window readers/writers wait on
        for lock in old.shard_locks:
            lock.acquire()
        try:
            # repro-analysis: disable=REPRO001 the tail catch-up publish must be atomic with the layout swap: records written after the snapshot exist only in the old generation, and releasing the index lock before the new shards absorb them would let readers see a layout missing live keys
            with self._index_lock:
                tail = sorted((dict(r) for r in self._index.values()
                               if r["seq"] not in planned_seqs),
                              key=lambda r: r["seq"])
                n_caught_up = len(tail)
                if tail:
                    tail_entries = [
                        {"key": r["key"], "seq": r["seq"],
                         "method": r["method"], "n_chars": r["n_chars"],
                         "blob": old.shards[
                             self._shard_of(r["key"], old.n_shards)
                         ].read(r["offset"], r["length"])}
                        for r in tail
                    ]
                    self._strip_dict_frames(tail_entries)
                    tail_parts: Dict[int, List[dict]] = {}
                    for e in tail_entries:
                        tail_parts.setdefault(
                            self._shard_of(e["key"], n_new), []).append(e)
                    for i, entries in tail_parts.items():
                        shard = new_shards[i]
                        recs = _index_records(
                            entries,
                            shard.append([e["blob"] for e in entries]))
                        shard.publish(recs)
                        new_records.setdefault(i, []).extend(recs)
                old_files: List[str] = []
                for i in range(old.n_shards):
                    old_files += [p.name for p in self._shard_paths(
                        i, old.gens[i], old.n_shards)]
                    if old.dict_shas[i]:
                        old_files.append(self._dict_path(
                            i, old.gens[i], old.n_shards).name)
                new_lay = _Layout(n_new, new_shards, [gen] * n_new,
                                  [None] * n_new)
                self._layout = new_lay
                # the committed meta carries the old layout's files as an
                # explicit sweep list: if we die before the unlinks below,
                # the next open finishes the cleanup by name (gen-0 names
                # are ambiguous with foreign backups, so GC never guesses)
                self._pending_sweep = old_files
                self._write_meta()  # atomic commit point
                for recs in new_records.values():
                    for rec in recs:
                        self._index[rec["key"]] = rec
                bytes_after = sum(s.data_size() for s in new_shards)
        finally:
            for lock in reversed(old.shard_locks):
                lock.release()
        # Unlink exactly the OLD layout's files (dict sidecars included).
        # NOT the full _gc_stale_files sweep: a compactor on the freshly
        # installed layout may already be writing its next generation's
        # files phase-1-unlocked, and a sweep keyed on the current gens
        # would delete them mid-write.  Old-layout names can never collide
        # with files any new-layout writer produces (their generations are
        # all <= max(old gens) < gen).  Once done, drop the sweep list
        # from the meta so a later reopen doesn't re-unlink names a future
        # layout might legitimately reuse.
        for name in list(self._pending_sweep):
            try:
                (self.root / name).unlink()
            except OSError:  # pragma: no cover - best effort
                pass
        self._pending_sweep = []
        self._write_meta()
        return {"n_shards_before": old.n_shards, "n_shards_after": n_new,
                "n_records": sum(len(r) for r in new_records.values()),
                "n_caught_up": n_caught_up, "n_reencoded": n_reencoded,
                "bytes_before": bytes_before, "bytes_after": bytes_after}

    # -- ops ------------------------------------------------------------------

    def stats(self) -> dict:
        lay = self._layout
        with self._index_lock:
            recs = list(self._index.values())
            quar_shards = sorted(self._quar_shards)
            quar_keys = len(self._bad_keys)
        stored = sum(r["length"] for r in recs)
        original = sum(r["n_chars"] for r in recs)
        per_shard = [0] * lay.n_shards
        for r in recs:
            per_shard[self._shard_of(r["key"], lay.n_shards)] += 1
        file_bytes = 0
        dict_bytes = 0
        for i in range(lay.n_shards):
            with lay.shard_locks[i]:
                file_bytes += lay.shards[i].data_size()
                if lay.dict_shas[i]:
                    path = self._dict_path(i, lay.gens[i], lay.n_shards)
                    try:  # same vanish window data_size() tolerates
                        dict_bytes += path.stat().st_size
                    except OSError:
                        pass
        return {
            "n_prompts": len(recs),
            "n_shards": lay.n_shards,
            "prompts_per_shard": per_shard,
            "stored_bytes": stored,
            "original_chars": original,
            "space_savings_pct": 100.0 * (1 - stored / original) if original else 0.0,
            "file_bytes": file_bytes,
            "dict_bytes": dict_bytes,
            "dead_bytes": max(file_bytes - stored, 0),
            "gens": list(lay.gens),
            "dicts": sum(1 for s in lay.dict_shas if s),
            # commit counter + casualty list: staleness is writer meta_gen
            # minus replica meta_gen; quarantine is the degraded-read set
            "meta_gen": self._meta_gen,
            "quarantined_shards": quar_shards,
            "quarantined_keys": quar_keys,
        }

    def verify_all(self) -> dict:
        """SHA-256 sweep over every record (paper §5.10 robustness check)."""
        ok = bad = 0
        for key in self.keys():
            try:
                self.get(key, verify=True)
                ok += 1
            except Exception:
                bad += 1
        return {"success": ok, "failure": bad, "total": ok + bad}


class PromptStore(ShardedPromptStore):
    """Single-shard store with the legacy flat ``data.bin``/``index.jsonl``
    layout — the paper-scale configuration, and the drop-in default.  Pass
    ``n_shards`` (or use ShardedPromptStore) for the scaled layout."""

    def __init__(self, root: str | Path,
                 compressor: Optional[PromptCompressor] = None,
                 n_shards: int = 1, *,
                 readonly: bool = False,
                 lease: Optional[str] = "try"):
        super().__init__(root, compressor, n_shards=n_shards,
                         readonly=readonly, lease=lease)
