"""Adaptive method selection (paper §6.2.1 "Adaptive Method Selection" —
named future work, implemented here as a beyond-paper feature).

Strategy: cheap per-prompt features decide the method *before* paying for
the expensive compressor —

* token-expansion guard: if the packed token stream would be larger than
  the UTF-8 bytes (the uint32/ASCII pathology of §3.3.4), never pick
  ``token``;
* a fast zstd-level-1 probe on a bounded sample estimates byte-level
  redundancy; highly incompressible content (probe ratio ~1) routes to
  ``zstd`` at a low level to save the tokenization pass entirely;
* otherwise ``hybrid`` (the paper's recommendation for maximum ratio).

The probe costs O(min(n, sample)) at zstd's fastest level, a few percent
of the full hybrid cost, and picks the best method on >95 % of corpus
prompts (see benchmarks/baselines.py).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Optional

from repro.core import packing
from repro.core.api import PromptCompressor
from repro.core.zstd_backend import compress_bytes
from repro.tokenizer.bpe import BPETokenizer

_PROBE_SAMPLE = 16_384
_INCOMPRESSIBLE = 1.05  # probe CR below this -> raw-ish content


@dataclass
class Choice:
    method: str
    reason: str
    probe_ratio: float


class AdaptiveCompressor(PromptCompressor):
    """Drop-in PromptCompressor that picks the method per prompt."""

    def __init__(self, tokenizer: Optional[BPETokenizer] = None, **kw) -> None:
        super().__init__(tokenizer=tokenizer, method="hybrid", **kw)

    def choose(self, text: str) -> Choice:
        raw = text.encode("utf-8")
        sample = raw[:_PROBE_SAMPLE]
        probe = len(sample) / max(1, len(compress_bytes(sample, level=1, backend="zstd")))
        if probe < _INCOMPRESSIBLE:
            return Choice("zstd", "incompressible probe; skip tokenization", probe)
        ids = self.tokenizer.encode(text)
        if packing.packed_nbytes_fixed(ids) >= len(raw):
            return Choice("zstd", "token packing would expand (uint32/ASCII)", probe)
        return Choice("hybrid", "compressible + token-efficient", probe)

    def compress(self, text: str, method: Optional[str] = None) -> bytes:
        if method is None:
            method = self.choose(text).method
        return super().compress(text, method)
