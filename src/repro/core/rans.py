"""JAX interleaved rANS — the TPU-native batch entropy coder.

Hardware adaptation (DESIGN.md §4): the paper's entropy stage (FSE inside
Zstd) is a sequential, branchy CPU loop.  The rANS state recurrence cannot
be parallelized *within* a stream, but streams are embarrassingly
parallel, so the TPU formulation is:

* split each token stream round-robin across K interleaved lanes,
* run all lanes in lockstep with one ``lax.scan`` over vectorized uint32
  state updates (VPU-friendly: every op is an elementwise u32 op or a
  2^prob_bits-entry table gather),
* a 32-bit state with 16-bit renormalization emits **at most one** word
  per step (x_max = f << (32-pb) >= 2^20 > 2^16 for pb <= 16), so the
  emit buffer has static shape [K, T] and a host-side compaction recovers
  the dense stream — no data-dependent shapes anywhere,
* ``vmap`` over the batch of prompts on top of the lane axis.

Decode is symmetric (at most one word consumed per step) and
division-free.  All arithmetic is uint32 with the same semantics as the
python oracle in ``rans_np`` (tests assert stream equivalence).

Alphabet handling: token ids are remapped to a dense alphabet of the
symbols actually present (stored delta-varint in the header — reusing
LoPace's own packing), so the slot table stays <= 2^prob_bits regardless
of vocabulary size.
"""

from __future__ import annotations

import struct
from functools import partial
from typing import Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.core import packing
from repro.core.rans_np import normalize_freqs

_STATE_LOW = np.uint32(1 << 16)
DEFAULT_LANES = 8


@partial(jax.jit, static_argnames=("prob_bits",))
def rans_encode_lanes(symbols, valid, freqs, prob_bits: int = 12):
    """Encode K lanes in lockstep.

    symbols: [K, T] int32 dense-alphabet ids; valid: [K, T] bool;
    freqs: [A] uint32 summing to 2**prob_bits.
    Returns (words [K, T] u32 — junk where ~flag, flags [K, T] bool
    in *emission order along reversed time*, states [K] u32).
    """
    cum = jnp.concatenate([jnp.zeros(1, jnp.uint32), jnp.cumsum(freqs).astype(jnp.uint32)])
    shift = jnp.uint32(32 - prob_bits)
    pb = jnp.uint32(prob_bits)

    def lane(sym_l, val_l):
        def step(x, inp):
            s, ok = inp
            f = freqs[s]
            c = cum[s]
            x_max = f << shift
            emit = (x >= x_max) & ok
            word = jnp.where(emit, x & jnp.uint32(0xFFFF), jnp.uint32(0))
            x1 = jnp.where(emit, x >> jnp.uint32(16), x)
            fs = jnp.maximum(f, jnp.uint32(1))  # div-safe on masked steps
            x2 = ((x1 // fs) << pb) + (x1 % fs) + c
            return jnp.where(ok, x2, x), (word, emit)

        # encoder walks the symbols back-to-front
        x_final, (words, flags) = jax.lax.scan(
            step, jnp.uint32(_STATE_LOW), (sym_l[::-1], val_l[::-1])
        )
        return words, flags, x_final

    return jax.vmap(lane)(symbols, valid)


@partial(jax.jit, static_argnames=("prob_bits", "n_steps"))
def rans_decode_lanes(words, n_words, states, n_valid, freqs, prob_bits: int, n_steps: int):
    """Decode K lanes in lockstep.

    words: [K, W] u32 per-lane streams in emission order (decoder consumes
    from index n_words-1 downward); states/n_words/n_valid: [K].
    Returns symbols [K, n_steps] int32 (zeros beyond n_valid).
    """
    cum = jnp.concatenate([jnp.zeros(1, jnp.uint32), jnp.cumsum(freqs).astype(jnp.uint32)])
    slot2sym = jnp.repeat(
        jnp.arange(freqs.shape[0], dtype=jnp.int32), freqs.astype(jnp.int32),
        total_repeat_length=1 << prob_bits,
    )
    mask = jnp.uint32((1 << prob_bits) - 1)
    pb = jnp.uint32(prob_bits)
    W = words.shape[1]

    def lane(words_l, state_l, n_words_l, n_valid_l):
        def step(carry, t):
            x, pos = carry
            ok = t < n_valid_l
            slot = x & mask
            s = slot2sym[slot]
            x1 = freqs[s] * (x >> pb) + slot - cum[s]
            need = (x1 < _STATE_LOW) & ok
            safe_pos = jnp.clip(pos, 0, W - 1)
            x2 = jnp.where(need, (x1 << jnp.uint32(16)) | words_l[safe_pos], x1)
            pos2 = jnp.where(need, pos - jnp.int32(1), pos)
            return (jnp.where(ok, x2, x), jnp.where(ok, pos2, pos)), jnp.where(ok, s, 0)

        (_, _), syms = jax.lax.scan(
            step,
            (state_l, n_words_l - jnp.int32(1)),
            jnp.arange(n_steps, dtype=jnp.int32),
        )
        return syms

    return jax.vmap(lane)(words, states, n_words, n_valid)


# ---------------------------------------------------------------------------
# Host wrappers: token stream <-> self-contained blob
# ---------------------------------------------------------------------------
#
# blob layout:
#   u32 n_tokens | u8 prob_bits | u8 lanes | u16 alphabet_size
#   u32 alpha_len | alphabet ids delta-varint packed (LoPace packing §3.3.3)
#   freqs          : alphabet_size x u16le  (freq 2**16 impossible: alphabet>=2
#                    enforced by padding a dummy symbol)
#   per-lane       : u32 state | u16 n_words
#   words          : concatenated u16le, per lane in consumption order


def _pick_prob_bits(n_present: int) -> int:
    pb = 12
    while (1 << pb) < 4 * n_present:
        pb += 1
    return min(pb, 16)


def _dense_histogram(dense: np.ndarray, n_present: int) -> np.ndarray:
    """Frequency table for the dense-alphabet ids: the Pallas histogram
    kernel when a non-CPU backend is attached (the table build is then as
    device-resident as the coder itself), ``np.bincount`` on CPU hosts —
    the same routing convention as `repro.core.entropy.byte_histogram`."""
    if jax.default_backend() != "cpu":
        from repro.kernels.histogram import token_histogram

        return np.asarray(
            token_histogram(jnp.asarray(dense, jnp.int32), int(n_present),
                            interpret=False),
            dtype=np.int64)
    return np.bincount(dense, minlength=n_present)


def _lane_split(ids: np.ndarray, lanes: int) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Round-robin split into [lanes, T] + validity mask + per-lane counts."""
    n = ids.size
    T = max(1, -(-n // lanes))
    sym = np.zeros((lanes, T), dtype=np.int32)
    val = np.zeros((lanes, T), dtype=bool)
    cnt = np.zeros(lanes, dtype=np.int32)
    for k in range(lanes):
        lane_ids = ids[k::lanes]
        sym[k, : lane_ids.size] = lane_ids
        val[k, : lane_ids.size] = True
        cnt[k] = lane_ids.size
    return sym, val, cnt


def tokens_compress_device(ids, lanes: int = DEFAULT_LANES) -> bytes:
    """Compress a token-id stream with the JAX coder. Returns a blob."""
    ids = np.asarray(ids, dtype=np.int64)
    if ids.size == 0:
        return struct.pack("<IBBH", 0, 12, lanes, 0)
    alphabet, dense = np.unique(ids, return_inverse=True)
    if alphabet.size == 1:  # degenerate single-symbol stream: pad alphabet
        alphabet = np.concatenate([alphabet, alphabet[-1:] + 1])
    n_present = alphabet.size
    prob_bits = _pick_prob_bits(n_present)
    counts = _dense_histogram(dense, n_present)
    freqs = normalize_freqs(counts, prob_bits)

    sym, val, _ = _lane_split(dense.astype(np.int32), lanes)
    words, flags, states = rans_encode_lanes(
        jnp.asarray(sym), jnp.asarray(val), jnp.asarray(freqs.astype(np.uint32)),
        prob_bits=prob_bits,
    )
    words = np.asarray(words, dtype=np.uint32)
    flags = np.asarray(flags)
    states = np.asarray(states, dtype=np.uint32)

    header = struct.pack("<IBBH", ids.size, prob_bits, lanes, n_present)
    alpha_blob = packing.pack_tokens(alphabet.astype(np.uint32), scheme="delta-varint")
    parts = [header, struct.pack("<I", len(alpha_blob)), alpha_blob,
             freqs.astype("<u2").tobytes()]
    lane_words = []
    for k in range(lanes):
        w = words[k][flags[k]].astype(np.uint16)  # dense, in emission order
        lane_words.append(w)
        parts.append(struct.pack("<IH", int(states[k]), w.size))
    for w in lane_words:
        parts.append(w.astype("<u2").tobytes())
    return b"".join(parts)


def tokens_decompress_device(blob: bytes) -> np.ndarray:
    n, prob_bits, lanes, n_present = struct.unpack_from("<IBBH", blob, 0)
    off = 8
    if n == 0:
        return np.zeros(0, dtype=np.uint32)
    (alpha_len,) = struct.unpack_from("<I", blob, off)
    off += 4
    alphabet = packing.unpack_tokens(blob[off : off + alpha_len]).astype(np.int64)
    off += alpha_len
    freqs = np.frombuffer(blob, dtype="<u2", count=n_present, offset=off).astype(np.uint32)
    off += 2 * n_present
    states = np.zeros(lanes, dtype=np.uint32)
    n_words = np.zeros(lanes, dtype=np.int32)
    for k in range(lanes):
        s, w = struct.unpack_from("<IH", blob, off)
        off += 6
        states[k], n_words[k] = s, w
    max_w = max(1, int(n_words.max()))
    words = np.zeros((lanes, max_w), dtype=np.uint32)
    for k in range(lanes):
        w = np.frombuffer(blob, dtype="<u2", count=int(n_words[k]), offset=off)
        off += 2 * int(n_words[k])
        words[k, : w.size] = w.astype(np.uint32)

    n_valid = np.array([len(range(k, n, lanes)) for k in range(lanes)], dtype=np.int32)
    T_sym = max(1, -(-n // lanes))
    sym = rans_decode_lanes(
        jnp.asarray(words),
        jnp.asarray(n_words),
        jnp.asarray(states),
        jnp.asarray(n_valid),
        jnp.asarray(freqs),
        prob_bits=prob_bits,
        n_steps=T_sym,
    )
    sym = np.asarray(sym)
    out = np.zeros(n, dtype=np.int64)
    for k in range(lanes):
        cnt = int(n_valid[k])
        out[k::lanes] = sym[k, :cnt]
    return alphabet[out].astype(np.uint32)
