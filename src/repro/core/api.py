"""LoPace public API: the paper's three compression methods plus the
production frame format used by the PromptStore and the data pipeline.

Paper-exact payloads (Algorithms 1 and 2; used by the benchmark suite so
measured sizes match the paper's definitions bit-for-bit):

    zstd   : C_zstd(utf8(T))
    token  : [format_byte | packed(τ(T))]
    hybrid : C_zstd([format_byte | packed(τ(T))])

Production frames wrap a payload with a 15-byte self-describing header
(magic, version, method, backend, level, packing scheme, tokenizer
fingerprint) so stored blobs can always be decoded — the tokenizer
versioning safeguard of §8.4.1 #1.  Frames whose byte stage used a
trained dictionary carry a second header version (2) with an extra
8-byte dictionary fingerprint; version-1 frames are unchanged, so every
pre-dictionary store stays decodable byte-for-byte.
"""

from __future__ import annotations

import hashlib
import struct
from dataclasses import dataclass
from typing import Dict, List, Optional, Sequence

import numpy as np

from repro.core import failpoints, packing
from repro.core.codec import PipelineCodec, TokenPackCodec, method_pipeline
from repro.core.zstd_backend import (BACKENDS, DEFAULT_LEVEL, compress_bytes,
                                     decompress_bytes, decompress_bytes_dict)
from repro.tokenizer.bpe import BPETokenizer

MAGIC = b"LP"
VERSION = 1        # plain frames — bit-identical to every earlier store
DICT_VERSION = 2   # + 8-byte dictionary fingerprint after the v1 fields

METHODS = ("zstd", "token", "hybrid")
_METHOD_ID = {m: i for i, m in enumerate(METHODS)}
_BACKEND_IDS = {name: i for i, name in enumerate(sorted(BACKENDS))}
_BACKEND_NAMES = {i: name for name, i in _BACKEND_IDS.items()}
_SCHEME_IDS = {"fixed": 0, "varint": 1, "delta-varint": 2}
_SCHEME_NAMES = {v: k for k, v in _SCHEME_IDS.items()}


# ---------------------------------------------------------------------------
# Paper-exact method functions
# ---------------------------------------------------------------------------


def compress_zstd(text: str, level: int = DEFAULT_LEVEL, backend: str = "zstd") -> bytes:
    """Method 1 (§3.2): byte-level dictionary compression of UTF-8 text."""
    return compress_bytes(text.encode("utf-8"), level=level, backend=backend)


def decompress_zstd(payload: bytes, backend: str = "zstd") -> str:
    return decompress_bytes(payload, backend=backend).decode("utf-8")


def compress_token(text: str, tokenizer: BPETokenizer, scheme: str = "fixed") -> bytes:
    """Method 2 (§3.3): BPE tokenize + binary pack (format byte included)."""
    return packing.pack_tokens(tokenizer.encode(text), scheme=scheme)


def decompress_token(payload: bytes, tokenizer: BPETokenizer) -> str:
    return tokenizer.decode(packing.unpack_tokens(payload))


def compress_hybrid(
    text: str,
    tokenizer: BPETokenizer,
    level: int = DEFAULT_LEVEL,
    backend: str = "zstd",
    scheme: str = "fixed",
) -> bytes:
    """Method 3 (§3.4, Algorithm 1): C_zstd(P(τ(T)))."""
    return compress_bytes(
        packing.pack_tokens(tokenizer.encode(text), scheme=scheme),
        level=level,
        backend=backend,
    )


def decompress_hybrid(payload: bytes, tokenizer: BPETokenizer, backend: str = "zstd") -> str:
    """Algorithm 2: τ⁻¹(P⁻¹(C_zstd⁻¹(payload)))."""
    return tokenizer.decode(packing.unpack_tokens(decompress_bytes(payload, backend=backend)))


def hybrid_tokens(payload: bytes, backend: str = "zstd") -> np.ndarray:
    """Token-stream storage mode (§8.4.2 #10): recover token ids WITHOUT
    detokenization — the training/serving pipeline consumes these directly."""
    return packing.unpack_tokens(decompress_bytes(payload, backend=backend))


# ---------------------------------------------------------------------------
# Production frame
# ---------------------------------------------------------------------------

# magic, ver, method, backend, level (signed: zstd accepts negative levels),
# scheme, tokenizer fingerprint
_HEADER = struct.Struct("<2sBBBbB8s")
# v2 appends the dictionary fingerprint (sha256(dict)[:8]) after the v1
# fields, so a v2 header is a v1 header plus 8 bytes — old frames parse
# unchanged and old stores stay byte-identical on disk
_DICT_FP = struct.Struct("<8s")


def dict_fingerprint(dictionary: bytes) -> bytes:
    """The 8-byte content address a v2 frame stores for its dictionary."""
    return hashlib.sha256(dictionary).digest()[:8]


@dataclass(frozen=True)
class FrameInfo:
    method: str
    backend: str
    level: int
    scheme: str
    tokenizer_fp: bytes
    payload: bytes
    dict_fp: Optional[bytes] = None  # None for v1 (dictionary-less) frames


def _tok_fp(tokenizer: Optional[BPETokenizer]) -> bytes:
    if tokenizer is None:
        return b"\x00" * 8
    return bytes.fromhex(tokenizer.fingerprint())[:8]


def parse_frame(blob: bytes) -> FrameInfo:
    if len(blob) < _HEADER.size or blob[:2] != MAGIC:
        raise ValueError("not a LoPace frame")
    magic, ver, mid, bid, level, sid, fp = _HEADER.unpack_from(blob, 0)
    if ver not in (VERSION, DICT_VERSION):
        raise ValueError(f"unsupported LoPace frame version {ver}")
    # Corrupt or future frames must fail loudly as ValueError, not leak
    # bare KeyError/IndexError from the id tables.
    if mid >= len(METHODS):
        raise ValueError(f"corrupt or future LoPace frame: unknown method id {mid}")
    if bid not in _BACKEND_NAMES:
        raise ValueError(f"corrupt or future LoPace frame: unknown backend id {bid}")
    if sid not in _SCHEME_NAMES:
        raise ValueError(f"corrupt or future LoPace frame: unknown scheme id {sid}")
    dict_fp: Optional[bytes] = None
    body = _HEADER.size
    if ver == DICT_VERSION:
        if len(blob) < _HEADER.size + _DICT_FP.size:
            raise ValueError("corrupt LoPace frame: truncated dict header")
        (dict_fp,) = _DICT_FP.unpack_from(blob, _HEADER.size)
        body += _DICT_FP.size
    return FrameInfo(
        method=METHODS[mid],
        backend=_BACKEND_NAMES[bid],
        level=level,
        scheme=_SCHEME_NAMES[sid],
        tokenizer_fp=fp,
        payload=blob[body:],
        dict_fp=dict_fp,
    )


class PromptCompressor:
    """The engine of the paper: one instance, three methods, lossless.

    Cross-instance compatibility (§6.2.2): any instance constructed with
    the same tokenizer decodes any other instance's output; frames carry
    the tokenizer fingerprint and decompress refuses a mismatch instead
    of corrupting data.
    """

    def __init__(
        self,
        tokenizer: Optional[BPETokenizer] = None,
        method: str = "hybrid",
        level: int = DEFAULT_LEVEL,
        backend: str = "zstd",
        scheme: str = "fixed",
    ) -> None:
        if method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}")
        if backend not in BACKENDS:
            raise ValueError(f"unknown backend {backend!r}")
        if scheme not in _SCHEME_IDS:
            raise ValueError(f"unknown packing scheme {scheme!r}")
        # Levels ride in the frame header as a signed byte; negative levels
        # are valid for the zstd backend (fast mode), so reject anything a
        # signed byte cannot round-trip instead of silently wrapping.
        if not -128 <= level <= 127:
            raise ValueError(
                f"level {level} does not fit the frame's signed level byte "
                "[-128, 127]")
        if method in ("token", "hybrid") and tokenizer is None:
            from repro.tokenizer.vocab import default_tokenizer

            tokenizer = default_tokenizer()
        self.tokenizer = tokenizer
        self.method = method
        self.level = level
        self.backend = backend
        self.scheme = scheme
        self._pipelines: Dict[tuple, PipelineCodec] = {}
        self._dicts: Dict[bytes, bytes] = {}  # fingerprint -> dictionary

    # -- trained dictionaries -----------------------------------------------

    def register_dictionary(self, dictionary: bytes) -> bytes:
        """Make a trained dictionary available for encode/decode; returns
        its 8-byte fingerprint (the id v2 frames carry).  Content-addressed
        and idempotent — the store calls this for every sidecar it opens.

        Registrations are never evicted: a reader may hold a frame fetched
        before a generation swap and decode it after, so dropping a
        superseded dictionary would turn that read into an error.  Growth
        is bounded in practice — compaction only registers a *winning*
        dictionary (candidates are scored on a scratch compressor), and
        the strict-win adoption rule means a stable corpus converges on
        its incumbent (same bytes ⇒ same fingerprint ⇒ no new entry).
        Refcounted eviction keyed on live sidecars is a noted follow-on."""
        if not dictionary:
            raise ValueError("cannot register an empty dictionary")
        fp = dict_fingerprint(dictionary)
        self._dicts[fp] = bytes(dictionary)
        return fp

    def dictionary_for(self, fp: bytes) -> bytes:
        try:
            return self._dicts[fp]
        except KeyError:
            raise ValueError(
                f"frame references dictionary {fp.hex()} but it is not "
                "registered — the store's .dict sidecar is missing or was "
                "not loaded") from None

    # -- codec pipelines ----------------------------------------------------

    def pipeline(self, method: Optional[str] = None,
                 backend: Optional[str] = None,
                 dict_fp: Optional[bytes] = None) -> PipelineCodec:
        """The stage pipeline implementing `method` (cached per
        method/backend/dictionary)."""
        key = (method or self.method, backend or self.backend, dict_fp)
        pipe = self._pipelines.get(key)
        if pipe is None:
            dictionary = self.dictionary_for(dict_fp) if dict_fp else None
            pipe = method_pipeline(key[0], tokenizer=self.tokenizer,
                                   level=self.level, backend=key[1],
                                   scheme=self.scheme, dictionary=dictionary)
            self._pipelines[key] = pipe
        return pipe

    def byte_stage_payloads(self, texts: Sequence[str],
                            method: Optional[str] = None) -> List[bytes]:
        """The inputs the byte-compressor stage of `method` would see for
        `texts` — what a dictionary for that (method, scheme) must be
        trained on (utf-8 text for ``zstd``, packed token streams for
        ``hybrid``)."""
        method = method or self.method
        if method == "token":
            raise ValueError("method 'token' has no byte-compressor stage")
        payloads = [t.encode("utf-8") for t in texts]
        for stage in self.pipeline(method).stages[:-1]:
            payloads = stage.encode_batch(payloads)
        return payloads

    # -- raw (paper-exact) ------------------------------------------------

    def compress_raw(self, text: str, method: Optional[str] = None) -> bytes:
        return self.pipeline(method).encode_batch([text.encode("utf-8")])[0]

    def decompress_raw(self, payload: bytes, method: Optional[str] = None) -> str:
        return self.pipeline(method).decode_batch([payload])[0].decode("utf-8")

    # -- framed (production) ------------------------------------------------

    def _header(self, method: str, dict_fp: Optional[bytes] = None) -> bytes:
        head = _HEADER.pack(
            MAGIC,
            DICT_VERSION if dict_fp else VERSION,
            _METHOD_ID[method],
            _BACKEND_IDS[self.backend],
            self.level,
            _SCHEME_IDS[self.scheme],
            _tok_fp(self.tokenizer if method != "zstd" else None),
        )
        if dict_fp:
            head += _DICT_FP.pack(dict_fp)
        return head

    def compress(self, text: str, method: Optional[str] = None) -> bytes:
        return self.compress_batch([text], method)[0]

    def compress_batch(self, texts: Sequence[str],
                       method: Optional[str] = None,
                       dictionary: Optional[bytes] = None) -> List[bytes]:
        """Batch-first compression: one pipeline pass over the whole batch
        (batch BPE encode, one kernel launch per packing width on device,
        per-record byte compression fanned out over the shared codec
        thread pool — see ``repro.core.codec``), bit-identical to calling
        `compress` per text.

        With ``dictionary``, the byte stage is primed with it and the
        frames are emitted at header version 2 carrying its fingerprint;
        without one, output is byte-identical to every earlier version.
        """
        method = method or self.method
        if method not in METHODS:
            raise ValueError(f"method must be one of {METHODS}")
        dict_fp = self.register_dictionary(dictionary) if dictionary else None
        payloads = self.pipeline(method, dict_fp=dict_fp).encode_batch(
            [t.encode("utf-8") for t in texts])
        header = self._header(method, dict_fp)
        return [header + p for p in payloads]

    def _check_frame(self, info: FrameInfo) -> None:
        if info.method != "zstd":
            if self.tokenizer is None:
                raise ValueError("frame needs a tokenizer but none configured")
            if info.tokenizer_fp != _tok_fp(self.tokenizer):
                raise ValueError(
                    "tokenizer fingerprint mismatch: payload was compressed with a "
                    "different vocabulary (paper §8.4.1 versioning safeguard)"
                )

    def decompress(self, blob: bytes) -> str:
        return self.decompress_batch([blob])[0]

    def decompress_batch(self, blobs: Sequence[bytes]) -> List[str]:
        """Decode a batch of frames; frames are grouped by (method,
        backend, dict fingerprint) so each pipeline decodes its group in
        one batched pass."""
        failpoints.fire("codec.decompress")
        infos = [parse_frame(b) for b in blobs]
        out: List[Optional[str]] = [None] * len(blobs)
        groups: Dict[tuple, List[int]] = {}
        for i, info in enumerate(infos):
            self._check_frame(info)
            groups.setdefault(
                (info.method, info.backend, info.dict_fp), []).append(i)
        for (method, backend, dict_fp), members in groups.items():
            decoded = self.pipeline(method, backend, dict_fp).decode_batch(
                [infos[i].payload for i in members])
            for i, raw in zip(members, decoded):
                out[i] = raw.decode("utf-8")
        return out  # type: ignore[return-value]

    def tokens(self, blob: bytes, to_device: bool = False) -> np.ndarray:
        """Token-stream mode on a framed blob (no detokenization)."""
        return self.tokens_batch([blob], to_device=to_device)[0]

    def tokens_batch(self, blobs: Sequence[bytes],
                     to_device: bool = False) -> List[np.ndarray]:
        """Framed blobs -> token-id arrays.  ``to_device=True`` lands the
        arrays in device memory (jnp uint32) — serve-path decompress-to-
        tokens hands them to model input staging without a host round
        trip (the byte-stage undo stays on host; only the final unpack
        uploads)."""
        failpoints.fire("codec.tokens")
        infos = [parse_frame(b) for b in blobs]
        out: List[Optional[np.ndarray]] = [None] * len(blobs)
        groups: Dict[tuple, List[int]] = {}
        for i, info in enumerate(infos):
            if info.method == "zstd" and self.tokenizer is None:
                # same guard as decompress(): a zstd frame stores text, so
                # producing token ids requires a configured tokenizer
                raise ValueError("frame needs a tokenizer but none configured")
            self._check_frame(info)
            groups.setdefault(
                (info.method, info.backend, info.dict_fp), []).append(i)
        for (method, backend, dict_fp), members in groups.items():
            payloads = [infos[i].payload for i in members]
            if method in ("zstd", "hybrid"):  # undo the byte stage first
                if dict_fp:
                    d = self.dictionary_for(dict_fp)
                    payloads = [decompress_bytes_dict(p, d, backend=backend)
                                for p in payloads]
                else:
                    payloads = [decompress_bytes(p, backend=backend)
                                for p in payloads]
            if method == "zstd":
                ids = [np.asarray(self.tokenizer.encode(p.decode("utf-8")),
                                  dtype=np.uint32) for p in payloads]
                if to_device:
                    import jax.numpy as jnp

                    ids = [jnp.asarray(a) for a in ids]
            else:
                pack_stage = self.pipeline(method, backend).stages[0]
                assert isinstance(pack_stage, TokenPackCodec)
                ids = pack_stage.decode_ids_batch(payloads,
                                                  to_device=to_device)
            for i, arr in zip(members, ids):
                out[i] = arr
        return out  # type: ignore[return-value]

    # -- verification (§3.5.2) ---------------------------------------------

    def verify(self, text: str, method: Optional[str] = None) -> dict:
        """Compress + decompress + the paper's three-way lossless check."""
        blob = self.compress(text, method)
        rt = self.decompress(blob)
        exact = rt == text
        h0 = hashlib.sha256(text.encode("utf-8")).hexdigest()
        h1 = hashlib.sha256(rt.encode("utf-8")).hexdigest()
        n_err = sum(a != b for a, b in zip(text, rt)) + abs(len(text) - len(rt))
        return {
            "exact_match": exact,
            "sha256_match": h0 == h1,
            "reconstruction_errors": n_err,
            "original_bytes": len(text.encode("utf-8")),
            "compressed_bytes": len(blob),
        }
