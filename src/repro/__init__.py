"""repro: LoPace (lossless prompt compression engine) as a first-class
storage layer of a multi-pod JAX LM training/serving framework.

See DESIGN.md for the system inventory and EXPERIMENTS.md for results.
"""

__version__ = "1.0.0"
