"""gemma2-27b [dense] — 46L d_model=4608 32H (GQA kv=16) d_ff=36864
vocab=256000; local+global alternating, logit softcaps, pre+post norms
[arXiv:2408.00118; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="gemma2-27b",
    family="dense",
    n_layers=46,
    d_model=4608,
    n_heads=32,
    n_kv_heads=16,
    head_dim=128,
    d_ff=36864,
    vocab_size=256_000,
    block_pattern=("local", "attn"),   # sliding 4096 alternating with global
    window=4096,
    attn_logit_softcap=50.0,
    final_logit_softcap=30.0,
    post_block_norms=True,
    ffn_kind="geglu",
    norm_style="rmsnorm_unit",
    scale_embeddings=True,
    tie_embeddings=True,
)
