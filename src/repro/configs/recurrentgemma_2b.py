"""recurrentgemma-2b [hybrid] — 26L d_model=2560 10H (MQA kv=1) d_ff=7680
vocab=256000; RG-LRU + local attention at 2:1 [arXiv:2402.19427; hf]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="recurrentgemma-2b",
    family="hybrid",
    n_layers=26,                  # 8 full (rglru,rglru,local) periods + 2 rem
    d_model=2560,
    n_heads=10,
    n_kv_heads=1,
    head_dim=256,
    d_ff=7680,
    vocab_size=256_000,
    block_pattern=("rglru", "rglru", "local"),
    window=2048,
    ffn_kind="geglu",
    norm_style="rmsnorm_unit",
    scale_embeddings=True,
    tie_embeddings=True,
    rnn_width=2560,
    supports_long_context=True,   # bounded window + O(1) recurrent state
)
