"""The paper's own end-to-end config: a ~100M-param LM trained from the
LoPace-compressed PromptStore (examples/train_lm.py), demonstrating the
token-stream storage mode feeding a real training loop."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="lopace-100m",
    family="dense",
    n_layers=12,
    d_model=768,
    n_heads=12,
    n_kv_heads=4,
    d_ff=2048,
    vocab_size=8192,   # matches repro.tokenizer.default_tokenizer()
    block_pattern=("attn",),
    ffn_kind="swiglu",
)
