"""ModelConfig: the single declarative description every architecture in
the pool reduces to.  Configs are frozen dataclasses; reduced smoke
variants are derived with `.smoke()`."""

from __future__ import annotations

import dataclasses
from dataclasses import dataclass, field, replace
from typing import Optional, Tuple


@dataclass(frozen=True)
class MoEConfig:
    n_experts: int
    top_k: int
    n_shared: int = 0
    d_ff_expert: int = 0  # per-expert hidden size
    router_softcap: float = 0.0


@dataclass(frozen=True)
class MLAConfig:
    """Multi-head Latent Attention dims (DeepSeek-V2 / MiniCPM3)."""
    q_lora_rank: int = 768
    kv_lora_rank: int = 256
    qk_nope_head_dim: int = 64
    qk_rope_head_dim: int = 32
    v_head_dim: int = 64


@dataclass(frozen=True)
class ModelConfig:
    name: str
    n_layers: int
    d_model: int
    n_heads: int
    n_kv_heads: int
    d_ff: int
    vocab_size: int
    head_dim: int = 0  # 0 -> d_model // n_heads

    # temporal-mixer pattern, repeated every len(block_pattern) layers.
    # kinds: attn | local | mla | rglru | mlstm | slstm
    block_pattern: Tuple[str, ...] = ("attn",)

    # attention details
    window: int = 0                 # local-attention window (kind "local")
    attn_logit_softcap: float = 0.0  # gemma2 attention softcap
    final_logit_softcap: float = 0.0  # gemma2 output softcap
    rope_base: float = 10_000.0
    pos_embedding: str = "rope"     # rope | sinusoidal | none

    # channel mixer
    ffn_kind: str = "swiglu"        # swiglu | geglu | gelu | none
    moe: Optional[MoEConfig] = None

    mla: Optional[MLAConfig] = None

    # norms / embeddings
    norm_style: str = "rmsnorm"     # rmsnorm | rmsnorm_unit | layernorm
    norm_eps: float = 1e-6
    post_block_norms: bool = False  # gemma2 pre+post sandwich norms
    tie_embeddings: bool = False
    scale_embeddings: bool = False  # gemma: embed * sqrt(d_model)

    # recurrent dims
    rnn_width: int = 0              # RG-LRU width (0 -> d_model)
    conv_width: int = 4             # temporal conv in griffin/xlstm blocks

    # modality frontend: token | audio_stub | vision_stub
    frontend: str = "token"
    n_patches: int = 576            # vision_stub prefix length

    # numerics
    param_dtype: str = "float32"
    activation_dtype: str = "bfloat16"
    kv_cache_dtype: str = "bfloat16"   # "int8" -> quantized KV (KIVI-style)

    # which shapes this arch supports (DESIGN.md §Arch-applicability)
    supports_long_context: bool = False

    # family tag from the assignment table: moe|ssm|hybrid|dense|audio|vlm
    family: str = "dense"

    def __post_init__(self):
        if self.head_dim == 0:
            object.__setattr__(self, "head_dim", self.d_model // self.n_heads)
        if self.rnn_width == 0:
            object.__setattr__(self, "rnn_width", self.d_model)
        assert self.n_heads % max(self.n_kv_heads, 1) == 0

    @property
    def period(self) -> int:
        return len(self.block_pattern)

    def kind_of_layer(self, i: int) -> str:
        return self.block_pattern[i % self.period]

    def smoke(self) -> "ModelConfig":
        """Reduced same-family config for CPU smoke tests."""
        moe = None
        if self.moe is not None:
            moe = replace(
                self.moe,
                n_experts=min(self.moe.n_experts, 4),
                top_k=min(self.moe.top_k, 2),
                n_shared=min(self.moe.n_shared, 1),
                d_ff_expert=min(self.moe.d_ff_expert or 64, 64),
            )
        mla = None
        if self.mla is not None:
            mla = MLAConfig(q_lora_rank=32, kv_lora_rank=16,
                            qk_nope_head_dim=8, qk_rope_head_dim=4, v_head_dim=8)
        return replace(
            self,
            name=self.name + "-smoke",
            n_layers=2 * self.period,
            d_model=64,
            n_heads=4,
            n_kv_heads=min(self.n_kv_heads, 2),
            head_dim=16,
            d_ff=0 if self.d_ff == 0 else 128,
            vocab_size=512,
            window=min(self.window, 32) if self.window else 0,
            rnn_width=64 if self.rnn_width else 0,
            moe=moe,
            mla=mla,
            n_patches=8,
        )


def count_params(cfg: ModelConfig) -> int:
    """Exact parameter count via shape-only tracing of init_params
    (no allocation)."""
    import jax

    from repro.models.transformer import init_params

    shapes = jax.eval_shape(lambda k: init_params(k, cfg),
                            jax.ShapeDtypeStruct((2,), "uint32"))
    return sum(int(_prod(l.shape)) for l in jax.tree_util.tree_leaves(shapes))


def _prod(shape) -> int:
    out = 1
    for s in shape:
        out *= int(s)
    return out


def active_params(cfg: ModelConfig) -> int:
    """Parameters touched per token (MoE: shared + top_k routed experts
    instead of all routed experts) — the N in MODEL_FLOPS = 6*N_active*D."""
    total = count_params(cfg)
    if cfg.moe is None:
        return total
    e = cfg.moe
    f = e.d_ff_expert or cfg.d_ff
    per_expert = 3 * cfg.d_model * f
    inactive = (e.n_experts - e.top_k) * per_expert * cfg.n_layers
    return total - inactive
