"""Architecture registry: `get_config(arch_id)` + the assigned shape grid.

Shapes (assignment):
  train_4k     seq_len=4096    global_batch=256   (train_step)
  prefill_32k  seq_len=32768   global_batch=32    (serve prefill forward)
  decode_32k   seq_len=32768   global_batch=128   (serve_step: 1 new token,
                                                   KV cache of seq_len)
  long_500k    seq_len=524288  global_batch=1     (decode; sub-quadratic
                                                   archs only)
"""

from __future__ import annotations

import importlib
from dataclasses import dataclass
from typing import Dict, List, Tuple

from repro.configs.base import ModelConfig

ARCH_IDS = [
    "deepseek_moe_16b",
    "dbrx_132b",
    "xlstm_1_3b",
    "recurrentgemma_2b",
    "minicpm3_4b",
    "gemma_7b",
    "gemma2_27b",
    "internlm2_20b",
    "musicgen_medium",
    "llava_next_34b",
]

# canonical ids from the assignment (dash form) -> module names
ALIASES = {a.replace("_", "-"): a for a in ARCH_IDS}
ALIASES.update({"xlstm-1.3b": "xlstm_1_3b", "minicpm3-4b": "minicpm3_4b",
                "dbrx-132b": "dbrx_132b", "deepseek-moe-16b": "deepseek_moe_16b",
                "recurrentgemma-2b": "recurrentgemma_2b", "gemma-7b": "gemma_7b",
                "gemma2-27b": "gemma2_27b", "internlm2-20b": "internlm2_20b",
                "musicgen-medium": "musicgen_medium",
                "llava-next-34b": "llava_next_34b"})


@dataclass(frozen=True)
class ShapeSpec:
    name: str
    seq_len: int
    global_batch: int
    step: str  # "train" | "prefill" | "decode"


SHAPES: Dict[str, ShapeSpec] = {
    "train_4k": ShapeSpec("train_4k", 4_096, 256, "train"),
    "prefill_32k": ShapeSpec("prefill_32k", 32_768, 32, "prefill"),
    "decode_32k": ShapeSpec("decode_32k", 32_768, 128, "decode"),
    "long_500k": ShapeSpec("long_500k", 524_288, 1, "decode"),
}


def get_config(arch: str) -> ModelConfig:
    mod_name = ALIASES.get(arch, arch).replace("-", "_")
    if mod_name not in ARCH_IDS:
        raise ValueError(f"unknown arch {arch!r}; known: {sorted(ALIASES)}")
    mod = importlib.import_module(f"repro.configs.{mod_name}")
    return mod.CONFIG


def all_configs() -> Dict[str, ModelConfig]:
    return {a: get_config(a) for a in ARCH_IDS}


def cells(include_skipped: bool = False) -> List[Tuple[str, str, str]]:
    """All (arch, shape, status) cells of the assignment grid.
    status: "run" or "skip:<reason>"."""
    out = []
    for arch in ARCH_IDS:
        cfg = get_config(arch)
        for shape in SHAPES.values():
            status = "run"
            if shape.name == "long_500k" and not cfg.supports_long_context:
                status = ("skip:full-attention arch — 512k dense KV is "
                          "quadratic prefill; no windowing mechanism")
            out.append((arch, shape.name, status))
    if include_skipped:
        return out
    return [c for c in out if c[2] == "run"]
