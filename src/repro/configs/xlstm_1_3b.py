"""xlstm-1.3b [ssm] — 48L d_model=2048 4H d_ff=0 vocab=50304; sLSTM + mLSTM
blocks at ratio 7:1 (xLSTM[7:1]) [arXiv:2405.04517]."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="xlstm-1.3b",
    family="ssm",
    n_layers=48,
    d_model=2048,
    n_heads=4,
    n_kv_heads=4,
    d_ff=0,                      # xLSTM blocks carry their own projections
    vocab_size=50_304,
    block_pattern=("mlstm",) * 7 + ("slstm",),
    ffn_kind="none",
    pos_embedding="none",
    supports_long_context=True,  # O(1) recurrent state per layer
)
