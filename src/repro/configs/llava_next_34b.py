"""llava-next-34b [vlm] — 60L d_model=7168 56H (GQA kv=8) d_ff=20480
vocab=64000; anyres tiling [hf:llava-hf/llava-v1.6].
Frontend is a STUB per assignment: input_specs() provides precomputed
patch embeddings (one 576-patch tile) prepended to the token stream; the
Yi-34B-style text backbone is exact."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="llava-next-34b",
    family="vlm",
    n_layers=60,
    d_model=7168,
    n_heads=56,
    n_kv_heads=8,
    d_ff=20480,
    vocab_size=64_000,
    block_pattern=("attn",),
    ffn_kind="swiglu",
    rope_base=5_000_000.0,
    frontend="vision_stub",
    n_patches=576,
)
