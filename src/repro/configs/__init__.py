"""Per-architecture configs (assignment pool) + registry."""

from repro.configs.base import MLAConfig, ModelConfig, MoEConfig, count_params, active_params
from repro.configs.registry import ARCH_IDS, SHAPES, ShapeSpec, all_configs, cells, get_config

__all__ = [
    "ModelConfig", "MoEConfig", "MLAConfig", "count_params", "active_params",
    "ARCH_IDS", "SHAPES", "ShapeSpec", "all_configs", "cells", "get_config",
]
