"""musicgen-medium [audio] — 48L d_model=1536 24H (kv=24) d_ff=6144
vocab=2048; decoder-only over EnCodec tokens [arXiv:2306.05284].
Frontend is a STUB per assignment: input_specs() provides precomputed
frame embeddings; the backbone (layernorm + gelu MLP + sinusoidal
positions, MusicGen-style) is exact."""

from repro.configs.base import ModelConfig

CONFIG = ModelConfig(
    name="musicgen-medium",
    family="audio",
    n_layers=48,
    d_model=1536,
    n_heads=24,
    n_kv_heads=24,
    d_ff=6144,
    vocab_size=2048,
    block_pattern=("attn",),
    ffn_kind="gelu",
    norm_style="layernorm",
    pos_embedding="sinusoidal",
    frontend="audio_stub",
)
