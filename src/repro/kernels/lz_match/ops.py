"""Public wrapper: LZ77 match-candidate stage on device.

``lz_candidates_device(buf, plen)`` produces the exact candidate
contract of ``repro.core.lz77._candidates_np`` — ``(ok, cand, mlen)``
over the ``len(buf) - 3`` positions holding a full 4-gram — so the
host-side greedy selection + sequence emit (``_select_emit``, shared
with the NumPy path) turns it into a byte-identical compressed stream.

Stage layout inside the one jitted function:

* gram/hash build — Pallas elementwise kernel over four shifted byte
  planes;
* head-table candidate scatter — ``lax.fori_loop`` over
  ``_SCAN_BLOCK``-byte blocks with an XLA ``scatter-max``: each block
  reads candidates *before* writing its own positions (a position never
  proposes itself), and since positions only grow, scatter-max over the
  block history equals the NumPy path's last-write-wins overwrite;
* short-period run candidates (periods 1-4) as shifted compares;
* dense batched 8-gram XOR extension — Pallas kernel over
  ``_EXT_ROUNDS`` gram planes gathered from the same u32 array
  (``v[g], v[g+4]``).

Equivalence note: the NumPy path marks some positions *lazy* (negative
``mlen``) that the dense device extension resolves exactly — its
run-dominance early-break keeps survivor sets dynamic, which a fixed
device schedule has no reason to copy.  That is output-invariant: lazy
markers resolve to the same exact length at selection time, so the
device lazy set being a subset of the NumPy lazy set still yields
identical bytes.  ``ok`` and ``cand`` match the NumPy stage exactly.

Payload bytes are padded to 1/8-octave size buckets (min 16 KiB) so
recompiles stay logarithmic in payload size; padded positions are
masked out of ``ok`` and scattered only after every real read in their
block.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.lz_match.kernel import (gram_hash_kernel,
                                           match_extend_kernel)

_MIN_MATCH = 4
_WINDOW = 0xFFFF
_HASH_BITS = 20
_SCAN_BLOCK = 1024
_EXT_ROUNDS = 3
_PAD_MIN = 16384   # must be a multiple of _SCAN_BLOCK and the kernel block


def _interpret_default(interpret: Optional[bool]) -> bool:
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


def _bucket(n: int) -> int:
    """Pad target: next multiple of an eighth of the enclosing power of
    two (>= _PAD_MIN) — bounds both pad waste (<12.5%) and the number of
    distinct jit compilations (<= 8 per octave)."""
    q = max(_PAD_MIN, 1 << max(int(n).bit_length() - 3, 0))
    return max(-(-n // q) * q, _PAD_MIN)


@partial(jax.jit, static_argnames=("p", "interpret"))
def _candidate_stage(b: jnp.ndarray, n: jnp.ndarray, plen: jnp.ndarray,
                     p: int, interpret: bool):
    zero = jnp.zeros(3, jnp.uint8)
    b1 = jnp.concatenate([b[1:], zero[:1]])
    b2 = jnp.concatenate([b[2:], zero[:2]])
    b3 = jnp.concatenate([b[3:], zero[:3]])
    v, h = gram_hash_kernel(b, b1, b2, b3, hash_bits=_HASH_BITS,
                            interpret=interpret)
    idx = jnp.arange(p, dtype=jnp.int32)
    nv = (n - 3).astype(jnp.int32)

    # head-table scatter, block by block (reads before writes per block;
    # positions past nv land in trailing blocks, after every real read)
    def blk(k, carry):
        head, cand = carry
        a = k * _SCAN_BLOCK
        hb = jax.lax.dynamic_slice(h, (a,), (_SCAN_BLOCK,))
        ib = a + jnp.arange(_SCAN_BLOCK, dtype=jnp.int32)
        cand = jax.lax.dynamic_update_slice(cand, head[hb], (a,))
        return head.at[hb].max(ib), cand

    head0 = jnp.full(1 << _HASH_BITS, -1, jnp.int32)
    _, cand = jax.lax.fori_loop(0, p // _SCAN_BLOCK, blk,
                                (head0, jnp.zeros(p, jnp.int32)))

    # short-period runs are invisible to the block scatter — catch them
    # directly; d=4 covers periods 1/2/4, then d=3 (nearer candidates
    # overwrite, matching the NumPy application order)
    for d in (4, 3):
        vs = jnp.concatenate([jnp.zeros(d, jnp.uint32), v[:-d]])
        eq = (v == vs) & (idx >= d)
        cand = jnp.where(eq, idx - d, cand)

    ok = ((cand >= 0) & (idx - cand <= _WINDOW)
          & (v[jnp.maximum(cand, 0)] == v)
          & (idx >= plen.astype(jnp.int32)) & (idx < nv))

    # dense 8-gram XOR extension planes: round r compares the grams at
    # l = MIN_MATCH + 8r via two u32 halves gathered from v
    n8 = (n - 7).astype(jnp.int32)
    dlo, dhi, inb = [], [], []
    top = p - 1
    for r in range(_EXT_ROUNDS):
        l = _MIN_MATCH + 8 * r
        g = idx + l
        gc = cand + l
        dlo.append(v[jnp.clip(g, 0, top)] ^ v[jnp.clip(gc, 0, top)])
        dhi.append(v[jnp.clip(g + 4, 0, top)] ^ v[jnp.clip(gc + 4, 0, top)])
        inb.append((g < n8).astype(jnp.int32))
    mlen = match_extend_kernel(
        jnp.stack(dlo), jnp.stack(dhi), jnp.stack(inb),
        ok.astype(jnp.int32), min_match=_MIN_MATCH, interpret=interpret)
    return ok, cand, mlen


def lz_candidates_device(
        buf: bytes, plen: int, interpret: Optional[bool] = None,
) -> Tuple[np.ndarray, np.ndarray, np.ndarray]:
    """Device counterpart of ``lz77._candidates_np``: (ok bool[nv],
    cand intp[nv], mlen int64[nv]) for the full window+payload buffer."""
    interpret = _interpret_default(interpret)
    n = len(buf)
    nv = n - 3
    if nv <= 0:
        return (np.zeros(max(nv, 0), bool), np.zeros(max(nv, 0), np.intp),
                np.zeros(max(nv, 0), np.int64))
    p = _bucket(n)
    padded = np.zeros(p, np.uint8)
    padded[:n] = np.frombuffer(buf, np.uint8)
    ok, cand, mlen = _candidate_stage(
        jnp.asarray(padded), jnp.int32(n), jnp.int32(plen), p, interpret)
    return (np.asarray(ok[:nv]), np.asarray(cand[:nv]).astype(np.intp),
            np.asarray(mlen[:nv]).astype(np.int64))
