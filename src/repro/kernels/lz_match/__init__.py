from repro.kernels.lz_match.ops import lz_candidates_device
from repro.kernels.lz_match.ref import lz_candidates_ref

__all__ = ["lz_candidates_device", "lz_candidates_ref"]
