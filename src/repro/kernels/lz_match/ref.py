"""Oracle for the device LZ77 match finder.

The parity reference is the NumPy candidate stage of
``repro.core.lz77`` — whose selection/emit output is in turn held
byte-identical to the pure-Python scalar parse's wire format by the
core codec tests — so the oracle chain bottoms out at the original
scalar loop, matching the flash_attention/histogram/token_pack
convention of importing the reference from the kernel package.

``mlen`` equivalence is *up to lazy markers*: the NumPy stage may mark
positions lazy (negative) that the dense device extension resolves
exactly; both resolve to the same length at selection time, so compare
``ok``/``cand`` exactly and final compressed bytes for the rest.
"""

from __future__ import annotations

from typing import Tuple

import numpy as np

from repro.core.lz77 import _candidates_np


def lz_candidates_ref(buf: bytes, plen: int) -> Tuple[np.ndarray,
                                                      np.ndarray,
                                                      np.ndarray]:
    return _candidates_np(buf, plen, len(buf))
