"""Pallas TPU kernels for the LZ77 match finder (repro-lz hot path).

The NumPy fast path of ``repro.core.lz77`` already expresses match
finding as array passes; the two dense, regular passes move into Pallas
kernels here, and the irregular one (the hashed head-table scatter) runs
as a jitted XLA scatter-max loop in ops.py — scatter is accelerator-
native in XLA, while a 2^20-bucket one-hot matmul inside a kernel is
not.

* ``gram_hash_kernel`` — byte stream -> per-position little-endian
  4-gram u32 (``v[i]`` is also the low half of the 8-gram at ``i``, so
  the extension stage gathers from the same array) and its
  multiplicative hash.  Elementwise over four shifted byte planes
  (the shifts are free XLA slices), the same thin-kernel split the
  token-pack byte-split kernel uses.
* ``match_extend_kernel`` — batched 8-gram XOR match extension + the
  per-position length reduction: given XOR'd gram planes for
  ``_EXT_ROUNDS`` rounds, a branch-free state machine accumulates the
  exact match length (trailing-zero-byte count of the first mismatching
  gram) and flags cap survivors / out-of-room positions *lazy*
  (negative length), which the host's greedy selection resolves by
  memcmp — the identical contract the NumPy path hands it.

Greedy sequence selection and emit stay on the host: selection is an
inherently serial jump loop, and keeping it shared between the NumPy
and device paths is what freezes the wire format.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_M = 4096
_HASH_MUL = 2654435761


def _gram_hash_kernel(b0_ref, b1_ref, b2_ref, b3_ref, v_ref, h_ref, *,
                      hash_bits: int):
    b0 = b0_ref[...].astype(jnp.uint32)
    b1 = b1_ref[...].astype(jnp.uint32)
    b2 = b2_ref[...].astype(jnp.uint32)
    b3 = b3_ref[...].astype(jnp.uint32)
    v = b0 | (b1 << jnp.uint32(8)) | (b2 << jnp.uint32(16)) \
        | (b3 << jnp.uint32(24))
    v_ref[...] = v
    h_ref[...] = ((v * jnp.uint32(_HASH_MUL))
                  >> jnp.uint32(32 - hash_bits)).astype(jnp.int32)


def gram_hash_kernel(b0, b1, b2, b3, *, hash_bits: int,
                     block_m: int = DEFAULT_BLOCK_M,
                     interpret: bool = False):
    """Four shifted byte planes [M] u8 -> (v [M] u32, h [M] i32)."""
    m = b0.shape[0]
    block_m = min(block_m, m)
    if m % block_m:
        raise ValueError("pad M to a block multiple upstream")
    grid = (m // block_m,)
    return pl.pallas_call(
        functools.partial(_gram_hash_kernel, hash_bits=hash_bits),
        grid=grid,
        in_specs=[pl.BlockSpec((block_m,), lambda i: (i,))] * 4,
        out_specs=[pl.BlockSpec((block_m,), lambda i: (i,))] * 2,
        out_shape=[jax.ShapeDtypeStruct((m,), jnp.uint32),
                   jax.ShapeDtypeStruct((m,), jnp.int32)],
        interpret=interpret,
    )(b0, b1, b2, b3)


def _tz_bytes(d: jnp.ndarray) -> jnp.ndarray:
    """Trailing-zero-byte count of a u32 (4 when d == 0)."""
    z = jnp.int32(0)
    b0 = (d & jnp.uint32(0xFF)) != 0
    b1 = (d & jnp.uint32(0xFF00)) != 0
    b2 = (d & jnp.uint32(0xFF0000)) != 0
    b3 = (d & jnp.uint32(0xFF000000)) != 0
    return jnp.where(b0, z, jnp.where(b1, 1, jnp.where(b2, 2,
                     jnp.where(b3, 3, 4)))).astype(jnp.int32)


def _match_extend_kernel(dlo_ref, dhi_ref, inb_ref, ok_ref, mlen_ref, *,
                         rounds: int, min_match: int):
    dlo = dlo_ref[...]                       # [rounds, bm] u32
    dhi = dhi_ref[...]
    inb = inb_ref[...]                       # [rounds, bm] i32 (1 = gram fits)
    ok = ok_ref[...] != 0                    # [bm]
    m = jnp.full(ok.shape, min_match, jnp.int32)
    # state: 0 = still matching, 1 = exact length found, 2 = lazy (cap
    # survivor or ran out of gram room — host memcmp resolves it)
    state = jnp.zeros(ok.shape, jnp.int32)
    for r in range(rounds):
        running = state == 0
        oob = running & (inb[r] == 0)
        state = jnp.where(oob, 2, state)
        running = state == 0
        full = (dlo[r] | dhi[r]) == 0
        mism = running & ~full
        extra = jnp.where(dlo[r] != 0, _tz_bytes(dlo[r]),
                          4 + _tz_bytes(dhi[r]))
        m = jnp.where(mism, m + extra, m)
        state = jnp.where(mism, 1, state)
        m = jnp.where(state == 0, m + 8, m)
    state = jnp.where(state == 0, 2, state)  # cap survivors go lazy
    mlen_ref[...] = jnp.where(ok, jnp.where(state == 2, -m, m), 0)


def match_extend_kernel(dlo, dhi, inb, ok, *, min_match: int = 4,
                        block_m: int = DEFAULT_BLOCK_M,
                        interpret: bool = False):
    """dlo/dhi/inb: [rounds, M]; ok: [M] i32 -> mlen [M] i32 (negative =
    lazy, 0 = no candidate)."""
    rounds, m = dlo.shape
    block_m = min(block_m, m)
    if m % block_m:
        raise ValueError("pad M to a block multiple upstream")
    grid = (m // block_m,)
    plane = pl.BlockSpec((rounds, block_m), lambda i: (0, i))
    lane = pl.BlockSpec((block_m,), lambda i: (i,))
    return pl.pallas_call(
        functools.partial(_match_extend_kernel, rounds=rounds,
                          min_match=min_match),
        grid=grid,
        in_specs=[plane, plane, plane, lane],
        out_specs=lane,
        out_shape=jax.ShapeDtypeStruct((m,), jnp.int32),
        interpret=interpret,
    )(dlo, dhi, inb, ok)
