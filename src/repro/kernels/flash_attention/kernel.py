"""Pallas TPU flash-attention forward kernel.

Blockwise online-softmax attention with causal masking, GQA head mapping,
sliding-window masking and gemma2-style score soft-capping — the same
semantics as the pure-jnp fallback in repro.models.attention (oracle in
ref.py).

Grid: (batch, q_heads, n_q_blocks, n_kv_blocks); the kv-block axis is the
minor-most (sequential on TPU) so VMEM scratch carries the online-softmax
state (m, l, acc) across kv steps.  BlockSpecs keep one (block_q, head_dim)
q tile and one (block_kv, head_dim) k/v tile in VMEM at a time; with the
default 512x512 blocks and head_dim 128 that is ~0.8 MB of operand VMEM
plus ~0.5 MB scratch — comfortably inside a v5e core's 16 MB while leaving
room for double-buffered pipelining.  MXU alignment: block sizes are
multiples of 128 and head_dim is 128/256 for every assigned arch.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
import numpy as np
from jax.experimental import pallas as pl
from jax.experimental.pallas import tpu as pltpu

DEFAULT_BLOCK_Q = 512
DEFAULT_BLOCK_KV = 512
_NEG = -0.7 * float(np.finfo(np.float32).max)


def _flash_kernel(
    q_ref, k_ref, v_ref, o_ref,        # blocks
    acc_ref, m_ref, l_ref,             # VMEM scratch
    *, scale: float, causal: bool, window: int, softcap: float,
    block_q: int, block_kv: int, n_kv_blocks: int, q_offset: int, kv_len: int,
):
    iq = pl.program_id(2)
    ikv = pl.program_id(3)

    @pl.when(ikv == 0)
    def _init():
        acc_ref[...] = jnp.zeros_like(acc_ref)
        m_ref[...] = jnp.full_like(m_ref, _NEG)
        l_ref[...] = jnp.zeros_like(l_ref)

    q = q_ref[0, 0].astype(jnp.float32)          # [bq, hd]
    k = k_ref[0, 0].astype(jnp.float32)          # [bkv, hd]
    v = v_ref[0, 0].astype(jnp.float32)

    s = jax.lax.dot_general(
        q, k, (((1,), (1,)), ((), ())), preferred_element_type=jnp.float32
    ) * scale                                     # [bq, bkv]
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)

    q_pos = (q_offset + iq * block_q
             + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 0))
    k_pos = ikv * block_kv + jax.lax.broadcasted_iota(jnp.int32, (block_q, block_kv), 1)
    mask = k_pos < kv_len  # drop kv padding columns
    if causal:
        mask &= k_pos <= q_pos
    if window > 0:
        mask &= q_pos - k_pos < window
    s = jnp.where(mask, s, _NEG)

    m_prev = m_ref[:, :1]                         # [bq, 1]
    l_prev = l_ref[:, :1]
    m_cur = jnp.max(s, axis=1, keepdims=True)     # [bq, 1]
    m_new = jnp.maximum(m_prev, m_cur)
    p = jnp.exp(s - m_new)                        # [bq, bkv]
    corr = jnp.exp(m_prev - m_new)                # [bq, 1]
    l_new = l_prev * corr + jnp.sum(p, axis=1, keepdims=True)
    acc_ref[...] = acc_ref[...] * corr + jax.lax.dot_general(
        p, v, (((1,), (0,)), ((), ())), preferred_element_type=jnp.float32)
    m_ref[...] = jnp.broadcast_to(m_new, m_ref.shape)
    l_ref[...] = jnp.broadcast_to(l_new, l_ref.shape)

    @pl.when(ikv == n_kv_blocks - 1)
    def _finalize():
        l = l_ref[:, :1]
        o_ref[0, 0] = (acc_ref[...] / jnp.maximum(l, 1e-37)).astype(o_ref.dtype)


def flash_attention_fwd(
    q: jnp.ndarray,   # [B, Hq, Sq, hd]
    k: jnp.ndarray,   # [B, Hkv, Skv, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    q_offset: int = 0,
    kv_len: int | None = None,
    interpret: bool = False,
) -> jnp.ndarray:
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    group = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    kv_len = Skv if kv_len is None else kv_len
    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    if Sq % block_q or Skv % block_kv:
        raise ValueError(f"seq lens ({Sq},{Skv}) must divide blocks "
                         f"({block_q},{block_kv}); pad upstream")
    nq, nkv = Sq // block_q, Skv // block_kv
    grid = (B, Hq, nq, nkv)

    kernel = functools.partial(
        _flash_kernel, scale=scale, causal=causal, window=window,
        softcap=softcap, block_q=block_q, block_kv=block_kv, n_kv_blocks=nkv,
        q_offset=q_offset, kv_len=kv_len,
    )
    return pl.pallas_call(
        kernel,
        grid=grid,
        in_specs=[
            pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
            pl.BlockSpec((1, 1, block_kv, hd), lambda b, h, i, j, g=group: (b, h // g, j, 0)),
        ],
        out_specs=pl.BlockSpec((1, 1, block_q, hd), lambda b, h, i, j: (b, h, i, 0)),
        out_shape=jax.ShapeDtypeStruct((B, Hq, Sq, hd), q.dtype),
        scratch_shapes=[
            pltpu.VMEM((block_q, hd), jnp.float32),   # acc
            pltpu.VMEM((block_q, 128), jnp.float32),  # m (lane-broadcast)
            pltpu.VMEM((block_q, 128), jnp.float32),  # l
        ],
        interpret=interpret,
    )(q, k, v)
