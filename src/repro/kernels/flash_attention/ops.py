"""Jit'd public wrapper for the flash-attention kernel.

`flash_attention(...)` takes the model-layout tensors [B, S, H, hd],
transposes to the kernel layout, pads sequence to block multiples and
dispatches to the Pallas kernel (TPU) or interpret mode (CPU tests).
"""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.flash_attention.kernel import (
    DEFAULT_BLOCK_KV,
    DEFAULT_BLOCK_Q,
    flash_attention_fwd,
)


@partial(jax.jit, static_argnames=(
    "causal", "window", "softcap", "scale", "block_q", "block_kv",
    "q_offset", "interpret"))
def flash_attention(
    q: jnp.ndarray,  # [B, Sq, Hq, hd]
    k: jnp.ndarray,  # [B, Skv, Hkv, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    block_q: int = DEFAULT_BLOCK_Q,
    block_kv: int = DEFAULT_BLOCK_KV,
    q_offset: int = 0,
    interpret: bool = False,
) -> jnp.ndarray:
    Sq, Skv = q.shape[1], k.shape[1]
    bq = min(block_q, max(Sq, 16))
    bkv = min(block_kv, max(Skv, 16))
    pad_q = (-Sq) % bq
    pad_kv = (-Skv) % bkv
    qt = jnp.moveaxis(q, 1, 2)
    kt = jnp.moveaxis(k, 1, 2)
    vt = jnp.moveaxis(v, 1, 2)
    if pad_q:
        qt = jnp.pad(qt, ((0, 0), (0, 0), (0, pad_q), (0, 0)))
    if pad_kv:
        # padded kv columns are dropped inside the kernel via kv_len mask
        kt = jnp.pad(kt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
        vt = jnp.pad(vt, ((0, 0), (0, 0), (0, pad_kv), (0, 0)))
    out = flash_attention_fwd(
        qt, kt, vt, causal=causal, window=window, softcap=softcap,
        scale=scale, block_q=bq, block_kv=bkv, q_offset=q_offset,
        kv_len=Skv, interpret=interpret)
    out = out[:, :, :Sq]
    return jnp.moveaxis(out, 2, 1)
