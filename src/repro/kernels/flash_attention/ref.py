"""Pure-jnp oracle for the flash-attention kernel: naive materialized
softmax attention with identical masking/softcap semantics."""

from __future__ import annotations

import jax
import jax.numpy as jnp
import numpy as np


def attention_ref(
    q: jnp.ndarray,   # [B, Hq, Sq, hd]
    k: jnp.ndarray,   # [B, Hkv, Skv, hd]
    v: jnp.ndarray,
    *,
    causal: bool = True,
    window: int = 0,
    softcap: float = 0.0,
    scale: float | None = None,
    q_offset: int = 0,
) -> jnp.ndarray:
    B, Hq, Sq, hd = q.shape
    Hkv, Skv = k.shape[1], k.shape[2]
    g = Hq // Hkv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    qf = q.astype(jnp.float32).reshape(B, Hkv, g, Sq, hd)
    kf = k.astype(jnp.float32)
    vf = v.astype(jnp.float32)
    s = jnp.einsum("bngqh,bnkh->bngqk", qf, kf) * scale
    if softcap > 0.0:
        s = softcap * jnp.tanh(s / softcap)
    q_pos = jnp.arange(Sq) + q_offset
    k_pos = jnp.arange(Skv)
    mask = jnp.ones((Sq, Skv), bool)
    if causal:
        mask &= k_pos[None, :] <= q_pos[:, None]
    if window > 0:
        mask &= q_pos[:, None] - k_pos[None, :] < window
    s = jnp.where(mask[None, None, None], s, jnp.finfo(jnp.float32).min * 0.5)
    p = jax.nn.softmax(s, axis=-1)
    o = jnp.einsum("bngqk,bnkh->bngqh", p, vf)
    return o.reshape(B, Hq, Sq, v.shape[-1]).astype(q.dtype)
