"""Pallas TPU kernel for LoPace binary packing (paper §3.3.3).

The hot loop of the token method is pure data movement: split each token
id into little-endian bytes (2 for uint16 mode, 4 for uint32 mode) —
strictly memory-bound, so the kernel's job is to stream blocks through
VMEM at line rate with byte extraction on the VPU.  Output layout is
[N, k] uint8 whose row-major view *is* the packed little-endian stream.

The delta-zigzag variant fuses LoPace's beyond-paper delta packing
(DESIGN.md §7): given x and x_prev (shifted by the wrapper), it emits
zigzag(x - x_prev) bytes in the same layout.

Block shape (block_n, 128-aligned byte lanes): ids arrive as [block_n]
int32 tiles; per-element shifts/masks vectorize on 8x128 VREGs.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 2048


def _pack_kernel(x_ref, o_ref, *, width: int):
    x = x_ref[...].astype(jnp.uint32)                   # [bn]
    parts = [(x >> jnp.uint32(8 * i)) & jnp.uint32(0xFF) for i in range(width)]
    o_ref[...] = jnp.stack(parts, axis=-1).astype(jnp.uint8)  # [bn, width]


def _delta_zigzag_kernel(x_ref, xp_ref, o_ref, *, width: int):
    x = x_ref[...].astype(jnp.int32)
    xp = xp_ref[...].astype(jnp.int32)
    d = x - xp                                          # token ids < 2**31
    z = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)       # zigzag to unsigned
    parts = [(z >> jnp.uint32(8 * i)) & jnp.uint32(0xFF) for i in range(width)]
    o_ref[...] = jnp.stack(parts, axis=-1).astype(jnp.uint8)


def pack_tokens_kernel(ids: jnp.ndarray, *, width: int,
                       block_n: int = DEFAULT_BLOCK_N,
                       interpret: bool = False) -> jnp.ndarray:
    """ids: [N] int32/uint32 -> [N, width] uint8 little-endian bytes."""
    n = ids.shape[0]
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError("pad N to a block multiple upstream")
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_pack_kernel, width=width),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), jnp.uint8),
        interpret=interpret,
    )(ids)


def delta_zigzag_kernel(ids: jnp.ndarray, prev: jnp.ndarray, *, width: int = 4,
                        block_n: int = DEFAULT_BLOCK_N,
                        interpret: bool = False) -> jnp.ndarray:
    n = ids.shape[0]
    block_n = min(block_n, n)
    if n % block_n:
        raise ValueError("pad N to a block multiple upstream")
    grid = (n // block_n,)
    return pl.pallas_call(
        functools.partial(_delta_zigzag_kernel, width=width),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda i: (i,)),
                  pl.BlockSpec((block_n,), lambda i: (i,))],
        out_specs=pl.BlockSpec((block_n, width), lambda i: (i, 0)),
        out_shape=jax.ShapeDtypeStruct((n, width), jnp.uint8),
        interpret=interpret,
    )(ids, prev)
