from repro.kernels.token_pack.ops import (delta_zigzag_device,
                                          pack_fixed_batch_device,
                                          pack_tokens_device,
                                          unpack_fixed_device)
from repro.kernels.token_pack.ref import delta_zigzag_ref, pack_ref

__all__ = ["pack_tokens_device", "pack_fixed_batch_device",
           "unpack_fixed_device", "delta_zigzag_device", "pack_ref",
           "delta_zigzag_ref"]
