"""Public wrapper: batch token packing on device.

`pack_tokens_device(ids)` reproduces LoPace's fixed-width packing decision
(Eq. 7: uint16 iff max(ids) <= 65535) and returns (format_byte, bytes) —
bit-identical to repro.core.packing.pack_fixed, validated in tests.
"""

from __future__ import annotations

from functools import partial
from typing import List, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.token_pack.kernel import delta_zigzag_kernel, pack_tokens_kernel

_BLOCK = 2048


@partial(jax.jit, static_argnames=("width", "interpret"))
def _pack_padded(ids: jnp.ndarray, width: int, interpret: bool) -> jnp.ndarray:
    n = ids.shape[0]
    pad = (-n) % min(_BLOCK, max(n, 1))
    idsp = jnp.pad(ids, (0, pad))
    return pack_tokens_kernel(idsp.astype(jnp.int32), width=width,
                              block_n=min(_BLOCK, idsp.shape[0]),
                              interpret=interpret)


def pack_tokens_device(ids, interpret: bool = True) -> Tuple[int, bytes]:
    """Returns (format_byte, packed_bytes) per paper Algorithm 1 lines 2-8."""
    ids = np.asarray(ids, dtype=np.uint32)
    if ids.size == 0:
        return 0x00, b""
    width = 2 if int(ids.max()) <= 0xFFFF else 4
    out = _pack_padded(jnp.asarray(ids, jnp.int32), width, interpret)
    return (0x00 if width == 2 else 0x01), np.asarray(out)[: ids.size].tobytes()


def pack_fixed_batch_device(ids_list, interpret: bool = True) -> List[bytes]:
    """Batch fixed-width packing: the vectorized device path of the codec layer.

    Streams are grouped by packing width (Eq. 7 decides per stream), each
    group is concatenated into one [N] id vector, streamed through the
    Pallas byte-split kernel in a single launch, and the [N, k] byte plane
    is sliced back per stream.  Bit-identical to
    ``repro.core.packing.pack_fixed`` applied per stream (format byte
    included), which the kernel parity tests assert.
    """
    arrs = [np.asarray(ids, dtype=np.uint32) for ids in ids_list]
    out: List[bytes] = [b""] * len(arrs)
    groups: dict = {2: [], 4: []}
    for i, a in enumerate(arrs):
        if a.size == 0:
            out[i] = bytes([0x00])  # empty stream: u16 header, no body
            continue
        groups[2 if int(a.max()) <= 0xFFFF else 4].append(i)
    for width, members in groups.items():
        if not members:
            continue
        fmt = 0x00 if width == 2 else 0x01
        concat = np.concatenate([arrs[i] for i in members])
        plane = np.asarray(
            _pack_padded(jnp.asarray(concat, jnp.int32), width, interpret)
        )[: concat.size]
        offsets = np.cumsum([0] + [arrs[i].size for i in members])
        for j, i in enumerate(members):
            out[i] = bytes([fmt]) + plane[offsets[j]:offsets[j + 1]].tobytes()
    return out


def unpack_fixed_device(payload) -> jnp.ndarray:
    """Inverse of the fixed-width packers, landing the ids **on device**:
    a self-describing payload (format byte + LE body) -> uint32 jnp
    array.  Accepts host bytes or a device-resident uint8 array (e.g.
    straight from ``rans_decompress_to_device``), so the serve path's
    decompress-to-tokens never bounces the body through host memory.

    Only the fixed formats (0x00 u16 / 0x01 u32) are byte-combinable on
    device; varint payloads raise and the caller falls back to the host
    unpacker."""
    if isinstance(payload, (bytes, bytearray, memoryview)):
        payload = jnp.asarray(np.frombuffer(payload, np.uint8))
    fmt = int(payload[0])
    body = payload[1:].astype(jnp.uint32)
    if fmt == 0x00:
        return body[0::2] | (body[1::2] << jnp.uint32(8))
    if fmt == 0x01:
        return (body[0::4] | (body[1::4] << jnp.uint32(8))
                | (body[2::4] << jnp.uint32(16))
                | (body[3::4] << jnp.uint32(24)))
    raise ValueError(f"format {fmt:#x} has no device unpacker")


@partial(jax.jit, static_argnames=("interpret",))
def delta_zigzag_device(ids: jnp.ndarray, interpret: bool = True) -> jnp.ndarray:
    """[N] ids -> [N,4] zigzag-delta bytes (feeder for the rANS stage)."""
    prev = jnp.concatenate([jnp.zeros(1, ids.dtype), ids[:-1]])
    n = ids.shape[0]
    pad = (-n) % min(_BLOCK, max(n, 1))
    idsp = jnp.pad(ids, (0, pad))
    prevp = jnp.pad(prev, (0, pad))
    out = delta_zigzag_kernel(idsp.astype(jnp.int32), prevp.astype(jnp.int32),
                              width=4, block_n=min(_BLOCK, idsp.shape[0]),
                              interpret=interpret)
    return out[:n]
