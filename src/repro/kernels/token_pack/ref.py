"""Pure-jnp oracle for the token-pack kernels."""

from __future__ import annotations

import jax.numpy as jnp


def pack_ref(ids: jnp.ndarray, width: int) -> jnp.ndarray:
    x = ids.astype(jnp.uint32)
    parts = [(x >> jnp.uint32(8 * i)) & jnp.uint32(0xFF) for i in range(width)]
    return jnp.stack(parts, axis=-1).astype(jnp.uint8)


def delta_zigzag_ref(ids: jnp.ndarray, prev: jnp.ndarray, width: int = 4) -> jnp.ndarray:
    d = ids.astype(jnp.int32) - prev.astype(jnp.int32)
    z = ((d << 1) ^ (d >> 31)).astype(jnp.uint32)
    parts = [(z >> jnp.uint32(8 * i)) & jnp.uint32(0xFF) for i in range(width)]
    return jnp.stack(parts, axis=-1).astype(jnp.uint8)
