"""Oracle for the lane-parallel rANS kernels.

The parity reference is the NumPy interleaved coder itself
(``repro.core.rans_np``) — lane 1 of which is bit-identical to the
scalar seed coder, so the chain of oracles bottoms out at the original
pure-Python loop.  These wrappers exist so the kernel test suite imports
its oracle from the kernel package like every other kernel
(flash_attention/histogram/token_pack convention).
"""

from __future__ import annotations

import numpy as np

from repro.core.rans_np import (rans_decode_interleaved,
                                rans_encode_interleaved)


def encode_lanes_ref(symbols: np.ndarray, freqs: np.ndarray, lanes: int,
                     prob_bits: int):
    """(words u16 forward order, final states u32 [lanes])."""
    return rans_encode_interleaved(symbols, freqs, lanes, prob_bits)


def decode_lanes_ref(words: np.ndarray, states: np.ndarray, n: int,
                     freqs: np.ndarray, lanes: int,
                     prob_bits: int) -> np.ndarray:
    return rans_decode_interleaved(words, states, n, freqs, lanes, prob_bits)
