"""Pallas TPU kernels: lane-parallel interleaved rANS encode/decode.

The interleaved N-lane coder (``repro.core.rans_np``) was laid out for
exactly this port: N independent 32-bit rANS states advance in lockstep
over a round-robin symbol split, every step is a handful of elementwise
uint32 ops over the N states, and 16-bit renormalization emits **at most
one** word per lane per step — so the data-dependent part of the stream
reduces to a dense [T, lanes] word/mask pair that the host compacts into
the shared word stream (encode) or a prefix-sum word-consumption schedule
(decode).

Both kernels keep the *step* axis sequential (rANS states chain through
every symbol) and vectorize across lanes, mirroring the NumPy lockstep
loop one-to-one so the produced stream is bit-identical:

* encode walks step blocks in **reverse** grid order (rANS encodes
  back-to-front), carrying the lane states in an output ref whose block
  index_map is constant — the classic Pallas sequential-reduction
  pattern the histogram kernel uses;
* decode walks forward, carrying lane states plus a scalar word cursor;
  per step it gathers the k needy lanes' renorm words at
  ``cursor + exclusive_cumsum(need)`` — ascending-lane order, exactly
  the NumPy consumption order.

All state arithmetic is uint32: a 32-bit state with 16-bit renorm stays
below 2**32, and ``x_max = f << (32 - prob_bits)`` fits iff every
frequency is below ``2**prob_bits`` — the single-symbol-alphabet edge
(f == 2**prob_bits) is routed to the NumPy uint64 path by the dispatch
layer, never to this kernel.

Step blocks are padded to ``block_t`` multiples; padded rows are masked
out of the state evolution (and emit nothing), so padding never touches
the stream.
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_T = 256   # lockstep steps per grid block


def _encode_kernel(x0_ref, fs_ref, cs_ref, words_ref, emit_ref, state_ref, *,
                   block_t: int, total_t: int, prob_bits: int):
    i = pl.program_id(0)
    nb = pl.num_programs(0)

    @pl.when(i == 0)
    def _init():
        state_ref[...] = x0_ref[...]

    x = state_ref[...]                       # [lanes] u32
    fs = fs_ref[...]                         # [bt, lanes] u32
    cs = cs_ref[...]
    base = (nb - 1 - i) * block_t            # reverse block order
    shift = jnp.uint32(32 - prob_bits)
    pb = jnp.uint32(prob_bits)
    lo16 = jnp.uint32(0xFFFF)
    sixteen = jnp.uint32(16)

    def row(t, x):
        r = block_t - 1 - t                  # reverse rows within the block
        valid = base + r < total_t
        f = fs[r]
        c = cs[r]
        em = (x >= (f << shift)) & valid
        words_ref[pl.ds(r, 1), :] = (x & lo16)[None, :]
        emit_ref[pl.ds(r, 1), :] = em.astype(jnp.int32)[None, :]
        x2 = jnp.where(em, x >> sixteen, x)
        xn = ((x2 // f) << pb) + (x2 % f) + c
        return jnp.where(valid, xn, x)

    state_ref[...] = jax.lax.fori_loop(0, block_t, row, x)


def rans_encode_lanes_kernel(fs: jnp.ndarray, cs: jnp.ndarray,
                             x0: jnp.ndarray, *, total_t: int,
                             prob_bits: int,
                             block_t: int = DEFAULT_BLOCK_T,
                             interpret: bool = False):
    """fs/cs: [Tp, lanes] u32 per-step (freq, cumfreq), Tp a block_t
    multiple covering total_t real steps; x0: [lanes] u32 initial states
    (the host runs the partial tail step first — rANS encodes it first).

    Returns (words [Tp, lanes] u32 dense, emit [Tp, lanes] i32 mask,
    states [lanes] u32).  Forward stream = words[emit] in row-major
    order; padded rows never emit.
    """
    tp, lanes = fs.shape
    if tp % block_t:
        raise ValueError("pad T to a block multiple upstream")
    nb = tp // block_t
    kernel = functools.partial(_encode_kernel, block_t=block_t,
                               total_t=total_t, prob_bits=prob_bits)
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((block_t, lanes), lambda i, nb=nb: (nb - 1 - i, 0)),
            pl.BlockSpec((block_t, lanes), lambda i, nb=nb: (nb - 1 - i, 0)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, lanes), lambda i, nb=nb: (nb - 1 - i, 0)),
            pl.BlockSpec((block_t, lanes), lambda i, nb=nb: (nb - 1 - i, 0)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, lanes), jnp.uint32),
            jax.ShapeDtypeStruct((tp, lanes), jnp.int32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
        ],
        interpret=interpret,
    )(x0, fs, cs)


def _decode_kernel(words_ref, st_ref, freq_ref, cum_ref, s2s_ref,
                   sym_ref, state_ref, wpos_ref, *,
                   block_t: int, total_t: int, prob_bits: int, n_words: int):
    i = pl.program_id(0)

    @pl.when(i == 0)
    def _init():
        state_ref[...] = st_ref[...]
        wpos_ref[0] = 0

    x = state_ref[...]                       # [lanes] u32
    wpos = wpos_ref[0]
    words = words_ref[...]                   # [Wp] u32, whole stream
    freqs = freq_ref[...]                    # [256] u32
    cum = cum_ref[...]                       # [256] u32
    s2s = s2s_ref[...]                       # [2**prob_bits] i32
    slot_mask = jnp.uint32((1 << prob_bits) - 1)
    pb = jnp.uint32(prob_bits)
    low = jnp.uint32(1 << 16)
    sixteen = jnp.uint32(16)
    base = i * block_t

    def row(r, carry):
        x, wpos = carry
        valid = base + r < total_t
        slot = x & slot_mask
        s = s2s[slot.astype(jnp.int32)]      # [lanes] gather
        sym_ref[pl.ds(r, 1), :] = s[None, :]
        xn = freqs[s] * (x >> pb) + (slot - cum[s])
        need = (xn < low) & valid
        cnt = jnp.cumsum(need.astype(jnp.int32))
        pos = wpos + cnt - need.astype(jnp.int32)   # exclusive prefix
        w = words[jnp.clip(pos, 0, n_words - 1)]
        xn = jnp.where(need, (xn << sixteen) | w, xn)
        return jnp.where(valid, xn, x), wpos + cnt[-1]

    x, wpos = jax.lax.fori_loop(0, block_t, row, (x, wpos))
    state_ref[...] = x
    wpos_ref[0] = wpos


def rans_decode_lanes_kernel(words: jnp.ndarray, states: jnp.ndarray,
                             freqs: jnp.ndarray, cum: jnp.ndarray,
                             slot2sym: jnp.ndarray, *, total_t: int,
                             prob_bits: int,
                             block_t: int = DEFAULT_BLOCK_T,
                             interpret: bool = False):
    """words: [Wp] u32 forward stream (zero-padded), states: [lanes] u32,
    freqs/cum: [256] u32, slot2sym: [2**prob_bits] i32.

    Returns (symbols [Tp, lanes] i32 — row-major flatten IS the
    round-robin interleave order, states [lanes] u32 after the full
    steps, words_consumed [1] i32).  The host runs the partial tail step
    (slot lookup only, no renorm) on the returned states.
    """
    tp = -(-total_t // block_t) * block_t if total_t else block_t
    lanes = states.shape[0]
    nb = tp // block_t
    kernel = functools.partial(_decode_kernel, block_t=block_t,
                               total_t=total_t, prob_bits=prob_bits,
                               n_words=words.shape[0])
    return pl.pallas_call(
        kernel,
        grid=(nb,),
        in_specs=[
            pl.BlockSpec((words.shape[0],), lambda i: (0,)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((256,), lambda i: (0,)),
            pl.BlockSpec((slot2sym.shape[0],), lambda i: (0,)),
        ],
        out_specs=[
            pl.BlockSpec((block_t, lanes), lambda i: (i, 0)),
            pl.BlockSpec((lanes,), lambda i: (0,)),
            pl.BlockSpec((1,), lambda i: (0,)),
        ],
        out_shape=[
            jax.ShapeDtypeStruct((tp, lanes), jnp.int32),
            jax.ShapeDtypeStruct((lanes,), jnp.uint32),
            jax.ShapeDtypeStruct((1,), jnp.int32),
        ],
        interpret=interpret,
    )(words, states, freqs, cum, slot2sym)
