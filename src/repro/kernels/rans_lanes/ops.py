"""Public wrappers: lane-parallel rANS encode/decode on device.

These drive the existing multi-lane blob layout of
``repro.core.rans_np`` — same round-robin lane split, same shared word
stream, same header — so blobs produced by either implementation decode
under the other byte-for-byte (asserted across the parity corpus in
tests/test_kernel_codec.py).

Split of labor:

* the jitted stage functions run the frequency-table gathers, the
  partial tail step (rANS encodes it first / decodes it last — one
  vector op), padding, and the Pallas lockstep kernel on device;
* the host side only compacts the dense [T, lanes] word/mask pair into
  the serialized stream (encode) and runs the underflow check (decode).
  Decode can skip the host entirely: ``to_host=False`` returns the
  symbol array still resident in device memory — the serve path's
  decompress-to-tokens feeds on this.

The dispatch layer (``rans_np.rans_compress_bytes``) never routes the
single-symbol alphabet here: ``f == 2**prob_bits`` makes
``x_max == 2**32``, which needs the NumPy coder's uint64 lanes.
"""

from __future__ import annotations

from functools import partial
from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.kernels.rans_lanes.kernel import (DEFAULT_BLOCK_T,
                                             rans_decode_lanes_kernel,
                                             rans_encode_lanes_kernel)

_WORD_PAD = 1024   # word-stream padding granularity (bounds recompiles)


def _interpret_default(interpret: Optional[bool]) -> bool:
    # compiled kernel on real accelerators; interpret mode only when the
    # device path is forced on a CPU host (tests, parity smokes)
    if interpret is None:
        return jax.default_backend() == "cpu"
    return interpret


@partial(jax.jit, static_argnames=("lanes", "prob_bits", "interpret"))
def _encode_stage(symbols: jnp.ndarray, freqs: jnp.ndarray, lanes: int,
                  prob_bits: int, interpret: bool):
    n = symbols.shape[0]
    T = n // lanes
    rem = n - T * lanes
    f32 = freqs.astype(jnp.uint32)
    cum = jnp.cumsum(f32, dtype=jnp.uint32) - f32      # exclusive prefix
    sym = symbols.astype(jnp.int32)
    fs_all = f32[sym]
    cs_all = cum[sym]
    shift = jnp.uint32(32 - prob_bits)
    pb = jnp.uint32(prob_bits)
    x0 = jnp.full((lanes,), 1 << 16, jnp.uint32)
    tail_w = jnp.zeros((lanes,), jnp.uint32)
    tail_em = jnp.zeros((lanes,), jnp.int32)
    if rem:   # tail step runs first on the encode side
        ft = fs_all[T * lanes:]
        ct = cs_all[T * lanes:]
        xa = x0[:rem]
        em = xa >= (ft << shift)
        tail_w = tail_w.at[:rem].set(xa & jnp.uint32(0xFFFF))
        tail_em = tail_em.at[:rem].set(em.astype(jnp.int32))
        xa = jnp.where(em, xa >> jnp.uint32(16), xa)
        xa = ((xa // ft) << pb) + (xa % ft) + ct
        x0 = x0.at[:rem].set(xa)
    bt = DEFAULT_BLOCK_T
    tp = max(-(-T // bt) * bt, bt)
    fs = jnp.pad(fs_all[: T * lanes].reshape(T, lanes),
                 ((0, tp - T), (0, 0)), constant_values=1)
    cs = jnp.pad(cs_all[: T * lanes].reshape(T, lanes),
                 ((0, tp - T), (0, 0)))
    words, emit, states = rans_encode_lanes_kernel(
        fs, cs, x0, total_t=T, prob_bits=prob_bits, block_t=bt,
        interpret=interpret)
    return words, emit, states, tail_w, tail_em


def rans_encode_interleaved_device(
        symbols: np.ndarray, freqs: np.ndarray, lanes: int,
        prob_bits: int, interpret: Optional[bool] = None,
) -> Tuple[np.ndarray, np.ndarray]:
    """Device counterpart of ``rans_np.rans_encode_interleaved``: returns
    (words u16 in forward/decode order, final states [lanes] u32),
    bit-identical to the NumPy coder."""
    interpret = _interpret_default(interpret)
    n = int(symbols.size)
    words_d, emit_d, states_d, tail_w, tail_em = _encode_stage(
        jnp.asarray(symbols, jnp.uint8), jnp.asarray(freqs, jnp.uint32),
        int(lanes), int(prob_bits), interpret)
    # forward stream = dense words masked in row-major (step asc, lane
    # asc) order; the tail step's words (emitted first) come last
    emit = np.asarray(emit_d, dtype=bool)
    fwd = np.asarray(words_d)[emit].astype(np.uint16)
    rem = n - (n // lanes) * lanes
    if rem:
        te = np.asarray(tail_em, dtype=bool)
        fwd = np.concatenate([fwd, np.asarray(tail_w)[te].astype(np.uint16)])
    return fwd, np.asarray(states_d, np.uint32)


@partial(jax.jit, static_argnames=("n", "lanes", "prob_bits", "interpret"))
def _decode_stage(words: jnp.ndarray, states: jnp.ndarray,
                  freqs: jnp.ndarray, n: int, lanes: int, prob_bits: int,
                  interpret: bool):
    T = n // lanes
    rem = n - T * lanes
    f32 = freqs.astype(jnp.uint32)
    cum = jnp.cumsum(f32, dtype=jnp.uint32) - f32
    s2s = jnp.repeat(jnp.arange(256, dtype=jnp.int32), f32,
                     total_repeat_length=1 << prob_bits)
    wp = max(-(-words.shape[0] // _WORD_PAD) * _WORD_PAD, _WORD_PAD)
    wpad = jnp.pad(words.astype(jnp.uint32), (0, wp - words.shape[0]))
    sym, states_f, wcnt = rans_decode_lanes_kernel(
        wpad, states.astype(jnp.uint32), f32, cum, s2s, total_t=T,
        prob_bits=prob_bits, interpret=interpret)
    flat = sym.reshape(-1)[: T * lanes]
    if rem:   # tail symbols: slot lookup only, no renorm (mirrors NumPy)
        slot = states_f[:rem] & jnp.uint32((1 << prob_bits) - 1)
        flat = jnp.concatenate([flat, s2s[slot.astype(jnp.int32)]])
    return flat.astype(jnp.uint8), wcnt


def rans_decode_interleaved_device(
        words: np.ndarray, states: np.ndarray, n: int, freqs: np.ndarray,
        lanes: int, prob_bits: int, interpret: Optional[bool] = None,
        to_host: bool = True):
    """Device counterpart of ``rans_np.rans_decode_interleaved``.

    ``to_host=False`` returns the uint8 symbol array still resident on
    the device (a jnp array) — the serve path hands it straight to the
    token-unpack stage without a host byte round trip."""
    interpret = _interpret_default(interpret)
    out, wcnt = _decode_stage(
        jnp.asarray(words, jnp.uint16), jnp.asarray(states, jnp.uint32),
        jnp.asarray(freqs, jnp.uint32), int(n), int(lanes),
        int(prob_bits), interpret)
    if int(wcnt[0]) > int(words.size):
        raise ValueError("rANS stream underflow")
    return np.asarray(out) if to_host else out
