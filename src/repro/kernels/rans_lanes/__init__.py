from repro.kernels.rans_lanes.ops import (rans_decode_interleaved_device,
                                          rans_encode_interleaved_device)
from repro.kernels.rans_lanes.ref import decode_lanes_ref, encode_lanes_ref

__all__ = [
    "rans_encode_interleaved_device",
    "rans_decode_interleaved_device",
    "encode_lanes_ref",
    "decode_lanes_ref",
]
