from repro.kernels.histogram.ops import token_histogram
from repro.kernels.histogram.ref import histogram_ref

__all__ = ["token_histogram", "histogram_ref"]
