from repro.kernels.histogram.ops import byte_histogram_device, token_histogram
from repro.kernels.histogram.ref import histogram_ref

__all__ = ["byte_histogram_device", "token_histogram", "histogram_ref"]
