"""Public wrapper: device histogram feeding rANS table normalization."""

from __future__ import annotations

from functools import partial

import jax
import jax.numpy as jnp

from repro.kernels.histogram.kernel import histogram_kernel


@partial(jax.jit, static_argnames=("vocab_size", "interpret"))
def token_histogram(ids: jnp.ndarray, vocab_size: int,
                    interpret: bool = True) -> jnp.ndarray:
    """ids: [N] any int dtype -> counts [vocab_size] int32.
    Pads N and vocab to kernel block multiples (pad ids are -1 = no bucket)."""
    n = ids.shape[0]
    block_n = min(1024, max(n, 8))
    pad_n = (-n) % block_n
    idsp = jnp.pad(ids.astype(jnp.int32), (0, pad_n), constant_values=-1)
    block_v = min(2048, vocab_size)
    pad_v = (-vocab_size) % block_v
    out = histogram_kernel(idsp, vocab_size + pad_v, block_n=block_n,
                           block_v=block_v, interpret=interpret)
    return out[:vocab_size]


def byte_histogram_device(data, interpret: bool = False):
    """256-bucket byte histogram on the accelerator — the rANS frequency
    table builder for device-resident entropy coding.  Accepts bytes or a
    uint8 ndarray; returns numpy int64 counts [256] (the shape
    ``normalize_freqs`` consumes)."""
    import numpy as np

    arr = np.frombuffer(data, np.uint8) if isinstance(data, (bytes, bytearray, memoryview)) \
        else np.asarray(data, np.uint8)
    if arr.size == 0:
        return np.zeros(256, np.int64)
    counts = token_histogram(jnp.asarray(arr, jnp.int32), 256,
                             interpret=interpret)
    return np.asarray(counts, dtype=np.int64)
