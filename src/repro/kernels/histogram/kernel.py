"""Pallas TPU kernel: token-frequency histogram (rANS table builder).

Scatter-add is the natural GPU formulation; on TPU the MXU-native
formulation is a one-hot matmul per block: counts += 1[ids == v] summed
over the block, accumulated across the sequential grid axis in the output
ref (classic Pallas reduction pattern — output block index_map is constant
so the same [V] tile stays resident in VMEM).

Block sizing: [block_n] ids expand to a [block_n, V_tile] one-hot in
VREGs; V is tiled by the second grid axis so arbitrary vocabularies fit
(V_tile lanes are 128-aligned).
"""

from __future__ import annotations

import functools

import jax
import jax.numpy as jnp
from jax.experimental import pallas as pl

DEFAULT_BLOCK_N = 1024
DEFAULT_BLOCK_V = 2048


def _hist_kernel(ids_ref, o_ref, *, block_v: int):
    # grid = (v_tiles, n_blocks): token blocks are the MINOR axis, so for a
    # fixed vocab tile the output block stays resident in VMEM while every
    # token block accumulates into it.
    jv = pl.program_id(0)      # vocab tile
    i = pl.program_id(1)       # sequential accumulation axis (token blocks)

    @pl.when(i == 0)
    def _init():
        o_ref[...] = jnp.zeros_like(o_ref)

    ids = ids_ref[...]                                   # [bn]
    base = jv * block_v
    vocab = base + jax.lax.broadcasted_iota(jnp.int32, (ids.shape[0], block_v), 1)
    onehot = (ids[:, None] == vocab).astype(jnp.int32)   # [bn, bv]
    o_ref[...] += onehot.sum(axis=0)


def histogram_kernel(ids: jnp.ndarray, vocab_size: int, *,
                     block_n: int = DEFAULT_BLOCK_N,
                     block_v: int = DEFAULT_BLOCK_V,
                     interpret: bool = False) -> jnp.ndarray:
    """ids: [N] int32 in [0, vocab); returns counts [vocab] int32.
    Out-of-range ids (e.g. -1 padding) fall in no bucket."""
    n = ids.shape[0]
    block_n = min(block_n, n)
    block_v = min(block_v, vocab_size)
    if n % block_n or vocab_size % block_v:
        raise ValueError("pad N / vocab to block multiples upstream")
    grid = (vocab_size // block_v, n // block_n)
    return pl.pallas_call(
        functools.partial(_hist_kernel, block_v=block_v),
        grid=grid,
        in_specs=[pl.BlockSpec((block_n,), lambda j, i: (i,))],
        out_specs=pl.BlockSpec((block_v,), lambda j, i: (j,)),
        out_shape=jax.ShapeDtypeStruct((vocab_size,), jnp.int32),
        interpret=interpret,
    )(ids)
