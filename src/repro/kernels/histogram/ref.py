"""Pure-jnp oracle for the histogram kernel."""

from __future__ import annotations

import jax.numpy as jnp


def histogram_ref(ids: jnp.ndarray, vocab_size: int) -> jnp.ndarray:
    """Out-of-range ids contribute to no bucket (matches the kernel)."""
    valid = (ids >= 0) & (ids < vocab_size)
    safe = jnp.where(valid, ids, 0)
    return jnp.zeros(vocab_size, jnp.int32).at[safe].add(valid.astype(jnp.int32))
