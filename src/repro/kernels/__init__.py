"""Pallas TPU kernels (validated on CPU via interpret=True against the
pure-jnp oracles in each kernel's ref.py):

  flash_attention/  blockwise online-softmax attention (causal/GQA/window/
                    softcap) — the perf-critical layer of every arch
  token_pack/       LoPace fixed-width + delta-zigzag byte packing
  histogram/        token-frequency one-hot-matmul reduction (rANS tables)
"""
