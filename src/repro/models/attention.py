"""Attention temporal mixers: full/local GQA and MLA (latent KV).

Two attention engines with identical semantics (one oracle in
repro.kernels.flash_attention.ref):

* `flash_self_attention` — flash-style blockwise attention with a
  **custom VJP** (FlashAttention backward: recompute score blocks from
  the saved (q, k, v, out, logsumexp) instead of letting autodiff save
  every scan step's O(S^2) probabilities).  This is the training/prefill
  path; activation memory is O(S * hd) per head.
* `blockwise_attention` — forward-only online-softmax blockwise attention
  over arbitrary cached kv positions (ring buffers, decode); never
  differentiated.

The Pallas kernel in repro.kernels.flash_attention is the TPU fast path
for the same contract.

`ANALYSIS_FULL_BLOCKS` (set by launch.dryrun) lifts block sizes to the
full sequence so every internal scan has trip count 1 — XLA's
cost_analysis counts while-bodies once, so this makes the dry-run FLOP
accounting exact (see launch/dryrun.py depth-extrapolation notes).

Cache layouts (per layer; stacked over layers by the transformer scan):
  full attn : k,v [B, S_max, n_kv, hd] + key_pos [S_max]
  local attn: ring buffer with S_max = window
  MLA       : ckv [B, S_max, kv_rank] + krope [B, S_max, rope_dim]
              (decode runs the absorbed MQA-over-latent form)
"""

from __future__ import annotations

from functools import partial
from typing import NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import apply_rope, big_neg, dense_init, softcap

ANALYSIS_FULL_BLOCKS = False  # dry-run cost-accounting mode
_BLOCK_Q, _BLOCK_KV = 512, 512


def _block_sizes(Sq: int, Skv: int) -> Tuple[int, int]:
    if ANALYSIS_FULL_BLOCKS:
        return Sq, Skv
    return min(_BLOCK_Q, Sq), min(_BLOCK_KV, Skv)


# ---------------------------------------------------------------------------
# Blockwise (flash-style) attention in pure jnp
# ---------------------------------------------------------------------------


def blockwise_attention(
    q: jnp.ndarray,            # [B, Sq, n_q, hd_qk]
    k: jnp.ndarray,            # [B, Skv, n_kv, hd_qk]
    v: jnp.ndarray,            # [B, Skv, n_kv, hd_v]
    q_positions: jnp.ndarray,  # [Sq] int32
    kv_positions: jnp.ndarray, # [Skv] int32 (-1 = invalid slot)
    *,
    causal: bool = True,
    window: int = 0,           # 0 = unlimited
    attn_softcap: float = 0.0,
    block_q: int = 512,
    block_kv: int = 512,
    scale: Optional[float] = None,
    k_scale: Optional[jnp.ndarray] = None,  # [B, Skv, n_kv, 1] int8-KV scales
    v_scale: Optional[jnp.ndarray] = None,
) -> jnp.ndarray:
    """Returns [B, Sq, n_q, hd_v]; fp32 accumulation, input-dtype output."""
    B, Sq, n_q, hd_qk = q.shape
    Skv, n_kv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    g = n_q // n_kv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd_qk)

    if Sq <= 8:
        # decode path: one fused pass over the whole cache (flash-decoding
        # layout — a kv-block scan would serialize and force SPMD to
        # rematerialize a sequence-sharded cache; a single einsum lets the
        # partitioner keep kv sharded and combine partial softmaxes with an
        # O(B*n_q) collective instead of moving the cache).
        # keep k/v in their storage dtype: bf16 x bf16 -> f32 accumulate is
        # MXU-native; up-casting the whole cache would double the bytes
        # actually moved from HBM (§Perf iteration A1).  int8-KV scales are
        # per (token, head) — constant along the contracted hd — so they
        # fold into the POST-contraction scores/probs and the dequantized
        # cache never materializes (§Perf iteration A3).
        qf = q.reshape(B, Sq, n_kv, g, hd_qk)
        kk = k.astype(q.dtype) if k.dtype == jnp.int8 else k
        s = jnp.einsum("bqngh,bsnh->bngqs", qf, kk,
                       preferred_element_type=jnp.float32) * scale
        if k_scale is not None:
            ksc = k_scale[..., 0].astype(jnp.float32).transpose(0, 2, 1)
            s = s * ksc[:, :, None, None, :]
        if attn_softcap > 0.0:
            s = attn_softcap * jnp.tanh(s / attn_softcap)
        mask = kv_positions[None, :] >= 0
        if causal:
            mask = mask & (kv_positions[None, :] <= q_positions[:, None])
        if window > 0:
            mask = mask & (q_positions[:, None] - kv_positions[None, :] < window)
        s = jnp.where(mask[None, None, None], s, big_neg(jnp.float32))
        p = jax.nn.softmax(s, axis=-1)
        if v_scale is not None:
            vsc = v_scale[..., 0].astype(jnp.float32).transpose(0, 2, 1)
            p = p * vsc[:, :, None, None, :]
        vv = v.astype(q.dtype) if v.dtype == jnp.int8 else v
        o = jnp.einsum("bngqs,bsnh->bqngh", p.astype(vv.dtype), vv,
                       preferred_element_type=jnp.float32)
        return o.reshape(B, Sq, n_q, hd_v).astype(q.dtype)

    block_q = min(block_q, Sq)
    block_kv = min(block_kv, Skv)
    # pad sequences up to block multiples (padding masked via positions)
    pad_q = (-Sq) % block_q
    pad_kv = (-Skv) % block_kv
    if pad_q:
        q = jnp.pad(q, ((0, 0), (0, pad_q), (0, 0), (0, 0)))
        q_positions = jnp.pad(q_positions, (0, pad_q), constant_values=2**30)
    if pad_kv:
        k = jnp.pad(k, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        v = jnp.pad(v, ((0, 0), (0, pad_kv), (0, 0), (0, 0)))
        kv_positions = jnp.pad(kv_positions, (0, pad_kv), constant_values=-1)
    Sq_p, Skv_p = q.shape[1], k.shape[1]
    nq_blk, nkv_blk = Sq_p // block_q, Skv_p // block_kv

    # [B, S, n, h] -> [n_blocks, B, n_kv, g, block, h]
    qb = q.reshape(B, nq_blk, block_q, n_kv, g, hd_qk).transpose(1, 0, 3, 4, 2, 5)
    kb = k.reshape(B, nkv_blk, block_kv, n_kv, hd_qk).transpose(1, 0, 3, 2, 4)
    vb = v.reshape(B, nkv_blk, block_kv, n_kv, hd_v).transpose(1, 0, 3, 2, 4)
    qpb = q_positions.reshape(nq_blk, block_q)
    kpb = kv_positions.reshape(nkv_blk, block_kv)

    neg = big_neg(jnp.float32)

    def q_step(_, q_in):
        q_blk, qp = q_in  # [B, n_kv, g, bq, hd], [bq]

        def kv_step(carry, kv_in):
            acc, m, l = carry
            k_blk, v_blk, kp = kv_in  # [B, n_kv, bkv, hd], ..., [bkv]
            s = jnp.einsum(
                "bngqh,bnkh->bngqk", q_blk.astype(jnp.float32),
                k_blk.astype(jnp.float32),
            ) * scale
            if attn_softcap > 0.0:
                s = attn_softcap * jnp.tanh(s / attn_softcap)
            mask = kp[None, :] >= 0
            if causal:
                mask &= kp[None, :] <= qp[:, None]
            if window > 0:
                mask &= qp[:, None] - kp[None, :] < window
            s = jnp.where(mask[None, None, None], s, neg)
            m_new = jnp.maximum(m, s.max(-1))
            p = jnp.exp(s - m_new[..., None])
            corr = jnp.exp(m - m_new)
            l_new = l * corr + p.sum(-1)
            acc_new = acc * corr[..., None] + jnp.einsum(
                "bngqk,bnkh->bngqh", p, v_blk.astype(jnp.float32)
            )
            return (acc_new, m_new, l_new), None

        acc0 = jnp.zeros((B, n_kv, g, block_q, hd_v), jnp.float32)
        m0 = jnp.full((B, n_kv, g, block_q), neg, jnp.float32)
        l0 = jnp.zeros((B, n_kv, g, block_q), jnp.float32)
        (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kb, vb, kpb))
        out = acc / jnp.maximum(l[..., None], 1e-37)
        return None, out

    _, ob = jax.lax.scan(q_step, None, (qb, qpb))  # [nq_blk, B, n_kv, g, bq, hd_v]
    out = ob.transpose(1, 0, 4, 2, 3, 5).reshape(B, Sq_p, n_q, hd_v)
    return out[:, :Sq].astype(q.dtype)


# ---------------------------------------------------------------------------
# Flash self-attention with custom VJP (training / prefill path)
# ---------------------------------------------------------------------------
#
# Layout inside: q [B, n_kv, g, Sq, hd] kept WHOLE (so SPMD can shard heads
# or the q-sequence — context parallelism for head counts that do not
# divide the TP axis); kv blocks are scanned with online softmax.  Peak
# temporary per step is [B, n_kv, g, Sq_shard, bkv].
#
# Backward (FlashAttention-style): recompute score blocks from the saved
# (q, k, v, out, logsumexp) in one kv-block sweep that accumulates dq and
# emits per-block dk/dv — no O(S^2) residuals.
#
# SEQ_SHARD_SPECS, set by the launcher for archs whose head count does not
# divide the model axis, pins (q, kv) sharding so the einsums split over
# the q-sequence instead of replicating (XLA inserts the all-gather /
# reduce-scatter pair that sequence-parallel attention requires).


SEQ_SHARD_SPECS = None  # Optional[(q_pspec, kv_pspec)] — launcher-controlled


def _maybe_seq_shard(q, k, v):
    if SEQ_SHARD_SPECS is None:
        return q, k, v
    q_spec, kv_spec = SEQ_SHARD_SPECS
    q = jax.lax.with_sharding_constraint(q, q_spec)
    k = jax.lax.with_sharding_constraint(k, kv_spec)
    v = jax.lax.with_sharding_constraint(v, kv_spec)
    return q, k, v


def _mask_block(qp, kp, causal: bool, window: int):
    mask = (kp[None, :] >= 0)
    mask = jnp.broadcast_to(mask, (qp.shape[0], kp.shape[0]))
    if causal:
        mask = mask & (kp[None, :] <= qp[:, None])
    if window > 0:
        mask = mask & (qp[:, None] - kp[None, :] < window)
    return mask


def _scores_block(q_all, k_blk, qp, kp, scale, causal, window, cap):
    """q_all [B,n,g,Sq,hd] x k_blk [B,n,bkv,hd] -> (s, dcap) [B,n,g,Sq,bkv]."""
    s = jnp.einsum("bngqh,bnkh->bngqk", q_all, k_blk) * scale
    dcap = None
    if cap > 0.0:
        t = jnp.tanh(s / cap)
        dcap = 1.0 - t * t
        s = cap * t
    neg = big_neg(jnp.float32)
    mask = _mask_block(qp, kp, causal, window)
    s = jnp.where(mask[None, None, None], s, neg)
    return s, dcap


@partial(jax.custom_vjp, nondiff_argnums=(3, 4, 5, 6, 7, 8))
def flash_self_attention(q, k, v, causal=True, window=0, attn_softcap=0.0,
                         scale=None, blocks=None, q_offset=0):
    """Self-attention over positions q_offset+[0..Sq) x [0..Skv) (the
    train/prefill layout; ring-buffer caches use blockwise_attention).
    q [B,Sq,n_q,hd], k/v [B,Skv,n_kv,hd(:v)] -> [B,Sq,n_q,hd_v]."""
    out, _, _ = _flash_fwd_impl(q, k, v, causal, window, attn_softcap, scale,
                                blocks, q_offset)
    return out


def _split_heads(q, k, v):
    B, Sq, n_q, hd = q.shape
    Skv, n_kv = k.shape[1], k.shape[2]
    g = n_q // n_kv
    qh = q.astype(jnp.float32).reshape(B, Sq, n_kv, g, hd).transpose(0, 2, 3, 1, 4)
    kh = k.astype(jnp.float32).transpose(0, 2, 1, 3)
    vh = v.astype(jnp.float32).transpose(0, 2, 1, 3)
    return qh, kh, vh


def _flash_fwd_impl(q, k, v, causal, window, cap, scale, blocks, q_offset):
    B, Sq, n_q, hd = q.shape
    Skv, n_kv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    g = n_q // n_kv
    scale = scale if scale is not None else 1.0 / np.sqrt(hd)
    _, bkv = blocks if blocks is not None else _block_sizes(Sq, Skv)
    if Skv % bkv:
        raise ValueError(f"flash attention needs block-divisible kv ({Skv}%{bkv})")
    nkv = Skv // bkv
    neg = big_neg(jnp.float32)

    q, k, v = _maybe_seq_shard(q, k, v)
    qh, kh, vh = _split_heads(q, k, v)            # [B,n,g,Sq,h], [B,n,Skv,h]
    kb = kh.reshape(B, n_kv, nkv, bkv, hd).transpose(2, 0, 1, 3, 4)
    vb = vh.reshape(B, n_kv, nkv, bkv, hd_v).transpose(2, 0, 1, 3, 4)
    qp = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    kpb = jnp.arange(Skv, dtype=jnp.int32).reshape(nkv, bkv)

    def kv_step(carry, kv_in):
        acc, m, l = carry
        k_blk, v_blk, kp = kv_in
        s, _ = _scores_block(qh, k_blk, qp, kp, scale, causal, window, cap)
        m_new = jnp.maximum(m, s.max(-1))
        p = jnp.exp(s - m_new[..., None])
        corr = jnp.exp(m - m_new)
        l_new = l * corr + p.sum(-1)
        acc_new = acc * corr[..., None] + jnp.einsum("bngqk,bnkh->bngqh", p, v_blk)
        return (acc_new, m_new, l_new), None

    acc0 = jnp.zeros((B, n_kv, g, Sq, hd_v), jnp.float32)
    m0 = jnp.full((B, n_kv, g, Sq), neg, jnp.float32)
    l0 = jnp.zeros((B, n_kv, g, Sq), jnp.float32)
    (acc, m, l), _ = jax.lax.scan(kv_step, (acc0, m0, l0), (kb, vb, kpb))
    oh = acc / jnp.maximum(l[..., None], 1e-37)   # [B,n,g,Sq,hd_v]
    lse = m + jnp.log(jnp.maximum(l, 1e-37))      # [B,n,g,Sq]
    out = oh.transpose(0, 3, 1, 2, 4).reshape(B, Sq, n_q, hd_v).astype(q.dtype)
    return out, oh, lse


def _flash_fwd_rule(q, k, v, causal, window, cap, scale, blocks, q_offset):
    out, oh, lse = _flash_fwd_impl(q, k, v, causal, window, cap, scale,
                                   blocks, q_offset)
    return out, (q, k, v, oh, lse)


def _flash_bwd_rule(causal, window, cap, scale, blocks, q_offset,
                    residuals, dout):
    q, k, v, oh, lse = residuals
    B, Sq, n_q, hd = q.shape
    Skv, n_kv = k.shape[1], k.shape[2]
    hd_v = v.shape[-1]
    g = n_q // n_kv
    scale_v = scale if scale is not None else 1.0 / np.sqrt(hd)
    _, bkv = blocks if blocks is not None else _block_sizes(Sq, Skv)
    nkv = Skv // bkv

    q, k, v = _maybe_seq_shard(q, k, v)
    qh, kh, vh = _split_heads(q, k, v)
    kb = kh.reshape(B, n_kv, nkv, bkv, hd).transpose(2, 0, 1, 3, 4)
    vb = vh.reshape(B, n_kv, nkv, bkv, hd_v).transpose(2, 0, 1, 3, 4)
    doh = (dout.astype(jnp.float32)
           .reshape(B, Sq, n_kv, g, hd_v).transpose(0, 2, 3, 1, 4))
    qp = q_offset + jnp.arange(Sq, dtype=jnp.int32)
    kpb = jnp.arange(Skv, dtype=jnp.int32).reshape(nkv, bkv)
    D = jnp.einsum("bngqh,bngqh->bngq", doh, oh)   # rowsum(dout*out)

    def kv_step(dq_acc, kv_in):
        k_blk, v_blk, kp = kv_in
        s, dcap = _scores_block(qh, k_blk, qp, kp, scale_v, causal, window, cap)
        p = jnp.exp(s - lse[..., None])
        dp = jnp.einsum("bngqh,bnkh->bngqk", doh, v_blk)
        ds = p * (dp - D[..., None])
        if dcap is not None:
            ds = ds * dcap
        ds = ds * scale_v
        dq_acc = dq_acc + jnp.einsum("bngqk,bnkh->bngqh", ds, k_blk)
        dk_blk = jnp.einsum("bngqk,bngqh->bnkh", ds, qh)
        dv_blk = jnp.einsum("bngqk,bngqh->bnkh", p, doh)
        return dq_acc, (dk_blk, dv_blk)

    dq0 = jnp.zeros((B, n_kv, g, Sq, hd), jnp.float32)
    dqh, (dkb, dvb) = jax.lax.scan(kv_step, dq0, (kb, vb, kpb))
    dq = dqh.transpose(0, 3, 1, 2, 4).reshape(B, Sq, n_q, hd).astype(q.dtype)
    dk = (dkb.transpose(1, 2, 0, 3, 4).reshape(B, n_kv, Skv, hd)
          .transpose(0, 2, 1, 3).astype(k.dtype))
    dv = (dvb.transpose(1, 2, 0, 3, 4).reshape(B, n_kv, Skv, hd_v)
          .transpose(0, 2, 1, 3).astype(v.dtype))
    return dq, dk, dv


flash_self_attention.defvjp(_flash_fwd_rule, _flash_bwd_rule)


# ---------------------------------------------------------------------------
# GQA (full / local)
# ---------------------------------------------------------------------------


def init_attention(key, cfg: ModelConfig) -> dict:
    d, hd, n_q, n_kv = cfg.d_model, cfg.head_dim, cfg.n_heads, cfg.n_kv_heads
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3, k4 = jax.random.split(key, 4)
    return {
        "wq": dense_init(k1, (d, n_q, hd), in_axis=0, dtype=dt),
        "wk": dense_init(k2, (d, n_kv, hd), in_axis=0, dtype=dt),
        "wv": dense_init(k3, (d, n_kv, hd), in_axis=0, dtype=dt),
        "wo": dense_init(k4, (n_q, hd, d), in_axis=0, dtype=dt),
    }


def _quantize_kv(x):
    """Per-(token, head) int8 symmetric quantization (KIVI-style)."""
    scale = jnp.max(jnp.abs(x.astype(jnp.float32)), axis=-1, keepdims=True) / 127.0
    scale = jnp.maximum(scale, 1e-8)
    q = jnp.clip(jnp.round(x.astype(jnp.float32) / scale), -127, 127)
    return q.astype(jnp.int8), scale.astype(jnp.bfloat16)


def _dequantize_kv(q, scale, dtype):
    return (q.astype(jnp.float32) * scale.astype(jnp.float32)).astype(dtype)


def init_attn_cache(cfg: ModelConfig, batch: int, max_len: int, kind: str) -> dict:
    s = min(max_len, cfg.window) if kind == "local" and cfg.window else max_len
    shape = (batch, s, cfg.n_kv_heads, cfg.head_dim)
    cache = {"key_pos": jnp.full((s,), -1, jnp.int32)}
    if cfg.kv_cache_dtype == "int8":
        cache.update({
            "k": jnp.zeros(shape, jnp.int8),
            "v": jnp.zeros(shape, jnp.int8),
            "k_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
            "v_scale": jnp.zeros(shape[:-1] + (1,), jnp.bfloat16),
        })
    else:
        dt = jnp.dtype(cfg.activation_dtype)
        cache.update({"k": jnp.zeros(shape, dt), "v": jnp.zeros(shape, dt)})
    return cache


def apply_attention(
    params: dict,
    cfg: ModelConfig,
    kind: str,
    x: jnp.ndarray,                 # [B, S, d]
    positions: jnp.ndarray,         # [S] int32
    cache: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    B, S, _ = x.shape
    window = cfg.window if kind == "local" else 0

    q = jnp.einsum("bsd,dnh->bsnh", x, params["wq"].astype(x.dtype))
    k = jnp.einsum("bsd,dnh->bsnh", x, params["wk"].astype(x.dtype))
    v = jnp.einsum("bsd,dnh->bsnh", x, params["wv"].astype(x.dtype))
    if cfg.pos_embedding == "rope":
        q = apply_rope(q, positions, cfg.rope_base)
        k = apply_rope(k, positions, cfg.rope_base)

    if cache is None:
        # train/prefill self-attention: flash path with custom VJP
        out = flash_self_attention(
            q, k, v, True, window, cfg.attn_logit_softcap, None, None, 0)
        y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
        return y, None
    else:
        s_max = cache["k"].shape[1]
        # keep only the last s_max entries (ring semantics for local attn)
        k_new, v_new, pos_new = k[:, -s_max:], v[:, -s_max:], positions[-s_max:]
        slots = pos_new % s_max  # identity for full prefix, ring for local
        if "k_scale" in cache:   # int8 quantized KV (beyond-paper, §Perf)
            kq, ks = _quantize_kv(k_new)
            vq, vs = _quantize_kv(v_new)
            cache = {
                "k": cache["k"].at[:, slots].set(kq),
                "v": cache["v"].at[:, slots].set(vq),
                "k_scale": cache["k_scale"].at[:, slots].set(ks),
                "v_scale": cache["v_scale"].at[:, slots].set(vs),
                "key_pos": cache["key_pos"].at[slots].set(pos_new.astype(jnp.int32)),
            }
            if S <= 8:   # decode: scales fold into scores (no dequant buffer)
                out = blockwise_attention(
                    q, cache["k"], cache["v"], positions, cache["key_pos"],
                    causal=True, window=window,
                    attn_softcap=cfg.attn_logit_softcap,
                    k_scale=cache["k_scale"], v_scale=cache["v_scale"])
                y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
                return y, cache
            k_all = _dequantize_kv(cache["k"], cache["k_scale"], x.dtype)
            v_all = _dequantize_kv(cache["v"], cache["v_scale"], x.dtype)
        else:
            cache = {
                "k": cache["k"].at[:, slots].set(k_new.astype(cache["k"].dtype)),
                "v": cache["v"].at[:, slots].set(v_new.astype(cache["v"].dtype)),
                "key_pos": cache["key_pos"].at[slots].set(pos_new.astype(jnp.int32)),
            }
            k_all = cache["k"].astype(x.dtype)
            v_all = cache["v"].astype(x.dtype)
        kv_pos = cache["key_pos"]

    out = blockwise_attention(
        q, k_all, v_all, positions, kv_pos,
        causal=True, window=window, attn_softcap=cfg.attn_logit_softcap,
    )
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache


# ---------------------------------------------------------------------------
# MLA (DeepSeek-V2 / MiniCPM3 latent attention)
# ---------------------------------------------------------------------------


def init_mla(key, cfg: ModelConfig) -> dict:
    m = cfg.mla
    d, n = cfg.d_model, cfg.n_heads
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 8)
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    return {
        "wdq": dense_init(ks[0], (d, m.q_lora_rank), in_axis=0, dtype=dt),
        "q_norm": jnp.ones((m.q_lora_rank,), dt),
        "wuq": dense_init(ks[1], (m.q_lora_rank, n, qk_hd), in_axis=0, dtype=dt),
        "wdkv": dense_init(ks[2], (d, m.kv_lora_rank), in_axis=0, dtype=dt),
        "kv_norm": jnp.ones((m.kv_lora_rank,), dt),
        "wkrope": dense_init(ks[3], (d, m.qk_rope_head_dim), in_axis=0, dtype=dt),
        "wuk": dense_init(ks[4], (m.kv_lora_rank, n, m.qk_nope_head_dim), in_axis=0, dtype=dt),
        "wuv": dense_init(ks[5], (m.kv_lora_rank, n, m.v_head_dim), in_axis=0, dtype=dt),
        "wo": dense_init(ks[6], (n, m.v_head_dim, d), in_axis=0, dtype=dt),
    }


def init_mla_cache(cfg: ModelConfig, batch: int, max_len: int) -> dict:
    m = cfg.mla
    dt = jnp.dtype(cfg.activation_dtype)
    return {
        "ckv": jnp.zeros((batch, max_len, m.kv_lora_rank), dt),
        "krope": jnp.zeros((batch, max_len, m.qk_rope_head_dim), dt),
        "key_pos": jnp.full((max_len,), -1, jnp.int32),
    }


def _rms(x, w, eps=1e-6):
    xf = x.astype(jnp.float32)
    return (xf * jax.lax.rsqrt((xf**2).mean(-1, keepdims=True) + eps)
            * w.astype(jnp.float32)).astype(x.dtype)


def apply_mla(
    params: dict,
    cfg: ModelConfig,
    x: jnp.ndarray,
    positions: jnp.ndarray,
    cache: Optional[dict] = None,
) -> Tuple[jnp.ndarray, Optional[dict]]:
    m = cfg.mla
    B, S, _ = x.shape
    n = cfg.n_heads
    qk_hd = m.qk_nope_head_dim + m.qk_rope_head_dim
    scale = 1.0 / np.sqrt(qk_hd)

    cq = _rms(jnp.einsum("bsd,dr->bsr", x, params["wdq"].astype(x.dtype)),
              params["q_norm"])
    qfull = jnp.einsum("bsr,rnh->bsnh", cq, params["wuq"].astype(x.dtype))
    q_nope = qfull[..., : m.qk_nope_head_dim]
    q_rope = apply_rope(qfull[..., m.qk_nope_head_dim:], positions, cfg.rope_base)

    ckv = _rms(jnp.einsum("bsd,dr->bsr", x, params["wdkv"].astype(x.dtype)),
               params["kv_norm"])
    krope = apply_rope(
        jnp.einsum("bsd,dh->bsh", x, params["wkrope"].astype(x.dtype))[:, :, None, :],
        positions, cfg.rope_base,
    )[:, :, 0, :]

    if cache is not None:
        s_max = cache["ckv"].shape[1]
        ckv_new, kr_new, pos_new = ckv[:, -s_max:], krope[:, -s_max:], positions[-s_max:]
        slots = pos_new % s_max
        cache = {
            "ckv": cache["ckv"].at[:, slots].set(ckv_new.astype(cache["ckv"].dtype)),
            "krope": cache["krope"].at[:, slots].set(kr_new.astype(cache["krope"].dtype)),
            "key_pos": cache["key_pos"].at[slots].set(pos_new.astype(jnp.int32)),
        }
        ckv_use = cache["ckv"].astype(x.dtype)
        kr_use = cache["krope"].astype(x.dtype)
        kv_pos = cache["key_pos"]
    else:
        ckv_use, kr_use, kv_pos = ckv, krope, positions

    # Absorbed MQA-over-latent form (identical math to expanding k/v):
    #   scores = q_nope . (W_uk^T k-latent) + q_rope . k_rope
    #          = (q_nope W_uk) . latent + q_rope . k_rope
    q_lat = jnp.einsum("bsnh,rnh->bsnr", q_nope, params["wuk"].astype(x.dtype))
    q_cat = jnp.concatenate([q_lat, q_rope], axis=-1)           # [B,S,n,r+rope]
    k_cat = jnp.concatenate([ckv_use, kr_use], axis=-1)[:, :, None, :]  # MQA head
    if cache is None:
        out_lat = flash_self_attention(
            q_cat, k_cat, ckv_use[:, :, None, :], True, 0, 0.0, scale, None, 0)
    else:
        out_lat = blockwise_attention(
            q_cat, k_cat, ckv_use[:, :, None, :], positions, kv_pos,
            causal=True, scale=scale,
        )                                                        # [B,S,n,r]
    out = jnp.einsum("bsnr,rnh->bsnh", out_lat, params["wuv"].astype(x.dtype))
    y = jnp.einsum("bsnh,nhd->bsd", out, params["wo"].astype(x.dtype))
    return y, cache
