"""Recurrent temporal mixers: RG-LRU (Griffin/RecurrentGemma), mLSTM and
sLSTM (xLSTM).

Training uses parallel forms where they exist (associative scan for the
RG-LRU's linear recurrence, the stabilized quadratic form for mLSTM);
sLSTM's nonlinear recurrence is a `lax.scan`.  Decode carries O(1) state
per layer — this is what makes the `long_500k` shape feasible for the
ssm/hybrid architectures (DESIGN.md §Arch-applicability).

Cache pytrees:
  rglru : {"h": [B, W], "conv": [B, cw-1, W]}
  mlstm : {"C": [B, nh, hd, hd], "n": [B, nh, hd], "m": [B, nh],
           "conv": [B, cw-1, W]}
  slstm : {"c","n","h": [B, nh, hd], "m": [B, nh, hd]}
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from functools import partial

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

_RGLRU_C = 8.0
ANALYSIS_FULL_CHUNKS = False  # dry-run cost accounting (see launch/dryrun.py)
RGLRU_SEQ_SPEC = None  # launcher-set NamedSharding [B, S:model, W]: sequence-
                       # parallel RG-LRU — gate matmuls go local (no psum per
                       # gate per layer); the linear scan crosses shard
                       # boundaries with O(B*W) state collectives only.
_MLSTM_CHUNK = 256
_SLSTM_SEGMENT = 512


# ---------------------------------------------------------------------------
# Linear recurrence h_t = a_t * h_{t-1} + b_t with an O(S)-memory VJP.
#
# Autodiff through lax.associative_scan saves every tree level (~2 log S full
# arrays); the closed-form adjoint is itself a linear recurrence run in
# reverse:  g_t = dh_t + a_{t+1} g_{t+1},  da_t = g_t * h_{t-1},  db_t = g_t.
# ---------------------------------------------------------------------------


def _assoc_scan(a, b, axis=1):
    def combine(e1, e2):
        a1, b1 = e1
        a2, b2 = e2
        return a1 * a2, a2 * b1 + b2

    _, h = jax.lax.associative_scan(combine, (a, b), axis=axis)
    return h


@jax.custom_vjp
def linear_scan(a, b):
    """a, b: [B, S, W] fp32 -> h [B, S, W] with h_t = a_t h_{t-1} + b_t
    (h_0 = b_0 convention: a_0 multiplies an implicit zero state)."""
    return _assoc_scan(a, b)


def _linear_scan_fwd(a, b):
    h = _assoc_scan(a, b)
    return h, (a, h)


def _linear_scan_bwd(res, dh):
    a, h = res
    # reverse-time linear recurrence on the cotangent
    a_next = jnp.concatenate([a[:, 1:], jnp.zeros_like(a[:, :1])], axis=1)
    g = _assoc_scan(a_next[:, ::-1], dh[:, ::-1])[:, ::-1]
    h_prev = jnp.concatenate([jnp.zeros_like(h[:, :1]), h[:, :-1]], axis=1)
    return g * h_prev, g


linear_scan.defvjp(_linear_scan_fwd, _linear_scan_bwd)


# ---------------------------------------------------------------------------
# Causal depthwise conv1d (width cw), with carried state for decode
# ---------------------------------------------------------------------------


def _causal_conv(x: jnp.ndarray, w: jnp.ndarray,
                 state: Optional[jnp.ndarray]) -> Tuple[jnp.ndarray, Optional[jnp.ndarray]]:
    """x: [B, S, W]; w: [cw, W] depthwise taps; state: [B, cw-1, W] prior
    inputs (decode) or None (train, zero history)."""
    cw = w.shape[0]
    if state is None:
        hist = jnp.zeros((x.shape[0], cw - 1, x.shape[2]), x.dtype)
    else:
        hist = state.astype(x.dtype)
    xp = jnp.concatenate([hist, x], axis=1)  # [B, S+cw-1, W]
    out = sum(
        xp[:, i : i + x.shape[1], :] * w[i][None, None, :].astype(x.dtype)
        for i in range(cw)
    )
    new_state = xp[:, -(cw - 1):, :] if state is not None else None
    return out, new_state


# ---------------------------------------------------------------------------
# RG-LRU (Griffin)
# ---------------------------------------------------------------------------


def init_rglru(key, cfg: ModelConfig) -> dict:
    d, w = cfg.d_model, cfg.rnn_width
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 7)
    # Lambda init so a = exp(-c*softplus(L)) lands in [0.9, 0.999]
    u = jax.random.uniform(ks[0], (w,), jnp.float32, 0.9**2, 0.999**2)
    lam = jnp.log(jnp.expm1(-jnp.log(u) / _RGLRU_C))
    return {
        "w_x": dense_init(ks[1], (d, w), in_axis=0, dtype=dt),
        "w_y": dense_init(ks[2], (d, w), in_axis=0, dtype=dt),
        "conv_w": dense_init(ks[3], (cfg.conv_width, w), in_axis=0, dtype=dt),
        "w_rec_gate": dense_init(ks[4], (w, w), in_axis=0, dtype=dt),
        "w_in_gate": dense_init(ks[5], (w, w), in_axis=0, dtype=dt),
        "lambda": lam.astype(dt),
        "w_out": dense_init(ks[6], (w, d), in_axis=0, dtype=dt),
    }


def _rglru_coeffs(params, xc):
    """Gate math shared by train/decode. xc: [..., W] conv output."""
    r = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xc, params["w_rec_gate"].astype(xc.dtype)).astype(jnp.float32))
    i = jax.nn.sigmoid(jnp.einsum("...w,wv->...v", xc, params["w_in_gate"].astype(xc.dtype)).astype(jnp.float32))
    log_a = -_RGLRU_C * jax.nn.softplus(params["lambda"].astype(jnp.float32)) * r
    a = jnp.exp(log_a)
    b = jnp.sqrt(jnp.maximum(1.0 - jnp.exp(2.0 * log_a), 1e-12)) * (
        i * xc.astype(jnp.float32))
    return a, b


def apply_rglru(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                cache: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    """Griffin recurrent block: dual-branch in-proj, causal conv, RG-LRU,
    GeLU-gated merge, out-proj. x: [B, S, d]."""
    y_br = jax.nn.gelu(jnp.einsum("bsd,dw->bsw", x, params["w_y"].astype(x.dtype)))
    x_br = jnp.einsum("bsd,dw->bsw", x, params["w_x"].astype(x.dtype))
    if cache is None and RGLRU_SEQ_SPEC is not None:
        y_br = jax.lax.with_sharding_constraint(y_br, RGLRU_SEQ_SPEC)
        x_br = jax.lax.with_sharding_constraint(x_br, RGLRU_SEQ_SPEC)
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv(x_br, params["conv_w"], conv_state)

    a, b = _rglru_coeffs(params, xc)  # [B,S,W] fp32
    if cache is None:
        # h_t = a_t h_{t-1} + b_t — O(S)-memory custom-VJP parallel scan
        h = linear_scan(a, b)
        new_cache = None
    else:
        h0 = cache["h"].astype(jnp.float32)  # [B, W]
        # decode steps are S=1 in production; support small S via mini-scan
        def step(h, ab):
            a_t, b_t = ab
            h_new = a_t * h + b_t
            return h_new, h_new
        hT, hs = jax.lax.scan(step, h0, (a.swapaxes(0, 1), b.swapaxes(0, 1)))
        h = hs.swapaxes(0, 1)
        new_cache = {"h": hT, "conv": new_conv}
    out = (h.astype(x.dtype) * y_br)
    return jnp.einsum("bsw,wd->bsd", out, params["w_out"].astype(x.dtype)), new_cache


def init_rglru_cache(cfg: ModelConfig, batch: int) -> dict:
    return {
        "h": jnp.zeros((batch, cfg.rnn_width), jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, cfg.rnn_width),
                          jnp.dtype(cfg.activation_dtype)),
    }


# ---------------------------------------------------------------------------
# mLSTM (xLSTM matrix cell)
# ---------------------------------------------------------------------------


def _mlstm_dims(cfg: ModelConfig) -> Tuple[int, int, int]:
    w = 2 * cfg.d_model          # up-projection factor 2 (xLSTM paper)
    nh = cfg.n_heads
    return w, nh, w // nh


def init_mlstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    w, nh, hd = _mlstm_dims(cfg)
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 9)
    return {
        "w_up": dense_init(ks[0], (d, w), in_axis=0, dtype=dt),
        "w_gate": dense_init(ks[1], (d, w), in_axis=0, dtype=dt),
        "conv_w": dense_init(ks[2], (cfg.conv_width, w), in_axis=0, dtype=dt),
        # block-diagonal per-head projections (xLSTM paper §mLSTM block)
        "w_q": dense_init(ks[3], (nh, hd, hd), in_axis=1, dtype=dt),
        "w_k": dense_init(ks[4], (nh, hd, hd), in_axis=1, dtype=dt),
        "w_v": dense_init(ks[5], (nh, hd, hd), in_axis=1, dtype=dt),
        "w_i": dense_init(ks[6], (w, nh), in_axis=0, dtype=dt),
        "w_f": dense_init(ks[7], (w, nh), in_axis=0, dtype=dt),
        "b_i": jnp.zeros((nh,), dt),
        "b_f": jnp.full((nh,), 3.0, dt),  # forget-gate bias toward remembering
        "gn_scale": jnp.ones((w,), dt),
        "w_down": dense_init(ks[8], (w, d), in_axis=0, dtype=dt),
    }


def _headwise_rms(h, scale, nh):
    """Per-head group norm (rms flavor) as in xLSTM blocks. h: [B,S,nh,hd]."""
    hf = h.astype(jnp.float32)
    hf = hf * jax.lax.rsqrt((hf**2).mean(-1, keepdims=True) + 1e-6)
    B, S = h.shape[:2]
    return (hf.reshape(B, S, -1) * scale.astype(jnp.float32)).astype(h.dtype)


def apply_mlstm(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                cache: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    B, S, d = x.shape
    w, nh, hd = _mlstm_dims(cfg)
    up = jnp.einsum("bsd,dw->bsw", x, params["w_up"].astype(x.dtype))
    gate = jnp.einsum("bsd,dw->bsw", x, params["w_gate"].astype(x.dtype))
    conv_state = None if cache is None else cache["conv"]
    xc, new_conv = _causal_conv(up, params["conv_w"], conv_state)
    xc = jax.nn.silu(xc)

    xch = xc.reshape(B, S, nh, hd)
    uph = up.reshape(B, S, nh, hd)
    q = jnp.einsum("bsne,neh->bsnh", xch, params["w_q"].astype(x.dtype))
    k = jnp.einsum("bsne,neh->bsnh", xch, params["w_k"].astype(x.dtype)) / np.sqrt(hd)
    v = jnp.einsum("bsne,neh->bsnh", uph, params["w_v"].astype(x.dtype))
    i_pre = (jnp.einsum("bsw,wn->bsn", xc, params["w_i"].astype(x.dtype))
             + params["b_i"].astype(x.dtype)).astype(jnp.float32)   # [B,S,nh]
    f_pre = (jnp.einsum("bsw,wn->bsn", xc, params["w_f"].astype(x.dtype))
             + params["b_f"].astype(x.dtype)).astype(jnp.float32)

    log_f = -jax.nn.softplus(-f_pre)  # log sigmoid

    if cache is None:
        # Chunkwise-parallel stabilized form (xLSTM eq. 19-27 reorganized):
        # quadratic only within a chunk of length L, state (C', n', m)
        # carried across chunks — peak memory O(B*L*L*nh) instead of O(S^2).
        # analysis mode caps the chunk at 1024: the chunk scan is counted
        # once by XLA cost analysis, so the un-counted repetitions are the
        # intra-chunk quadratic+state terms only (<2-3% of mLSTM flops,
        # which its dense projections dominate) — and compile time drops 16x
        # vs full-sequence chunks.
        L = min(1024, S) if ANALYSIS_FULL_CHUNKS else min(_MLSTM_CHUNK, S)
        if S % L:
            raise ValueError(f"mLSTM requires seq divisible by chunk {L}")
        nC = S // L

        def chunked(t, hdim):
            return t.astype(jnp.float32).reshape(B, nC, L, nh, hdim).swapaxes(0, 1)

        qc, kc, vc = chunked(q, hd), chunked(k, hd), chunked(v, hd)
        ic = i_pre.reshape(B, nC, L, nh).swapaxes(0, 1)           # [nC,B,L,nh]
        lfc = log_f.reshape(B, nC, L, nh).swapaxes(0, 1)

        def chunk_step(carry, xs):
            # C' [B,nh,hd_v,hd_e], n' [B,nh,hd_e], m [B,nh]; true state is
            # C = C' * exp(m) (stabilized scaling).  e = key dim, h = value dim.
            Cp, npv, mp = carry
            q_c, k_c, v_c, i_c, lf_c = xs
            F = jnp.cumsum(lf_c, axis=1)                          # [B,L,nh]
            a = i_c - F                                           # a_s = i_s - F_s
            g = jnp.maximum(jax.lax.cummax(a, axis=1), mp[:, None, :])
            m_t = F + g                                           # running max
            # intra-chunk: q_t.k_s * exp(a_s - g_t) for s <= t
            tri = jnp.tril(jnp.ones((L, L), bool))
            w_ts = jnp.where(tri[None, :, :, None],
                             jnp.exp(a[:, None, :, :] - g[:, :, None, :]), 0.0)
            s_ts = jnp.einsum("btne,bsne->btsn", q_c, k_c) * w_ts
            num = jnp.einsum("btsn,bsnh->btnh", s_ts, v_c)
            n_loc = jnp.einsum("btsn,bsne->btne", w_ts, k_c)
            # inter-chunk contribution, scaled by exp(mp - g_t)
            inter_w = jnp.exp(mp[:, None, :] - g)                 # [B,L,nh]
            num = num + jnp.einsum("btne,bnhe->btnh", q_c * inter_w[..., None], Cp)
            n_tot = n_loc + npv[:, None, :, :] * inter_w[..., None]
            denom = jnp.maximum(
                jnp.abs(jnp.einsum("btne,btne->btn", n_tot, q_c)), jnp.exp(-m_t))
            h_c = num / denom[..., None]
            # end-of-chunk state update (rescaled to stabilizer g_L + F_L)
            gL, FL = g[:, -1, :], F[:, -1, :]
            scale_prev = jnp.exp(mp - gL)                          # [B,nh]
            wa = jnp.exp(a - gL[:, None, :])                       # [B,L,nh]
            C_new = (Cp * scale_prev[..., None, None]
                     + jnp.einsum("bsnh,bsne->bnhe", v_c, k_c * wa[..., None]))
            n_new = npv * scale_prev[..., None] + (k_c * wa[..., None]).sum(1)
            m_new = FL + gL
            return (C_new, n_new, m_new), h_c

        C0 = jnp.zeros((B, nh, hd, hd), jnp.float32)
        n0 = jnp.zeros((B, nh, hd), jnp.float32)
        m0 = jnp.full((B, nh), -1e30, jnp.float32)
        # checkpoint the chunk body: backward recomputes the O(L^2) block
        # from the carried state instead of saving it per chunk.
        body = jax.checkpoint(chunk_step, prevent_cse=False)
        (_, _, _), hcs = jax.lax.scan(body, (C0, n0, m0), (qc, kc, vc, ic, lfc))
        h = hcs.swapaxes(0, 1).reshape(B, S, nh, hd)
        new_cache = None
    else:
        C0 = cache["C"].astype(jnp.float32)
        n0 = cache["n"].astype(jnp.float32)
        m0 = cache["m"].astype(jnp.float32)

        def step(carry, qkvif):
            C, n, m_prev = carry
            q_t, k_t, v_t, i_t, lf_t = qkvif
            m_new = jnp.maximum(lf_t + m_prev, i_t)               # [B,nh]
            fs = jnp.exp(lf_t + m_prev - m_new)[..., None]
            is_ = jnp.exp(i_t - m_new)[..., None]
            C_new = fs[..., None] * C + is_[..., None] * jnp.einsum(
                "bnh,bnk->bnhk", v_t, k_t)
            n_new = fs * n + is_ * k_t
            denom = jnp.maximum(
                jnp.abs(jnp.einsum("bnk,bnk->bn", n_new, q_t)), jnp.exp(-m_new))
            h_t = jnp.einsum("bnhk,bnk->bnh", C_new, q_t) / denom[..., None]
            return (C_new, n_new, m_new), h_t

        seq = (q.swapaxes(0, 1).astype(jnp.float32),
               k.swapaxes(0, 1).astype(jnp.float32),
               v.swapaxes(0, 1).astype(jnp.float32),
               i_pre.swapaxes(0, 1), log_f.swapaxes(0, 1))
        (Cf, nf, mf), hs = jax.lax.scan(step, (C0, n0, m0), seq)
        h = hs.swapaxes(0, 1)                                     # [B,S,nh,hd]
        new_cache = {"C": Cf, "n": nf, "m": mf, "conv": new_conv}

    hn = _headwise_rms(h.astype(x.dtype), params["gn_scale"], nh)  # [B,S,w]
    out = hn * jax.nn.silu(gate)
    return jnp.einsum("bsw,wd->bsd", out, params["w_down"].astype(x.dtype)), new_cache


def init_mlstm_cache(cfg: ModelConfig, batch: int) -> dict:
    w, nh, hd = _mlstm_dims(cfg)
    return {
        "C": jnp.zeros((batch, nh, hd, hd), jnp.float32),
        "n": jnp.zeros((batch, nh, hd), jnp.float32),
        "m": jnp.full((batch, nh), -1e30, jnp.float32),
        "conv": jnp.zeros((batch, cfg.conv_width - 1, w),
                          jnp.dtype(cfg.activation_dtype)),
    }


# ---------------------------------------------------------------------------
# sLSTM (xLSTM scalar cell with recurrent mixing)
# ---------------------------------------------------------------------------


def init_slstm(key, cfg: ModelConfig) -> dict:
    d = cfg.d_model
    nh = cfg.n_heads
    hd = d // nh
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 10)
    p = {"w_out": dense_init(ks[8], (d, d), in_axis=0, dtype=dt),
         "gn_scale": jnp.ones((d,), dt)}
    for j, g in enumerate(("z", "i", "f", "o")):
        p[f"w_{g}"] = dense_init(ks[j], (d, d), in_axis=0, dtype=dt)
        p[f"r_{g}"] = dense_init(ks[4 + j], (nh, hd, hd), in_axis=1, dtype=dt)
        p[f"b_{g}"] = (jnp.full((d,), 1.0, dt) if g == "f" else jnp.zeros((d,), dt))
    return p


def apply_slstm(params: dict, cfg: ModelConfig, x: jnp.ndarray,
                cache: Optional[dict] = None) -> Tuple[jnp.ndarray, Optional[dict]]:
    B, S, d = x.shape
    nh = cfg.n_heads
    hd = d // nh

    pre = {
        g: jnp.einsum("bsd,de->bse", x, params[f"w_{g}"].astype(x.dtype))
        + params[f"b_{g}"].astype(x.dtype)
        for g in ("z", "i", "f", "o")
    }

    if cache is None:
        c0 = jnp.zeros((B, nh, hd), jnp.float32)
        n0 = jnp.zeros((B, nh, hd), jnp.float32)
        h0 = jnp.zeros((B, nh, hd), jnp.float32)
        m0 = jnp.full((B, nh, hd), -1e30, jnp.float32)
    else:
        c0, n0, h0, m0 = (cache[k].astype(jnp.float32) for k in ("c", "n", "h", "m"))

    def rmul(h, g):  # block-diagonal recurrent matmul per head
        return jnp.einsum("bnh,nhk->bnk", h, params[f"r_{g}"].astype(jnp.float32))

    def step(carry, pre_t):
        c, n, h, m = carry
        z_p, i_p, f_p, o_p = (p.astype(jnp.float32).reshape(B, nh, hd) for p in pre_t)
        z = jnp.tanh(z_p + rmul(h, "z"))
        i_log = i_p + rmul(h, "i")
        f_log = -jax.nn.softplus(-(f_p + rmul(h, "f")))  # log sigmoid(f)
        o = jax.nn.sigmoid(o_p + rmul(h, "o"))
        m_new = jnp.maximum(f_log + m, i_log)
        i_s = jnp.exp(i_log - m_new)
        f_s = jnp.exp(f_log + m - m_new)
        c_new = f_s * c + i_s * z
        n_new = jnp.maximum(f_s * n + i_s, 1e-6)
        h_new = o * c_new / n_new
        return (c_new, n_new, h_new, m_new), h_new

    seq = tuple(p.swapaxes(0, 1) for p in (pre["z"], pre["i"], pre["f"], pre["o"]))
    # sLSTM is a nonlinear recurrence: time is segmented and each segment is
    # checkpointed, so backward saves only segment-boundary carries and
    # recomputes within a segment (O(S/seg) live state instead of O(S)).
    seg = S if (ANALYSIS_FULL_CHUNKS or S % _SLSTM_SEGMENT) else _SLSTM_SEGMENT
    if S % seg:
        (cf, nf, hf, mf), hs = jax.lax.scan(step, (c0, n0, h0, m0), seq)
    else:
        n_seg = S // seg
        seq_seg = tuple(p.reshape(n_seg, seg, *p.shape[1:]) for p in seq)

        def segment(carry, xs):
            return jax.lax.scan(step, carry, xs)

        body = jax.checkpoint(segment, prevent_cse=False)
        (cf, nf, hf, mf), hs_seg = jax.lax.scan(body, (c0, n0, h0, m0), seq_seg)
        hs = hs_seg.reshape(S, *hs_seg.shape[2:])
    h = hs.swapaxes(0, 1).reshape(B, S, d)                        # [B,S,d]
    new_cache = None if cache is None else {"c": cf, "n": nf, "h": hf, "m": mf}

    hf32 = h.astype(jnp.float32)
    hn = (hf32 * jax.lax.rsqrt((hf32.reshape(B, S, nh, hd) ** 2).mean(-1, keepdims=True)
                               .repeat(hd, -1).reshape(B, S, d) + 1e-6)
          * params["gn_scale"].astype(jnp.float32)).astype(x.dtype)
    return jnp.einsum("bsd,de->bse", hn, params["w_out"].astype(x.dtype)), new_cache


def init_slstm_cache(cfg: ModelConfig, batch: int) -> dict:
    nh = cfg.n_heads
    hd = cfg.d_model // nh
    z = lambda: jnp.zeros((batch, nh, hd), jnp.float32)
    return {"c": z(), "n": z(), "h": z(),
            "m": jnp.full((batch, nh, hd), -1e30, jnp.float32)}
