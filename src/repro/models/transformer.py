"""Decoder-only LM composition: embeddings, period-aware scan-over-layers,
loss, and the KV-cache / recurrent-state decode path.

Scan-over-layers: parameters of layer i belong to pattern position
i % period; per position they are stacked over the n_layers//period full
periods and consumed by one `lax.scan`, so HLO size (and compile time on
the dry-run meshes) is independent of depth.  A partial trailing period
(e.g. recurrentgemma's 26 = 8*3 + 2) is applied unrolled after the scan.

`forward` serves all entry points:
  train/loss      : cache=None
  prefill         : cache=init_cache(...), positions = arange(S)
  decode_step     : cache=..., positions = [pos], S=1
"""

from __future__ import annotations

import dataclasses
from typing import Any, Dict, NamedTuple, Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models import attention as attn_mod
from repro.models import ffn as ffn_mod
from repro.models import recurrent as rec_mod
from repro.models.common import (
    apply_norm,
    embed_init,
    init_norm,
    sinusoidal_positions,
    softcap,
)

Params = Dict[str, Any]
Cache = Any


# ---------------------------------------------------------------------------
# Layer = temporal mixer + optional channel mixer
# ---------------------------------------------------------------------------

_MIXER_INIT = {
    "attn": attn_mod.init_attention,
    "local": attn_mod.init_attention,
    "mla": attn_mod.init_mla,
    "rglru": rec_mod.init_rglru,
    "mlstm": rec_mod.init_mlstm,
    "slstm": rec_mod.init_slstm,
}


def _has_ffn(cfg: ModelConfig) -> bool:
    return cfg.moe is not None or (cfg.d_ff > 0 and cfg.ffn_kind != "none")


def init_layer(key, cfg: ModelConfig, kind: str) -> Params:
    ks = jax.random.split(key, 4)
    p: Params = {
        "mixer_norm": init_norm(cfg.d_model, cfg.norm_style, jnp.dtype(cfg.param_dtype)),
        "mixer": _MIXER_INIT[kind](ks[0], cfg),
    }
    if _has_ffn(cfg):
        p["ffn_norm"] = init_norm(cfg.d_model, cfg.norm_style, jnp.dtype(cfg.param_dtype))
        p["ffn"] = (ffn_mod.init_moe(ks[1], cfg) if cfg.moe is not None
                    else ffn_mod.init_ffn(ks[1], cfg))
    if cfg.post_block_norms:
        p["post_mixer_norm"] = init_norm(cfg.d_model, cfg.norm_style,
                                         jnp.dtype(cfg.param_dtype))
        if _has_ffn(cfg):
            p["post_ffn_norm"] = init_norm(cfg.d_model, cfg.norm_style,
                                           jnp.dtype(cfg.param_dtype))
    return p


def apply_layer(
    p: Params, cfg: ModelConfig, kind: str, x: jnp.ndarray,
    positions: jnp.ndarray, cache: Optional[Cache],
) -> Tuple[jnp.ndarray, Optional[Cache], jnp.ndarray]:
    h = apply_norm(p["mixer_norm"], x, cfg.norm_style, cfg.norm_eps)
    if kind in ("attn", "local"):
        y, new_cache = attn_mod.apply_attention(p["mixer"], cfg, kind, h, positions, cache)
    elif kind == "mla":
        y, new_cache = attn_mod.apply_mla(p["mixer"], cfg, h, positions, cache)
    elif kind == "rglru":
        y, new_cache = rec_mod.apply_rglru(p["mixer"], cfg, h, cache)
    elif kind == "mlstm":
        y, new_cache = rec_mod.apply_mlstm(p["mixer"], cfg, h, cache)
    elif kind == "slstm":
        y, new_cache = rec_mod.apply_slstm(p["mixer"], cfg, h, cache)
    else:
        raise ValueError(f"unknown block kind {kind!r}")
    if cfg.post_block_norms:
        y = apply_norm(p["post_mixer_norm"], y, cfg.norm_style, cfg.norm_eps)
    x = x + y

    aux = jnp.zeros((), jnp.float32)
    if _has_ffn(cfg):
        h = apply_norm(p["ffn_norm"], x, cfg.norm_style, cfg.norm_eps)
        if cfg.moe is not None:
            y, moe_aux = ffn_mod.apply_moe(p["ffn"], cfg, h)
            aux = aux + moe_aux["load_balance_loss"]
        else:
            y = ffn_mod.apply_ffn(p["ffn"], cfg, h)
        if cfg.post_block_norms:
            y = apply_norm(p["post_ffn_norm"], y, cfg.norm_style, cfg.norm_eps)
        x = x + y
    return x, new_cache, aux


def init_layer_cache(cfg: ModelConfig, kind: str, batch: int, max_len: int) -> Cache:
    if kind in ("attn", "local"):
        return attn_mod.init_attn_cache(cfg, batch, max_len, kind)
    if kind == "mla":
        return attn_mod.init_mla_cache(cfg, batch, max_len)
    if kind == "rglru":
        return rec_mod.init_rglru_cache(cfg, batch)
    if kind == "mlstm":
        return rec_mod.init_mlstm_cache(cfg, batch)
    if kind == "slstm":
        return rec_mod.init_slstm_cache(cfg, batch)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Full model
# ---------------------------------------------------------------------------


def _layout(cfg: ModelConfig) -> Tuple[int, int]:
    """(n_full_periods, n_remainder_layers)."""
    return cfg.n_layers // cfg.period, cfg.n_layers % cfg.period


def init_params(rng, cfg: ModelConfig) -> Params:
    n_per, n_rem = _layout(cfg)
    keys = jax.random.split(rng, 4)
    dt = jnp.dtype(cfg.param_dtype)
    params: Params = {"final_norm": init_norm(cfg.d_model, cfg.norm_style, dt)}
    if cfg.frontend != "audio_stub":
        params["embed"] = {"table": embed_init(keys[0], (cfg.vocab_size, cfg.d_model), dt)}
    if not cfg.tie_embeddings:
        params["head"] = {
            "w": embed_init(keys[1], (cfg.d_model, cfg.vocab_size), dt)}

    blocks = []
    for pos, kind in enumerate(cfg.block_pattern):
        pkeys = jax.random.split(jax.random.fold_in(keys[2], pos), max(n_per, 1))
        stacked = jax.vmap(lambda k: init_layer(k, cfg, kind))(pkeys[:n_per]) \
            if n_per else None
        blocks.append(stacked)
    params["blocks"] = tuple(blocks)
    rem = []
    for pos in range(n_rem):
        kind = cfg.block_pattern[pos]
        rem.append(init_layer(jax.random.fold_in(keys[3], pos), cfg, kind))
    params["rem_blocks"] = tuple(rem)
    return params


def init_cache(cfg: ModelConfig, batch: int, max_len: int) -> Cache:
    n_per, n_rem = _layout(cfg)

    def stack(kind):
        one = init_layer_cache(cfg, kind, batch, max_len)
        return jax.tree_util.tree_map(
            lambda a: jnp.broadcast_to(a, (n_per,) + a.shape).copy(), one)

    scanned = tuple(stack(kind) for kind in cfg.block_pattern) if n_per else tuple()
    rem = tuple(init_layer_cache(cfg, cfg.block_pattern[i], batch, max_len)
                for i in range(n_rem))
    return {"scanned": scanned, "rem": rem}


def embed_inputs(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
                 positions: jnp.ndarray) -> jnp.ndarray:
    dt = jnp.dtype(cfg.activation_dtype)
    if cfg.frontend == "audio_stub":
        x = batch["embeds"].astype(dt)  # precomputed EnCodec frame embeddings
    elif cfg.frontend == "vision_stub":
        tok = params["embed"]["table"].astype(dt)[batch["tokens"]]
        if "patch_embeds" in batch:
            x = jnp.concatenate([batch["patch_embeds"].astype(dt), tok], axis=1)
        else:
            x = tok
    else:
        x = params["embed"]["table"].astype(dt)[batch["tokens"]]
    if cfg.scale_embeddings:
        x = x * np.sqrt(cfg.d_model).astype(np.float32)
    if cfg.pos_embedding == "sinusoidal":
        table = sinusoidal_positions(int(positions.shape[0]), cfg.d_model)
        # positions may be offset (decode); recompute per position
        half = cfg.d_model // 2
        dim = jnp.arange(half, dtype=jnp.float32)
        ang = positions[:, None].astype(jnp.float32) / jnp.power(
            10_000.0, 2 * dim / cfg.d_model)
        x = x + jnp.concatenate([jnp.sin(ang), jnp.cos(ang)], -1)[None].astype(dt)
    return x


def forward(
    params: Params,
    cfg: ModelConfig,
    batch: Dict[str, jnp.ndarray],
    cache: Optional[Cache] = None,
    positions: Optional[jnp.ndarray] = None,
    remat: str = "none",
    unroll: bool = False,
) -> Tuple[jnp.ndarray, Optional[Cache], jnp.ndarray]:
    """Returns (logits [B,S,V], new_cache or None, aux_loss scalar).

    unroll=True replaces the layer scan with a python loop — used by the
    dry-run cost-accounting pass (XLA counts scan bodies once; see
    launch/dryrun.py), never in production."""
    n_per, n_rem = _layout(cfg)
    if positions is None:
        S = (batch["embeds"].shape[1] if cfg.frontend == "audio_stub"
             else batch["tokens"].shape[1])
        if cfg.frontend == "vision_stub" and "patch_embeds" in batch:
            S += batch["patch_embeds"].shape[1]
        positions = jnp.arange(S, dtype=jnp.int32)
    x = embed_inputs(params, cfg, batch, positions)

    def period_body(carry, xs):
        x, aux = carry
        layer_params, layer_caches = xs
        new_caches = []
        for pos, kind in enumerate(cfg.block_pattern):
            c = None if layer_caches is None else layer_caches[pos]
            x, nc, a = apply_layer(layer_params[pos], cfg, kind, x, positions, c)
            new_caches.append(nc)
            aux = aux + a
        return (x, aux), tuple(new_caches)

    body = period_body
    if remat == "full":
        body = jax.checkpoint(period_body, prevent_cse=False)
    elif remat == "dots":
        body = jax.checkpoint(
            period_body, prevent_cse=False,
            policy=jax.checkpoint_policies.dots_with_no_batch_dims_saveable)

    aux0 = jnp.zeros((), jnp.float32)
    new_cache = None
    if n_per and unroll:
        from repro.models.common import take_block

        per_period_caches = []
        for i in range(n_per):
            layer_params = tuple(take_block(b, i) for b in params["blocks"])
            layer_caches = (tuple(take_block(c, i) for c in cache["scanned"])
                            if cache is not None else None)
            (x, aux0), ncs = body((x, aux0), (layer_params, layer_caches))
            per_period_caches.append(ncs)
        if cache is not None:
            scanned_caches = tuple(
                jax.tree_util.tree_map(lambda *xs: jnp.stack(xs), *[
                    pcs[pos] for pcs in per_period_caches])
                for pos in range(len(cfg.block_pattern)))
        else:
            scanned_caches = tuple()
    elif n_per:
        xs_cache = cache["scanned"] if cache is not None else None
        (x, aux0), scanned_caches = jax.lax.scan(
            body, (x, aux0), (params["blocks"], xs_cache))
    else:
        scanned_caches = tuple()

    rem_caches = []
    for pos in range(n_rem):
        kind = cfg.block_pattern[pos]
        c = cache["rem"][pos] if cache is not None else None
        x, nc, a = apply_layer(params["rem_blocks"][pos], cfg, kind, x, positions, c)
        rem_caches.append(nc)
        aux0 = aux0 + a
    if cache is not None:
        new_cache = {"scanned": scanned_caches, "rem": tuple(rem_caches)}

    x = apply_norm(params["final_norm"], x, cfg.norm_style, cfg.norm_eps)
    head_w = (params["embed"]["table"].T if cfg.tie_embeddings
              else params["head"]["w"]).astype(x.dtype)
    logits = jnp.einsum("bsd,dv->bsv", x, head_w).astype(jnp.float32)
    if cfg.final_logit_softcap:
        logits = softcap(logits, cfg.final_logit_softcap)
    return logits, new_cache, aux0


# ---------------------------------------------------------------------------
# Loss / decode entry points
# ---------------------------------------------------------------------------

IGNORE_INDEX = -100


def loss_fn(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            remat: str = "none", aux_weight: float = 0.01,
            z_weight: float = 1e-4, unroll: bool = False
            ) -> Tuple[jnp.ndarray, Dict[str, jnp.ndarray]]:
    logits, _, aux = forward(params, cfg, batch, remat=remat, unroll=unroll)
    labels = batch["labels"]
    if logits.shape[1] != labels.shape[1]:  # vision prefix: pad labels w/ ignore
        pad = logits.shape[1] - labels.shape[1]
        labels = jnp.pad(labels, ((0, 0), (pad, 0)), constant_values=IGNORE_INDEX)
    mask = labels != IGNORE_INDEX
    safe = jnp.where(mask, labels, 0)
    # Vocab-shard-friendly cross entropy: no take_along_axis gather (which
    # would force SPMD to all-gather the [B,S,V] logits when the head is
    # vocab-parallel). logsumexp reduces over the sharded axis; the label
    # logit comes from a fused one-hot contraction.
    z = jax.scipy.special.logsumexp(logits, axis=-1)
    onehot = jax.nn.one_hot(safe, logits.shape[-1], dtype=logits.dtype)
    label_logit = jnp.einsum("bsv,bsv->bs", logits, onehot)
    nll = z - label_logit
    denom = jnp.maximum(mask.sum(), 1)
    ce = jnp.where(mask, nll, 0.0).sum() / denom
    # z-loss (stabilizes the fp32 logits against drift)
    zl = jnp.where(mask, z**2, 0.0).sum() / denom
    total = ce + aux_weight * aux + z_weight * zl
    return total, {"ce": ce, "aux": aux, "z_loss": zl}


def prefill(params: Params, cfg: ModelConfig, batch: Dict[str, jnp.ndarray],
            max_len: int) -> Tuple[jnp.ndarray, Cache]:
    B = (batch["embeds"] if cfg.frontend == "audio_stub" else batch["tokens"]).shape[0]
    cache = init_cache(cfg, B, max_len)
    logits, cache, _ = forward(params, cfg, batch, cache=cache)
    return logits, cache


def decode_step(params: Params, cfg: ModelConfig, cache: Cache,
                inputs: Dict[str, jnp.ndarray], pos) -> Tuple[jnp.ndarray, Cache]:
    """One token for the whole batch. inputs: {"tokens": [B,1]} or
    {"embeds": [B,1,d]}; pos: scalar int32 position of this token."""
    positions = jnp.asarray(pos, jnp.int32).reshape(1)
    logits, cache, _ = forward(params, cfg, inputs, cache=cache, positions=positions)
    return logits, cache


class Model(NamedTuple):
    """Convenience bundle used by examples and the launcher."""
    cfg: ModelConfig

    def init(self, rng) -> Params:
        return init_params(rng, self.cfg)

    def loss(self, params, batch, remat="none"):
        return loss_fn(params, self.cfg, batch, remat=remat)

    def decode(self, params, cache, inputs, pos):
        return decode_step(params, self.cfg, cache, inputs, pos)
