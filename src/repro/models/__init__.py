"""Model substrate: composable decoder-only LM blocks in pure JAX.

Every assigned architecture is expressed as a `ModelConfig` (see
repro.configs) consumed by `repro.models.transformer`:

* temporal mixers: full/local GQA attention (w/ RoPE, softcaps, sinks),
  MLA (latent KV), RG-LRU (Griffin), mLSTM / sLSTM (xLSTM)
* channel mixers: SwiGLU / GeGLU / GELU FFN, fine-grained MoE with shared
  + routed experts (DeepSeekMoE / DBRX style)
* scan-over-layers (period-aware for interleaved block patterns) so HLO
  size and compile time are depth-independent
* KV cache / recurrent-state decode path (`init_cache`, `decode_step`)
"""

from repro.models.transformer import (
    Model,
    init_params,
    forward,
    loss_fn,
    init_cache,
    prefill,
    decode_step,
)

__all__ = [
    "Model",
    "init_params",
    "forward",
    "loss_fn",
    "init_cache",
    "prefill",
    "decode_step",
]
