"""Channel mixers: gated FFNs and fine-grained MoE (shared + routed).

The MoE dispatch is the TPU-native sort-based formulation: tokens are
grouped (one group per batch row, so dispatch stays local to the data
shard — no global sort collectives), sorted by routed expert, gathered to
a fixed [E, C] capacity layout, processed with grouped einsums that shard
cleanly over the `model` axis (expert parallelism), and scattered back
with combine weights.  Capacity overflow drops tokens (GShard semantics);
the router returns load-balance aux stats so the training loss can add
the standard auxiliary term.
"""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np

from repro.configs.base import ModelConfig
from repro.models.common import dense_init

_CAPACITY_FACTOR = 1.25
ANALYSIS_VMAP_GROUPS = False  # dry-run cost accounting (launch/dryrun.py)


def _act(kind: str, x):
    if kind in ("swiglu",):
        return jax.nn.silu(x)
    if kind in ("geglu", "gelu"):
        return jax.nn.gelu(x)
    raise ValueError(kind)


# ---------------------------------------------------------------------------
# Dense FFN
# ---------------------------------------------------------------------------


def init_ffn(key, cfg: ModelConfig, d_ff: Optional[int] = None) -> dict:
    d, f = cfg.d_model, d_ff or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    k1, k2, k3 = jax.random.split(key, 3)
    if cfg.ffn_kind in ("swiglu", "geglu"):
        return {
            "w_gate": dense_init(k1, (d, f), in_axis=0, dtype=dt),
            "w_up": dense_init(k2, (d, f), in_axis=0, dtype=dt),
            "w_down": dense_init(k3, (f, d), in_axis=0, dtype=dt),
        }
    return {  # plain gelu MLP (musicgen backbone)
        "w_up": dense_init(k1, (d, f), in_axis=0, dtype=dt),
        "w_down": dense_init(k2, (f, d), in_axis=0, dtype=dt),
    }


def apply_ffn(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> jnp.ndarray:
    if "w_gate" in params:
        g = _act(cfg.ffn_kind, jnp.einsum("bsd,df->bsf", x, params["w_gate"].astype(x.dtype)))
        u = jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype))
        return jnp.einsum("bsf,fd->bsd", g * u, params["w_down"].astype(x.dtype))
    h = _act("gelu", jnp.einsum("bsd,df->bsf", x, params["w_up"].astype(x.dtype)))
    return jnp.einsum("bsf,fd->bsd", h, params["w_down"].astype(x.dtype))


# ---------------------------------------------------------------------------
# MoE
# ---------------------------------------------------------------------------


def init_moe(key, cfg: ModelConfig) -> dict:
    e = cfg.moe
    d = cfg.d_model
    f = e.d_ff_expert or cfg.d_ff
    dt = jnp.dtype(cfg.param_dtype)
    ks = jax.random.split(key, 5)
    params = {
        "router": dense_init(ks[0], (d, e.n_experts), in_axis=0, dtype=dt),
        "w_gate": dense_init(ks[1], (e.n_experts, d, f), in_axis=1, dtype=dt),
        "w_up": dense_init(ks[2], (e.n_experts, d, f), in_axis=1, dtype=dt),
        "w_down": dense_init(ks[3], (e.n_experts, f, d), in_axis=1, dtype=dt),
    }
    if e.n_shared:
        params["shared"] = init_ffn(ks[4], cfg, d_ff=f * e.n_shared)
    return params


def moe_capacity(cfg: ModelConfig, group_size: int) -> int:
    e = cfg.moe
    c = int(np.ceil(e.top_k * group_size * _CAPACITY_FACTOR / e.n_experts))
    return max(8, min(c + (-c) % 8, group_size))  # 8-aligned, <= group


MOE_XE_SPEC = None     # set by the launcher: NamedSharding for [G, E, C, d]
MOE_XG_SPEC = None     # set by the launcher: NamedSharding for [G, Sg, d]
                       # (pins the B,S->G,Sg reshape; without it SPMD
                       # all-gathers the full activation at the reshape)
MOE_CHUNKS = 1         # group-chunks processed per map step (memory knob)
MOE_GROUP = 512        # tokens per dispatch group (smaller -> smaller C,
                       # quadratically less dispatch-tensor traffic)
MOE_DISPATCH_DTYPE = "float32"  # "bfloat16" halves dispatch/combine bytes


def moe_groups(total_tokens: int) -> Tuple[int, int]:
    """(n_groups, group_size): ~512-token groups, at least 16 groups so the
    dispatch shards over the data axis even at decode shapes."""
    sg = min(MOE_GROUP, max(1, total_tokens // 16))
    while total_tokens % sg:
        sg -= 1
    return total_tokens // sg, sg


def _gshard_dispatch(cfg, top_e, top_p, C):
    """GShard one-hot dispatch/combine tensors — matmul-only, no
    sort/scatter (SPMD-partitionable along the group axis).

    top_e/top_p: [G, Sg, k] -> dispatch [G,Sg,E,C] (0/1), combine (weighted).
    Tokens beyond an expert's capacity C within a group are dropped.
    """
    e = cfg.moe
    G, Sg, k = top_e.shape
    E = e.n_experts
    counts = jnp.zeros((G, E), jnp.float32)
    dispatch = jnp.zeros((G, Sg, E, C), jnp.float32)
    combine = jnp.zeros((G, Sg, E, C), jnp.float32)
    for j in range(k):
        mask = jax.nn.one_hot(top_e[..., j], E, dtype=jnp.float32)  # [G,Sg,E]
        pos = counts[:, None, :] + jnp.cumsum(mask, axis=1) - mask   # rank
        pos_tok = jnp.einsum("gse,gse->gs", pos, mask)               # [G,Sg]
        within = (pos_tok < C).astype(jnp.float32)
        oh_pos = jax.nn.one_hot(pos_tok, C, dtype=jnp.float32)       # [G,Sg,C]
        disp_j = jnp.einsum("gse,gsc->gsec", mask, oh_pos * within[..., None])
        dispatch = dispatch + disp_j
        combine = combine + disp_j * top_p[..., j][..., None, None]
        counts = counts + mask.sum(axis=1)
    dt = jnp.dtype(MOE_DISPATCH_DTYPE)
    return dispatch.astype(dt), combine.astype(dt)


def apply_moe(params: dict, cfg: ModelConfig, x: jnp.ndarray) -> Tuple[jnp.ndarray, dict]:
    """x: [B, S, d] -> (y, aux).

    TPU-native MoE (GShard/MaxText lineage): tokens form ~512-token groups;
    a one-hot dispatch einsum gathers them into the [G, E, C, d] capacity
    layout, which is sharding-constrained to expert-parallel layout (E over
    `model`) so the partitioner emits activation all-to-alls instead of
    gathering expert weights.  Group-chunks run under a checkpointed
    lax.map to bound dispatch memory; the dry-run analysis mode processes
    all groups at once so scan-once FLOP accounting stays exact.
    """
    e = cfg.moe
    B, S, d = x.shape
    E, k = e.n_experts, e.top_k
    total = B * S
    G, Sg = moe_groups(total)
    C = moe_capacity(cfg, Sg)

    logits = jnp.einsum("bsd,de->bse", x, params["router"].astype(x.dtype))
    logits = logits.astype(jnp.float32)
    if e.router_softcap:
        logits = e.router_softcap * jnp.tanh(logits / e.router_softcap)
    probs = jax.nn.softmax(logits, axis=-1)
    top_p, top_e = jax.lax.top_k(probs, k)                     # [B,S,k]
    top_p = top_p / jnp.maximum(top_p.sum(-1, keepdims=True), 1e-9)

    xg = x.reshape(G, Sg, d)
    eg = top_e.reshape(G, Sg, k)
    pg = top_p.reshape(G, Sg, k)
    if MOE_XG_SPEC is not None:
        xg = jax.lax.with_sharding_constraint(xg, MOE_XG_SPEC)

    def process(args):
        xg, eg, pg = args
        dispatch, combine = _gshard_dispatch(cfg, eg, pg, C)
        xe = jnp.einsum("gsec,gsd->gecd", dispatch.astype(x.dtype), xg)
        if MOE_XE_SPEC is not None:                             # -> EP layout
            xe = jax.lax.with_sharding_constraint(xe, MOE_XE_SPEC)
        ge = _act(cfg.ffn_kind, jnp.einsum(
            "gecd,edf->gecf", xe, params["w_gate"].astype(x.dtype)))
        ue = jnp.einsum("gecd,edf->gecf", xe, params["w_up"].astype(x.dtype))
        ye = jnp.einsum("gecf,efd->gecd", ge * ue, params["w_down"].astype(x.dtype))
        if MOE_XE_SPEC is not None:
            ye = jax.lax.with_sharding_constraint(ye, MOE_XE_SPEC)
        yg = jnp.einsum("gsec,gecd->gsd", combine.astype(x.dtype), ye)
        if MOE_XG_SPEC is not None and yg.ndim == 3:
            yg = jax.lax.with_sharding_constraint(yg, MOE_XG_SPEC)
        return yg

    if ANALYSIS_VMAP_GROUPS or MOE_CHUNKS <= 1 or G % MOE_CHUNKS:
        y = process((xg, eg, pg)).reshape(B, S, d)
    else:
        gc = G // MOE_CHUNKS
        xs = (xg.reshape(MOE_CHUNKS, gc, Sg, d),
              eg.reshape(MOE_CHUNKS, gc, Sg, k),
              pg.reshape(MOE_CHUNKS, gc, Sg, k))
        y = jax.lax.map(jax.checkpoint(process, prevent_cse=False),
                        xs).reshape(B, S, d)

    # load-balance aux (Switch-style): E * sum_e f_e * p_e
    frac_tokens = jnp.mean(
        jax.nn.one_hot(top_e, E, dtype=jnp.float32).sum(2), axis=(0, 1))
    frac_probs = probs.mean((0, 1))
    aux = {"load_balance_loss": E * jnp.sum(frac_tokens / k * frac_probs)}

    if "shared" in params:
        y = y + apply_ffn(params["shared"], cfg, x)
    return y, aux
