"""Shared model components: norms, RoPE, initializers, dtype policy."""

from __future__ import annotations

from typing import Optional, Tuple

import jax
import jax.numpy as jnp
import numpy as np


# ---------------------------------------------------------------------------
# Initializers
# ---------------------------------------------------------------------------


def dense_init(key, shape, in_axis: int = -2, dtype=jnp.float32):
    """Truncated-normal fan-in init (He-style, matches MaxText defaults)."""
    fan_in = shape[in_axis]
    std = 1.0 / np.sqrt(fan_in)
    return (jax.random.truncated_normal(key, -2.0, 2.0, shape, jnp.float32) * std).astype(dtype)


def embed_init(key, shape, dtype=jnp.float32):
    return (jax.random.normal(key, shape, jnp.float32) * 0.02).astype(dtype)


# ---------------------------------------------------------------------------
# Norms
# ---------------------------------------------------------------------------


def init_norm(d: int, style: str = "rmsnorm", dtype=jnp.float32) -> dict:
    p = {"scale": jnp.zeros((d,), dtype) if style == "rmsnorm_unit" else jnp.ones((d,), dtype)}
    if style == "layernorm":
        p["bias"] = jnp.zeros((d,), dtype)
    return p


def apply_norm(p: dict, x: jnp.ndarray, style: str = "rmsnorm", eps: float = 1e-6) -> jnp.ndarray:
    xf = x.astype(jnp.float32)
    if style == "layernorm":
        mu = xf.mean(-1, keepdims=True)
        var = ((xf - mu) ** 2).mean(-1, keepdims=True)
        out = (xf - mu) * jax.lax.rsqrt(var + eps) * p["scale"] + p["bias"]
    else:
        var = (xf**2).mean(-1, keepdims=True)
        scale = p["scale"].astype(jnp.float32)
        if style == "rmsnorm_unit":  # gemma zero-centered weights: (1 + w)
            scale = 1.0 + scale
        out = xf * jax.lax.rsqrt(var + eps) * scale
    return out.astype(x.dtype)


# ---------------------------------------------------------------------------
# Rotary position embeddings
# ---------------------------------------------------------------------------


def rope_freqs(head_dim: int, base: float = 10_000.0) -> jnp.ndarray:
    half = head_dim // 2
    return 1.0 / (base ** (jnp.arange(0, half, dtype=jnp.float32) / half))


def apply_rope(x: jnp.ndarray, positions: jnp.ndarray, base: float = 10_000.0) -> jnp.ndarray:
    """x: [..., S, n_heads, head_dim]; positions: [..., S] (broadcastable)."""
    half = x.shape[-1] // 2
    freqs = rope_freqs(x.shape[-1], base)  # [half]
    angles = positions[..., :, None].astype(jnp.float32) * freqs  # [..., S, half]
    cos = jnp.cos(angles)[..., :, None, :]  # [..., S, 1, half]
    sin = jnp.sin(angles)[..., :, None, :]
    x1, x2 = x[..., :half], x[..., half:]
    out = jnp.concatenate(
        [x1 * cos - x2 * sin, x2 * cos + x1 * sin], axis=-1
    )
    return out.astype(x.dtype)


def sinusoidal_positions(seq_len: int, d_model: int, offset: int = 0) -> jnp.ndarray:
    """Classic transformer sinusoidal table (musicgen backbone)."""
    pos = np.arange(offset, offset + seq_len)[:, None]
    dim = np.arange(d_model // 2)[None, :]
    angle = pos / np.power(10_000.0, 2 * dim / d_model)
    out = np.concatenate([np.sin(angle), np.cos(angle)], axis=-1)
    return jnp.asarray(out, dtype=jnp.float32)


# ---------------------------------------------------------------------------
# Misc
# ---------------------------------------------------------------------------


def softcap(x: jnp.ndarray, cap: float) -> jnp.ndarray:
    """Gemma-2 logit soft-capping: cap * tanh(x / cap)."""
    return (cap * jnp.tanh(x.astype(jnp.float32) / cap)).astype(x.dtype)


def take_block(stacked, idx: int):
    """Slice one layer's params out of a stacked [n, ...] tree."""
    return jax.tree_util.tree_map(lambda a: a[idx], stacked)


def big_neg(dtype) -> float:
    return float(jnp.finfo(dtype).min) * 0.5
