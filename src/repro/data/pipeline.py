"""Training data pipeline backed by the LoPace PromptStore.

The corpus lives compressed (hybrid method).  The loader decompresses to
token ids directly (token-stream storage mode — the paper's §8.4.2 #10),
packs them into fixed-length example windows, and yields deterministic,
host-sharded, resumable batches:

* determinism: example order is a seeded permutation of window indices;
  batch i is a pure function of (seed, step) — restart-safe;
* host sharding: each data-parallel host takes a strided slice of every
  global batch (shard_id, num_shards);
* resume: `state()`/`restore()` round-trip the step counter through the
  checkpoint `extra` dict (repro.dist.checkpoint).
"""

from __future__ import annotations

from dataclasses import dataclass
from typing import Dict, Iterator, List, Optional

import numpy as np

from repro.core.store import PromptStore, ShardedPromptStore
from repro.models.transformer import IGNORE_INDEX


@dataclass
class PipelineConfig:
    seq_len: int = 1024
    global_batch: int = 8
    seed: int = 0
    shard_id: int = 0
    num_shards: int = 1
    pad_id: int = 0


class TokenPipeline:
    def __init__(self, store: ShardedPromptStore, cfg: PipelineConfig):
        assert cfg.global_batch % cfg.num_shards == 0
        self.cfg = cfg
        # Concatenate every stored prompt's token stream (decompressed via
        # the token-stream path — never re-tokenized).
        streams = [np.asarray(t, np.int64) for t in store.iter_tokens()]
        if not streams:
            raise ValueError("empty PromptStore")
        tokens = np.concatenate(streams)
        n_windows = (tokens.size - 1) // cfg.seq_len
        if n_windows < 1:
            raise ValueError("corpus smaller than one window")
        self._inputs = tokens[: n_windows * cfg.seq_len].reshape(
            n_windows, cfg.seq_len)
        self._labels = tokens[1 : n_windows * cfg.seq_len + 1].reshape(
            n_windows, cfg.seq_len)
        self.n_windows = n_windows
        self._step = 0

    # -- determinism / resume -------------------------------------------------

    def state(self) -> Dict[str, int]:
        return {"step": self._step, "seed": self.cfg.seed,
                "seq_len": self.cfg.seq_len,
                "global_batch": self.cfg.global_batch,
                "n_windows": self.n_windows}

    def restore(self, state: Dict[str, int]) -> None:
        assert state["seed"] == self.cfg.seed, "resume with a different seed"
        # resuming with different batch geometry or against a different
        # corpus silently changes the data order — refuse instead
        # (n_windows is the corpus fingerprint the permutation ranges over)
        for key, have in (("seq_len", self.cfg.seq_len),
                          ("global_batch", self.cfg.global_batch),
                          ("n_windows", self.n_windows)):
            if key in state and int(state[key]) != have:
                raise ValueError(
                    f"pipeline resume mismatch: checkpoint {key}="
                    f"{state[key]}, this pipeline has {key}={have}")
        self._step = int(state["step"])

    def _order_for_epoch(self, epoch: int) -> np.ndarray:
        rng = np.random.default_rng((self.cfg.seed, epoch))
        return rng.permutation(self.n_windows)

    # -- batches ---------------------------------------------------------------

    def batch_at(self, step: int) -> Dict[str, np.ndarray]:
        """Global batch `step` (pure function of step — restart-safe),
        sliced down to this host's shard."""
        gb, ns = self.cfg.global_batch, self.cfg.num_shards
        per_epoch = max(self.n_windows // gb, 1)
        epoch, pos = divmod(step, per_epoch)
        order = self._order_for_epoch(epoch)
        idx = order[(pos * gb) % self.n_windows:][:gb]
        if idx.size < gb:  # wrap
            idx = np.concatenate([idx, order[: gb - idx.size]])
        shard = idx[self.cfg.shard_id::ns]
        return {"tokens": self._inputs[shard], "labels": self._labels[shard]}

    def __iter__(self) -> Iterator[Dict[str, np.ndarray]]:
        return self

    def __next__(self) -> Dict[str, np.ndarray]:
        b = self.batch_at(self._step)
        self._step += 1
        return b

    def with_accum(self, batch: Dict[str, np.ndarray], grad_accum: int
                   ) -> Dict[str, np.ndarray]:
        """Reshape [B, S] -> [accum, B/accum, S] for the scan-accum step."""
        out = {}
        for k, v in batch.items():
            b = v.shape[0]
            assert b % grad_accum == 0
            out[k] = v.reshape(grad_accum, b // grad_accum, *v.shape[1:])
        return out


def build_store_from_corpus(root, n_prompts: int = 64, seed: int = 0,
                            method: str = "hybrid",
                            n_shards: int = 4,
                            async_ingest: bool = False,
                            ingest_batch: int = 32) -> ShardedPromptStore:
    """Helper used by examples/tests: synthesize corpus -> compress -> store.

    Writes are batch-first: one `put_many` group commit over the whole
    corpus (one fsync per shard, not per prompt).  With `async_ingest`
    the corpus flows through the service tier's ingest queue instead —
    `ingest_batch`-sized submissions, per-shard writer threads committing
    in parallel — and the store is drained before it is returned."""
    from repro.core.api import PromptCompressor
    from repro.data.corpus import generate_corpus
    from repro.tokenizer.vocab import default_tokenizer

    store = ShardedPromptStore(root, PromptCompressor(default_tokenizer(), method=method),
                               n_shards=n_shards)
    texts = [p.text for p in generate_corpus(n_prompts, seed=seed)]
    if async_ingest:
        from repro.service.ingest import IngestQueue

        with IngestQueue(store, flush_batch=ingest_batch) as q:
            tickets = [q.submit(texts[i:i + ingest_batch])
                       for i in range(0, len(texts), ingest_batch)]
            q.drain()
        for t in tickets:
            t.wait(0)  # surface any commit error
    else:
        store.put_many(texts)
    return store
