"""Data substrate: synthetic corpus generation and the sharded,
LoPace-compressed training data pipeline."""

from repro.data.corpus import Prompt, generate_corpus, corpus_stats

__all__ = ["Prompt", "generate_corpus", "corpus_stats"]
