"""Synthetic evaluation corpus matching the paper's dataset statistics.

The paper evaluates on 386 prompts from a markdown-docs dataset
(philschmid/markdown-docs-transformers, unavailable offline) with:

* content mix: code 82.6 %, markdown 16.8 %, plain text 0.5 %  (§4.1)
* log-normal size distribution: min 129, median 20 803, mean 30 982,
  max 213 379 characters (§4.1, Fig. 3/4)

We regenerate a corpus with the same mix and the same log-normal law
(mu = ln 20803, sigma derived from mean/median ratio), clipped to the
paper's min/max.  Content is template-based technical material (python
code with API/doc patterns, markdown documentation, prose) so redundancy
structure — the thing compression ratios actually measure — resembles the
paper's code-heavy documentation corpus.  Fully deterministic per seed.
"""

from __future__ import annotations

import math
from dataclasses import dataclass
from typing import List

import numpy as np

# Log-normal parameters derived from the paper's summary statistics.
_MU = math.log(20_803.0)                       # median
_SIGMA = math.sqrt(2.0 * math.log(30_982.0 / 20_803.0))  # mean/median ratio
_MIN_CHARS, _MAX_CHARS = 129, 213_379

_CONTENT_MIX = (("code", 0.826), ("markdown", 0.168), ("text", 0.006))


@dataclass(frozen=True)
class Prompt:
    pid: int
    kind: str  # code | markdown | text
    text: str

    @property
    def n_chars(self) -> int:
        return len(self.text)


# ---------------------------------------------------------------------------
# Vocabulary pools for template generation
# ---------------------------------------------------------------------------

_IDENTIFIERS = [
    "model", "config", "tokenizer", "batch", "sequence", "attention", "hidden",
    "layer", "output", "input_ids", "logits", "embedding", "cache", "state",
    "params", "gradients", "optimizer", "learning_rate", "checkpoint", "dataset",
    "pipeline", "request", "response", "prompt", "context", "window", "mask",
    "head", "query", "key", "value", "projection", "norm", "residual", "buffer",
]

_TYPES = ["int", "float", "str", "bool", "Tensor", "Array", "Optional[int]",
          "List[str]", "Dict[str, Any]", "np.ndarray"]

_VERBS = ["compute", "apply", "build", "load", "save", "encode", "decode",
          "compress", "validate", "initialize", "update", "merge", "split",
          "shard", "gather", "scatter", "prefetch", "tokenize"]

_NOUNS = ["compression ratio", "space savings", "throughput", "memory footprint",
          "token sequence", "binary payload", "format byte", "vocabulary",
          "sliding window", "entropy coder", "checkpoint shard", "device mesh",
          "attention head", "expert router", "KV cache", "prompt store"]

_SENTS = [
    "The {n} is computed from the compressed representation before storage.",
    "Large language model applications must {v} the {n} without loss.",
    "We {v} the {n} and verify bit-perfect reconstruction via SHA-256.",
    "This configuration controls how the system will {v} each {n}.",
    "Higher levels favor ratio over speed when we {v} the {n}.",
    "Production deployments should {v} the {n} before each release.",
    "The {n} scales sub-linearly with input size across the evaluated range.",
    "Decompression of the {n} consistently outperforms compression.",
]


def _rng_choice(rng: np.random.Generator, pool: List[str]) -> str:
    return pool[int(rng.integers(0, len(pool)))]


def _gen_sentence(rng: np.random.Generator) -> str:
    t = _rng_choice(rng, _SENTS)
    return t.replace("{v}", _rng_choice(rng, _VERBS)).replace("{n}", _rng_choice(rng, _NOUNS))


def _gen_function(rng: np.random.Generator) -> str:
    name = f"{_rng_choice(rng, _VERBS)}_{_rng_choice(rng, _IDENTIFIERS)}"
    args = ", ".join(
        f"{_rng_choice(rng, _IDENTIFIERS)}: {_rng_choice(rng, _TYPES)}"
        for _ in range(int(rng.integers(1, 4)))
    )
    ret = _rng_choice(rng, _TYPES)
    body_var = _rng_choice(rng, _IDENTIFIERS)
    lines = [
        f"def {name}({args}) -> {ret}:",
        f'    """{_gen_sentence(rng)}"""',
    ]
    for _ in range(int(rng.integers(2, 7))):
        lhs = _rng_choice(rng, _IDENTIFIERS)
        rhs = _rng_choice(rng, _IDENTIFIERS)
        op = _rng_choice(rng, ["+", "*", "//", "-"])
        lines.append(f"    {lhs} = {rhs} {op} {int(rng.integers(1, 128))}")
    lines.append(f"    if {body_var} is None:")
    lines.append(f"        raise ValueError(\"{body_var} must be provided\")")
    lines.append(f"    return {body_var}")
    return "\n".join(lines)


def _gen_class(rng: np.random.Generator) -> str:
    cname = "".join(w.capitalize() for w in
                    [_rng_choice(rng, _VERBS), _rng_choice(rng, _IDENTIFIERS)])
    lines = [f"class {cname}:", f'    """{_gen_sentence(rng)}"""', ""]
    for _ in range(int(rng.integers(1, 4))):
        lines.append(_indent(_gen_function(rng), 4))
        lines.append("")
    return "\n".join(lines)


def _indent(block: str, n: int) -> str:
    pad = " " * n
    return "\n".join(pad + ln if ln else ln for ln in block.split("\n"))


def _gen_code(rng: np.random.Generator, target_chars: int) -> str:
    parts = [
        "import numpy as np",
        "from typing import Any, Dict, List, Optional",
        "",
    ]
    size = sum(len(p) + 1 for p in parts)
    while size < target_chars:
        block = _gen_class(rng) if rng.random() < 0.3 else _gen_function(rng)
        parts.append(block)
        parts.append("")
        size += len(block) + 2
    return "\n".join(parts)[:max(target_chars, _MIN_CHARS)]


def _gen_markdown(rng: np.random.Generator, target_chars: int) -> str:
    parts = [f"# {_rng_choice(rng, _NOUNS).title()} Guide", ""]
    size = sum(len(p) + 1 for p in parts)
    section = 0
    while size < target_chars:
        section += 1
        parts.append(f"## {section}. {_rng_choice(rng, _VERBS).title()} the "
                     f"{_rng_choice(rng, _NOUNS).title()}")
        parts.append("")
        for _ in range(int(rng.integers(2, 5))):
            parts.append(_gen_sentence(rng))
        parts.append("")
        if rng.random() < 0.5:
            parts.append("```python")
            parts.append(_gen_function(rng))
            parts.append("```")
            parts.append("")
        if rng.random() < 0.4:
            for _ in range(int(rng.integers(2, 6))):
                parts.append(f"- **{_rng_choice(rng, _NOUNS)}**: {_gen_sentence(rng)}")
            parts.append("")
        if rng.random() < 0.25:
            parts.append(f"See [the {_rng_choice(rng, _NOUNS)} docs]"
                         f"(https://docs.example.com/{_rng_choice(rng, _IDENTIFIERS)}).")
            parts.append("")
        size = sum(len(p) + 1 for p in parts)
    return "\n".join(parts)[:max(target_chars, _MIN_CHARS)]


def _gen_text(rng: np.random.Generator, target_chars: int) -> str:
    parts: List[str] = []
    size = 0
    while size < target_chars:
        para = " ".join(_gen_sentence(rng) for _ in range(int(rng.integers(3, 8))))
        parts.append(para)
        size += len(para) + 2
    return "\n\n".join(parts)[:max(target_chars, _MIN_CHARS)]


_GENERATORS = {"code": _gen_code, "markdown": _gen_markdown, "text": _gen_text}


# ---------------------------------------------------------------------------
# Public API
# ---------------------------------------------------------------------------


def generate_corpus(n_prompts: int = 386, seed: int = 0) -> List[Prompt]:
    """Deterministic synthetic corpus with the paper's size/type statistics."""
    rng = np.random.default_rng(seed)
    kinds: List[str] = []
    for kind, frac in _CONTENT_MIX:
        kinds.extend([kind] * max(1, round(frac * n_prompts)))
    kinds = kinds[:n_prompts]
    while len(kinds) < n_prompts:
        kinds.append("code")
    rng.shuffle(kinds)  # type: ignore[arg-type]

    sizes = np.clip(
        rng.lognormal(mean=_MU, sigma=_SIGMA, size=n_prompts),
        _MIN_CHARS, _MAX_CHARS,
    ).astype(int)
    # pin the extremes so the evaluated range matches the paper exactly
    if n_prompts >= 2:
        sizes[int(np.argmin(sizes))] = _MIN_CHARS
        sizes[int(np.argmax(sizes))] = _MAX_CHARS

    prompts = []
    for pid, (kind, target) in enumerate(zip(kinds, sizes)):
        text = _GENERATORS[kind](rng, int(target))
        # sprinkle special-token markers on a subset (exercises uint32 path)
        if pid % 9 == 0:
            text = "<|system|>\n" + text + "\n<|endofprompt|>"
        prompts.append(Prompt(pid=pid, kind=kind, text=text))
    return prompts


def corpus_stats(prompts: List[Prompt]) -> dict:
    """Summary statistics in the shape of the paper's §4.1 EDA table."""
    sizes = np.array([p.n_chars for p in prompts])
    kinds = {}
    for p in prompts:
        kinds[p.kind] = kinds.get(p.kind, 0) + 1
    pct = {f"P{q}": float(np.percentile(sizes, q)) for q in (10, 25, 50, 75, 90, 95, 99)}
    return {
        "n_prompts": len(prompts),
        "min": int(sizes.min()),
        "max": int(sizes.max()),
        "mean": float(sizes.mean()),
        "median": float(np.median(sizes)),
        "std": float(sizes.std()),
        "percentiles": pct,
        "content_mix": {k: v / len(prompts) for k, v in sorted(kinds.items())},
    }
