"""Training substrate: pure-JAX AdamW, train-step factory, serving loop."""

from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state, lr_schedule
from repro.train.train_loop import init_train_state, make_train_step

__all__ = ["AdamWConfig", "adamw_update", "init_opt_state", "lr_schedule",
           "make_train_step", "init_train_state"]
