"""Pure-JAX AdamW with global-norm clipping and warmup+cosine schedule
(optax is not available offline; this is the full substrate)."""

from __future__ import annotations

from dataclasses import dataclass
from typing import Any, Dict, NamedTuple, Tuple

import jax
import jax.numpy as jnp


@dataclass(frozen=True)
class AdamWConfig:
    lr: float = 3e-4
    b1: float = 0.9
    b2: float = 0.95
    eps: float = 1e-8
    weight_decay: float = 0.1
    clip_norm: float = 1.0
    warmup_steps: int = 100
    total_steps: int = 10_000
    min_lr_ratio: float = 0.1


def lr_schedule(cfg: AdamWConfig, step: jnp.ndarray) -> jnp.ndarray:
    step = step.astype(jnp.float32)
    warm = step / jnp.maximum(cfg.warmup_steps, 1)
    decay_steps = jnp.maximum(cfg.total_steps - cfg.warmup_steps, 1)
    t = jnp.clip((step - cfg.warmup_steps) / decay_steps, 0.0, 1.0)
    cos = cfg.min_lr_ratio + (1 - cfg.min_lr_ratio) * 0.5 * (1 + jnp.cos(jnp.pi * t))
    return cfg.lr * jnp.where(step < cfg.warmup_steps, warm, cos)


def init_opt_state(params: Any) -> Dict[str, Any]:
    zeros = lambda: jax.tree_util.tree_map(
        lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return {"m": zeros(), "v": zeros(), "step": jnp.zeros((), jnp.int32)}


def global_norm(tree: Any) -> jnp.ndarray:
    return jnp.sqrt(sum(jnp.sum(l.astype(jnp.float32) ** 2)
                        for l in jax.tree_util.tree_leaves(tree)))


def adamw_update(
    cfg: AdamWConfig, grads: Any, opt_state: Dict[str, Any], params: Any,
) -> Tuple[Any, Dict[str, Any], Dict[str, jnp.ndarray]]:
    step = opt_state["step"] + 1
    gnorm = global_norm(grads)
    scale = jnp.minimum(1.0, cfg.clip_norm / jnp.maximum(gnorm, 1e-9))
    lr = lr_schedule(cfg, step)
    b1c = 1 - cfg.b1 ** step.astype(jnp.float32)
    b2c = 1 - cfg.b2 ** step.astype(jnp.float32)

    def upd(g, m, v, p):
        g = g.astype(jnp.float32) * scale
        m = cfg.b1 * m + (1 - cfg.b1) * g
        v = cfg.b2 * v + (1 - cfg.b2) * g * g
        mh = m / b1c
        vh = v / b2c
        delta = mh / (jnp.sqrt(vh) + cfg.eps) + cfg.weight_decay * p.astype(jnp.float32)
        return (p.astype(jnp.float32) - lr * delta).astype(p.dtype), m, v

    flat_p, treedef = jax.tree_util.tree_flatten(params)
    flat_g = treedef.flatten_up_to(grads)
    flat_m = treedef.flatten_up_to(opt_state["m"])
    flat_v = treedef.flatten_up_to(opt_state["v"])
    new = [upd(g, m, v, p) for g, m, v, p in zip(flat_g, flat_m, flat_v, flat_p)]
    new_p = treedef.unflatten([t[0] for t in new])
    new_m = treedef.unflatten([t[1] for t in new])
    new_v = treedef.unflatten([t[2] for t in new])
    metrics = {"grad_norm": gnorm, "lr": lr}
    return new_p, {"m": new_m, "v": new_v, "step": step}, metrics
