"""Training step factory: grad accumulation, remat policy, mixed precision,
optional int8 error-feedback gradient compression on the DP axis.

`make_train_step(cfg, opt_cfg, ...)` returns a pure function
(params, opt_state, batch) -> (params, opt_state, metrics) suitable for
jax.jit with in/out shardings from repro.dist.sharding — this is exactly
what the dry-run lowers for the `train_4k` cells.
"""

from __future__ import annotations

from functools import partial
from typing import Any, Callable, Dict, Optional, Tuple

import jax
import jax.numpy as jnp

from repro.configs.base import ModelConfig
from repro.models.transformer import loss_fn
from repro.train.optimizer import AdamWConfig, adamw_update, init_opt_state


def make_train_step(
    cfg: ModelConfig,
    opt_cfg: AdamWConfig,
    *,
    remat: str = "dots",
    grad_accum: int = 1,
    compress_grads: bool = False,  # requires repro.dist.collectives
    unroll: bool = False,
) -> Callable:
    """Returns train_step(params, opt_state, batch) -> (params, opt, metrics).

    With grad_accum > 1 the batch leaves must have leading dims
    [grad_accum, micro_batch, ...]; microbatches run under lax.scan so the
    lowered HLO stays accumulation-depth independent.
    """

    def grads_of(params, batch):
        (loss, parts), grads = jax.value_and_grad(
            loss_fn, has_aux=True)(params, cfg, batch, remat=remat,
                                   unroll=unroll)
        return loss, parts, grads

    def train_step(params, opt_state, batch):
        if grad_accum > 1:
            def micro(carry, mb):
                acc, loss_acc = carry
                loss, parts, grads = grads_of(params, mb)
                acc = jax.tree_util.tree_map(
                    lambda a, g: a + g.astype(jnp.float32), acc, grads)
                return (acc, loss_acc + loss), parts

            zero = jax.tree_util.tree_map(
                lambda p: jnp.zeros(p.shape, jnp.float32), params)
            (gsum, loss_sum), parts_stack = jax.lax.scan(micro, (zero, 0.0), batch)
            grads = jax.tree_util.tree_map(lambda g: g / grad_accum, gsum)
            loss = loss_sum / grad_accum
            # per-part losses averaged over microbatches so the metrics
            # dict matches the grad_accum == 1 path key-for-key
            parts = jax.tree_util.tree_map(lambda p: p.mean(0), parts_stack)
        else:
            loss, parts, grads = grads_of(params, batch)

        if compress_grads:
            # int8 error-feedback: quantization residual is re-added next
            # step via the opt_state["ef"] carry (1-bit-Adam/EF-SGD style).
            # Imported lazily: repro.dist is optional until the distributed
            # layer lands (ROADMAP open items), and only this branch needs it.
            from repro.dist.collectives import ef_compress_tree

            grads, ef = ef_compress_tree(grads, opt_state.get("ef"))
        new_params, new_opt, metrics = adamw_update(opt_cfg, grads, opt_state, params)
        if compress_grads:
            new_opt["ef"] = ef
        metrics["loss"] = loss
        metrics.update(parts)  # ce / aux / z_loss breakdown, both paths
        return new_params, new_opt, metrics

    return train_step


def init_train_state(rng, cfg: ModelConfig, compress_grads: bool = False):
    from repro.models.transformer import init_params

    params = init_params(rng, cfg)
    opt_state = init_opt_state(params)
    if compress_grads:
        opt_state["ef"] = jax.tree_util.tree_map(
            lambda p: jnp.zeros(p.shape, jnp.float32), params)
    return params, opt_state
