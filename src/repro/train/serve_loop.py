"""Serving loop: LoPace-backed prompt admission + batched decode.

The paper's storage layer sits at admission: request prompts are looked
up in the PromptStore and decompressed *to token ids directly*
(token-stream mode, §8.4.2 #10) — no detokenize/retokenize round trip —
then prefilled and decoded with the model's KV cache.

`BatchServer` implements slot-based continuous batching: a fixed [B]
decode batch where finished slots are refilled from the queue between
decode steps (the production pattern; per-slot prefill keeps the compiled
decode step shape-stable).
"""

from __future__ import annotations

import dataclasses
import time
from dataclasses import dataclass, field
from typing import Callable, Dict, List, Optional

import jax
import jax.numpy as jnp
import numpy as np

from repro import obs
from repro.configs.base import ModelConfig
from repro.core.store import PromptStore
from repro.models.transformer import decode_step, forward, init_cache


@dataclass
class Request:
    rid: int
    prompt_tokens: np.ndarray
    max_new_tokens: int = 32
    out_tokens: List[int] = field(default_factory=list)
    done: bool = False


class BatchServer:
    """Greedy-decode batch server over a fixed slot count."""

    def __init__(self, params, cfg: ModelConfig, batch_slots: int = 4,
                 max_len: int = 512, eos_id: Optional[int] = None):
        self.params = params
        self.cfg = cfg
        self.B = batch_slots
        self.max_len = max_len
        self.eos_id = eos_id
        self.cache = init_cache(cfg, batch_slots, max_len)
        self.slots: List[Optional[Request]] = [None] * batch_slots
        self.pos = np.zeros(batch_slots, dtype=np.int64)
        self.queue: List[Request] = []
        self._next_rid = 0
        self._decode = jax.jit(
            lambda p, c, t, pos: decode_step(p, cfg, c, {"tokens": t}, pos))
        # ms-per-token accounting (ROADMAP serving-latency item): prefill
        # is per slot filled, decode is per wave step / #active slots.
        # Timings are host-side dispatch+sync time — the np<->jnp
        # conversions in both loops force the device work.
        self._obs_prefill = obs.histogram("serve.prefill.ms_per_token")
        self._obs_decode = obs.histogram("serve.decode.ms_per_token")
        self._obs_steps = obs.counter("serve.decode.steps")
        self._obs_tokens = obs.counter("serve.decode.tokens")

    # -- admission -----------------------------------------------------------
    #
    # `store` duck-types: a PromptStore/ShardedPromptStore reads straight
    # from disk; a repro.service.PromptService routes the same calls
    # through its serve-path token cache, so repeat admissions of hot
    # prompts skip the codec decode entirely.

    def submit_text(self, store: PromptStore, key: str, **kw) -> Request:
        """Admit a stored prompt without detokenization."""
        toks = np.asarray(store.get_tokens(key), dtype=np.int64)
        return self.submit_tokens(toks, **kw)

    def submit_text_many(self, store: PromptStore, keys: List[str],
                         **kw) -> List[Request]:
        """Batch admission: one batched token-stream decode over all keys
        (grouped by method/backend inside the codec layer)."""
        return [self.submit_tokens(np.asarray(toks, dtype=np.int64), **kw)
                for toks in store.get_tokens_many(keys)]

    def submit_tokens(self, tokens: np.ndarray, max_new_tokens: int = 32) -> Request:
        # rids are server-lifetime monotonic; queue length would recycle
        # ids once the queue drains and alias distinct requests
        req = Request(rid=self._next_rid, prompt_tokens=tokens,
                      max_new_tokens=max_new_tokens)
        self._next_rid += 1
        self.queue.append(req)
        return req

    # -- scheduling ----------------------------------------------------------

    def _fill_slots(self) -> None:
        # Wave-synchronous batching: the KV cache's position bookkeeping is
        # batch-shared, so slots refill together at a wave boundary (all
        # empty), resetting positions and cache. Production continuous
        # batching needs per-row position tracking — future work.
        if any(s is not None for s in self.slots) or not self.queue:
            return
        self.cache = init_cache(self.cfg, self.B, self.max_len)
        self.pos[:] = 0
        for b in range(self.B):
            if self.slots[b] is not None or not self.queue:
                continue
            req = self.queue.pop(0)
            # per-slot prefill: feed prompt tokens one step at a time into
            # this slot (shape-stable: reuses the compiled decode step with
            # a masked batch; simple and correct for the reference server)
            toks = req.prompt_tokens[: self.max_len - req.max_new_tokens - 1]
            t0 = time.perf_counter()
            for t in toks:
                step_tok = np.zeros((self.B, 1), np.int64)
                step_tok[b, 0] = t
                logits, self.cache = self._decode(
                    self.params, self.cache, jnp.asarray(step_tok),
                    int(self.pos[b]))
                self.pos[b] += 1
            if len(toks):
                self._obs_prefill.observe(
                    (time.perf_counter() - t0) * 1e3 / len(toks))
            self.slots[b] = req

    def step(self) -> int:
        """One decode step across all active slots; returns #active."""
        self._fill_slots()
        active = [b for b in range(self.B) if self.slots[b] is not None]
        if not active:
            return 0
        t0 = time.perf_counter()
        # NOTE: the reference server steps positions per slot; production
        # would vectorize positions — the decode fn takes a scalar pos, so
        # we step the batch at the max pos and mask per-slot in admission.
        tok = np.zeros((self.B, 1), np.int64)
        for b in active:
            req = self.slots[b]
            last = (req.out_tokens[-1] if req.out_tokens
                    else int(req.prompt_tokens[-1]))
            tok[b, 0] = last
        pos = int(self.pos[active[0]])
        logits, self.cache = self._decode(self.params, self.cache,
                                          jnp.asarray(tok), pos)
        nxt = np.asarray(jnp.argmax(logits[:, -1, :], axis=-1))
        for b in active:
            req = self.slots[b]
            t = int(nxt[b])
            req.out_tokens.append(t)
            self.pos[b] += 1
            if (len(req.out_tokens) >= req.max_new_tokens
                    or (self.eos_id is not None and t == self.eos_id)
                    or int(self.pos[b]) >= self.max_len - 1):
                req.done = True
                self.slots[b] = None
        self._obs_decode.observe(
            (time.perf_counter() - t0) * 1e3 / len(active))
        self._obs_steps.inc()
        self._obs_tokens.inc(len(active))
        return len(active)

    def run(self, max_steps: int = 10_000) -> None:
        for _ in range(max_steps):
            if self.step() == 0 and not self.queue:
                break
