"""PromptService: the long-running service tier over a PromptStore.

Composes the three service components around one `ShardedPromptStore`:

    PromptService
    ├── IngestQueue            async write path (put_async; group commit,
    │                          per-shard parallel fsync, backpressure)
    ├── BackgroundCompactor    dead-byte reclaim + codec stage reselection
    ├── BackgroundScrubber     integrity sweep -> quarantine + degraded
    │                          reads (repro.service.scrub)
    └── TokenCache             serve-path get_tokens LRU (byte budget)

Read/write API is a superset of the store's (`put/put_many/get/get_many/
get_tokens/get_tokens_many/keys/stats/verify_all` all work), so anything
that takes a store — `BatchServer` admission, `TokenPipeline` — can take
a `PromptService` instead and transparently gain the cache.

Lifecycle: `start()` → serve → `drain()`/`stop()`.  `stop()` is the
crash-safe shutdown: the ingest queue flushes and fsyncs everything
acknowledged, the compactor finishes its in-flight shard (its swap is
atomic anyway, so even a SIGKILL mid-compaction reopens intact — see
`swap_shard`), and both joins are idempotent.  Use as a context manager
to get that on any exit path.
"""

from __future__ import annotations

from typing import List, Optional, Sequence

import numpy as np

from repro.core.store import ShardedPromptStore
from repro.service.cache import TokenCache
from repro.service.compaction import (BackgroundCompactor, CompactionResult,
                                      compact_shard, compact_store)
from repro.service.ingest import IngestQueue, IngestTicket
from repro.service.scrub import (BackgroundScrubber, RepairResult,
                                 ScrubResult, repair_shard, repair_store,
                                 scrub_shard, scrub_store)


class PromptService:
    def __init__(
        self,
        store: ShardedPromptStore,
        cache_bytes: int = 64 << 20,
        ingest_async: bool = True,
        flush_batch: int = 64,
        flush_interval_s: float = 0.05,
        max_pending: int = 1024,
        compact_interval_s: Optional[float] = None,
        compact_trigger_dead_ratio: float = 0.25,
        compact_min_dead_bytes: int = 4096,
        compact_reselect: bool = True,
        compact_train_dict: bool = True,
        scrub_interval_s: Optional[float] = None,
    ) -> None:
        self.store = store
        self.cache = TokenCache(cache_bytes) if cache_bytes > 0 else None
        self.ingest = (IngestQueue(store, flush_batch=flush_batch,
                                   flush_interval_s=flush_interval_s,
                                   max_pending=max_pending)
                       if ingest_async else None)
        self.compactor = (BackgroundCompactor(
            store, interval_s=compact_interval_s,
            trigger_dead_ratio=compact_trigger_dead_ratio,
            min_dead_bytes=compact_min_dead_bytes,
            reselect=compact_reselect,
            train_dict=compact_train_dict)
            if compact_interval_s is not None else None)
        self.scrubber = (BackgroundScrubber(store,
                                            interval_s=scrub_interval_s)
                         if scrub_interval_s is not None else None)
        self._started = False
        self._stopped = False

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "PromptService":
        if self._stopped:
            raise RuntimeError(
                "service is stopped: a PromptService cannot restart — its "
                "ingest dispatcher and compactor threads are gone, so a "
                "restarted handle would accept work nothing drains; build "
                "a fresh PromptService over the store instead")
        if self._started:
            raise RuntimeError("service already started")
        self._started = True
        if self.ingest is not None:
            self.ingest.start()
        if self.compactor is not None:
            self.compactor.start()
        if self.scrubber is not None:
            self.scrubber.start()
        return self

    def drain(self) -> None:
        """Block until every async ingest acknowledged so far is durable."""
        if self.ingest is not None:
            self.ingest.drain()

    def stop(self) -> None:
        """Crash-safe shutdown (idempotent): drain + commit the ingest
        queue, stop the compactor, release the threads."""
        if self._stopped:
            return
        self._stopped = True
        if self.ingest is not None:
            self.ingest.stop()
        if self.compactor is not None:
            self.compactor.stop()
        if self.scrubber is not None:
            self.scrubber.stop()

    def __enter__(self) -> "PromptService":
        if self._stopped:
            # delegate so the zombie-restart message lives in one place
            return self.start()
        return self.start() if not self._started else self

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- write path ------------------------------------------------------------

    def put_async(self, texts: Sequence[str],
                  method: Optional[str] = None) -> IngestTicket:
        """Queue texts for ingest; never blocks on fsync (only on
        backpressure).  Degrades to a synchronous, already-durable ticket
        when the service was built with `ingest_async=False`."""
        if self._stopped:
            raise RuntimeError(
                "put_async on a stopped service: the ingest dispatcher is "
                "gone, so queued texts would never commit")
        if self.ingest is not None:
            return self.ingest.submit(texts, method)
        keys = self.store.put_many(texts, method)
        ticket = IngestTicket(list(keys))
        ticket._finish(None)
        return ticket

    def put(self, text: str, method: Optional[str] = None) -> str:
        return self.store.put(text, method)

    def put_many(self, texts: Sequence[str],
                 method: Optional[str] = None) -> List[str]:
        return self.store.put_many(texts, method)

    # -- read path -------------------------------------------------------------

    def get(self, key: str, verify: bool = True) -> str:
        return self.store.get(key, verify=verify)

    def get_many(self, keys: Sequence[str], verify: bool = True) -> List[str]:
        return self.store.get_many(keys, verify=verify)

    def get_tokens(self, key: str) -> np.ndarray:
        """Serve-path admission: token ids via the LRU, decoding only on
        a miss (cached arrays are shared — treat as read-only)."""
        if self.cache is None:
            return self.store.get_tokens(key)
        return self.cache.get_or_load(key, self.store.get_tokens)

    def get_tokens_many(self, keys: Sequence[str]) -> List[np.ndarray]:
        if self.cache is None:
            return self.store.get_tokens_many(keys)
        return self.cache.get_or_load_many(keys, self.store.get_tokens_many)

    def iter_tokens(self):
        return self.store.iter_tokens()

    # -- store passthrough -----------------------------------------------------

    def keys(self) -> List[str]:
        return self.store.keys()

    def __len__(self) -> int:
        return len(self.store)

    def __contains__(self, key: str) -> bool:
        return key in self.store

    def verify_all(self) -> dict:
        return self.store.verify_all()

    # -- maintenance -----------------------------------------------------------

    def compact(self, shard_id: Optional[int] = None, reselect: bool = True,
                train_dict: bool = True) -> List[CompactionResult]:
        """Synchronous compaction (all shards, or one)."""
        if shard_id is not None:
            res = compact_shard(self.store, shard_id, reselect=reselect,
                                train_dict=train_dict)
            return [res] if res is not None else []
        return compact_store(self.store, reselect=reselect,
                             train_dict=train_dict)

    def rebalance(self, n_shards: int) -> dict:
        """Online shard-count change: re-partition every key through the
        store's atomic meta commit (readers served throughout; async
        ingest keeps flowing — stale plans re-route)."""
        return self.store.rebalance(n_shards)

    def scrub(self, shard_id: Optional[int] = None) -> List[ScrubResult]:
        """Synchronous integrity sweep (all shards, or one); failing
        shards are quarantined — see ``repro.service.scrub``."""
        if shard_id is not None:
            return [scrub_shard(self.store, shard_id)]
        return scrub_store(self.store)

    def repair(self, shard_id: Optional[int] = None,
               source: Optional[ShardedPromptStore] = None
               ) -> List[RepairResult]:
        """Heal quarantined shards: re-commit survivors, resync
        casualties from ``source`` (a replica/backup root), drop the
        rest.  Destructive for unrecoverable records — explicit call
        only, never automatic."""
        if shard_id is not None:
            return [repair_shard(self.store, shard_id, source=source)]
        return repair_store(self.store, source=source)

    def stats(self) -> dict:
        """One snapshot across every component."""
        return {
            "store": self.store.stats(),
            "cache": self.cache.stats() if self.cache is not None else None,
            "ingest": self.ingest.stats() if self.ingest is not None else None,
            "compaction": (self.compactor.stats()
                           if self.compactor is not None else None),
            "scrub": (self.scrubber.stats()
                      if self.scrubber is not None else None),
        }
