"""Async ingest queue: producers hand off batches of texts and move on;
a dispatcher thread plans group commits and per-shard writer threads make
them durable in parallel.

Why a queue at all: `ShardedPromptStore.put_many` is synchronous — the
caller eats the codec-pipeline pass *and* two fsyncs per shard touched.
In the request path of a real-time LLM app (the paper's target, §6.2.3)
that latency lands on the user.  Here `submit()` costs one sha256 per
text plus an enqueue; durability happens behind the scenes:

    producers ──submit()──> pending deque ──dispatcher──> per-shard
    (backpressure when      (group-commit    (plan_batch:  writer threads
     max_pending texts       accumulation)    compress +   (commit_batch:
     are queued)                              reserve seq)  parallel fsync)

Group-commit state machine (one flush):

    IDLE --submit--> ACCUMULATING --[>= flush_batch texts
                         |            or flush_interval_s elapsed
                         |            or flush()/drain()/stop()]--> FLUSH
                         '--submit--' (resets nothing; deadline is the
                                       OLDEST pending submission's age)

    FLUSH: dispatcher pops whole submissions until >= flush_batch texts,
    plans them (one batched codec pass, no locks held; the byte stage
    fans records out over the shared codec thread pool — see
    ``repro.core.codec`` — so a flush costs its slowest record, not the
    sum), then enqueues one commit per shard touched.  The flush is DONE when every shard part is
    durable AND every earlier flush is done — completion is prefix-ORDERED
    like WAL group commit (a later ticket never completes before an
    earlier one), so on an error-free run `ticket.wait()` returning means
    everything submitted up to that point is durable.  Errors are isolated
    per flush: a failed flush raises on its OWN tickets only, and later,
    independent flushes still commit — a caller that needs cross-flush
    atomicity must wait on each of its tickets.

Racing duplicates (same text submitted twice before the first commit
lands) may be written twice; content keys make that harmless and the
compactor reclaims the dead copy — see the store's concurrency notes.
"""

from __future__ import annotations

import queue
import threading
import time
from collections import deque
from typing import Dict, List, Optional, Sequence

from repro import obs
from repro.core.codec import codec_pool_size
from repro.core.store import ShardedPromptStore, content_key


class IngestError(RuntimeError):
    """A flush failed and this ticket's texts did not commit.  Raised by
    `IngestTicket.wait` as a FRESH instance per call — every ticket of a
    failed flush shares one underlying cause (``__cause__``), but never
    one exception object, so concurrent waiters can't mutate each
    other's tracebacks."""


class IngestTicket:
    """Handle for one `submit()`: the content keys are known immediately
    (they are content addresses); `wait()` blocks until this submission's
    texts are durable on disk — and, because completion is prefix-ordered,
    until every earlier submission has *settled* (committed, or raised on
    its own ticket)."""

    def __init__(self, keys: List[str]) -> None:
        self.keys = keys
        self.submitted_ts = time.monotonic()
        self._event = threading.Event()
        self._error: Optional[BaseException] = None

    def done(self) -> bool:
        return self._event.is_set()

    def wait(self, timeout: Optional[float] = None) -> List[str]:
        if not self._event.wait(timeout):
            raise TimeoutError("ingest ticket not durable within timeout")
        if self._error is not None:
            # wrap per call: re-raising the flush's one exception object
            # from N waiters would let them race on its traceback
            raise IngestError(
                f"ingest flush failed; this ticket's {len(self.keys)} "
                f"text(s) were not committed: {self._error}"
            ) from self._error
        return self.keys

    def _finish(self, error: Optional[BaseException]) -> None:
        self._error = error
        self._event.set()


class _Submission:
    __slots__ = ("ts", "texts", "method", "ticket")

    def __init__(self, texts: Sequence[str], method: Optional[str],
                 ticket: IngestTicket) -> None:
        self.ts = time.monotonic()
        self.texts = list(texts)
        self.method = method
        self.ticket = ticket


class _Flush:
    """One group commit in flight: `remaining` shard parts still being
    fsynced, chained to the previous flush for prefix-ordered completion."""

    __slots__ = ("tickets", "remaining", "error", "finished",
                 "prev_finished", "next")

    def __init__(self, tickets: List[IngestTicket], n_parts: int,
                 prev_finished: bool) -> None:
        self.tickets = tickets
        self.remaining = n_parts
        self.error: Optional[BaseException] = None
        self.finished = False
        self.prev_finished = prev_finished
        self.next: Optional["_Flush"] = None


class IngestQueue:
    """Bounded async ingest into a `ShardedPromptStore`.

    Lifecycle: `start()` -> `submit()`/`flush()`/`drain()` -> `stop()`
    (also usable as a context manager).  `stop()` always drains — pending
    submissions are flushed and committed before the threads exit, so a
    clean shutdown never loses acknowledged work.
    """

    def __init__(self, store: ShardedPromptStore, flush_batch: int = 64,
                 flush_interval_s: float = 0.05, max_pending: int = 1024) -> None:
        if flush_batch < 1:
            raise ValueError("flush_batch must be >= 1")
        if max_pending < 1:
            raise ValueError("max_pending must be >= 1")
        self._store = store
        self.flush_batch = int(flush_batch)
        self.flush_interval_s = float(flush_interval_s)
        self.max_pending = int(max_pending)
        self._cv = threading.Condition()
        self._items: "deque[_Submission]" = deque()
        self._pending_texts = 0
        self._dispatching = False
        self._outstanding = 0          # registered, unfinished flushes
        self._tail: Optional[_Flush] = None
        self._flush_requested = False
        self._started = False
        self._stopping = False
        self._stopped = False
        self._writer_queues: List["queue.Queue"] = [
            queue.Queue() for _ in range(store.n_shards)]
        self._writers: List[threading.Thread] = []
        self._dispatcher: Optional[threading.Thread] = None
        # metrics: registry-backed counters (always real; see repro.obs)
        # plus queue-depth and submit->durable wait-time histograms
        self._n_submitted = obs.owned_counter("ingest.submitted")
        self._n_committed = obs.owned_counter("ingest.committed")
        self._n_flushes = obs.owned_counter("ingest.flushes")
        self._n_backpressure_waits = obs.owned_counter(
            "ingest.backpressure_waits")
        self._max_depth = 0
        self._depth_h = obs.histogram("ingest.queue_depth")
        self._wait_h = obs.histogram("ingest.wait.s")
        obs.owned_gauge("ingest.pending", lambda: self._pending_texts)

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "IngestQueue":
        with self._cv:
            if self._started:
                raise RuntimeError("ingest queue already started")
            self._started = True
        self._dispatcher = threading.Thread(
            target=self._dispatch_loop, name="ingest-dispatcher", daemon=True)
        self._dispatcher.start()
        for i in range(self._store.n_shards):
            w = threading.Thread(target=self._writer_loop, args=(i,),
                                 name=f"ingest-writer-{i}", daemon=True)
            w.start()
            self._writers.append(w)
        return self

    def stop(self) -> None:
        """Drain + shut down (idempotent): flush everything pending, wait
        for the writers' fsyncs, then join all threads."""
        with self._cv:
            if not self._started or self._stopped:
                self._stopped = True
                return
            self._stopping = True
            self._cv.notify_all()
        self._dispatcher.join()
        for q in self._writer_queues:
            q.put(None)
        for w in self._writers:
            w.join()
        with self._cv:
            assert self._outstanding == 0 and not self._items
            self._stopped = True

    def __enter__(self) -> "IngestQueue":
        return self.start()

    def __exit__(self, *exc) -> None:
        self.stop()

    # -- producer API ----------------------------------------------------------

    def submit(self, texts: Sequence[str],
               method: Optional[str] = None) -> IngestTicket:
        """Enqueue a batch; returns immediately (after backpressure) with
        a ticket whose `.keys` are already the final content keys."""
        ticket = IngestTicket([content_key(t) for t in texts])
        if not texts:
            ticket._finish(None)
            return ticket
        with self._cv:
            if not self._started or self._stopping:
                raise RuntimeError("ingest queue is not running")
            while self._pending_texts >= self.max_pending and not self._stopping:
                self._n_backpressure_waits.inc()
                self._cv.wait()
            if self._stopping:
                raise RuntimeError("ingest queue is not running")
            self._items.append(_Submission(texts, method, ticket))
            self._pending_texts += len(texts)
            self._n_submitted.inc(len(texts))
            self._max_depth = max(self._max_depth, self._pending_texts)
            self._depth_h.observe(self._pending_texts)
            self._cv.notify_all()
        return ticket

    def flush(self) -> None:
        """Ask the dispatcher to flush now instead of waiting for the
        batch/interval threshold."""
        with self._cv:
            self._flush_requested = True
            self._cv.notify_all()

    def drain(self) -> None:
        """Block until everything submitted so far is durable."""
        with self._cv:
            if not self._started:
                raise RuntimeError("ingest queue is not running")
            self._flush_requested = True
            self._cv.notify_all()
            while self._items or self._dispatching or self._outstanding:
                self._cv.wait()

    def stats(self) -> dict:
        with self._cv:
            return {
                "submitted": self._n_submitted.value,
                "committed": self._n_committed.value,
                "pending": self._pending_texts,
                "flushes": self._n_flushes.value,
                "backpressure_waits": self._n_backpressure_waits.value,
                "max_queue_depth": self._max_depth,
                "flush_batch": self.flush_batch,
                "flush_interval_s": self.flush_interval_s,
                "max_pending": self.max_pending,
                # compression parallelism the dispatcher's plan_batch calls
                # inherit (REPRO_CODEC_THREADS; 0/1 = sequential)
                "codec_threads": codec_pool_size(),
            }

    # -- dispatcher ------------------------------------------------------------

    def _dispatch_loop(self) -> None:
        while True:
            with self._cv:
                while True:
                    if self._items:
                        now = time.monotonic()
                        deadline = self._items[0].ts + self.flush_interval_s
                        if (self._pending_texts >= self.flush_batch
                                or self._flush_requested or self._stopping
                                or now >= deadline):
                            break
                        self._cv.wait(timeout=max(deadline - now, 1e-3))
                    elif self._stopping:
                        return
                    else:
                        self._cv.wait()
                taken: List[_Submission] = []
                n = 0
                while self._items and n < self.flush_batch:
                    sub = self._items.popleft()
                    taken.append(sub)
                    n += len(sub.texts)
                self._pending_texts -= n
                if not self._items:
                    self._flush_requested = False
                self._dispatching = True
                self._cv.notify_all()  # wake backpressured producers
            self._plan_and_dispatch(taken)

    def _plan_and_dispatch(self, taken: List[_Submission]) -> None:
        """Plan one flush (compress outside any lock) and hand each shard's
        entries to its writer.  Runs on the dispatcher thread, overlapping
        the previous flush's fsyncs."""
        parts: Dict[int, List[dict]] = {}
        plan_error: Optional[BaseException] = None
        try:
            # group by explicit method, preserving submission order per group
            by_method: Dict[Optional[str], List[str]] = {}
            for sub in taken:
                by_method.setdefault(sub.method, []).extend(sub.texts)
            for method, texts in by_method.items():
                _, plan = self._store.plan_batch(texts, method)
                for shard_id, entries in plan.items():
                    parts.setdefault(shard_id, []).extend(entries)
        except BaseException as e:  # fail the whole flush, keep the queue alive
            plan_error = e
            parts = {}
        with self._cv:
            flush = _Flush(
                tickets=[sub.ticket for sub in taken],
                n_parts=len(parts),
                prev_finished=self._tail is None or self._tail.finished,
            )
            flush.error = plan_error
            if self._tail is not None and not self._tail.finished:
                self._tail.next = flush
            self._tail = flush
            self._outstanding += 1
            self._n_flushes.inc()
            self._dispatching = False
            if not parts:
                self._maybe_finish(flush)
            self._cv.notify_all()
        for shard_id, entries in parts.items():
            # writer threads are a parallelism pool, not the routing: an
            # online rebalance can return shard ids beyond the pool size
            # (and commit_batch re-routes stale plans itself), so the
            # true shard id travels with the work item
            q = self._writer_queues[shard_id % len(self._writer_queues)]
            q.put((shard_id, entries, flush))

    def _maybe_finish(self, flush: Optional[_Flush]) -> None:
        """cv held: cascade prefix-ordered flush completion."""
        now = time.monotonic()
        while (flush is not None and flush.remaining == 0
               and flush.prev_finished and not flush.finished):
            flush.finished = True
            self._outstanding -= 1
            for ticket in flush.tickets:
                self._wait_h.observe(now - ticket.submitted_ts)
                ticket._finish(flush.error)
            nxt = flush.next
            if nxt is not None:
                nxt.prev_finished = True
            if self._tail is flush:
                self._tail = None
            flush = nxt
        self._cv.notify_all()

    # -- writers ---------------------------------------------------------------

    def _writer_loop(self, writer_id: int) -> None:
        q = self._writer_queues[writer_id]
        while True:
            item = q.get()
            if item is None:
                return
            shard_id, entries, flush = item
            err: Optional[BaseException] = None
            try:
                self._store.commit_batch(shard_id, entries)
            except BaseException as e:
                err = e
            with self._cv:
                if err is not None and flush.error is None:
                    flush.error = err
                elif err is None:
                    self._n_committed.inc(len(entries))
                flush.remaining -= 1
                self._maybe_finish(flush)
