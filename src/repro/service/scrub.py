"""Background integrity scrubbing + quarantine repair: the self-healing
half of the store's fault-tolerance story (ARCHITECTURE.md "Fault
tolerance").

The paper's production claim is 100% lossless reconstruction; a bit that
rots *after* the ingest-time fsync silently breaks it until the key is
next read.  The scrubber closes that window: it walks every shard
decoding every record and checking its sha256 content key (the same
verification ``get()`` does, run proactively), and a shard with any
failing record is **quarantined** via
:meth:`~repro.core.store.ShardedPromptStore.quarantine_shard`:

* reads of the provably-corrupt keys raise
  :class:`~repro.core.store.ShardQuarantined` naming the full casualty
  list — every healthy key, in that shard and every other, keeps
  serving (the degraded-read contract: corruption is never allowed to
  escalate into a store-wide failure);
* the background compactor skips the shard, preserving the corrupt
  generation as forensics instead of laundering it through a rebuild;
* :func:`repair_shard` heals it: survivors are re-committed through the
  normal ``swap_shard`` generation swap, casualties are re-fetched from
  a ``source`` store (a replica root opened read-only) when one is
  given, and only records no copy of survives are dropped — an honest
  ``KeyError`` thereafter instead of a quarantine held forever.

Scrub state machine per shard::

    healthy --scrub finds bad record--> quarantined --repair--> healthy
       ^                                     |  (casualties without a
       +----- scrub pass finds no rot -------+   source are dropped)

:class:`BackgroundScrubber` is the ``BackgroundCompactor`` sibling the
service tier wires in (``PromptService(scrub_interval_s=...)``); both
follow the same lifecycle (daemon thread, ``stop()`` joins, counters via
``repro.obs.owned_counter``).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass, field
from typing import List, Optional

from repro import obs
from repro.core.store import ShardedPromptStore, content_key

#: per-record decode is the slow fallback; batches amortize the pipeline
_SCRUB_BATCH = 64


@dataclass
class ScrubResult:
    shard_id: int
    n_records: int
    bad_keys: List[str] = field(default_factory=list)
    quarantined: bool = False
    wall_s: float = 0.0

    @property
    def clean(self) -> bool:
        return not self.bad_keys


@dataclass
class RepairResult:
    shard_id: int
    n_survivors: int
    n_resynced: int      # casualties recovered from the source store
    n_dropped: int       # casualties no copy of survives
    repaired: bool       # False: could not run (lock/layout race)


def _verify(store: ShardedPromptStore, recs: List[dict],
            blobs: List[bytes]) -> List[str]:
    """Keys in `recs` whose blob fails decode or hash check.  The fast
    path decodes a whole batch; any batch-level failure falls back to
    per-record decode so one rotten frame doesn't condemn its batch."""
    bad: List[str] = []
    for start in range(0, len(recs), _SCRUB_BATCH):
        chunk = recs[start:start + _SCRUB_BATCH]
        chunk_blobs = blobs[start:start + _SCRUB_BATCH]
        try:
            texts = store.compressor.decompress_batch(chunk_blobs)
        except Exception:
            texts = None
        if texts is None:
            for rec, blob in zip(chunk, chunk_blobs):
                try:
                    text = store.compressor.decompress(blob)
                except Exception:
                    bad.append(rec["key"])
                    continue
                if content_key(text) != rec["key"]:
                    bad.append(rec["key"])
        else:
            bad.extend(rec["key"] for rec, text in zip(chunk, texts)
                       if content_key(text) != rec["key"])
    return bad


def scrub_shard(store: ShardedPromptStore, shard_id: int) -> ScrubResult:
    """Verify every live record of one shard; quarantine on any failure.
    Safe to run concurrently with ingest and reads (snapshot + read use
    the store's own locking); an already-quarantined shard is re-scanned
    so repeated rot extends the casualty list."""
    with obs.span("scrub.shard", shard=str(shard_id)) as span:
        recs = store.shard_records(shard_id)
        try:
            blobs = store.read_records(shard_id, recs)
        except OSError:
            # raced a compaction/rebalance generation unlink: the records
            # now live in a fresh file the next pass will scan
            return ScrubResult(shard_id, 0, wall_s=span.elapsed_s)
        bad = _verify(store, recs, blobs)
        obs.counter("scrub.records").inc(len(recs))
        if bad:
            obs.counter("scrub.corrupt_records").inc(len(bad))
            store.quarantine_shard(shard_id, bad, "scrub integrity failure")
        return ScrubResult(shard_id, len(recs), bad_keys=bad,
                           quarantined=bool(bad), wall_s=span.elapsed_s)


def scrub_store(store: ShardedPromptStore) -> List[ScrubResult]:
    """One full scrub pass (skips nothing; also callable synchronously)."""
    out: List[ScrubResult] = []
    for shard_id in range(store.n_shards):
        if shard_id >= store.n_shards:  # shrunk by a concurrent rebalance
            break
        out.append(scrub_shard(store, shard_id))
    return out


def repair_shard(store: ShardedPromptStore, shard_id: int,
                 source: Optional[ShardedPromptStore] = None) -> RepairResult:
    """Heal a quarantined shard.

    Survivors (records that still verify) are re-committed as a fresh
    generation through the store's normal ``swap_shard`` crash-safe
    protocol.  Each casualty is re-fetched from ``source`` — typically a
    replica root opened ``readonly=True`` — and re-compressed; casualties
    the source cannot produce are dropped from the index (the loss
    surfaces as ``KeyError``, never as silent wrong bytes).  Lifts the
    quarantine on commit.  Mirrors ``compact_shard``'s locking: returns
    ``repaired=False`` when another rebuild holds the shard or a
    rebalance replaced the layout mid-acquire."""
    try:
        lock = store.compaction_lock(shard_id)
    except IndexError:  # raced a shrinking rebalance
        return RepairResult(shard_id, 0, 0, 0, repaired=False)
    if not lock.acquire(blocking=False):
        return RepairResult(shard_id, 0, 0, 0, repaired=False)
    try:
        try:
            if store.compaction_lock(shard_id) is not lock:
                return RepairResult(shard_id, 0, 0, 0, repaired=False)
        except IndexError:
            return RepairResult(shard_id, 0, 0, 0, repaired=False)
        with obs.span("scrub.repair", shard=str(shard_id)):
            return _repair_locked(store, shard_id, source)
    finally:
        lock.release()


def _repair_locked(store: ShardedPromptStore, shard_id: int,
                   source: Optional[ShardedPromptStore]) -> RepairResult:
    recs = store.shard_records(shard_id)
    blobs = store.read_records(shard_id, recs)
    bad = set(_verify(store, recs, blobs))
    entries = [
        {"key": r["key"], "seq": r["seq"], "method": r["method"],
         "n_chars": r["n_chars"], "blob": b}
        for r, b in zip(recs, blobs) if r["key"] not in bad
    ]
    n_survivors = len(entries)
    resynced: List[str] = []
    dropped: List[str] = []
    by_key = {r["key"]: r for r in recs}
    for key in sorted(bad):
        text: Optional[str] = None
        if source is not None:
            try:
                text = source.get(key)
            except Exception:
                text = None
        if text is None:
            dropped.append(key)
            continue
        rec = by_key[key]
        blob = store.compressor.compress(text, rec["method"])
        entries.append({"key": key, "seq": rec["seq"],
                        "method": rec["method"], "n_chars": len(text),
                        "blob": blob})
        resynced.append(key)
    # casualties leave the index BEFORE the swap: swap_shard's catch-up
    # would otherwise copy the corrupt blobs (still indexed, not in the
    # planned seq set) straight into the healed generation
    if dropped:
        store.drop_keys(dropped)
        obs.counter("scrub.dropped_records").inc(len(dropped))
    # surviving frames may reference the shard's dictionary sidecar; the
    # healed generation must re-persist it or they rot on reopen (same
    # carry rule as compaction)
    from repro.service.compaction import _carried_dictionary

    store.swap_shard(shard_id, sorted(entries, key=lambda e: e["seq"]),
                     dictionary=_carried_dictionary(store, entries))
    store.clear_quarantine(shard_id)
    obs.counter("scrub.repairs").inc()
    if resynced:
        obs.counter("scrub.resynced_records").inc(len(resynced))
    return RepairResult(shard_id, n_survivors, len(resynced), len(dropped),
                        repaired=True)


def repair_store(store: ShardedPromptStore,
                 source: Optional[ShardedPromptStore] = None
                 ) -> List[RepairResult]:
    """Repair every quarantined shard."""
    return [repair_shard(store, sid, source=source)
            for sid in sorted(store.quarantined())]


class BackgroundScrubber:
    """Periodic integrity sweep thread — the ``BackgroundCompactor``
    sibling.  Every ``interval_s`` it scrubs each shard; quarantines are
    declared but NOT auto-repaired (repair drops unrecoverable records,
    a destructive step an operator or the chaos harness triggers
    explicitly via :func:`repair_shard` / ``PromptService.repair``)."""

    def __init__(self, store: ShardedPromptStore,
                 interval_s: float = 30.0) -> None:
        self._store = store
        self.interval_s = float(interval_s)
        self._stop_event = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._passes = obs.owned_counter("scrub.passes")
        self._quarantines = obs.owned_counter("scrub.quarantines")
        self._errors = obs.owned_counter("scrub.errors")

    def start(self) -> "BackgroundScrubber":
        if self._thread is not None:
            raise RuntimeError("scrubber already started")
        self._thread = threading.Thread(target=self._loop,
                                        name="shard-scrubber", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        self._stop_event.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    def _loop(self) -> None:
        while not self._stop_event.wait(self.interval_s):
            self.run_pass()

    def run_pass(self) -> List[ScrubResult]:
        """One scrub sweep over all shards (also callable synchronously)."""
        self._passes.inc()
        results: List[ScrubResult] = []
        with obs.span("scrub.pass"):
            for shard_id in range(self._store.n_shards):
                if self._stop_event.is_set():
                    break
                was_quarantined = self._store.is_quarantined(shard_id)
                try:
                    res = scrub_shard(self._store, shard_id)
                except Exception:  # racing a rebalance teardown
                    self._errors.inc()
                    continue
                results.append(res)
                if res.quarantined and not was_quarantined:
                    self._quarantines.inc()
        return results

    def stats(self) -> dict:
        return {
            "passes": self._passes.value,
            "quarantines": self._quarantines.value,
            "errors": self._errors.value,
            "interval_s": self.interval_s,
        }
