"""Serve-path token cache: a byte-budgeted LRU over decompressed token
arrays.

Every `get_tokens` admission in the serving loop otherwise re-reads the
frame from disk and re-runs the codec pipeline's decode stages; for the
hot prompts of a production workload (system prompts, few-shot prefixes,
retried requests) that work is identical every time.  The cache keys on
the store's content address (sha256 of the text), so entries can never go
stale: a re-`put` of the same key stores the same text, and compaction
preserves content per key even when it re-encodes a shard with a
different codec pipeline — no invalidation protocol is needed.

Sizing is by payload bytes (`np.ndarray.nbytes`), not entry count, since
prompt token streams span ~30 to ~200k ids (paper §4.1).  Cached arrays
are shared, not copied — and ENFORCED read-only: `put` clears the numpy
writeable flag, so a caller that tries to mutate a served array gets a
ValueError instead of silently corrupting every later hit for that key.
"""

from __future__ import annotations

import threading
from collections import OrderedDict
from typing import Callable, List, Optional, Sequence

import numpy as np

from repro import obs


class TokenCache:
    """Thread-safe byte-budgeted LRU: content key -> token id array."""

    def __init__(self, capacity_bytes: int) -> None:
        if capacity_bytes < 0:
            raise ValueError("capacity_bytes must be >= 0")
        self.capacity_bytes = int(capacity_bytes)
        self._entries: "OrderedDict[str, np.ndarray]" = OrderedDict()
        self._bytes = 0
        self._lock = threading.Lock()
        # Registry-backed counters: always real (their values feed
        # stats() regardless of REPRO_OBS); registered globally only
        # when obs is on, replacing any prior instance's.
        self._hits = obs.owned_counter("cache.hits")
        self._misses = obs.owned_counter("cache.misses")
        self._evictions = obs.owned_counter("cache.evictions")
        self._oversize_rejects = obs.owned_counter("cache.oversize_rejects")
        self._invalidations = obs.owned_counter("cache.invalidations")
        self._clears = obs.owned_counter("cache.clears")
        obs.owned_gauge("cache.hit_rate", self._hit_rate)
        obs.owned_gauge("cache.bytes", lambda: self._bytes)
        obs.owned_gauge("cache.entries", lambda: len(self._entries))

    def _hit_rate(self) -> float:
        total = self._hits.value + self._misses.value
        return self._hits.value / total if total else 0.0

    # -- core ----------------------------------------------------------------

    def get(self, key: str) -> Optional[np.ndarray]:
        with self._lock:
            arr = self._entries.get(key)
            if arr is None:
                self._misses.inc()
                return None
            self._entries.move_to_end(key)
            self._hits.inc()
            return arr

    def put(self, key: str, tokens: np.ndarray) -> None:
        arr = np.asarray(tokens)
        # cached arrays are handed out shared across every later hit;
        # freeze so a caller mutating one raises instead of corrupting
        # the entry for everyone else
        arr.flags.writeable = False
        with self._lock:
            if arr.nbytes > self.capacity_bytes:
                # would evict the entire cache and still not fit
                self._oversize_rejects.inc()
                return
            old = self._entries.pop(key, None)
            if old is not None:
                self._bytes -= old.nbytes
            self._entries[key] = arr
            self._bytes += arr.nbytes
            while self._bytes > self.capacity_bytes and self._entries:
                _, victim = self._entries.popitem(last=False)
                self._bytes -= victim.nbytes
                self._evictions.inc()

    # -- loader composition ---------------------------------------------------

    def get_or_load(self, key: str,
                    loader: Callable[[str], np.ndarray]) -> np.ndarray:
        arr = self.get(key)
        if arr is None:
            arr = np.asarray(loader(key))
            self.put(key, arr)
        return arr

    def get_or_load_many(
        self, keys: Sequence[str],
        loader_many: Callable[[List[str]], List[np.ndarray]],
    ) -> List[np.ndarray]:
        """Batch lookup: misses are fetched in ONE `loader_many` call (so
        the store's batched token-stream decode still groups by pipeline)
        and populate the cache."""
        out: List[Optional[np.ndarray]] = [self.get(k) for k in keys]
        miss_pos = [i for i, arr in enumerate(out) if arr is None]
        if miss_pos:
            # dedupe: repeated miss keys decode once
            miss_keys: List[str] = []
            pos_of: dict = {}
            for i in miss_pos:
                if keys[i] not in pos_of:
                    pos_of[keys[i]] = len(miss_keys)
                    miss_keys.append(keys[i])
            loaded = [np.asarray(a) for a in loader_many(miss_keys)]
            for k, arr in zip(miss_keys, loaded):
                self.put(k, arr)
            for i in miss_pos:
                out[i] = loaded[pos_of[keys[i]]]
        return out  # type: ignore[return-value]

    # -- ops ------------------------------------------------------------------

    def invalidate(self, key: str) -> bool:
        with self._lock:
            arr = self._entries.pop(key, None)
            if arr is None:
                return False
            self._bytes -= arr.nbytes
            self._invalidations.inc()
            return True

    def clear(self) -> None:
        with self._lock:
            self._entries.clear()
            self._bytes = 0
            self._clears.inc()

    def __len__(self) -> int:
        with self._lock:
            return len(self._entries)

    def stats(self) -> dict:
        with self._lock:
            return {
                "capacity_bytes": self.capacity_bytes,
                "bytes": self._bytes,
                "entries": len(self._entries),
                "hits": self._hits.value,
                "misses": self._misses.value,
                "evictions": self._evictions.value,
                "oversize_rejects": self._oversize_rejects.value,
                "invalidations": self._invalidations.value,
                "clears": self._clears.value,
                "hit_rate": self._hit_rate(),
            }
