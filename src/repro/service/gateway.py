"""Socket gateway: the network front end of the service tier.

One writer gateway (owning the store lease) and N read-replica gateways
front a shared store root; clients speak a length-prefixed JSON frame
protocol over TCP:

    frame   := uint32_be(len(payload)) payload
    payload := JSON object, UTF-8

    request : {"op": <op>, "id": <any>, ...op fields}
    response: {"id": <echoed>, "ok": true, ...result}
            | {"id": <echoed>, "ok": false, "error": <code>,
               "message": <human text>, "retryable": <bool>}

Every error response carries a ``retryable`` verdict — the server-side
retry taxonomy (ARCHITECTURE.md "Fault tolerance").  Transient rejects
(``admission_reject``, ``timeout``, ``draining``) are safe to retry
because every op is idempotent (puts are content-addressed; ``wait``
re-attaches to its server-side ticket across reconnects); contract
violations (``bad_frame``, ``unknown_op``, ``read_only``, ``not_found``,
``shard_quarantined``, ...) will fail identically forever and must not
be retried.  ``GatewayClient`` obeys the verdict with seeded
exponential backoff (``REPRO_GATEWAY_RETRIES`` /
``REPRO_GATEWAY_RETRY_BASE_S``) and transparent reconnects.

Ops: ``ping``, ``put`` (synchronous durable put_many), ``put_async``
(queue + ticket; ``wait: true`` blocks until durable), ``wait`` (redeem
a ticket id), ``get``, ``get_tokens``, ``stats`` (``snapshot: true``
embeds the full obs snapshot), ``refresh`` (replica: re-poll the
writer's store.json).

Admission control — the gateway never buffers unboundedly:

* **global max in-flight** (``REPRO_GATEWAY_MAX_INFLIGHT``): a request
  arriving while that many are executing is REJECTED immediately
  (``error=admission_reject``), not queued — shedding load beats
  building an invisible queue in front of the ingest queue's own
  bounded backpressure;
* **per-connection window** (``REPRO_GATEWAY_CONN_WINDOW``): the
  connection's reader loop stops consuming frames while a window's
  worth are in flight, so a pipelining client is stalled by TCP flow
  control — which is how the ingest queue's ``max_pending`` propagates
  all the way back to the client socket instead of being absorbed by
  server-side buffering.

Graceful drain: SIGTERM/SIGINT stops accepting connections, lets
in-flight requests finish (bounded by ``REPRO_GATEWAY_DRAIN_S``),
drains the ingest queue so every acknowledged ticket is durable, then
exits.  Requests executing blocking store/service calls run on a
thread pool sized to the in-flight cap; the asyncio loop itself only
frames, admits, and responds.

Instrumented through ``repro.obs``: per-op request-latency histograms
(``gateway.request.s{op=...}``), an in-flight gauge, and counters for
requests, admission rejects, errors, and connections.
"""

from __future__ import annotations

import asyncio
import itertools
import json
import random
import signal
import socket
import struct
import threading
import time
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core import env, failpoints
from repro.core.store import ShardQuarantined
from repro.service.service import PromptService

_HDR = struct.Struct(">I")

#: ticket ids kept redeemable per gateway (oldest pruned first)
_TICKET_BACKLOG = 1024

#: ops a read-only replica gateway refuses outright
_WRITE_OPS = frozenset({"put", "put_async", "wait"})

#: known ops (bounds the label cardinality of the request histogram)
_OPS = frozenset({"ping", "put", "put_async", "wait", "get", "get_tokens",
                  "stats", "refresh"})

#: error codes a client may retry: the condition is transient AND every
#: op is idempotent (content-addressed puts; ticket-keyed wait).  All
#: other codes are contract violations that retry identically forever.
_RETRYABLE = frozenset({"admission_reject", "timeout", "draining"})


class GatewayError(RuntimeError):
    """A gateway request failed; ``code`` is the protocol error code and
    ``retryable`` the server's taxonomy verdict for it."""

    def __init__(self, message: str, code: str = "error",
                 retryable: bool = False) -> None:
        super().__init__(message)
        self.code = code
        self.retryable = bool(retryable)


class GatewayConnectionLost(ConnectionError):
    """The gateway connection died mid-request.  Carries enough context
    to debug a torn exchange: which op, which request id, and how many
    response bytes had arrived when the peer vanished."""

    def __init__(self, detail: str, *, op: str = "?",
                 request_id: Any = None, bytes_read: int = 0) -> None:
        super().__init__(
            f"{detail} (op={op!r} id={request_id!r} "
            f"bytes_read={bytes_read})")
        self.op = op
        self.request_id = request_id
        self.bytes_read = bytes_read


def _frame(doc: Dict[str, Any]) -> bytes:
    payload = json.dumps(doc).encode("utf-8")
    return _HDR.pack(len(payload)) + payload


def _error_doc(code: str, message: str, **extra: Any) -> Dict[str, Any]:
    """An ``ok: false`` response stamped with the retry-taxonomy verdict."""
    return {"ok": False, "error": code, "message": message,
            "retryable": code in _RETRYABLE, **extra}


class GatewayServer:
    """Asyncio TCP server fronting one `PromptService`.

    ``readonly=True`` marks a replica gateway: write ops are refused at
    the front door (the store would refuse them anyway) and ``refresh``
    is served.  ``port=0`` binds an ephemeral port, published on
    ``self.port`` once running."""

    def __init__(self, service: PromptService, host: str = "127.0.0.1",
                 port: int = 0, *, max_inflight: Optional[int] = None,
                 conn_window: Optional[int] = None,
                 frame_max: Optional[int] = None,
                 drain_s: Optional[float] = None,
                 readonly: bool = False) -> None:
        self.service = service
        self.host = host
        self.port = port
        self.readonly = bool(readonly)
        self.max_inflight = (env.read("REPRO_GATEWAY_MAX_INFLIGHT")
                             if max_inflight is None else int(max_inflight))
        self.conn_window = (env.read("REPRO_GATEWAY_CONN_WINDOW")
                            if conn_window is None else int(conn_window))
        self.frame_max = (env.read("REPRO_GATEWAY_FRAME_MAX")
                          if frame_max is None else int(frame_max))
        self.drain_s = (env.read("REPRO_GATEWAY_DRAIN_S")
                        if drain_s is None else float(drain_s))
        if min(self.max_inflight, self.conn_window, self.frame_max) < 1:
            raise ValueError("max_inflight, conn_window and frame_max must "
                             "be >= 1")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self._done: Optional[asyncio.Event] = None
        self._draining = False
        self._inflight = 0          # event-loop-thread only
        self._open_conns = 0        # event-loop-thread only
        self._executor = ThreadPoolExecutor(
            max_workers=self.max_inflight, thread_name_prefix="gateway-exec")
        self._tickets: "OrderedDict[str, Any]" = OrderedDict()
        self._tickets_lock = threading.Lock()
        self._ticket_ids = itertools.count(1)
        self._requests = obs.owned_counter("gateway.requests")
        self._rejects = obs.owned_counter("gateway.admission_rejects")
        self._errors = obs.owned_counter("gateway.request_errors")
        self._conns = obs.owned_counter("gateway.connections")
        obs.owned_gauge("gateway.inflight", lambda: self._inflight)
        obs.owned_gauge("gateway.open_connections", lambda: self._open_conns)

    # -- lifecycle ------------------------------------------------------------

    def run(self, ready_cb=None, install_signals: bool = True) -> None:
        """Serve until drained (blocks).  ``ready_cb(self)`` fires once
        the socket is bound (``self.port`` is final); ``install_signals``
        wires SIGTERM/SIGINT to graceful drain."""
        asyncio.run(self._main(ready_cb, install_signals))

    async def _main(self, ready_cb, install_signals: bool) -> None:
        self._loop = asyncio.get_running_loop()
        self._done = asyncio.Event()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._loop.add_signal_handler(
                        sig, lambda: asyncio.ensure_future(self.shutdown()))
                except (NotImplementedError, RuntimeError):
                    pass  # pragma: no cover - non-main-thread / platform
        if ready_cb is not None:
            ready_cb(self)
        try:
            await self._done.wait()
        finally:
            # in-flight work has settled (or overstayed the drain budget)
            self._executor.shutdown(wait=False)

    async def shutdown(self) -> None:
        """Graceful drain: stop accepting, let in-flight requests finish
        (bounded by ``drain_s``), flush the ingest queue, release run()."""
        if self._draining:
            return
        self._draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        deadline = self._loop.time() + self.drain_s
        while self._inflight > 0 and self._loop.time() < deadline:
            await asyncio.sleep(0.02)
        try:
            # every ticket acknowledged before the drain becomes durable
            await self._loop.run_in_executor(None, self.service.drain)
        except Exception:  # pragma: no cover - service already stopped
            pass
        self._done.set()

    # -- connection handling --------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        if self._draining:
            writer.close()
            return
        self._conns.inc()
        self._open_conns += 1
        window = asyncio.Semaphore(self.conn_window)
        wlock = asyncio.Lock()
        tasks: set = set()
        try:
            while True:
                try:
                    hdr = await reader.readexactly(_HDR.size)
                except (asyncio.IncompleteReadError, ConnectionResetError):
                    break
                (length,) = _HDR.unpack(hdr)
                if length > self.frame_max:
                    await self._send(writer, wlock, _error_doc(
                        "frame_too_large",
                        f"frame of {length} bytes exceeds the "
                        f"{self.frame_max}-byte limit"))
                    break
                try:
                    payload = await reader.readexactly(length)
                except (asyncio.IncompleteReadError, ConnectionResetError):
                    break
                try:
                    req = json.loads(payload)
                    if not isinstance(req, dict):
                        raise ValueError("frame payload must be an object")
                except ValueError as e:
                    await self._send(writer, wlock,
                                     _error_doc("bad_frame", str(e)))
                    break
                # per-connection backpressure: while a full window is in
                # flight this await parks the reader loop, the kernel
                # socket buffer fills, and the CLIENT stalls — bounded
                # buffering end to end
                await window.acquire()
                if self._draining:
                    window.release()
                    await self._send(writer, wlock, _error_doc(
                        "draining", "gateway is draining for shutdown",
                        id=req.get("id")))
                    continue
                if self._inflight >= self.max_inflight:
                    window.release()
                    self._rejects.inc()
                    await self._send(writer, wlock, _error_doc(
                        "admission_reject",
                        f"{self.max_inflight} requests already in flight; "
                        "retry with backoff", id=req.get("id")))
                    continue
                self._inflight += 1
                task = asyncio.ensure_future(
                    self._serve_one(req, writer, wlock, window))
                tasks.add(task)
                task.add_done_callback(tasks.discard)
        except (ConnectionResetError, OSError):  # pragma: no cover - peer
            pass
        finally:
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, OSError):  # pragma: no cover
                pass
            self._open_conns -= 1

    async def _serve_one(self, req: dict, writer: asyncio.StreamWriter,
                         wlock: asyncio.Lock,
                         window: asyncio.Semaphore) -> None:
        try:
            resp = await self._loop.run_in_executor(
                self._executor, self._execute, req)
        except Exception as e:  # pragma: no cover - _execute catches its own
            resp = _error_doc(type(e).__name__, str(e))
        finally:
            self._inflight -= 1
            window.release()
        resp.setdefault("id", req.get("id"))
        await self._send(writer, wlock, resp)

    async def _send(self, writer: asyncio.StreamWriter, wlock: asyncio.Lock,
                    doc: dict) -> None:
        # one response frame at a time per connection; drain() honors the
        # peer's receive window so slow readers backpressure us too
        async with wlock:
            try:
                writer.write(_frame(doc))
                await writer.drain()
            except (ConnectionResetError, OSError):  # pragma: no cover
                pass

    # -- request execution (thread pool) --------------------------------------

    def _execute(self, req: dict) -> dict:
        op = req.get("op")
        self._requests.inc()
        label = op if op in _OPS else "unknown"
        try:
            with obs.span("gateway.request", op=label):
                if op not in _OPS:
                    raise GatewayError(f"unknown op {op!r}", "unknown_op")
                if self.readonly and op in _WRITE_OPS:
                    raise GatewayError(
                        f"{op} on a read-replica gateway; send writes to "
                        "the lease-holding writer", "read_only")
                out = getattr(self, f"_op_{op}")(req)
            out["ok"] = True
            return out
        except GatewayError as e:
            self._errors.inc()
            return _error_doc(e.code, str(e))
        except ShardQuarantined as e:
            # degraded-read contract: the error names the casualties so a
            # client can route healthy keys elsewhere in the same batch
            self._errors.inc()
            return _error_doc("shard_quarantined", str(e),
                              shard=e.shard_id, key=e.key,
                              bad_keys=list(e.bad_keys))
        except KeyError as e:
            self._errors.inc()
            return _error_doc(
                "not_found", f"no such key: {e.args[0] if e.args else e}")
        except TimeoutError as e:
            self._errors.inc()
            return _error_doc("timeout", str(e))
        except Exception as e:
            self._errors.inc()
            return _error_doc(type(e).__name__, str(e))

    @staticmethod
    def _req_texts(req: dict) -> List[str]:
        texts = req.get("texts")
        if texts is None:
            texts = [req["text"]] if "text" in req else None
        if not texts or not all(isinstance(t, str) for t in texts):
            raise GatewayError("op needs 'texts': [str, ...] or 'text': str",
                               "bad_request")
        return list(texts)

    @staticmethod
    def _req_keys(req: dict) -> List[str]:
        keys = req.get("keys")
        if keys is None:
            keys = [req["key"]] if "key" in req else None
        if not keys or not all(isinstance(k, str) for k in keys):
            raise GatewayError("op needs 'keys': [str, ...] or 'key': str",
                               "bad_request")
        return list(keys)

    def _op_ping(self, req: dict) -> dict:
        return {"pong": True, "readonly": self.readonly}

    def _op_put(self, req: dict) -> dict:
        keys = self.service.put_many(self._req_texts(req), req.get("method"))
        return {"keys": keys, "durable": True}

    def _op_put_async(self, req: dict) -> dict:
        ticket = self.service.put_async(self._req_texts(req),
                                        req.get("method"))
        if req.get("wait"):
            return {"keys": ticket.wait(float(req.get("timeout", 30.0))),
                    "durable": True}
        with self._tickets_lock:
            tid = str(next(self._ticket_ids))
            self._tickets[tid] = ticket
            while len(self._tickets) > _TICKET_BACKLOG:
                self._tickets.popitem(last=False)
        return {"keys": ticket.keys, "ticket": tid, "durable": False}

    def _op_wait(self, req: dict) -> dict:
        tid = str(req.get("ticket", ""))
        with self._tickets_lock:
            ticket = self._tickets.get(tid)
        if ticket is None:
            raise GatewayError(
                f"unknown ticket {tid!r} (expired or never issued)",
                "unknown_ticket")
        return {"keys": ticket.wait(float(req.get("timeout", 30.0))),
                "durable": True}

    def _op_get(self, req: dict) -> dict:
        return {"texts": self.service.get_many(self._req_keys(req))}

    def _op_get_tokens(self, req: dict) -> dict:
        arrs = self.service.get_tokens_many(self._req_keys(req))
        return {"tokens": [np.asarray(a).tolist() for a in arrs]}

    def _op_stats(self, req: dict) -> dict:
        out = {"service": self.service.stats(),
               "gateway": self.gateway_stats()}
        if req.get("snapshot"):
            out["obs"] = obs.snapshot()
        return {"stats": out}

    def _op_refresh(self, req: dict) -> dict:
        store = self.service.store
        if not getattr(store, "readonly", False):
            raise GatewayError("refresh is a replica op; the writer's "
                               "in-memory state is authoritative",
                               "not_a_replica")
        return {"refreshed": store.refresh(force=bool(req.get("force",
                                                              True)))}

    def gateway_stats(self) -> dict:
        return {
            "inflight": self._inflight,
            "open_connections": self._open_conns,
            # replica staleness = writer's store_generation − this one's
            "store_generation": self.service.store.meta_generation,
            "requests": self._requests.value,
            "admission_rejects": self._rejects.value,
            "request_errors": self._errors.value,
            "connections": self._conns.value,
            "max_inflight": self.max_inflight,
            "conn_window": self.conn_window,
            "draining": self._draining,
            "readonly": self.readonly,
        }


class GatewayHandle:
    """An in-process gateway running on a daemon thread (tests and
    benchmarks; real deployments use ``launch/gateway.py``)."""

    def __init__(self, server: GatewayServer,
                 thread: threading.Thread) -> None:
        self.server = server
        self._thread = thread

    @property
    def port(self) -> int:
        return self.server.port

    def shutdown(self, timeout: float = 10.0) -> None:
        loop = self.server._loop
        if loop is not None and loop.is_running():
            asyncio.run_coroutine_threadsafe(
                self.server.shutdown(), loop).result(timeout)
        self._thread.join(timeout)

    def __enter__(self) -> "GatewayHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


def start_in_thread(service: PromptService, **kwargs) -> GatewayHandle:
    """Run a `GatewayServer` on a background thread; returns once the
    socket is bound (``handle.port`` is final)."""
    server = GatewayServer(service, **kwargs)
    ready = threading.Event()
    failure: List[BaseException] = []

    def _run() -> None:
        try:
            server.run(ready_cb=lambda _s: ready.set(),
                       install_signals=False)
        except BaseException as e:  # startup failure: surface to caller
            failure.append(e)
            ready.set()

    thread = threading.Thread(target=_run, name="gateway", daemon=True)
    thread.start()
    if not ready.wait(10.0) or failure:
        raise RuntimeError(
            f"gateway failed to start: {failure[0] if failure else 'timeout'}")
    return GatewayHandle(server, thread)


class RetryPolicy:
    """Client retry budget: up to ``retries`` re-attempts after the
    first try, exponential backoff from ``base_s`` doubling up to
    ``max_s``, jittered by a seeded RNG so a chaos run replays the exact
    same sleep schedule.  Defaults come from ``REPRO_GATEWAY_RETRIES`` /
    ``REPRO_GATEWAY_RETRY_BASE_S`` / ``REPRO_FAULTS_SEED``."""

    def __init__(self, retries: Optional[int] = None,
                 base_s: Optional[float] = None, max_s: float = 2.0,
                 seed: Optional[int] = None) -> None:
        self.retries = (env.read("REPRO_GATEWAY_RETRIES")
                        if retries is None else int(retries))
        self.base_s = (env.read("REPRO_GATEWAY_RETRY_BASE_S")
                       if base_s is None else float(base_s))
        self.max_s = float(max_s)
        self._rng = random.Random(env.read("REPRO_FAULTS_SEED")
                                  if seed is None else seed)

    def backoff_s(self, attempt: int) -> float:
        """Sleep before retry number ``attempt`` (0-based): full-jitter
        over the upper half of the exponential window."""
        span = min(self.max_s, self.base_s * (2.0 ** attempt))
        return span * (0.5 + self._rng.random() / 2.0)


class GatewayClient:
    """Blocking client for the frame protocol (one request/response at a
    time per client; open one client per concurrent stream, or pipeline
    raw frames yourself to exercise the connection window).

    ``call`` and every convenience wrapper are resilient: connection
    loss triggers a transparent reconnect, and error responses the
    server marks ``retryable`` (admission rejects, timeouts, drains)
    are retried with seeded exponential backoff — safe because every op
    is idempotent.  ``request`` stays a single raw attempt.  Pass
    ``retries=0`` to observe single-attempt protocol behavior."""

    def __init__(self, host: str, port: int, timeout: float = 30.0, *,
                 retries: Optional[int] = None,
                 retry_base_s: Optional[float] = None,
                 retry_seed: Optional[int] = None) -> None:
        self._host = host
        self._port = port
        self._timeout = float(timeout)
        self.policy = RetryPolicy(retries=retries, base_s=retry_base_s,
                                  seed=retry_seed)
        self._sock: Optional[socket.socket] = None
        self._rfile = None
        self._ids = itertools.count(1)
        self._lock = threading.Lock()
        self._connect()

    # -- connection management -------------------------------------------------

    def _connect(self) -> None:
        self._sock = socket.create_connection((self._host, self._port),
                                              timeout=self._timeout)
        self._rfile = self._sock.makefile("rb")

    def _drop_locked(self) -> None:
        """Tear down a (possibly torn) connection; the next request
        reconnects lazily.  Caller holds ``self._lock``."""
        try:
            if self._rfile is not None:
                self._rfile.close()
            if self._sock is not None:
                self._sock.close()
        except OSError:  # pragma: no cover - already dead
            pass
        self._sock = None
        self._rfile = None

    def request(self, op: str, **fields) -> dict:
        """Send one request, return the raw response document.  Exactly
        one attempt — no retry, no backoff; ``call`` layers those.  A
        dead connection is re-established first (the reconnect half of
        resilience lives here so raw-protocol users heal too)."""
        doc = {"op": op, "id": next(self._ids), **fields}
        with self._lock:
            if self._sock is None:
                self._connect()
                obs.counter("gateway.client.reconnects").inc()
            try:
                failpoints.fire("gateway.send")
                self._sock.sendall(_frame(doc))
            except OSError as e:
                self._drop_locked()
                raise GatewayConnectionLost(
                    f"send failed: {e}", op=op,
                    request_id=doc["id"]) from e
            try:
                return self._read_response(op, doc["id"])
            except GatewayConnectionLost:
                self._drop_locked()
                raise
            except OSError as e:
                self._drop_locked()
                raise GatewayConnectionLost(
                    f"receive failed: {e}", op=op,
                    request_id=doc["id"]) from e

    def _read_response(self, op: str = "?", request_id: Any = None) -> dict:
        failpoints.fire("gateway.recv")
        hdr = self._rfile.read(_HDR.size)
        n_hdr = len(hdr) if hdr else 0
        if n_hdr < _HDR.size:
            raise GatewayConnectionLost(
                "gateway closed the connection", op=op,
                request_id=request_id, bytes_read=n_hdr)
        (length,) = _HDR.unpack(hdr)
        payload = self._rfile.read(length)
        n_payload = len(payload) if payload else 0
        if n_payload < length:
            raise GatewayConnectionLost(
                "gateway closed mid-frame", op=op, request_id=request_id,
                bytes_read=n_hdr + n_payload)
        return json.loads(payload)

    # -- resilient call --------------------------------------------------------

    def call(self, op: str, *, deadline_s: Optional[float] = None,
             **fields) -> dict:
        """`request` + raise `GatewayError` on ``ok: false`` — wrapped
        in the retry loop: reconnect-and-retry on connection loss, and
        backoff-and-retry on responses the server marks ``retryable``,
        bounded by the retry budget and the optional per-op deadline."""
        deadline = (None if deadline_s is None
                    else time.monotonic() + float(deadline_s))
        attempt = 0
        while True:
            try:
                resp = self.request(op, **fields)
            except GatewayConnectionLost:
                if not self._sleep_before_retry(attempt, deadline):
                    raise
                attempt += 1
                continue
            if resp.get("ok"):
                return resp
            err = GatewayError(
                f"{resp.get('error', 'error')}: {resp.get('message', '')}",
                resp.get("error", "error"),
                retryable=bool(resp.get("retryable")))
            if not err.retryable or not self._sleep_before_retry(attempt,
                                                                 deadline):
                raise err
            attempt += 1

    def _sleep_before_retry(self, attempt: int,
                            deadline: Optional[float]) -> bool:
        """True iff budget and deadline allow retry ``attempt`` — after
        sleeping the backoff (clipped so we never sleep past deadline)."""
        if attempt >= self.policy.retries:
            return False
        pause = self.policy.backoff_s(attempt)
        if deadline is not None:
            remaining = deadline - time.monotonic()
            if remaining <= 0:
                return False
            pause = min(pause, remaining)
        time.sleep(pause)
        obs.counter("gateway.client.retries").inc()
        return True

    # -- convenience wrappers --------------------------------------------------

    def ping(self) -> dict:
        return self.call("ping")

    def put(self, texts: Sequence[str],
            method: Optional[str] = None) -> List[str]:
        return self.call("put", texts=list(texts), method=method)["keys"]

    def put_async(self, texts: Sequence[str], method: Optional[str] = None,
                  wait: bool = False, timeout: float = 30.0) -> dict:
        return self.call("put_async", texts=list(texts), method=method,
                         wait=wait, timeout=timeout)

    def wait(self, ticket: str, timeout: float = 30.0) -> List[str]:
        return self.call("wait", ticket=ticket, timeout=timeout)["keys"]

    def get(self, key: str) -> str:
        return self.call("get", key=key)["texts"][0]

    def get_many(self, keys: Sequence[str]) -> List[str]:
        return self.call("get", keys=list(keys))["texts"]

    def get_tokens(self, key: str) -> np.ndarray:
        return np.asarray(self.call("get_tokens", key=key)["tokens"][0])

    def stats(self, snapshot: bool = False) -> dict:
        return self.call("stats", snapshot=snapshot)["stats"]

    def refresh(self) -> bool:
        return self.call("refresh")["refreshed"]

    def close(self) -> None:
        with self._lock:
            self._drop_locked()

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
