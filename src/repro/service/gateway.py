"""Socket gateway: the network front end of the service tier.

One writer gateway (owning the store lease) and N read-replica gateways
front a shared store root; clients speak a length-prefixed JSON frame
protocol over TCP:

    frame   := uint32_be(len(payload)) payload
    payload := JSON object, UTF-8

    request : {"op": <op>, "id": <any>, ...op fields}
    response: {"id": <echoed>, "ok": true, ...result}
            | {"id": <echoed>, "ok": false, "error": <code>,
               "message": <human text>}

Ops: ``ping``, ``put`` (synchronous durable put_many), ``put_async``
(queue + ticket; ``wait: true`` blocks until durable), ``wait`` (redeem
a ticket id), ``get``, ``get_tokens``, ``stats`` (``snapshot: true``
embeds the full obs snapshot), ``refresh`` (replica: re-poll the
writer's store.json).

Admission control — the gateway never buffers unboundedly:

* **global max in-flight** (``REPRO_GATEWAY_MAX_INFLIGHT``): a request
  arriving while that many are executing is REJECTED immediately
  (``error=admission_reject``), not queued — shedding load beats
  building an invisible queue in front of the ingest queue's own
  bounded backpressure;
* **per-connection window** (``REPRO_GATEWAY_CONN_WINDOW``): the
  connection's reader loop stops consuming frames while a window's
  worth are in flight, so a pipelining client is stalled by TCP flow
  control — which is how the ingest queue's ``max_pending`` propagates
  all the way back to the client socket instead of being absorbed by
  server-side buffering.

Graceful drain: SIGTERM/SIGINT stops accepting connections, lets
in-flight requests finish (bounded by ``REPRO_GATEWAY_DRAIN_S``),
drains the ingest queue so every acknowledged ticket is durable, then
exits.  Requests executing blocking store/service calls run on a
thread pool sized to the in-flight cap; the asyncio loop itself only
frames, admits, and responds.

Instrumented through ``repro.obs``: per-op request-latency histograms
(``gateway.request.s{op=...}``), an in-flight gauge, and counters for
requests, admission rejects, errors, and connections.
"""

from __future__ import annotations

import asyncio
import itertools
import json
import signal
import socket
import struct
import threading
from collections import OrderedDict
from concurrent.futures import ThreadPoolExecutor
from typing import Any, Dict, List, Optional, Sequence

import numpy as np

from repro import obs
from repro.core import env
from repro.service.service import PromptService

_HDR = struct.Struct(">I")

#: ticket ids kept redeemable per gateway (oldest pruned first)
_TICKET_BACKLOG = 1024

#: ops a read-only replica gateway refuses outright
_WRITE_OPS = frozenset({"put", "put_async", "wait"})

#: known ops (bounds the label cardinality of the request histogram)
_OPS = frozenset({"ping", "put", "put_async", "wait", "get", "get_tokens",
                  "stats", "refresh"})


class GatewayError(RuntimeError):
    """A gateway request failed; ``code`` is the protocol error code."""

    def __init__(self, message: str, code: str = "error") -> None:
        super().__init__(message)
        self.code = code


def _frame(doc: Dict[str, Any]) -> bytes:
    payload = json.dumps(doc).encode("utf-8")
    return _HDR.pack(len(payload)) + payload


class GatewayServer:
    """Asyncio TCP server fronting one `PromptService`.

    ``readonly=True`` marks a replica gateway: write ops are refused at
    the front door (the store would refuse them anyway) and ``refresh``
    is served.  ``port=0`` binds an ephemeral port, published on
    ``self.port`` once running."""

    def __init__(self, service: PromptService, host: str = "127.0.0.1",
                 port: int = 0, *, max_inflight: Optional[int] = None,
                 conn_window: Optional[int] = None,
                 frame_max: Optional[int] = None,
                 drain_s: Optional[float] = None,
                 readonly: bool = False) -> None:
        self.service = service
        self.host = host
        self.port = port
        self.readonly = bool(readonly)
        self.max_inflight = (env.read("REPRO_GATEWAY_MAX_INFLIGHT")
                             if max_inflight is None else int(max_inflight))
        self.conn_window = (env.read("REPRO_GATEWAY_CONN_WINDOW")
                            if conn_window is None else int(conn_window))
        self.frame_max = (env.read("REPRO_GATEWAY_FRAME_MAX")
                          if frame_max is None else int(frame_max))
        self.drain_s = (env.read("REPRO_GATEWAY_DRAIN_S")
                        if drain_s is None else float(drain_s))
        if min(self.max_inflight, self.conn_window, self.frame_max) < 1:
            raise ValueError("max_inflight, conn_window and frame_max must "
                             "be >= 1")
        self._loop: Optional[asyncio.AbstractEventLoop] = None
        self._server: Optional[asyncio.base_events.Server] = None
        self._done: Optional[asyncio.Event] = None
        self._draining = False
        self._inflight = 0          # event-loop-thread only
        self._open_conns = 0        # event-loop-thread only
        self._executor = ThreadPoolExecutor(
            max_workers=self.max_inflight, thread_name_prefix="gateway-exec")
        self._tickets: "OrderedDict[str, Any]" = OrderedDict()
        self._tickets_lock = threading.Lock()
        self._ticket_ids = itertools.count(1)
        self._requests = obs.owned_counter("gateway.requests")
        self._rejects = obs.owned_counter("gateway.admission_rejects")
        self._errors = obs.owned_counter("gateway.request_errors")
        self._conns = obs.owned_counter("gateway.connections")
        obs.owned_gauge("gateway.inflight", lambda: self._inflight)
        obs.owned_gauge("gateway.open_connections", lambda: self._open_conns)

    # -- lifecycle ------------------------------------------------------------

    def run(self, ready_cb=None, install_signals: bool = True) -> None:
        """Serve until drained (blocks).  ``ready_cb(self)`` fires once
        the socket is bound (``self.port`` is final); ``install_signals``
        wires SIGTERM/SIGINT to graceful drain."""
        asyncio.run(self._main(ready_cb, install_signals))

    async def _main(self, ready_cb, install_signals: bool) -> None:
        self._loop = asyncio.get_running_loop()
        self._done = asyncio.Event()
        self._server = await asyncio.start_server(
            self._handle_conn, self.host, self.port)
        self.port = self._server.sockets[0].getsockname()[1]
        if install_signals:
            for sig in (signal.SIGTERM, signal.SIGINT):
                try:
                    self._loop.add_signal_handler(
                        sig, lambda: asyncio.ensure_future(self.shutdown()))
                except (NotImplementedError, RuntimeError):
                    pass  # pragma: no cover - non-main-thread / platform
        if ready_cb is not None:
            ready_cb(self)
        try:
            await self._done.wait()
        finally:
            # in-flight work has settled (or overstayed the drain budget)
            self._executor.shutdown(wait=False)

    async def shutdown(self) -> None:
        """Graceful drain: stop accepting, let in-flight requests finish
        (bounded by ``drain_s``), flush the ingest queue, release run()."""
        if self._draining:
            return
        self._draining = True
        if self._server is not None:
            self._server.close()
            await self._server.wait_closed()
        deadline = self._loop.time() + self.drain_s
        while self._inflight > 0 and self._loop.time() < deadline:
            await asyncio.sleep(0.02)
        try:
            # every ticket acknowledged before the drain becomes durable
            await self._loop.run_in_executor(None, self.service.drain)
        except Exception:  # pragma: no cover - service already stopped
            pass
        self._done.set()

    # -- connection handling --------------------------------------------------

    async def _handle_conn(self, reader: asyncio.StreamReader,
                           writer: asyncio.StreamWriter) -> None:
        if self._draining:
            writer.close()
            return
        self._conns.inc()
        self._open_conns += 1
        window = asyncio.Semaphore(self.conn_window)
        wlock = asyncio.Lock()
        tasks: set = set()
        try:
            while True:
                try:
                    hdr = await reader.readexactly(_HDR.size)
                except (asyncio.IncompleteReadError, ConnectionResetError):
                    break
                (length,) = _HDR.unpack(hdr)
                if length > self.frame_max:
                    await self._send(writer, wlock, {
                        "ok": False, "error": "frame_too_large",
                        "message": f"frame of {length} bytes exceeds the "
                                   f"{self.frame_max}-byte limit"})
                    break
                try:
                    payload = await reader.readexactly(length)
                except (asyncio.IncompleteReadError, ConnectionResetError):
                    break
                try:
                    req = json.loads(payload)
                    if not isinstance(req, dict):
                        raise ValueError("frame payload must be an object")
                except ValueError as e:
                    await self._send(writer, wlock, {
                        "ok": False, "error": "bad_frame", "message": str(e)})
                    break
                # per-connection backpressure: while a full window is in
                # flight this await parks the reader loop, the kernel
                # socket buffer fills, and the CLIENT stalls — bounded
                # buffering end to end
                await window.acquire()
                if self._draining:
                    window.release()
                    await self._send(writer, wlock, {
                        "id": req.get("id"), "ok": False, "error": "draining",
                        "message": "gateway is draining for shutdown"})
                    continue
                if self._inflight >= self.max_inflight:
                    window.release()
                    self._rejects.inc()
                    await self._send(writer, wlock, {
                        "id": req.get("id"), "ok": False,
                        "error": "admission_reject",
                        "message": f"{self.max_inflight} requests already "
                                   "in flight; retry with backoff"})
                    continue
                self._inflight += 1
                task = asyncio.ensure_future(
                    self._serve_one(req, writer, wlock, window))
                tasks.add(task)
                task.add_done_callback(tasks.discard)
        except (ConnectionResetError, OSError):  # pragma: no cover - peer
            pass
        finally:
            if tasks:
                await asyncio.gather(*tasks, return_exceptions=True)
            try:
                writer.close()
                await writer.wait_closed()
            except (ConnectionResetError, OSError):  # pragma: no cover
                pass
            self._open_conns -= 1

    async def _serve_one(self, req: dict, writer: asyncio.StreamWriter,
                         wlock: asyncio.Lock,
                         window: asyncio.Semaphore) -> None:
        try:
            resp = await self._loop.run_in_executor(
                self._executor, self._execute, req)
        except Exception as e:  # pragma: no cover - _execute catches its own
            resp = {"ok": False, "error": type(e).__name__, "message": str(e)}
        finally:
            self._inflight -= 1
            window.release()
        resp.setdefault("id", req.get("id"))
        await self._send(writer, wlock, resp)

    async def _send(self, writer: asyncio.StreamWriter, wlock: asyncio.Lock,
                    doc: dict) -> None:
        # one response frame at a time per connection; drain() honors the
        # peer's receive window so slow readers backpressure us too
        async with wlock:
            try:
                writer.write(_frame(doc))
                await writer.drain()
            except (ConnectionResetError, OSError):  # pragma: no cover
                pass

    # -- request execution (thread pool) --------------------------------------

    def _execute(self, req: dict) -> dict:
        op = req.get("op")
        self._requests.inc()
        label = op if op in _OPS else "unknown"
        try:
            with obs.span("gateway.request", op=label):
                if op not in _OPS:
                    raise GatewayError(f"unknown op {op!r}", "unknown_op")
                if self.readonly and op in _WRITE_OPS:
                    raise GatewayError(
                        f"{op} on a read-replica gateway; send writes to "
                        "the lease-holding writer", "read_only")
                out = getattr(self, f"_op_{op}")(req)
            out["ok"] = True
            return out
        except GatewayError as e:
            self._errors.inc()
            return {"ok": False, "error": e.code, "message": str(e)}
        except KeyError as e:
            self._errors.inc()
            return {"ok": False, "error": "not_found",
                    "message": f"no such key: {e.args[0] if e.args else e}"}
        except TimeoutError as e:
            self._errors.inc()
            return {"ok": False, "error": "timeout", "message": str(e)}
        except Exception as e:
            self._errors.inc()
            return {"ok": False, "error": type(e).__name__, "message": str(e)}

    @staticmethod
    def _req_texts(req: dict) -> List[str]:
        texts = req.get("texts")
        if texts is None:
            texts = [req["text"]] if "text" in req else None
        if not texts or not all(isinstance(t, str) for t in texts):
            raise GatewayError("op needs 'texts': [str, ...] or 'text': str",
                               "bad_request")
        return list(texts)

    @staticmethod
    def _req_keys(req: dict) -> List[str]:
        keys = req.get("keys")
        if keys is None:
            keys = [req["key"]] if "key" in req else None
        if not keys or not all(isinstance(k, str) for k in keys):
            raise GatewayError("op needs 'keys': [str, ...] or 'key': str",
                               "bad_request")
        return list(keys)

    def _op_ping(self, req: dict) -> dict:
        return {"pong": True, "readonly": self.readonly}

    def _op_put(self, req: dict) -> dict:
        keys = self.service.put_many(self._req_texts(req), req.get("method"))
        return {"keys": keys, "durable": True}

    def _op_put_async(self, req: dict) -> dict:
        ticket = self.service.put_async(self._req_texts(req),
                                        req.get("method"))
        if req.get("wait"):
            return {"keys": ticket.wait(float(req.get("timeout", 30.0))),
                    "durable": True}
        with self._tickets_lock:
            tid = str(next(self._ticket_ids))
            self._tickets[tid] = ticket
            while len(self._tickets) > _TICKET_BACKLOG:
                self._tickets.popitem(last=False)
        return {"keys": ticket.keys, "ticket": tid, "durable": False}

    def _op_wait(self, req: dict) -> dict:
        tid = str(req.get("ticket", ""))
        with self._tickets_lock:
            ticket = self._tickets.get(tid)
        if ticket is None:
            raise GatewayError(
                f"unknown ticket {tid!r} (expired or never issued)",
                "unknown_ticket")
        return {"keys": ticket.wait(float(req.get("timeout", 30.0))),
                "durable": True}

    def _op_get(self, req: dict) -> dict:
        return {"texts": self.service.get_many(self._req_keys(req))}

    def _op_get_tokens(self, req: dict) -> dict:
        arrs = self.service.get_tokens_many(self._req_keys(req))
        return {"tokens": [np.asarray(a).tolist() for a in arrs]}

    def _op_stats(self, req: dict) -> dict:
        out = {"service": self.service.stats(),
               "gateway": self.gateway_stats()}
        if req.get("snapshot"):
            out["obs"] = obs.snapshot()
        return {"stats": out}

    def _op_refresh(self, req: dict) -> dict:
        store = self.service.store
        if not getattr(store, "readonly", False):
            raise GatewayError("refresh is a replica op; the writer's "
                               "in-memory state is authoritative",
                               "not_a_replica")
        return {"refreshed": store.refresh(force=bool(req.get("force",
                                                              True)))}

    def gateway_stats(self) -> dict:
        return {
            "inflight": self._inflight,
            "open_connections": self._open_conns,
            "requests": self._requests.value,
            "admission_rejects": self._rejects.value,
            "request_errors": self._errors.value,
            "connections": self._conns.value,
            "max_inflight": self.max_inflight,
            "conn_window": self.conn_window,
            "draining": self._draining,
            "readonly": self.readonly,
        }


class GatewayHandle:
    """An in-process gateway running on a daemon thread (tests and
    benchmarks; real deployments use ``launch/gateway.py``)."""

    def __init__(self, server: GatewayServer,
                 thread: threading.Thread) -> None:
        self.server = server
        self._thread = thread

    @property
    def port(self) -> int:
        return self.server.port

    def shutdown(self, timeout: float = 10.0) -> None:
        loop = self.server._loop
        if loop is not None and loop.is_running():
            asyncio.run_coroutine_threadsafe(
                self.server.shutdown(), loop).result(timeout)
        self._thread.join(timeout)

    def __enter__(self) -> "GatewayHandle":
        return self

    def __exit__(self, *exc) -> None:
        self.shutdown()


def start_in_thread(service: PromptService, **kwargs) -> GatewayHandle:
    """Run a `GatewayServer` on a background thread; returns once the
    socket is bound (``handle.port`` is final)."""
    server = GatewayServer(service, **kwargs)
    ready = threading.Event()
    failure: List[BaseException] = []

    def _run() -> None:
        try:
            server.run(ready_cb=lambda _s: ready.set(),
                       install_signals=False)
        except BaseException as e:  # startup failure: surface to caller
            failure.append(e)
            ready.set()

    thread = threading.Thread(target=_run, name="gateway", daemon=True)
    thread.start()
    if not ready.wait(10.0) or failure:
        raise RuntimeError(
            f"gateway failed to start: {failure[0] if failure else 'timeout'}")
    return GatewayHandle(server, thread)


class GatewayClient:
    """Blocking client for the frame protocol (one request/response at a
    time per client; open one client per concurrent stream, or pipeline
    raw frames yourself to exercise the connection window)."""

    def __init__(self, host: str, port: int, timeout: float = 30.0) -> None:
        self._sock = socket.create_connection((host, port), timeout=timeout)
        self._rfile = self._sock.makefile("rb")
        self._ids = itertools.count(1)
        self._lock = threading.Lock()

    def request(self, op: str, **fields) -> dict:
        """Send one request, return the raw response document."""
        doc = {"op": op, "id": next(self._ids), **fields}
        with self._lock:
            self._sock.sendall(_frame(doc))
            return self._read_response()

    def _read_response(self) -> dict:
        hdr = self._rfile.read(_HDR.size)
        if hdr is None or len(hdr) < _HDR.size:
            raise ConnectionError("gateway closed the connection")
        (length,) = _HDR.unpack(hdr)
        payload = self._rfile.read(length)
        if payload is None or len(payload) < length:
            raise ConnectionError("gateway closed mid-frame")
        return json.loads(payload)

    def call(self, op: str, **fields) -> dict:
        """`request` + raise `GatewayError` on ``ok: false``."""
        resp = self.request(op, **fields)
        if not resp.get("ok"):
            raise GatewayError(
                f"{resp.get('error', 'error')}: {resp.get('message', '')}",
                resp.get("error", "error"))
        return resp

    # -- convenience wrappers --------------------------------------------------

    def ping(self) -> dict:
        return self.call("ping")

    def put(self, texts: Sequence[str],
            method: Optional[str] = None) -> List[str]:
        return self.call("put", texts=list(texts), method=method)["keys"]

    def put_async(self, texts: Sequence[str], method: Optional[str] = None,
                  wait: bool = False, timeout: float = 30.0) -> dict:
        return self.call("put_async", texts=list(texts), method=method,
                         wait=wait, timeout=timeout)

    def wait(self, ticket: str, timeout: float = 30.0) -> List[str]:
        return self.call("wait", ticket=ticket, timeout=timeout)["keys"]

    def get(self, key: str) -> str:
        return self.call("get", key=key)["texts"][0]

    def get_many(self, keys: Sequence[str]) -> List[str]:
        return self.call("get", keys=list(keys))["texts"]

    def get_tokens(self, key: str) -> np.ndarray:
        return np.asarray(self.call("get_tokens", key=key)["tokens"][0])

    def stats(self, snapshot: bool = False) -> dict:
        return self.call("stats", snapshot=snapshot)["stats"]

    def refresh(self) -> bool:
        return self.call("refresh")["refreshed"]

    def close(self) -> None:
        try:
            self._rfile.close()
            self._sock.close()
        except OSError:  # pragma: no cover
            pass

    def __enter__(self) -> "GatewayClient":
        return self

    def __exit__(self, *exc) -> None:
        self.close()
