"""repro.service — the concurrent PromptStore service tier.

Turns the passive store library into a long-running service: async
ingest with group commit (`ingest`), background per-shard compaction
with codec stage reselection (`compaction`), a byte-budgeted serve-path
token cache (`cache`), and the composed lifecycle (`service`).
See ARCHITECTURE.md "Service tier".
"""

from repro.service.cache import TokenCache
from repro.service.compaction import (BackgroundCompactor, CompactionResult,
                                      compact_shard, compact_store)
from repro.service.ingest import IngestError, IngestQueue, IngestTicket
from repro.service.service import PromptService

__all__ = [
    "BackgroundCompactor",
    "CompactionResult",
    "IngestError",
    "IngestQueue",
    "IngestTicket",
    "PromptService",
    "TokenCache",
    "compact_shard",
    "compact_store",
]
