"""repro.service — the concurrent PromptStore service tier.

Turns the passive store library into a long-running service: async
ingest with group commit (`ingest`), background per-shard compaction
with codec stage reselection (`compaction`), background integrity
scrubbing with quarantine + repair (`scrub`), a byte-budgeted
serve-path token cache (`cache`), and the composed lifecycle
(`service`).  See ARCHITECTURE.md "Service tier" and "Fault tolerance".
"""

from repro.service.cache import TokenCache
from repro.service.compaction import (BackgroundCompactor, CompactionResult,
                                      compact_shard, compact_store)
from repro.service.ingest import IngestError, IngestQueue, IngestTicket
from repro.service.scrub import (BackgroundScrubber, RepairResult,
                                 ScrubResult, repair_shard, repair_store,
                                 scrub_shard, scrub_store)
from repro.service.service import PromptService

__all__ = [
    "BackgroundCompactor",
    "BackgroundScrubber",
    "CompactionResult",
    "IngestError",
    "IngestQueue",
    "IngestTicket",
    "PromptService",
    "RepairResult",
    "ScrubResult",
    "TokenCache",
    "compact_shard",
    "compact_store",
    "repair_shard",
    "repair_store",
    "scrub_shard",
    "scrub_store",
]
