"""Per-shard background compaction: reclaim dead bytes and re-run codec
stage selection on each shard's actual content mix.

Dead bytes accumulate from racing duplicate ingests (the async queue's
documented dup window) and from records dropped at recovery time (torn
tails); the append-only segment files never shrink on their own.  And the
codec pipeline that was best at ingest time is not necessarily best for
the shard's final content mix — the paper's own results (§5) show the
winner flipping between zstd/token/hybrid with prompt size and content
type, so compaction re-evaluates ALL available method pipelines over the
shard's decompressed texts and re-encodes iff a different pipeline wins.

A rebuild is crash-safe end to end: blobs are read from a snapshot, the
new generation is written to fresh filenames, records committed during
the rebuild are caught up under the shard lock, and the atomic meta
replace in `ShardedPromptStore.swap_shard` is the single commit point
(either generation reopens intact; see the store's docstring).

Losslessness: compaction only ever rewrites a record's *encoding* — each
text is decompressed and its sha256 is checked against the content key
before any re-encode is considered; a shard with even one integrity
failure is compacted without re-encoding (the bad blob is preserved
bit-for-bit for forensics rather than laundered through a codec).
"""

from __future__ import annotations

import threading
import time
from dataclasses import dataclass
from typing import List, Optional

from repro.core.store import ShardedPromptStore, content_key


@dataclass
class CompactionResult:
    shard_id: int
    n_records: int
    n_caught_up: int
    bytes_before: int
    bytes_after: int
    method: Optional[str]       # pipeline the shard was re-encoded with
    reencoded: bool
    wall_s: float

    @property
    def bytes_reclaimed(self) -> int:
        return max(self.bytes_before - self.bytes_after, 0)


def _candidate_methods(store: ShardedPromptStore) -> List[str]:
    from repro.core.api import METHODS

    if store.compressor.tokenizer is None:
        return ["zstd"]
    return list(METHODS)


def compact_shard(store: ShardedPromptStore, shard_id: int,
                  reselect: bool = True) -> Optional[CompactionResult]:
    """Rebuild one shard; returns None if another compactor holds it.

    Phases (heavy work happens with no store lock held):
    1. snapshot the live records + blobs;
    2. integrity-check every text against its content key;
    3. if `reselect` and the shard is clean: encode the texts through every
       candidate method pipeline, pick the smallest total, and keep the
       re-encoded blobs only on a strict win;
    4. `swap_shard` — catch-up + new generation + atomic meta commit.
    """
    lock = store.compaction_lock(shard_id)
    if not lock.acquire(blocking=False):
        return None
    try:
        t0 = time.perf_counter()
        recs = store.shard_records(shard_id)
        blobs = store.read_records(shard_id, recs)
        entries = [
            {"key": r["key"], "seq": r["seq"], "method": r["method"],
             "n_chars": r["n_chars"], "blob": b}
            for r, b in zip(recs, blobs)
        ]
        chosen: Optional[str] = None
        reencoded = False
        if reselect and entries:
            try:
                texts = store.compressor.decompress_batch(blobs)
                clean = all(content_key(t) == r["key"]
                            for t, r in zip(texts, recs))
            except Exception:
                clean = False
            if clean:
                current_total = sum(len(b) for b in blobs)
                best_total = current_total
                best_blobs: Optional[List[bytes]] = None
                for method in _candidate_methods(store):
                    new_blobs = store.compressor.compress_batch(texts, method)
                    total = sum(len(b) for b in new_blobs)
                    if total < best_total:
                        best_total, best_blobs, chosen = total, new_blobs, method
                if best_blobs is not None:
                    reencoded = True
                    for e, b in zip(entries, best_blobs):
                        e["blob"] = b
                        e["method"] = chosen
        swap = store.swap_shard(shard_id, entries)
        return CompactionResult(
            shard_id=shard_id,
            n_records=swap["n_records"],
            n_caught_up=swap["n_caught_up"],
            bytes_before=swap["bytes_before"],
            bytes_after=swap["bytes_after"],
            method=chosen,
            reencoded=reencoded,
            wall_s=time.perf_counter() - t0,
        )
    finally:
        lock.release()


def compact_store(store: ShardedPromptStore,
                  reselect: bool = True) -> List[CompactionResult]:
    """Compact every shard (skipping any a background compactor holds)."""
    out = []
    for shard_id in range(store.n_shards):
        res = compact_shard(store, shard_id, reselect=reselect)
        if res is not None:
            out.append(res)
    return out


class BackgroundCompactor:
    """Periodic scan-and-compact thread.

    Every `interval_s` it reads each shard's live/dead byte accounting
    (`store.shard_stats`) and rebuilds shards whose dead ratio exceeds
    `trigger_dead_ratio` (with at least `min_dead_bytes` reclaimable, so
    tiny stores don't churn).  `force_reselect_every` full passes, clean
    shards are compacted too, to pick up stage-selection wins that dead
    bytes alone would never trigger (0 disables that sweep).
    """

    def __init__(self, store: ShardedPromptStore, interval_s: float = 5.0,
                 trigger_dead_ratio: float = 0.25, min_dead_bytes: int = 4096,
                 reselect: bool = True, force_reselect_every: int = 0) -> None:
        self._store = store
        self.interval_s = float(interval_s)
        self.trigger_dead_ratio = float(trigger_dead_ratio)
        self.min_dead_bytes = int(min_dead_bytes)
        self.reselect = reselect
        self.force_reselect_every = int(force_reselect_every)
        self._stop_event = threading.Event()
        self._thread: Optional[threading.Thread] = None
        self._lock = threading.Lock()
        self._passes = 0
        self._compactions = 0
        self._bytes_reclaimed = 0
        self._errors = 0

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "BackgroundCompactor":
        if self._thread is not None:
            raise RuntimeError("compactor already started")
        self._thread = threading.Thread(target=self._loop,
                                        name="shard-compactor", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Idempotent: finish the in-flight shard (never torn — the swap
        is atomic regardless) and join."""
        self._stop_event.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- scan loop -------------------------------------------------------------

    def _loop(self) -> None:
        while not self._stop_event.wait(self.interval_s):
            self.run_pass()

    def run_pass(self) -> List[CompactionResult]:
        """One scan over all shards (also callable synchronously)."""
        with self._lock:
            self._passes += 1
            sweep = (self.force_reselect_every > 0
                     and self._passes % self.force_reselect_every == 0)
        results: List[CompactionResult] = []
        all_stats = self._store.all_shard_stats()  # one index pass
        for shard_id in range(self._store.n_shards):
            if self._stop_event.is_set() and not sweep:
                break
            try:
                st = all_stats[shard_id]
                dead, size = st["dead_bytes"], max(st["file_bytes"], 1)
                due = (dead >= self.min_dead_bytes
                       and dead / size >= self.trigger_dead_ratio)
                if not due and not (sweep and st["n_records"]):
                    continue
                res = compact_shard(self._store, shard_id, reselect=self.reselect)
            except Exception:
                with self._lock:
                    self._errors += 1
                continue
            if res is not None:
                results.append(res)
                with self._lock:
                    self._compactions += 1
                    self._bytes_reclaimed += res.bytes_reclaimed
        return results

    def stats(self) -> dict:
        with self._lock:
            return {
                "passes": self._passes,
                "compactions": self._compactions,
                "bytes_reclaimed": self._bytes_reclaimed,
                "errors": self._errors,
                "interval_s": self.interval_s,
                "trigger_dead_ratio": self.trigger_dead_ratio,
                "min_dead_bytes": self.min_dead_bytes,
            }
