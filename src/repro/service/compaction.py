"""Per-shard background compaction: reclaim dead bytes and re-run codec
stage selection — now including trained-dictionary candidates — on each
shard's actual content mix.

Dead bytes accumulate from racing duplicate ingests (the async queue's
documented dup window) and from records dropped at recovery time (torn
tails); the append-only segment files never shrink on their own.  And the
codec pipeline that was best at ingest time is not necessarily best for
the shard's final content mix — the paper's own results (§5) show the
winner flipping between zstd/token/hybrid with prompt size and content
type, so compaction re-evaluates ALL available method pipelines over the
shard's decompressed texts and re-encodes iff a different pipeline wins.

Dictionary training rides the same pass: per-record compression cannot
see cross-record redundancy, which is exactly where short prompts lose
the most (paper §8.4.2 #2), so for each dict-capable method the pass
trains a dictionary on the shard's byte-stage payloads and adds
"method + dictionary" to the candidate set.  A dictionary candidate is
charged its own sidecar size, and — like every re-encode — is adopted
only on a STRICT total-bytes win; the winning dictionary is persisted by
`swap_shard` as the new generation's `.dict` sidecar.  A shard whose
current frames already reference a dictionary carries it (and its size)
through a rebuild that keeps those blobs, so sidecars are never dropped
out from under live frames.

A rebuild is crash-safe end to end: blobs are read from a snapshot, the
new generation is written to fresh filenames, records committed during
the rebuild are caught up under the shard lock, and the atomic meta
replace in `ShardedPromptStore.swap_shard` is the single commit point
(either generation reopens intact; see the store's docstring).

Losslessness: compaction only ever rewrites a record's *encoding* — each
text is decompressed and its sha256 is checked against the content key
before any re-encode is considered; a shard with even one integrity
failure is compacted without re-encoding (the bad blob is preserved
bit-for-bit for forensics rather than laundered through a codec).
"""

from __future__ import annotations

import threading
from dataclasses import dataclass
from typing import Dict, List, Optional, Tuple

from repro import obs
from repro.core.api import parse_frame
from repro.core.store import ShardedPromptStore, content_key

MIN_DICT_RECORDS = 4    # below this, a dictionary cannot pay for itself
DICT_SAMPLE_CAP = 128   # train on at most this many records per shard
MAX_DICT_BYTES = 16384


@dataclass
class CompactionResult:
    shard_id: int
    n_records: int
    n_caught_up: int
    bytes_before: int
    bytes_after: int
    method: Optional[str]       # pipeline the shard was re-encoded with
    reencoded: bool
    wall_s: float
    dict_bytes: int = 0         # sidecar size of the adopted dictionary

    @property
    def bytes_reclaimed(self) -> int:
        return max(self.bytes_before - self.bytes_after, 0)

    @property
    def used_dict(self) -> bool:
        return self.dict_bytes > 0


def _candidate_methods(store: ShardedPromptStore) -> List[str]:
    from repro.core.api import METHODS

    if store.compressor.tokenizer is None:
        return ["zstd"]
    return list(METHODS)


def _train_dicts(store: ShardedPromptStore,
                 texts: List[str]) -> Dict[str, bytes]:
    """One trained dictionary per dict-capable candidate method, trained
    on the byte-stage payloads that method would actually compress (utf-8
    text for zstd, packed token streams for hybrid)."""
    from repro.core.zstd_backend import DICT_BACKENDS, train_dictionary_bytes

    comp = store.compressor
    if comp.backend not in DICT_BACKENDS or len(texts) < MIN_DICT_RECORDS:
        return {}
    out: Dict[str, bytes] = {}
    for method in _candidate_methods(store):
        if method == "token":  # no byte stage to apply a dictionary to
            continue
        payloads = comp.byte_stage_payloads(texts, method)
        step = max(1, len(payloads) // DICT_SAMPLE_CAP)
        sample = payloads[::step][:DICT_SAMPLE_CAP]
        size = min(MAX_DICT_BYTES, max(512, sum(map(len, sample)) // 4))
        d = train_dictionary_bytes(sample, size)
        if d:
            out[method] = d
    return out


def _scratch_compressor(comp):
    """A compressor with the identical frame-relevant config but its own
    dictionary registry (same tokenizer object, so no vocab retraining)."""
    from repro.core.api import PromptCompressor

    return PromptCompressor(tokenizer=comp.tokenizer, method=comp.method,
                            level=comp.level, backend=comp.backend,
                            scheme=comp.scheme)


def _carried_dictionary(store: ShardedPromptStore,
                        entries: List[dict]) -> Optional[bytes]:
    """The dictionary the shard's current frames reference, if any (a
    generation holds at most one — its own sidecar's).  A rebuild that
    keeps these blobs must re-persist it, or they become undecodable on
    reopen."""
    for e in entries:
        try:
            fp = parse_frame(e["blob"]).dict_fp
        except ValueError:
            continue
        if fp is not None:
            return store.compressor.dictionary_for(fp)
    return None


def compact_shard(store: ShardedPromptStore, shard_id: int,
                  reselect: bool = True,
                  train_dict: bool = True) -> Optional[CompactionResult]:
    """Rebuild one shard; returns None if another compactor holds it (or
    a rebalance replaced the layout mid-acquire).

    Phases (heavy work happens with no store lock held):
    1. snapshot the live records + blobs;
    2. integrity-check every text against its content key;
    3. if `reselect` and the shard is clean: encode the texts through every
       candidate method pipeline — plus, with `train_dict`, each
       dict-capable method primed with a freshly trained dictionary
       (charged its sidecar size) — pick the smallest total, and keep the
       re-encoded blobs only on a strict win;
    4. `swap_shard` — catch-up + new generation (+ dict sidecar) + atomic
       meta commit.
    """
    if store.is_quarantined(shard_id):
        # the scrubber owns this shard now: rewriting generations would
        # launder the corrupt blobs it preserved as forensics — repair
        # (repro.service.scrub) lifts the quarantine, then compaction
        # resumes
        return None
    try:
        lock = store.compaction_lock(shard_id)
    except IndexError:  # raced a shrinking rebalance
        return None
    if not lock.acquire(blocking=False):
        return None
    try:
        # a rebalance may have swapped the layout (and its lock table)
        # between lookup and acquire: holding a dead layout's lock
        # excludes nothing, so bow out
        try:
            if store.compaction_lock(shard_id) is not lock:
                return None
        except IndexError:
            return None
        # the span is also the product's timer: CompactionResult.wall_s
        # comes from span.elapsed_s, which keeps measuring with
        # REPRO_OBS=0 (see repro.obs.trace.NullSpan)
        with obs.span("compaction.shard") as span:
            result = _rebuild_shard(store, shard_id, reselect, train_dict,
                                    span)
        obs.counter("compaction.reclaimed_bytes").inc(result.bytes_reclaimed)
        return result
    finally:
        lock.release()


def _rebuild_shard(store: ShardedPromptStore, shard_id: int, reselect: bool,
                   train_dict: bool, span) -> CompactionResult:
    """Phases 1-4 of :func:`compact_shard`; runs with the compaction lock
    held and the layout validated."""
    recs = store.shard_records(shard_id)
    blobs = store.read_records(shard_id, recs)
    entries = [
        {"key": r["key"], "seq": r["seq"], "method": r["method"],
         "n_chars": r["n_chars"], "blob": b}
        for r, b in zip(recs, blobs)
    ]
    carry_dict = _carried_dictionary(store, entries)
    dictionary = carry_dict  # sidecar the rebuild must persist
    chosen: Optional[str] = None
    reencoded = False
    if reselect and entries:
        try:
            texts = store.compressor.decompress_batch(blobs)
            clean = all(content_key(t) == r["key"]
                        for t, r in zip(texts, recs))
        except Exception:
            clean = False
        if clean:
            # keeping the current encoding keeps its sidecar too, so
            # the incumbent is charged the dictionary's own size —
            # same rule every dictionary candidate plays by
            best_total = sum(len(b) for b in blobs) + len(carry_dict or b"")
            best: Optional[Tuple[List[bytes], Optional[bytes]]] = None
            for method in _candidate_methods(store):
                new_blobs = store.compressor.compress_batch(texts, method)
                total = sum(len(b) for b in new_blobs)
                if total < best_total:
                    best_total, best, chosen = total, (new_blobs, None), method
            if train_dict:
                # score dictionary candidates on a throwaway compressor:
                # registering every loser on the live one would pin its
                # bytes (and a cached pipeline) for the process lifetime.
                # Frames depend only on the config, so the winner's blobs
                # are valid as-is; swap_shard registers its dictionary.
                scratch = _scratch_compressor(store.compressor)
                for method, d in _train_dicts(store, texts).items():
                    dict_blobs = scratch.compress_batch(
                        texts, method, dictionary=d)
                    total = sum(len(b) for b in dict_blobs) + len(d)
                    if total < best_total:
                        best_total, best, chosen = (
                            total, (dict_blobs, d), method)
            if best is not None:
                reencoded = True
                new_blobs, dictionary = best
                for e, b in zip(entries, new_blobs):
                    e["blob"] = b
                    e["method"] = chosen
    swap = store.swap_shard(shard_id, entries, dictionary=dictionary)
    return CompactionResult(
        shard_id=shard_id,
        n_records=swap["n_records"],
        n_caught_up=swap["n_caught_up"],
        bytes_before=swap["bytes_before"],
        bytes_after=swap["bytes_after"],
        method=chosen,
        reencoded=reencoded,
        wall_s=span.elapsed_s,
        dict_bytes=len(dictionary or b""),
    )


def compact_store(store: ShardedPromptStore, reselect: bool = True,
                  train_dict: bool = True) -> List[CompactionResult]:
    """Compact every shard (skipping any a background compactor holds)."""
    out = []
    for shard_id in range(store.n_shards):
        if shard_id >= store.n_shards:  # shrunk by a concurrent rebalance
            break
        res = compact_shard(store, shard_id, reselect=reselect,
                            train_dict=train_dict)
        if res is not None:
            out.append(res)
    return out


class BackgroundCompactor:
    """Periodic scan-and-compact thread.

    Every `interval_s` it reads each shard's live/dead byte accounting
    (`store.shard_stats`) and rebuilds shards whose dead ratio exceeds
    `trigger_dead_ratio` (with at least `min_dead_bytes` reclaimable, so
    tiny stores don't churn).  `force_reselect_every` full passes, clean
    shards are compacted too, to pick up stage-selection wins that dead
    bytes alone would never trigger (0 disables that sweep).
    """

    def __init__(self, store: ShardedPromptStore, interval_s: float = 5.0,
                 trigger_dead_ratio: float = 0.25, min_dead_bytes: int = 4096,
                 reselect: bool = True, force_reselect_every: int = 0,
                 train_dict: bool = True) -> None:
        self._store = store
        self.interval_s = float(interval_s)
        self.trigger_dead_ratio = float(trigger_dead_ratio)
        self.min_dead_bytes = int(min_dead_bytes)
        self.reselect = reselect
        self.force_reselect_every = int(force_reselect_every)
        self.train_dict = train_dict
        self._stop_event = threading.Event()
        self._thread: Optional[threading.Thread] = None
        # registry-backed counters (always real; see repro.obs) — each
        # is internally locked, so no extra compactor-wide lock is needed
        self._passes = obs.owned_counter("compaction.passes")
        self._compactions = obs.owned_counter("compaction.compactions")
        self._bytes_reclaimed = obs.owned_counter("compaction.bytes_reclaimed")
        self._errors = obs.owned_counter("compaction.errors")

    # -- lifecycle -------------------------------------------------------------

    def start(self) -> "BackgroundCompactor":
        if self._thread is not None:
            raise RuntimeError("compactor already started")
        self._thread = threading.Thread(target=self._loop,
                                        name="shard-compactor", daemon=True)
        self._thread.start()
        return self

    def stop(self) -> None:
        """Idempotent: finish the in-flight shard (never torn — the swap
        is atomic regardless) and join."""
        self._stop_event.set()
        if self._thread is not None:
            self._thread.join()
            self._thread = None

    # -- scan loop -------------------------------------------------------------

    def _loop(self) -> None:
        while not self._stop_event.wait(self.interval_s):
            self.run_pass()

    def run_pass(self) -> List[CompactionResult]:
        """One scan over all shards (also callable synchronously)."""
        self._passes.inc()
        sweep = (self.force_reselect_every > 0
                 and self._passes.value % self.force_reselect_every == 0)
        results: List[CompactionResult] = []
        with obs.span("compaction.pass"):
            return self._scan_shards(sweep, results)

    def _scan_shards(self, sweep: bool,
                     results: List[CompactionResult]
                     ) -> List[CompactionResult]:
        try:
            all_stats = self._store.all_shard_stats()  # one index pass
        except Exception:  # e.g. racing a rebalance's layout teardown
            self._errors.inc()
            return results
        for shard_id in range(len(all_stats)):
            # a concurrent rebalance may change n_shards mid-pass;
            # compact_shard revalidates and bows out on a dead layout
            if self._stop_event.is_set() and not sweep:
                break
            try:
                st = all_stats[shard_id]
                dead, size = st["dead_bytes"], max(st["file_bytes"], 1)
                due = (dead >= self.min_dead_bytes
                       and dead / size >= self.trigger_dead_ratio)
                if not due and not (sweep and st["n_records"]):
                    continue
                res = compact_shard(self._store, shard_id,
                                    reselect=self.reselect,
                                    train_dict=self.train_dict)
            except Exception:
                self._errors.inc()
                continue
            if res is not None:
                results.append(res)
                self._compactions.inc()
                self._bytes_reclaimed.inc(res.bytes_reclaimed)
        return results

    def stats(self) -> dict:
        return {
            "passes": self._passes.value,
            "compactions": self._compactions.value,
            "bytes_reclaimed": self._bytes_reclaimed.value,
            "errors": self._errors.value,
            "interval_s": self.interval_s,
            "trigger_dead_ratio": self.trigger_dead_ratio,
            "min_dead_bytes": self.min_dead_bytes,
        }
