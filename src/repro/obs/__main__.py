"""CLI: ``python -m repro.obs render SNAP.json`` pretty-prints a saved
snapshot; ``python -m repro.obs diff BEFORE.json AFTER.json`` turns two
snapshots into rates (counter deltas/s, histogram sample rates and
in-window means).  Exit codes: 0 ok, 2 usage error.
"""

from __future__ import annotations

import argparse
import json
import sys
from typing import List, Optional

from repro.obs import export


def _load(path: str) -> dict:
    with open(path, encoding="utf-8") as fh:
        return json.load(fh)


def main(argv: Optional[List[str]] = None) -> int:
    parser = argparse.ArgumentParser(
        prog="python -m repro.obs",
        description="render/diff repro.obs snapshots")
    sub = parser.add_subparsers(dest="cmd", required=True)
    p_render = sub.add_parser("render", help="pretty-print one snapshot")
    p_render.add_argument("snapshot", help="snapshot JSON path")
    p_diff = sub.add_parser("diff", help="rates between two snapshots")
    p_diff.add_argument("before", help="earlier snapshot JSON path")
    p_diff.add_argument("after", help="later snapshot JSON path")
    p_diff.add_argument("--json", action="store_true",
                        help="emit the diff as JSON instead of text")
    args = parser.parse_args(argv)

    try:
        if args.cmd == "render":
            print(export.render(_load(args.snapshot)))
        else:
            d = export.diff(_load(args.before), _load(args.after))
            if args.json:
                print(json.dumps(d, indent=1, sort_keys=True))
            else:
                print(export.render_diff(d))
    except (OSError, ValueError, KeyError) as exc:
        print(f"error: {exc}", file=sys.stderr)
        return 2
    return 0


if __name__ == "__main__":
    sys.exit(main())
